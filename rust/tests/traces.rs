//! fed::traces integration tests.
//!
//! The regression tests prove the trace-replay subsystem is faithful:
//! recording a run's realized per-round latencies/availability and
//! replaying the CSV through `--speed trace:FILE` reproduces the run
//! bit-for-bit — wall-clock, losses, and every trace column — for a
//! static, a jitter, a Markov and a clustered-availability scenario
//! (the ISSUE acceptance). Parse errors carry file name + line number,
//! the checked-in fixture replays with its always-offline straggler
//! never charged to the clock nor fed to the speed estimator, and the
//! headline Hard-et-al. test shows correlated (diurnal) availability
//! flipping the FLANP-vs-FedGATE winner relative to the i.i.d.
//! availability control at the same 25% duty.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::{ClientFleet, SystemModel, Trace};
use flanp::setup;
use std::path::{Path, PathBuf};

fn base_cfg(solver: SolverKind, n: usize, s: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(solver, "linreg_d25", n, s);
    cfg.tau = 10;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.max_rounds = 2000;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg.seed = 3;
    cfg
}

fn run(cfg: &ExperimentConfig) -> (Trace, ClientFleet) {
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
    let trace = run_solver(&engine, &mut fleet, cfg).unwrap();
    (trace, fleet)
}

fn assert_traces_identical(a: &Trace, b: &Trace) {
    assert_eq!(a.rounds.len(), b.rounds.len());
    assert_eq!(a.stage_transitions, b.stage_transitions);
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.finished, b.finished);
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.time, y.time, "round {}", x.round);
        assert_eq!(x.loss_full, y.loss_full, "round {}", x.round);
        assert_eq!(x.loss_active, y.loss_active, "round {}", x.round);
        assert_eq!(x.grad_norm_sq, y.grad_norm_sq, "round {}", x.round);
        assert_eq!(x.participants, y.participants, "round {}", x.round);
        assert_eq!(x.dropped, y.dropped, "round {}", x.round);
        assert_eq!(x.missed, y.missed, "round {}", x.round);
        assert_eq!(x.reranks, y.reranks, "round {}", x.round);
        assert_eq!(x.available, y.available, "round {}", x.round);
    }
}

/// Record a run under `spec`, replay the exported CSV, and assert the
/// replay is bit-identical — including the re-recorded trace itself
/// (record ∘ replay is a fixed point on the CSV bytes).
fn record_replay_roundtrip(spec: &str, solver: SolverKind, file: &str) {
    let mut rec_cfg = base_cfg(solver.clone(), 16, 50);
    rec_cfg.system = SystemModel::parse(spec).unwrap();
    rec_cfg.record_trace = true;
    let (t_rec, fleet) = run(&rec_cfg);
    let path = std::env::temp_dir().join(file);
    fleet.write_recorded_trace(&path).unwrap();

    // replay in wrap mode: identical before exhaustion (the replay is
    // deterministic, so it never outlives the recorded rounds), and
    // immune to validation's rejection of hold replays whose recorded
    // final round happened to leave everyone offline (possible for the
    // clustered-availability recording)
    let mut rep_cfg = base_cfg(solver, 16, 50);
    rep_cfg.system =
        SystemModel::parse(&format!("trace:{}:wrap", path.display())).unwrap();
    rep_cfg.record_trace = true;
    let (t_rep, rep_fleet) = run(&rep_cfg);
    assert_traces_identical(&t_rec, &t_rep);
    let original = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        rep_fleet.recorded_trace().unwrap().to_csv(),
        original,
        "re-recorded replay CSV diverged from the recorded one ({spec})"
    );
}

#[test]
fn record_replay_static_is_bit_identical() {
    record_replay_roundtrip(
        "uniform:50:500",
        SolverKind::Flanp,
        "flanp_traces_static.csv",
    );
}

#[test]
fn record_replay_markov_is_bit_identical() {
    // ISSUE acceptance: a time-varying Markov run records, replays and
    // re-records without a bit of drift in wall-clock, losses or any
    // trace column
    record_replay_roundtrip(
        "markov:4:0.1:0.5:uniform:50:500",
        SolverKind::Flanp,
        "flanp_traces_markov.csv",
    );
}

#[test]
fn record_replay_jitter_is_bit_identical() {
    record_replay_roundtrip(
        "jitter:0.3:uniform:50:500",
        SolverKind::FedGate,
        "flanp_traces_jitter.csv",
    );
}

#[test]
fn record_replay_clustered_availability_is_bit_identical() {
    // correlated outages roundtrip too: the recorded availability column
    // replays as observable offline rounds with identical accounting
    record_replay_roundtrip(
        "avail:cluster:4:0.1:0.3:uniform:50:500",
        SolverKind::FedGate,
        "flanp_traces_cluster.csv",
    );
}

#[test]
fn trace_parse_errors_carry_file_and_line() {
    let dir = std::env::temp_dir();
    let cases: Vec<(&str, &str, &str)> = vec![
        (
            "flanp_traces_bad_header.csv",
            "round,client,latency\n0,0,10\n",
            ":1:",
        ),
        (
            "flanp_traces_bad_time.csv",
            "round,client,time,available\n0,0,10,1\n0,1,oops,1\n",
            ":3:",
        ),
        (
            "flanp_traces_bad_order.csv",
            "round,client,time,available\n0,1,10,1\n",
            ":2:",
        ),
        (
            "flanp_traces_ragged.csv",
            "round,client,time,available\n0,0,10,1\n0,1,20,1\n1,0,10,1\n",
            ":4:",
        ),
    ];
    for (file, text, line) in cases {
        let path: PathBuf = dir.join(file);
        std::fs::write(&path, text).unwrap();
        let spec = format!("trace:{}", path.display());
        let e = SystemModel::parse(&spec).unwrap_err();
        let name = path.display().to_string();
        assert!(e.contains(&name), "error '{e}' does not name '{name}'");
        assert!(e.contains(line), "error '{e}' lacks line marker '{line}'");
    }
    // an unreadable file names the path too
    let e = SystemModel::parse("trace:/no/such/flanp_trace.csv").unwrap_err();
    assert!(e.contains("/no/such/flanp_trace.csv"), "{e}");
}

fn fixture_spec(mode: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/smoke_trace.csv");
    format!("trace:{}{mode}", path.display())
}

#[test]
fn fixture_replay_never_charges_or_estimates_offline_clients() {
    // the checked-in fixture: 4 clients at 10/20/30/400, with the
    // slowest (client 3) available only in the probe round. Replaying it
    // must (a) never charge client 3's 400 to the clock — round cost is
    // tau * 30 — and (b) never feed client 3 to the speed estimator.
    let mut cfg = base_cfg(SolverKind::FedGate, 4, 50);
    cfg.system = SystemModel::parse(&fixture_spec("")).unwrap();
    cfg.max_rounds = 5;
    cfg.eval_every = 1;
    cfg.c_stat = 1e-12; // timing-only run: never reaches accuracy
    let (t, fleet) = run(&cfg);
    assert_eq!(t.rounds.len(), 6, "initial row + 5 rounds");
    for w in t.rounds.windows(2) {
        let dt = w[1].time - w[0].time;
        assert!(
            (dt - 10.0 * 30.0).abs() < 1e-9,
            "round {} cost {dt} charged the offline straggler",
            w[1].round
        );
        assert_eq!(w[1].available, 3, "available column");
        assert_eq!(w[1].dropped, 0, "offline is not dropout");
        assert_eq!(w[1].missed, 0);
    }
    // the offline client was never observed; its estimate is still the
    // probe prior
    assert_eq!(fleet.estimates.observations(3), 0);
    assert_eq!(fleet.estimates.estimate(3), 400.0);
    assert!(fleet.estimates.observations(0) > 0);
}

#[test]
fn hold_and_wrap_extend_the_fixture_differently() {
    // 7 trace rounds, probe consumes round 0. Under hold, every round
    // past the end repeats the last (client 3 offline, cost 300); under
    // wrap, realized round 7 cycles back to round 0 where client 3 is
    // ONLINE at 400 — that round costs tau * 400.
    let mut hold = base_cfg(SolverKind::FedGate, 4, 50);
    hold.system = SystemModel::parse(&fixture_spec(":hold")).unwrap();
    hold.max_rounds = 10;
    hold.eval_every = 1;
    hold.c_stat = 1e-12;
    let (t_hold, _) = run(&hold);
    for w in t_hold.rounds.windows(2) {
        assert!((w[1].time - w[0].time - 300.0).abs() < 1e-9);
    }
    let mut wrap = base_cfg(SolverKind::FedGate, 4, 50);
    wrap.system = SystemModel::parse(&fixture_spec(":wrap")).unwrap();
    wrap.max_rounds = 10;
    wrap.eval_every = 1;
    wrap.c_stat = 1e-12;
    let (t_wrap, _) = run(&wrap);
    // training round k is realized round k (the probe took idx 0), so
    // the wrapped replay hits trace round 0 at trace row 7
    let dt7 = t_wrap.rounds[7].time - t_wrap.rounds[6].time;
    assert!(
        (dt7 - 10.0 * 400.0).abs() < 1e-9,
        "wrapped round 7 cost {dt7}, expected 4000"
    );
    assert_eq!(t_wrap.rounds[7].available, 4);
}

#[test]
fn trace_width_must_match_the_fleet() {
    let mut cfg = base_cfg(SolverKind::FedGate, 8, 50);
    cfg.system = SystemModel::parse(&fixture_spec("")).unwrap();
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let e = cfg.validate(engine.meta().batch).unwrap_err();
    assert!(
        e.contains("4") && e.contains("8"),
        "width mismatch error '{e}' lacks the counts"
    );
}

#[test]
fn diurnal_correlated_availability_flips_the_winner() {
    // The Hard-et-al. effect (the ISSUE acceptance): correlated
    // availability changes which algorithm wins. Control: i.i.d.
    // availability at 25% — FLANP's adaptive prefix still beats
    // full-participation FedGATE (its all-offline prefix rounds charge
    // one cheap estimate-priced wait over a tiny fast prefix, while
    // every FedGATE round is priced by the slowest of ~4 online
    // clients drawn from the whole speed range). Treatment:
    // diurnal ROTATION at the same 25% marginal availability — FLANP's
    // small fastest-prefix must now WAIT, on the clock, for its two
    // designated clients' windows to come around, while FedGATE always
    // finds the rotating 4-client online cohort. The ranking flips.
    let time_to = |spec: &str, solver: SolverKind| -> Trace {
        let mut cfg = base_cfg(solver, 16, 50);
        cfg.system = SystemModel::parse(spec).unwrap();
        cfg.max_rounds = 12_000;
        let (t, _) = run(&cfg);
        t
    };
    let iid = "avail:iid:0.25:uniform:50:500";
    let diu = "avail:diurnal:40000:0.25:1:uniform:50:500";
    let f_iid = time_to(iid, SolverKind::Flanp);
    let g_iid = time_to(iid, SolverKind::FedGate);
    let f_diu = time_to(diu, SolverKind::Flanp);
    let g_diu = time_to(diu, SolverKind::FedGate);
    // compare at a loss every run actually reaches within its budget
    let target = 1.02
        * [&f_iid, &g_iid, &f_diu, &g_diu]
            .iter()
            .map(|t| t.last().unwrap().loss_full)
            .fold(f64::MIN, f64::max);
    let tt = |t: &Trace, what: &str| -> f64 {
        t.time_to_loss(target)
            .unwrap_or_else(|| panic!("{what} never reached loss {target}"))
    };
    let (tf_iid, tg_iid) = (tt(&f_iid, "flanp/iid"), tt(&g_iid, "gate/iid"));
    let (tf_diu, tg_diu) = (tt(&f_diu, "flanp/diu"), tt(&g_diu, "gate/diu"));
    assert!(
        tf_iid < tg_iid,
        "uncorrelated control: flanp {tf_iid} !< fedgate {tg_iid}"
    );
    assert!(
        tg_diu < tf_diu,
        "diurnal rotation must flip the winner: fedgate {tg_diu} !< flanp {tf_diu}"
    );
}

#[test]
fn diurnal_waits_jump_the_clock_to_the_next_window() {
    // deterministic outage windows advance the clock to the cohort's
    // next window (the server genuinely waits, in one charged jump)
    let mut diu = base_cfg(SolverKind::Flanp, 16, 50);
    // spread 0: one shared window — rounds realized inside the off
    // window must jump the clock forward
    diu.system =
        SystemModel::parse("avail:diurnal:50000:0.5:0:uniform:50:500")
            .unwrap();
    diu.max_rounds = 400;
    diu.c_stat = 1e-12; // timing-only
    let (t, _) = run(&diu);
    let waited = t
        .rounds
        .windows(2)
        .any(|w| w[1].available == 0 && w[1].time > w[0].time + 1000.0);
    assert!(waited, "no charged diurnal wait in {} rounds", t.rounds.len());
}

#[test]
fn stochastic_all_down_rounds_charge_an_estimate_priced_wait() {
    // the ROADMAP time-basis gap, closed: a stochastic outage with no
    // computable wake time used to make an all-down round a FREE retry,
    // letting a solver spin through dark rounds at zero cost. It must
    // now charge one estimate-priced waiting round — `tau * max est`
    // over the cohort — every time the whole cohort is offline.
    let mut cfg = base_cfg(SolverKind::Flanp, 8, 50);
    // one cluster, p_fail 1, p_recover 0: permanently dark from the
    // first chain step, so EVERY round is an all-down waiting round
    cfg.system = SystemModel::parse("avail:cluster:1:1:0:homog:100").unwrap();
    cfg.max_rounds = 25;
    cfg.c_stat = 1e-12; // timing-only
    let (t, _) = run(&cfg);
    // rounds[0] is the pre-training evaluation row; every later round
    // is an all-down wait
    assert!(t.rounds.len() >= 3, "expected recorded waiting rounds");
    let mut prev = 0.0;
    for r in &t.rounds[1..] {
        assert_eq!(r.available, 0, "round {} unexpectedly online", r.round);
        // homog:100 estimates are exactly 100; tau = 10 → 1000 charged
        assert!(
            (r.time - prev - 1000.0).abs() < 1e-9,
            "round {} charged {} (want tau * max est = 1000)",
            r.round,
            r.time - prev
        );
        prev = r.time;
    }
}
