//! Observability integration tests (`fed::observe`).
//!
//! Four properties, end-to-end through `run_solver_with`:
//!
//! * **inertness** — an ENABLED collect-only observer leaves the
//!   solver byte-stream untouched: the trace CSV is byte-identical to
//!   the plain `run_solver` path (which `tests/golden.rs` pins against
//!   the committed fixtures). Observability may read the round loop,
//!   never steer it.
//! * **schema** — every line a [`JsonlObserver`] writes parses back
//!   through [`Event::from_json`] (the Rust twin of
//!   `ci/check_events.py`), after a `flanp-events/v1` header.
//! * **accounting** — per deadline round, the per-client events
//!   partition the cohort: `arrived + missed + cancelled + offline ==
//!   cohort`, and the per-round missed/cancelled event counts equal the
//!   trace CSV's columns row by row.
//! * **summary** — the `flanp-summary/v1` totals agree with the trace
//!   sums, and the event counters agree with the event log.
//!
//! The scenario is the golden diurnal+jitter rotation with the full
//! selection stack on top (overselect:1.3, tiers:3, quantile deadline)
//! so cancellations, misses, offline skips and tier churn all occur.

use flanp::coordinator::{
    run_solver, run_solver_with, ExperimentConfig, SolverKind,
};
use flanp::fed::{
    DeadlinePolicy, Event, EventKind, JsonlObserver, NoopObserver, Observe,
    SystemModel, TierPolicy, EVENTS_SCHEMA,
};
use flanp::setup;
use flanp::util::json::Json;
use std::collections::HashMap;
use std::path::PathBuf;

/// The golden scenario (`tests/golden.rs`): diurnal availability
/// rotation + log-normal speed jitter.
const SCENARIO: &str = "avail:diurnal:20000:0.5:1:jitter:0.2:uniform:50:500";

/// The golden FLANP config, byte-comparable to the committed fixture.
fn golden_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "linreg_d25", 16, 50);
    cfg.eta = 0.05;
    cfg.tau = 10;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.system = SystemModel::parse(SCENARIO).unwrap();
    cfg.seed = 7;
    cfg.max_rounds = 120;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg
}

/// The golden config with the full selection stack on top — the
/// ISSUE's acceptance scenario: every per-client outcome kind occurs.
fn rich_cfg() -> ExperimentConfig {
    let mut cfg = golden_cfg();
    cfg.tiers = Some(TierPolicy::parse("tiers:3").unwrap());
    cfg.overselect = 1.3;
    cfg.deadline = DeadlinePolicy::parse("quantile:0.9").unwrap();
    cfg
}

fn run_with(cfg: &ExperimentConfig, obs: &mut Observe) -> flanp::fed::Trace {
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
    run_solver_with(&engine, &mut fleet, cfg, obs).unwrap()
}

/// Run `cfg` with a JSONL sink + registry, returning the parsed events
/// and the trace. The sidecar lives in the target tmp dir.
fn run_logged(cfg: &ExperimentConfig, tag: &str) -> (Vec<Event>, flanp::fed::Trace) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target/tmp");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("observe_{tag}_{}.events.jsonl", std::process::id()));
    let mut obs = Observe::new(
        Box::new(JsonlObserver::create(&path).unwrap()),
        true,
    );
    let trace = run_with(cfg, &mut obs);
    drop(obs); // flush the sink
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut lines = text.lines();
    let header = Json::parse(lines.next().expect("empty event log")).unwrap();
    assert_eq!(header.req_str("schema").unwrap(), EVENTS_SCHEMA);
    let events: Vec<Event> = lines
        .map(|l| {
            Event::from_json(&Json::parse(l).unwrap())
                .unwrap_or_else(|e| panic!("bad event line '{l}': {e}"))
        })
        .collect();
    assert!(!events.is_empty(), "rich run emitted no events");
    (events, trace)
}

/// An enabled (collect-only) observer must not perturb the solver:
/// same RNG consumption, same clock arithmetic, same trace bytes as
/// the plain path the golden fixtures pin.
#[test]
fn enabled_observer_is_inert() {
    for cfg in [golden_cfg(), rich_cfg()] {
        let engine = setup::native_from_name(&cfg.model).unwrap();
        let mut fleet =
            setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0).unwrap();
        let plain = run_solver(&engine, &mut fleet, &cfg).unwrap().to_csv();

        let mut obs = Observe::new(Box::new(NoopObserver), true);
        assert!(obs.enabled());
        let mut fleet2 =
            setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0).unwrap();
        let observed =
            run_solver_with(&engine, &mut fleet2, &cfg, &mut obs).unwrap();
        assert_eq!(
            plain,
            observed.to_csv(),
            "collect-only observer changed the trace byte-stream"
        );
    }
}

/// Every JSONL line roundtrips through the schema; the kinds seen
/// cover the full per-client outcome space of the rich scenario.
#[test]
fn jsonl_schema_roundtrip() {
    let (events, _) = run_logged(&rich_cfg(), "schema");
    let mut seen = [false; flanp::fed::observe::NUM_KINDS];
    for ev in &events {
        seen[ev.kind as usize] = true;
        // per-client kinds carry a client id; round-level kinds don't
        match ev.kind {
            EventKind::Arrived
            | EventKind::Missed
            | EventKind::Cancelled
            | EventKind::Offline
            | EventKind::Censored => {
                assert!(ev.client.is_some(), "{:?} without client", ev.kind)
            }
            EventKind::Deadline | EventKind::Wait | EventKind::Stage => {
                assert!(ev.client.is_none(), "{:?} with client", ev.kind)
            }
            _ => {}
        }
    }
    // Rerank/TierPromote/TierDemote/Missed/Wait depend on whether the
    // jitter actually breaches the hysteresis band (resp. on wait
    // rounds occurring), so only the kinds the scenario guarantees:
    for kind in [
        EventKind::CohortSelected,
        EventKind::CohortPadded,
        EventKind::Deadline,
        EventKind::Arrived,
        EventKind::Cancelled,
        EventKind::Offline,
        EventKind::Censored,
        EventKind::Stage,
    ] {
        assert!(seen[kind as usize], "rich scenario never emitted {kind:?}");
    }
}

/// THE accounting invariant: in every round that priced a deadline,
/// the per-client events partition the cohort, and the missed /
/// cancelled counts match the trace CSV row for that round.
#[test]
fn per_round_accounting_matches_trace() {
    let (events, trace) = run_logged(&rich_cfg(), "accounting");
    #[derive(Default)]
    struct Tally {
        cohort: Option<usize>,
        arrived: usize,
        missed: usize,
        cancelled: usize,
        offline: usize,
    }
    let mut rounds: HashMap<usize, Tally> = HashMap::new();
    for ev in &events {
        let t = rounds.entry(ev.round).or_default();
        match ev.kind {
            EventKind::Deadline => {
                assert!(
                    t.cohort.is_none(),
                    "two deadline events in round {}",
                    ev.round
                );
                t.cohort = Some(ev.detail.req_usize("cohort").unwrap());
            }
            EventKind::Arrived => t.arrived += 1,
            EventKind::Missed => t.missed += 1,
            EventKind::Cancelled => t.cancelled += 1,
            EventKind::Offline => t.offline += 1,
            _ => {}
        }
    }
    let rows: HashMap<usize, &flanp::fed::RoundRecord> =
        trace.rounds.iter().map(|r| (r.round, r)).collect();
    let mut deadline_rounds = 0usize;
    for (r, t) in &rounds {
        let Some(cohort) = t.cohort else {
            // wait rounds price no deadline and train nobody
            assert_eq!(
                (t.arrived, t.missed, t.cancelled, t.offline),
                (0, 0, 0, 0),
                "per-client events in deadline-less round {r}"
            );
            continue;
        };
        deadline_rounds += 1;
        assert_eq!(
            t.arrived + t.missed + t.cancelled + t.offline,
            cohort,
            "round {r}: events do not partition the cohort"
        );
        let row = rows
            .get(r)
            .unwrap_or_else(|| panic!("no trace row for event round {r}"));
        assert_eq!(t.missed, row.missed, "round {r}: missed != trace");
        assert_eq!(t.cancelled, row.cancelled, "round {r}: cancelled != trace");
    }
    assert!(deadline_rounds > 0, "no deadline rounds observed");
}

/// The run summary's totals block equals the trace sums and its event
/// counters equal the event log.
#[test]
fn summary_totals_match_trace() {
    let cfg = rich_cfg();
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0).unwrap();
    let mut obs = Observe::new(Box::new(NoopObserver), true);
    let trace = run_solver_with(&engine, &mut fleet, &cfg, &mut obs).unwrap();

    let s = obs.summary_json(&trace, 1.0);
    assert_eq!(s.req_str("schema").unwrap(), "flanp-summary/v1");
    let totals = s.req("totals").unwrap();
    assert_eq!(totals.req_usize("missed").unwrap(), trace.total_missed());
    assert_eq!(
        totals.req_usize("cancelled").unwrap(),
        trace.total_cancelled()
    );
    assert_eq!(
        totals.req("min_available").unwrap().as_usize(),
        trace.min_available(),
        "summary min_available != trace"
    );
    // two independent accounting paths agree: the per-kind event
    // counters vs the trace columns deadline_round filled in
    let ev = s.req("events").unwrap();
    assert_eq!(ev.req_usize("missed").unwrap(), trace.total_missed());
    assert_eq!(ev.req_usize("cancelled").unwrap(), trace.total_cancelled());
    assert_eq!(
        s.req("rounds").unwrap().as_usize().unwrap(),
        trace.rounds.len() - 1
    );
    // the registry saw estimator errors for every arrived client
    assert!(
        s.req("estimator_error").unwrap().req_usize("count").unwrap() > 0,
        "no estimator-error observations collected"
    );
}
