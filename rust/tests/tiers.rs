//! fed::tiers acceptance + regression tests.
//!
//! The regression tests prove tier caching is a strict superset of the
//! estimate-based ranking it replaces: under a static scenario the
//! exact-fixed-point EWMA keeps the cached tier ranking bit-identical
//! to the live estimate ranking, so a tiered FLANP run whose tier
//! boundaries align with the stage doubling reproduces the plain run's
//! prefix sequence, losses and wall-clock to the bit — with zero
//! re-tier events. The acceptance test is the ISSUE's headline: under
//! Markov drift, tier-cached FLANP reaches the statistical-accuracy
//! stop with <= 10% of the re-rank events of per-round individual
//! re-ranking while its wall-clock stays within 5%.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::{SystemModel, TierPolicy, Trace};
use flanp::setup;

fn base_cfg(solver: SolverKind, n: usize, s: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(solver, "linreg_d25", n, s);
    cfg.tau = 10;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.max_rounds = 2000;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg.seed = 3;
    cfg
}

fn run(cfg: &ExperimentConfig) -> Trace {
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
    run_solver(&engine, &mut fleet, cfg).unwrap()
}

#[test]
fn static_tier_cached_ranking_is_bit_identical_to_estimate_ranking() {
    // ISSUE acceptance: under a static scenario the tier-cached FLANP
    // ranking reproduces the estimate-based ranking exactly — same
    // prefix sequence, same wall-clock, same losses, to the bit. With
    // tiers:8 over 16 clients the tier boundaries (2, 4, 6, ..., 16)
    // contain every doubling stage size, so snapping is the identity
    // and any divergence would be a real tiering bug.
    let plain = base_cfg(SolverKind::Flanp, 16, 50);
    let mut tiered = plain.clone();
    tiered.tiers = Some(TierPolicy::parse("tiers:8").unwrap());
    let (t_plain, t_tiered) = (run(&plain), run(&tiered));
    assert!(t_plain.finished && t_tiered.finished);
    assert_eq!(t_plain.stage_transitions, t_tiered.stage_transitions);
    assert_eq!(t_plain.total_time, t_tiered.total_time);
    assert_eq!(t_plain.rounds.len(), t_tiered.rounds.len());
    for (a, b) in t_plain.rounds.iter().zip(&t_tiered.rounds) {
        assert_eq!(a.time, b.time, "round {}", a.round);
        assert_eq!(a.participants, b.participants, "round {}", a.round);
        assert_eq!(a.loss_full, b.loss_full, "round {}", a.round);
        assert_eq!(a.grad_norm_sq, b.grad_norm_sq, "round {}", a.round);
        assert_eq!(a.stage, b.stage, "round {}", a.round);
    }
    // static estimates are an exact fixed point: the cache never re-tiers
    assert_eq!(t_tiered.total_reranks(), 0);
}

#[test]
fn stages_snap_to_whole_tier_boundaries() {
    // tiers:3 over 12 clients puts boundaries at 4, 8, 12: the n0 = 2
    // opening stage must admit the whole fastest tier, and doubling
    // lands on tier boundaries from there
    let mut cfg = base_cfg(SolverKind::Flanp, 12, 50);
    cfg.tiers = Some(TierPolicy::parse("tiers:3").unwrap());
    let t = run(&cfg);
    assert!(t.finished);
    let ns: Vec<usize> = t.stage_transitions.iter().map(|&(_, n)| n).collect();
    assert_eq!(ns, vec![4, 8, 12], "stages did not admit whole tiers");
}

#[test]
fn tiered_ranking_cuts_rerank_churn_under_markov_drift() {
    // ISSUE acceptance: under Markov drift, tier-cached FLANP reaches
    // the statistical-accuracy stop with <= 10% of the re-rank/re-tier
    // events of per-round individual re-ranking, while its wall-clock
    // stays within 5%. The drift (slow factor 1.5) sits inside the
    // hysteresis band (H = 2), so the cache absorbs every oscillation
    // that per-round re-ranking pays a full re-rank for, every round.
    let system =
        SystemModel::parse("markov:1.5:0.05:0.5:uniform:50:500").unwrap();
    let mut perround = base_cfg(SolverKind::Flanp, 16, 50);
    perround.system = system.clone();
    perround.rerank_per_round = true;
    let mut tiered = base_cfg(SolverKind::Flanp, 16, 50);
    tiered.system = system;
    tiered.tiers = Some(TierPolicy::parse("tiers:8:hysteresis:2").unwrap());
    let (t_pr, t_ti) = (run(&perround), run(&tiered));
    assert!(t_pr.finished, "per-round flanp unfinished under markov drift");
    assert!(t_ti.finished, "tiered flanp unfinished under markov drift");
    // per-round individual re-ranking pays one re-rank EVERY round...
    let (e_pr, e_ti) = (t_pr.total_reranks(), t_ti.total_reranks());
    assert_eq!(
        e_pr,
        t_pr.rounds.len() - 1,
        "per-round mode must re-rank every round"
    );
    // ...while the tier cache re-tiers at most 10% as often
    assert!(
        e_ti * 10 <= e_pr,
        "tiered re-tiers {e_ti} !<= 10% of per-round re-ranks {e_pr}"
    );
    // and pays at most 5% wall-clock for the cached (possibly stale)
    // membership
    assert!(
        t_ti.total_time <= 1.05 * t_pr.total_time,
        "tiered wall-clock {} not within 5% of per-round {}",
        t_ti.total_time,
        t_pr.total_time
    );
}

#[test]
fn within_band_markov_drift_never_invalidates_the_cache() {
    // hysteresis stability end to end: drift whose slow factor stays
    // inside the band (F = 1.4 <= H = 1.5) oscillates every estimate
    // inside its tier, and a full FLANP run records zero re-tiers
    let mut cfg = base_cfg(SolverKind::Flanp, 16, 50);
    cfg.system =
        SystemModel::parse("markov:1.4:0.3:0.3:uniform:50:500").unwrap();
    cfg.tiers = Some(TierPolicy::parse("tiers:4:hysteresis:1.5").unwrap());
    let t = run(&cfg);
    assert!(t.finished);
    assert_eq!(
        t.total_reranks(),
        0,
        "within-band oscillation invalidated the tier cache"
    );
}

#[test]
fn tifl_solver_runs_the_scenario_grid() {
    // the credit-scheduled tifl solver descends under every scenario
    // class and its rounds never wait for a client outside the selected
    // tier (per-round participant count == one tier)
    for spec in [
        "uniform:50:500",
        "jitter:0.3:uniform:50:500",
        "markov:4:0.1:0.5:uniform:50:500",
    ] {
        let mut cfg = base_cfg(SolverKind::Tifl, 12, 50);
        cfg.system = SystemModel::parse(spec).unwrap();
        cfg.tiers = Some(TierPolicy::parse("tiers:4").unwrap());
        cfg.max_rounds = 600;
        let t = run(&cfg);
        assert!(
            t.last().unwrap().loss_full < t.rounds[0].loss_full,
            "tifl did not descend under {spec}"
        );
        // 12 clients / 4 tiers: every round trains exactly one 3-client tier
        assert!(
            t.rounds[1..].iter().all(|r| r.participants == 3),
            "tifl round trained more than one tier under {spec}"
        );
    }
}

#[test]
fn tier_policy_flows_through_config_validation() {
    let mut cfg = base_cfg(SolverKind::Flanp, 8, 50);
    cfg.tiers = Some(TierPolicy::parse("tiers:4:hysteresis:2").unwrap());
    assert!(cfg.validate(10).is_ok());
    // oracle ranking contradicts estimate-driven tiering
    cfg.estimate_speeds = false;
    assert!(cfg.validate(10).is_err());
    cfg.estimate_speeds = true;
    // the two ranking cadences are mutually exclusive
    cfg.rerank_per_round = true;
    assert!(cfg.validate(10).is_err());
    cfg.rerank_per_round = false;
    // tifl without a tier policy is rejected
    cfg.solver = SolverKind::Tifl;
    cfg.tiers = None;
    assert!(cfg.validate(10).is_err());
}
