//! End-to-end integration tests: full experiments through the public API
//! on the native engine (fast) plus paper-shape assertions — the
//! qualitative claims each figure makes, at CI scale.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::data::{shard, synth};
use flanp::engine::{Engine, NativeEngine};
use flanp::fed::{ClientFleet, SpeedModel};
use flanp::setup;
use flanp::util::json::Json;
use flanp::util::Rng;

fn linreg_cfg(solver: SolverKind, n: usize, s: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(solver, "linreg_d25", n, s);
    cfg.tau = 10;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.max_rounds = 1500;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg.seed = 3;
    cfg
}

fn run(cfg: &ExperimentConfig) -> flanp::fed::Trace {
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
    run_solver(&engine, &mut fleet, cfg).unwrap()
}

#[test]
fn headline_flanp_beats_all_full_participation_benchmarks() {
    // Figures 1-4's qualitative claim at small scale: FLANP reaches the
    // final statistical accuracy in less simulated time than every
    // full-participation benchmark.
    let flanp = run(&linreg_cfg(SolverKind::Flanp, 24, 50));
    assert!(flanp.finished);
    for bench in [SolverKind::FedGate, SolverKind::FedAvg, SolverKind::FedNova] {
        let t = run(&linreg_cfg(bench.clone(), 24, 50));
        assert!(t.finished, "{} unfinished", bench.name());
        assert!(
            flanp.total_time < t.total_time,
            "flanp {} !< {} {}",
            flanp.total_time,
            bench.name(),
            t.total_time
        );
    }
}

#[test]
fn speedup_grows_with_heterogeneity() {
    // wider speed spread => bigger FLANP gain (the straggler premise)
    let mut narrow_f = linreg_cfg(SolverKind::Flanp, 16, 50);
    narrow_f.system = SpeedModel::Uniform { lo: 240.0, hi: 280.0 }.into();
    let mut narrow_g = linreg_cfg(SolverKind::FedGate, 16, 50);
    narrow_g.system = SpeedModel::Uniform { lo: 240.0, hi: 280.0 }.into();
    let ratio_narrow =
        run(&narrow_f).total_time / run(&narrow_g).total_time;

    let wide_f = linreg_cfg(SolverKind::Flanp, 16, 50); // default [50,500)
    let wide_g = linreg_cfg(SolverKind::FedGate, 16, 50);
    let ratio_wide = run(&wide_f).total_time / run(&wide_g).total_time;

    assert!(
        ratio_wide < ratio_narrow,
        "wide-spread ratio {ratio_wide} !< narrow {ratio_narrow}"
    );
}

#[test]
fn homogeneous_speed_ratio_improves_with_s() {
    // Section 4.2's second gain is the log(Ns)/log(N) *sample-adaptivity*
    // factor: asymptotic in s (the expressions in (4) favor FLANP only
    // once log(5*Delta0*N*s/c) > (18 log6 / 7.5) * log2(N)). At CI scale
    // the testable claim is the trend: with identical clients, the
    // T_FLANP / T_FedGATE ratio must improve (decrease) as s grows, and
    // stay within a small constant of 1.
    let ratio = |s: usize| {
        let mut f = linreg_cfg(SolverKind::Flanp, 16, s);
        f.system = SpeedModel::Homogeneous { t: 100.0 }.into();
        let mut g = linreg_cfg(SolverKind::FedGate, 16, s);
        g.system = SpeedModel::Homogeneous { t: 100.0 }.into();
        let tf = run(&f);
        let tg = run(&g);
        assert!(tf.finished && tg.finished);
        tf.total_time / tg.total_time
    };
    let (r_small, r_big) = (ratio(50), ratio(200));
    assert!(
        r_big < r_small,
        "homogeneous ratio did not improve with s: {r_small} -> {r_big}"
    );
    assert!(r_big < 2.0, "homogeneous overhead too large: {r_big}");
}

#[test]
fn fastest_k_saturates_above_flanp() {
    // Figure 6b: fastest-k partial participation converges fast but to a
    // worse model (only k clients' data); FLANP reaches lower loss
    let mut flanp_cfg = linreg_cfg(SolverKind::Flanp, 16, 50);
    flanp_cfg.max_rounds = 600;
    let flanp = run(&flanp_cfg);
    let mut pk = linreg_cfg(SolverKind::FedGatePartialFastest { k: 2 }, 16, 50);
    pk.max_rounds = 600;
    pk.c_stat = 0.5;
    let partial = run(&pk);
    let lf = flanp.last().unwrap().dist_to_opt;
    let lp = partial.last().unwrap().dist_to_opt;
    assert!(
        lp > lf,
        "fastest-k dist {lp} should saturate above flanp {lf}"
    );
}

#[test]
fn exponential_speeds_runtime_ratio_shrinks_with_n() {
    // Theorem 2 / Table 2 shape: T_FLANP / T_FedGATE decreases with N
    let ratio = |n: usize| {
        let mut f = linreg_cfg(SolverKind::Flanp, n, 50);
        f.system = SpeedModel::Exponential { lambda: 1.0 }.into();
        f.seed = 9;
        let mut g = linreg_cfg(SolverKind::FedGate, n, 50);
        g.system = SpeedModel::Exponential { lambda: 1.0 }.into();
        g.seed = 9;
        run(&f).total_time / run(&g).total_time
    };
    let (r_small, r_big) = (ratio(8), ratio(64));
    assert!(
        r_big < r_small,
        "ratio at N=64 ({r_big}) !< ratio at N=8 ({r_small})"
    );
}

#[test]
fn trace_csv_and_json_roundtrip() {
    let t = run(&linreg_cfg(SolverKind::Flanp, 8, 50));
    let csv = t.to_csv();
    assert!(csv.lines().count() == t.rounds.len() + 1);
    assert!(csv.starts_with("round,time,participants"));
    let j = Json::parse(&t.to_json().to_string()).unwrap();
    assert_eq!(
        j.req_arr("rounds").unwrap().len(),
        t.rounds.len()
    );
    assert_eq!(j.req_str("algo").unwrap(), "flanp");
}

#[test]
fn logreg_federation_learns_to_classify() {
    // classification E2E on the native engine: accuracy well above chance
    let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "logreg_d16_c4", 8, 100);
    cfg.tau = 5;
    cfg.eta = 0.1;
    cfg.n0 = 2;
    cfg.mu = 0.01;
    cfg.c_stat = 10.0;
    cfg.max_rounds = 100;
    cfg.seed = 4;

    let engine = NativeEngine::logreg(16, 4, 0.01, 10, 5);
    let mut rng = Rng::new(cfg.seed);
    let spec = synth::MixtureSpec {
        n: 800,
        d: 16,
        classes: 4,
        separation: 3.0,
        sigma: 1.0,
    };
    let ds = synth::mixture(&mut rng, &spec);
    let shards = shard::partition_fixed_s(&mut rng, &ds, 8, 100);
    let mut fleet = ClientFleet::new(ds, shards, &cfg.system, &mut rng);
    let t = run_solver(&engine, &mut fleet, &cfg).unwrap();
    let acc = t.last().unwrap().accuracy;
    assert!(acc > 0.8, "final accuracy {acc} <= 0.8");
}

#[test]
fn mlp_federation_reduces_loss() {
    // small nonconvex E2E: two-hidden-layer MLP on a mixture
    let mut cfg =
        ExperimentConfig::new(SolverKind::Flanp, "mlp_d16_c4_h12_h8", 6, 60);
    cfg.tau = 5;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.01;
    cfg.c_stat = 20.0;
    cfg.max_rounds = 60;
    cfg.seed = 6;

    let engine = NativeEngine::mlp(16, 4, vec![12, 8], 0.01, 10, 5);
    let mut rng = Rng::new(cfg.seed);
    let spec = synth::MixtureSpec {
        n: 360,
        d: 16,
        classes: 4,
        separation: 2.5,
        sigma: 1.0,
    };
    let ds = synth::mixture(&mut rng, &spec);
    let shards = shard::partition_fixed_s(&mut rng, &ds, 6, 60);
    let mut fleet = ClientFleet::new(ds, shards, &cfg.system, &mut rng);
    let t = run_solver(&engine, &mut fleet, &cfg).unwrap();
    let first = t.rounds.first().unwrap().loss_full;
    let last = t.last().unwrap().loss_full;
    assert!(last < 0.6 * first, "mlp loss {first} -> {last}");
}

#[test]
fn config_validation_bubbles_up() {
    let engine = NativeEngine::linreg(5, 10, 5);
    let mut rng = Rng::new(1);
    let (ds, _) = synth::linreg(&mut rng, 100, 5, 0.1);
    let shards = shard::partition_iid(&mut rng, &ds, 4);
    let mut fleet =
        ClientFleet::new(ds, shards, &SpeedModel::paper_uniform().into(), &mut rng);
    // s = 25 is not a multiple of batch 10 => config error
    let cfg = ExperimentConfig::new(SolverKind::FedGate, "linreg_d5", 4, 25);
    let err = run_solver(&engine, &mut fleet, &cfg).unwrap_err();
    assert!(err.to_string().contains("multiple"), "{err}");
}
