//! Deadline-policy + buffered-async integration tests.
//!
//! The regression tests prove the aggregation-policy layer is a strict
//! superset of the seed's synchronous model: an unreachable deadline
//! (`fixed:+inf`) reproduces the synchronous FLANP trace bit-for-bit,
//! under static AND time-varying scenarios. The edge-case tests cover
//! rounds where nothing arrives (deadline too tight, or every client
//! dropped). The acceptance test is the ISSUE's headline: under a Markov
//! straggler scenario, deadline-based partial aggregation strictly
//! reduces simulated wall-clock vs synchronous aggregation while still
//! reaching the target statistical accuracy.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::{DeadlinePolicy, SystemModel, Trace};
use flanp::setup;

fn base_cfg(solver: SolverKind, n: usize, s: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(solver, "linreg_d25", n, s);
    cfg.tau = 10;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.max_rounds = 2000;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg.seed = 3;
    cfg
}

fn run(cfg: &ExperimentConfig) -> Trace {
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
    run_solver(&engine, &mut fleet, cfg).unwrap()
}

fn assert_traces_identical(a: &Trace, b: &Trace) {
    assert_eq!(a.rounds.len(), b.rounds.len());
    assert_eq!(a.stage_transitions, b.stage_transitions);
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.finished, b.finished);
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.time, y.time, "round {}", x.round);
        assert_eq!(x.loss_full, y.loss_full, "round {}", x.round);
        assert_eq!(x.grad_norm_sq, y.grad_norm_sq, "round {}", x.round);
        assert_eq!(x.missed, y.missed, "round {}", x.round);
        assert_eq!(x.dropped, y.dropped, "round {}", x.round);
    }
}

#[test]
fn infinite_deadline_reproduces_sync_flanp_bit_identically() {
    // regression (ISSUE acceptance): deadline = +inf IS the synchronous
    // model — same costs, same losses, same stage machine, to the bit
    let sync = base_cfg(SolverKind::Flanp, 16, 50);
    let mut inf = base_cfg(SolverKind::Flanp, 16, 50);
    inf.deadline = DeadlinePolicy::Fixed { t: f64::INFINITY };
    let (t_sync, t_inf) = (run(&sync), run(&inf));
    assert!(t_sync.finished);
    assert!(t_sync.rounds.iter().all(|r| r.missed == 0));
    assert_traces_identical(&t_sync, &t_inf);
}

#[test]
fn infinite_deadline_is_sync_under_time_varying_scenarios_too() {
    let system =
        SystemModel::parse("drop:0.05:markov:4:0.1:0.5:uniform:50:500").unwrap();
    let mut sync = base_cfg(SolverKind::Flanp, 12, 50);
    sync.system = system.clone();
    let mut inf = sync.clone();
    inf.deadline = DeadlinePolicy::Fixed { t: f64::INFINITY };
    assert_traces_identical(&run(&sync), &run(&inf));
}

#[test]
fn zero_arrivals_by_deadline_never_panics() {
    // homogeneous T_i = 100 and a 500-budget deadline with tau = 10:
    // every client needs 1000 > 500, so NOTHING ever arrives. The run
    // must not panic or divide by zero: the model never moves, every
    // round charges exactly the deadline, every cohort member is missed.
    let mut cfg = base_cfg(SolverKind::Flanp, 8, 50);
    cfg.system = SystemModel::parse("homog:100").unwrap();
    cfg.deadline = DeadlinePolicy::Fixed { t: 500.0 };
    cfg.c_stat = 1e-9; // the stage machine must stay at n0 = 2
    cfg.max_rounds = 15;
    let t = run(&cfg);
    assert!(!t.finished);
    assert_eq!(t.rounds.len(), 16, "initial row + 15 starved rounds");
    for (k, r) in t.rounds.iter().enumerate() {
        assert_eq!(r.time, 500.0 * k as f64, "round {k} must charge the deadline");
        assert_eq!(r.loss_full, t.rounds[0].loss_full, "model moved with 0 arrivals");
        if k > 0 {
            assert_eq!(r.missed, 2, "whole n0 = 2 cohort misses every round");
            assert_eq!(r.dropped, 0);
        }
    }
    assert_eq!(t.total_time, 500.0 * 15.0);
}

#[test]
fn all_dropout_rounds_never_panic() {
    // p_drop = 0.9 over a 2-client cohort: most rounds lose EVERY client
    // (dropout + deadline layers both see empty arrival sets)
    let mut cfg = base_cfg(SolverKind::Flanp, 8, 50);
    cfg.system = SystemModel::parse("drop:0.9:uniform:50:500").unwrap();
    cfg.deadline = DeadlinePolicy::Quantile { q: 0.8 };
    cfg.c_stat = 1e-6; // keep the stage machine at n0 = 2 all run
    cfg.max_rounds = 40;
    let t = run(&cfg);
    assert_eq!(t.rounds.len(), 41);
    // times never decrease even across starved rounds
    assert!(t.rounds.windows(2).all(|w| w[1].time >= w[0].time));
    // at p = 0.9 an all-dropout 2-client round is near-certain in 40
    let max_dropped = t.rounds.iter().map(|r| r.dropped).max().unwrap();
    assert_eq!(max_dropped, 2, "no all-dropout round in 40 tries at p=0.9");
    // accounting never exceeds the cohort
    assert!(t.rounds.iter().all(|r| r.dropped + r.missed <= 2));
}

#[test]
fn deadline_partial_aggregation_beats_sync_under_markov_stragglers() {
    // ISSUE acceptance: under a Markov straggler scenario, aggregating
    // whatever arrived by an estimated-speed quantile deadline strictly
    // reduces simulated wall-clock vs waiting for the slowest client —
    // while still reaching the same target statistical accuracy
    let system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500").unwrap();
    let mut sync = base_cfg(SolverKind::Flanp, 16, 50);
    sync.system = system.clone();
    let mut ddl = sync.clone();
    ddl.deadline = DeadlinePolicy::Quantile { q: 0.8 };
    let (t_sync, t_ddl) = (run(&sync), run(&ddl));
    assert!(t_sync.finished, "sync flanp unfinished under markov drift");
    assert!(
        t_ddl.finished,
        "deadline flanp did not reach the target statistical accuracy"
    );
    // partial rounds actually happened…
    let missed: usize = t_ddl.rounds.iter().map(|r| r.missed).sum();
    assert!(missed > 0, "deadline policy never cut a straggler");
    // …and they strictly reduce total wall-clock
    assert!(
        t_ddl.total_time < t_sync.total_time,
        "deadline {} !< sync {}",
        t_ddl.total_time,
        t_sync.total_time
    );
}

#[test]
fn deadline_fedgate_also_runs_and_cuts_stragglers() {
    let system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500").unwrap();
    let mut sync = base_cfg(SolverKind::FedGate, 12, 50);
    sync.system = system.clone();
    let mut ddl = sync.clone();
    ddl.deadline = DeadlinePolicy::Quantile { q: 0.8 };
    let (t_sync, t_ddl) = (run(&sync), run(&ddl));
    assert!(t_sync.finished && t_ddl.finished);
    let missed: usize = t_ddl.rounds.iter().map(|r| r.missed).sum();
    assert!(missed > 0);
    assert!(
        t_ddl.total_time < t_sync.total_time,
        "deadline {} !< sync {}",
        t_ddl.total_time,
        t_sync.total_time
    );
}

#[test]
fn adaptive_deadline_converges_and_self_tunes() {
    // the adaptive policy starts from the estimated-median budget (which
    // misses ~half a uniform cohort) and must loosen itself enough to
    // keep making progress — the run still reaches full accuracy
    let mut cfg = base_cfg(SolverKind::Flanp, 16, 50);
    cfg.system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500").unwrap();
    cfg.deadline = DeadlinePolicy::Adaptive { target: 0.8 };
    let t = run(&cfg);
    assert!(t.finished, "adaptive-deadline flanp unfinished");
    let missed: usize = t.rounds.iter().map(|r| r.missed).sum();
    assert!(missed > 0, "adaptive policy never closed a round early");
}

#[test]
fn fedbuff_descends_faster_than_sync_fedgate_under_markov() {
    // buffered-async aggregation never waits for stragglers at all;
    // under Markov drift its cheap fast-client flushes reach a shared
    // mid-training loss target in less simulated time than synchronous
    // full-participation FedGATE (whose every round pays the straggler)
    let system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500").unwrap();
    let mut gate = base_cfg(SolverKind::FedGate, 12, 50);
    gate.system = system.clone();
    gate.eval_every = 1;
    let mut buff = base_cfg(SolverKind::FedBuff { k: 3 }, 12, 50);
    buff.system = system;
    buff.eval_every = 1;
    buff.max_rounds = 20_000; // flushes are much cheaper than full rounds
    let (t_gate, t_buff) = (run(&gate), run(&buff));
    assert!(t_gate.finished, "fedgate unfinished under markov drift");
    // fedbuff still descends to a meaningful loss under async staleness
    let start = t_buff.rounds[0].loss_full;
    let finl = t_buff.last().unwrap().loss_full;
    assert!(finl < 0.1 * start, "fedbuff barely descended: {start} -> {finl}");
    // shared target: 90% of fedgate's total drop — both curves cross it
    let g_final = t_gate.last().unwrap().loss_full;
    let target = start - 0.9 * (start - g_final);
    let tt_gate = t_gate.time_to_loss(target).expect("fedgate missed target");
    let tt_buff = t_buff.time_to_loss(target).expect("fedbuff missed target");
    assert!(
        tt_buff < tt_gate,
        "fedbuff {tt_buff} !< fedgate {tt_gate} to shared loss {target}"
    );
}

#[test]
fn fedbuff_dropped_counts_are_bounded_by_the_fleet() {
    // regression: a fast unavailable client fails several upload
    // attempts within one flush window; the trace must report distinct
    // dropped clients, never more than the fleet holds
    let mut cfg = base_cfg(SolverKind::FedBuff { k: 3 }, 10, 50);
    cfg.system = SystemModel::parse("drop:0.5:uniform:50:500").unwrap();
    cfg.c_stat = 1e-9; // never finish; exercise many flush windows
    cfg.max_rounds = 200;
    let t = run(&cfg);
    assert!(t.rounds.iter().all(|r| r.dropped <= 10), "dropped exceeds fleet");
    let total: usize = t.rounds.iter().map(|r| r.dropped).sum();
    assert!(total > 0, "50% dropout produced no dropped uploads");
    assert!(t.rounds.windows(2).all(|w| w[1].time >= w[0].time));
}

#[test]
fn adaptive_deadline_ignores_dropouts_when_tuning() {
    // regression: dropped clients can never arrive by any deadline; if
    // they counted toward the arrival-fraction target the scale would
    // pin at its ceiling and the policy would degenerate to sync. Under
    // drift + dropout the adaptive policy must still cut stragglers.
    let mut cfg = base_cfg(SolverKind::Flanp, 16, 50);
    cfg.system =
        SystemModel::parse("drop:0.3:markov:4:0.1:0.5:uniform:50:500").unwrap();
    cfg.deadline = DeadlinePolicy::Adaptive { target: 0.8 };
    cfg.max_rounds = 400;
    let t = run(&cfg);
    let missed: usize = t.rounds.iter().map(|r| r.missed).sum();
    assert!(missed > 0, "adaptive policy degenerated to sync under dropout");
}

#[test]
fn deadline_policy_flows_through_config_validation() {
    let mut cfg = base_cfg(SolverKind::Flanp, 8, 50);
    cfg.deadline = DeadlinePolicy::parse("quantile:0.8").unwrap();
    assert!(cfg.validate(10).is_ok());
    cfg.deadline = DeadlinePolicy::Quantile { q: 0.0 };
    assert!(cfg.validate(10).is_err());
    // async + cohort deadline is contradictory
    cfg.solver = SolverKind::FedBuff { k: 2 };
    cfg.deadline = DeadlinePolicy::parse("fixed:1000").unwrap();
    assert!(cfg.validate(10).is_err());
}

#[test]
fn infinite_deadline_is_sync_for_the_averaging_solvers_too() {
    // ROADMAP follow-on from PR 3 (this PR's satellite): FedAvg, FedProx
    // and FedNova now route through the shared deadline_round step;
    // deadline = +inf must reproduce their synchronous rounds
    // bit-for-bit, exactly as it does for FLANP/FedGATE
    for solver in
        [SolverKind::FedAvg, SolverKind::FedProx, SolverKind::FedNova]
    {
        let mut sync = base_cfg(solver, 10, 50);
        sync.max_rounds = 300;
        let mut inf = sync.clone();
        inf.deadline = DeadlinePolicy::Fixed { t: f64::INFINITY };
        assert_traces_identical(&run(&sync), &run(&inf));
    }
}

#[test]
fn quantile_deadline_prunes_stragglers_for_fedavg() {
    // the deadline policies now apply to the averaging solvers: under
    // Markov stragglers a quantile deadline cuts slow-state clients
    // (missed > 0) while the model still descends
    let system =
        SystemModel::parse("markov:6:0.15:0.4:uniform:50:500").unwrap();
    let mut sync_cfg = base_cfg(SolverKind::FedAvg, 12, 50);
    sync_cfg.system = system;
    sync_cfg.max_rounds = 300;
    let mut q = sync_cfg.clone();
    q.deadline = DeadlinePolicy::Quantile { q: 0.8 };
    let (t_sync, t_q) = (run(&sync_cfg), run(&q));
    let missed: usize = t_q.rounds.iter().map(|r| r.missed).sum();
    assert!(missed > 0, "quantile deadline never cut a straggler");
    assert!(t_sync.rounds.iter().all(|r| r.missed == 0));
    assert!(t_q.last().unwrap().loss_full < t_q.rounds[0].loss_full);
}
