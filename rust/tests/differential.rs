//! Cross-layer differential tests: HloEngine (PJRT executing the
//! JAX/Pallas artifacts) vs NativeEngine (pure Rust) must agree to f32
//! tolerance on identical inputs, for every model in the catalog and
//! every Engine method. This is the correctness keystone of the stack:
//! pallas == jnp (pytest) and jnp == rust (here) closes the triangle.
//!
//! Requires `make artifacts`; tests are skipped (with a loud message)
//! when the manifest is missing.

use flanp::engine::{Engine, HloEngine, Manifest, ModelKind, NativeEngine};
use flanp::setup;
use flanp::util::Rng;

fn manifest() -> Option<Manifest> {
    let dir = setup::default_artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP differential tests: {e:#}");
            None
        }
    }
}

fn rand_vec(rng: &mut Rng, n: usize, sigma: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, sigma);
    v
}

fn labels(rng: &mut Rng, meta: &flanp::engine::ModelMeta, tau: usize) -> Vec<f32> {
    let rows = tau * meta.batch;
    if meta.y_width() == 1 {
        rand_vec(rng, rows, 1.0)
    } else {
        let mut y = vec![0.0f32; rows * meta.classes];
        for r in 0..rows {
            y[r * meta.classes + rng.below(meta.classes)] = 1.0;
        }
        y
    }
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let denom = 1.0f32.max(x.abs()).max(y.abs());
        worst = worst.max((x - y).abs() / denom);
    }
    assert!(worst <= tol, "{what}: max rel err {worst} > {tol}");
}

fn check_model(manifest: &Manifest, model: &str, tol: f32) {
    let hlo = HloEngine::load(manifest, model).expect("load hlo engine");
    let native = NativeEngine::new(manifest.model(model).unwrap().clone());
    let meta = native.meta().clone();
    let mut rng = Rng::new(0xd1ff ^ meta.param_count as u64);

    let params = rand_vec(&mut rng, meta.param_count, 0.3);
    let delta = rand_vec(&mut rng, meta.param_count, 0.05);
    let x = rand_vec(&mut rng, meta.batch * meta.d, 1.0);
    let y = labels(&mut rng, &meta, 1);
    let xs = rand_vec(&mut rng, meta.tau * meta.batch * meta.d, 1.0);
    let ys = labels(&mut rng, &meta, meta.tau);

    // loss
    let lh = hlo.loss(&params, &x, &y).unwrap();
    let ln = native.loss(&params, &x, &y).unwrap();
    assert_close(&[lh], &[ln], tol, &format!("{model}/loss"));

    // loss + grad
    let (glh, gh) = hlo.loss_grad(&params, &x, &y).unwrap();
    let (gln, gn) = native.loss_grad(&params, &x, &y).unwrap();
    assert_close(&[glh], &[gln], tol, &format!("{model}/grad.loss"));
    assert_close(&gh, &gn, tol, &format!("{model}/grad"));

    // gate step
    let sh = hlo.gate_step(&params, &delta, &x, &y, 0.05).unwrap();
    let sn = native.gate_step(&params, &delta, &x, &y, 0.05).unwrap();
    assert_close(&sh, &sn, tol, &format!("{model}/gate_step"));

    // fused round
    let rh = hlo.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
    let rn = native.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
    assert_close(&rh, &rn, tol * 4.0, &format!("{model}/gate_round"));

    // prox round
    let anchor = rand_vec(&mut rng, meta.param_count, 0.3);
    let ph = hlo.prox_round(&params, &anchor, &xs, &ys, 0.05, 0.1).unwrap();
    let pn = native.prox_round(&params, &anchor, &xs, &ys, 0.05, 0.1).unwrap();
    assert_close(&ph, &pn, tol * 4.0, &format!("{model}/prox_round"));

    // accuracy (classification only)
    if meta.kind != ModelKind::LinReg {
        let ah = hlo.accuracy(&params, &x, &y).unwrap();
        let an = native.accuracy(&params, &x, &y).unwrap();
        assert_close(&[ah], &[an], 1e-6, &format!("{model}/accuracy"));
    }
}

#[test]
fn hlo_matches_native_linreg() {
    let Some(m) = manifest() else { return };
    check_model(&m, "linreg_d25", 2e-4);
}

#[test]
fn hlo_matches_native_logreg() {
    let Some(m) = manifest() else { return };
    check_model(&m, "logreg_d784_c10", 5e-4);
}

#[test]
fn hlo_matches_native_mlp_mnist_like() {
    let Some(m) = manifest() else { return };
    check_model(&m, "mlp_d784_c10_h128_h64", 2e-3);
}

#[test]
fn hlo_matches_native_mlp_cifar_like() {
    let Some(m) = manifest() else { return };
    check_model(&m, "mlp_d512_c10_h128_h64", 2e-3);
}

#[test]
fn full_run_identical_between_engines() {
    // the strongest check: a complete FLANP run produces the same round
    // count and near-identical trajectories on both engines
    let Some(m) = manifest() else { return };
    use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};

    let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "linreg_d25", 12, 50);
    cfg.tau = 10;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.05;
    cfg.seed = 77;

    let hlo = HloEngine::load(&m, "linreg_d25").unwrap();
    let native = NativeEngine::new(m.model("linreg_d25").unwrap().clone());

    let mut fleet1 = setup::build_fleet(hlo.meta(), &cfg, 0.1, 0.0).unwrap();
    let t1 = run_solver(&hlo, &mut fleet1, &cfg).unwrap();
    let mut fleet2 = setup::build_fleet(native.meta(), &cfg, 0.1, 0.0).unwrap();
    let t2 = run_solver(&native, &mut fleet2, &cfg).unwrap();

    assert_eq!(t1.rounds.len(), t2.rounds.len(), "round count");
    assert_eq!(t1.stage_transitions, t2.stage_transitions, "stages");
    for (a, b) in t1.rounds.iter().zip(&t2.rounds) {
        assert!(
            (a.loss_full - b.loss_full).abs() < 1e-4 * (1.0 + a.loss_full.abs()),
            "round {}: {} vs {}",
            a.round,
            a.loss_full,
            b.loss_full
        );
        assert_eq!(a.time, b.time, "virtual clock must be engine-invariant");
    }
}
