//! fed::selection end-to-end regressions: the ISSUE-8 acceptance pins.
//!
//! Under diurnal availability rotation, FLANP with over-selection
//! (`overselect:1.3`) plus availability forecasting (`forecast:ewma`)
//! must beat plain quantile-deadline FLANP on wall-clock at equal final
//! statistical accuracy. With the selection layer off the behavior is
//! bit-identical to the defaults (the coordinator unit tests and the
//! golden harness pin that side).

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::{DeadlinePolicy, ForecastPolicy, SystemModel, Trace};
use flanp::setup;

fn run(cfg: &ExperimentConfig) -> Trace {
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
    run_solver(&engine, &mut fleet, cfg).unwrap()
}

/// Quantile-deadline FLANP under a slowly-rotating 25%-duty diurnal
/// window: at any instant only ~a quarter of the fleet is online, and
/// the online quarter persists for several rounds before rotating on —
/// the regime where a window forecaster has signal to exploit.
fn diurnal_cfg(
    overselect: f64,
    forecast: Option<ForecastPolicy>,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "linreg_d25", 16, 50);
    cfg.eta = 0.05;
    cfg.tau = 10;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.system =
        SystemModel::parse("avail:diurnal:200000:0.25:1:uniform:50:500")
            .unwrap();
    cfg.deadline = DeadlinePolicy::Quantile { q: 0.8 };
    cfg.overselect = overselect;
    cfg.forecast = forecast;
    cfg.seed = 11;
    cfg.max_rounds = 4000;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg
}

#[test]
fn overselect_plus_forecast_beats_plain_quantile_flanp_under_diurnal() {
    let plain = run(&diurnal_cfg(1.0, None));
    let predictive = run(&diurnal_cfg(
        1.3,
        Some(ForecastPolicy::Ewma { alpha: 0.3 }),
    ));
    // equal final statistical accuracy: both certify the full-fleet
    // gradient threshold, and the final full losses agree closely
    assert!(plain.finished, "plain quantile FLANP unfinished under diurnal");
    assert!(predictive.finished, "predictive FLANP unfinished under diurnal");
    let lp = plain.last().unwrap().loss_full;
    let lq = predictive.last().unwrap().loss_full;
    assert!(
        (lp - lq).abs() <= 0.10 * lp.max(lq),
        "final losses diverged: plain {lp} vs predictive {lq}"
    );
    // the acceptance pin: predictive selection wins on wall-clock
    assert!(
        predictive.total_time < plain.total_time,
        "predictive FLANP {} !< plain {} under diurnal rotation",
        predictive.total_time,
        plain.total_time
    );
    // and its price is visible: cancelled work is booked, never hidden
    assert!(
        predictive.total_cancelled() > 0,
        "over-selection at 1.3 never cancelled anyone"
    );
    assert_eq!(plain.total_cancelled(), 0, "plain run booked cancellations");
}

#[test]
fn forecast_alone_reduces_wasted_offline_selections() {
    // forecasting with no over-selection must also help (or at least
    // never hurt) under the same rotation: predicted-offline clients
    // yield their slots to online ones, so fewer selected-but-offline
    // skips and fewer all-offline wait rounds are paid
    let plain = run(&diurnal_cfg(1.0, None));
    let forecast =
        run(&diurnal_cfg(1.0, Some(ForecastPolicy::Ewma { alpha: 0.3 })));
    assert!(forecast.finished);
    assert!(
        forecast.total_time <= plain.total_time,
        "forecast-only FLANP {} slower than plain {}",
        forecast.total_time,
        plain.total_time
    );
    // forecasting alone never cancels: cancellation is over-selection's
    // mechanism, not the forecaster's
    assert_eq!(forecast.total_cancelled(), 0);
}
