//! Property-based tests of coordinator invariants (hand-rolled harness,
//! `flanp::util::prop`). Each property runs over randomized federation
//! shapes, speeds and seeds; failures shrink to a minimal counterexample.

use flanp::coordinator::gate::{
    active_loss_gradsq, fedgate_round, GateState, RoundBuffers,
};
use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::data::{shard, synth};
use flanp::engine::NativeEngine;
use flanp::fed::population::{LazyShards, LAZY_CLUSTERS};
use flanp::fed::speed::sort_fastest_first;
use flanp::fed::{ClientFleet, QuantileSketch, SpeedModel, TopK, VirtualClock};
use flanp::util::prop::{forall, gen_usize};
use flanp::util::{linalg, Rng};

fn fleet_of(seed: u64, n_clients: usize, s: usize, d: usize) -> (NativeEngine, ClientFleet) {
    let mut rng = Rng::new(seed);
    let (ds, _) = synth::linreg(&mut rng, n_clients * s, d, 0.1);
    let shards = shard::partition_iid(&mut rng, &ds, n_clients);
    let fleet =
        ClientFleet::new(ds, shards, &SpeedModel::paper_uniform().into(), &mut rng);
    (NativeEngine::linreg(d, 10, 5), fleet)
}

#[test]
fn prop_flanp_participants_monotone_and_doubling() {
    forall(
        101,
        8,
        |r| (gen_usize(r, 2, 16), gen_usize(r, 1, 3), r.next_u64()),
        |&(n_clients, n0, seed)| {
            if n_clients < 2 || n0 < 1 {
                return Ok(()); // out of domain (shrunk candidates)
            }
            let (e, mut fleet) = fleet_of(seed, n_clients, 50, 5);
            let mut cfg =
                ExperimentConfig::new(SolverKind::Flanp, "linreg_d5", n_clients, 50);
            cfg.n0 = n0.min(n_clients);
            cfg.tau = 5;
            cfg.mu = 0.5;
            cfg.c_stat = 0.1;
            cfg.max_rounds = 300;
            cfg.seed = seed;
            let t = run_solver(&e, &mut fleet, &cfg).map_err(|e| e.to_string())?;
            // 1. participants never decrease
            if !t.rounds.windows(2).all(|w| w[1].participants >= w[0].participants) {
                return Err("participants decreased".into());
            }
            // 2. stage sizes follow n -> min(2n, N)
            let sizes: Vec<usize> =
                t.stage_transitions.iter().map(|&(_, n)| n).collect();
            for w in sizes.windows(2) {
                if w[1] != (2 * w[0]).min(n_clients) {
                    return Err(format!("stage sizes {sizes:?} not doubling"));
                }
            }
            // 3. virtual time strictly increases
            if !t.rounds.windows(2).all(|w| w[1].time > w[0].time) {
                return Err("virtual clock not monotone".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_flanp_active_prefix_is_fastest() {
    forall(
        102,
        10,
        |r| (gen_usize(r, 3, 24), r.next_u64()),
        |&(n_clients, seed)| {
            let (_, fleet) = fleet_of(seed, n_clients, 20, 4);
            // fastest(k) must be exactly the k smallest speeds
            for k in 1..=n_clients {
                let chosen = fleet.speeds_of(fleet.fastest(k));
                let mut all = fleet.speeds.clone();
                all.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let max_chosen = chosen.iter().cloned().fold(0.0f64, f64::max);
                if max_chosen > all[k - 1] + 1e-12 {
                    return Err(format!("fastest({k}) includes speed {max_chosen}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tracking_sum_invariant() {
    // sum_i delta_i over the ACTIVE set stays ~0 through any number of
    // rounds (the gradient-tracking correction is mean-preserving)
    forall(
        103,
        6,
        |r| (gen_usize(r, 2, 10), gen_usize(r, 1, 12), r.next_u64()),
        |&(n_clients, rounds, seed)| {
            let (_, mut fleet) = fleet_of(seed, n_clients, 30, 4);
            let e = NativeEngine::linreg(4, 10, 5);
            let active: Vec<usize> = (0..n_clients).collect();
            let mut state = GateState::new(vec![0.05; 5], n_clients);
            let mut bufs = RoundBuffers::new(&e, 5);
            for _ in 0..rounds {
                fedgate_round(&e, &mut fleet, &mut state, &active, 5, 0.05, 1.0, &mut bufs)
                    .map_err(|er| er.to_string())?;
            }
            for k in 0..state.w.len() {
                let s: f64 = state.deltas.iter().map(|d| d[k] as f64).sum();
                if s.abs() > 1e-3 {
                    return Err(format!("tracking sum drifted to {s}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_clock_round_cost_formula() {
    forall(
        104,
        50,
        |r| {
            let n = gen_usize(r, 1, 12);
            let speeds: Vec<usize> =
                (0..n).map(|_| gen_usize(r, 1, 1000)).collect();
            (speeds, gen_usize(r, 1, 30))
        },
        |(speeds, tau)| {
            let fs: Vec<f64> = speeds.iter().map(|&s| s as f64).collect();
            let mut clock = VirtualClock::new();
            let cost = clock.advance_round(&fs, *tau);
            let expect = *tau as f64 * fs.iter().cloned().fold(0.0, f64::max);
            if (cost - expect).abs() > 1e-9 {
                return Err(format!("cost {cost} != {expect}"));
            }
            if clock.now() != cost {
                return Err("clock.now() != first round cost".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sort_fastest_first_is_sorting_network() {
    forall(
        105,
        60,
        |r| {
            let n = gen_usize(r, 1, 40);
            (0..n).map(|_| gen_usize(r, 0, 10_000)).collect::<Vec<usize>>()
        },
        |speeds| {
            let fs: Vec<f64> = speeds.iter().map(|&s| s as f64).collect();
            let order = sort_fastest_first(&fs);
            // permutation
            let mut sorted = order.clone();
            sorted.sort_unstable();
            if sorted != (0..fs.len()).collect::<Vec<_>>() {
                return Err("not a permutation".into());
            }
            // non-decreasing speeds
            let ordered: Vec<f64> = order.iter().map(|&i| fs[i]).collect();
            if !ordered.windows(2).all(|w| w[0] <= w[1]) {
                return Err("not sorted".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_aggregation_linearity() {
    // mean_of(accumulate(xs)) == elementwise mean, for any shapes
    forall(
        106,
        40,
        |r| (gen_usize(r, 1, 8), gen_usize(r, 1, 50), r.next_u64()),
        |&(k, p, seed)| {
            let mut rng = Rng::new(seed);
            let vecs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..p).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut acc = vec![0.0f64; p];
            for v in &vecs {
                linalg::accumulate(&mut acc, v);
            }
            let mean = linalg::mean_of(&acc, k);
            for j in 0..p {
                let want: f64 =
                    vecs.iter().map(|v| v[j] as f64).sum::<f64>() / k as f64;
                if (mean[j] as f64 - want).abs() > 1e-5 {
                    return Err(format!("mean[{j}] {} != {want}", mean[j]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gradient_of_active_set_is_mean_of_locals() {
    forall(
        107,
        6,
        |r| (gen_usize(r, 1, 6), r.next_u64()),
        |&(n_active, seed)| {
            let (e, fleet) = fleet_of(seed, 6, 30, 4);
            let active: Vec<usize> = (0..n_active).collect();
            let w = vec![0.1f32; 5];
            let (_, gsq) = active_loss_gradsq(&e, &fleet, &active, &w)
                .map_err(|er| er.to_string())?;
            // manual recomputation
            let mut acc = vec![0.0f64; 5];
            for &i in &active {
                let (_, gi) = flanp::engine::full_loss_grad(&e, &fleet, i, &w)
                    .map_err(|er| er.to_string())?;
                linalg::accumulate(&mut acc, &gi);
            }
            let want: f64 = acc
                .iter()
                .map(|g| (g / n_active as f64).powi(2))
                .sum();
            if (gsq - want).abs() > 1e-9 * (1.0 + want) {
                return Err(format!("gradsq {gsq} != {want}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_determinism_across_identical_runs() {
    forall(
        108,
        4,
        |r| (gen_usize(r, 2, 8), r.next_u64() % 1000),
        |&(n_clients, seed)| {
            let run = || {
                let (_, mut fleet) = fleet_of(seed, n_clients, 30, 4);
                let e = NativeEngine::linreg(4, 10, 5);
                let mut cfg = ExperimentConfig::new(
                    SolverKind::FedGate,
                    "linreg_d4",
                    n_clients,
                    30,
                );
                cfg.tau = 5;
                cfg.mu = 0.5;
                cfg.c_stat = 0.1;
                cfg.max_rounds = 20;
                cfg.seed = seed;
                run_solver(&e, &mut fleet, &cfg).map_err(|er| er.to_string())
            };
            let (a, b) = (run()?, run()?);
            if a.rounds.len() != b.rounds.len() {
                return Err("round counts differ".into());
            }
            for (x, y) in a.rounds.iter().zip(&b.rounds) {
                if x.loss_full != y.loss_full || x.time != y.time {
                    return Err(format!(
                        "round {} diverged: {} vs {}",
                        x.round, x.loss_full, y.loss_full
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantile_sketch_rank_error_within_bound() {
    // the sketch's documented guarantee: rank error of any query is at
    // most (log2(n/m) + 1) / m of the total weight (m = capacity),
    // exercised over adversarially-shaped streams — random, sorted,
    // reverse-sorted, and duplicate-heavy (the compaction worst cases)
    forall(
        110,
        24,
        |r| (gen_usize(r, 1, 4000), gen_usize(r, 0, 3), r.next_u64()),
        |&(n, shape, seed)| {
            let mut rng = Rng::new(seed);
            let mut xs: Vec<f64> = match shape {
                0 => (0..n).map(|_| rng.next_f64() * 1e3).collect(),
                1 => (0..n).map(|i| i as f64).collect(),
                2 => (0..n).map(|i| (n - i) as f64).collect(),
                _ => (0..n).map(|_| rng.below(8) as f64).collect(),
            };
            let m = 32usize;
            let mut sk = QuantileSketch::new(m);
            for &x in &xs {
                sk.push(x);
            }
            xs.sort_by(|a, b| a.total_cmp(b));
            let bound =
                ((n as f64 / m as f64).log2().max(0.0) + 1.0) / m as f64;
            for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0] {
                let v = sk.query(q);
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                // v's admissible rank range is [lo+1, hi]; error is the
                // distance from the target rank to that range
                let lo = xs.partition_point(|&x| x < v);
                let hi = xs.partition_point(|&x| x <= v);
                if hi == lo {
                    return Err(format!(
                        "query({q}) returned {v}, absent from the stream"
                    ));
                }
                let err = if rank < lo + 1 {
                    (lo + 1 - rank) as f64 / n as f64
                } else if rank > hi {
                    (rank - hi) as f64 / n as f64
                } else {
                    0.0
                };
                if err > bound {
                    return Err(format!(
                        "rank error {err:.4} > bound {bound:.4} \
                         (n={n}, shape={shape}, q={q})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_matches_stable_sort_truncate() {
    // TopK::select and a streaming TopK must both equal "stable-sort
    // the values fastest-first (ties by ascending id), truncate to k" —
    // for k below, at, and past the input size, with heavy duplicates
    // so the id tiebreak is load-bearing
    forall(
        111,
        40,
        |r| {
            let n = gen_usize(r, 0, 60);
            let values: Vec<f64> = (0..n)
                .map(|_| gen_usize(r, 0, 12) as f64 * 0.25)
                .collect();
            (values, gen_usize(r, 0, 70))
        },
        |(values, k)| {
            let want: Vec<usize> =
                sort_fastest_first(values).into_iter().take(*k).collect();
            let got = TopK::select(values, *k);
            if got != want {
                return Err(format!(
                    "select(k={k}) = {got:?} != {want:?} for {values:?}"
                ));
            }
            let mut t = TopK::new(*k);
            for (i, &v) in values.iter().enumerate() {
                t.push(v, i);
            }
            if t.ids() != want {
                return Err(format!(
                    "streaming ids(k={k}) = {:?} != {want:?}",
                    t.ids()
                ));
            }
            // retained values agree with the sorted prefix too
            let vals: Vec<f64> = t.items().iter().map(|&(v, _)| v).collect();
            let want_vals: Vec<f64> =
                want.iter().map(|&i| values[i]).collect();
            if vals != want_vals {
                return Err(format!("values {vals:?} != {want_vals:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dirichlet_proportions_simplex_and_deterministic() {
    // every (seed, client, alpha, k) yields a valid probability simplex,
    // bit-identical on every call — the statelessness both the eager
    // partitioner and the lazy population path rely on
    forall(
        112,
        40,
        |r| {
            (
                r.next_u64(),
                gen_usize(r, 0, 500),
                gen_usize(r, 1, 40) as f64 / 10.0, // alpha in [0.1, 4.0]
                gen_usize(r, 2, 10),
            )
        },
        |&(seed, client, alpha, k)| {
            let p = synth::dirichlet_proportions(seed, client, alpha, k);
            if p.len() != k {
                return Err(format!("len {} != k {k}", p.len()));
            }
            if !p.iter().all(|&x| (0.0..=1.0).contains(&x)) {
                return Err(format!("proportion outside [0,1]: {p:?}"));
            }
            let sum: f64 = p.iter().sum();
            if (sum - 1.0).abs() > 1e-9 {
                return Err(format!("sum {sum} != 1"));
            }
            if p != synth::dirichlet_proportions(seed, client, alpha, k) {
                return Err("not deterministic per (seed, client)".into());
            }
            // the same draws flow from the client's skew stream — the
            // eager partitioner's entry point
            let with = synth::dirichlet_proportions_with(
                &mut synth::skew_stream(seed, client),
                alpha,
                k,
            );
            if p != with {
                return Err("skew_stream path diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dirichlet_concentration_monotone_in_alpha() {
    // smaller alpha = more concentrated shards: the mean max-class
    // share (over enough clients for the noise to wash out) must
    // decrease as alpha grows
    forall(
        113,
        8,
        |r| (r.next_u64(), gen_usize(r, 3, 10)),
        |&(seed, k)| {
            let clients = 60usize;
            let mean_max = |alpha: f64| -> f64 {
                (0..clients)
                    .map(|c| {
                        synth::dirichlet_proportions(seed, c, alpha, k)
                            .into_iter()
                            .fold(0.0f64, f64::max)
                    })
                    .sum::<f64>()
                    / clients as f64
            };
            let shares: Vec<f64> =
                [0.05, 0.5, 5.0].iter().map(|&a| mean_max(a)).collect();
            if !(shares[0] > shares[1] && shares[1] > shares[2]) {
                return Err(format!(
                    "mean max share not decreasing in alpha: {shares:?}"
                ));
            }
            // alpha -> large approaches the uniform 1/k share
            if shares[2] < 1.0 / k as f64 {
                return Err(format!(
                    "max share {} below uniform 1/{k}",
                    shares[2]
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lazy_and_eager_derive_identical_skew_state() {
    // the cross-path contract: LazyShards synthesizes non-IID state
    // from the SAME pure per-client streams the eager path consumes —
    // proportions bit-exact, shift vectors bit-exact, and the shifted
    // row exactly plain-row + strength * shift_vector. (Full row
    // identity across paths is impossible by design: eager shards are
    // drawn rows of a materialized dataset, lazy rows are synthesized —
    // the shared state is the skew, not the features.)
    forall(
        114,
        12,
        |r| {
            (
                r.next_u64(),
                gen_usize(r, 0, 40),
                gen_usize(r, 0, 15),
                gen_usize(r, 1, 30) as f64 / 10.0,
            )
        },
        |&(seed, client, row, mag)| {
            let (s, d, noise) = (16usize, 6usize, 0.1f64);
            let alpha = 0.3;
            let row = row.min(s - 1);
            let iid = LazyShards::new(seed, s, d, noise);
            let skew = synth::DataSpec {
                dirichlet: Some(alpha),
                shift: Some(mag),
                corr_speed: false,
            };
            let lazy = LazyShards::with_data(seed, s, d, noise, skew, None);

            // 1. the lazy teacher mixture reuses the eager proportions
            let p = synth::dirichlet_proportions(
                seed,
                client,
                alpha,
                LAZY_CLUSTERS,
            );
            // strength 1.0 without corr:speed — blending is a no-op
            if lazy.strength(client) != 1.0 {
                return Err("strength != 1 without corr:speed".into());
            }
            let t = lazy.client_teacher(client);
            if t.len() != d || t == lazy.teacher() {
                return Err("client teacher not a cluster mixture".into());
            }
            let _ = p; // proportions validity pinned by prop 112

            // 2. shift vectors: deterministic, length d, norm mag
            let v = synth::shift_vector(seed, client, d, mag);
            if v != synth::shift_vector(seed, client, d, mag) {
                return Err("shift vector not deterministic".into());
            }
            let norm: f64 =
                v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
            if (norm - mag).abs() > 1e-5 * (1.0 + mag) {
                return Err(format!("||v|| {norm} != {mag}"));
            }

            // 3. the shifted row is exactly plain + 1.0 * v, feature by
            // feature (same f32 op the implementation performs)
            let shift_only = LazyShards::with_data(
                seed,
                s,
                d,
                noise,
                synth::DataSpec {
                    dirichlet: None,
                    shift: Some(mag),
                    corr_speed: false,
                },
                None,
            );
            let mut plain = vec![0.0f32; d];
            let y_plain = iid.realize_row(client, row, &mut plain);
            let mut shifted = vec![0.0f32; d];
            let y_shifted = shift_only.realize_row(client, row, &mut shifted);
            for j in 0..d {
                if shifted[j] != plain[j] + 1.0f32 * v[j] {
                    return Err(format!(
                        "x[{j}] {} != plain {} + v {}",
                        shifted[j], plain[j], v[j]
                    ));
                }
            }
            // labels predate the shift: y|x moves, y itself does not
            if y_shifted != y_plain {
                return Err("shift changed the label draw".into());
            }

            // 4. the eager partitioner is deterministic in the same state
            let labels: Vec<usize> = (0..8 * s).map(|i| i % 4).collect();
            let a = shard::partition_dirichlet(
                seed, &labels, 4, 8, s, alpha, &[1.0; 8],
            );
            let b = shard::partition_dirichlet(
                seed, &labels, 4, 8, s, alpha, &[1.0; 8],
            );
            if a.iter().zip(&b).any(|(x, y)| x.indices != y.indices) {
                return Err("partition_dirichlet not deterministic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partial_fastest_round_cost_bounded_by_kth_speed() {
    forall(
        109,
        5,
        |r| (gen_usize(r, 4, 10), gen_usize(r, 1, 3), r.next_u64()),
        |&(n_clients, k, seed)| {
            let (_, mut fleet) = fleet_of(seed, n_clients, 30, 4);
            let mut cfg = ExperimentConfig::new(
                SolverKind::FedGatePartialFastest { k },
                "linreg_d4",
                n_clients,
                30,
            );
            cfg.tau = 5;
            cfg.mu = 0.5;
            cfg.c_stat = 1e-12; // never finish; measure timing only
            cfg.max_rounds = 5;
            cfg.seed = seed;
            let kth = fleet.speeds_of(fleet.fastest(k)).iter().cloned().fold(0.0, f64::max);
            let e = NativeEngine::linreg(4, 10, 5);
            let t = run_solver(&e, &mut fleet, &cfg).map_err(|er| er.to_string())?;
            for w in t.rounds.windows(2) {
                let dt = w[1].time - w[0].time;
                if (dt - 5.0 * kth).abs() > 1e-9 {
                    return Err(format!("round cost {dt} != tau*T_(k) {}", 5.0 * kth));
                }
            }
            Ok(())
        },
    );
}
