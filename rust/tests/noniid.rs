//! Acceptance test for the statistical/system-heterogeneity interplay
//! (ISSUE PR 9): under diurnal availability with SPEED-CORRELATED
//! Dirichlet label skew and covariate shift — the slow cohort is the
//! shifted one — a personalized solver (`ditto`) must beat the
//! global-model solvers (plain FLANP and FedAvg) on worst-decile
//! per-client held-out accuracy at a comparable simulated wall-clock.
//! The IID control pins the converse: with no skew, all three tie, so
//! the gap is attributable to the interplay, not to the solver.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::data::DataSpec;
use flanp::fed::{SystemModel, Trace};
use flanp::setup;

const MODEL: &str = "logreg_d16_c4";
const CLIENTS: usize = 12;
const S: usize = 100; // 2 engine batches: 50 train + 50 held out
const ROUNDS_BUDGET: usize = 40;

/// One arm of the grid: fixed scenario, fixed simulated-time budget,
/// varying solver and data spec. The per-client holdout is FORCED even
/// when the config would not reserve one (IID + non-ditto arms), so
/// every cell reports the same metric.
fn run(solver: SolverKind, data: &DataSpec) -> Trace {
    let mut cfg = ExperimentConfig::new(solver, MODEL, CLIENTS, S);
    cfg.eta = 0.05;
    cfg.tau = 10;
    cfg.n0 = 2;
    cfg.mu = 0.01;
    cfg.c_stat = 40.0;
    cfg.system =
        SystemModel::parse("avail:diurnal:40000:0.25:1:uniform:50:500")
            .unwrap();
    cfg.data = data.clone();
    cfg.seed = 11;
    // a COMMON simulated-time budget: the comparison below is at
    // comparable wall-clock, the paper's x-axis
    cfg.max_rounds = 50 * ROUNDS_BUDGET;
    cfg.max_time = ROUNDS_BUDGET as f64 * cfg.tau as f64 * 500.0;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg.validate(50).unwrap();

    let engine = setup::native_from_name(MODEL).unwrap();
    let mut fleet =
        setup::build_fleet(engine.meta(), &cfg, 0.1, 2.0).unwrap();
    if fleet.holdout() == 0 {
        fleet.set_holdout(engine.meta().batch);
    }
    run_solver(&engine, &mut fleet, &cfg).unwrap()
}

fn worst_decile(t: &Trace) -> f64 {
    let wd = t.worst_decile_acc();
    assert!(
        wd.is_finite(),
        "{}: no per-client accuracy recorded (client_acc len {})",
        t.algo,
        t.client_acc.len()
    );
    wd
}

#[test]
fn personalization_wins_under_speed_correlated_skew() {
    let skew =
        DataSpec::parse("data:dirichlet:0.1:shift:3:corr:speed").unwrap();
    let fedavg = run(SolverKind::FedAvg, &skew);
    let flanp = run(SolverKind::Flanp, &skew);
    let ditto = run(SolverKind::Ditto { lambda: 1.0 }, &skew);

    // comparable wall-clock: every arm ran against the same max_time
    // budget. An arm may stop before the budget only by REACHING
    // statistical accuracy (finished = true, its best answer); anything
    // else undercutting the budget by more than one sync round at the
    // slowest possible speed (tau * 500) would make the comparison
    // unfair
    let budget = ROUNDS_BUDGET as f64 * 10.0 * 500.0;
    for t in [&fedavg, &flanp, &ditto] {
        assert!(
            t.finished || t.total_time >= budget - 10.0 * 500.0,
            "{} stopped early: {} of {budget}",
            t.algo,
            t.total_time
        );
    }

    let (fa, fl, di) =
        (worst_decile(&fedavg), worst_decile(&flanp), worst_decile(&ditto));
    // the interplay result: the slow decile is the shifted, skewed
    // cohort — global models collapse there, personal heads do not
    assert!(
        di > fa + 0.05,
        "ditto worst-decile {di:.3} does not beat fedavg {fa:.3}"
    );
    assert!(
        di > fl + 0.05,
        "ditto worst-decile {di:.3} does not beat flanp {fl:.3}"
    );
}

#[test]
fn iid_control_ties_within_tolerance() {
    let iid = DataSpec::iid();
    let accs = [
        worst_decile(&run(SolverKind::FedAvg, &iid)),
        worst_decile(&run(SolverKind::Flanp, &iid)),
        worst_decile(&run(SolverKind::Ditto { lambda: 1.0 }, &iid)),
    ];
    let (lo, hi) = (
        accs.iter().cloned().fold(f64::MAX, f64::min),
        accs.iter().cloned().fold(f64::MIN, f64::max),
    );
    assert!(
        hi - lo < 0.25,
        "IID control did not tie: fedavg/flanp/ditto = {accs:?}"
    );
}
