//! Blocked-vs-naive kernel differential tests (PR 6).
//!
//! The blocked kernels in `engine::kernels` are order-preserving: every
//! output element accumulates the same floating-point additions in the
//! same order as the naive reference, so the two `KernelPath`s are
//! expected to agree bit-for-bit. These tests assert the weaker (and
//! future-proof) contract promised by the ISSUE — agreement to f32
//! tolerance — across every Engine entry point, for the full model
//! catalog AND awkward shapes: dimensions that are not multiples of the
//! MR/BK/BN tiles, `batch = 1` (all micro-tile remainder), and
//! `fout = 1` (linreg; degenerate column blocking).
//!
//! A finite-difference check validates the gradient THROUGH the blocked
//! path independently of the naive twin, closing the loop in case both
//! paths ever share a bug.

use flanp::engine::{Engine, KernelPath, NativeEngine};
use flanp::util::Rng;

/// Relative-ish f32 tolerance: |a-b| <= atol + rtol * max(|a|,|b|).
fn close(a: f32, b: f32, atol: f32, rtol: f32) -> bool {
    let diff = (a - b).abs();
    diff <= atol + rtol * a.abs().max(b.abs())
}

fn assert_vec_close(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            close(x, y, atol, rtol),
            "{what}[{i}]: blocked {x} vs naive {y}"
        );
    }
}

fn rand_vec(rng: &mut Rng, n: usize, sigma: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, sigma);
    v
}

fn labels(rng: &mut Rng, meta: &flanp::engine::ModelMeta, tau: usize) -> Vec<f32> {
    let rows = tau * meta.batch;
    if meta.y_width() == 1 {
        rand_vec(rng, rows, 1.0)
    } else {
        let mut y = vec![0.0f32; rows * meta.classes];
        for r in 0..rows {
            y[r * meta.classes + rng.below(meta.classes)] = 1.0;
        }
        y
    }
}

/// The catalog models plus tile-hostile shapes. Each pair shares one
/// `ModelMeta`, differing only in `KernelPath`.
fn model_pairs() -> Vec<(NativeEngine, NativeEngine)> {
    let builders: Vec<fn() -> NativeEngine> = vec![
        // catalog (aot.py defaults)
        || NativeEngine::linreg(25, 10, 10),
        || NativeEngine::logreg(784, 10, 0.01, 50, 10),
        || NativeEngine::mlp(784, 10, vec![128, 64], 0.01, 50, 10),
        // awkward: no dimension is a multiple of MR=4 / BK=128 / BN=512,
        // batch 9 engages the packed-transpose dprev path (b >= 8)
        || NativeEngine::mlp(130, 3, vec![66, 17], 0.01, 9, 5),
        // batch = 1: every row is micro-tile remainder
        || NativeEngine::mlp(33, 5, vec![13], 0.0, 1, 4),
        || NativeEngine::linreg(7, 1, 3),
        // fout = 1 output layer with a hidden layer above it would need
        // a regression MLP (not in the catalog); linreg covers fout=1
        || NativeEngine::linreg(257, 6, 2),
    ];
    builders
        .into_iter()
        .map(|mk| {
            (
                mk().kernel_path(KernelPath::Blocked),
                mk().kernel_path(KernelPath::Naive),
            )
        })
        .collect()
}

#[test]
fn blocked_agrees_with_naive_on_all_entry_points() {
    for (blocked, naive) in model_pairs() {
        let meta = blocked.meta().clone();
        let mut rng = Rng::new(41);
        let params = rand_vec(&mut rng, meta.param_count, 0.3);
        let delta = rand_vec(&mut rng, meta.param_count, 0.1);
        let anchor = rand_vec(&mut rng, meta.param_count, 0.3);
        let x = rand_vec(&mut rng, meta.batch * meta.d, 0.7);
        let y = labels(&mut rng, &meta, 1);
        let xs = rand_vec(&mut rng, meta.tau * meta.batch * meta.d, 0.7);
        let ys = labels(&mut rng, &meta, meta.tau);
        let name = &meta.name;
        let (atol, rtol) = (1e-6, 1e-5);

        let la = blocked.loss(&params, &x, &y).unwrap();
        let lb = naive.loss(&params, &x, &y).unwrap();
        assert!(close(la, lb, atol, rtol), "{name}/loss: {la} vs {lb}");

        let (la, ga) = blocked.loss_grad(&params, &x, &y).unwrap();
        let (lb, gb) = naive.loss_grad(&params, &x, &y).unwrap();
        assert!(close(la, lb, atol, rtol), "{name}/loss_grad loss");
        assert_vec_close(&ga, &gb, atol, rtol, &format!("{name}/loss_grad"));

        let wa = blocked.gate_step(&params, &delta, &x, &y, 0.05).unwrap();
        let wb = naive.gate_step(&params, &delta, &x, &y, 0.05).unwrap();
        assert_vec_close(&wa, &wb, atol, rtol, &format!("{name}/gate_step"));

        let wa = blocked.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
        let wb = naive.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
        assert_vec_close(&wa, &wb, atol, rtol, &format!("{name}/gate_round"));

        let wa = blocked
            .prox_round(&params, &anchor, &xs, &ys, 0.05, 0.3)
            .unwrap();
        let wb = naive
            .prox_round(&params, &anchor, &xs, &ys, 0.05, 0.3)
            .unwrap();
        assert_vec_close(&wa, &wb, atol, rtol, &format!("{name}/prox_round"));

        let aa = blocked.accuracy(&params, &x, &y).unwrap();
        let ab = naive.accuracy(&params, &x, &y).unwrap();
        if meta.y_width() == 1 {
            assert!(aa.is_nan() && ab.is_nan(), "{name}/accuracy NaN");
        } else {
            assert!(close(aa, ab, atol, rtol), "{name}/accuracy");
        }
    }
}

#[test]
fn blocked_and_naive_are_bit_identical_on_catalog() {
    // The strong (order-preservation) contract the solver pins rely on:
    // the blocked kernels perform identical additions in identical
    // order, so results match bitwise, not just to tolerance.
    for (blocked, naive) in model_pairs() {
        let meta = blocked.meta().clone();
        let mut rng = Rng::new(97);
        let params = rand_vec(&mut rng, meta.param_count, 0.3);
        let delta = rand_vec(&mut rng, meta.param_count, 0.1);
        let xs = rand_vec(&mut rng, meta.tau * meta.batch * meta.d, 0.7);
        let ys = labels(&mut rng, &meta, meta.tau);
        let wa = blocked.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
        let wb = naive.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
        assert_eq!(wa, wb, "{}/gate_round bitwise", meta.name);
    }
}

/// Central-difference gradient of `loss` at `params`.
fn finite_diff(engine: &dyn Engine, params: &[f32], x: &[f32], y: &[f32], h: f32) -> Vec<f32> {
    (0..params.len())
        .map(|k| {
            let mut p = params.to_vec();
            p[k] = params[k] + h;
            let lp = engine.loss(&p, x, y).unwrap() as f64;
            p[k] = params[k] - h;
            let lm = engine.loss(&p, x, y).unwrap() as f64;
            ((lp - lm) / (2.0 * h as f64)) as f32
        })
        .collect()
}

#[test]
fn blocked_gradient_matches_finite_differences_smooth_model() {
    // logreg is smooth (softmax-xent, no ReLU kinks), so central
    // differences are tight: truncation O(h^2), f32 roundoff O(eps/h)
    // ~ 1e-5 per eval at h = 1e-2. Exercises the blocked forward
    // matmul + grad_weights kernels (no hidden layer => no dprev).
    let engine = NativeEngine::logreg(6, 3, 0.01, 9, 2)
        .kernel_path(KernelPath::Blocked);
    let meta = engine.meta().clone();
    let mut rng = Rng::new(11);
    let params = rand_vec(&mut rng, meta.param_count, 0.4);
    let x = rand_vec(&mut rng, meta.batch * meta.d, 0.8);
    let y = labels(&mut rng, &meta, 1);
    let (_, grad) = engine.loss_grad(&params, &x, &y).unwrap();
    let fd = finite_diff(&engine, &params, &x, &y, 1e-2);
    assert_vec_close(&grad, &fd, 2e-3, 2e-2, "logreg grad vs finite-diff");
}

#[test]
fn blocked_gradient_matches_finite_differences_mlp() {
    // Small MLP, batch 9 so the packed-transpose dprev path (b >= 8) is
    // exercised. ReLU kinks can land inside a +-h interval for a few
    // (param, row) pairs, each skewing that element's central
    // difference by up to ~|slope change|/2 (~1e-2 here), so the
    // per-element tolerance is loose — the aggregate mean-abs-error
    // check below is what catches a systematically wrong gradient
    // (a bad transpose or offset errs on most elements, not a few).
    let engine = NativeEngine::mlp(5, 3, vec![4], 0.02, 9, 2)
        .kernel_path(KernelPath::Blocked);
    let meta = engine.meta().clone();
    let mut rng = Rng::new(11);
    let params = rand_vec(&mut rng, meta.param_count, 0.4);
    let x = rand_vec(&mut rng, meta.batch * meta.d, 0.8);
    let y = labels(&mut rng, &meta, 1);
    let (_, grad) = engine.loss_grad(&params, &x, &y).unwrap();
    let fd = finite_diff(&engine, &params, &x, &y, 1e-2);
    assert_vec_close(&grad, &fd, 2e-2, 5e-2, "mlp grad vs finite-diff");
    let mean_err = grad
        .iter()
        .zip(&fd)
        .map(|(&g, &f)| (g - f).abs() as f64)
        .sum::<f64>()
        / grad.len() as f64;
    assert!(mean_err < 3e-3, "mean |analytic - fd| = {mean_err}");
}

#[test]
fn kernel_path_default_is_blocked() {
    let e = NativeEngine::linreg(5, 4, 2);
    // the builder default must be the fast path; `native-naive` in
    // setup.rs is the only way to get the reference kernels
    assert_eq!(format!("{:?}", KernelPath::default()), "Blocked");
    // and a default-constructed engine behaves identically to an
    // explicitly-blocked one
    let eb = NativeEngine::linreg(5, 4, 2).kernel_path(KernelPath::Blocked);
    let mut rng = Rng::new(5);
    let params = rand_vec(&mut rng, e.meta().param_count, 0.3);
    let x = rand_vec(&mut rng, 4 * 5, 0.5);
    let y = rand_vec(&mut rng, 4, 1.0);
    assert_eq!(
        e.loss_grad(&params, &x, &y).unwrap(),
        eb.loss_grad(&params, &x, &y).unwrap()
    );
}
