//! fed::system differential + scenario tests.
//!
//! The differential tests prove the event-driven clock reproduces the
//! seed's accounting EXACTLY under a static `SystemModel`: same per-round
//! costs (recomputed with the legacy `advance_round` arithmetic from the
//! oracle speeds), same stage transitions, same `total_time`, and
//! estimate-ranked prefixes identical to oracle-ranked ones. The scenario
//! tests exercise the new time-varying models end to end through the
//! public CLI spec grammar.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::{SpeedEstimator, SystemModel, Trace, VirtualClock};
use flanp::setup;

fn base_cfg(solver: SolverKind, n: usize, s: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(solver, "linreg_d25", n, s);
    cfg.tau = 10;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.max_rounds = 2000;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg.seed = 3;
    cfg
}

fn run(cfg: &ExperimentConfig) -> (Trace, Vec<f64>, Vec<usize>) {
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
    let speeds = fleet.speeds.clone();
    let order = fleet.order.clone();
    let trace = run_solver(&engine, &mut fleet, cfg).unwrap();
    (trace, speeds, order)
}

/// Recompute the seed's cost sequence with the legacy clock arithmetic:
/// round k over the fastest-`participants` oracle prefix costs
/// `tau * max(prefix speeds) + comm`. Times must match bit-for-bit.
fn assert_seed_accounting(
    trace: &Trace,
    speeds: &[f64],
    order: &[usize],
    tau: usize,
) {
    let mut legacy = VirtualClock::new();
    assert_eq!(trace.rounds[0].time, 0.0, "initial record precedes rounds");
    for r in &trace.rounds[1..] {
        let prefix: Vec<f64> =
            order[..r.participants].iter().map(|&c| speeds[c]).collect();
        legacy.advance_round(&prefix, tau);
        assert_eq!(
            r.time,
            legacy.now(),
            "round {} diverged from the seed cost model",
            r.round
        );
        assert_eq!(r.dropped, 0, "static scenario recorded a dropout");
    }
    assert_eq!(trace.total_time, legacy.now());
}

#[test]
fn static_flanp_trace_reproduces_seed_costs_exactly() {
    let cfg = base_cfg(SolverKind::Flanp, 16, 50);
    assert!(cfg.system.is_static() && cfg.estimate_speeds);
    let (trace, speeds, order) = run(&cfg);
    assert!(trace.finished);
    // participants double through stages exactly as in the seed
    let ns: Vec<usize> = trace.stage_transitions.iter().map(|&(_, n)| n).collect();
    assert_eq!(ns, vec![2, 4, 8, 16]);
    assert_seed_accounting(&trace, &speeds, &order, cfg.tau);
}

#[test]
fn static_fedgate_trace_reproduces_seed_costs_exactly() {
    let cfg = base_cfg(SolverKind::FedGate, 12, 50);
    let (trace, speeds, order) = run(&cfg);
    assert!(trace.finished);
    assert_seed_accounting(&trace, &speeds, &order, cfg.tau);
}

#[test]
fn online_estimation_is_bit_identical_to_oracle_when_static() {
    // the estimator's probe prior equals the oracle speeds under static
    // dynamics and observations are exact fixed points, so the FULL
    // trace — ranking, costs, losses — matches the oracle run exactly
    let est = base_cfg(SolverKind::Flanp, 16, 50);
    let mut oracle = base_cfg(SolverKind::Flanp, 16, 50);
    oracle.estimate_speeds = false;
    let (t_est, ..) = run(&est);
    let (t_ora, ..) = run(&oracle);
    assert_eq!(t_est.rounds.len(), t_ora.rounds.len());
    assert_eq!(t_est.stage_transitions, t_ora.stage_transitions);
    assert_eq!(t_est.total_time, t_ora.total_time);
    for (a, b) in t_est.rounds.iter().zip(&t_ora.rounds) {
        assert_eq!(a.time, b.time);
        assert_eq!(a.loss_full, b.loss_full);
        assert_eq!(a.grad_norm_sq, b.grad_norm_sq);
    }
}

#[test]
fn flanp_with_estimation_beats_fedgate_under_markov_drift() {
    // acceptance: a time-varying scenario runs end to end from the CLI
    // spec grammar, FLANP (online speed estimation on by default) still
    // reaches full-N statistical accuracy and wins on wall-clock
    let system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500").unwrap();
    let mut flanp = base_cfg(SolverKind::Flanp, 16, 50);
    flanp.system = system.clone();
    let mut gate = base_cfg(SolverKind::FedGate, 16, 50);
    gate.system = system;
    let (t_flanp, ..) = run(&flanp);
    let (t_gate, ..) = run(&gate);
    assert!(t_flanp.finished, "flanp unfinished under markov drift");
    assert!(t_gate.finished, "fedgate unfinished under markov drift");
    assert!(
        t_flanp.total_time < t_gate.total_time,
        "flanp {} !< fedgate {} under markov drift",
        t_flanp.total_time,
        t_gate.total_time
    );
}

#[test]
fn jitter_scenario_runs_end_to_end_and_perturbs_the_clock() {
    let mut cfg = base_cfg(SolverKind::Flanp, 16, 50);
    cfg.system = SystemModel::parse("jitter:0.3:uniform:50:500").unwrap();
    let (jittered, ..) = run(&cfg);
    let (still, ..) = run(&base_cfg(SolverKind::Flanp, 16, 50));
    assert!(jittered.finished);
    // same optimization trajectory lengths are possible, but realized
    // round costs must differ from the static draw
    assert_ne!(jittered.total_time, still.total_time);
}

#[test]
fn dropout_scenario_records_drops_and_still_converges() {
    let mut cfg = base_cfg(SolverKind::FedGate, 16, 50);
    cfg.system = SystemModel::parse("drop:0.1:uniform:50:500").unwrap();
    let (trace, ..) = run(&cfg);
    assert!(trace.finished, "fedgate unfinished under 10% dropout");
    let total_dropped: usize = trace.rounds.iter().map(|r| r.dropped).sum();
    assert!(
        total_dropped > 0,
        "no dropouts recorded across {} rounds at p=0.1",
        trace.rounds.len()
    );
    // dropped counts never exceed the cohort
    assert!(trace.rounds.iter().all(|r| r.dropped <= 16));
}

#[test]
fn estimator_recovers_true_ranking_after_a_censored_burst() {
    // the over-selection failure mode: a burst of deadline-censored
    // observations (cancelled stragglers report only "slower than the
    // cutoff") pulls the FAST clients' estimates up toward the bound
    // and scrambles the ranking; a bounded number of uncensored rounds
    // must restore it
    let truth = [10.0, 20.0, 40.0, 80.0, 160.0];
    let mut est = SpeedEstimator::new(&truth, 0.25);
    assert_eq!(est.ranked(), vec![0, 1, 2, 3, 4]);
    // five rounds where the two fastest clients get cancelled at a
    // cutoff of 500 per update — censoring only ever pulls UP, so only
    // their estimates move
    for _ in 0..5 {
        est.observe_censored(0, 500.0);
        est.observe_censored(1, 500.0);
    }
    assert_ne!(
        est.ranked(),
        vec![0, 1, 2, 3, 4],
        "censored burst left the ranking intact — the test is vacuous"
    );
    assert!(est.estimate(0) > truth[4], "client 0 not pushed past slowest");
    // uncensored recovery: exact observations are EWMA fixed points, so
    // with alpha = 0.25 the ranking must re-converge within a bounded
    // number of rounds (analytically ~11 here; 20 is a safe ceiling)
    let mut recovered = None;
    for round in 1..=20 {
        for (i, &t) in truth.iter().enumerate() {
            est.observe(i, t);
        }
        if est.ranked() == vec![0, 1, 2, 3, 4] {
            recovered = Some(round);
            break;
        }
    }
    let r = recovered.expect("ranking never recovered in 20 uncensored rounds");
    assert!(r <= 15, "recovery took {r} rounds, expected <= 15");
    // and the estimates themselves converge back toward the truth
    // (geometric decay: ~500 * 0.75^80 residual, far under tolerance)
    for (i, &t) in truth.iter().enumerate() {
        for _ in 0..80 {
            est.observe(i, t);
        }
        assert!((est.estimate(i) - t).abs() < 1e-6 * t.max(1.0));
    }
}

#[test]
fn scenario_selection_flows_through_config_validation() {
    let mut cfg = base_cfg(SolverKind::Flanp, 8, 50);
    cfg.system =
        SystemModel::parse("drop:0.05:markov:4:0.1:0.5:uniform:50:500").unwrap();
    assert!(cfg.validate(10).is_ok());
    cfg.system.p_drop = 1.0; // every client always drops: invalid
    assert!(cfg.validate(10).is_err());
}
