//! fed::population integration tests.
//!
//! The load-bearing regression is the small-N bit-identity pin: a
//! `pop:N:SCENARIO` population at materializable N must run EXACTLY
//! like a plain fleet built from the same scenario — same prefix
//! growth, same losses, same wall-clock, and a byte-identical recorded
//! trace CSV — across the static, jitter, Markov and correlated-
//! availability scenarios. The lazy regime's own contracts (per-client
//! re-realization, O(cohort) state, sketch bounds) are unit-tested in
//! `fed::{population,sketch}`; here we check the two regimes meet at
//! the threshold.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::{
    PopulationSpec, SystemModel, Trace, DEFAULT_EXACT_THRESHOLD,
};
use flanp::setup;
use std::path::PathBuf;

fn base_cfg(solver: SolverKind, n: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(solver, "linreg_d25", n, 50);
    cfg.tau = 10;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.max_rounds = 400;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg.seed = 3;
    cfg.record_trace = true;
    cfg
}

fn assert_traces_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    assert_eq!(a.stage_transitions, b.stage_transitions, "{what}: stages");
    assert_eq!(a.total_time, b.total_time, "{what}: wall-clock");
    assert_eq!(a.finished, b.finished, "{what}: finished");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.time, y.time, "{what} round {}", x.round);
        assert_eq!(x.loss_full, y.loss_full, "{what} round {}", x.round);
        assert_eq!(x.participants, y.participants, "{what} round {}", x.round);
        assert_eq!(x.available, y.available, "{what} round {}", x.round);
    }
}

fn csv_path(file: &str) -> PathBuf {
    std::env::temp_dir().join(file)
}

/// Run `spec` once through the population path (exact regime) and once
/// through the plain fleet path, asserting identical traces and
/// byte-identical recorded trace CSVs.
fn pin_exact_regime(spec: &str, solver: SolverKind, tag: &str) {
    let n = 16;
    let engine = setup::native_from_name("linreg_d25").unwrap();

    // population path: cfg carries a deliberately WRONG size/system so
    // the test fails if build_population_fleet stops overriding them
    let cfg = base_cfg(solver.clone(), 4);
    let pop = PopulationSpec::parse(&format!("pop:{n}:{spec}")).unwrap();
    let mut pf = setup::build_population_fleet(
        engine.meta(),
        &cfg,
        &pop,
        0.1,
        0.0,
        DEFAULT_EXACT_THRESHOLD,
    )
    .unwrap();
    let fleet = pf.exact_mut().expect("small population must materialize");
    let mut sized = cfg.clone();
    sized.num_clients = n;
    sized.system = pop.system.clone();
    let t_pop = run_solver(&engine, fleet, &sized).unwrap();
    let p_pop = csv_path(&format!("pop_pin_{tag}_pop.csv"));
    fleet.write_recorded_trace(&p_pop).unwrap();

    // plain path: the ordinary build_fleet construction
    let mut plain_cfg = base_cfg(solver, n);
    plain_cfg.system = SystemModel::parse(spec).unwrap();
    let mut plain =
        setup::build_fleet(engine.meta(), &plain_cfg, 0.1, 0.0).unwrap();
    let t_plain = run_solver(&engine, &mut plain, &plain_cfg).unwrap();
    let p_plain = csv_path(&format!("pop_pin_{tag}_plain.csv"));
    plain.write_recorded_trace(&p_plain).unwrap();

    assert_traces_identical(&t_pop, &t_plain, tag);
    let (a, b) = (
        std::fs::read(&p_pop).unwrap(),
        std::fs::read(&p_plain).unwrap(),
    );
    assert!(!a.is_empty(), "{tag}: empty recorded trace");
    assert_eq!(a, b, "{tag}: recorded trace CSVs differ");
}

#[test]
fn exact_regime_is_bit_identical_static() {
    pin_exact_regime("uniform:50:500", SolverKind::Flanp, "static");
}

#[test]
fn exact_regime_is_bit_identical_jitter() {
    pin_exact_regime("jitter:0.3:uniform:50:500", SolverKind::Flanp, "jitter");
}

#[test]
fn exact_regime_is_bit_identical_markov() {
    pin_exact_regime(
        "markov:4:0.1:0.5:uniform:50:500",
        SolverKind::FedGate,
        "markov",
    );
}

#[test]
fn exact_regime_is_bit_identical_clustered_availability() {
    pin_exact_regime(
        "avail:cluster:4:0.1:0.3:uniform:50:500",
        SolverKind::Flanp,
        "cluster",
    );
}

#[test]
fn exact_regime_is_bit_identical_diurnal_availability() {
    pin_exact_regime(
        "avail:diurnal:40000:0.25:1:uniform:50:500",
        SolverKind::Flanp,
        "diurnal",
    );
}

#[test]
fn lazy_regime_engages_past_the_threshold_and_is_deterministic() {
    let engine = setup::native_from_name("linreg_d25").unwrap();
    let cfg = base_cfg(SolverKind::Flanp, 4);
    let pop = PopulationSpec::parse(
        "pop:10000:avail:diurnal:40000:0.25:1:uniform:50:500",
    )
    .unwrap();
    let build = || {
        setup::build_population_fleet(
            engine.meta(),
            &cfg,
            &pop,
            0.1,
            0.0,
            DEFAULT_EXACT_THRESHOLD,
        )
        .unwrap()
    };
    let (mut a, mut b) = (build(), build());
    assert!(!a.is_exact());
    let (fa, fb) = (a.lazy_mut().unwrap(), b.lazy_mut().unwrap());
    // frontier + rounds are reproducible across independent builds
    assert_eq!(fa.frontier(), fb.frontier());
    for r in 0..20 {
        let cohort = fa.cohort(64);
        assert_eq!(cohort, fb.cohort(64));
        let now = r as f64 * 1000.0;
        let ca = fa.realize_cohort(&cohort, now);
        let cb = fb.realize_cohort(&cohort, now);
        assert_eq!(ca.times, cb.times, "round {r}");
        assert_eq!(ca.online, cb.online, "round {r}");
        for (k, &i) in ca.ids.iter().enumerate() {
            if ca.online[k] {
                fa.observe(i, ca.times[k]);
                fb.observe(i, cb.times[k]);
            }
        }
    }
}
