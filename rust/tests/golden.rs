//! Golden-trace regression harness.
//!
//! One fixed scenario — diurnal availability rotation plus log-normal
//! speed jitter — and one canonical config per solver; the full
//! per-round CSV trace ([`flanp::fed::Trace::to_csv`]) is byte-compared
//! against a committed fixture in `tests/fixtures/golden/`. Any change
//! to selection, deadline accounting, RNG stream consumption, or the
//! trace schema shows up as a byte diff here. In particular the
//! predictive-selection layer (`fed::selection`) is pinned OFF-path:
//! with `overselect = 1.0` and no forecaster (the defaults every golden
//! config uses) each solver must stay bit-identical to the
//! pre-selection-layer behavior these fixtures freeze.
//!
//! Blessing protocol:
//!   * a MISSING fixture is written from the current run and the test
//!     passes — the first run on a fresh checkout self-blesses; commit
//!     the generated CSVs so later runs compare,
//!   * `FLANP_BLESS=1 cargo test --test golden` regenerates every
//!     fixture after an INTENDED behavior change — commit the diff and
//!     call it out in the PR description.
//!
//! Fixtures are text CSVs produced by deterministic arithmetic on one
//! platform; `exp`/`ln` come from the system libm, so bless on the same
//! platform class that runs CI if a byte diff appears with no code
//! change.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::data::DataSpec;
use flanp::fed::{SystemModel, TierPolicy};
use flanp::setup;
use std::path::PathBuf;

/// The one golden scenario: a rotating 50%-duty diurnal window over the
/// fleet plus mild log-normal jitter — exercises availability skips,
/// wait rounds, deadline arithmetic and estimate drift all at once.
const SCENARIO: &str = "avail:diurnal:20000:0.5:1:jitter:0.2:uniform:50:500";

fn fixtures_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden")
}

fn golden_cfg(solver: SolverKind, tiered: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(solver, "linreg_d25", 16, 50);
    cfg.eta = 0.05;
    cfg.tau = 10;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.system = SystemModel::parse(SCENARIO).unwrap();
    if tiered {
        cfg.tiers = Some(TierPolicy::parse("tiers:4").unwrap());
    }
    cfg.seed = 7;
    // a fixed budget keeps every fixture the same length whether or not
    // the solver reaches statistical accuracy first
    cfg.max_rounds = 120;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg
}

/// Run `cfg`, then byte-compare (or bless) the trace CSV for `tag`.
fn check(tag: &str, cfg: &ExperimentConfig) {
    let engine = setup::native_from_name(&cfg.model).unwrap();
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
    let trace = run_solver(&engine, &mut fleet, cfg).unwrap();
    let got = trace.to_csv();
    let path = fixtures_dir().join(format!("{tag}.csv"));
    let bless = std::env::var("FLANP_BLESS").is_ok_and(|v| v == "1");
    if bless || !path.exists() {
        std::fs::create_dir_all(fixtures_dir()).unwrap();
        std::fs::write(&path, &got).unwrap();
        if !bless {
            eprintln!(
                "golden: blessed missing fixture {} — commit it",
                path.display()
            );
        }
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    if got != want {
        // a full-string assert_eq! dumps both traces; report the first
        // diverging line instead
        let (mut line, mut a, mut b) = (0usize, "", "");
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            if g != w {
                (line, a, b) = (i + 1, g, w);
                break;
            }
        }
        if line == 0 {
            line = got.lines().count().min(want.lines().count()) + 1;
            (a, b) = ("<end>", "<end>");
        }
        panic!(
            "golden trace drifted for {tag} at line {line}:\n  got:  {a}\n  \
             want: {b}\n({} vs {} lines) — if this change is intended, \
             regenerate with FLANP_BLESS=1 and commit the fixture diff",
            got.lines().count(),
            want.lines().count(),
        );
    }
}

#[test]
fn golden_flanp_stage() {
    check("flanp-stage", &golden_cfg(SolverKind::Flanp, false));
}

#[test]
fn golden_flanp_tiered() {
    check("flanp-tiered", &golden_cfg(SolverKind::Flanp, true));
}

#[test]
fn golden_fedgate() {
    check("fedgate", &golden_cfg(SolverKind::FedGate, false));
}

#[test]
fn golden_fedavg() {
    check("fedavg", &golden_cfg(SolverKind::FedAvg, false));
}

#[test]
fn golden_fedprox() {
    check("fedprox", &golden_cfg(SolverKind::FedProx, false));
}

#[test]
fn golden_fednova() {
    check("fednova", &golden_cfg(SolverKind::FedNova, false));
}

#[test]
fn golden_fedbuff2() {
    check("fedbuff2", &golden_cfg(SolverKind::FedBuff { k: 2 }, false));
}

#[test]
fn golden_tifl() {
    check("tifl", &golden_cfg(SolverKind::Tifl, true));
}

/// The non-IID + personalization fixture: speed-correlated Dirichlet
/// label skew with covariate shift on a classification model, solved by
/// ditto — pins the `data:` partitioner, the per-client holdout
/// reservation, the personal-head updates AND the `acc` trace column in
/// one byte-compared trace.
#[test]
fn golden_ditto_noniid() {
    let mut cfg = ExperimentConfig::new(
        SolverKind::Ditto { lambda: 1.0 },
        "logreg_d16_c4",
        8,
        100,
    );
    cfg.eta = 0.05;
    cfg.tau = 10;
    cfg.mu = 0.01;
    cfg.c_stat = 0.5;
    cfg.system = SystemModel::parse(SCENARIO).unwrap();
    cfg.data = DataSpec::parse("data:dirichlet:0.5:shift:2:corr:speed").unwrap();
    cfg.seed = 7;
    cfg.max_rounds = 60;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    check("ditto-noniid", &cfg);
}

/// `data:` off must be BYTE-identical to the pre-`data:` behavior: an
/// explicit `data:iid` spec and the default config produce the same
/// trace bytes. (The eight pre-existing fixtures above pin the same
/// property against the committed CSVs — this pins the explicit spec
/// against the default in-process, with no fixture required.)
#[test]
fn data_iid_spec_is_byte_identical_to_default() {
    let base = golden_cfg(SolverKind::FedAvg, false);
    let mut explicit = base.clone();
    explicit.data = DataSpec::parse("data:iid").unwrap();
    let run = |cfg: &ExperimentConfig| {
        let engine = setup::native_from_name(&cfg.model).unwrap();
        let mut fleet =
            setup::build_fleet(engine.meta(), cfg, 0.1, 0.0).unwrap();
        run_solver(&engine, &mut fleet, cfg).unwrap().to_csv()
    };
    assert_eq!(run(&base), run(&explicit));
}
