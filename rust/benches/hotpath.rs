//! Hot-path microbenchmarks (custom harness — criterion is unavailable
//! offline). Run with `cargo bench`. Results feed EXPERIMENTS.md §Perf
//! and docs/perf.md.
//!
//! Covered paths:
//!   * engine primitives: loss / grad / gate_step / fused gate_round,
//!     native vs HLO (PJRT), per model of the full catalog;
//!   * the fused-round vs per-step dispatch tradeoff (the L3 perf lever);
//!   * a full FedGATE communication round (the end-to-end unit of work);
//!   * server-side aggregation at N=1000 clients;
//!   * naive-vs-blocked kernel ablation on the native engine (PR 6).
//!
//! Besides the human-readable table, the harness writes a
//! machine-readable summary (`BENCH_6.json`, schema `flanp-bench/v1` —
//! see docs/perf.md) so CI can diff runs against a checked-in baseline.
//!
//! Environment knobs:
//!   * `FLANP_BENCH_ITERS=<n>` pins every bench to exactly `n` timed
//!     iterations (after one warmup), bypassing the adaptive ~0.3 s
//!     calibration — use this in CI for reproducible iteration counts.
//!   * `FLANP_BENCH_OUT=<path>` overrides the JSON output path
//!     (default `BENCH_6.json` in the current directory).

use flanp::coordinator::gate::{fedgate_round, GateState, RoundBuffers};
use flanp::coordinator::{ExperimentConfig, SolverKind};
use flanp::engine::{kernels, Engine};
use flanp::fed::ClientFleet;
use flanp::setup;
use flanp::util::json::{obj, Json};
use flanp::util::{linalg, Rng};
use std::collections::BTreeMap;
use std::time::Instant;

/// Schema tag written into the JSON summary; bump on layout changes.
const SCHEMA: &str = "flanp-bench/v1";

#[derive(Clone, Copy)]
struct BenchResult {
    mean_ns: f64,
    min_ns: f64,
    iters: usize,
}

/// Collected results, keyed by bench name (insertion order preserved in
/// the table; JSON objects are sorted by the writer).
#[derive(Default)]
struct Recorder {
    benches: BTreeMap<String, BenchResult>,
    ablation: BTreeMap<String, Json>,
}

fn pinned_iters() -> Option<usize> {
    std::env::var("FLANP_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

/// Time `f`: one warmup call, then either the pinned iteration count
/// (`FLANP_BENCH_ITERS`) or enough iterations for ~0.3 s. Each timed
/// iteration is measured individually so both the mean and the min
/// per-iter time are reported (min is the steadier statistic under CI
/// noise; the ~30 ns `Instant::now` overhead per iter is negligible at
/// the µs+ scale of these benches).
fn bench<F: FnMut()>(rec: &mut Recorder, name: &str, mut f: F) -> BenchResult {
    f(); // warmup + correctness
    let iters = pinned_iters().unwrap_or_else(|| {
        let t0 = Instant::now();
        let mut probe = 0u32;
        while t0.elapsed().as_secs_f64() < 0.05 {
            f();
            probe += 1;
        }
        let per = t0.elapsed().as_secs_f64() / probe as f64;
        ((0.3 / per) as usize).clamp(3, 10_000)
    });
    let mut total_ns = 0.0f64;
    let mut min_ns = f64::INFINITY;
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let ns = t.elapsed().as_secs_f64() * 1e9;
        total_ns += ns;
        min_ns = min_ns.min(ns);
    }
    let res = BenchResult { mean_ns: total_ns / iters as f64, min_ns, iters };
    let (m, mu) = humanize(res.mean_ns);
    let (n, nu) = humanize(res.min_ns);
    println!(
        "{name:<58} mean {m:>8.3} {mu}  min {n:>8.3} {nu}  ({iters} iters)"
    );
    rec.benches.insert(name.to_string(), res);
    res
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns >= 1e9 {
        (ns / 1e9, "s ")
    } else if ns >= 1e6 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e3, "us")
    }
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 0.3);
    v
}

fn engine_suite(rec: &mut Recorder, engine: &dyn Engine, label: &str) {
    let meta = engine.meta().clone();
    let mut rng = Rng::new(9);
    let params = rand_vec(&mut rng, meta.param_count);
    let delta = rand_vec(&mut rng, meta.param_count);
    let x = rand_vec(&mut rng, meta.batch * meta.d);
    let y = onehot_or_real(&mut rng, &meta, 1);
    let xs = rand_vec(&mut rng, meta.tau * meta.batch * meta.d);
    let ys = onehot_or_real(&mut rng, &meta, meta.tau);

    bench(rec, &format!("{label}/loss"), || {
        engine.loss(&params, &x, &y).unwrap();
    });
    bench(rec, &format!("{label}/loss_grad"), || {
        engine.loss_grad(&params, &x, &y).unwrap();
    });
    bench(rec, &format!("{label}/gate_step"), || {
        engine.gate_step(&params, &delta, &x, &y, 0.05).unwrap();
    });
    bench(rec, &format!("{label}/gate_round[fused tau={}]", meta.tau), || {
        engine.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
    });
    // per-step equivalent of the fused round: the dispatch-overhead probe
    bench(rec, &format!("{label}/gate_round[{} x gate_step]", meta.tau), || {
        let mut w = params.clone();
        for t in 0..meta.tau {
            let xi = &xs[t * meta.batch * meta.d..(t + 1) * meta.batch * meta.d];
            let yw = meta.y_width();
            let yi = &ys[t * meta.batch * yw..(t + 1) * meta.batch * yw];
            w = engine.gate_step(&w, &delta, xi, yi, 0.05).unwrap();
        }
    });
}

fn onehot_or_real(rng: &mut Rng, meta: &flanp::engine::ModelMeta, tau: usize) -> Vec<f32> {
    let rows = tau * meta.batch;
    if meta.y_width() == 1 {
        rand_vec(rng, rows)
    } else {
        let mut y = vec![0.0f32; rows * meta.classes];
        for r in 0..rows {
            y[r * meta.classes + rng.below(meta.classes)] = 1.0;
        }
        y
    }
}

/// Naive-vs-blocked kernel ablation (PR 6): time the two hottest native
/// entry points on both `KernelPath`s and record the speedup. Rows land
/// both in `benches` (under the `native-naive/` prefix) and in the
/// dedicated `ablation` map keyed by `{model}/{bench}`.
fn ablation_suite(rec: &mut Recorder, model: &str, artifacts: &std::path::Path) {
    let blocked = setup::build_engine("native", model, artifacts).unwrap();
    let naive = setup::build_engine("native-naive", model, artifacts).unwrap();
    let meta = blocked.meta().clone();
    let mut rng = Rng::new(9);
    let params = rand_vec(&mut rng, meta.param_count);
    let delta = rand_vec(&mut rng, meta.param_count);
    let x = rand_vec(&mut rng, meta.batch * meta.d);
    let y = onehot_or_real(&mut rng, &meta, 1);
    let xs = rand_vec(&mut rng, meta.tau * meta.batch * meta.d);
    let ys = onehot_or_real(&mut rng, &meta, meta.tau);

    let mut row = |rec: &mut Recorder,
                   bench_name: &str,
                   b: BenchResult,
                   n: BenchResult| {
        rec.ablation.insert(
            format!("{model}/{bench_name}"),
            obj(vec![
                ("naive_mean_ns", Json::Num(n.mean_ns)),
                ("blocked_mean_ns", Json::Num(b.mean_ns)),
                ("naive_min_ns", Json::Num(n.min_ns)),
                ("blocked_min_ns", Json::Num(b.min_ns)),
                ("speedup_mean", Json::Num(n.mean_ns / b.mean_ns)),
                ("speedup_min", Json::Num(n.min_ns / b.min_ns)),
            ]),
        );
    };

    let b = bench(rec, &format!("native/{model}/loss_grad [ablation]"), || {
        blocked.loss_grad(&params, &x, &y).unwrap();
    });
    let n = bench(rec, &format!("native-naive/{model}/loss_grad"), || {
        naive.loss_grad(&params, &x, &y).unwrap();
    });
    row(rec, "loss_grad", b, n);

    let b = bench(rec, &format!("native/{model}/gate_round[fused] [ablation]"), || {
        blocked.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
    });
    let n = bench(rec, &format!("native-naive/{model}/gate_round[fused]"), || {
        naive.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
    });
    row(rec, "gate_round[fused]", b, n);
}

fn fedgate_round_bench(
    rec: &mut Recorder,
    engine: &dyn Engine,
    label: &str,
    n_clients: usize,
    s: usize,
) {
    let cfg = ExperimentConfig::new(
        SolverKind::FedGate,
        &engine.meta().name,
        n_clients,
        s,
    );
    let mut fleet: ClientFleet =
        setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0).unwrap();
    let active: Vec<usize> = (0..n_clients).collect();
    let mut state = GateState::new(
        vec![0.01; engine.meta().param_count],
        n_clients,
    );
    let mut bufs = RoundBuffers::new(engine, engine.meta().tau);
    bench(
        rec,
        &format!("{label}/fedgate_round[N={n_clients}, tau={}]", engine.meta().tau),
        || {
            fedgate_round(
                engine, &mut fleet, &mut state, &active,
                engine.meta().tau, 0.05, 1.0, &mut bufs,
            )
            .unwrap();
        },
    );
}

fn aggregation_bench(rec: &mut Recorder) {
    let mut rng = Rng::new(4);
    let p = 109_386; // the MLP parameter count
    let n = 1000;
    let updates: Vec<Vec<f32>> = (0..8).map(|_| rand_vec(&mut rng, p)).collect();
    bench(rec, &format!("server/aggregate[P={p}, N={n}]"), || {
        let mut acc = vec![0.0f64; p];
        for _ in 0..(n / updates.len()) {
            for u in &updates {
                linalg::accumulate(&mut acc, u);
            }
        }
        let _ = linalg::mean_of(&acc, n);
    });
}

/// Serialize the run to the `flanp-bench/v1` schema (docs/perf.md).
fn emit_json(rec: &Recorder, models: &[&str]) {
    let threads = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    let benches = Json::Obj(
        rec.benches
            .iter()
            .map(|(k, r)| {
                (
                    k.clone(),
                    obj(vec![
                        ("mean_ns", Json::Num(r.mean_ns)),
                        ("min_ns", Json::Num(r.min_ns)),
                        ("iters", Json::from(r.iters)),
                    ]),
                )
            })
            .collect(),
    );
    let config = obj(vec![
        ("threads", Json::from(threads)),
        (
            "pinned_iters",
            pinned_iters().map(Json::from).unwrap_or(Json::Null),
        ),
        ("models", models.iter().copied().collect()),
        (
            "kernel_tiles",
            obj(vec![
                ("mr", Json::from(kernels::MR)),
                ("bk", Json::from(kernels::BK)),
                ("bn", Json::from(kernels::BN)),
            ]),
        ),
        ("fedgate_round", obj(vec![
            ("n_clients", Json::from(8usize)),
            ("s", Json::from(100usize)),
        ])),
    ]);
    let doc = obj(vec![
        ("schema", Json::from(SCHEMA)),
        ("config", config),
        ("benches", benches),
        ("ablation", Json::Obj(rec.ablation.clone())),
        ("pending_first_ci_run", Json::Bool(false)),
    ]);
    let out = std::env::var("FLANP_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_6.json".to_string());
    match std::fs::write(&out, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => println!("(could not write {out}: {e})"),
    }
}

fn main() {
    println!("flanp hot-path benchmarks (lower is better)");
    println!("{}", "-".repeat(100));

    let artifacts = setup::default_artifacts_dir();
    let models = ["linreg_d25", "logreg_d784_c10", "mlp_d784_c10_h128_h64"];
    let mut rec = Recorder::default();

    for model in models {
        let native = setup::build_engine("native", model, &artifacts).unwrap();
        engine_suite(&mut rec, native.as_ref(), &format!("native/{model}"));
    }
    aggregation_bench(&mut rec);

    // naive-vs-blocked kernel ablation (native only; always available)
    for model in models {
        ablation_suite(&mut rec, model, &artifacts);
    }
    // end-to-end round cost on the native engine (always available)
    for model in ["linreg_d25", "mlp_d784_c10_h128_h64"] {
        let native = setup::build_engine("native", model, &artifacts).unwrap();
        fedgate_round_bench(
            &mut rec,
            native.as_ref(),
            &format!("native/{model}"),
            8,
            100,
        );
    }

    match setup::build_engine("hlo", models[0], &artifacts) {
        Ok(_) => {
            let manifest =
                flanp::engine::Manifest::load(&artifacts).unwrap();
            for model in models {
                let hlo = setup::build_engine("hlo", model, &artifacts).unwrap();
                engine_suite(&mut rec, hlo.as_ref(), &format!("hlo/{model}"));
                // ablation: same entry points lowered WITHOUT the pallas
                // kernels (plain jnp) — quantifies the CPU-side cost of
                // interpret-mode pallas lowering (EXPERIMENTS.md §Perf;
                // on real TPU the pallas path lowers to Mosaic instead)
                if let Ok(jnp) =
                    flanp::engine::HloEngine::load_variant(&manifest, model, true)
                {
                    engine_suite(&mut rec, &jnp, &format!("hlo-jnp/{model}"));
                }
            }
            for model in ["linreg_d25", "mlp_d784_c10_h128_h64"] {
                let hlo = setup::build_engine("hlo", model, &artifacts).unwrap();
                fedgate_round_bench(
                    &mut rec,
                    hlo.as_ref(),
                    &format!("hlo/{model}"),
                    8,
                    100,
                );
            }
        }
        Err(e) => println!("(hlo benches skipped: {e:#} — run `make artifacts`)"),
    }
    println!("{}", "-".repeat(100));
    emit_json(&rec, &models);
    println!("done");
}
