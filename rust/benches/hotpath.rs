//! Hot-path microbenchmarks (custom harness — criterion is unavailable
//! offline). Run with `cargo bench`. Results feed EXPERIMENTS.md §Perf.
//!
//! Covered paths:
//!   * engine primitives: loss / grad / gate_step / fused gate_round,
//!     native vs HLO (PJRT), per model of the full catalog;
//!   * the fused-round vs per-step dispatch tradeoff (the L3 perf lever);
//!   * a full FedGATE communication round (the end-to-end unit of work);
//!   * server-side aggregation at N=1000 clients.

use flanp::coordinator::gate::{fedgate_round, GateState, RoundBuffers};
use flanp::coordinator::{ExperimentConfig, SolverKind};
use flanp::engine::Engine;
use flanp::fed::ClientFleet;
use flanp::setup;
use flanp::util::{linalg, Rng};
use std::time::Instant;

/// Time `f` adaptively: warm up, then run enough iterations for ~0.3 s.
fn bench<F: FnMut()>(name: &str, mut f: F) {
    f(); // warmup + correctness
    let t0 = Instant::now();
    let mut iters = 0u32;
    while t0.elapsed().as_secs_f64() < 0.05 {
        f();
        iters += 1;
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let target_iters = ((0.3 / per) as u32).clamp(3, 10_000);
    let t1 = Instant::now();
    for _ in 0..target_iters {
        f();
    }
    let per = t1.elapsed().as_secs_f64() / target_iters as f64;
    let (val, unit) = if per >= 1.0 {
        (per, "s ")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else {
        (per * 1e6, "us")
    };
    println!("{name:<58} {val:>9.3} {unit}/iter  ({target_iters} iters)");
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 0.3);
    v
}

fn engine_suite(engine: &dyn Engine, label: &str) {
    let meta = engine.meta().clone();
    let mut rng = Rng::new(9);
    let params = rand_vec(&mut rng, meta.param_count);
    let delta = rand_vec(&mut rng, meta.param_count);
    let x = rand_vec(&mut rng, meta.batch * meta.d);
    let y = onehot_or_real(&mut rng, &meta, 1);
    let xs = rand_vec(&mut rng, meta.tau * meta.batch * meta.d);
    let ys = onehot_or_real(&mut rng, &meta, meta.tau);

    bench(&format!("{label}/loss"), || {
        engine.loss(&params, &x, &y).unwrap();
    });
    bench(&format!("{label}/loss_grad"), || {
        engine.loss_grad(&params, &x, &y).unwrap();
    });
    bench(&format!("{label}/gate_step"), || {
        engine.gate_step(&params, &delta, &x, &y, 0.05).unwrap();
    });
    bench(&format!("{label}/gate_round[fused tau={}]", meta.tau), || {
        engine.gate_round(&params, &delta, &xs, &ys, 0.05).unwrap();
    });
    // per-step equivalent of the fused round: the dispatch-overhead probe
    bench(&format!("{label}/gate_round[{} x gate_step]", meta.tau), || {
        let mut w = params.clone();
        for t in 0..meta.tau {
            let xi = &xs[t * meta.batch * meta.d..(t + 1) * meta.batch * meta.d];
            let yw = meta.y_width();
            let yi = &ys[t * meta.batch * yw..(t + 1) * meta.batch * yw];
            w = engine.gate_step(&w, &delta, xi, yi, 0.05).unwrap();
        }
    });
}

fn onehot_or_real(rng: &mut Rng, meta: &flanp::engine::ModelMeta, tau: usize) -> Vec<f32> {
    let rows = tau * meta.batch;
    if meta.y_width() == 1 {
        rand_vec(rng, rows)
    } else {
        let mut y = vec![0.0f32; rows * meta.classes];
        for r in 0..rows {
            y[r * meta.classes + rng.below(meta.classes)] = 1.0;
        }
        y
    }
}

fn fedgate_round_bench(engine: &dyn Engine, label: &str, n_clients: usize, s: usize) {
    let cfg = ExperimentConfig::new(
        SolverKind::FedGate,
        &engine.meta().name,
        n_clients,
        s,
    );
    let mut fleet: ClientFleet =
        setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0).unwrap();
    let active: Vec<usize> = (0..n_clients).collect();
    let mut state = GateState::new(
        vec![0.01; engine.meta().param_count],
        n_clients,
    );
    let mut bufs = RoundBuffers::new(engine, engine.meta().tau);
    bench(
        &format!("{label}/fedgate_round[N={n_clients}, tau={}]", engine.meta().tau),
        || {
            fedgate_round(
                engine, &mut fleet, &mut state, &active,
                engine.meta().tau, 0.05, 1.0, &mut bufs,
            )
            .unwrap();
        },
    );
}

fn aggregation_bench() {
    let mut rng = Rng::new(4);
    let p = 109_386; // the MLP parameter count
    let n = 1000;
    let updates: Vec<Vec<f32>> = (0..8).map(|_| rand_vec(&mut rng, p)).collect();
    bench(&format!("server/aggregate[P={p}, N={n}]"), || {
        let mut acc = vec![0.0f64; p];
        for _ in 0..(n / updates.len()) {
            for u in &updates {
                linalg::accumulate(&mut acc, u);
            }
        }
        let _ = linalg::mean_of(&acc, n);
    });
}

fn main() {
    println!("flanp hot-path benchmarks (lower is better)");
    println!("{}", "-".repeat(90));

    let artifacts = setup::default_artifacts_dir();
    let models = ["linreg_d25", "logreg_d784_c10", "mlp_d784_c10_h128_h64"];

    for model in models {
        let native = setup::build_engine("native", model, &artifacts).unwrap();
        engine_suite(native.as_ref(), &format!("native/{model}"));
    }
    aggregation_bench();

    match setup::build_engine("hlo", models[0], &artifacts) {
        Ok(_) => {
            let manifest =
                flanp::engine::Manifest::load(&artifacts).unwrap();
            for model in models {
                let hlo = setup::build_engine("hlo", model, &artifacts).unwrap();
                engine_suite(hlo.as_ref(), &format!("hlo/{model}"));
                // ablation: same entry points lowered WITHOUT the pallas
                // kernels (plain jnp) — quantifies the CPU-side cost of
                // interpret-mode pallas lowering (EXPERIMENTS.md §Perf;
                // on real TPU the pallas path lowers to Mosaic instead)
                if let Ok(jnp) =
                    flanp::engine::HloEngine::load_variant(&manifest, model, true)
                {
                    engine_suite(&jnp, &format!("hlo-jnp/{model}"));
                }
            }
            // end-to-end round cost on both engines
            for model in ["linreg_d25", "mlp_d784_c10_h128_h64"] {
                let native = setup::build_engine("native", model, &artifacts).unwrap();
                fedgate_round_bench(native.as_ref(), &format!("native/{model}"), 8, 100);
                let hlo = setup::build_engine("hlo", model, &artifacts).unwrap();
                fedgate_round_bench(hlo.as_ref(), &format!("hlo/{model}"), 8, 100);
            }
        }
        Err(e) => println!("(hlo benches skipped: {e:#} — run `make artifacts`)"),
    }
    println!("{}", "-".repeat(90));
    println!("done");
}
