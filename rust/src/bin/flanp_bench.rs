//! `flanp-bench` — regenerates every table and figure of the paper's
//! evaluation (Section 5) plus the scenario and async/semi-synchronous
//! sweeps. One subcommand per experiment; see DESIGN.md §5 for the
//! mapping, EXPERIMENTS.md for recorded paper-vs-measured runs and
//! `docs/scenarios.md` for the scenario playbook. Run
//! `flanp-bench help` for the full option reference.

use anyhow::{Context, Result};
use flanp::coordinator::config::Subroutine;
use flanp::coordinator::{
    run_solver, run_solver_with, ExperimentConfig, SolverKind,
};
use flanp::data::DataSpec;
use flanp::engine::Engine;
use flanp::fed::{
    observe, ClientFleet, DeadlineController, DeadlinePolicy, EventKind,
    ForecastPolicy, JsonlObserver, LazyFleet, LazyShards, NoopObserver,
    Observe, Observer, Phase, PopulationSpec, Span, SpeedModel,
    StreamingStats, SystemModel, TierPolicy, Trace, VirtualClock,
    LAZY_EVENT_SAMPLE,
};
use flanp::setup;
use flanp::util::cli::Args;
use flanp::util::log;
use flanp::{log_error, log_info};
use std::path::PathBuf;

const USAGE: &str = "\
flanp-bench — regenerate the paper's evaluation + scenario sweeps

USAGE:
  flanp-bench <experiment> [options]
  flanp-bench help

EXPERIMENTS (each row enumerates the shared flags it honors; flags not
listed are accepted but have no effect on that experiment):
  fig1 .. fig9      the paper's figures (fig7 = table1, fig8 = table2)
                    flags: --quick --engine --out --seed --speed
                           --events --summary --log-level
                           (--trials: fig7/fig8 only)
  table1 | table2   runtime ratio tables (effect of s / of N)
                    flags: --quick --engine --out --seed --trials
                           --speed --events --summary --log-level
  ablate            warm start / growth factor / subroutine ablations
                    flags: --quick --engine --out --seed --speed
                           --events --summary --log-level
  scenarios         FLANP vs FedGATE under time-varying heterogeneity
                    (static / jitter / markov / markov+drop)
                    flags: --quick --engine --out --seed --events
                           --summary --log-level (--speed rejected:
                           the sweep runs its own scenario grid)
  async             FLANP vs FedGATE vs FedBuff vs deadline variants
                    under the same four scenarios (semi-sync + async
                    aggregation; see docs/scenarios.md)
                    flags: --quick --engine --out --seed --events
                           --summary --log-level (--speed rejected)
  tiers             tier-cached FLANP (tiers:K[:hysteresis:H]) vs
                    per-round individual re-ranking vs stage re-ranking
                    vs oracle ranking, plus the tifl solver, under the
                    same four scenarios — reports wall-clock AND the
                    re-rank/re-tier events each cadence pays
                    flags: --quick --engine --out --seed --events
                           --summary --log-level (--speed rejected)
  avail             FLANP (stage/tiered) vs FedGATE vs FedBuff vs TiFL
                    under correlated availability: i.i.d. (uncorrelated
                    control), diurnal rotation, clustered outages, and a
                    recorded Markov trace replayed via trace:FILE —
                    the Hard-et-al. \"winner flips\" sweep
                    flags: --quick --engine --out --seed --events
                           --summary --log-level (--speed rejected)
  select            predictive selection: plain quantile-deadline FLANP
                    vs over-selection (overselect:1.3, cancel stragglers
                    at the k-th arrival) vs availability forecasting
                    (forecast:ewma:0.3) vs both, under diurnal rotation,
                    clustered outages and a recorded trace replay —
                    reports wall-clock, cancelled work and misses (see
                    docs/scenarios.md §8)
                    flags: --quick --engine --out --seed --events
                           --summary --log-level (--speed rejected)
  noniid            statistical heterogeneity: FedAvg vs FLANP vs
                    ditto:1 under diurnal availability with
                    speed-correlated Dirichlet label skew + covariate
                    shift (data:dirichlet:0.1:shift:3:corr:speed)
                    against an IID control, at a COMMON simulated-time
                    budget — reports mean and worst-decile per-client
                    held-out accuracy, i.e. whose personalized accuracy
                    collapses when the slow cohort is the shifted one
                    (see docs/scenarios.md §9)
                    flags: --quick --engine --out --seed --events
                           --summary --log-level (--speed rejected)
  scale             population-scale lazy-fleet sweep: O(cohort) rounds
                    over pop:N:avail:diurnal populations (10k -> 1M
                    clients; --quick: 10k -> 50k), measuring host
                    time-per-round flatness as N grows and writing
                    <out>/scale.json (schema flanp-scale/v1, including
                    a per-phase host-time spans object; round count
                    pinned by FLANP_BENCH_ITERS, default 200) — see
                    docs/scale.md
                    flags: --quick --out --seed --events --log-level
                           (--speed rejected; --engine/--trials/
                           --summary unused — spans land in scale.json)
  all               every figure/table/ablation above
                    flags: --quick --engine --out --seed --trials
                           --speed --events --summary --log-level

OPTIONS:
  --quick           reduced sizes (CI-scale; shapes still hold)
  --engine E        native | hlo            [native]
  --out DIR         CSV trace directory     [results]
  --seed N          PRNG seed               [1]
  --trials N        seeds averaged for tables [3]
  --events          write a structured event log per run (JSONL, schema
                    flanp-events/v1) next to its CSV trace in --out:
                    <tag>_<algo>.events.jsonl (scale: scale.events.jsonl
                    with sampled lazy_round events)
  --summary         write a run summary per run (JSON, schema
                    flanp-summary/v1) next to its CSV trace in --out:
                    <tag>_<algo>.summary.json — event totals, estimator-
                    error quantiles, per-phase host-time spans
  --log-level L     error | warn | info | debug [info] (FLANP_LOG env
                    var is the fallback; the flag wins)
  --speed SPEC      override every experiment's system-heterogeneity
                    scenario (not valid for the scenario-grid sweeps,
                    which run their own scenario grids)
                    grammar: [drop:P:][static:|jitter:SIGMA:|markov:F:PS:PR:]BASE
                    prefixes (composable, dropout first):
                      drop:P:            P in [0,1): per-round client dropout
                      static:            no per-round dynamics (default)
                      jitter:SIGMA:      log-normal per-round speed jitter
                      markov:F:PS:PR:    fast/slow Markov drift (slow = F x
                                         base, fast->slow PS, slow->fast PR)
                    BASE = uniform:lo:hi | exp:lambda | homog:t
                    e.g. markov:4:0.1:0.5:uniform:50:500

Deadline policy specs used by the async sweep (and `flanp run
--deadline`): sync | fixed:T | quantile:Q | adaptive:F.

Tier specs used by the tiers sweep (and `flanp run --tiers`):
tiers:K[:split:quantile|kmeans][:hysteresis:H] — K latency tiers
clustered from the online speed estimates (equal-rank quantiles or 1-D
k-means boundaries), membership cached until an estimate drifts past
H x its tier's band (H >= 1, default 1.5).

Availability specs used by the avail sweep (and every `--speed`):
avail:iid:P: | avail:diurnal:PERIOD:DUTY:SPREAD: | avail:cluster:C:PF:PR:
prefixes compose with every base scenario; trace:FILE[:wrap|:hold]
replays a CSV recorded with `flanp run --record-trace` (offline clients
are observable at selection time — skipped, never charged, unlike
drop: dropouts).

Measured \"time\" is the simulated wall-clock of the paper's timing
model (round cost = tau * max participant T_i; deadline rounds cost
min(deadline, slowest); FedBuff charges buffer-flush times) — the same
units the paper's x-axes use, since its speeds are simulated draws too.
";

struct BenchOpts {
    quick: bool,
    engine: String,
    out: PathBuf,
    seed: u64,
    trials: usize,
    /// global scenario override (--speed)
    system: Option<SystemModel>,
    /// per-run event-log sidecars (--events)
    events: bool,
    /// per-run summary sidecars (--summary)
    summary: bool,
}

fn main() {
    log::init_from_env();
    if let Err(e) = real_main() {
        log_error!("error: {e:#}");
        std::process::exit(1);
    }
}

const EXPS: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6a", "fig6b", "fig7",
    "fig8", "fig9", "table1", "table2", "ablate", "scenarios", "async",
    "tiers", "avail", "select", "noniid", "scale", "all", "help",
];

fn real_main() -> Result<()> {
    let mut args = Args::from_env(EXPS).map_err(|e| anyhow::anyhow!(e))?;
    // `flanp-bench --help` works like the `help` subcommand
    if args.switch("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let sub = args
        .subcommand
        .clone()
        .with_context(|| format!("missing experiment subcommand\n{USAGE}"))?;
    if sub == "help" {
        print!("{USAGE}");
        return Ok(());
    }
    if let Some(l) = args.flag_opt("log-level") {
        log::set_level(log::Level::parse(&l).map_err(|e| anyhow::anyhow!(e))?);
    }
    let opts = BenchOpts {
        quick: args.switch("quick"),
        engine: args.flag_str("engine", "native"),
        out: PathBuf::from(args.flag_str("out", "results")),
        seed: args.flag_usize("seed", 1).map_err(|e| anyhow::anyhow!(e))? as u64,
        trials: args.flag_usize("trials", 3).map_err(|e| anyhow::anyhow!(e))?,
        system: args
            .flag_opt("speed")
            .map(|s| SystemModel::parse(&s))
            .transpose()
            .map_err(|e| anyhow::anyhow!(e))?,
        events: args.switch("events"),
        summary: args.switch("summary"),
    };
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    std::fs::create_dir_all(&opts.out)?;

    match sub.as_str() {
        "fig1" => fig1(&opts)?,
        "fig2" => fig2(&opts)?,
        "fig3" => fig34(&opts, false)?,
        "fig4" => fig34(&opts, true)?,
        "fig5" => fig5(&opts)?,
        "fig6a" => fig6(&opts, false)?,
        "fig6b" => fig6(&opts, true)?,
        "fig7" | "table1" => table1(&opts)?,
        "fig8" | "table2" => table2(&opts)?,
        "fig9" => fig9(&opts)?,
        "ablate" => ablate(&opts)?,
        "scenarios" => scenarios(&opts)?,
        "async" => async_sweep(&opts)?,
        "tiers" => tiers_sweep(&opts)?,
        "avail" => avail_sweep(&opts)?,
        "select" => select_sweep(&opts)?,
        "noniid" => noniid_sweep(&opts)?,
        "scale" => scale_sweep(&opts)?,
        "all" => {
            fig1(&opts)?;
            fig2(&opts)?;
            fig34(&opts, false)?;
            fig34(&opts, true)?;
            fig5(&opts)?;
            fig6(&opts, false)?;
            fig6(&opts, true)?;
            table1(&opts)?;
            table2(&opts)?;
            fig9(&opts)?;
            ablate(&opts)?;
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// shared machinery
// ---------------------------------------------------------------------------

/// Run the solver with the optional per-run observability sidecars
/// (`--events` / `--summary`): `<stem>.events.jsonl` and
/// `<stem>.summary.json` land next to the run's CSV trace in `--out`.
/// With neither switch this is exactly `run_solver` — the disabled
/// observer keeps every benchmark number bit-identical.
fn run_observed(
    opts: &BenchOpts,
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    stem: &str,
) -> Result<Trace> {
    if !opts.events && !opts.summary {
        return run_solver_with(engine, fleet, cfg, &mut Observe::off());
    }
    let sink: Box<dyn Observer> = if opts.events {
        let p = opts.out.join(format!("{stem}.events.jsonl"));
        Box::new(
            JsonlObserver::create(&p)
                .with_context(|| format!("creating event log {}", p.display()))?,
        )
    } else {
        Box::new(NoopObserver)
    };
    if opts.summary {
        observe::reset_spans();
        observe::enable_profiling(true);
    }
    let mut obs = Observe::new(sink, opts.summary);
    let t0 = std::time::Instant::now();
    let trace = run_solver_with(engine, fleet, cfg, &mut obs)?;
    if opts.summary {
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let p = opts.out.join(format!("{stem}.summary.json"));
        std::fs::write(&p, obs.summary_json(&trace, wall_ms).to_string() + "\n")
            .with_context(|| format!("writing run summary {}", p.display()))?;
    }
    Ok(trace)
}

/// `"ditto:1"` -> `"ditto-1"`: keep sidecar/CSV names shell-friendly.
fn file_stem(tag: &str, algo: &str) -> String {
    format!("{tag}_{}", algo.replace(':', "-"))
}

/// Run one config and return its trace (building engine + fleet fresh so
/// every algorithm sees identical data and speeds for a given seed). A
/// `--speed` override replaces the experiment's scenario wholesale.
fn run_one(opts: &BenchOpts, cfg: &ExperimentConfig, tag: &str) -> Result<Trace> {
    let mut cfg = cfg.clone();
    if let Some(system) = &opts.system {
        cfg.system = system.clone();
    }
    let cfg = &cfg;
    let engine: Box<dyn Engine> = setup::build_engine(
        &opts.engine,
        &cfg.model,
        &setup::default_artifacts_dir(),
    )?;
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 0.0)?;
    let t0 = std::time::Instant::now();
    let stem = file_stem(tag, &cfg.solver.name());
    let trace = run_observed(opts, engine.as_ref(), &mut fleet, cfg, &stem)?;
    let last = trace.last().context("empty trace")?;
    log_info!(
        "  {:<16} rounds={:<5} time={:<12.1} loss={:<10.6} dist={:<9.4} \
         acc={:<7.4} finished={} [{:.2?}]",
        trace.algo,
        last.round,
        trace.total_time,
        last.loss_full,
        last.dist_to_opt,
        last.accuracy,
        trace.finished,
        t0.elapsed()
    );
    let path = opts.out.join(format!("{tag}_{}.csv", trace.algo));
    trace.write_csv(&path)?;
    Ok(trace)
}

fn print_speedups(base: &str, traces: &[(String, &Trace)], target: f64, by_dist: bool) {
    let time_of = |t: &Trace| -> Option<f64> {
        if by_dist {
            t.time_to_dist(target)
        } else {
            t.time_to_loss(target)
        }
    };
    let base_time = traces
        .iter()
        .find(|(n, _)| n == base)
        .and_then(|(_, t)| time_of(t));
    let metric = if by_dist { "dist" } else { "loss" };
    match base_time {
        Some(bt) => {
            log_info!("  -- time to {metric} <= {target:.4} --");
            for (name, t) in traces {
                match time_of(t) {
                    Some(tt) => log_info!(
                        "  {name:<16} {tt:>12.1}   {:>5.2}x vs {base}",
                        bt / tt
                    ),
                    None => log_info!("  {name:<16} {:>12}   (target not reached)", "-"),
                }
            }
        }
        None => log_info!("  (baseline {base} did not reach the target)"),
    }
}

/// Deep target: 2% above the second-lowest final value, so at least two
/// algorithms reach it — measures endgame speed (where the paper's
/// speedup factors are quoted).
fn deep_target(traces: &[(String, &Trace)], by_dist: bool) -> f64 {
    let mut finals: Vec<f64> = traces
        .iter()
        .map(|(_, t)| {
            let last = t.last().unwrap();
            if by_dist { last.dist_to_opt } else { last.loss_full }
        })
        .collect();
    finals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    finals[1.min(finals.len() - 1)] * 1.02
}

/// Common target = a point most of the way down the drop, clipped so the
/// slowest algorithm can still reach it — every algorithm is compared at
/// the same statistical accuracy.
fn shared_target(traces: &[(String, &Trace)], frac_of_drop: f64, by_dist: bool) -> f64 {
    let finals: Vec<f64> = traces
        .iter()
        .map(|(_, t)| {
            let last = t.last().unwrap();
            if by_dist { last.dist_to_opt } else { last.loss_full }
        })
        .collect();
    let worst_final = finals.iter().cloned().fold(f64::MIN, f64::max);
    let first = traces[0].1.rounds.first().unwrap();
    let start = if by_dist { first.dist_to_opt } else { first.loss_full };
    (start - (start - worst_final) * frac_of_drop).max(worst_final * 1.02)
}

/// Curve figures compare algorithms at a COMMON simulated-time budget
/// (the paper's x-axes are wall-clock): round budgets would be unfair to
/// FLANP, whose early rounds are much cheaper by construction. The budget
/// is expressed as the time `rounds` full-participation rounds would cost
/// at the slowest possible speed (500 for the uniform model).
fn time_budget(rounds: usize, tau: usize) -> f64 {
    rounds as f64 * tau as f64 * 500.0
}

// ---------------------------------------------------------------------------
// Figure 1 — logistic regression (MNIST-like), N=50, s=1200
// ---------------------------------------------------------------------------

fn fig1(opts: &BenchOpts) -> Result<()> {
    log_info!("=== Figure 1: logistic regression, MNIST-like (N=50, s=1200) ===");
    let (n, s, rounds) = if opts.quick { (10, 200, 40) } else { (50, 1200, 120) };
    let mut traces = Vec::new();
    for solver in [SolverKind::Flanp, SolverKind::FedGate, SolverKind::FedAvg] {
        let mut cfg = ExperimentConfig::new(solver.clone(), "logreg_d784_c10", n, s);
        cfg.eta = 0.05;
        // Theorem 1: tau = O(s) local updates per round — one local epoch
        cfg.tau = s / 50;
        cfg.n0 = 2;
        cfg.seed = opts.seed;
        cfg.max_rounds = 50 * rounds;
        cfg.max_time = time_budget(rounds, cfg.tau);
        cfg.eval_rows = 1000;
        // logreg l2 = 0.01 => mu = 0.01; c sized so the full-N stage is
        // reachable within the round budget
        cfg.mu = 0.01;
        cfg.c_stat = if opts.quick { 40.0 } else { 9600.0 };
        traces.push((cfg.solver.name(), run_one(opts, &cfg, "fig1")?));
    }
    let refs: Vec<(String, &Trace)> =
        traces.iter().map(|(n, t)| (n.clone(), t)).collect();
    let target = shared_target(&refs, 0.9, false);
    print_speedups("fedgate", &refs, target, false);
    print_speedups("fedgate", &refs, deep_target(&refs, false), false);
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 2 — linear regression, synthetic, N=100 (10,000 samples)
// ---------------------------------------------------------------------------

fn fig2(opts: &BenchOpts) -> Result<()> {
    log_info!("=== Figure 2: linear regression, synthetic (N=100, 10k samples) ===");
    let (n, s, rounds) = if opts.quick { (20, 50, 150) } else { (100, 100, 600) };
    let mut traces = Vec::new();
    for solver in [SolverKind::Flanp, SolverKind::FedGate, SolverKind::FedAvg] {
        let mut cfg = ExperimentConfig::new(solver.clone(), "linreg_d25", n, s);
        cfg.eta = 0.05;
        cfg.tau = 10;
        cfg.n0 = 2;
        cfg.seed = opts.seed;
        cfg.max_rounds = rounds;
        cfg.eval_rows = 1000;
        cfg.mu = 0.5;
        cfg.c_stat = 0.5;
        traces.push((cfg.solver.name(), run_one(opts, &cfg, "fig2")?));
    }
    let refs: Vec<(String, &Trace)> =
        traces.iter().map(|(n, t)| (n.clone(), t)).collect();
    let target = shared_target(&refs, 0.95, true);
    print_speedups("fedgate", &refs, target, true);
    print_speedups("fedgate", &refs, deep_target(&refs, true), true);
    Ok(())
}

// ---------------------------------------------------------------------------
// Figures 3/4 — MLP(128, 64) on MNIST-like / CIFAR-like, N=20
// ---------------------------------------------------------------------------

fn fig34(opts: &BenchOpts, cifar: bool) -> Result<()> {
    let (label, model, eta) = if cifar {
        ("Figure 4: MLP, CIFAR-like (N=20)", "mlp_d512_c10_h128_h64", 0.02f32)
    } else {
        ("Figure 3: MLP, MNIST-like (N=20)", "mlp_d784_c10_h128_h64", 0.05f32)
    };
    log_info!("=== {label} ===");
    let tag = if cifar { "fig4" } else { "fig3" };
    let (n, s, rounds) = if opts.quick { (8, 100, 12) } else { (20, 500, 60) };
    let mut traces = Vec::new();
    for solver in [
        SolverKind::Flanp,
        SolverKind::FedGate,
        SolverKind::FedAvg,
        SolverKind::FedNova,
    ] {
        let mut cfg = ExperimentConfig::new(solver.clone(), model, n, s);
        cfg.eta = eta;
        cfg.gamma = 1.0;
        cfg.tau = 10;
        cfg.n0 = 2;
        cfg.seed = opts.seed;
        cfg.max_rounds = 50 * rounds;
        cfg.max_time = time_budget(rounds, cfg.tau);
        cfg.eval_rows = 500;
        // nonconvex: the oracle rule applies with the surrogate mu = l2;
        // c sized so FLANP stages advance within the budget
        cfg.mu = 0.01;
        cfg.c_stat = if opts.quick { 400.0 } else { 4000.0 };
        traces.push((cfg.solver.name(), run_one(opts, &cfg, tag)?));
    }
    let refs: Vec<(String, &Trace)> =
        traces.iter().map(|(n, t)| (n.clone(), t)).collect();
    let target = shared_target(&refs, 0.8, false);
    print_speedups("fednova", &refs, target, false);
    print_speedups("fednova", &refs, deep_target(&refs, false), false);
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 5 — MLP, MNIST-like, i.i.d. exponential speeds
// ---------------------------------------------------------------------------

fn fig5(opts: &BenchOpts) -> Result<()> {
    log_info!("=== Figure 5: MLP, MNIST-like, exponential speeds (N=20) ===");
    let (n, s, rounds) = if opts.quick { (8, 100, 12) } else { (20, 500, 60) };
    let mut traces = Vec::new();
    for solver in [
        SolverKind::Flanp,
        SolverKind::FedGate,
        SolverKind::FedAvg,
        SolverKind::FedNova,
    ] {
        let mut cfg =
            ExperimentConfig::new(solver.clone(), "mlp_d784_c10_h128_h64", n, s);
        cfg.eta = 0.05;
        cfg.tau = 10;
        cfg.n0 = 2;
        cfg.system = SpeedModel::Exponential { lambda: 1.0 / 275.0 }.into();
        cfg.seed = opts.seed;
        cfg.max_rounds = 50 * rounds;
        cfg.max_time = time_budget(rounds, cfg.tau);
        cfg.eval_rows = 500;
        cfg.mu = 0.01;
        cfg.c_stat = if opts.quick { 400.0 } else { 4000.0 };
        traces.push((cfg.solver.name(), run_one(opts, &cfg, "fig5")?));
    }
    let refs: Vec<(String, &Trace)> =
        traces.iter().map(|(n, t)| (n.clone(), t)).collect();
    let target = shared_target(&refs, 0.8, false);
    print_speedups("fedgate", &refs, target, false);
    print_speedups("fedgate", &refs, deep_target(&refs, false), false);
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 6 — FLANP vs partial-participation FedGATE (random-k / fastest-k)
// ---------------------------------------------------------------------------

fn fig6(opts: &BenchOpts, fastest: bool) -> Result<()> {
    let label = if fastest {
        "Figure 6b: FLANP vs FedGATE fastest-k (saturation)"
    } else {
        "Figure 6a: FLANP vs FedGATE random-k"
    };
    log_info!("=== {label} (N=50) ===");
    let tag = if fastest { "fig6b" } else { "fig6a" };
    let (n, s, rounds) = if opts.quick { (10, 100, 20) } else { (50, 500, 80) };
    let ks = if opts.quick { vec![2, 5] } else { vec![5, 10, 20] };

    let mut cfg =
        ExperimentConfig::new(SolverKind::Flanp, "mlp_d784_c10_h128_h64", n, s);
    cfg.eta = 0.05;
    cfg.tau = 10;
    cfg.n0 = 2;
    cfg.seed = opts.seed;
    cfg.max_rounds = 50 * rounds;
    cfg.max_time = time_budget(rounds, cfg.tau);
    cfg.eval_rows = 500;
    cfg.mu = 0.01;
    cfg.c_stat = if opts.quick { 400.0 } else { 4000.0 };

    let mut traces = vec![("flanp".to_string(), run_one(opts, &cfg, tag)?)];
    for k in ks {
        let mut c = cfg.clone();
        c.solver = if fastest {
            SolverKind::FedGatePartialFastest { k }
        } else {
            SolverKind::FedGatePartialRandom { k }
        };
        traces.push((c.solver.name(), run_one(opts, &c, tag)?));
    }
    // saturation check (6b): fastest-k should end with HIGHER loss than
    // FLANP because only k clients' data is ever used
    let flanp_final = traces[0].1.last().unwrap().loss_full;
    for (name, t) in &traces[1..] {
        let fin = t.last().unwrap().loss_full;
        log_info!(
            "  {name:<16} final loss {fin:.6} vs flanp {flanp_final:.6} ({})",
            if fin > flanp_final { "saturates above flanp" } else { "below" }
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 7 + Table 1 — effect of s (linreg, N=50, s in {20, 200, 2000})
// ---------------------------------------------------------------------------

/// Run FLANP + FedGATE to the full-set statistical accuracy and report
/// total runtimes + ratio (Table 1/2 rows). Averaged over `trials` seeds
/// with i.i.d. exponential speeds (the Theorem-2 setting).
fn runtime_pair(opts: &BenchOpts, n: usize, s: usize, tag: &str) -> Result<(f64, f64)> {
    let mut t_flanp = 0.0;
    let mut t_gate = 0.0;
    for trial in 0..opts.trials {
        for solver in [SolverKind::Flanp, SolverKind::FedGate] {
            let mut cfg = ExperimentConfig::new(solver.clone(), "linreg_d25", n, s);
            cfg.eta = 0.05;
            cfg.tau = 10;
            cfg.n0 = 2;
            cfg.system = SpeedModel::Exponential { lambda: 1.0 / 275.0 }.into();
            cfg.seed = opts.seed + trial as u64;
            cfg.max_rounds = 3000;
            cfg.eval_rows = 500;
            cfg.eval_every = 5;
            cfg.mu = 0.5;
            cfg.c_stat = 5.0;
            let trace = run_one(opts, &cfg, tag)?;
            anyhow::ensure!(
                trace.finished,
                "{} did not reach statistical accuracy (N={n}, s={s})",
                cfg.solver.name()
            );
            if cfg.solver == SolverKind::Flanp {
                t_flanp += trace.total_time / opts.trials as f64;
            } else {
                t_gate += trace.total_time / opts.trials as f64;
            }
        }
    }
    Ok((t_flanp, t_gate))
}

fn table1(opts: &BenchOpts) -> Result<()> {
    log_info!("=== Figure 7 / Table 1: effect of s (linreg, N=50, exp speeds) ===");
    let n = if opts.quick { 16 } else { 50 };
    let svals = if opts.quick { vec![20, 200] } else { vec![20, 200, 2000] };
    log_info!("  {:>6} {:>14} {:>14} {:>10}", "s", "T_FLANP", "T_FedGATE", "ratio");
    let mut ratios = Vec::new();
    for s in svals {
        let (tf, tg) = runtime_pair(opts, n, s, "table1")?;
        let ratio = tf / tg;
        ratios.push(ratio);
        log_info!("  {s:>6} {tf:>14.1} {tg:>14.1} {ratio:>10.2}");
    }
    // paper's shape: ratio decreases as s grows (0.74 -> 0.43 -> 0.35)
    let monotone = ratios.windows(2).all(|w| w[1] <= w[0] * 1.15);
    log_info!(
        "  ratio trend with s: {:?} — {}",
        ratios.iter().map(|r| (r * 100.0).round() / 100.0).collect::<Vec<_>>(),
        if monotone { "decreasing (matches Table 1)" } else { "NOT decreasing" }
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 8 + Table 2 — effect of N (linreg, s=100, N in {10, 100, 1000})
// ---------------------------------------------------------------------------

fn table2(opts: &BenchOpts) -> Result<()> {
    log_info!("=== Figure 8 / Table 2: effect of N (linreg, s=100, exp speeds) ===");
    let nvals = if opts.quick { vec![8, 64] } else { vec![10, 100, 1000] };
    log_info!("  {:>6} {:>14} {:>14} {:>10}", "N", "T_FLANP", "T_FedGATE", "ratio");
    let mut ratios = Vec::new();
    for n in nvals {
        let (tf, tg) = runtime_pair(opts, n, 100, "table2")?;
        let ratio = tf / tg;
        ratios.push(ratio);
        log_info!("  {n:>6} {tf:>14.1} {tg:>14.1} {ratio:>10.2}");
    }
    let monotone = ratios.windows(2).all(|w| w[1] <= w[0] * 1.15);
    log_info!(
        "  ratio trend with N: {:?} — {}",
        ratios.iter().map(|r| (r * 100.0).round() / 100.0).collect::<Vec<_>>(),
        if monotone { "decreasing (matches Table 2)" } else { "NOT decreasing" }
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 9 — FLANP with heuristic threshold tuning
// ---------------------------------------------------------------------------

fn fig9(opts: &BenchOpts) -> Result<()> {
    log_info!("=== Figure 9: FLANP with heuristic parameter tuning (MLP, N=20) ===");
    let (n, s, rounds) = if opts.quick { (8, 100, 15) } else { (20, 500, 60) };
    let mut traces = Vec::new();
    for solver in [SolverKind::Flanp, SolverKind::FlanpHeuristic, SolverKind::FedGate] {
        let mut cfg =
            ExperimentConfig::new(solver.clone(), "mlp_d784_c10_h128_h64", n, s);
        cfg.eta = 0.05;
        cfg.tau = 10;
        cfg.n0 = 2;
        cfg.seed = opts.seed;
        cfg.max_rounds = 50 * rounds;
        cfg.max_time = time_budget(rounds, cfg.tau);
        cfg.eval_rows = 500;
        cfg.mu = 0.01;
        cfg.c_stat = if opts.quick { 400.0 } else { 4000.0 };
        traces.push((cfg.solver.name(), run_one(opts, &cfg, "fig9")?));
    }
    // heuristic should track oracle: final losses within a factor
    let oracle = traces[0].1.last().unwrap().loss_full;
    let heur = traces[1].1.last().unwrap().loss_full;
    log_info!(
        "  heuristic final loss {heur:.6} vs oracle {oracle:.6} \
         (ratio {:.2} — {})",
        heur / oracle,
        if heur <= oracle * 2.0 { "tracks oracle (Fig 9)" } else { "diverges" }
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Scenarios — time-varying heterogeneity (fed::system): FLANP's online
// speed estimation vs full-participation FedGATE under drift and dropout
// ---------------------------------------------------------------------------

fn scenarios(opts: &BenchOpts) -> Result<()> {
    // each row runs its OWN spec; a global override would silently turn
    // the sweep into four identical, mislabeled runs
    anyhow::ensure!(
        opts.system.is_none(),
        "--speed conflicts with the scenarios sweep (it runs a fixed scenario grid)"
    );
    log_info!("=== Scenarios: FLANP (online estimation) vs FedGATE under drift ===");
    let (n, s, rounds) = if opts.quick { (12, 50, 800) } else { (32, 100, 3000) };
    let specs = [
        ("static", "uniform:50:500"),
        ("jitter", "jitter:0.3:uniform:50:500"),
        ("markov", "markov:4:0.1:0.5:uniform:50:500"),
        ("markov+drop", "drop:0.05:markov:4:0.1:0.5:uniform:50:500"),
    ];
    log_info!(
        "  {:>14} {:>14} {:>14} {:>10} {:>15}",
        "scenario", "T_FLANP", "T_FedGATE", "ratio", "dropped(f/g)"
    );
    for (label, spec) in specs {
        let system = SystemModel::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
        let mut times = [0.0f64; 2];
        let mut dropped = [0usize; 2];
        for (slot, solver) in [SolverKind::Flanp, SolverKind::FedGate]
            .into_iter()
            .enumerate()
        {
            let mut cfg = ExperimentConfig::new(solver, "linreg_d25", n, s);
            cfg.eta = 0.05;
            cfg.tau = 10;
            cfg.n0 = 2;
            cfg.mu = 0.5;
            cfg.c_stat = 0.5;
            cfg.system = system.clone();
            cfg.seed = opts.seed;
            cfg.max_rounds = rounds;
            cfg.eval_every = 5;
            cfg.eval_rows = 500;
            let trace = run_one(opts, &cfg, &format!("scenario_{label}"))?;
            anyhow::ensure!(
                trace.finished,
                "{} did not reach statistical accuracy under {spec}",
                cfg.solver.name()
            );
            times[slot] = trace.total_time;
            dropped[slot] = trace.rounds.iter().map(|r| r.dropped).sum::<usize>();
        }
        log_info!(
            "  {label:>14} {:>14.1} {:>14.1} {:>10.2} {:>15}",
            times[0],
            times[1],
            times[0] / times[1],
            format!("{}/{}", dropped[0], dropped[1]),
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Async / semi-synchronous aggregation — deadline policies + FedBuff vs
// the synchronous baselines, across the fed::system scenario grid
// ---------------------------------------------------------------------------

fn async_sweep(opts: &BenchOpts) -> Result<()> {
    // each row runs its OWN spec; a global override would silently turn
    // the sweep into identical, mislabeled runs
    anyhow::ensure!(
        opts.system.is_none(),
        "--speed conflicts with the async sweep (it runs a fixed scenario grid)"
    );
    log_info!(
        "=== Async/semi-sync: FLANP vs FedGATE vs FedBuff vs deadline variants ==="
    );
    let (n, s, rounds) = if opts.quick { (12, 50, 1200) } else { (32, 100, 4000) };
    let specs = [
        ("static", "uniform:50:500"),
        ("jitter", "jitter:0.3:uniform:50:500"),
        ("markov", "markov:6:0.15:0.4:uniform:50:500"),
        ("markov+drop", "drop:0.05:markov:6:0.15:0.4:uniform:50:500"),
    ];
    let variants: Vec<(&str, SolverKind, DeadlinePolicy)> = vec![
        ("flanp-sync", SolverKind::Flanp, DeadlinePolicy::Sync),
        (
            "flanp-q80",
            SolverKind::Flanp,
            DeadlinePolicy::Quantile { q: 0.8 },
        ),
        (
            "flanp-adapt",
            SolverKind::Flanp,
            DeadlinePolicy::Adaptive { target: 0.8 },
        ),
        ("fedgate-sync", SolverKind::FedGate, DeadlinePolicy::Sync),
        (
            "fedgate-q80",
            SolverKind::FedGate,
            DeadlinePolicy::Quantile { q: 0.8 },
        ),
        (
            "fedbuff",
            SolverKind::FedBuff { k: (n / 4).max(2) },
            DeadlinePolicy::Sync,
        ),
    ];
    for (label, spec) in specs {
        let system = SystemModel::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
        log_info!("  -- scenario {label} ({spec}) --");
        let mut sync_time = None;
        for (name, solver, ddl) in &variants {
            let mut cfg = ExperimentConfig::new(solver.clone(), "linreg_d25", n, s);
            cfg.eta = 0.05;
            cfg.tau = 10;
            cfg.n0 = 2;
            cfg.mu = 0.5;
            cfg.c_stat = 0.5;
            cfg.system = system.clone();
            cfg.deadline = ddl.clone();
            cfg.seed = opts.seed;
            // fedbuff "rounds" are buffer flushes — far cheaper than a
            // full cohort round, so a fair time-to-target comparison
            // needs a proportionally larger flush budget
            cfg.max_rounds = if matches!(solver, SolverKind::FedBuff { .. }) {
                rounds * 10
            } else {
                rounds
            };
            cfg.eval_every = 5;
            cfg.eval_rows = 500;
            let trace = run_one(opts, &cfg, &format!("async_{label}_{name}"))?;
            let missed: usize = trace.rounds.iter().map(|r| r.missed).sum();
            let dropped: usize = trace.rounds.iter().map(|r| r.dropped).sum();
            if *name == "flanp-sync" {
                sync_time = Some(trace.total_time);
            }
            let speedup = sync_time
                .map(|t0| format!("{:>5.2}x vs flanp-sync", t0 / trace.total_time))
                .unwrap_or_default();
            log_info!(
                "  {name:<14} time={:<12.1} rounds={:<5} missed={missed:<5} \
                 dropped={dropped:<5} finished={} {speedup}",
                trace.total_time,
                trace.rounds.len().saturating_sub(1),
                trace.finished,
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Tiers — TiFL-style cached tier scheduling (fed::tiers) vs per-round
// individual re-ranking vs oracle ranking, across the scenario grid
// ---------------------------------------------------------------------------

fn tiers_sweep(opts: &BenchOpts) -> Result<()> {
    // each row runs its OWN spec; a global override would silently turn
    // the sweep into identical, mislabeled runs
    anyhow::ensure!(
        opts.system.is_none(),
        "--speed conflicts with the tiers sweep (it runs a fixed scenario grid)"
    );
    log_info!("=== Tiers: cached tier scheduling vs re-ranking cadences ===");
    let (n, s, rounds) = if opts.quick { (12, 50, 800) } else { (32, 100, 3000) };
    let policy = TierPolicy::parse("tiers:4").map_err(|e| anyhow::anyhow!(e))?;
    let specs = [
        ("static", "uniform:50:500"),
        ("jitter", "jitter:0.3:uniform:50:500"),
        ("markov", "markov:4:0.1:0.5:uniform:50:500"),
        ("markov+drop", "drop:0.05:markov:4:0.1:0.5:uniform:50:500"),
    ];
    // (label, solver, tiers, per-round re-rank, estimate-based ranking).
    // The per-round baseline runs first so every later row — tiered in
    // particular — prints its wall-clock ratio against it.
    let variants: Vec<(&str, SolverKind, bool, bool, bool)> = vec![
        ("flanp-perround", SolverKind::Flanp, false, true, true),
        ("flanp-tiered", SolverKind::Flanp, true, false, true),
        ("flanp-stage", SolverKind::Flanp, false, false, true),
        ("flanp-oracle", SolverKind::Flanp, false, false, false),
        ("tifl", SolverKind::Tifl, true, false, true),
    ];
    for (label, spec) in specs {
        let system = SystemModel::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
        log_info!("  -- scenario {label} ({spec}) --");
        let mut perround_time = None;
        for (name, solver, tiered, perround, estimated) in &variants {
            let mut cfg = ExperimentConfig::new(solver.clone(), "linreg_d25", n, s);
            cfg.eta = 0.05;
            cfg.tau = 10;
            cfg.n0 = 2;
            cfg.mu = 0.5;
            cfg.c_stat = 0.5;
            cfg.system = system.clone();
            cfg.tiers = if *tiered { Some(policy.clone()) } else { None };
            cfg.rerank_per_round = *perround;
            cfg.estimate_speeds = *estimated;
            cfg.seed = opts.seed;
            // tifl trains one tier per round — cheap, straggler-free
            // rounds, but only 1/K of the fleet progresses per round, so
            // a fair time-to-accuracy comparison needs a larger budget
            cfg.max_rounds =
                if *solver == SolverKind::Tifl { rounds * 4 } else { rounds };
            cfg.eval_every = 5;
            cfg.eval_rows = 500;
            let trace = run_one(opts, &cfg, &format!("tiers_{label}_{name}"))?;
            if *name == "flanp-perround" {
                perround_time = Some(trace.total_time);
            }
            let vs = perround_time
                .map(|t0| format!("{:>5.2}x vs perround", t0 / trace.total_time))
                .unwrap_or_default();
            log_info!(
                "  {name:<15} time={:<12.1} rounds={:<5} reranks={:<5} \
                 finished={} {vs}",
                trace.total_time,
                trace.rounds.len().saturating_sub(1),
                trace.total_reranks(),
                trace.finished,
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Avail — correlated availability (fed::traces): i.i.d. control vs
// diurnal rotation vs clustered outages vs a replayed recorded trace
// ---------------------------------------------------------------------------

fn avail_sweep(opts: &BenchOpts) -> Result<()> {
    // each row runs its OWN spec; a global override would silently turn
    // the sweep into identical, mislabeled runs
    anyhow::ensure!(
        opts.system.is_none(),
        "--speed conflicts with the avail sweep (it runs a fixed scenario grid)"
    );
    log_info!(
        "=== Avail: correlated availability vs the uncorrelated control ==="
    );
    let (n, s, rounds) = if opts.quick { (12, 50, 1500) } else { (32, 100, 6000) };

    // record a Markov reference run first, so the grid includes a
    // replayed measured trace: every synthetic scenario is a replayable
    // fixture (record -> replay is bit-identical; see tests/traces.rs)
    let recorded = opts.out.join("avail_recorded_markov.csv");
    {
        let mut cfg =
            ExperimentConfig::new(SolverKind::FedGate, "linreg_d25", n, s);
        cfg.eta = 0.05;
        cfg.tau = 10;
        cfg.mu = 0.5;
        cfg.c_stat = 0.5;
        cfg.system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500")
            .map_err(|e| anyhow::anyhow!(e))?;
        cfg.seed = opts.seed;
        cfg.max_rounds = rounds;
        cfg.eval_every = 5;
        cfg.eval_rows = 500;
        cfg.record_trace = true;
        let engine = setup::build_engine(
            &opts.engine,
            &cfg.model,
            &setup::default_artifacts_dir(),
        )?;
        let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
        run_solver(engine.as_ref(), &mut fleet, &cfg)?;
        fleet
            .write_recorded_trace(&recorded)
            .map_err(|e| anyhow::anyhow!(e))?;
        log_info!(
            "  recorded {} realized rounds to {}",
            fleet.recorded_trace().map_or(0, |d| d.num_rounds()),
            recorded.display()
        );
    }

    // the diurnal row rotates a 25%-duty online window around the fleet
    // (spread 1); iid is the same marginal availability, uncorrelated
    let specs: Vec<(&str, String)> = vec![
        ("iid", "avail:iid:0.25:uniform:50:500".into()),
        ("diurnal", "avail:diurnal:40000:0.25:1:uniform:50:500".into()),
        ("clustered", "avail:cluster:4:0.1:0.3:uniform:50:500".into()),
        ("replayed", format!("trace:{}", recorded.display())),
    ];
    let policy = TierPolicy::parse("tiers:4").map_err(|e| anyhow::anyhow!(e))?;
    // (label, solver, tier policy on)
    let variants: Vec<(&str, SolverKind, bool)> = vec![
        ("flanp-stage", SolverKind::Flanp, false),
        ("flanp-tiered", SolverKind::Flanp, true),
        ("fedgate", SolverKind::FedGate, false),
        ("fedbuff", SolverKind::FedBuff { k: (n / 4).max(2) }, false),
        ("tifl", SolverKind::Tifl, true),
    ];
    for (label, spec) in &specs {
        let system =
            SystemModel::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
        log_info!("  -- scenario {label} ({spec}) --");
        for (name, solver, tiered) in &variants {
            let mut cfg =
                ExperimentConfig::new(solver.clone(), "linreg_d25", n, s);
            cfg.eta = 0.05;
            cfg.tau = 10;
            cfg.n0 = 2;
            cfg.mu = 0.5;
            cfg.c_stat = 0.5;
            cfg.system = system.clone();
            cfg.tiers = if *tiered { Some(policy.clone()) } else { None };
            cfg.seed = opts.seed;
            // fedbuff "rounds" are buffer flushes and tifl trains one
            // tier per round: both need proportionally larger budgets
            // for a fair time-to-accuracy comparison
            cfg.max_rounds = match solver {
                SolverKind::FedBuff { .. } => rounds * 10,
                SolverKind::Tifl => rounds * 4,
                _ => rounds,
            };
            cfg.eval_every = 5;
            cfg.eval_rows = 500;
            let trace = run_one(opts, &cfg, &format!("avail_{label}_{name}"))?;
            let min_avail = trace.min_available().unwrap_or(0);
            log_info!(
                "  {name:<14} time={:<12.1} rounds={:<5} min-avail={min_avail:<3} \
                 finished={}",
                trace.total_time,
                trace.rounds.len().saturating_sub(1),
                trace.finished,
            );
        }
    }
    log_info!(
        "  (the ranking under diurnal vs iid is the Hard-et-al. effect: \
         correlated availability changes the winner)"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Select — predictive selection (fed::selection): over-selection with
// straggler cancellation and availability forecasting vs the plain
// quantile-deadline baseline, under correlated availability
// ---------------------------------------------------------------------------

fn select_sweep(opts: &BenchOpts) -> Result<()> {
    // each row runs its OWN spec; a global override would silently turn
    // the sweep into identical, mislabeled runs
    anyhow::ensure!(
        opts.system.is_none(),
        "--speed conflicts with the select sweep (it runs a fixed scenario grid)"
    );
    log_info!(
        "=== Select: over-selection + availability forecasting vs plain \
         quantile-deadline FLANP ==="
    );
    let (n, s, rounds) = if opts.quick { (12, 50, 1500) } else { (32, 100, 6000) };

    // record a diurnal reference run first so the grid includes a
    // replayed measured trace (record -> replay is bit-identical)
    let recorded = opts.out.join("select_recorded_diurnal.csv");
    {
        let mut cfg =
            ExperimentConfig::new(SolverKind::FedGate, "linreg_d25", n, s);
        cfg.eta = 0.05;
        cfg.tau = 10;
        cfg.mu = 0.5;
        cfg.c_stat = 0.5;
        cfg.system = SystemModel::parse(
            "avail:diurnal:40000:0.25:1:jitter:0.2:uniform:50:500",
        )
        .map_err(|e| anyhow::anyhow!(e))?;
        cfg.seed = opts.seed;
        cfg.max_rounds = rounds;
        cfg.eval_every = 5;
        cfg.eval_rows = 500;
        cfg.record_trace = true;
        let engine = setup::build_engine(
            &opts.engine,
            &cfg.model,
            &setup::default_artifacts_dir(),
        )?;
        let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
        run_solver(engine.as_ref(), &mut fleet, &cfg)?;
        fleet
            .write_recorded_trace(&recorded)
            .map_err(|e| anyhow::anyhow!(e))?;
        log_info!(
            "  recorded {} realized rounds to {}",
            fleet.recorded_trace().map_or(0, |d| d.num_rounds()),
            recorded.display()
        );
    }

    let specs: Vec<(&str, String)> = vec![
        (
            "diurnal",
            "avail:diurnal:40000:0.25:1:jitter:0.2:uniform:50:500".into(),
        ),
        ("clustered", "avail:cluster:4:0.1:0.3:uniform:50:500".into()),
        ("replayed", format!("trace:{}", recorded.display())),
    ];
    // (label, overselect factor, forecast policy)
    let variants: Vec<(&str, f64, Option<ForecastPolicy>)> = vec![
        ("flanp-plain", 1.0, None),
        ("flanp-over1.3", 1.3, None),
        ("flanp-fc-ewma", 1.0, Some(ForecastPolicy::Ewma { alpha: 0.3 })),
        (
            "flanp-over+fc",
            1.3,
            Some(ForecastPolicy::Ewma { alpha: 0.3 }),
        ),
    ];
    for (label, spec) in &specs {
        let system =
            SystemModel::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
        log_info!("  -- scenario {label} ({spec}) --");
        let mut plain_time = None;
        for (name, overselect, forecast) in &variants {
            let mut cfg =
                ExperimentConfig::new(SolverKind::Flanp, "linreg_d25", n, s);
            cfg.eta = 0.05;
            cfg.tau = 10;
            cfg.n0 = 2;
            cfg.mu = 0.5;
            cfg.c_stat = 0.5;
            cfg.system = system.clone();
            cfg.deadline = DeadlinePolicy::Quantile { q: 0.8 };
            cfg.overselect = *overselect;
            cfg.forecast = forecast.clone();
            cfg.seed = opts.seed;
            cfg.max_rounds = rounds;
            cfg.eval_every = 5;
            cfg.eval_rows = 500;
            let trace = run_one(opts, &cfg, &format!("select_{label}_{name}"))?;
            if *name == "flanp-plain" {
                plain_time = Some(trace.total_time);
            }
            let vs = plain_time
                .map(|t0| format!("{:>5.2}x vs plain", t0 / trace.total_time))
                .unwrap_or_default();
            log_info!(
                "  {name:<14} time={:<12.1} rounds={:<5} cancelled={:<5} \
                 missed={:<5} finished={} {vs}",
                trace.total_time,
                trace.rounds.len().saturating_sub(1),
                trace.total_cancelled(),
                trace.total_missed(),
                trace.finished,
            );
        }
    }
    log_info!(
        "  (over-selection trades cancelled work for wall-clock; the \
         cancelled column is the price — see docs/scenarios.md §8)"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Non-IID — statistical heterogeneity (data/synth.rs): whose personalized
// accuracy collapses when the slow cohort is the shifted one?
// ---------------------------------------------------------------------------

/// The paper's interplay, pushed to its adversarial corner: FLANP's
/// fastest-prefix stages and diurnal availability both bias
/// participation toward a cohort — and `corr:speed` makes that cohort
/// the statistically CLEAN one, so the slow, shifted clients' data is
/// systematically under-represented in every global model. Ditto's
/// personal heads are the control that separates "never participated"
/// from "participated but averaged away".
fn noniid_sweep(opts: &BenchOpts) -> Result<()> {
    // each row runs its OWN data/system spec; a global override would
    // silently turn the sweep into identical, mislabeled runs
    anyhow::ensure!(
        opts.system.is_none(),
        "--speed conflicts with the noniid sweep (it runs a fixed scenario grid)"
    );
    log_info!(
        "=== Non-IID: FedAvg vs FLANP vs ditto under diurnal availability \
         + speed-correlated skew ==="
    );
    let (n, s, rounds) = if opts.quick { (8, 100, 30) } else { (24, 200, 100) };
    let system = SystemModel::parse("avail:diurnal:40000:0.25:1:uniform:50:500")
        .map_err(|e| anyhow::anyhow!(e))?;
    let scenarios: Vec<(&str, DataSpec)> = vec![
        ("iid", DataSpec::iid()),
        (
            "skewed",
            DataSpec::parse("data:dirichlet:0.1:shift:3:corr:speed")
                .map_err(|e| anyhow::anyhow!(e))?,
        ),
    ];
    let solvers = [
        SolverKind::FedAvg,
        SolverKind::Flanp,
        SolverKind::Ditto { lambda: 1.0 },
    ];
    for (label, data) in &scenarios {
        log_info!("  -- scenario {label} ({}) --", data.spec());
        let mut worst: Vec<(String, f64)> = Vec::new();
        for solver in &solvers {
            let mut cfg =
                ExperimentConfig::new(solver.clone(), "logreg_d16_c4", n, s);
            cfg.eta = 0.05;
            cfg.tau = 10;
            cfg.n0 = 2;
            cfg.mu = 0.01;
            cfg.c_stat = if opts.quick { 40.0 } else { 400.0 };
            cfg.system = system.clone();
            cfg.data = data.clone();
            cfg.seed = opts.seed;
            // every solver gets the SAME simulated-time budget, so the
            // accuracy comparison below is at comparable wall-clock
            cfg.max_rounds = 50 * rounds;
            cfg.max_time = time_budget(rounds, cfg.tau);
            cfg.eval_every = 5;
            cfg.eval_rows = 500;
            let trace =
                run_noniid_one(opts, &cfg, &format!("noniid_{label}"))?;
            worst.push((cfg.solver.name(), trace.worst_decile_acc()));
        }
        let by = |name: &str| {
            worst.iter().find(|(n2, _)| n2 == name).map(|(_, a)| *a).unwrap()
        };
        let (fa, fl, di) = (by("fedavg"), by("flanp"), by("ditto:1"));
        log_info!(
            "  worst-decile acc: fedavg={fa:.3} flanp={fl:.3} ditto={di:.3} \
             — {}",
            if *label == "skewed" {
                if di > fa && di > fl {
                    "global models collapse on the slow+shifted cohort; \
                     ditto's heads hold (the interplay result)"
                } else {
                    "WARNING: personalization did not win — check budgets"
                }
            } else {
                "IID control: the three should tie"
            }
        );
    }
    Ok(())
}

/// Like [`run_one`], but for the non-IID sweep: classification data with
/// a clearer class structure (separation 2.0 instead of the model
/// default), a per-client holdout FORCED even for the IID control arms
/// (so every cell of the grid reports the same per-client metric), and
/// mean / worst-decile held-out accuracy printed alongside the usual
/// row.
fn run_noniid_one(
    opts: &BenchOpts,
    cfg: &ExperimentConfig,
    tag: &str,
) -> Result<Trace> {
    let engine: Box<dyn Engine> = setup::build_engine(
        &opts.engine,
        &cfg.model,
        &setup::default_artifacts_dir(),
    )?;
    let mut fleet = setup::build_fleet(engine.meta(), cfg, 0.1, 2.0)?;
    if fleet.holdout() == 0 {
        // IID + non-ditto arms don't reserve a holdout on their own;
        // force one so the control reports the same per-client metric
        fleet.set_holdout(engine.meta().batch);
    }
    let t0 = std::time::Instant::now();
    let stem = file_stem(tag, &cfg.solver.name());
    let trace = run_observed(opts, engine.as_ref(), &mut fleet, cfg, &stem)?;
    let last = trace.last().context("empty trace")?;
    log_info!(
        "  {:<12} rounds={:<5} time={:<12.1} loss={:<10.6} acc(mean)={:<7.4} \
         acc(wd)={:<7.4} finished={} [{:.2?}]",
        trace.algo,
        last.round,
        trace.total_time,
        last.loss_full,
        trace.mean_client_acc(),
        trace.worst_decile_acc(),
        trace.finished,
        t0.elapsed()
    );
    // "ditto:1" -> "ditto-1": keep CSV names shell- and glob-friendly
    let path = opts
        .out
        .join(format!("{tag}_{}.csv", trace.algo.replace(':', "-")));
    trace.write_csv(&path)?;
    Ok(trace)
}

/// Population-scale sweep (docs/scale.md): run the lazily-realized
/// fleet over `pop:N:avail:diurnal` populations from 10k to 1M clients
/// and measure the HOST cost of a round. The O(cohort) contract says
/// that cost is flat in N — the only O(N) work is the one-time
/// construction scan, reported separately as `setup_ms`. Each round:
/// select a cohort inside the frontier, realize conditions for the
/// cohort only, price a `quantile:0.9` deadline off the population
/// speed sketch, charge the virtual clock (all-offline rounds charge an
/// estimate-priced wait, mirroring `deadline_round`), run plain SGD on
/// lazily synthesized minibatches for the arrivals, and fold exact /
/// censored observations back into the frontier estimates. Writes
/// `<out>/scale.json` (schema `flanp-scale/v1`).
fn scale_sweep(opts: &BenchOpts) -> Result<()> {
    anyhow::ensure!(
        opts.system.is_none(),
        "--speed conflicts with the scale sweep (populations carry their \
         own pop:N: scenarios)"
    );
    let rounds: usize = match std::env::var("FLANP_BENCH_ITERS") {
        Ok(v) => v
            .parse()
            .with_context(|| format!("bad FLANP_BENCH_ITERS '{v}'"))?,
        Err(_) => 200,
    };
    let pinned = std::env::var("FLANP_BENCH_ITERS").is_ok();
    let populations: &[usize] = if opts.quick {
        &[10_000, 50_000]
    } else {
        &[10_000, 100_000, 1_000_000]
    };
    let (cohort_size, tau, s, d, batch) = (256usize, 10usize, 64usize, 32usize, 16usize);
    let eta = 0.01f32;
    log_info!(
        "=== Scale: O(cohort) rounds over lazy populations \
         (cohort={cohort_size}, rounds={rounds}) ==="
    );

    let ddl = DeadlineController::new(
        DeadlinePolicy::parse("quantile:0.9").map_err(|e| anyhow::anyhow!(e))?,
    );
    // host-side span profiler: the per-phase breakdown lands in
    // scale.json's "spans" object (run-wide, summed over populations)
    observe::reset_spans();
    observe::enable_profiling(true);
    // --events: one sidecar for the whole sweep, lazy_round events
    // sampled every LAZY_EVENT_SAMPLE rounds (stage = population index)
    let mut obs = if opts.events {
        let p = opts.out.join("scale.events.jsonl");
        Observe::new(
            Box::new(JsonlObserver::create(&p).with_context(|| {
                format!("creating event log {}", p.display())
            })?),
            false,
        )
    } else {
        Observe::off()
    };
    let mut rows = Vec::new();
    for (pi, &n) in populations.iter().enumerate() {
        obs.set_stage(pi);
        let spec = PopulationSpec::parse(&format!(
            "pop:{n}:avail:diurnal:40000:0.25:1:jitter:0.2:uniform:50:500"
        ))
        .map_err(|e| anyhow::anyhow!(e))?;
        let t0 = std::time::Instant::now();
        let mut fleet = LazyFleet::new(spec, opts.seed);
        let mut shards = LazyShards::new(opts.seed, s, d, 0.1);
        let setup_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut clock = VirtualClock::new();
        let mut w = vec![0.0f32; d];
        let mut grad = vec![0.0f32; d];
        let mut xb = vec![0.0f32; batch * d];
        let mut yb = vec![0.0f32; batch];
        let mut per_round = StreamingStats::new();
        let mut waits = 0usize;
        for r in 0..rounds {
            let r0 = std::time::Instant::now();
            let cond = {
                let _sp = Span::enter(Phase::Select);
                let cohort = fleet.cohort(cohort_size);
                fleet.realize_cohort(&cohort, clock.now())
            };
            obs.set_round(r);
            if obs.enabled() && r % LAZY_EVENT_SAMPLE == 0 {
                obs.emit(EventKind::LazyRound, None, cond.event_detail());
            }
            let present = cond.online_positions();
            if present.is_empty() {
                // mirror deadline_round: diurnal outages wake at the
                // cohort's next window; the wait is charged
                let now = clock.now();
                let wake = fleet
                    .spec()
                    .system
                    .avail
                    .as_ref()
                    .and_then(|a| a.next_online_time(now, &cond.ids, n))
                    .unwrap_or_else(|| {
                        let est_max = cond
                            .ids
                            .iter()
                            .map(|&i| fleet.estimate(i))
                            .fold(0.0, f64::max);
                        now + tau as f64 * est_max
                    });
                clock.charge_wait(wake);
                waits += 1;
                per_round.push(r0.elapsed().as_secs_f64() * 1e6);
                continue;
            }
            let sp_agg = Span::enter(Phase::Aggregate);
            let deadline = ddl.round_deadline_sketch(fleet.speed_sketch(), tau);
            let mut ids = Vec::with_capacity(present.len());
            let mut times = Vec::with_capacity(present.len());
            let (mut arrived, mut late) = (Vec::new(), Vec::new());
            let mut dropped = 0usize;
            for &k in &present {
                ids.push(cond.ids[k]);
                times.push(cond.times[k]);
                if tau as f64 * cond.times[k] > deadline {
                    late.push(k);
                } else if cond.available[k] {
                    arrived.push(k);
                } else {
                    dropped += 1;
                }
            }
            clock.charge_round_deadline(
                &ids,
                &times,
                tau,
                deadline,
                dropped,
                late.len(),
            );
            drop(sp_agg);
            if !arrived.is_empty() {
                let _sp = Span::enter(Phase::LocalRounds);
                grad.iter_mut().for_each(|g| *g = 0.0);
                for &k in &arrived {
                    shards.fill_minibatch(cond.ids[k], batch, &mut xb, &mut yb);
                    for b in 0..batch {
                        let x = &xb[b * d..(b + 1) * d];
                        let err: f32 = x
                            .iter()
                            .zip(&w)
                            .map(|(xi, wi)| xi * wi)
                            .sum::<f32>()
                            - yb[b];
                        for (g, xi) in grad.iter_mut().zip(x) {
                            *g += err * xi;
                        }
                    }
                }
                let scale = eta / (arrived.len() * batch) as f32;
                for (wi, g) in w.iter_mut().zip(&grad) {
                    *wi -= scale * g;
                }
            }
            {
                let _sp = Span::enter(Phase::Bookkeeping);
                for &k in &arrived {
                    fleet.observe(cond.ids[k], cond.times[k]);
                }
                for &k in &late {
                    fleet.observe_censored(cond.ids[k], deadline / tau as f64);
                }
            }
            per_round.push(r0.elapsed().as_secs_f64() * 1e6);
        }
        let dist: f64 = w
            .iter()
            .zip(shards.teacher())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        log_info!(
            "  n={n:<9} setup={setup_ms:>8.1}ms round_us mean={:<8.1} \
             min={:<8.1} max={:<8.1} waits={waits:<4} vtime={:<12.1} \
             dist={dist:.4}",
            per_round.mean(),
            per_round.min(),
            per_round.max(),
            clock.now(),
        );
        rows.push((n, setup_ms, per_round, waits, clock.now(), dist));
    }

    // the flatness verdict: O(cohort) means the mean host round cost
    // may not grow with the population
    let means: Vec<f64> = rows.iter().map(|r| r.2.mean()).collect();
    let ratio = means.iter().fold(f64::MIN, |a, &b| a.max(b))
        / means.iter().fold(f64::MAX, |a, &b| a.min(b));
    let flat = ratio <= 2.0;
    log_info!(
        "  round cost {} -> {} clients: {ratio:.2}x {}",
        populations.first().unwrap(),
        populations.last().unwrap(),
        if flat { "FLAT (within 2x) PASS" } else { "NOT flat (>2x) FAIL" }
    );

    let mut json = String::from("{\n");
    json.push_str("  \"schema\": \"flanp-scale/v1\",\n");
    json.push_str(&format!("  \"rounds\": {rounds},\n"));
    json.push_str(&format!(
        "  \"pinned_iters\": {},\n",
        if pinned { rounds.to_string() } else { "null".into() }
    ));
    json.push_str(&format!("  \"cohort\": {cohort_size},\n"));
    json.push_str(&format!("  \"flat_within_2x\": {flat},\n"));
    json.push_str(&format!("  \"ratio\": {ratio},\n"));
    // host-side per-phase breakdown, summed across all populations
    json.push_str("  \"spans\": {\n");
    let report = observe::span_report();
    for (j, (name, total_us, count)) in report.iter().enumerate() {
        json.push_str(&format!(
            "    \"{name}\": {{\"total_us\": {total_us}, \"count\": {count}}}{}\n",
            if j + 1 < report.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n");
    json.push_str("  \"populations\": [\n");
    for (j, (n, setup_ms, st, waits, vtime, dist)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"n\": {n}, \"setup_ms\": {setup_ms}, \
             \"round_us_mean\": {}, \"round_us_min\": {}, \
             \"round_us_max\": {}, \"waits\": {waits}, \
             \"virtual_time\": {vtime}, \"dist_to_teacher\": {dist}}}{}\n",
            st.mean(),
            st.min(),
            st.max(),
            if j + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = opts.out.join("scale.json");
    std::fs::write(&path, json)?;
    log_info!("  wrote {}", path.display());
    if opts.events {
        log_info!("  wrote {}", opts.out.join("scale.events.jsonl").display());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Ablations — the design choices DESIGN.md §5a calls out
// ---------------------------------------------------------------------------

fn ablate(opts: &BenchOpts) -> Result<()> {
    log_info!("=== Ablations: warm start / growth factor / subroutine (linreg, N=64) ===");
    let n = if opts.quick { 16 } else { 64 };
    let s = 100;
    let base = || {
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "linreg_d25", n, s);
        cfg.eta = 0.05;
        cfg.tau = 10;
        cfg.n0 = 2;
        cfg.mu = 0.5;
        cfg.c_stat = 0.5;
        cfg.seed = opts.seed;
        cfg.max_rounds = 3000;
        cfg.eval_every = 5;
        cfg.eval_rows = 500;
        cfg
    };
    let variants: Vec<(&str, ExperimentConfig)> = vec![
        ("paper (warm, x2, gate)", base()),
        ("no warm start", {
            let mut c = base();
            c.warm_start = false;
            c
        }),
        ("growth x4", {
            let mut c = base();
            c.growth = 4.0;
            c
        }),
        ("growth x1.5", {
            let mut c = base();
            c.growth = 1.5;
            c
        }),
        ("fedavg subroutine", {
            let mut c = base();
            c.subroutine = Subroutine::Avg;
            c
        }),
        ("fedgate benchmark", {
            let mut c = base();
            c.solver = SolverKind::FedGate;
            c
        }),
    ];
    for (label, cfg) in variants {
        let engine = setup::build_engine(
            &opts.engine, &cfg.model, &setup::default_artifacts_dir())?;
        let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
        let stem =
            format!("ablate_{}", label.replace([' ', ',', '(', ')'], "_"));
        let trace =
            run_observed(opts, engine.as_ref(), &mut fleet, &cfg, &stem)?;
        let last = trace.last().context("empty trace")?;
        log_info!(
            "  {label:<24} stages={:<2} rounds={:<5} time={:<12.1} dist={:<9.4} finished={}",
            trace.stage_transitions.len().max(1),
            last.round,
            trace.total_time,
            last.dist_to_opt,
            trace.finished,
        );
        let path = opts.out.join(format!(
            "ablate_{}.csv",
            label.replace([' ', ',', '(', ')'], "_")
        ));
        trace.write_csv(&path)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Help snapshot — pins the per-subcommand flag enumeration in USAGE
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::USAGE;
    use std::path::PathBuf;

    /// Byte-compare (or bless) the USAGE text against the committed
    /// snapshot, so the per-subcommand `flags:` enumeration cannot
    /// silently drift from the options a subcommand actually honors.
    /// Same blessing protocol as `tests/golden.rs`: a missing fixture
    /// self-blesses, `FLANP_BLESS=1` regenerates after an intended
    /// help-text change.
    #[test]
    fn usage_snapshot() {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("tests/fixtures/help/flanp_bench_usage.txt");
        let bless = std::env::var("FLANP_BLESS").is_ok_and(|v| v == "1");
        if bless || !path.exists() {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, USAGE).unwrap();
            if !bless {
                eprintln!(
                    "help snapshot: blessed missing fixture {} — commit it",
                    path.display()
                );
            }
            return;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        if USAGE != want {
            let (mut line, mut a, mut b) = (0usize, "", "");
            for (i, (g, w)) in USAGE.lines().zip(want.lines()).enumerate() {
                if g != w {
                    (line, a, b) = (i + 1, g, w);
                    break;
                }
            }
            if line == 0 {
                line = USAGE.lines().count().min(want.lines().count()) + 1;
                (a, b) = ("<end>", "<end>");
            }
            panic!(
                "flanp-bench USAGE drifted from its snapshot at line \
                 {line}:\n  got:  {a}\n  want: {b}\nIf the help-text \
                 change is intended (e.g. a subcommand gained a flag), \
                 regenerate with FLANP_BLESS=1 and commit the fixture \
                 diff — and keep the per-subcommand flags: rows in sync \
                 with what each experiment parses."
            );
        }
    }
}
