//! Benchmark solvers (Section 5): FedGATE, FedAvg, FedNova, FedProx, the
//! partial-participation FedGATE variants, the FedBuff buffered-async
//! solver and the TiFL tier-scheduled solver — plus the shared run loop
//! used by FLANP (`flanp.rs`) and the deadline-bounded round step shared
//! by the semi-synchronous solvers.

use super::config::{ExperimentConfig, SolverKind};
use super::eval::{ClientEval, EvalData};
use super::gate::{
    active_loss_gradsq, fedgate_round, local_round, local_rounds, GateState,
    LocalSpec, RoundBuffers, TauSpec,
};
use crate::engine::{Engine, ModelKind};
use crate::fed::{
    overselect_target, ClientFleet, DeadlineController, DeadlinePolicy,
    EventKind, Observe, RoundConditions, RoundEvent, RoundRecord, Trace,
    VirtualClock, OVERSELECT_OFF,
};
use crate::fed::observe::num as json_num;
use crate::util::json::obj;
use crate::util::{linalg, Rng};
use anyhow::Result;

/// He-initialized flat parameter vector (weights ~ N(0, 2/fan_in),
/// biases 0) — mirrors `model.init_params` in Layer 2. Deterministic in
/// the config seed. Zero-init would dead-lock MLP hidden layers.
pub fn init_params(engine: &dyn Engine, seed: u64) -> Vec<f32> {
    let meta = engine.meta();
    let mut rng = Rng::with_stream(seed, 0x1217);
    let mut out = Vec::with_capacity(meta.param_count);
    for (fin, fout) in meta.layer_dims() {
        let scale = (2.0 / fin as f64).sqrt() as f32;
        for _ in 0..fin * fout {
            out.push(rng.normal_f32() * scale);
        }
        out.extend(std::iter::repeat(0.0).take(fout));
    }
    // linear models start at exactly zero (matches the paper's convex
    // experiments and makes runs comparable across solvers)
    if meta.kind != ModelKind::Mlp {
        out.fill(0.0);
    }
    out
}

/// Shared run-loop context: clock + trace + budget/termination logic.
pub struct RunContext<'a> {
    pub engine: &'a dyn Engine,
    pub cfg: &'a ExperimentConfig,
    pub eval: &'a EvalData,
    /// per-client held-out evaluator (None — the zero-cost default —
    /// unless the fleet reserved a holdout: non-IID `data:` runs and
    /// the ditto solver on classification models). Feeds the trace's
    /// `acc` column and `client_acc` aggregates.
    pub client_eval: Option<ClientEval>,
    pub clock: VirtualClock,
    pub trace: Trace,
}

impl<'a> RunContext<'a> {
    pub fn new(
        engine: &'a dyn Engine,
        cfg: &'a ExperimentConfig,
        eval: &'a EvalData,
    ) -> Self {
        RunContext {
            engine,
            cfg,
            eval,
            client_eval: None,
            clock: VirtualClock::with_comm_overhead(cfg.comm_overhead),
            trace: Trace::new(&cfg.solver.name()),
        }
    }

    /// Completed communication rounds so far. The trace holds one extra
    /// row for the initial (round-0, pre-training) evaluation.
    pub fn completed_rounds(&self) -> usize {
        self.trace.rounds.len().saturating_sub(1)
    }

    /// Evaluate + append one trace row. `loss_active`/`grad_sq` are the
    /// active-set objective stats already computed by the solver (NaN if
    /// unavailable this round); `dropped` / `missed` are the round's
    /// dropout and deadline-miss counts from the clock's
    /// [`crate::fed::RoundEvent`]; `reranks` counts the ranking
    /// refreshes (estimate re-ranks / tier-cache re-tiers) charged to
    /// this round (0 for the fixed-cohort solvers); `available` is the
    /// fleet-wide observably-online count from the round's realized
    /// conditions (`RoundConditions::online_count`; the fleet size for
    /// the initial pre-training row); `cancelled` is the round's
    /// actively-cancelled in-flight count (over-selection,
    /// `fed::selection`; 0 unless `overselect > 1`).
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        w: &[f32],
        participants: usize,
        stage: usize,
        loss_active: f64,
        grad_sq: f64,
        dropped: usize,
        missed: usize,
        reranks: usize,
        available: usize,
        cancelled: usize,
    ) -> Result<()> {
        self.record_impl(
            w, None, participants, stage, loss_active, grad_sq, dropped,
            missed, reranks, available, cancelled,
        )
    }

    /// [`RunContext::record`] for personalized solvers: the `acc`
    /// column scores each client's held-out chunk with its OWN head
    /// (`models[c]`) instead of the global model `w` (every other
    /// column still describes `w`).
    #[allow(clippy::too_many_arguments)]
    pub fn record_personal(
        &mut self,
        w: &[f32],
        models: &[Vec<f32>],
        participants: usize,
        stage: usize,
        loss_active: f64,
        grad_sq: f64,
        dropped: usize,
        missed: usize,
        reranks: usize,
        available: usize,
        cancelled: usize,
    ) -> Result<()> {
        self.record_impl(
            w,
            Some(models),
            participants,
            stage,
            loss_active,
            grad_sq,
            dropped,
            missed,
            reranks,
            available,
            cancelled,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn record_impl(
        &mut self,
        w: &[f32],
        models: Option<&[Vec<f32>]>,
        participants: usize,
        stage: usize,
        loss_active: f64,
        grad_sq: f64,
        dropped: usize,
        missed: usize,
        reranks: usize,
        available: usize,
        cancelled: usize,
    ) -> Result<()> {
        let round = self.trace.rounds.len();
        let evaluate = round % self.cfg.eval_every.max(1) == 0;
        let (loss_full, accuracy) = if evaluate {
            (
                self.eval.full_loss(self.engine, w)?,
                self.eval.full_accuracy(self.engine, w)?,
            )
        } else {
            let prev = self.trace.last();
            (
                prev.map(|r| r.loss_full).unwrap_or(f64::NAN),
                prev.map(|r| r.accuracy).unwrap_or(f64::NAN),
            )
        };
        // per-client held-out accuracy rides the same eval cadence as
        // the full objective (it is N extra engine batches); between
        // eval rounds the previous value carries, like loss_full
        let acc = if !evaluate {
            self.trace.last().map(|r| r.acc).unwrap_or(f64::NAN)
        } else if let Some(ce) = &self.client_eval {
            let per = match models {
                Some(m) => ce.accuracies_personal(self.engine, m)?,
                None => ce.accuracies_global(self.engine, w)?,
            };
            let mean = per.iter().sum::<f64>() / per.len() as f64;
            self.trace.client_acc = per;
            mean
        } else {
            f64::NAN
        };
        self.trace.push(RoundRecord {
            round,
            time: self.clock.now(),
            participants,
            loss_active,
            loss_full,
            grad_norm_sq: grad_sq,
            dist_to_opt: self.eval.dist_to_opt(w),
            accuracy,
            stage,
            dropped,
            missed,
            reranks,
            available,
            cancelled,
            acc,
        });
        Ok(())
    }

    /// Number of trace rows so far (used as the next round's index).
    pub fn rounds_done(&self) -> usize {
        self.trace.rounds.len()
    }

    /// True when any run budget or target has been hit.
    pub fn should_stop(&self) -> bool {
        if self.completed_rounds() >= self.cfg.max_rounds {
            return true;
        }
        if self.cfg.max_time > 0.0 && self.clock.now() >= self.cfg.max_time {
            return true;
        }
        if let Some(last) = self.trace.last() {
            if self.cfg.target_loss > 0.0 && last.loss_full <= self.cfg.target_loss {
                return true;
            }
            if self.cfg.target_dist > 0.0
                && last.dist_to_opt.is_finite()
                && last.dist_to_opt <= self.cfg.target_dist
            {
                return true;
            }
        }
        false
    }
}

/// One deadline-bounded synchronous round step, shared by every
/// synchronous cohort solver (FLANP, benchmark FedGATE, FedAvg/FedProx
/// via [`run_solver`], TiFL): compute the cohort's deadline from the
/// *estimated* speeds, split the realized arrivals from the deadline
/// misses, charge the clock (`min(deadline, slowest ONLINE cohort
/// member)` — a partial round charges only the deadline), and feed
/// exact / censored observations back into the speed estimator. Returns
/// the clients whose update actually arrived (the only ones the caller
/// may aggregate) and the charged [`RoundEvent`].
///
/// Availability is handled here, once, for everyone: offline clients
/// (`!cond.online[i]` — the `avail:`/`trace:` scenarios of
/// `fed::traces`) are observable at selection time and are SKIPPED —
/// they never hold the round open, are never charged to the clock, and
/// are never fed to the speed estimator (neither exact nor censored
/// observations: a client that never ran teaches nothing). When the
/// whole cohort is offline the server waits instead of training:
/// deterministic (diurnal) outages advance the clock straight to the
/// cohort's next window; stochastic ones charge one estimate-priced
/// waiting round (`updates * max est` over the cohort) and retry, so an
/// all-down round always costs wall-clock time.
///
/// Under [`crate::fed::DeadlinePolicy::Sync`] with every client online
/// the deadline is `+inf`: every available client arrives, no censored
/// observations are made and the charged cost is bit-identical to the
/// synchronous path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn deadline_round(
    ctx: &mut RunContext,
    fleet: &mut ClientFleet,
    ddl: &mut DeadlineController,
    active: &[usize],
    cond: &RoundConditions,
    participants: &[usize],
    updates: usize,
    obs: &mut Observe,
) -> (Vec<usize>, RoundEvent) {
    deadline_round_impl(
        ctx,
        fleet,
        ddl,
        active,
        cond,
        participants,
        updates,
        None,
        None,
        obs,
    )
}

/// Over-selecting variant of [`deadline_round`] (`fed::selection`): the
/// caller selected MORE clients than it statistically needs (`active`
/// holds `ceil(F * target)` ids) and the round closes at the `target`-th
/// ARRIVAL — the server actively cancels every other in-flight client at
/// that moment instead of waiting for (or billing) the deadline. The
/// clock charges `min(deadline, target-th arrival total)` via
/// [`VirtualClock::charge_round_cancel`]; cancelled clients are fed
/// censored observations floored at the cancellation cutoff (all the
/// server learned is that they were still running when it hung up).
/// With `target >= active.len()` no arrival is surplus and the only
/// remaining difference from [`deadline_round`] is that deadline misses
/// are booked as cancellations (the server hangs up on them at the
/// deadline rather than letting them expire).
#[allow(clippy::too_many_arguments)]
pub(crate) fn deadline_round_overselect(
    ctx: &mut RunContext,
    fleet: &mut ClientFleet,
    ddl: &mut DeadlineController,
    active: &[usize],
    cond: &RoundConditions,
    participants: &[usize],
    updates: usize,
    target: usize,
    obs: &mut Observe,
) -> (Vec<usize>, RoundEvent) {
    deadline_round_impl(
        ctx,
        fleet,
        ddl,
        active,
        cond,
        participants,
        updates,
        None,
        Some(target),
        obs,
    )
}

/// Heterogeneous-step variant of [`deadline_round`] (FedNova): client
/// `i` performs `taus[i]` local updates. The deadline budget is priced
/// over each client's projected TOTAL `taus[i] * est_i` (reducing to
/// the homogeneous formula when taus are uniform), and
/// censored-observation floors use each late client's OWN `taus[i]`
/// (the only bound its miss implies).
#[allow(clippy::too_many_arguments)]
pub(crate) fn deadline_round_hetero(
    ctx: &mut RunContext,
    fleet: &mut ClientFleet,
    ddl: &mut DeadlineController,
    active: &[usize],
    cond: &RoundConditions,
    participants: &[usize],
    updates: usize,
    taus: &[usize],
    obs: &mut Observe,
) -> (Vec<usize>, RoundEvent) {
    deadline_round_impl(
        ctx,
        fleet,
        ddl,
        active,
        cond,
        participants,
        updates,
        Some(taus),
        None,
        obs,
    )
}

#[allow(clippy::too_many_arguments)]
fn deadline_round_impl(
    ctx: &mut RunContext,
    fleet: &mut ClientFleet,
    ddl: &mut DeadlineController,
    active: &[usize],
    cond: &RoundConditions,
    participants: &[usize],
    updates: usize,
    taus: Option<&[usize]>,
    target: Option<usize>,
    obs: &mut Observe,
) -> (Vec<usize>, RoundEvent) {
    // over-selection only combines with homogeneous local steps (the
    // overselecting solvers — FLANP, TiFL — are uniform-tau)
    debug_assert!(taus.is_none() || target.is_none());
    // the clock may only charge the observably-online cohort members
    let present = cond.online_of(active);
    if present.is_empty() {
        let now = ctx.clock.now();
        // deterministic (diurnal) outages advance the clock straight to
        // the cohort's next window; stochastic outages (iid/cluster,
        // replayed traces) have no computable wake time, so the server
        // waits one estimate-priced round — the time a full round over
        // the cohort's slowest estimated member would have cost — and
        // retries. A waiting round is CHARGED, never free: real time
        // passes while the fleet is dark (ROADMAP time-basis note).
        let wake = fleet
            .system
            .model()
            .avail
            .as_ref()
            .and_then(|a| a.next_online_time(now, active, fleet.num_clients()))
            .unwrap_or_else(|| {
                let est_max = active
                    .iter()
                    .map(|&i| fleet.estimates.estimate(i))
                    .fold(0.0, f64::max);
                now + updates as f64 * est_max
            });
        if obs.enabled() {
            obs.emit(
                EventKind::Wait,
                None,
                obj(vec![("now", now.into()), ("wake", wake.into())]),
            );
        }
        let ev = ctx.clock.charge_wait(wake);
        return (Vec::new(), ev);
    }
    // deadline budget per client: its estimated PER-UPDATE time, scaled
    // on the heterogeneous path by its own local-update count so the
    // controller's `updates * quantile` arithmetic prices each client's
    // projected TOTAL `taus[i] * est_i`. Without the scaling a quantile
    // deadline under FedNova — where every uncapped client finishes
    // near the common window `tau * max_t` — would reject nearly the
    // whole cohort every round.
    let est: Vec<f64> = match taus {
        None => {
            present.iter().map(|&i| fleet.estimates.estimate(i)).collect()
        }
        Some(t) => present
            .iter()
            .map(|&i| {
                fleet.estimates.estimate(i) * t[i] as f64 / updates as f64
            })
            .collect(),
    };
    let deadline = ddl.round_deadline(&est, updates);
    let total = |i: usize| match taus {
        Some(t) => t[i] as f64 * cond.times[i],
        None => updates as f64 * cond.times[i],
    };
    let (arrived, late): (Vec<usize>, Vec<usize>) =
        participants.iter().copied().partition(|&i| total(i) <= deadline);
    let times: Vec<f64> = present.iter().map(|&i| cond.times[i]).collect();
    let dropped = present.len() - participants.len();
    // observability: one `deadline` event prices the round, one
    // `offline` event for every cohort member that could never arrive
    // (observably offline OR a silent dropout). Together with the
    // per-client arrived/missed/cancelled events emitted below they
    // satisfy `arrived + missed + cancelled + offline == cohort`, the
    // accounting invariant `ci/check_events.py` enforces per round.
    if obs.enabled() {
        obs.emit(
            EventKind::Deadline,
            None,
            obj(vec![
                ("deadline", json_num(deadline)),
                ("updates", updates.into()),
                ("cohort", active.len().into()),
                ("present", present.len().into()),
            ]),
        );
        for &i in active {
            if !participants.contains(&i) {
                obs.emit(
                    EventKind::Offline,
                    Some(i),
                    obj(vec![
                        ("online", cond.online[i].into()),
                        ("available", cond.available[i].into()),
                    ]),
                );
            }
        }
    }
    // over-selection (`fed::selection`): close the round at the
    // `target`-th arrival. Every other in-flight client — surplus
    // arrival-to-be and would-be deadline miss alike — is CANCELLED at
    // the cutoff and booked in the `cancelled` column, never as a
    // deadline `miss` (cancellation is a selection-policy cost the
    // over-selector chose to pay, not a deadline outcome).
    if let Some(t_kept) = target {
        // rank arrivals by completion time (ties broken by id so the
        // kept set is deterministic) and keep the first `target`
        let mut by_arrival = arrived.clone();
        by_arrival.sort_by(|&a, &b| {
            total(a).partial_cmp(&total(b)).unwrap().then(a.cmp(&b))
        });
        by_arrival.truncate(t_kept);
        // cutoff: the server hangs up at the target-th arrival when
        // enough clients make it; otherwise it waits out the full
        // deadline hoping for more and cancels whatever still runs there
        let cutoff = if arrived.len() >= t_kept && t_kept > 0 {
            total(by_arrival[t_kept - 1])
        } else {
            deadline
        };
        // kept ids back in selection order: aggregation order (batch
        // sampling, float accumulation) must not depend on realized
        // timings
        let kept: Vec<usize> =
            arrived.iter().copied().filter(|i| by_arrival.contains(i)).collect();
        let cancelled = participants.len() - kept.len();
        let ev = ctx.clock.charge_round_cancel(
            &present, &times, updates, cutoff, dropped, cancelled,
        );
        // estimator errors are read BEFORE observe_round folds this
        // round's realizations back into the estimates
        if obs.enabled() {
            for &i in &kept {
                let t = cond.times[i];
                obs.observe_estimate_error(
                    (fleet.estimates.estimate(i) - t).abs() / t,
                );
                obs.emit(
                    EventKind::Arrived,
                    Some(i),
                    obj(vec![
                        ("total", json_num(total(i))),
                        ("time", json_num(t)),
                    ]),
                );
            }
        }
        fleet.observe_round(&kept, cond);
        // a cancelled client's only information is that it was still
        // running when the server hung up: times[i] > cutoff / updates
        for &i in participants {
            if !by_arrival.contains(&i) {
                if obs.enabled() {
                    obs.emit(
                        EventKind::Cancelled,
                        Some(i),
                        obj(vec![
                            ("total", json_num(total(i))),
                            ("cutoff", json_num(cutoff)),
                        ]),
                    );
                    obs.emit(
                        EventKind::Censored,
                        Some(i),
                        obj(vec![(
                            "floor",
                            json_num(cutoff / updates as f64),
                        )]),
                    );
                }
                fleet.observe_censored(&[i], cutoff / updates as f64);
            }
        }
        ddl.observe_round(kept.len(), participants.len());
        return (kept, ev);
    }
    let ev = match taus {
        None => ctx.clock.charge_round_deadline(
            &present,
            &times,
            updates,
            deadline,
            dropped,
            late.len(),
        ),
        Some(t) => {
            let tp: Vec<usize> = present.iter().map(|&i| t[i]).collect();
            ctx.clock.charge_round_hetero_deadline(
                &present,
                &times,
                &tp,
                deadline,
                dropped,
                late.len(),
            )
        }
    };
    if obs.enabled() {
        for &i in &arrived {
            let t = cond.times[i];
            obs.observe_estimate_error(
                (fleet.estimates.estimate(i) - t).abs() / t,
            );
            obs.emit(
                EventKind::Arrived,
                Some(i),
                obj(vec![
                    ("total", json_num(total(i))),
                    ("time", json_num(t)),
                ]),
            );
        }
    }
    fleet.observe_round(&arrived, cond);
    // a late client's only information is `times[i] > deadline / (ITS
    // OWN local-update count)`: under heterogeneous taus the nominal
    // floor would overstate a 2*tau client's bound by 2x and inflate
    // fast clients' estimates
    for &i in &late {
        let u = match taus {
            Some(t) => t[i],
            None => updates,
        };
        if obs.enabled() {
            obs.emit(
                EventKind::Missed,
                Some(i),
                obj(vec![
                    ("total", json_num(total(i))),
                    ("deadline", json_num(deadline)),
                ]),
            );
            obs.emit(
                EventKind::Censored,
                Some(i),
                obj(vec![("floor", json_num(deadline / u as f64))]),
            );
        }
        fleet.observe_censored(&[i], deadline / u as f64);
    }
    // the adaptive policy tunes on the deadline-CONTROLLABLE outcome:
    // arrivals out of the available participants. Dropped (and offline)
    // clients can never arrive by any deadline, so counting them would
    // pin the scale at its ceiling under heavy dropout (degenerating to
    // sync).
    ddl.observe_round(arrived.len(), participants.len());
    (arrived, ev)
}

/// Round stats with the empty-arrival fast path, shared by the
/// fixed-eval-set solver loops: an empty (wait / all-dropped / deadline-
/// starved) round leaves the model unchanged, so the cached
/// `(loss, grad^2)` pair is exact and the objective — the dominant host
/// cost under low availability — is not recomputed. FLANP keeps its own
/// variant because its eval set (the active prefix) can change between
/// rounds.
fn round_stats(
    arrived_empty: bool,
    cached: (f64, f64),
    fresh: impl FnOnce() -> Result<(f64, f64)>,
) -> Result<(f64, f64)> {
    if arrived_empty {
        Ok(cached)
    } else {
        fresh()
    }
}

/// Hysteresis-gated re-tier with observability: snapshot the tier
/// assignments and frozen bands, refresh, and — iff a re-tier fired —
/// emit one `rerank` event plus one promote/demote event per moved
/// client carrying the band of its FORMER tier (the band it breached to
/// trigger the move). With `obs` disabled this is exactly
/// [`ClientFleet::refresh_tiers`]: no snapshot, no diff.
pub(crate) fn refresh_tiers_observed(
    fleet: &mut ClientFleet,
    obs: &mut Observe,
) -> bool {
    if !obs.enabled() {
        return fleet.refresh_tiers();
    }
    let before = fleet.tier_assignments();
    let bands = fleet.tier_bands();
    let retiered = fleet.refresh_tiers();
    if retiered {
        obs.emit(EventKind::Rerank, None, obj(vec![("count", 1usize.into())]));
        let after = fleet.tier_assignments();
        for (i, (&b, &a)) in before.iter().zip(after.iter()).enumerate() {
            if a == b {
                continue;
            }
            // tier 0 is the fastest: moving DOWN the index is a promotion
            let kind = if a < b {
                EventKind::TierPromote
            } else {
                EventKind::TierDemote
            };
            let (lo, hi) =
                bands.get(b).copied().unwrap_or((f64::NAN, f64::NAN));
            obs.emit(
                kind,
                Some(i),
                obj(vec![
                    ("from", b.into()),
                    ("to", a.into()),
                    ("band", [lo, hi].into_iter().map(json_num).collect()),
                ]),
            );
        }
    }
    retiered
}

/// Emit the cohort-selection events (`fed::selection`) for one round:
/// the ranked/scheduled `base`, the over-selection padding past it
/// (when `active` outgrew `base`), and the forecaster's reordering of
/// the final pick (when a forecaster is learned). Call only under
/// `obs.enabled()`.
pub(crate) fn emit_cohort_events(
    obs: &mut Observe,
    fleet: &ClientFleet,
    base: &[usize],
    active: &[usize],
    overselect: f64,
) {
    obs.emit(
        EventKind::CohortSelected,
        None,
        obj(vec![
            ("n", base.len().into()),
            ("ids", base.iter().copied().collect()),
        ]),
    );
    if active.len() > base.len() {
        obs.emit(
            EventKind::CohortPadded,
            None,
            obj(vec![
                ("base", base.len().into()),
                ("padded", active.len().into()),
                ("factor", overselect.into()),
            ]),
        );
    }
    if fleet.forecast.is_some() {
        obs.emit(
            EventKind::CohortReordered,
            None,
            obj(vec![("ids", active.iter().copied().collect())]),
        );
    }
}

/// Entry point: dispatch a config to its solver with observability
/// fully off. Kept as THE plain API — every existing caller and test
/// goes through here, and [`Observe::off`] guarantees the run is
/// bit-identical to the pre-observability code path.
pub fn run_solver(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
) -> Result<Trace> {
    run_solver_with(engine, fleet, cfg, &mut Observe::off())
}

/// [`run_solver`] with an observability bundle (`fed::observe`): the
/// event sink and metrics registry in `obs` receive one typed event per
/// round-loop decision. With `obs` disabled every emission site
/// short-circuits on a single branch. FLANP variants live in `flanp.rs`
/// but are reachable from here too.
pub fn run_solver_with(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    obs: &mut Observe,
) -> Result<Trace> {
    cfg.validate(engine.meta().batch).map_err(|e| anyhow::anyhow!(e))?;
    match cfg.solver {
        SolverKind::Flanp | SolverKind::FlanpHeuristic => {
            super::flanp::run_flanp_with(engine, fleet, cfg, obs)
        }
        SolverKind::FedGate => run_fedgate_full(engine, fleet, cfg, obs),
        SolverKind::FedAvg => {
            run_model_average(engine, fleet, cfg, Local::Sgd, obs)
        }
        SolverKind::FedProx => {
            run_model_average(engine, fleet, cfg, Local::Prox, obs)
        }
        SolverKind::FedNova => run_fednova(engine, fleet, cfg, obs),
        SolverKind::FedGatePartialRandom { k } => {
            run_fedgate_partial(engine, fleet, cfg, k, false, obs)
        }
        SolverKind::FedGatePartialFastest { k } => {
            run_fedgate_partial(engine, fleet, cfg, k, true, obs)
        }
        SolverKind::FedBuff { k } => run_fedbuff(engine, fleet, cfg, k, obs),
        SolverKind::Tifl => run_tifl(engine, fleet, cfg, obs),
        SolverKind::Ditto { lambda } => {
            run_ditto(engine, fleet, cfg, lambda, obs)
        }
    }
}

/// Non-adaptive FedGATE with all N clients (Proposition 3's benchmark).
/// Honors the configured aggregation deadline policy: with a finite
/// deadline only arrived clients are aggregated and the round charges
/// `min(deadline, slowest)`.
fn run_fedgate_full(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    obs: &mut Observe,
) -> Result<Trace> {
    let eval = EvalData::build(engine, fleet, cfg.eval_rows, cfg.seed)?;
    let mut ctx = RunContext::new(engine, cfg, &eval);
    ctx.client_eval = ClientEval::maybe_build(engine, fleet)?;
    let mut ddl = DeadlineController::new(cfg.deadline.clone());
    let n = fleet.num_clients();
    let active: Vec<usize> = (0..n).collect();
    let mut state = GateState::new(init_params(engine, cfg.seed), n);
    let mut bufs = RoundBuffers::new(engine, cfg.tau);
    let threshold = cfg.grad_threshold(n);

    let (l0, g0) = active_loss_gradsq(engine, fleet, &active, &state.w)?;
    ctx.record(&state.w, n, 0, l0, g0, 0, 0, 0, n, 0)?;
    // cached stats for the fixed eval set: an empty (wait/all-dropped)
    // round leaves w unchanged, so the objective need not be recomputed
    let mut stats = (l0, g0);
    loop {
        obs.set_round(ctx.rounds_done());
        let (cond, participants) =
            fleet.realize_round(&active, ctx.clock.now());
        let (arrived, ev) = deadline_round(
            &mut ctx, fleet, &mut ddl, &active, &cond, &participants, cfg.tau,
            obs,
        );
        if !arrived.is_empty() {
            fedgate_round(
                engine, fleet, &mut state, &arrived, cfg.tau, cfg.eta,
                cfg.gamma, &mut bufs,
            )?;
        }
        let (loss, gsq) = round_stats(arrived.is_empty(), stats, || {
            active_loss_gradsq(engine, fleet, &active, &state.w)
        })?;
        stats = (loss, gsq);
        ctx.record(
            &state.w,
            n,
            0,
            loss,
            gsq,
            ev.dropped,
            ev.missed,
            0,
            cond.online_count(),
            ev.cancelled,
        )?;
        if gsq <= threshold {
            ctx.trace.finished = true;
            break;
        }
        if ctx.should_stop() {
            break;
        }
    }
    Ok(ctx.trace)
}

enum Local {
    Sgd,
    Prox,
}

/// FedAvg / FedProx: tau local steps then model averaging. Routed
/// through the shared [`deadline_round`] step (ROADMAP follow-on from
/// PR 3), so both honor the configured aggregation deadline policy and
/// skip offline clients; at `deadline = +inf` with every client online
/// the rounds are bit-identical to the purely synchronous path (see
/// `rust/tests/deadline.rs`).
fn run_model_average(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    local: Local,
    obs: &mut Observe,
) -> Result<Trace> {
    let eval = EvalData::build(engine, fleet, cfg.eval_rows, cfg.seed)?;
    let mut ctx = RunContext::new(engine, cfg, &eval);
    ctx.client_eval = ClientEval::maybe_build(engine, fleet)?;
    let mut ddl = DeadlineController::new(cfg.deadline.clone());
    let n = fleet.num_clients();
    let active: Vec<usize> = (0..n).collect();
    let p = engine.meta().param_count;
    let mut w = init_params(engine, cfg.seed);
    let zero_delta = vec![0.0f32; p];
    let mut bufs = RoundBuffers::new(engine, cfg.tau);
    let threshold = cfg.grad_threshold(n);

    let (l0, g0) = active_loss_gradsq(engine, fleet, &active, &w)?;
    ctx.record(&w, n, 0, l0, g0, 0, 0, 0, n, 0)?;
    // cached stats for the fixed eval set: an empty (wait/all-dropped)
    // round leaves w unchanged, so the objective need not be recomputed
    let mut stats = (l0, g0);
    loop {
        obs.set_round(ctx.rounds_done());
        let (cond, participants) =
            fleet.realize_round(&active, ctx.clock.now());
        let (arrived, ev) = deadline_round(
            &mut ctx, fleet, &mut ddl, &active, &cond, &participants, cfg.tau,
            obs,
        );
        // shared fan-out (gate::local_rounds): parallel local compute
        // with serially pre-sampled batches — results identical to the
        // old per-client loop (same RNG streams, same stepping)
        let spec = match local {
            Local::Sgd => LocalSpec::Sgd(&zero_delta),
            Local::Prox => LocalSpec::Prox { mu: cfg.prox_mu },
        };
        let wis = local_rounds(
            engine,
            fleet,
            &arrived,
            &w,
            spec,
            TauSpec::Uniform(cfg.tau),
            cfg.eta,
            &mut bufs,
        )?;
        if !arrived.is_empty() {
            let mut acc = vec![0.0f64; p];
            for wi in &wis {
                linalg::accumulate(&mut acc, wi);
            }
            w = linalg::mean_of(&acc, arrived.len());
        }
        let (loss, gsq) = round_stats(arrived.is_empty(), stats, || {
            active_loss_gradsq(engine, fleet, &active, &w)
        })?;
        stats = (loss, gsq);
        ctx.record(
            &w,
            n,
            0,
            loss,
            gsq,
            ev.dropped,
            ev.missed,
            0,
            cond.online_count(),
            ev.cancelled,
        )?;
        if gsq <= threshold {
            ctx.trace.finished = true;
            break;
        }
        if ctx.should_stop() {
            break;
        }
    }
    Ok(ctx.trace)
}

/// Ditto (Li et al., 2021): personalized federated learning as global
/// plus per-client proximal objectives. The GLOBAL model follows the
/// plain FedAvg path — same shared [`deadline_round`] step, same
/// aggregation — while every arrived client additionally trains its own
/// personal head `v_i` with proximal SGD anchored at the freshly
/// aggregated `w`:
///
///   v_i <- v_i - eta * (grad f_i(v_i) + lambda * (v_i - w))
///
/// The head steps ride the tau budget the round already charged (the
/// paper's on-device framing: personalization is concurrent local work,
/// not extra wall-clock), so ditto's round clock matches fedavg's and
/// wall-clock comparisons across solvers are apples-to-apples. Heads
/// persist across rounds and start at the initial `w`; clients that
/// never arrive keep their stale heads — exactly the availability
/// pathology the `noniid` bench sweep measures. Trace rows score the
/// personal heads through [`RunContext::record_personal`], so the `acc`
/// column is personalized accuracy whenever client eval is on.
fn run_ditto(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    lambda: f64,
    obs: &mut Observe,
) -> Result<Trace> {
    let eval = EvalData::build(engine, fleet, cfg.eval_rows, cfg.seed)?;
    let mut ctx = RunContext::new(engine, cfg, &eval);
    ctx.client_eval = ClientEval::maybe_build(engine, fleet)?;
    let mut ddl = DeadlineController::new(cfg.deadline.clone());
    let n = fleet.num_clients();
    let active: Vec<usize> = (0..n).collect();
    let p = engine.meta().param_count;
    let mut w = init_params(engine, cfg.seed);
    let zero_delta = vec![0.0f32; p];
    let mut bufs = RoundBuffers::new(engine, cfg.tau);
    let threshold = cfg.grad_threshold(n);

    // personal heads, one per client, initialized at the global init;
    // head batches come from dedicated streams so the global trajectory
    // stays bit-identical to plain fedavg (see `ditto_local`)
    let mut heads: Vec<Vec<f32>> = vec![w.clone(); n];
    let mut head_rngs: Vec<Rng> = (0..n)
        .map(|i| Rng::with_stream(cfg.seed ^ 0xd177_0b57, i as u64))
        .collect();

    let (l0, g0) = active_loss_gradsq(engine, fleet, &active, &w)?;
    ctx.record_personal(&w, &heads, n, 0, l0, g0, 0, 0, 0, n, 0)?;
    let mut stats = (l0, g0);
    loop {
        obs.set_round(ctx.rounds_done());
        let (cond, participants) =
            fleet.realize_round(&active, ctx.clock.now());
        let (arrived, ev) = deadline_round(
            &mut ctx, fleet, &mut ddl, &active, &cond, &participants, cfg.tau,
            obs,
        );
        let wis = local_rounds(
            engine,
            fleet,
            &arrived,
            &w,
            LocalSpec::Sgd(&zero_delta),
            TauSpec::Uniform(cfg.tau),
            cfg.eta,
            &mut bufs,
        )?;
        if !arrived.is_empty() {
            let mut acc = vec![0.0f64; p];
            for wi in &wis {
                linalg::accumulate(&mut acc, wi);
            }
            w = linalg::mean_of(&acc, arrived.len());
        }
        // personal proximal steps, anchored at the fresh post-round w
        for &i in &arrived {
            ditto_local(
                engine, fleet, i, &mut heads[i], &w, lambda, cfg.tau,
                cfg.eta, &mut bufs, &mut head_rngs[i],
            )?;
        }
        let (loss, gsq) = round_stats(arrived.is_empty(), stats, || {
            active_loss_gradsq(engine, fleet, &active, &w)
        })?;
        stats = (loss, gsq);
        ctx.record_personal(
            &w,
            &heads,
            n,
            0,
            loss,
            gsq,
            ev.dropped,
            ev.missed,
            0,
            cond.online_count(),
            ev.cancelled,
        )?;
        if gsq <= threshold {
            ctx.trace.finished = true;
            break;
        }
        if ctx.should_stop() {
            break;
        }
    }
    Ok(ctx.trace)
}

/// tau proximal SGD steps on client `client`'s personal head:
/// `head -= eta * (grad(head; batch) + lambda * (head - anchor))`.
///
/// This is NOT [`LocalSpec::Prox`] — that spec anchors at the
/// round-START parameters it was handed (the FedProx contract), while
/// Ditto's head must be pulled toward the freshly AGGREGATED global
/// model. Charges no clock: the head steps ride the tau budget the
/// round already paid for (see [`run_ditto`]). Batches are drawn from
/// `rng`, a head-only stream, so the client's canonical minibatch
/// stream — and with it the global model's trajectory — is untouched.
#[allow(clippy::too_many_arguments)]
fn ditto_local(
    engine: &dyn Engine,
    fleet: &ClientFleet,
    client: usize,
    head: &mut [f32],
    anchor: &[f32],
    lambda: f64,
    tau: usize,
    eta: f32,
    bufs: &mut RoundBuffers,
    rng: &mut Rng,
) -> Result<()> {
    let b = engine.meta().batch;
    for _ in 0..tau {
        fleet.fill_minibatch_with(rng, client, b, &mut bufs.x, &mut bufs.y);
        let (_, mut g) = engine.loss_grad(head, &bufs.x, &bufs.y)?;
        for (k, gk) in g.iter_mut().enumerate() {
            *gk += lambda as f32 * (head[k] - anchor[k]);
        }
        linalg::axpy(-eta, &g, head);
    }
    Ok(())
}

/// FedNova (Wang et al., 2020): heterogeneous local-step counts tau_i
/// sized to a common time window, normalized aggregation. Routed through
/// the shared [`deadline_round_hetero`] step, so FedNova honors the
/// configured aggregation deadline policy and skips offline clients;
/// `deadline = +inf` with everyone online is bit-identical to the
/// synchronous path.
fn run_fednova(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    obs: &mut Observe,
) -> Result<Trace> {
    let eval = EvalData::build(engine, fleet, cfg.eval_rows, cfg.seed)?;
    let mut ctx = RunContext::new(engine, cfg, &eval);
    ctx.client_eval = ClientEval::maybe_build(engine, fleet)?;
    let mut ddl = DeadlineController::new(cfg.deadline.clone());
    let n = fleet.num_clients();
    let active: Vec<usize> = (0..n).collect();
    let p = engine.meta().param_count;

    let mut w = init_params(engine, cfg.seed);
    let zero_delta = vec![0.0f32; p];
    let mut bufs = RoundBuffers::new(engine, cfg.tau);
    let threshold = cfg.grad_threshold(n);

    let (l0, g0) = active_loss_gradsq(engine, fleet, &active, &w)?;
    ctx.record(&w, n, 0, l0, g0, 0, 0, 0, n, 0)?;
    // cached stats for the fixed eval set: an empty (wait/all-dropped)
    // round leaves w unchanged, so the objective need not be recomputed
    let mut stats = (l0, g0);
    loop {
        // Wang et al.'s deadline setup, re-derived each round from the
        // REALIZED speeds: the round window fits tau local steps of the
        // slowest ONLINE client (every online client trains for the same
        // wall-clock window; the server normalizes the heterogeneous
        // tau_i; offline clients neither size the window nor train).
        // tau_i is capped at 2*tau: with i.i.d. synthetic shards the
        // local drift that penalizes huge tau_i in real federations is
        // mild, so an uncapped window would overstate FedNova
        // (DESIGN.md §6). Under a static scenario every round derives
        // the seed's original constants.
        obs.set_round(ctx.rounds_done());
        let (cond, participants) =
            fleet.realize_round(&active, ctx.clock.now());
        let present = cond.online_of(&active);
        let max_t = present
            .iter()
            .map(|&i| cond.times[i])
            .fold(0.0f64, f64::max);
        let window = cfg.tau as f64 * max_t;
        let taus: Vec<usize> = cond
            .times
            .iter()
            .map(|t| ((window / t).floor() as usize).clamp(1, 2 * cfg.tau))
            .collect();
        let (arrived, ev) = deadline_round_hetero(
            &mut ctx, fleet, &mut ddl, &active, &cond, &participants,
            cfg.tau, &taus, obs,
        );

        if !arrived.is_empty() {
            let tau_eff = arrived.iter().map(|&i| taus[i]).sum::<usize>()
                as f64
                / arrived.len() as f64;
            // heterogeneous-tau local work through the shared fan-out,
            // then normalized updates: d_i = (w - w_i) / (eta * tau_i)
            let wis = local_rounds(
                engine,
                fleet,
                &arrived,
                &w,
                LocalSpec::Sgd(&zero_delta),
                TauSpec::PerClient(&taus),
                cfg.eta,
                &mut bufs,
            )?;
            let mut acc = vec![0.0f64; p];
            for (&i, wi) in arrived.iter().zip(&wis) {
                let inv = 1.0 / (cfg.eta * taus[i] as f32);
                let di: Vec<f32> =
                    w.iter().zip(wi).map(|(a, b)| (a - b) * inv).collect();
                linalg::accumulate(&mut acc, &di);
            }
            let d_avg = linalg::mean_of(&acc, arrived.len());
            // w <- w - eta * tau_eff * mean_i d_i
            linalg::axpy(-(cfg.eta * tau_eff as f32), &d_avg, &mut w);
        }
        let (loss, gsq) = round_stats(arrived.is_empty(), stats, || {
            active_loss_gradsq(engine, fleet, &active, &w)
        })?;
        stats = (loss, gsq);
        ctx.record(
            &w,
            n,
            0,
            loss,
            gsq,
            ev.dropped,
            ev.missed,
            0,
            cond.online_count(),
            ev.cancelled,
        )?;
        if gsq <= threshold {
            ctx.trace.finished = true;
            break;
        }
        if ctx.should_stop() {
            break;
        }
    }
    Ok(ctx.trace)
}

/// Partial-participation FedGATE (Figure 6): k of N clients per round,
/// chosen uniformly at random or as the k fastest.
fn run_fedgate_partial(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    k: usize,
    fastest: bool,
    obs: &mut Observe,
) -> Result<Trace> {
    let eval = EvalData::build(engine, fleet, cfg.eval_rows, cfg.seed)?;
    let mut ctx = RunContext::new(engine, cfg, &eval);
    ctx.client_eval = ClientEval::maybe_build(engine, fleet)?;
    let n = fleet.num_clients();
    let mut state = GateState::new(init_params(engine, cfg.seed), n);
    let mut bufs = RoundBuffers::new(engine, cfg.tau);
    let mut rng = Rng::with_stream(cfg.seed, 0x9a47);
    // stopping measured on the FULL objective's gradient (the comparison
    // target is the same final accuracy as full participation)
    let all: Vec<usize> = (0..n).collect();
    let threshold = cfg.grad_threshold(n);

    // the partial baselines keep oracle selection and synchronous
    // aggregation, but share the availability handling (skip, never
    // charge, offline clients) of the common round step
    let mut ddl = DeadlineController::new(DeadlinePolicy::Sync);
    let (l0, g0) = active_loss_gradsq(engine, fleet, &all, &state.w)?;
    ctx.record(&state.w, k, 0, l0, g0, 0, 0, 0, n, 0)?;
    // cached stats for the fixed (full-objective) eval set
    let mut stats = (l0, g0);
    loop {
        // chosen from the oracle ordering (the paper's baseline — only
        // FLANP gets the online estimator), then realized conditions
        // split arrivals from dropouts
        let active: Vec<usize> = if fastest {
            fleet.fastest(k).to_vec()
        } else {
            rng.sample_indices(n, k)
        };
        obs.set_round(ctx.rounds_done());
        if obs.enabled() {
            obs.emit(
                EventKind::CohortSelected,
                None,
                obj(vec![
                    ("n", active.len().into()),
                    ("ids", active.iter().copied().collect()),
                ]),
            );
        }
        let (cond, participants) =
            fleet.realize_round(&active, ctx.clock.now());
        let (arrived, ev) = deadline_round(
            &mut ctx, fleet, &mut ddl, &active, &cond, &participants, cfg.tau,
            obs,
        );
        if !arrived.is_empty() {
            fedgate_round(
                engine, fleet, &mut state, &arrived, cfg.tau, cfg.eta,
                cfg.gamma, &mut bufs,
            )?;
        }
        let (loss, gsq) = round_stats(arrived.is_empty(), stats, || {
            active_loss_gradsq(engine, fleet, &all, &state.w)
        })?;
        stats = (loss, gsq);
        ctx.record(
            &state.w,
            k,
            0,
            loss,
            gsq,
            ev.dropped,
            ev.missed,
            0,
            cond.online_count(),
            ev.cancelled,
        )?;
        if gsq <= threshold {
            ctx.trace.finished = true;
            break;
        }
        if ctx.should_stop() {
            break;
        }
    }
    Ok(ctx.trace)
}

/// TiFL (Chai et al. 2020): tier-scheduled FedGATE. The fleet is
/// clustered into latency tiers from the online speed estimates
/// ([`crate::fed::TierScheduler`]); every round ONE whole tier trains —
/// chosen by the scheduler's fairness credits, so fast tiers are
/// scheduled proportionally more often while slow tiers still contribute
/// their data at a guaranteed rate. Tier membership is cached and only
/// recomputed when a client's estimate breaches its hysteresis band
/// (each such re-tier is charged to the trace's `reranks` column).
/// Because every round's cohort is a single tier of similar speeds, the
/// straggler the server waits for is never much slower than the tier's
/// typical member — the TiFL premise.
///
/// Honors the configured aggregation deadline policy exactly like the
/// other synchronous cohort solvers, and deadline-censored observations
/// can demote a client out of its tier through the same hysteresis path.
///
/// Stopping matches the benchmarks: the run finishes when the
/// full-objective gradient meets the N-client statistical accuracy.
fn run_tifl(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    obs: &mut Observe,
) -> Result<Trace> {
    let policy = cfg
        .tiers
        .clone()
        .expect("config validation requires a tier policy for tifl");
    fleet.ensure_tiers(&policy);
    let eval = EvalData::build(engine, fleet, cfg.eval_rows, cfg.seed)?;
    let mut ctx = RunContext::new(engine, cfg, &eval);
    ctx.client_eval = ClientEval::maybe_build(engine, fleet)?;
    let mut ddl = DeadlineController::new(cfg.deadline.clone());
    let n = fleet.num_clients();
    let all: Vec<usize> = (0..n).collect();
    let mut state = GateState::new(init_params(engine, cfg.seed), n);
    let mut bufs = RoundBuffers::new(engine, cfg.tau);
    // stopping measured on the FULL objective's gradient (the comparison
    // target is the same final accuracy as full participation)
    let threshold = cfg.grad_threshold(n);

    let (l0, g0) = active_loss_gradsq(engine, fleet, &all, &state.w)?;
    ctx.record(&state.w, n, 0, l0, g0, 0, 0, 0, n, 0)?;
    // cached stats for the fixed (full-objective) eval set
    let mut stats = (l0, g0);
    loop {
        // hysteresis-gated re-tier, then credit-based tier selection:
        // one whole tier is this round's cohort. A fully-offline tier
        // becomes a wait/idle round in deadline_round (its online
        // members are the only ones trained or charged).
        obs.set_round(ctx.rounds_done());
        let reranks = refresh_tiers_observed(fleet, obs) as usize;
        let base = {
            let tiers =
                fleet.tiers.as_mut().expect("tifl scheduler enabled above");
            let tier = tiers.select_tier();
            tiers.tier_members(tier).to_vec()
        };
        // predictive selection (fed::selection): pad the scheduled tier
        // to ceil(F * m) with the fastest non-members and let the
        // forecaster swap predicted-offline picks; the round still
        // statistically needs only the tier's m arrivals. Off by
        // default — select_cohort is then the identity on the tier.
        let m = base.len();
        let overselecting = cfg.overselect > OVERSELECT_OFF;
        let active = fleet
            .select_cohort(&base, overselect_target(m, cfg.overselect, n));
        if obs.enabled() {
            emit_cohort_events(obs, fleet, &base, &active, cfg.overselect);
        }
        let (cond, participants) =
            fleet.realize_round(&active, ctx.clock.now());
        let (arrived, ev) = if overselecting {
            deadline_round_overselect(
                &mut ctx, fleet, &mut ddl, &active, &cond, &participants,
                cfg.tau, m, obs,
            )
        } else {
            deadline_round(
                &mut ctx, fleet, &mut ddl, &active, &cond, &participants,
                cfg.tau, obs,
            )
        };
        if !arrived.is_empty() {
            fedgate_round(
                engine, fleet, &mut state, &arrived, cfg.tau, cfg.eta,
                cfg.gamma, &mut bufs,
            )?;
        }
        let (loss, gsq) = round_stats(arrived.is_empty(), stats, || {
            active_loss_gradsq(engine, fleet, &all, &state.w)
        })?;
        stats = (loss, gsq);
        ctx.record(
            &state.w,
            active.len(),
            0,
            loss,
            gsq,
            ev.dropped,
            ev.missed,
            reranks,
            cond.online_count(),
            ev.cancelled,
        )?;
        if gsq <= threshold {
            ctx.trace.finished = true;
            break;
        }
        if ctx.should_stop() {
            break;
        }
    }
    Ok(ctx.trace)
}

/// FedBuff staleness discount (Nguyen et al. 2022): an update computed
/// against a model `staleness` server versions old is downweighted by
/// `1 / sqrt(1 + staleness)`.
pub fn staleness_weight(staleness: usize) -> f64 {
    1.0 / (1.0 + staleness as f64).sqrt()
}

/// FedBuff (Nguyen et al. 2022): buffered asynchronous aggregation.
///
/// Every client trains continuously: it pulls the current server model,
/// runs tau local steps at its own realized speed, uploads, and
/// immediately pulls again. The server buffers uploads and applies one
/// staleness-weighted averaged update whenever `k` of them accumulate —
/// no round deadline, no waiting for stragglers. Simulated as a
/// discrete-event loop over per-client completion times; each buffer
/// flush is one "round" on the trace and advances the virtual clock to
/// the flush time ([`VirtualClock::charge_until`]). Speed realizations
/// advance once per flush via the same [`crate::fed::SystemState`]
/// process the synchronous solvers use, so FedBuff sees the same
/// scenario dynamics as its comparison baselines.
///
/// Stopping matches the synchronous benchmarks: the run finishes when
/// the full-objective gradient meets the N-client statistical accuracy
/// `||grad||^2 <= 2 mu V_Ns`.
fn run_fedbuff(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    k: usize,
    obs: &mut Observe,
) -> Result<Trace> {
    let eval = EvalData::build(engine, fleet, cfg.eval_rows, cfg.seed)?;
    let mut ctx = RunContext::new(engine, cfg, &eval);
    ctx.client_eval = ClientEval::maybe_build(engine, fleet)?;
    let n = fleet.num_clients();
    let all: Vec<usize> = (0..n).collect();
    let p = engine.meta().param_count;
    let mut w = init_params(engine, cfg.seed);
    let zero_delta = vec![0.0f32; p];
    let mut bufs = RoundBuffers::new(engine, cfg.tau);
    let threshold = cfg.grad_threshold(n);

    // per-client async state: the model snapshot it trains against, the
    // server version it pulled, its upload time and this attempt's
    // realized conditions
    let mut start_w: Vec<Vec<f32>> = vec![w.clone(); n];
    let mut start_version = vec![0usize; n];
    let mut finish = vec![0.0f64; n];
    let mut attempt_time = vec![0.0f64; n];
    let mut avail = vec![true; n];
    let mut version = 0usize;

    // an attempt produces an upload only when the client is both online
    // (observable availability, fed::traces) and not silently dropped
    let mut cond = fleet.next_round_conditions();
    for i in 0..n {
        attempt_time[i] = cond.times[i];
        avail[i] = cond.available[i] && cond.online[i];
        finish[i] = cfg.tau as f64 * cond.times[i];
    }

    let (l0, g0) = active_loss_gradsq(engine, fleet, &all, &w)?;
    ctx.record(&w, n, 0, l0, g0, 0, 0, 0, n, 0)?;

    // server buffer: staleness-weighted delta accumulator. Dropped
    // uploads are tracked per CLIENT (a fast unavailable client can
    // fail several attempts within one flush window; the trace row
    // reports distinct clients so `dropped` never exceeds the fleet)
    let mut acc = vec![0.0f64; p];
    let mut buffered = 0usize;
    let mut dropped_since_flush = vec![false; n];
    // liveness bound: under extreme dropout the buffer can take many
    // completions to fill; cap total client attempts so the loop always
    // terminates even if no flush ever happens
    let max_attempts = (cfg.max_rounds + 1) * n.max(k) * 4;
    let mut attempts = 0usize;
    loop {
        // pop the earliest completion (completion times are finite and
        // strictly positive, so the comparison never sees NaN)
        let i = (0..n)
            .min_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap())
            .unwrap();
        let t_i = finish[i];
        attempts += 1;
        if avail[i] {
            let wi = local_round(
                engine, fleet, i, &start_w[i], &zero_delta, cfg.tau, cfg.eta,
                &mut bufs,
            )?;
            // Delta_i = (w_start - w_i^tau) / eta, discounted by staleness
            let staleness = version - start_version[i];
            let inv = (staleness_weight(staleness) / cfg.eta as f64) as f32;
            let sw = &start_w[i];
            for j in 0..p {
                acc[j] += ((sw[j] - wi[j]) * inv) as f64;
            }
            buffered += 1;
            fleet.estimates.observe(i, attempt_time[i]);
        } else {
            dropped_since_flush[i] = true;
        }
        if buffered == k {
            // flush: apply the buffered mean, advance clock and version
            let d_avg = linalg::mean_of(&acc, k);
            linalg::axpy(-(cfg.eta * cfg.gamma), &d_avg, &mut w);
            version += 1;
            let dropped = dropped_since_flush.iter().filter(|&&d| d).count();
            // async path: one `deadline`-free event per flush (FedBuff
            // has no round deadline; the flush time IS the boundary)
            obs.set_round(ctx.rounds_done());
            let ev = ctx.clock.charge_until(t_i, k, dropped, 0);
            let (loss, gsq) = active_loss_gradsq(engine, fleet, &all, &w)?;
            ctx.record(
                &w,
                k,
                0,
                loss,
                gsq,
                ev.dropped,
                0,
                0,
                cond.online_count(),
                0,
            )?;
            acc.fill(0.0);
            buffered = 0;
            dropped_since_flush.fill(false);
            // the heterogeneity process advances once per flush, at the
            // flush's virtual time (diurnal windows are time-based)
            cond = fleet.next_round_conditions_at(ctx.clock.now());
            if gsq <= threshold {
                ctx.trace.finished = true;
                break;
            }
            if ctx.should_stop() {
                break;
            }
        }
        // relaunch client i from the current server model under the
        // latest realized conditions
        start_w[i].copy_from_slice(&w);
        start_version[i] = version;
        attempt_time[i] = cond.times[i];
        avail[i] = cond.available[i] && cond.online[i];
        finish[i] = t_i + cfg.tau as f64 * cond.times[i];
        // all-offline guard (fed::traces): when every client's current
        // attempt is doomed, no upload can ever fill the buffer — and
        // conditions are normally only re-realized on flushes, so the
        // loop would spin to max_attempts. Re-realize at this
        // completion's event time instead: completion times keep
        // growing, so time-based availability windows eventually reopen
        // and the relaunched client sees them.
        if avail.iter().all(|&a| !a) {
            cond = fleet.next_round_conditions_at(t_i);
            attempt_time[i] = cond.times[i];
            avail[i] = cond.available[i] && cond.online[i];
            finish[i] = t_i + cfg.tau as f64 * cond.times[i];
        }
        if attempts >= max_attempts {
            break;
        }
    }
    Ok(ctx.trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard, synth};
    use crate::engine::NativeEngine;
    use crate::fed::SpeedModel;

    fn setup(n_clients: usize, s: usize) -> (NativeEngine, ClientFleet) {
        let mut rng = Rng::new(21);
        let (ds, _) = synth::linreg(&mut rng, n_clients * s, 5, 0.05);
        let shards = shard::partition_iid(&mut rng, &ds, n_clients);
        let fleet = ClientFleet::new(
            ds,
            shards,
            &SpeedModel::paper_uniform().into(),
            &mut rng,
        );
        (NativeEngine::linreg(5, 10, 5), fleet)
    }

    fn base_cfg(solver: SolverKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::new(solver, "linreg_d5", 8, 50);
        cfg.tau = 5;
        cfg.eta = 0.05;
        cfg.max_rounds = 150;
        cfg.mu = 0.5;
        cfg.c_stat = 0.05;
        cfg
    }

    #[test]
    fn init_params_he_for_mlp_zero_for_linear() {
        let lin = NativeEngine::linreg(5, 10, 5);
        assert!(init_params(&lin, 1).iter().all(|&v| v == 0.0));
        let mlp = NativeEngine::mlp(6, 3, vec![4], 0.0, 2, 1);
        let p = init_params(&mlp, 1);
        assert!(p.iter().any(|&v| v != 0.0));
        // biases (after each weight block) are zero
        let w1 = 6 * 4;
        assert!(p[w1..w1 + 4].iter().all(|&v| v == 0.0));
        // deterministic
        assert_eq!(p, init_params(&mlp, 1));
        assert_ne!(p, init_params(&mlp, 2));
    }

    #[test]
    fn fedgate_full_converges_and_finishes() {
        let (e, mut fleet) = setup(8, 50);
        let cfg = base_cfg(SolverKind::FedGate);
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        assert!(t.finished, "did not reach statistical accuracy");
        let first = t.rounds.first().unwrap();
        let last = t.last().unwrap();
        assert!(last.loss_full < first.loss_full);
        assert!(last.grad_norm_sq <= cfg.grad_threshold(8));
        // times strictly increase
        assert!(t.rounds.windows(2).all(|w| w[1].time > w[0].time));
    }

    #[test]
    fn fedavg_converges() {
        let (e, mut fleet) = setup(8, 50);
        let cfg = base_cfg(SolverKind::FedAvg);
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        assert!(t.last().unwrap().loss_full < t.rounds[0].loss_full);
        assert!(t.finished);
    }

    #[test]
    fn ditto_converges_like_fedavg() {
        let (e, mut fleet) = setup(8, 50);
        let cfg = base_cfg(SolverKind::Ditto { lambda: 1.0 });
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        assert!(t.finished, "global model did not reach the threshold");
        assert!(t.last().unwrap().loss_full < t.rounds[0].loss_full);
        // linreg: no client eval, so the acc column stays NaN
        assert!(t.rounds.iter().all(|r| r.acc.is_nan()));
        assert!(t.client_acc.is_empty());
        // the GLOBAL path is fedavg verbatim: identical round count
        let (e2, mut fleet2) = setup(8, 50);
        let t2 = run_solver(&e2, &mut fleet2, &base_cfg(SolverKind::FedAvg))
            .unwrap();
        assert_eq!(t.rounds.len(), t2.rounds.len());
        assert_eq!(
            t.last().unwrap().loss_full.to_bits(),
            t2.last().unwrap().loss_full.to_bits(),
            "ditto's global model must be bit-identical to fedavg's"
        );
    }

    #[test]
    fn fedprox_converges() {
        let (e, mut fleet) = setup(8, 50);
        let mut cfg = base_cfg(SolverKind::FedProx);
        cfg.prox_mu = 0.05;
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        assert!(t.last().unwrap().loss_full < t.rounds[0].loss_full);
    }

    #[test]
    fn fednova_converges_with_hetero_taus() {
        let (e, mut fleet) = setup(8, 50);
        let cfg = base_cfg(SolverKind::FedNova);
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        assert!(t.finished);
        assert!(t.last().unwrap().loss_full < t.rounds[0].loss_full);
    }

    #[test]
    fn partial_random_converges_slower_than_full() {
        let (e, mut fleet) = setup(8, 50);
        let cfg_full = base_cfg(SolverKind::FedGate);
        let t_full = run_solver(&e, &mut fleet, &cfg_full).unwrap();
        let (e2, mut fleet2) = setup(8, 50);
        let cfg_part = base_cfg(SolverKind::FedGatePartialRandom { k: 2 });
        let t_part = run_solver(&e2, &mut fleet2, &cfg_part).unwrap();
        // partial still descends
        assert!(t_part.last().unwrap().loss_full < t_part.rounds[0].loss_full);
        // but needs at least as many rounds as full participation
        assert!(t_part.rounds.len() >= t_full.rounds.len());
    }

    #[test]
    fn partial_fastest_rounds_are_cheap() {
        let (e, mut fleet) = setup(8, 50);
        let mut cfg = base_cfg(SolverKind::FedGatePartialFastest { k: 2 });
        cfg.max_rounds = 10;
        cfg.c_stat = 1e-9; // never reach accuracy; observe timing only
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        // per-round cost must equal tau * T_(2) (the 2nd fastest client)
        let sorted_speed = fleet.speeds_of(fleet.fastest(2));
        let per_round = cfg.tau as f64
            * sorted_speed.iter().cloned().fold(0.0f64, f64::max);
        let dt = t.rounds[2].time - t.rounds[1].time;
        assert!((dt - per_round).abs() < 1e-9, "{dt} vs {per_round}");
    }

    #[test]
    fn tifl_trains_one_whole_tier_per_round() {
        let (e, mut fleet) = setup(8, 50);
        let mut cfg = base_cfg(SolverKind::Tifl);
        cfg.tiers = Some(crate::fed::TierPolicy::new(4));
        cfg.max_rounds = 400;
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        // every round's cohort is exactly one tier (8 clients / 4 tiers)
        assert!(t.rounds[1..].iter().all(|r| r.participants == 2));
        // rotating credits let every tier's data in: the model descends
        assert!(t.last().unwrap().loss_full < t.rounds[0].loss_full);
        // static scenario: the tier cache is never invalidated
        assert_eq!(t.total_reranks(), 0);
        assert_eq!(fleet.retier_events(), 0);
    }

    #[test]
    fn tifl_rounds_are_tier_bound_not_fleet_bound() {
        // the TiFL premise: a tier-scheduled round never waits for a
        // client outside the selected tier, so the fastest-tier rounds
        // cost at most tau * (2nd-fastest speed) while a full cohort
        // round would pay the fleet's slowest member
        let (e, mut fleet) = setup(8, 50);
        let sorted = {
            let mut s = fleet.speeds_of(fleet.fastest(8));
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s
        };
        let mut cfg = base_cfg(SolverKind::Tifl);
        cfg.tiers = Some(crate::fed::TierPolicy::new(4));
        cfg.max_rounds = 10;
        cfg.c_stat = 1e-9; // timing-only run
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        // round 1 selects the fastest tier {T_(1), T_(2)}
        let dt = t.rounds[1].time - t.rounds[0].time;
        assert!(
            (dt - cfg.tau as f64 * sorted[1]).abs() < 1e-9,
            "first tifl round {dt} != tau * 2nd-fastest {}",
            cfg.tau as f64 * sorted[1]
        );
        // and no round ever costs more than the slowest tier's straggler
        let max_cost = cfg.tau as f64 * sorted[7];
        assert!(t
            .rounds
            .windows(2)
            .all(|w| w[1].time - w[0].time <= max_cost + 1e-9));
    }

    #[test]
    fn tifl_overselect_pads_the_tier_and_cancels_the_surplus() {
        let (e, mut fleet) = setup(8, 50);
        let mut cfg = base_cfg(SolverKind::Tifl);
        cfg.tiers = Some(crate::fed::TierPolicy::new(4));
        cfg.overselect = 2.0;
        cfg.max_rounds = 400;
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        // every round selects 2 * tier(2) = 4 and cancels the 2 surplus
        // in-flight clients at the 2nd arrival
        assert!(t.rounds[1..].iter().all(|r| r.participants == 4));
        assert!(t.rounds[1..].iter().all(|r| r.cancelled == 2));
        assert_eq!(t.total_missed(), 0);
        assert!(t.last().unwrap().loss_full < t.rounds[0].loss_full);
    }

    #[test]
    fn staleness_weight_discounts_old_updates() {
        assert_eq!(staleness_weight(0), 1.0);
        assert_eq!(staleness_weight(3), 0.5);
        assert!(staleness_weight(10) < staleness_weight(1));
    }

    #[test]
    fn fedbuff_converges_and_finishes() {
        let (e, mut fleet) = setup(8, 50);
        let mut cfg = base_cfg(SolverKind::FedBuff { k: 3 });
        // staleness-discounted buffered updates make smaller effective
        // steps than a full synchronous round: allow more flushes
        cfg.max_rounds = 800;
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        assert!(t.finished, "fedbuff did not reach statistical accuracy");
        assert!(t.last().unwrap().loss_full < t.rounds[0].loss_full);
        // flush times never decrease
        assert!(t.rounds.windows(2).all(|w| w[1].time >= w[0].time));
        // every flush aggregates exactly k buffered uploads
        assert!(t.rounds[1..].iter().all(|r| r.participants == 3));
    }

    #[test]
    fn fedbuff_deterministic_given_seed() {
        let (e, mut fleet) = setup(6, 50);
        let cfg = base_cfg(SolverKind::FedBuff { k: 2 });
        let t1 = run_solver(&e, &mut fleet, &cfg).unwrap();
        let (e2, mut fleet2) = setup(6, 50);
        let t2 = run_solver(&e2, &mut fleet2, &cfg).unwrap();
        assert_eq!(t1.rounds.len(), t2.rounds.len());
        for (a, b) in t1.rounds.iter().zip(&t2.rounds) {
            assert_eq!(a.loss_full, b.loss_full);
            assert_eq!(a.time, b.time);
        }
    }

    #[test]
    fn fedbuff_flushes_track_fast_clients() {
        // with k = 2 of 8, early flushes happen before a full synchronous
        // round over all 8 would have closed: the first flush time must
        // be at most tau * (2nd fastest speed) * ... actually the 2nd
        // arrival of ANY client, which is bounded by tau * 2nd-fastest
        let (e, mut fleet) = setup(8, 50);
        let sorted = fleet.speeds_of(fleet.fastest(8));
        let slowest = sorted.iter().cloned().fold(0.0f64, f64::max);
        let second_fastest = {
            let mut s = sorted.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[1]
        };
        let mut cfg = base_cfg(SolverKind::FedBuff { k: 2 });
        cfg.max_rounds = 5;
        cfg.c_stat = 1e-9; // timing-only run
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        let first_flush = t.rounds[1].time;
        assert!(
            first_flush <= cfg.tau as f64 * second_fastest + 1e-9,
            "first flush {first_flush} waited past the 2nd-fastest client"
        );
        assert!(first_flush < cfg.tau as f64 * slowest);
    }

    #[test]
    fn max_rounds_budget_respected() {
        let (e, mut fleet) = setup(8, 50);
        let mut cfg = base_cfg(SolverKind::FedGate);
        cfg.max_rounds = 7;
        cfg.c_stat = 1e-12;
        let t = run_solver(&e, &mut fleet, &cfg).unwrap();
        assert!(!t.finished);
        // initial row + 7 rounds
        assert_eq!(t.rounds.len(), 8);
    }

    #[test]
    fn deterministic_given_seed() {
        let (e, mut fleet) = setup(6, 50);
        let cfg = base_cfg(SolverKind::FedGate);
        let t1 = run_solver(&e, &mut fleet, &cfg).unwrap();
        let (e2, mut fleet2) = setup(6, 50);
        let t2 = run_solver(&e2, &mut fleet2, &cfg).unwrap();
        assert_eq!(t1.rounds.len(), t2.rounds.len());
        for (a, b) in t1.rounds.iter().zip(&t2.rounds) {
            assert_eq!(a.loss_full, b.loss_full);
            assert_eq!(a.time, b.time);
        }
    }
}
