//! Shared full-objective evaluator: everything the figures plot.
//!
//! Computes the *global* training objective L_N (over all N clients'
//! data, whether or not they currently participate), the exact
//! suboptimality `||w - w*||` for linear regression (w* from the normal
//! equations), and classification accuracy — on a deterministic,
//! optionally subsampled evaluation slice chunked to the artifact batch.

use crate::data::Labels;
use crate::engine::{Engine, ModelKind};
use crate::fed::ClientFleet;
use crate::util::{linalg, Rng};
use anyhow::Result;

pub struct EvalData {
    /// prebuilt [chunks][b*d] feature batches
    x_chunks: Vec<Vec<f32>>,
    /// prebuilt [chunks][b*y_width] label batches
    y_chunks: Vec<Vec<f32>>,
    /// exact linreg optimum over ALL shard data (None otherwise)
    pub w_star: Option<Vec<f32>>,
    /// loss at w_star (linreg): lets traces report L - L* exactly
    pub loss_star: f64,
    classification: bool,
}

impl EvalData {
    /// Build from the union of all clients' shards, capped at `max_rows`
    /// rows (0 = all), chunked to the engine batch.
    pub fn build(
        engine: &dyn Engine,
        fleet: &ClientFleet,
        max_rows: usize,
        seed: u64,
    ) -> Result<EvalData> {
        let meta = engine.meta();
        let b = meta.batch;
        let d = meta.d;
        let yw = meta.y_width();

        // all rows owned by any client (in shard order = deterministic)
        let mut rows: Vec<usize> = fleet
            .shards
            .iter()
            .flat_map(|s| s.indices.iter().copied())
            .collect();
        if max_rows > 0 && rows.len() > max_rows {
            let mut rng = Rng::new(seed ^ 0x5eed_e7a1);
            rng.shuffle(&mut rows);
            rows.truncate(max_rows);
        }
        // drop the ragged tail so every chunk is exactly b rows
        let chunks = rows.len() / b;
        anyhow::ensure!(chunks > 0, "not enough rows to evaluate");
        rows.truncate(chunks * b);

        let mut x_chunks = Vec::with_capacity(chunks);
        let mut y_chunks = Vec::with_capacity(chunks);
        for chunk in rows.chunks(b) {
            let mut x = vec![0.0f32; b * d];
            let mut y = vec![0.0f32; b * yw];
            fleet.dataset.gather_x(chunk, &mut x);
            fleet.dataset.y.encode_into(chunk, &mut y);
            x_chunks.push(x);
            y_chunks.push(y);
        }

        // exact linreg optimum over the FULL federated training set
        let (w_star, loss_star) = if meta.kind == ModelKind::LinReg {
            let all_rows: Vec<usize> = fleet
                .shards
                .iter()
                .flat_map(|s| s.indices.iter().copied())
                .collect();
            let n = all_rows.len();
            let mut x = vec![0.0f32; n * d];
            fleet.dataset.gather_x(&all_rows, &mut x);
            let y: Vec<f32> = match &fleet.dataset.y {
                Labels::Real(v) => all_rows.iter().map(|&i| v[i]).collect(),
                _ => anyhow::bail!("linreg needs real labels"),
            };
            let w = linalg::linreg_optimum(&x, &y, n, d, meta.l2 as f64);
            // exact loss at w*
            let mut acc = 0.0f64;
            for r in 0..n {
                let mut pred = w[d] as f64;
                for j in 0..d {
                    pred += w[j] as f64 * x[r * d + j] as f64;
                }
                let resid = pred - y[r] as f64;
                acc += 0.5 * resid * resid;
            }
            let mut l2term = 0.0f64;
            for j in 0..d {
                l2term += (w[j] as f64) * (w[j] as f64);
            }
            (Some(w), acc / n as f64 + 0.5 * meta.l2 as f64 * l2term)
        } else {
            (None, 0.0)
        };

        Ok(EvalData {
            x_chunks,
            y_chunks,
            w_star,
            loss_star,
            classification: meta.kind != ModelKind::LinReg,
        })
    }

    pub fn num_chunks(&self) -> usize {
        self.x_chunks.len()
    }

    /// Mean loss of `params` over the evaluation slice.
    pub fn full_loss(&self, engine: &dyn Engine, params: &[f32]) -> Result<f64> {
        let mut acc = 0.0f64;
        for (x, y) in self.x_chunks.iter().zip(&self.y_chunks) {
            acc += engine.loss(params, x, y)? as f64;
        }
        Ok(acc / self.x_chunks.len() as f64)
    }

    /// Mean accuracy over the evaluation slice (NaN for regression).
    pub fn full_accuracy(&self, engine: &dyn Engine, params: &[f32]) -> Result<f64> {
        if !self.classification {
            return Ok(f64::NAN);
        }
        let mut acc = 0.0f64;
        for (x, y) in self.x_chunks.iter().zip(&self.y_chunks) {
            acc += engine.accuracy(params, x, y)? as f64;
        }
        Ok(acc / self.x_chunks.len() as f64)
    }

    /// ||w - w*|| when the exact optimum is known; NaN otherwise.
    pub fn dist_to_opt(&self, params: &[f32]) -> f64 {
        match &self.w_star {
            Some(w) => linalg::dist2(params, w),
            None => f64::NAN,
        }
    }
}

/// Per-client held-out evaluator: one engine-batch chunk per client,
/// built from the shard tail the fleet reserved via
/// [`ClientFleet::set_holdout`]. This is the statistical-heterogeneity
/// measurement the `acc` trace column and the `Trace` worst-decile
/// aggregate come from — under non-IID skew a client's held-out
/// accuracy reflects ITS distribution, not the population mixture.
pub struct ClientEval {
    /// [clients][b*d] held-out feature chunks
    x_chunks: Vec<Vec<f32>>,
    /// [clients][b*y_width] held-out label chunks
    y_chunks: Vec<Vec<f32>>,
}

impl ClientEval {
    /// Build iff the fleet reserved a holdout (`Ok(None)` otherwise, so
    /// callers can assign the result unconditionally — IID runs stay on
    /// the zero-cost path). The holdout must be exactly one engine
    /// batch (`setup::build_fleet` reserves `meta.batch` rows).
    pub fn maybe_build(
        engine: &dyn Engine,
        fleet: &ClientFleet,
    ) -> Result<Option<ClientEval>> {
        let h = fleet.holdout();
        if h == 0 {
            return Ok(None);
        }
        let meta = engine.meta();
        anyhow::ensure!(
            h == meta.batch,
            "holdout {h} is not one engine batch ({})",
            meta.batch
        );
        let (d, yw) = (meta.d, meta.y_width());
        let n = fleet.num_clients();
        let mut x_chunks = Vec::with_capacity(n);
        let mut y_chunks = Vec::with_capacity(n);
        for c in 0..n {
            let rows = fleet.holdout_rows(c);
            let mut x = vec![0.0f32; h * d];
            let mut y = vec![0.0f32; h * yw];
            fleet.dataset.gather_x(rows, &mut x);
            fleet.dataset.y.encode_into(rows, &mut y);
            x_chunks.push(x);
            y_chunks.push(y);
        }
        Ok(Some(ClientEval { x_chunks, y_chunks }))
    }

    pub fn num_clients(&self) -> usize {
        self.x_chunks.len()
    }

    /// Client `c`'s held-out accuracy under parameters `w`.
    pub fn accuracy_of(
        &self,
        engine: &dyn Engine,
        c: usize,
        w: &[f32],
    ) -> Result<f64> {
        Ok(engine.accuracy(w, &self.x_chunks[c], &self.y_chunks[c])? as f64)
    }

    /// Every client's held-out accuracy under ONE global model.
    pub fn accuracies_global(
        &self,
        engine: &dyn Engine,
        w: &[f32],
    ) -> Result<Vec<f64>> {
        (0..self.num_clients())
            .map(|c| self.accuracy_of(engine, c, w))
            .collect()
    }

    /// Every client's held-out accuracy under its OWN model (the
    /// personalized solvers' metric; `models[c]` is client c's head).
    pub fn accuracies_personal(
        &self,
        engine: &dyn Engine,
        models: &[Vec<f32>],
    ) -> Result<Vec<f64>> {
        assert_eq!(models.len(), self.num_clients());
        (0..self.num_clients())
            .map(|c| self.accuracy_of(engine, c, &models[c]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard, synth};
    use crate::engine::NativeEngine;
    use crate::fed::SpeedModel;

    fn linreg_fleet() -> (NativeEngine, ClientFleet) {
        let mut rng = Rng::new(3);
        let (ds, _) = synth::linreg(&mut rng, 200, 5, 0.1);
        let shards = shard::partition_iid(&mut rng, &ds, 10);
        let fleet = ClientFleet::new(
            ds,
            shards,
            &SpeedModel::paper_uniform().into(),
            &mut rng,
        );
        (NativeEngine::linreg(5, 10, 2), fleet)
    }

    #[test]
    fn eval_chunks_and_loss() {
        let (e, fleet) = linreg_fleet();
        let ev = EvalData::build(&e, &fleet, 0, 1).unwrap();
        assert_eq!(ev.num_chunks(), 20);
        let w0 = vec![0.0f32; 6];
        let l0 = ev.full_loss(&e, &w0).unwrap();
        assert!(l0 > 0.0);
        // loss at w* must be below loss at zero and near loss_star
        let ws = ev.w_star.clone().unwrap();
        let ls = ev.full_loss(&e, &ws).unwrap();
        assert!(ls < l0);
        assert!((ls - ev.loss_star).abs() < 1e-3, "{ls} vs {}", ev.loss_star);
    }

    #[test]
    fn dist_to_opt_zero_at_optimum() {
        let (e, fleet) = linreg_fleet();
        let ev = EvalData::build(&e, &fleet, 0, 1).unwrap();
        let ws = ev.w_star.clone().unwrap();
        assert_eq!(ev.dist_to_opt(&ws), 0.0);
        assert!(ev.dist_to_opt(&vec![0.0; 6]) > 0.0);
        let _ = e;
    }

    #[test]
    fn subsampling_caps_rows() {
        let (e, fleet) = linreg_fleet();
        let ev = EvalData::build(&e, &fleet, 50, 1).unwrap();
        assert_eq!(ev.num_chunks(), 5);
    }

    #[test]
    fn accuracy_nan_for_regression() {
        let (e, fleet) = linreg_fleet();
        let ev = EvalData::build(&e, &fleet, 0, 1).unwrap();
        assert!(ev.full_accuracy(&e, &vec![0.0; 6]).unwrap().is_nan());
    }

    #[test]
    fn client_eval_scores_each_holdout_chunk() {
        let e = NativeEngine::logreg(6, 3, 0.0, 10, 5);
        let mut rng = Rng::new(9);
        let mut spec = synth::MixtureSpec::cifar_like(4 * 30);
        spec.d = 6;
        spec.classes = 3;
        spec.separation = 2.0;
        let ds = synth::mixture(&mut rng, &spec);
        let shards = shard::partition_iid(&mut rng, &ds, 4);
        let mut fleet = ClientFleet::new(
            ds,
            shards,
            &SpeedModel::paper_uniform().into(),
            &mut rng,
        );
        // no holdout -> no evaluator, the zero-cost default
        assert!(ClientEval::maybe_build(&e, &fleet).unwrap().is_none());
        fleet.set_holdout(10);
        let ev = ClientEval::maybe_build(&e, &fleet).unwrap().unwrap();
        assert_eq!(ev.num_clients(), 4);
        let w = vec![0.0f32; e.meta().param_count];
        let global = ev.accuracies_global(&e, &w).unwrap();
        assert_eq!(global.len(), 4);
        assert!(global.iter().all(|a| (0.0..=1.0).contains(a)));
        // per-client heads: identical heads reproduce the global scores
        let heads = vec![w.clone(); 4];
        assert_eq!(ev.accuracies_personal(&e, &heads).unwrap(), global);
        // a holdout that is not one engine batch is rejected
        fleet.set_holdout(7);
        assert!(ClientEval::maybe_build(&e, &fleet).is_err());
    }
}
