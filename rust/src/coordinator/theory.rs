//! The paper's analytical runtime expressions (Propositions 2-3,
//! Theorem 2) as executable predictors — used by `flanp-bench theory`
//! to compare simulated wall-clock against the theory's shape.

use crate::util::stats::{expected_order_stat_exp, harmonic};

/// Proposition 2: E[T_FLANP] = R * tau * (T_{n0} + T_{2n0} + ... + T_N)
/// for given per-stage rounds R and local steps tau, over the *sorted*
/// speeds (fastest first).
pub fn flanp_runtime(sorted_speeds: &[f64], n0: usize, r: f64, tau: f64) -> f64 {
    let n = sorted_speeds.len();
    assert!(n0 >= 1 && n0 <= n);
    let mut sum = 0.0;
    let mut k = n0;
    loop {
        sum += sorted_speeds[k - 1]; // T_(k): slowest of the active stage
        if k == n {
            break;
        }
        k = (2 * k).min(n);
    }
    r * tau * sum
}

/// Proposition 3: E[T_FedGATE] = R_G * tau * T_N with
/// R_G = O(kappa * log(5 * Delta0 * N * s / c)).
pub fn fedgate_runtime(
    t_max: f64,
    n: usize,
    s: usize,
    kappa: f64,
    delta0: f64,
    c: f64,
    tau: f64,
) -> f64 {
    let r_g = 6.0 * kappa * (5.0 * delta0 * (n * s) as f64 / c).ln();
    r_g * tau * t_max
}

/// Theorem 1's per-stage round count R = 12 * kappa * ln 6.
pub fn stage_rounds(kappa: f64) -> f64 {
    12.0 * kappa * 6.0f64.ln()
}

/// Theorem 2 (exponential speeds): the expected-order-statistics ratio
///   (E[T_(n0)] + E[T_(2n0)] + ... + E[T_(N)]) / E[T_(N)]
/// which the appendix bounds by 2 + 1/N. Exact via harmonic numbers.
pub fn exp_order_ratio(n: usize, n0: usize) -> f64 {
    let mut k = n0;
    let mut sum = 0.0;
    loop {
        sum += expected_order_stat_exp(n, k);
        if k == n {
            break;
        }
        k = (2 * k).min(n);
    }
    sum / harmonic(n)
}

/// Theorem 2's speedup bound:
/// E[T_FLANP]/E[T_FedGATE] <= (12 log6 / (5 log(5 Delta0 N s / c))) * (2 + 1/N).
pub fn speedup_bound(n: usize, s: usize, delta0: f64, c: f64) -> f64 {
    let log_term = (5.0 * delta0 * (n * s) as f64 / c).ln();
    (12.0 * 6.0f64.ln() / (5.0 * log_term)) * (2.0 + 1.0 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flanp_runtime_sums_stage_slowest() {
        // speeds 1..8 sorted; stages 2,4,8 -> T_2 + T_4 + T_8 = 2+4+8
        let speeds: Vec<f64> = (1..=8).map(|v| v as f64).collect();
        let t = flanp_runtime(&speeds, 2, 1.0, 1.0);
        assert_eq!(t, 14.0);
        // r, tau scale linearly
        assert_eq!(flanp_runtime(&speeds, 2, 3.0, 2.0), 84.0);
    }

    #[test]
    fn flanp_runtime_handles_non_power_of_two() {
        let speeds: Vec<f64> = (1..=6).map(|v| v as f64).collect();
        // stages: 2, 4, min(8,6)=6 -> 2+4+6
        assert_eq!(flanp_runtime(&speeds, 2, 1.0, 1.0), 12.0);
    }

    #[test]
    fn fedgate_runtime_grows_logarithmically_in_ns() {
        let t1 = fedgate_runtime(1.0, 10, 100, 1.0, 1.0, 1.0, 1.0);
        let t2 = fedgate_runtime(1.0, 10, 10_000, 1.0, 1.0, 1.0, 1.0);
        // 100x more samples => + ln(100) rounds, NOT 100x
        assert!((t2 - t1 - 6.0 * (100.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn exp_order_ratio_bounded_by_theorem2() {
        for n in [4usize, 16, 64, 256, 1024] {
            let ratio = exp_order_ratio(n, 1);
            assert!(
                ratio <= 2.0 + 1.0 / n as f64 + 1e-9,
                "n={n}: ratio {ratio} exceeds 2 + 1/N"
            );
            assert!(ratio > 1.0);
        }
    }

    #[test]
    fn speedup_bound_shrinks_with_ns() {
        let b_small = speedup_bound(10, 100, 1.0, 1.0);
        let b_large = speedup_bound(1000, 100, 1.0, 1.0);
        assert!(b_large < b_small);
        // the O(1/log(Ns)) shape: doubling log(Ns) halves the bound
        let b1 = speedup_bound(10, 10, 1.0, 1.0);
        let b2 = speedup_bound(10_000, 10_000, 1.0, 1.0);
        assert!(b2 < b1 / 2.0);
    }

    #[test]
    fn stage_rounds_matches_theorem1() {
        assert!((stage_rounds(1.0) - 12.0 * 6.0f64.ln()).abs() < 1e-12);
    }
}
