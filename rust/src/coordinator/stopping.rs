//! Statistical-accuracy stopping rules (Section 3).
//!
//! Oracle rule: a stage with n participants ends once
//! `||grad L_n(w)||^2 <= 2 mu V_ns`, the sufficient condition for
//! `L_n(w) - L_n(w*) <= V_ns` under mu-strong convexity.
//!
//! Heuristic rule (Section 5.4, Figure 9): mu and c are unknown; the
//! threshold starts at half the initial squared gradient norm and is
//! halved at every stage transition.
//!
//! # Partial participation (aggregation deadlines)
//!
//! Both rules stay sound when rounds aggregate only a subset of the
//! stage's cohort (a finite [`crate::fed::DeadlinePolicy`]): the
//! statistical accuracy `V_ns = c/(n s)` is a property of the *intended*
//! cohort's n·s samples — the ERM the stage is solving — not of which
//! subset uploaded in a particular round. The FLANP driver therefore
//! keeps `n` = the stage cohort size and evaluates `||grad L_n(w)||^2`
//! over the full cohort's data; deadline-missed updates slow per-round
//! progress but never loosen the bar a stage must clear before the
//! participant set grows.

use super::config::ExperimentConfig;

pub trait StageStop {
    /// Threshold on the squared gradient norm for a stage with n nodes.
    fn threshold(&self, n: usize) -> f64;

    /// Should the stage with n participants end given `grad_norm_sq`?
    fn stage_done(&self, n: usize, grad_norm_sq: f64) -> bool {
        grad_norm_sq <= self.threshold(n)
    }

    /// Called when a stage ends (lets heuristics update their state).
    fn on_stage_advance(&mut self);
}

/// Oracle rule: threshold = 2 mu c / (n s).
pub struct OracleStop {
    mu: f64,
    c_stat: f64,
    s: usize,
}

impl OracleStop {
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        OracleStop { mu: cfg.mu, c_stat: cfg.c_stat, s: cfg.s }
    }
}

impl StageStop for OracleStop {
    fn threshold(&self, n: usize) -> f64 {
        2.0 * self.mu * self.c_stat / (n as f64 * self.s as f64)
    }

    fn on_stage_advance(&mut self) {}
}

/// Heuristic rule: successive halving of an observed-gradient threshold.
pub struct HeuristicStop {
    current: f64,
    initialized: bool,
}

impl HeuristicStop {
    pub fn new() -> Self {
        HeuristicStop { current: f64::INFINITY, initialized: false }
    }

    /// Prime the threshold from the first observed gradient norm.
    pub fn observe_initial(&mut self, grad_norm_sq: f64) {
        if !self.initialized && grad_norm_sq.is_finite() && grad_norm_sq > 0.0 {
            self.current = grad_norm_sq / 2.0;
            self.initialized = true;
        }
    }

    pub fn is_initialized(&self) -> bool {
        self.initialized
    }
}

impl Default for HeuristicStop {
    fn default() -> Self {
        Self::new()
    }
}

impl StageStop for HeuristicStop {
    fn threshold(&self, _n: usize) -> f64 {
        self.current
    }

    fn on_stage_advance(&mut self) {
        self.current /= 2.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SolverKind;

    #[test]
    fn oracle_threshold_formula() {
        let cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 8, 50);
        let stop = OracleStop::from_config(&cfg);
        let want = 2.0 * cfg.mu * cfg.c_stat / (4.0 * 50.0);
        assert!((stop.threshold(4) - want).abs() < 1e-15);
        assert!(stop.stage_done(4, want * 0.99));
        assert!(!stop.stage_done(4, want * 1.01));
    }

    #[test]
    fn oracle_threshold_halves_when_n_doubles() {
        let cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 8, 50);
        let stop = OracleStop::from_config(&cfg);
        assert!((stop.threshold(2) / stop.threshold(4) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn heuristic_initializes_then_halves() {
        let mut h = HeuristicStop::new();
        // uninitialized threshold is +inf => everything would pass;
        // callers must observe_initial first (the flanp driver guards
        // on is_initialized()).
        assert!(!h.is_initialized());
        h.observe_initial(8.0);
        assert!(h.is_initialized());
        assert_eq!(h.threshold(1), 4.0);
        assert!(h.stage_done(1, 3.9));
        h.on_stage_advance();
        assert_eq!(h.threshold(1), 2.0);
        // re-observing does not reset
        h.observe_initial(100.0);
        assert_eq!(h.threshold(1), 2.0);
    }

    #[test]
    fn heuristic_uninitialized_never_done() {
        let h = HeuristicStop::new();
        // +inf threshold means stage_done is trivially true; the flanp
        // driver guards on is_initialized() — assert the guard exists by
        // checking threshold is infinite.
        assert!(h.threshold(1).is_infinite());
    }
}
