//! FLANP (Algorithms 1 + 2): the straggler-resilient meta-algorithm.
//!
//! Stage machine over the FedGATE subroutine:
//!   * start with the n0 *fastest* clients;
//!   * run FedGATE rounds until the active ERM reaches its statistical
//!     accuracy, `||grad L_n(w)||^2 <= 2 mu V_ns` (or the Figure-9
//!     heuristic threshold when mu, c are unknown);
//!   * double the participant set (next-fastest clients join), reset the
//!     gradient-tracking variables, re-tune stepsizes (Theorem 1), and
//!     warm-start from the previous stage's model (Proposition 1);
//!   * finish when the full-N stage reaches its statistical accuracy.
//!
//! With a non-[`Sync`](crate::fed::DeadlinePolicy::Sync) aggregation
//! deadline the stage machine runs **semi-synchronously**: each round
//! aggregates only the clients that arrived by the policy's deadline and
//! charges `min(deadline, slowest)` to the clock. The statistical-
//! accuracy rule is unchanged — it thresholds the gradient of the FULL
//! intended cohort's objective, whose statistical accuracy `V_ns`
//! depends on the cohort's data, not on which subset arrived — so stage
//! boundaries (and the final full-N stop) remain sound under partial
//! participation; partial rounds just make less progress per round while
//! costing less wall-clock (see `stopping.rs`).
//!
//! Active-set ranking runs at one of three cadences:
//!
//! * **stage** (default): re-rank the estimate-based fastest prefix at
//!   every stage boundary;
//! * **per-round** ([`ExperimentConfig::rerank_per_round`]): re-rank the
//!   prefix every round — the individual re-ranking baseline TiFL
//!   measures against;
//! * **tiered** ([`ExperimentConfig::tiers`]): ride the cached
//!   [`crate::fed::TierScheduler`] membership — stage sizes snap to tier
//!   boundaries so a stage admits whole tiers — and recompute only when
//!   a client's estimate breaches its tier's hysteresis band.
//!
//! Every ranking refresh (re-rank or re-tier) is charged to the trace's
//! `reranks` column, so the scheduling-overhead comparison between the
//! cadences is inspectable per run (`flanp-bench tiers`).

use super::config::{ExperimentConfig, SolverKind, Subroutine};
use super::eval::{ClientEval, EvalData};
use super::gate::{
    active_loss_gradsq, fedgate_round, local_rounds, GateState, LocalSpec,
    RoundBuffers, TauSpec,
};
use super::solvers::{
    deadline_round, deadline_round_overselect, emit_cohort_events,
    init_params, refresh_tiers_observed, RunContext,
};
use crate::util::linalg;
use super::stopping::{HeuristicStop, OracleStop, StageStop};
use crate::engine::Engine;
use crate::fed::observe::num as json_num;
use crate::fed::{
    overselect_target, ClientFleet, DeadlineController, EventKind, Observe,
    Phase, Span, Trace, OVERSELECT_OFF,
};
use crate::util::json::obj;
use anyhow::Result;

/// [`run_flanp_with`] with observability fully off (the plain API every
/// test and pre-observability caller uses).
pub fn run_flanp(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
) -> Result<Trace> {
    run_flanp_with(engine, fleet, cfg, &mut Observe::off())
}

pub fn run_flanp_with(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    cfg: &ExperimentConfig,
    obs: &mut Observe,
) -> Result<Trace> {
    let heuristic = cfg.solver == SolverKind::FlanpHeuristic;
    let mut oracle = OracleStop::from_config(cfg);
    let mut heur = HeuristicStop::new();
    let mut ddl = DeadlineController::new(cfg.deadline.clone());
    let tiered = cfg.tiers.is_some();
    if let Some(policy) = &cfg.tiers {
        fleet.ensure_tiers(policy);
    }

    let eval = EvalData::build(engine, fleet, cfg.eval_rows, cfg.seed)?;
    let mut ctx = RunContext::new(engine, cfg, &eval);
    ctx.client_eval = ClientEval::maybe_build(engine, fleet)?;
    let n_total = fleet.num_clients();
    let mut state = GateState::new(init_params(engine, cfg.seed), n_total);
    let mut bufs = RoundBuffers::new(engine, cfg.tau);

    let w0 = state.w.clone();
    let mut n = cfg.n0.min(n_total);
    let mut stage = 0usize;
    'stages: loop {
        // stage setup: fastest-n prefix (re-ranked from the online speed
        // estimates at every stage boundary — or read from the cached
        // tier membership, snapping the stage to whole tiers — unless
        // the oracle ranking is forced), fresh tracking, stage stepsizes
        obs.set_stage(stage);
        obs.set_round(ctx.rounds_done());
        let mut pending_reranks = 0usize;
        let base = if tiered {
            pending_reranks += refresh_tiers_observed(fleet, obs) as usize;
            fleet.tiered_prefix(n)
        } else {
            if cfg.estimate_speeds {
                pending_reranks += 1;
                if obs.enabled() {
                    obs.emit(
                        EventKind::Rerank,
                        None,
                        obj(vec![("count", 1usize.into())]),
                    );
                }
            }
            fleet.active_prefix(n, cfg.estimate_speeds)
        };
        n = base.len(); // tier-granular stages admit whole tiers
        // predictive selection layer (fed::selection): over-select
        // ceil(F * n) candidates and swap predicted-offline picks for
        // forecast-approved alternates. n stays the STATISTICAL stage
        // size — stepsizes, the stopping threshold and the cancel target
        // all key off n, never off the padded cohort. With overselect
        // off and no forecaster this is the identity on `active`.
        let overselecting = cfg.overselect > OVERSELECT_OFF;
        let mut active = fleet
            .select_cohort(&base, overselect_target(n, cfg.overselect, n_total));
        if obs.enabled() {
            emit_cohort_events(obs, fleet, &base, &active, cfg.overselect);
        }
        state.reset_tracking();
        if !cfg.warm_start && stage > 0 {
            // ablation: discard the previous stage's model (Prop. 1 off)
            state.w.copy_from_slice(&w0);
        }
        let (mut eta, mut gamma) = cfg.stage_stepsizes(n);
        // stage_transitions logs the size each stage STARTS with; a
        // mid-stage re-tier that grows the snapped cohort (rare — it
        // needs boundary drift, not just membership churn) retunes the
        // stepsizes below but is not a stage transition
        ctx.trace.stage_transitions.push((ctx.rounds_done(), n));
        if obs.enabled() {
            // the stopping-rule inputs this stage starts from: its
            // statistical size, the last recorded gradient norm and the
            // oracle threshold `2 mu V_ns` the stage must reach
            let gsq = ctx.trace.last().map_or(f64::NAN, |r| r.grad_norm_sq);
            obs.emit(
                EventKind::Stage,
                None,
                obj(vec![
                    ("n", n.into()),
                    ("grad_norm_sq", json_num(gsq)),
                    ("threshold", json_num(cfg.grad_threshold(n))),
                ]),
            );
        }

        // initial stats (first stage only: later stages start from the
        // model the previous round already recorded at this same clock
        // time; a duplicate row would break clock monotonicity). Also
        // primes the heuristic threshold from the first gradient norm.
        if ctx.trace.rounds.is_empty() {
            let (l0, g0) =
                active_loss_gradsq(engine, fleet, &active[..n], &state.w)?;
            if heuristic {
                heur.observe_initial(g0);
            }
            ctx.record(
                &state.w,
                n,
                stage,
                l0,
                g0,
                0,
                0,
                std::mem::take(&mut pending_reranks),
                fleet.num_clients(),
                0,
            )?;
        }

        let mut first_round_of_stage = true;
        // cached (loss_active, grad^2) for the CURRENT (w, active) pair:
        // wait/empty rounds leave both unchanged, so re-evaluating the
        // objective (the dominant host cost under low availability)
        // would recompute the exact same numbers. Invalidated whenever
        // the active set changes.
        let mut stats: Option<(f64, f64)> = None;
        loop {
            obs.set_round(ctx.rounds_done());
            // SELECT phase: between-round ranking maintenance (the stage
            // setup above already ranked the first round) — tiered runs
            // ride the cached membership and only react when the
            // hysteresis band trips; the per-round baseline re-ranks
            // every round — then realize this round's system conditions
            // (event-driven: the process advances for every client,
            // active or not) and split the cohort into arrivals vs
            // offline clients vs dropouts.
            let (cond, participants) = {
                let _sp = Span::enter(Phase::Select);
                if !std::mem::take(&mut first_round_of_stage) {
                    if tiered {
                        if refresh_tiers_observed(fleet, obs) {
                            let tier_base = fleet.tiered_prefix(n);
                            if tier_base.len() != n {
                                // new boundaries grew the snapped cohort:
                                // retune the stage stepsizes so eta/gamma
                                // and the stopping threshold track the
                                // same n
                                n = tier_base.len();
                                (eta, gamma) = cfg.stage_stepsizes(n);
                            }
                            active = fleet.select_cohort(
                                &tier_base,
                                overselect_target(n, cfg.overselect, n_total),
                            );
                            pending_reranks += 1;
                            stats = None; // active changed
                        }
                    } else if cfg.rerank_per_round {
                        active = fleet.select_cohort(
                            &fleet.active_prefix(n, true),
                            overselect_target(n, cfg.overselect, n_total),
                        );
                        pending_reranks += 1;
                        stats = None; // active changed
                        if obs.enabled() {
                            obs.emit(
                                EventKind::Rerank,
                                None,
                                obj(vec![("count", 1usize.into())]),
                            );
                        }
                    }
                }
                // offline prefix members are SKIPPED, not waited for
                // (deadline_round charges only the online cohort; a
                // fully-offline prefix waits for its next availability
                // window). Only the arrived clients' updates are
                // aggregated; under the Sync policy with everyone online
                // this is the whole available cohort, bit-identically to
                // the seed's synchronous rounds.
                fleet.realize_round(&active, ctx.clock.now())
            };
            // AGGREGATE phase: over-selection closes the round at the
            // n-th arrival (the statistical requirement) and cancels the
            // padded tail; without it the plain deadline path runs
            // byte-for-byte
            let (arrived, ev) = {
                let _sp = Span::enter(Phase::Aggregate);
                if overselecting {
                    deadline_round_overselect(
                        &mut ctx, fleet, &mut ddl, &active, &cond,
                        &participants, cfg.tau, n, obs,
                    )
                } else {
                    deadline_round(
                        &mut ctx, fleet, &mut ddl, &active, &cond,
                        &participants, cfg.tau, obs,
                    )
                }
            };
            // LOCAL-ROUNDS phase: the subroutine's fan-out (its inner
            // `engine::kernels` share is attributed separately by the
            // `kernels` span inside `coordinator::gate`)
            if !arrived.is_empty() {
                let _sp = Span::enter(Phase::LocalRounds);
                match cfg.subroutine {
                    Subroutine::Gate => fedgate_round(
                        engine, fleet, &mut state, &arrived, cfg.tau,
                        eta, gamma, &mut bufs,
                    )?,
                    Subroutine::Avg => {
                        // Remark 1: FLANP over plain FedAvg — tau local SGD
                        // steps (zero tracking) then model averaging,
                        // fanned out through the shared gate::local_rounds
                        let p = state.w.len();
                        let zero = vec![0.0f32; p];
                        let wis = local_rounds(
                            engine,
                            fleet,
                            &arrived,
                            &state.w,
                            LocalSpec::Sgd(&zero),
                            TauSpec::Uniform(cfg.tau),
                            eta,
                            &mut bufs,
                        )?;
                        let mut acc = vec![0.0f64; p];
                        for wi in &wis {
                            linalg::accumulate(&mut acc, wi);
                        }
                        state.w = linalg::mean_of(&acc, arrived.len());
                    }
                }
            }
            // EVAL phase: the statistical-accuracy rule thresholds the
            // gradient of the STATISTICAL cohort's ERM (the n clients
            // the stage needs — active[..n]); over-selection's padding
            // is a systems-level spare pool, not extra statistical
            // accuracy
            let (loss, gsq) = match stats {
                Some(s) if arrived.is_empty() => s,
                _ => {
                    let _sp = Span::enter(Phase::Eval);
                    active_loss_gradsq(engine, fleet, &active[..n], &state.w)?
                }
            };
            stats = Some((loss, gsq));
            // BOOKKEEPING phase: trace row + stopping decision
            let _sp = Span::enter(Phase::Bookkeeping);
            ctx.record(
                &state.w,
                n,
                stage,
                loss,
                gsq,
                ev.dropped,
                ev.missed,
                std::mem::take(&mut pending_reranks),
                cond.online_count(),
                ev.cancelled,
            )?;

            let done = if heuristic {
                heur.is_initialized() && heur.stage_done(n, gsq)
            } else {
                oracle.stage_done(n, gsq)
            };
            if done {
                if n >= n_total {
                    if heuristic {
                        // Section 5.4: the heuristic has no oracle notion
                        // of "final accuracy reached" — it keeps halving
                        // the threshold within the full-N stage and
                        // refines until the run budget ends
                        heur.on_stage_advance();
                        if ctx.should_stop() {
                            break 'stages;
                        }
                        continue;
                    }
                    ctx.trace.finished = true;
                    break 'stages;
                }
                // advance: grow participants (Algorithm 1; paper: 2x)
                if heuristic {
                    heur.on_stage_advance();
                } else {
                    oracle.on_stage_advance();
                }
                n = (((n as f64) * cfg.growth).ceil() as usize)
                    .max(n + 1)
                    .min(n_total);
                stage += 1;
                continue 'stages;
            }
            if ctx.should_stop() {
                break 'stages;
            }
        }
    }
    Ok(ctx.trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard, synth};
    use crate::engine::NativeEngine;
    use crate::fed::SpeedModel;
    use crate::util::Rng;

    fn setup(n_clients: usize, s: usize, seed: u64) -> (NativeEngine, ClientFleet) {
        let mut rng = Rng::new(seed);
        let (ds, _) = synth::linreg(&mut rng, n_clients * s, 5, 0.05);
        let shards = shard::partition_iid(&mut rng, &ds, n_clients);
        let fleet = ClientFleet::new(
            ds,
            shards,
            &SpeedModel::paper_uniform().into(),
            &mut rng,
        );
        (NativeEngine::linreg(5, 10, 5), fleet)
    }

    fn cfg(solver: SolverKind, n: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::new(solver, "linreg_d5", n, 50);
        cfg.tau = 5;
        cfg.eta = 0.05;
        cfg.n0 = 2;
        cfg.max_rounds = 400;
        cfg.mu = 0.5;
        cfg.c_stat = 0.05;
        cfg
    }

    #[test]
    fn flanp_progresses_through_stages_to_full_n() {
        let (e, mut fleet) = setup(8, 50, 31);
        let t = run_flanp(&e, &mut fleet, &cfg(SolverKind::Flanp, 8)).unwrap();
        assert!(t.finished, "flanp did not finish");
        // participants double per stage: 2, 4, 8
        let ns: Vec<usize> = t.stage_transitions.iter().map(|&(_, n)| n).collect();
        assert_eq!(ns, vec![2, 4, 8]);
        // participants monotone nondecreasing over rounds
        assert!(t
            .rounds
            .windows(2)
            .all(|w| w[1].participants >= w[0].participants));
        // final stage satisfied the full-N statistical accuracy
        let c = cfg(SolverKind::Flanp, 8);
        assert!(t.last().unwrap().grad_norm_sq <= c.grad_threshold(8));
    }

    #[test]
    fn flanp_active_set_is_fastest_prefix() {
        let (e, mut fleet) = setup(8, 50, 32);
        let order = fleet.order.clone();
        let speeds = fleet.speeds.clone();
        let t = run_flanp(&e, &mut fleet, &cfg(SolverKind::Flanp, 8)).unwrap();
        // first-stage round cost must be tau * T_(n0), the n0-th fastest
        let n0_speed = speeds[order[1]]; // 2nd fastest (n0 = 2)
        let dt = t.rounds[2].time - t.rounds[1].time;
        assert!((dt - 5.0 * n0_speed).abs() < 1e-9, "{dt} vs {}", 5.0 * n0_speed);
    }

    #[test]
    fn flanp_beats_fedgate_wallclock() {
        // the paper's headline: FLANP reaches the final statistical
        // accuracy in less simulated time than full-participation FedGATE
        let (e, mut fleet) = setup(16, 50, 33);
        let t_flanp = run_flanp(&e, &mut fleet, &cfg(SolverKind::Flanp, 16)).unwrap();
        let (e2, mut fleet2) = setup(16, 50, 33);
        let t_gate = crate::coordinator::run_solver(
            &e2,
            &mut fleet2,
            &cfg(SolverKind::FedGate, 16),
        )
        .unwrap();
        assert!(t_flanp.finished && t_gate.finished);
        assert!(
            t_flanp.total_time < t_gate.total_time,
            "flanp {} !< fedgate {}",
            t_flanp.total_time,
            t_gate.total_time
        );
    }

    #[test]
    fn heuristic_flanp_also_converges() {
        let (e, mut fleet) = setup(8, 50, 34);
        let t =
            run_flanp(&e, &mut fleet, &cfg(SolverKind::FlanpHeuristic, 8)).unwrap();
        // heuristic keeps halving until budgets; it must at least have
        // advanced past the first stage and descended
        assert!(t.stage_transitions.len() >= 2, "{:?}", t.stage_transitions);
        assert!(t.last().unwrap().loss_full < t.rounds[0].loss_full);
    }

    #[test]
    fn overselect_cancels_surplus_without_slowing_the_run() {
        // static fleet, everyone online: the padded cohort's n-th arrival
        // IS the statistical prefix's straggler, so over-selection books
        // cancellations every round while the clock, the arrivals and
        // the whole statistical trajectory match the plain run exactly
        let (e, mut fleet) = setup(8, 50, 37);
        let mut c = cfg(SolverKind::Flanp, 8);
        c.overselect = 1.5;
        let t = run_flanp(&e, &mut fleet, &c).unwrap();
        assert!(t.finished);
        assert!(t.total_cancelled() > 0, "no in-flight work was cancelled");
        assert_eq!(t.total_missed(), 0, "cancellations booked as misses");
        let ns: Vec<usize> =
            t.stage_transitions.iter().map(|&(_, n)| n).collect();
        assert_eq!(ns, vec![2, 4, 8], "padding leaked into stage sizes");
        let (e2, mut fleet2) = setup(8, 50, 37);
        let t0 = run_flanp(&e2, &mut fleet2, &cfg(SolverKind::Flanp, 8)).unwrap();
        assert_eq!(t.rounds.len(), t0.rounds.len());
        for (a, b) in t.rounds.iter().zip(&t0.rounds) {
            assert_eq!(a.time, b.time);
            assert_eq!(a.loss_full, b.loss_full);
        }
    }

    #[test]
    fn overselect_off_and_no_forecast_is_bit_identical_to_default() {
        // the explicit "off" spelling must not perturb anything
        let (e, mut fleet) = setup(8, 50, 38);
        let mut c = cfg(SolverKind::Flanp, 8);
        c.overselect = 1.0;
        c.forecast = None;
        let t = run_flanp(&e, &mut fleet, &c).unwrap();
        let (e2, mut fleet2) = setup(8, 50, 38);
        let t0 = run_flanp(&e2, &mut fleet2, &cfg(SolverKind::Flanp, 8)).unwrap();
        assert_eq!(t.rounds.len(), t0.rounds.len());
        for (a, b) in t.rounds.iter().zip(&t0.rounds) {
            assert_eq!(a.time, b.time);
            assert_eq!(a.loss_full, b.loss_full);
            assert_eq!(a.cancelled, 0);
        }
    }

    #[test]
    fn flanp_n0_larger_than_n_clamps() {
        let (e, mut fleet) = setup(4, 50, 35);
        let mut c = cfg(SolverKind::Flanp, 4);
        c.n0 = 4; // == N: single stage
        let t = run_flanp(&e, &mut fleet, &c).unwrap();
        assert_eq!(t.stage_transitions.len(), 1);
        assert!(t.finished);
    }

    #[test]
    fn warm_start_helps_later_stages() {
        // rounds needed in stage k+1 should be modest thanks to the
        // warm start (Proposition 1): no stage after the first should
        // need more rounds than the whole budget
        let (e, mut fleet) = setup(16, 50, 36);
        let t = run_flanp(&e, &mut fleet, &cfg(SolverKind::Flanp, 16)).unwrap();
        assert!(t.finished);
        let mut per_stage = vec![0usize; t.stage_transitions.len()];
        for r in &t.rounds {
            per_stage[r.stage] += 1;
        }
        // every stage terminated (no stage ate the whole budget)
        for (s, &cnt) in per_stage.iter().enumerate() {
            assert!(cnt < 200, "stage {s} used {cnt} rounds");
        }
    }
}

#[cfg(test)]
mod ablation_tests {
    use super::super::config::Subroutine;
    use super::tests_support::*;
    use super::*;

    #[test]
    fn warm_start_saves_rounds() {
        let (e, mut fleet) = setup_ab(16, 50, 41);
        let mut warm = cfg_ab(16);
        warm.warm_start = true;
        let t_warm = run_flanp(&e, &mut fleet, &warm).unwrap();
        let (e2, mut fleet2) = setup_ab(16, 50, 41);
        let mut cold = cfg_ab(16);
        cold.warm_start = false;
        let t_cold = run_flanp(&e2, &mut fleet2, &cold).unwrap();
        assert!(t_warm.finished);
        // cold restarts must cost at least as much total time
        assert!(
            t_warm.total_time <= t_cold.total_time,
            "warm {} !<= cold {}",
            t_warm.total_time,
            t_cold.total_time
        );
    }

    #[test]
    fn growth_factor_controls_stage_count() {
        let (e, mut fleet) = setup_ab(16, 50, 42);
        let mut c4 = cfg_ab(16);
        c4.growth = 4.0;
        let t4 = run_flanp(&e, &mut fleet, &c4).unwrap();
        let (e2, mut fleet2) = setup_ab(16, 50, 42);
        let t2 = run_flanp(&e2, &mut fleet2, &cfg_ab(16)).unwrap();
        assert!(t4.stage_transitions.len() < t2.stage_transitions.len());
        let ns: Vec<usize> = t4.stage_transitions.iter().map(|&(_, n)| n).collect();
        assert_eq!(ns, vec![2, 8, 16]);
    }

    #[test]
    fn fedavg_subroutine_also_converges() {
        // Remark 1: the meta-algorithm works over other solvers
        let (e, mut fleet) = setup_ab(8, 50, 43);
        let mut c = cfg_ab(8);
        c.subroutine = Subroutine::Avg;
        let t = run_flanp(&e, &mut fleet, &c).unwrap();
        assert!(t.finished, "flanp-fedavg did not converge");
        assert!(t.stage_transitions.len() >= 3);
    }
}

#[cfg(test)]
pub(crate) mod tests_support {
    use super::*;
    use crate::data::{shard, synth};
    use crate::engine::NativeEngine;
    use crate::fed::SpeedModel;
    use crate::util::Rng;

    pub fn setup_ab(n_clients: usize, s: usize, seed: u64) -> (NativeEngine, ClientFleet) {
        let mut rng = Rng::new(seed);
        let (ds, _) = synth::linreg(&mut rng, n_clients * s, 5, 0.05);
        let shards = shard::partition_iid(&mut rng, &ds, n_clients);
        let fleet = ClientFleet::new(
            ds,
            shards,
            &SpeedModel::paper_uniform().into(),
            &mut rng,
        );
        (NativeEngine::linreg(5, 10, 5), fleet)
    }

    pub fn cfg_ab(n: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "linreg_d5", n, 50);
        cfg.tau = 5;
        cfg.eta = 0.05;
        cfg.n0 = 2;
        cfg.max_rounds = 600;
        cfg.mu = 0.5;
        cfg.c_stat = 0.05;
        cfg
    }
}
