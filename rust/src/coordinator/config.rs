//! Experiment configuration: every knob of every figure in one struct.

use crate::data::DataSpec;
use crate::fed::{
    validate_overselect, DeadlinePolicy, ForecastPolicy, SpeedModel,
    SystemModel, TierPolicy, OVERSELECT_OFF,
};

/// Which algorithm drives the run.
#[derive(Clone, Debug, PartialEq)]
pub enum SolverKind {
    /// FLANP (Algorithm 2) with the oracle statistical-accuracy rule.
    Flanp,
    /// FLANP with the Figure-9 heuristic threshold-halving rule
    /// (no knowledge of mu / c).
    FlanpHeuristic,
    /// Non-adaptive FedGATE with all N nodes (the paper's main benchmark).
    FedGate,
    /// FedAvg (McMahan et al. 2017): tau local SGD steps + model average.
    FedAvg,
    /// FedNova (Wang et al. 2020): heterogeneous tau_i, normalized avg.
    FedNova,
    /// FedProx (Li et al. 2018): proximal local objective + model average.
    FedProx,
    /// FedGATE with k uniformly random participants per round (Fig. 6a).
    FedGatePartialRandom { k: usize },
    /// FedGATE with the k fastest participants every round (Fig. 6b).
    FedGatePartialFastest { k: usize },
    /// FedBuff (Nguyen et al. 2022): buffered asynchronous aggregation —
    /// clients train continuously against the model snapshot they last
    /// pulled; the server applies a staleness-weighted average whenever
    /// k uploads fill its buffer. No round deadline: the clock advances
    /// to each buffer-flush time.
    FedBuff { k: usize },
    /// TiFL (Chai et al. 2020): tier-scheduled FedGATE — the fleet is
    /// clustered into latency tiers from the online speed estimates
    /// (`fed::tiers`) and each round trains ONE whole tier, chosen by
    /// fairness credits so slow tiers still contribute. The tier count
    /// and hysteresis come from [`ExperimentConfig::tiers`] (required).
    Tifl,
    /// Ditto-style personalization (Li et al. 2021, via the
    /// straggler-resilient personalized FL line): the GLOBAL model runs
    /// plain FedAvg rounds through the shared `deadline_round` step,
    /// while every arrived client additionally trains a PERSONAL head
    /// `v_i` with tau proximal steps `v_i -= eta * (grad_i(v_i) +
    /// lambda * (v_i - w))` inside its already-charged tau budget. The
    /// per-client held-out accuracy of the personal heads fills the
    /// trace's `acc` column — the quantity the non-IID acceptance
    /// scenario compares across solvers.
    Ditto { lambda: f64 },
}

impl SolverKind {
    pub fn name(&self) -> String {
        match self {
            SolverKind::Flanp => "flanp".into(),
            SolverKind::FlanpHeuristic => "flanp-heuristic".into(),
            SolverKind::FedGate => "fedgate".into(),
            SolverKind::FedAvg => "fedavg".into(),
            SolverKind::FedNova => "fednova".into(),
            SolverKind::FedProx => "fedprox".into(),
            SolverKind::FedGatePartialRandom { k } => format!("fedgate-rand{k}"),
            SolverKind::FedGatePartialFastest { k } => format!("fedgate-fast{k}"),
            SolverKind::FedBuff { k } => format!("fedbuff{k}"),
            SolverKind::Tifl => "tifl".into(),
            SolverKind::Ditto { lambda } => format!("ditto:{lambda}"),
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        if let Some(l) = s.strip_prefix("ditto:") {
            return Ok(SolverKind::Ditto {
                lambda: l
                    .parse()
                    .map_err(|_| format!("bad ditto lambda '{l}'"))?,
            });
        }
        if s == "ditto" {
            return Ok(SolverKind::Ditto { lambda: 1.0 });
        }
        if let Some(k) = s.strip_prefix("fedgate-rand") {
            return Ok(SolverKind::FedGatePartialRandom {
                k: k.parse().map_err(|_| "bad k")?,
            });
        }
        if let Some(k) = s.strip_prefix("fedgate-fast") {
            return Ok(SolverKind::FedGatePartialFastest {
                k: k.parse().map_err(|_| "bad k")?,
            });
        }
        if let Some(k) = s.strip_prefix("fedbuff") {
            return Ok(SolverKind::FedBuff {
                k: k.parse().map_err(|_| "bad buffer size k")?,
            });
        }
        match s {
            "flanp" => Ok(SolverKind::Flanp),
            "flanp-heuristic" => Ok(SolverKind::FlanpHeuristic),
            "fedgate" => Ok(SolverKind::FedGate),
            "fedavg" => Ok(SolverKind::FedAvg),
            "fednova" => Ok(SolverKind::FedNova),
            "fedprox" => Ok(SolverKind::FedProx),
            "tifl" => Ok(SolverKind::Tifl),
            _ => Err(format!("unknown solver '{s}'")),
        }
    }
}

/// How FLANP picks (eta_n, gamma_n) per stage.
#[derive(Clone, Debug, PartialEq)]
pub enum StepsizeSchedule {
    /// eta, gamma fixed across stages (the paper's experiments:
    /// eta = 0.05 MNIST / 0.02 CIFAR, gamma = 1).
    Fixed,
    /// Theorem 1: eta_n = alpha / (tau * sqrt(n)),
    ///            gamma_n = sqrt(n) / (2 * alpha * L).
    Theory { alpha: f64, lipschitz: f64 },
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub solver: SolverKind,
    /// manifest model name, e.g. "linreg_d25"
    pub model: String,
    pub num_clients: usize,
    /// samples per client (must be a multiple of the artifact batch)
    pub s: usize,
    pub eta: f32,
    pub gamma: f32,
    /// local updates per round (defaults to the artifact's fused tau)
    pub tau: usize,
    /// FLANP initial participant count n0
    pub n0: usize,
    pub stepsizes: StepsizeSchedule,
    /// strong-convexity constant mu for the statistical-accuracy rule
    pub mu: f64,
    /// V_ns = c_stat / (n*s)
    pub c_stat: f64,
    /// FedProx proximal coefficient
    pub prox_mu: f32,
    /// system-heterogeneity scenario: base speed draw + per-round
    /// dynamics + dropout (plain [`SpeedModel`]s convert via `.into()`)
    pub system: SystemModel,
    /// statistical-heterogeneity scenario (`--data`, the `data:`
    /// grammar): Dirichlet label skew + per-client covariate shift,
    /// optionally speed-correlated. [`DataSpec::iid`] (the default) is
    /// bit-identical to the seed's IID sharding.
    pub data: DataSpec,
    /// Aggregation deadline policy (fed::aggregation): how the server
    /// decides when to close a round and aggregate whatever arrived.
    /// [`DeadlinePolicy::Sync`] (the default) waits for the slowest
    /// cohort member — the paper's model, bit-identical to the seed.
    pub deadline: DeadlinePolicy,
    /// FLANP ranks its fastest-prefix from the online EWMA speed
    /// estimates (TiFL-style) instead of oracle speeds. Under static
    /// scenarios both rankings are identical bit-for-bit.
    pub estimate_speeds: bool,
    /// TiFL tier scheduling (`fed::tiers`): cluster the fleet into
    /// latency tiers from the online estimates, cache membership across
    /// rounds/stages and re-tier only past the hysteresis band. `None`
    /// disables tiering. When set, FLANP snaps its stage sizes to tier
    /// boundaries (a stage admits whole tiers); required by
    /// [`SolverKind::Tifl`].
    pub tiers: Option<TierPolicy>,
    /// Re-rank the FLANP active prefix from the estimates EVERY round
    /// instead of at stage boundaries — the per-round individual
    /// re-ranking baseline that tier caching is measured against.
    /// Mutually exclusive with `tiers`.
    pub rerank_per_round: bool,
    /// Over-selection factor F (`fed::selection`, `--overselect`): the
    /// adaptive cohort solvers (flanp | flanp-heuristic | tifl) select
    /// `ceil(F * k)` clients for a statistical requirement of k and
    /// close the round at the k-th ARRIVAL, cancelling the surplus
    /// in-flight work. 1.0 (the default) is off — bit-identical to the
    /// pre-selection behavior.
    pub overselect: f64,
    /// Availability forecasting (`fed::selection`, `--forecast`): learn
    /// per-client online-window predictions from the realized rounds and
    /// skip predicted-offline clients at selection time. `None` (the
    /// default) is off — bit-identical to the pre-selection behavior.
    pub forecast: Option<ForecastPolicy>,
    /// EWMA smoothing of the online speed estimator, in (0, 1]
    pub ewma_alpha: f64,
    /// Record every realized round (probe included) of the
    /// heterogeneity process for trace export (`fed::traces`):
    /// `ClientFleet::write_recorded_trace` / `flanp run --record-trace`
    /// turn the run into a CSV replayable via `--speed trace:FILE`.
    pub record_trace: bool,
    /// Structured event-log destination (`fed::observe`, schema
    /// `flanp-events/v1`): `flanp run --events PATH`. `None` (the
    /// default) keeps every run bit-identical to the pre-observability
    /// behavior — the hot path takes a single disabled-observer branch.
    pub events: Option<String>,
    /// Run-summary destination (`fed::observe`, schema
    /// `flanp-summary/v1`): `flanp run --summary PATH`. Enables the
    /// metrics registry and the host-side span profiler.
    pub summary: Option<String>,
    /// Bin log verbosity (`util::log`; `--log-level` /
    /// `FLANP_LOG`). [`crate::util::log::Level::Info`] reproduces the
    /// historical stdout byte-for-byte.
    pub log_level: crate::util::log::Level,
    pub seed: u64,
    pub max_rounds: usize,
    /// virtual-time budget (0 = unlimited)
    pub max_time: f64,
    /// evaluate the full objective every k rounds (1 = every round)
    pub eval_every: usize,
    /// cap on rows used for the full-objective evaluation (0 = all)
    pub eval_rows: usize,
    /// per-round communication overhead added to the virtual clock
    pub comm_overhead: f64,
    /// terminate the run once loss_full <= target (0 = disabled);
    /// lets benchmark curves share a common stopping point
    pub target_loss: f64,
    /// terminate once dist_to_opt <= target (0 = disabled; linreg only)
    pub target_dist: f64,
    /// FLANP ablations (DESIGN.md §5a): warm-start stages from the
    /// previous model (paper behaviour) or re-initialize
    pub warm_start: bool,
    /// FLANP participant growth factor alpha (paper: 2.0 = doubling)
    pub growth: f64,
    /// FLANP inner solver (Remark 1: the meta-algorithm is
    /// subroutine-agnostic)
    pub subroutine: Subroutine,
}

/// Inner federated solver driven by the FLANP stage machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Subroutine {
    /// FedGATE (Algorithm 2 — the paper's instantiation)
    Gate,
    /// plain FedAvg (tau local SGD steps + model averaging)
    Avg,
}

impl ExperimentConfig {
    /// Sensible defaults matching Section 5.1.
    pub fn new(solver: SolverKind, model: &str, num_clients: usize, s: usize) -> Self {
        ExperimentConfig {
            solver,
            model: model.to_string(),
            num_clients,
            s,
            eta: 0.05,
            gamma: 1.0,
            tau: 10,
            n0: 2,
            stepsizes: StepsizeSchedule::Fixed,
            mu: 0.01,
            c_stat: 1.0,
            prox_mu: 0.1,
            system: SpeedModel::paper_uniform().into(),
            data: DataSpec::iid(),
            deadline: DeadlinePolicy::Sync,
            estimate_speeds: true,
            tiers: None,
            rerank_per_round: false,
            overselect: OVERSELECT_OFF,
            forecast: None,
            ewma_alpha: crate::fed::DEFAULT_EWMA_ALPHA,
            record_trace: false,
            events: None,
            summary: None,
            log_level: crate::util::log::Level::Info,
            seed: 1,
            max_rounds: 400,
            max_time: 0.0,
            eval_every: 1,
            eval_rows: 2000,
            comm_overhead: 0.0,
            target_loss: 0.0,
            target_dist: 0.0,
            warm_start: true,
            growth: 2.0,
            subroutine: Subroutine::Gate,
        }
    }

    /// Statistical accuracy of the ERM over n participating clients:
    /// V_ns = c / (n*s).
    pub fn v_ns(&self, n: usize) -> f64 {
        self.c_stat / (n as f64 * self.s as f64)
    }

    /// The sufficient stopping threshold ||grad||^2 <= 2 mu V_ns.
    pub fn grad_threshold(&self, n: usize) -> f64 {
        2.0 * self.mu * self.v_ns(n)
    }

    /// Whether the configured model classifies (per-client accuracy is
    /// meaningful): every non-linreg model family in the manifest is a
    /// classifier.
    pub fn classification(&self) -> bool {
        self.model.starts_with("logreg") || self.model.starts_with("mlp")
    }

    /// Whether this run reserves per-client held-out rows and fills the
    /// trace's `acc` column: a classification model under a non-IID
    /// `data:` scenario, or any ditto run (the personalized solver is
    /// measured BY per-client accuracy). IID non-ditto runs stay off —
    /// bit-identical to the seed.
    pub fn client_eval_enabled(&self) -> bool {
        self.classification()
            && (!self.data.is_iid()
                || matches!(self.solver, SolverKind::Ditto { .. }))
    }

    /// Per-stage stepsizes for n participants.
    pub fn stage_stepsizes(&self, n: usize) -> (f32, f32) {
        match &self.stepsizes {
            StepsizeSchedule::Fixed => (self.eta, self.gamma),
            StepsizeSchedule::Theory { alpha, lipschitz } => {
                let eta = alpha / (self.tau as f64 * (n as f64).sqrt());
                let gamma = (n as f64).sqrt() / (2.0 * alpha * lipschitz);
                (eta as f32, gamma as f32)
            }
        }
    }

    pub fn validate(&self, batch: usize) -> Result<(), String> {
        if self.num_clients == 0 {
            return Err("num_clients must be positive".into());
        }
        if self.s % batch != 0 {
            return Err(format!(
                "s = {} must be a multiple of the artifact batch {batch}",
                self.s
            ));
        }
        if self.s < batch {
            return Err("s smaller than artifact batch".into());
        }
        if self.n0 == 0 || self.n0 > self.num_clients {
            return Err(format!(
                "n0 = {} out of range 1..={}",
                self.n0, self.num_clients
            ));
        }
        if self.tau == 0 {
            return Err("tau must be positive".into());
        }
        if self.growth <= 1.0 {
            return Err("growth factor must exceed 1".into());
        }
        if self.eta <= 0.0 || self.gamma <= 0.0 {
            return Err("stepsizes must be positive".into());
        }
        self.system.validate()?;
        if let Some(tr) = &self.system.trace {
            if tr.data.num_clients() != self.num_clients {
                return Err(format!(
                    "trace '{}' replays {} clients but the experiment has {}",
                    tr.path,
                    tr.data.num_clients(),
                    self.num_clients
                ));
            }
        }
        self.deadline.validate()?;
        // every synchronous cohort solver now routes through the shared
        // deadline_round step; only the async (fedbuff) and the
        // oracle-selection partial baselines have no cohort deadline
        if self.deadline != DeadlinePolicy::Sync
            && matches!(
                self.solver,
                SolverKind::FedBuff { .. }
                    | SolverKind::FedGatePartialRandom { .. }
                    | SolverKind::FedGatePartialFastest { .. }
            )
        {
            return Err(format!(
                "deadline policy '{}' applies to the synchronous cohort \
                 solvers (flanp | flanp-heuristic | fedgate | fedavg | \
                 fedprox | fednova | tifl | ditto), not {}",
                self.deadline.spec(),
                self.solver.name()
            ));
        }
        if let Some(tiers) = &self.tiers {
            tiers.validate()?;
            if !self.estimate_speeds {
                return Err("tier scheduling ranks from the online speed \
                            estimates; it cannot be combined with oracle \
                            ranking"
                    .into());
            }
            if self.rerank_per_round {
                return Err("tiers and rerank_per_round are mutually \
                            exclusive ranking cadences"
                    .into());
            }
            if !matches!(
                self.solver,
                SolverKind::Flanp | SolverKind::FlanpHeuristic | SolverKind::Tifl
            ) {
                return Err(format!(
                    "tier scheduling applies to flanp | flanp-heuristic | \
                     tifl, not {}",
                    self.solver.name()
                ));
            }
        }
        if self.solver == SolverKind::Tifl && self.tiers.is_none() {
            return Err(
                "tifl requires a tier policy (--tiers tiers:K[:hysteresis:H])"
                    .into(),
            );
        }
        if self.rerank_per_round && !self.estimate_speeds {
            return Err(
                "rerank_per_round requires estimate-based ranking".into()
            );
        }
        if self.rerank_per_round
            && !matches!(
                self.solver,
                SolverKind::Flanp | SolverKind::FlanpHeuristic
            )
        {
            return Err(format!(
                "rerank_per_round applies to flanp | flanp-heuristic, not {}",
                self.solver.name()
            ));
        }
        validate_overselect(self.overselect)?;
        // only the adaptive cohort solvers have selection freedom: the
        // full-participation benchmarks already use every client and the
        // partial/async baselines keep oracle selection by design
        if self.overselect > OVERSELECT_OFF
            && !matches!(
                self.solver,
                SolverKind::Flanp | SolverKind::FlanpHeuristic | SolverKind::Tifl
            )
        {
            return Err(format!(
                "overselect = {} applies to the adaptive cohort solvers \
                 (flanp | flanp-heuristic | tifl), not {}",
                self.overselect,
                self.solver.name()
            ));
        }
        if let Some(fc) = &self.forecast {
            fc.validate()?;
            if !matches!(
                self.solver,
                SolverKind::Flanp | SolverKind::FlanpHeuristic | SolverKind::Tifl
            ) {
                return Err(format!(
                    "forecast policy '{}' applies to the adaptive cohort \
                     solvers (flanp | flanp-heuristic | tifl), not {}",
                    fc.spec(),
                    self.solver.name()
                ));
            }
        }
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            return Err(format!(
                "ewma_alpha = {} outside (0, 1]",
                self.ewma_alpha
            ));
        }
        if matches!(
            self.solver,
            SolverKind::FedGatePartialRandom { k: 0 }
                | SolverKind::FedGatePartialFastest { k: 0 }
                | SolverKind::FedBuff { k: 0 }
        ) {
            return Err("partial participation / buffer size k must be positive".into());
        }
        if let SolverKind::FedGatePartialRandom { k }
        | SolverKind::FedGatePartialFastest { k }
        | SolverKind::FedBuff { k } = self.solver
        {
            if k > self.num_clients {
                return Err("k exceeds num_clients".into());
            }
        }
        if let SolverKind::Ditto { lambda } = self.solver {
            if !(lambda > 0.0) || !lambda.is_finite() {
                return Err(format!(
                    "ditto lambda = {lambda} must be positive and finite"
                ));
            }
        }
        // statistical-heterogeneity scenario (the data: grammar)
        if self.data.dirichlet.is_some() && !self.classification() {
            return Err(format!(
                "data:dirichlet label skew needs a classification model \
                 (logreg | mlp), not '{}' — the lazy population path \
                 interprets dirichlet as cluster-teacher skew instead",
                self.model
            ));
        }
        if self.client_eval_enabled() && self.s < 2 * batch {
            return Err(format!(
                "per-client held-out evaluation reserves one batch \
                 ({batch} rows) of each shard; s = {} must be at least \
                 2 x batch",
                self.s
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds_shrink_with_n() {
        let cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 16, 100);
        assert!(cfg.grad_threshold(2) > cfg.grad_threshold(4));
        assert!((cfg.v_ns(4) - cfg.c_stat / 400.0).abs() < 1e-12);
    }

    #[test]
    fn theory_stepsizes_scale_with_n() {
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 16, 100);
        cfg.stepsizes = StepsizeSchedule::Theory { alpha: 0.5, lipschitz: 2.0 };
        let (e1, g1) = cfg.stage_stepsizes(4);
        let (e2, g2) = cfg.stage_stepsizes(16);
        // eta shrinks ~1/sqrt(n), gamma grows ~sqrt(n); product constant
        assert!(e2 < e1);
        assert!(g2 > g1);
        assert!((e1 * g1 - e2 * g2).abs() < 1e-6);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 10, 100);
        assert!(cfg.validate(10).is_ok());
        assert!(cfg.validate(7).is_err()); // 100 % 7 != 0
        cfg.n0 = 0;
        assert!(cfg.validate(10).is_err());
        cfg.n0 = 11;
        assert!(cfg.validate(10).is_err());
        cfg.n0 = 2;
        cfg.solver = SolverKind::FedGatePartialRandom { k: 20 };
        assert!(cfg.validate(10).is_err());
        cfg.solver = SolverKind::Flanp;
        cfg.ewma_alpha = 0.0;
        assert!(cfg.validate(10).is_err());
        cfg.ewma_alpha = 0.25;
        cfg.system.p_drop = 1.0;
        assert!(cfg.validate(10).is_err());
    }

    #[test]
    fn scenario_configs_validate() {
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 10, 100);
        cfg.system =
            SystemModel::parse("drop:0.05:markov:4:0.1:0.5:uniform:50:500").unwrap();
        assert!(cfg.validate(10).is_ok());
        cfg.system = SystemModel::parse(
            "avail:diurnal:2000:0.5:1:drop:0.05:uniform:50:500",
        )
        .unwrap();
        assert!(cfg.validate(10).is_ok());
        // malformed availability models are rejected
        cfg.system.avail =
            Some(crate::fed::AvailabilityModel::Iid { p: 0.0 });
        assert!(cfg.validate(10).is_err());
    }

    #[test]
    fn trace_configs_validate_the_fleet_width() {
        use crate::fed::{TraceData, TraceMode, TraceReplay};
        let mut data = TraceData::empty(4);
        data.push_round(vec![10.0; 4], vec![true; 4]);
        let replay = TraceReplay::from_data("mem", data, TraceMode::Hold);
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 4, 100);
        cfg.system = SystemModel::from_trace(replay);
        assert!(cfg.validate(10).is_ok());
        // a 10-client experiment cannot replay a 4-client trace
        cfg.num_clients = 10;
        let e = cfg.validate(10).unwrap_err();
        assert!(e.contains("mem") && e.contains("4"), "{e}");
        // trace replay composes with nothing else
        cfg.num_clients = 4;
        cfg.system.p_drop = 0.1;
        assert!(cfg.validate(10).is_err());
    }

    #[test]
    fn solver_names_roundtrip() {
        for s in [
            "flanp",
            "flanp-heuristic",
            "fedgate",
            "fedavg",
            "fednova",
            "fedprox",
            "fedgate-rand5",
            "fedgate-fast8",
            "fedbuff4",
            "tifl",
            "ditto:0.5",
            "ditto:1",
        ] {
            assert_eq!(SolverKind::parse(s).unwrap().name(), s);
        }
        assert!(SolverKind::parse("sgd").is_err());
        assert!(SolverKind::parse("fedbuff").is_err(), "buffer size required");
        // bare ditto defaults its personalization strength
        assert_eq!(
            SolverKind::parse("ditto").unwrap(),
            SolverKind::Ditto { lambda: 1.0 }
        );
        assert!(SolverKind::parse("ditto:x").is_err());
    }

    #[test]
    fn data_configs_validate_per_model() {
        let mut cfg =
            ExperimentConfig::new(SolverKind::FedAvg, "logreg_d16_c4", 10, 100);
        cfg.data = DataSpec::parse("data:dirichlet:0.1:shift:3:corr:speed")
            .unwrap();
        assert!(cfg.validate(50).is_ok());
        // dirichlet label skew is a classification notion in the eager path
        cfg.model = "linreg_d25".into();
        assert!(cfg.validate(50).is_err());
        // covariate shift alone is model-agnostic
        cfg.data = DataSpec::parse("data:shift:2").unwrap();
        assert!(cfg.validate(50).is_ok());
        // held-out reservation needs s >= 2 x batch on classifiers
        cfg.model = "logreg_d16_c4".into();
        cfg.s = 50;
        assert!(cfg.validate(50).is_err());
        cfg.s = 100;
        assert!(cfg.validate(50).is_ok());
        // the explicit IID spelling stays valid everywhere
        cfg.data = DataSpec::iid();
        cfg.s = 50;
        assert!(cfg.validate(50).is_ok());
    }

    #[test]
    fn ditto_configs_validate() {
        let mut cfg = ExperimentConfig::new(
            SolverKind::Ditto { lambda: 1.0 },
            "logreg_d16_c4",
            10,
            100,
        );
        assert!(cfg.validate(50).is_ok());
        assert!(cfg.client_eval_enabled());
        // ditto is a synchronous cohort solver: deadlines apply
        cfg.deadline = DeadlinePolicy::Quantile { q: 0.8 };
        assert!(cfg.validate(50).is_ok());
        // ...but it has no adaptive prefix: selection knobs reject
        cfg.deadline = DeadlinePolicy::Sync;
        cfg.overselect = 1.3;
        assert!(cfg.validate(50).is_err());
        cfg.overselect = 1.0;
        cfg.tiers = Some(TierPolicy::new(4));
        assert!(cfg.validate(50).is_err());
        cfg.tiers = None;
        cfg.solver = SolverKind::Ditto { lambda: 0.0 };
        assert!(cfg.validate(50).is_err());
        cfg.solver = SolverKind::Ditto { lambda: f64::NAN };
        assert!(cfg.validate(50).is_err());
        // non-IID fedavg on a classifier also turns per-client eval on;
        // plain IID fedavg does not
        cfg.solver = SolverKind::FedAvg;
        assert!(!cfg.client_eval_enabled());
        cfg.data = DataSpec::parse("data:dirichlet:0.5").unwrap();
        assert!(cfg.client_eval_enabled());
    }

    #[test]
    fn deadline_policies_validate_per_solver() {
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 10, 100);
        cfg.deadline = DeadlinePolicy::Quantile { q: 0.8 };
        assert!(cfg.validate(10).is_ok());
        cfg.solver = SolverKind::FedGate;
        assert!(cfg.validate(10).is_ok());
        // every synchronous cohort solver takes a deadline now (PR 3's
        // ROADMAP follow-on routed FedAvg/FedProx/FedNova through the
        // shared deadline_round step)...
        for solver in
            [SolverKind::FedAvg, SolverKind::FedProx, SolverKind::FedNova]
        {
            cfg.solver = solver;
            assert!(cfg.validate(10).is_ok());
        }
        // ...while the async and oracle-selection baselines still reject
        cfg.solver = SolverKind::FedBuff { k: 4 };
        assert!(cfg.validate(10).is_err());
        cfg.solver = SolverKind::FedGatePartialRandom { k: 3 };
        assert!(cfg.validate(10).is_err());
        cfg.deadline = DeadlinePolicy::Sync;
        cfg.solver = SolverKind::FedAvg;
        assert!(cfg.validate(10).is_ok());
        // malformed policies are rejected regardless of solver
        cfg.solver = SolverKind::Flanp;
        cfg.deadline = DeadlinePolicy::Quantile { q: 1.5 };
        assert!(cfg.validate(10).is_err());
        // fedbuff buffer size is bounded by the fleet
        cfg.deadline = DeadlinePolicy::Sync;
        cfg.solver = SolverKind::FedBuff { k: 0 };
        assert!(cfg.validate(10).is_err());
        cfg.solver = SolverKind::FedBuff { k: 11 };
        assert!(cfg.validate(10).is_err());
        cfg.solver = SolverKind::FedBuff { k: 5 };
        assert!(cfg.validate(10).is_ok());
        // tifl is a synchronous cohort solver: deadlines apply
        cfg.solver = SolverKind::Tifl;
        cfg.tiers = Some(TierPolicy::new(4));
        cfg.deadline = DeadlinePolicy::Quantile { q: 0.8 };
        assert!(cfg.validate(10).is_ok());
    }

    #[test]
    fn selection_configs_validate_per_solver() {
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 10, 100);
        cfg.overselect = 1.3;
        assert!(cfg.validate(10).is_ok());
        cfg.forecast = Some(ForecastPolicy::parse("ewma:0.3").unwrap());
        assert!(cfg.validate(10).is_ok());
        // over-selection needs selection freedom: the full-participation
        // and oracle-selection baselines reject it
        for solver in [
            SolverKind::FedGate,
            SolverKind::FedAvg,
            SolverKind::FedGatePartialRandom { k: 3 },
            SolverKind::FedBuff { k: 3 },
        ] {
            cfg.solver = solver;
            assert!(cfg.validate(10).is_err());
        }
        // tifl over-selects its scheduled tier
        cfg.solver = SolverKind::Tifl;
        cfg.tiers = Some(TierPolicy::new(4));
        assert!(cfg.validate(10).is_ok());
        // out-of-range factors and malformed policies are rejected
        cfg.overselect = 0.5;
        assert!(cfg.validate(10).is_err());
        cfg.overselect = f64::INFINITY;
        assert!(cfg.validate(10).is_err());
        cfg.overselect = 1.0;
        cfg.forecast = Some(ForecastPolicy::Ewma { alpha: 0.0 });
        assert!(cfg.validate(10).is_err());
        // defaults are off and validate everywhere
        cfg.forecast = None;
        cfg.solver = SolverKind::FedGate;
        cfg.tiers = None;
        assert!(cfg.validate(10).is_ok());
    }

    #[test]
    fn tier_configs_validate_per_solver() {
        let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "m", 10, 100);
        cfg.tiers = Some(TierPolicy::parse("tiers:4:hysteresis:2").unwrap());
        assert!(cfg.validate(10).is_ok());
        // tifl requires a tier policy...
        cfg.solver = SolverKind::Tifl;
        assert!(cfg.validate(10).is_ok());
        cfg.tiers = None;
        assert!(cfg.validate(10).is_err());
        // ...and tiering is meaningless for the non-adaptive benchmarks
        cfg.solver = SolverKind::FedGate;
        cfg.tiers = Some(TierPolicy::new(4));
        assert!(cfg.validate(10).is_err());
        // tiering ranks from estimates: oracle ranking conflicts
        cfg.solver = SolverKind::Flanp;
        cfg.estimate_speeds = false;
        assert!(cfg.validate(10).is_err());
        cfg.estimate_speeds = true;
        assert!(cfg.validate(10).is_ok());
        // tier caching and per-round re-ranking are exclusive cadences
        cfg.rerank_per_round = true;
        assert!(cfg.validate(10).is_err());
        cfg.tiers = None;
        assert!(cfg.validate(10).is_ok());
        // per-round re-ranking needs estimates too
        cfg.estimate_speeds = false;
        assert!(cfg.validate(10).is_err());
        // ...and only the FLANP stage machine has a prefix to re-rank
        cfg.estimate_speeds = true;
        cfg.solver = SolverKind::FedGate;
        assert!(cfg.validate(10).is_err());
        cfg.solver = SolverKind::Flanp;
        // malformed tier policies are rejected regardless of solver
        cfg.estimate_speeds = true;
        cfg.rerank_per_round = false;
        cfg.tiers = Some(TierPolicy { tiers: 0, ..TierPolicy::new(4) });
        assert!(cfg.validate(10).is_err());
        cfg.tiers = Some(TierPolicy { hysteresis: 0.9, ..TierPolicy::new(4) });
        assert!(cfg.validate(10).is_err());
    }
}
