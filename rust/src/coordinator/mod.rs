//! Layer-3 coordinator: the paper's system contribution.
//!
//! * [`flanp`] — Algorithm 1/2: the adaptive-node-participation
//!   meta-algorithm (stage machine, doubling, warm starts, statistical-
//!   accuracy stopping) instantiated with the FedGATE subroutine.
//! * [`gate`] — the FedGATE round engine (gradient tracking, two-stepsize
//!   server update) shared by FLANP stages and the benchmarks.
//! * [`solvers`] — the benchmark algorithms: FedGATE, FedAvg, FedNova,
//!   FedProx, and partial-participation FedGATE (random-k / fastest-k).
//! * [`stopping`] — statistical-accuracy criteria (`||grad||^2 <=
//!   2 mu V_ns` with `V_ns = c/(ns)`) and the Figure-9 heuristic
//!   threshold-halving rule.
//! * [`config`] / [`eval`] — experiment configuration and the shared
//!   full-objective evaluator.

pub mod config;
pub mod eval;
pub mod flanp;
pub mod gate;
pub mod solvers;
pub mod stopping;
pub mod theory;

pub use config::{ExperimentConfig, SolverKind, StepsizeSchedule};
pub use eval::EvalData;
pub use flanp::{run_flanp, run_flanp_with};
pub use solvers::{run_solver, run_solver_with};
