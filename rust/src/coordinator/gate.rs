//! The FedGATE round engine (Algorithm 2's inner loop).
//!
//! One communication round over an arbitrary active client set:
//!   1. every active client i starts from the global model w and performs
//!      tau corrected local steps  w_i <- w_i - eta * (grad_i - delta_i);
//!   2. uploads Delta_i = (w - w_i^tau) / eta;
//!   3. the server averages Delta = mean_i Delta_i, updates the tracking
//!      variables delta_i += (Delta_i - Delta) / tau, and takes the
//!      two-stepsize step  w <- w - eta * gamma * Delta.
//!
//! The same primitives serve FLANP stages, benchmark FedGATE and the
//! partial-participation variants; FedAvg/FedNova/FedProx reuse the
//! local-round helper with their own aggregation (solvers.rs).

use crate::engine::{full_loss_grad, Engine};
use crate::fed::{ClientFleet, Phase, Span};
use crate::util::{linalg, par};
use anyhow::Result;

/// Mutable algorithm state carried across rounds and stages.
pub struct GateState {
    /// global model (flat f32[P])
    pub w: Vec<f32>,
    /// gradient-tracking variable per client id
    pub deltas: Vec<Vec<f32>>,
}

impl GateState {
    pub fn new(w0: Vec<f32>, num_clients: usize) -> Self {
        let p = w0.len();
        GateState { w: w0, deltas: vec![vec![0.0; p]; num_clients] }
    }

    /// Zero all tracking variables (done at every FLANP stage start).
    pub fn reset_tracking(&mut self) {
        for d in &mut self.deltas {
            d.fill(0.0);
        }
    }
}

/// Reusable batch buffers so the round loop does not allocate.
pub struct RoundBuffers {
    pub xs: Vec<f32>,
    pub ys: Vec<f32>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
}

impl RoundBuffers {
    pub fn new(engine: &dyn Engine, tau: usize) -> Self {
        let m = engine.meta();
        RoundBuffers {
            xs: vec![0.0; tau * m.batch * m.d],
            ys: vec![0.0; tau * m.batch * m.y_width()],
            x: vec![0.0; m.batch * m.d],
            y: vec![0.0; m.batch * m.y_width()],
        }
    }
}

/// tau corrected local steps for one client, starting from `w`.
/// Uses the fused round artifact when tau matches the artifact's tau,
/// otherwise falls back to per-step execution.
pub fn local_round(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    client: usize,
    w: &[f32],
    delta: &[f32],
    tau: usize,
    eta: f32,
    bufs: &mut RoundBuffers,
) -> Result<Vec<f32>> {
    let m = engine.meta();
    if tau == m.tau {
        fleet.fill_round_batches(client, tau, m.batch, &mut bufs.xs, &mut bufs.ys);
        return engine.gate_round(w, delta, &bufs.xs, &bufs.ys, eta);
    }
    let mut wi = w.to_vec();
    for _ in 0..tau {
        fleet.fill_minibatch(client, m.batch, &mut bufs.x, &mut bufs.y);
        wi = engine.gate_step(&wi, delta, &bufs.x, &bufs.y, eta)?;
    }
    Ok(wi)
}

/// What each client's tau local steps compute — the per-solver variation
/// of the one shared round shape (tau corrected steps from `w`):
/// FedGATE's tracking correction, plain local SGD (FedAvg, FedNova,
/// FLANP-Avg), or FedProx's proximal pull towards the round anchor `w`.
pub(crate) enum LocalSpec<'a> {
    /// FedGATE: per-CLIENT-ID tracking variables (indexed by client id,
    /// not by position in `active`).
    Gate(&'a [Vec<f32>]),
    /// Local SGD: a shared zero tracking variable.
    Sgd(&'a [f32]),
    /// FedProx: `grad + mu * (w_i - w)` steps anchored at the round's
    /// starting model.
    Prox { mu: f32 },
}

/// Per-client local-step counts: uniform (every synchronous solver) or
/// per client id (FedNova's window-sized tau_i).
#[derive(Clone, Copy)]
pub(crate) enum TauSpec<'a> {
    Uniform(usize),
    PerClient(&'a [usize]),
}

impl TauSpec<'_> {
    fn of(&self, i: usize) -> usize {
        match self {
            TauSpec::Uniform(t) => *t,
            TauSpec::PerClient(ts) => ts[i],
        }
    }
}

/// One client's local round under `spec` — the serial fallback used when
/// the pre-sampled fan-out path is unavailable. The FedProx per-step
/// fallback exists for engines whose fused round artifact is pinned to
/// `meta().tau` (HLO); tau-flexible engines take the fused path, which
/// evaluates the identical per-step expression.
#[allow(clippy::too_many_arguments)]
fn local_round_spec(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    i: usize,
    w: &[f32],
    spec: &LocalSpec,
    tau: usize,
    eta: f32,
    bufs: &mut RoundBuffers,
) -> Result<Vec<f32>> {
    let m = engine.meta();
    match spec {
        LocalSpec::Gate(deltas) => {
            local_round(engine, fleet, i, w, &deltas[i], tau, eta, bufs)
        }
        LocalSpec::Sgd(zero) => local_round(engine, fleet, i, w, zero, tau, eta, bufs),
        LocalSpec::Prox { mu } => {
            if tau == m.tau || engine.round_tau_flexible() {
                fleet.fill_round_batches(i, tau, m.batch, &mut bufs.xs, &mut bufs.ys);
                engine.prox_round(w, w, &bufs.xs, &bufs.ys, eta, *mu)
            } else {
                // per-step fallback: prox gradient = grad + mu*(w_i - w)
                let mut wi = w.to_vec();
                for _ in 0..tau {
                    fleet.fill_minibatch(i, m.batch, &mut bufs.x, &mut bufs.y);
                    let (_, mut g) = engine.loss_grad(&wi, &bufs.x, &bufs.y)?;
                    for k in 0..w.len() {
                        g[k] += mu * (wi[k] - w[k]);
                    }
                    linalg::axpy(-eta, &g, &mut wi);
                }
                Ok(wi)
            }
        }
    }
}

/// Local rounds for every active client, fanned out across cores when
/// the engine is thread-safe ([`Engine::as_sync`]) and the per-worker
/// chunk clears the [`par::min_chunk_for_work`] threshold (tiny models
/// stay serial rather than paying thread-spawn cost); identical results
/// to the serial path (same per-client RNG streams — batches are
/// pre-sampled serially in `active` order — and the same per-client
/// stepping). This is THE shared fan-out for every synchronous cohort
/// solver: FedGATE ([`fedgate_round`]), FedAvg/FedProx/FedNova
/// (solvers.rs) and FLANP's Avg subroutine (flanp.rs).
#[allow(clippy::too_many_arguments)]
pub(crate) fn local_rounds(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    active: &[usize],
    w: &[f32],
    spec: LocalSpec,
    taus: TauSpec,
    eta: f32,
    bufs: &mut RoundBuffers,
) -> Result<Vec<Vec<f32>>> {
    let m = engine.meta();
    // the fused-batch paths need either a tau-flexible engine or taus
    // matching the compiled round artifact
    let fused_ok = engine.round_tau_flexible()
        || active.iter().all(|&i| taus.of(i) == m.tau);
    if active.len() < 2 || !fused_ok {
        let _sp = Span::enter(Phase::Kernels);
        return active
            .iter()
            .map(|&i| {
                local_round_spec(engine, fleet, i, w, &spec, taus.of(i), eta, bufs)
            })
            .collect();
    }
    // phase 1 (serial): sample every client's tau_i batches. Per-client
    // offsets (not a uniform stride) so FedNova's heterogeneous taus
    // pack densely.
    let n = active.len();
    let mut xoff = Vec::with_capacity(n + 1);
    let mut yoff = Vec::with_capacity(n + 1);
    let (mut xo, mut yo) = (0usize, 0usize);
    for &i in active {
        xoff.push(xo);
        yoff.push(yo);
        xo += taus.of(i) * m.batch * m.d;
        yo += taus.of(i) * m.batch * m.y_width();
    }
    xoff.push(xo);
    yoff.push(yo);
    let mut all_xs = vec![0.0f32; xo];
    let mut all_ys = vec![0.0f32; yo];
    for (k, &i) in active.iter().enumerate() {
        fleet.fill_round_batches(
            i,
            taus.of(i),
            m.batch,
            &mut all_xs[xoff[k]..xoff[k + 1]],
            &mut all_ys[yoff[k]..yoff[k + 1]],
        );
    }
    // phase 2: the clients' local compute — parallel across cores when
    // the engine is Sync and each worker amortizes its spawn cost, else
    // a single batch call that shares the per-round literals (HLO path,
    // §Perf). The `kernels` span isolates this engine-bound share from
    // the host-side LocalRounds phase that wraps the whole fan-out.
    let _sp = Span::enter(Phase::Kernels);
    match engine.as_sync().filter(|e| e.round_tau_flexible()) {
        Some(es) => {
            let avg_tau = active.iter().map(|&i| taus.of(i)).sum::<usize>() / n;
            let min_chunk =
                par::min_chunk_for_work(6 * avg_tau * m.batch * m.param_count);
            par::par_map_min_chunk(n, min_chunk, |k| {
                let i = active[k];
                let xs = &all_xs[xoff[k]..xoff[k + 1]];
                let ys = &all_ys[yoff[k]..yoff[k + 1]];
                match &spec {
                    LocalSpec::Gate(deltas) => es.gate_round(w, &deltas[i], xs, ys, eta),
                    LocalSpec::Sgd(zero) => es.gate_round(w, zero, xs, ys, eta),
                    LocalSpec::Prox { mu } => es.prox_round(w, w, xs, ys, eta, *mu),
                }
            })
            .into_iter()
            .collect()
        }
        None => {
            // non-Sync engines are also non-flexible today, so fused_ok
            // guarantees uniform taus == m.tau here; keep the per-slice
            // loop as the safe fallback should that invariant relax
            let uniform = active.iter().all(|&i| taus.of(i) == taus.of(active[0]));
            match &spec {
                LocalSpec::Gate(deltas) if uniform => {
                    let drefs: Vec<&[f32]> =
                        active.iter().map(|&i| deltas[i].as_slice()).collect();
                    engine.gate_rounds_batch(w, &drefs, &all_xs, &all_ys, eta)
                }
                LocalSpec::Sgd(zero) if uniform => {
                    let drefs: Vec<&[f32]> = active.iter().map(|_| *zero).collect();
                    engine.gate_rounds_batch(w, &drefs, &all_xs, &all_ys, eta)
                }
                _ => (0..n)
                    .map(|k| {
                        let i = active[k];
                        let xs = &all_xs[xoff[k]..xoff[k + 1]];
                        let ys = &all_ys[yoff[k]..yoff[k + 1]];
                        match &spec {
                            LocalSpec::Gate(deltas) => {
                                engine.gate_round(w, &deltas[i], xs, ys, eta)
                            }
                            LocalSpec::Sgd(zero) => {
                                engine.gate_round(w, zero, xs, ys, eta)
                            }
                            LocalSpec::Prox { mu } => {
                                engine.prox_round(w, w, xs, ys, eta, *mu)
                            }
                        }
                    })
                    .collect(),
            }
        }
    }
}

/// One full FedGATE communication round over `active` clients.
/// Mutates `state` (global model + tracking variables).
pub fn fedgate_round(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    state: &mut GateState,
    active: &[usize],
    tau: usize,
    eta: f32,
    gamma: f32,
    bufs: &mut RoundBuffers,
) -> Result<()> {
    let p = state.w.len();
    let n = active.len();
    assert!(n > 0, "empty active set");

    // local work + Delta_i accumulation
    let wis = local_rounds(
        engine,
        fleet,
        active,
        &state.w,
        LocalSpec::Gate(&state.deltas),
        TauSpec::Uniform(tau),
        eta,
        bufs,
    )?;
    let mut delta_sum = vec![0.0f64; p];
    let mut delta_is: Vec<Vec<f32>> = Vec::with_capacity(n);
    let inv = 1.0 / eta;
    for wi in &wis {
        // Delta_i = (w - w_i^tau) / eta
        let di: Vec<f32> = state
            .w
            .iter()
            .zip(wi)
            .map(|(a, b)| (a - b) * inv)
            .collect();
        linalg::accumulate(&mut delta_sum, &di);
        delta_is.push(di);
    }
    let delta_avg = linalg::mean_of(&delta_sum, n);

    // tracking update: delta_i += (Delta_i - Delta) / tau
    let inv_tau = 1.0 / tau as f32;
    for (&i, di) in active.iter().zip(&delta_is) {
        let tr = &mut state.deltas[i];
        for k in 0..p {
            tr[k] += (di[k] - delta_avg[k]) * inv_tau;
        }
    }

    // server update: w <- w - eta * gamma * Delta
    linalg::axpy(-(eta * gamma), &delta_avg, &mut state.w);
    Ok(())
}

/// Exact objective over the active set: mean of full local (loss, grad);
/// returns (loss, ||grad||^2) — the stopping-rule inputs (the "clients
/// upload grad L_i(w_n)" step of Algorithm 2).
pub fn active_loss_gradsq(
    engine: &dyn Engine,
    fleet: &ClientFleet,
    active: &[usize],
    w: &[f32],
) -> Result<(f64, f64)> {
    let p = w.len();
    // per-client exact gradients, fanned out when the engine is Sync
    // and a worker's chunk of full-shard passes clears the min-work
    // threshold (one pass ≈ 6 * shard_rows * P flop)
    let avg_s = active.iter().map(|&i| fleet.shards[i].s()).sum::<usize>()
        / active.len().max(1);
    let min_chunk =
        par::min_chunk_for_work(6 * avg_s * engine.meta().param_count);
    let _sp = Span::enter(Phase::Kernels);
    let locals: Vec<(f64, Vec<f32>)> = match engine.as_sync() {
        Some(es) if active.len() >= 2 => {
            par::par_map_min_chunk(active.len(), min_chunk, |k| {
                full_loss_grad(es, fleet, active[k], w)
            })
            .into_iter()
            .collect::<Result<_>>()?
        }
        _ => active
            .iter()
            .map(|&i| full_loss_grad(engine, fleet, i, w))
            .collect::<Result<_>>()?,
    };
    let mut grad_acc = vec![0.0f64; p];
    let mut loss_acc = 0.0f64;
    for (li, gi) in &locals {
        loss_acc += li;
        linalg::accumulate(&mut grad_acc, gi);
    }
    let n = active.len() as f64;
    let gsq: f64 = grad_acc.iter().map(|g| (g / n) * (g / n)).sum();
    Ok((loss_acc / n, gsq))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard, synth};
    use crate::engine::NativeEngine;
    use crate::fed::SpeedModel;
    use crate::util::Rng;

    fn setup() -> (NativeEngine, ClientFleet) {
        let mut rng = Rng::new(11);
        let (ds, _) = synth::linreg(&mut rng, 400, 5, 0.05);
        let shards = shard::partition_iid(&mut rng, &ds, 8);
        let fleet = ClientFleet::new(
            ds,
            shards,
            &SpeedModel::paper_uniform().into(),
            &mut rng,
        );
        (NativeEngine::linreg(5, 10, 3), fleet)
    }

    #[test]
    fn fedgate_rounds_descend_to_low_gradient() {
        let (e, mut fleet) = setup();
        let active: Vec<usize> = (0..8).collect();
        let mut state = GateState::new(vec![0.0; 6], 8);
        let mut bufs = RoundBuffers::new(&e, 3);
        let (_, g0) = active_loss_gradsq(&e, &fleet, &active, &state.w).unwrap();
        for _ in 0..60 {
            fedgate_round(&e, &mut fleet, &mut state, &active, 3, 0.05, 1.0, &mut bufs)
                .unwrap();
        }
        let (_, g1) = active_loss_gradsq(&e, &fleet, &active, &state.w).unwrap();
        assert!(g1 < g0 * 1e-2, "grad^2 {g0} -> {g1}");
    }

    #[test]
    fn tracking_variables_sum_stays_near_zero() {
        // sum_i delta_i starts at 0 and the update adds (Delta_i - mean)
        // which sums to 0 over the active set => invariant preserved
        let (e, mut fleet) = setup();
        let active: Vec<usize> = (0..8).collect();
        let mut state = GateState::new(vec![0.1; 6], 8);
        let mut bufs = RoundBuffers::new(&e, 3);
        for _ in 0..5 {
            fedgate_round(&e, &mut fleet, &mut state, &active, 3, 0.05, 1.0, &mut bufs)
                .unwrap();
        }
        for k in 0..6 {
            let s: f64 = state.deltas.iter().map(|d| d[k] as f64).sum();
            assert!(s.abs() < 1e-4, "sum delta[{k}] = {s}");
        }
    }

    #[test]
    fn local_round_fallback_matches_fused_tau() {
        // engine tau = 3; calling with tau = 3 uses the fused path while
        // tau = 2 uses the fallback — both must advance the model
        let (e, mut fleet) = setup();
        let mut bufs = RoundBuffers::new(&e, 3);
        let w = vec![0.0f32; 6];
        let delta = vec![0.0f32; 6];
        let fused = local_round(&e, &mut fleet, 0, &w, &delta, 3, 0.05, &mut bufs).unwrap();
        let stepped = local_round(&e, &mut fleet, 0, &w, &delta, 2, 0.05, &mut bufs).unwrap();
        assert_ne!(fused, w);
        assert_ne!(stepped, w);
    }

    #[test]
    fn subset_round_only_touches_subset_tracking() {
        let (e, mut fleet) = setup();
        let mut state = GateState::new(vec![0.2; 6], 8);
        let mut bufs = RoundBuffers::new(&e, 3);
        fedgate_round(&e, &mut fleet, &mut state, &[1, 3], 3, 0.05, 1.0, &mut bufs)
            .unwrap();
        for (i, d) in state.deltas.iter().enumerate() {
            let touched = i == 1 || i == 3;
            let nonzero = d.iter().any(|&v| v != 0.0);
            assert_eq!(nonzero, touched, "client {i}");
        }
    }

    #[test]
    fn local_rounds_sgd_matches_serial_local_round_loop() {
        // the fan-out helper must be indistinguishable from the old
        // per-client loop: same RNG streams, same stepping, bit-equal
        let (e, mut fleet) = setup();
        let (e2, mut fleet2) = setup();
        let active: Vec<usize> = (0..8).collect();
        let w = vec![0.05f32; 6];
        let zero = vec![0.0f32; 6];
        let mut bufs = RoundBuffers::new(&e, 3);
        let mut bufs2 = RoundBuffers::new(&e2, 3);
        let fanned = local_rounds(
            &e,
            &mut fleet,
            &active,
            &w,
            LocalSpec::Sgd(&zero),
            TauSpec::Uniform(3),
            0.05,
            &mut bufs,
        )
        .unwrap();
        let serial: Vec<Vec<f32>> = active
            .iter()
            .map(|&i| {
                local_round(&e2, &mut fleet2, i, &w, &zero, 3, 0.05, &mut bufs2)
                    .unwrap()
            })
            .collect();
        assert_eq!(fanned, serial);
    }

    #[test]
    fn local_rounds_prox_matches_per_step_reference() {
        let (e, mut fleet) = setup();
        let (e2, mut fleet2) = setup();
        let active = vec![0usize, 1, 2];
        let w = vec![0.1f32; 6];
        let mut bufs = RoundBuffers::new(&e, 3);
        let fused = local_rounds(
            &e,
            &mut fleet,
            &active,
            &w,
            LocalSpec::Prox { mu: 0.3 },
            TauSpec::Uniform(3),
            0.05,
            &mut bufs,
        )
        .unwrap();
        // explicit per-step reference: g += mu*(w_i - w); w_i -= eta*g
        let mut x = vec![0.0f32; 10 * 5];
        let mut y = vec![0.0f32; 10];
        for (k, &i) in active.iter().enumerate() {
            let mut wi = w.clone();
            for _ in 0..3 {
                fleet2.fill_minibatch(i, 10, &mut x, &mut y);
                let (_, mut g) = e2.loss_grad(&wi, &x, &y).unwrap();
                for j in 0..6 {
                    g[j] += 0.3 * (wi[j] - w[j]);
                }
                linalg::axpy(-0.05, &g, &mut wi);
            }
            assert_eq!(fused[k], wi, "client {i}");
        }
    }

    #[test]
    fn local_rounds_per_client_taus_match_serial() {
        let (e, mut fleet) = setup();
        let (e2, mut fleet2) = setup();
        let active = vec![0usize, 2, 5];
        // taus indexed by CLIENT ID (FedNova convention)
        let taus = vec![2usize, 9, 4, 9, 9, 6, 9, 9];
        let w = vec![0.02f32; 6];
        let zero = vec![0.0f32; 6];
        let mut bufs = RoundBuffers::new(&e, 3);
        let mut bufs2 = RoundBuffers::new(&e2, 3);
        let fanned = local_rounds(
            &e,
            &mut fleet,
            &active,
            &w,
            LocalSpec::Sgd(&zero),
            TauSpec::PerClient(&taus),
            0.05,
            &mut bufs,
        )
        .unwrap();
        let serial: Vec<Vec<f32>> = active
            .iter()
            .map(|&i| {
                local_round(&e2, &mut fleet2, i, &w, &zero, taus[i], 0.05, &mut bufs2)
                    .unwrap()
            })
            .collect();
        assert_eq!(fanned, serial);
    }

    #[test]
    fn reset_tracking_zeroes() {
        let (e, mut fleet) = setup();
        let mut state = GateState::new(vec![0.2; 6], 8);
        let mut bufs = RoundBuffers::new(&e, 3);
        fedgate_round(&e, &mut fleet, &mut state, &[0, 1], 3, 0.05, 1.0, &mut bufs)
            .unwrap();
        state.reset_tracking();
        assert!(state.deltas.iter().all(|d| d.iter().all(|&v| v == 0.0)));
    }
}
