//! The FedGATE round engine (Algorithm 2's inner loop).
//!
//! One communication round over an arbitrary active client set:
//!   1. every active client i starts from the global model w and performs
//!      tau corrected local steps  w_i <- w_i - eta * (grad_i - delta_i);
//!   2. uploads Delta_i = (w - w_i^tau) / eta;
//!   3. the server averages Delta = mean_i Delta_i, updates the tracking
//!      variables delta_i += (Delta_i - Delta) / tau, and takes the
//!      two-stepsize step  w <- w - eta * gamma * Delta.
//!
//! The same primitives serve FLANP stages, benchmark FedGATE and the
//! partial-participation variants; FedAvg/FedNova/FedProx reuse the
//! local-round helper with their own aggregation (solvers.rs).

use crate::engine::{full_loss_grad, Engine};
use crate::fed::ClientFleet;
use crate::util::linalg;
use anyhow::Result;

/// Mutable algorithm state carried across rounds and stages.
pub struct GateState {
    /// global model (flat f32[P])
    pub w: Vec<f32>,
    /// gradient-tracking variable per client id
    pub deltas: Vec<Vec<f32>>,
}

impl GateState {
    pub fn new(w0: Vec<f32>, num_clients: usize) -> Self {
        let p = w0.len();
        GateState { w: w0, deltas: vec![vec![0.0; p]; num_clients] }
    }

    /// Zero all tracking variables (done at every FLANP stage start).
    pub fn reset_tracking(&mut self) {
        for d in &mut self.deltas {
            d.fill(0.0);
        }
    }
}

/// Reusable batch buffers so the round loop does not allocate.
pub struct RoundBuffers {
    pub xs: Vec<f32>,
    pub ys: Vec<f32>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
}

impl RoundBuffers {
    pub fn new(engine: &dyn Engine, tau: usize) -> Self {
        let m = engine.meta();
        RoundBuffers {
            xs: vec![0.0; tau * m.batch * m.d],
            ys: vec![0.0; tau * m.batch * m.y_width()],
            x: vec![0.0; m.batch * m.d],
            y: vec![0.0; m.batch * m.y_width()],
        }
    }
}

/// tau corrected local steps for one client, starting from `w`.
/// Uses the fused round artifact when tau matches the artifact's tau,
/// otherwise falls back to per-step execution.
pub fn local_round(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    client: usize,
    w: &[f32],
    delta: &[f32],
    tau: usize,
    eta: f32,
    bufs: &mut RoundBuffers,
) -> Result<Vec<f32>> {
    let m = engine.meta();
    if tau == m.tau {
        fleet.fill_round_batches(client, tau, m.batch, &mut bufs.xs, &mut bufs.ys);
        return engine.gate_round(w, delta, &bufs.xs, &bufs.ys, eta);
    }
    let mut wi = w.to_vec();
    for _ in 0..tau {
        fleet.fill_minibatch(client, m.batch, &mut bufs.x, &mut bufs.y);
        wi = engine.gate_step(&wi, delta, &bufs.x, &bufs.y, eta)?;
    }
    Ok(wi)
}

/// Local rounds for every active client, fanned out across cores when
/// the engine is thread-safe ([`Engine::as_sync`]); identical results to
/// the serial path (same per-client RNG streams, same reduction order).
fn local_rounds_all(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    active: &[usize],
    w: &[f32],
    deltas: &[Vec<f32>],
    tau: usize,
    eta: f32,
    bufs: &mut RoundBuffers,
) -> Result<Vec<Vec<f32>>> {
    let m = engine.meta();
    // the fused-batch paths need either a tau-flexible engine or a tau
    // matching the compiled round artifact
    if active.len() < 2 || (tau != m.tau && !engine.round_tau_flexible()) {
        return active
            .iter()
            .map(|&i| local_round(engine, fleet, i, w, &deltas[i], tau, eta, bufs))
            .collect();
    }
    // phase 1 (serial): sample every client's tau batches
    let xstride = tau * m.batch * m.d;
    let ystride = tau * m.batch * m.y_width();
    let mut all_xs = vec![0.0f32; active.len() * xstride];
    let mut all_ys = vec![0.0f32; active.len() * ystride];
    for (k, &i) in active.iter().enumerate() {
        fleet.fill_round_batches(
            i,
            tau,
            m.batch,
            &mut all_xs[k * xstride..(k + 1) * xstride],
            &mut all_ys[k * ystride..(k + 1) * ystride],
        );
    }
    // phase 2: the clients' local compute — parallel across cores when
    // the engine is Sync, else a single batch call that shares the
    // per-round literals (HLO path, §Perf)
    match engine.as_sync().filter(|e| e.round_tau_flexible()) {
        Some(es) => crate::util::par::par_map(active.len(), |k| {
            let i = active[k];
            es.gate_round(
                w,
                &deltas[i],
                &all_xs[k * xstride..(k + 1) * xstride],
                &all_ys[k * ystride..(k + 1) * ystride],
                eta,
            )
        })
        .into_iter()
        .collect(),
        None => {
            let drefs: Vec<&[f32]> =
                active.iter().map(|&i| deltas[i].as_slice()).collect();
            engine.gate_rounds_batch(w, &drefs, &all_xs, &all_ys, eta)
        }
    }
}

/// One full FedGATE communication round over `active` clients.
/// Mutates `state` (global model + tracking variables).
pub fn fedgate_round(
    engine: &dyn Engine,
    fleet: &mut ClientFleet,
    state: &mut GateState,
    active: &[usize],
    tau: usize,
    eta: f32,
    gamma: f32,
    bufs: &mut RoundBuffers,
) -> Result<()> {
    let p = state.w.len();
    let n = active.len();
    assert!(n > 0, "empty active set");

    // local work + Delta_i accumulation
    let wis = local_rounds_all(
        engine, fleet, active, &state.w, &state.deltas, tau, eta, bufs,
    )?;
    let mut delta_sum = vec![0.0f64; p];
    let mut delta_is: Vec<Vec<f32>> = Vec::with_capacity(n);
    let inv = 1.0 / eta;
    for wi in &wis {
        // Delta_i = (w - w_i^tau) / eta
        let di: Vec<f32> = state
            .w
            .iter()
            .zip(wi)
            .map(|(a, b)| (a - b) * inv)
            .collect();
        linalg::accumulate(&mut delta_sum, &di);
        delta_is.push(di);
    }
    let delta_avg = linalg::mean_of(&delta_sum, n);

    // tracking update: delta_i += (Delta_i - Delta) / tau
    let inv_tau = 1.0 / tau as f32;
    for (&i, di) in active.iter().zip(&delta_is) {
        let tr = &mut state.deltas[i];
        for k in 0..p {
            tr[k] += (di[k] - delta_avg[k]) * inv_tau;
        }
    }

    // server update: w <- w - eta * gamma * Delta
    linalg::axpy(-(eta * gamma), &delta_avg, &mut state.w);
    Ok(())
}

/// Exact objective over the active set: mean of full local (loss, grad);
/// returns (loss, ||grad||^2) — the stopping-rule inputs (the "clients
/// upload grad L_i(w_n)" step of Algorithm 2).
pub fn active_loss_gradsq(
    engine: &dyn Engine,
    fleet: &ClientFleet,
    active: &[usize],
    w: &[f32],
) -> Result<(f64, f64)> {
    let p = w.len();
    // per-client exact gradients, fanned out when the engine is Sync
    let locals: Vec<(f64, Vec<f32>)> = match engine.as_sync() {
        Some(es) if active.len() >= 2 => {
            crate::util::par::par_map(active.len(), |k| {
                full_loss_grad(es, fleet, active[k], w)
            })
            .into_iter()
            .collect::<Result<_>>()?
        }
        _ => active
            .iter()
            .map(|&i| full_loss_grad(engine, fleet, i, w))
            .collect::<Result<_>>()?,
    };
    let mut grad_acc = vec![0.0f64; p];
    let mut loss_acc = 0.0f64;
    for (li, gi) in &locals {
        loss_acc += li;
        linalg::accumulate(&mut grad_acc, gi);
    }
    let n = active.len() as f64;
    let gsq: f64 = grad_acc.iter().map(|g| (g / n) * (g / n)).sum();
    Ok((loss_acc / n, gsq))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard, synth};
    use crate::engine::NativeEngine;
    use crate::fed::SpeedModel;
    use crate::util::Rng;

    fn setup() -> (NativeEngine, ClientFleet) {
        let mut rng = Rng::new(11);
        let (ds, _) = synth::linreg(&mut rng, 400, 5, 0.05);
        let shards = shard::partition_iid(&mut rng, &ds, 8);
        let fleet = ClientFleet::new(
            ds,
            shards,
            &SpeedModel::paper_uniform().into(),
            &mut rng,
        );
        (NativeEngine::linreg(5, 10, 3), fleet)
    }

    #[test]
    fn fedgate_rounds_descend_to_low_gradient() {
        let (e, mut fleet) = setup();
        let active: Vec<usize> = (0..8).collect();
        let mut state = GateState::new(vec![0.0; 6], 8);
        let mut bufs = RoundBuffers::new(&e, 3);
        let (_, g0) = active_loss_gradsq(&e, &fleet, &active, &state.w).unwrap();
        for _ in 0..60 {
            fedgate_round(&e, &mut fleet, &mut state, &active, 3, 0.05, 1.0, &mut bufs)
                .unwrap();
        }
        let (_, g1) = active_loss_gradsq(&e, &fleet, &active, &state.w).unwrap();
        assert!(g1 < g0 * 1e-2, "grad^2 {g0} -> {g1}");
    }

    #[test]
    fn tracking_variables_sum_stays_near_zero() {
        // sum_i delta_i starts at 0 and the update adds (Delta_i - mean)
        // which sums to 0 over the active set => invariant preserved
        let (e, mut fleet) = setup();
        let active: Vec<usize> = (0..8).collect();
        let mut state = GateState::new(vec![0.1; 6], 8);
        let mut bufs = RoundBuffers::new(&e, 3);
        for _ in 0..5 {
            fedgate_round(&e, &mut fleet, &mut state, &active, 3, 0.05, 1.0, &mut bufs)
                .unwrap();
        }
        for k in 0..6 {
            let s: f64 = state.deltas.iter().map(|d| d[k] as f64).sum();
            assert!(s.abs() < 1e-4, "sum delta[{k}] = {s}");
        }
    }

    #[test]
    fn local_round_fallback_matches_fused_tau() {
        // engine tau = 3; calling with tau = 3 uses the fused path while
        // tau = 2 uses the fallback — both must advance the model
        let (e, mut fleet) = setup();
        let mut bufs = RoundBuffers::new(&e, 3);
        let w = vec![0.0f32; 6];
        let delta = vec![0.0f32; 6];
        let fused = local_round(&e, &mut fleet, 0, &w, &delta, 3, 0.05, &mut bufs).unwrap();
        let stepped = local_round(&e, &mut fleet, 0, &w, &delta, 2, 0.05, &mut bufs).unwrap();
        assert_ne!(fused, w);
        assert_ne!(stepped, w);
    }

    #[test]
    fn subset_round_only_touches_subset_tracking() {
        let (e, mut fleet) = setup();
        let mut state = GateState::new(vec![0.2; 6], 8);
        let mut bufs = RoundBuffers::new(&e, 3);
        fedgate_round(&e, &mut fleet, &mut state, &[1, 3], 3, 0.05, 1.0, &mut bufs)
            .unwrap();
        for (i, d) in state.deltas.iter().enumerate() {
            let touched = i == 1 || i == 3;
            let nonzero = d.iter().any(|&v| v != 0.0);
            assert_eq!(nonzero, touched, "client {i}");
        }
    }

    #[test]
    fn reset_tracking_zeroes() {
        let (e, mut fleet) = setup();
        let mut state = GateState::new(vec![0.2; 6], 8);
        let mut bufs = RoundBuffers::new(&e, 3);
        fedgate_round(&e, &mut fleet, &mut state, &[0, 1], 3, 0.05, 1.0, &mut bufs)
            .unwrap();
        state.reset_tracking();
        assert!(state.deltas.iter().all(|d| d.iter().all(|&v| v == 0.0)));
    }
}
