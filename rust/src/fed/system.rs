//! Event-driven system-heterogeneity core (`fed::system`).
//!
//! The seed modeled `T_i` as one static draw sorted once at fleet
//! construction. Real federations drift: TiFL (Chai et al.) re-estimates
//! client latency online because device speeds change, and Hard et al.
//! show availability churn materially changes which algorithm wins. This
//! module makes the heterogeneity model a first-class subsystem:
//!
//! * [`SystemModel`] — a scenario description: the base [`SpeedModel`]
//!   draw plus per-round [`Dynamics`] (static / multiplicative jitter /
//!   two-state Markov fast-slow) and an availability (dropout) process.
//! * [`SystemState`] — the realized per-round stochastic process, fully
//!   deterministic in its own RNG stream (independent of minibatch
//!   sampling, so scenarios never perturb the optimization path).
//! * [`SpeedEstimator`] — TiFL-style EWMA tracker of observed per-update
//!   times; FLANP re-ranks its fastest-prefix from these estimates at
//!   every stage boundary instead of reading oracle speeds.
//!
//! Under `Dynamics::Static` with zero dropout every realized round equals
//! the base draw bit-for-bit, so the event-driven clock reproduces the
//! seed's traces exactly (see `tests/system.rs`).
//!
//! Scenario specs compose an availability prefix
//! ([`crate::fed::AvailabilityModel`], `fed::traces`), a dropout prefix,
//! a dynamics prefix and a base speed model — or replay a recorded trace
//! wholesale (full grammar in `docs/scenarios.md`):
//!
//! ```
//! use flanp::fed::{AvailabilityModel, Dynamics, SystemModel};
//!
//! // [avail:iid:P:|avail:diurnal:PERIOD:DUTY:SPREAD:|avail:cluster:C:PF:PR:]
//! // [drop:P:][static:|jitter:SIGMA:|markov:F:PS:PR:]BASE
//! // or: trace:FILE[:wrap|:hold]
//! let m = SystemModel::parse("drop:0.05:markov:4:0.1:0.5:uniform:50:500").unwrap();
//! assert_eq!(m.p_drop, 0.05);
//! assert_eq!(
//!     m.dynamics,
//!     Dynamics::Markov { slow_factor: 4.0, p_slow: 0.1, p_recover: 0.5 }
//! );
//! // plain base specs parse as static scenarios (seed compatibility)
//! assert!(SystemModel::parse("uniform:50:500").unwrap().is_static());
//! // the canonical spec string roundtrips
//! assert_eq!(SystemModel::parse(&m.spec()).unwrap(), m);
//! // availability prefixes compose with every base scenario
//! let a = SystemModel::parse("avail:diurnal:2000:0.5:1:uniform:50:500").unwrap();
//! assert!(matches!(a.avail, Some(AvailabilityModel::Diurnal { .. })));
//! assert_eq!(SystemModel::parse(&a.spec()).unwrap(), a);
//! ```

use crate::fed::speed::{sort_fastest_first, SpeedModel};
use crate::fed::traces::{
    AvailabilityModel, TraceMode, TraceRecorder, TraceReplay,
};
use crate::util::Rng;

/// Per-round speed dynamics layered on top of the base draw.
#[derive(Clone, Debug, PartialEq)]
pub enum Dynamics {
    /// `T_i(round) = T_i` — the seed's behavior, bit-for-bit.
    Static,
    /// `T_i(round) = T_i * exp(sigma * z)`, `z ~ N(0,1)` i.i.d. per
    /// client and round (multiplicative log-normal jitter).
    Jitter { sigma: f64 },
    /// Two-state Markov chain per client: fast (`T_i`) and slow
    /// (`slow_factor * T_i`). One transition per round:
    /// fast→slow w.p. `p_slow`, slow→fast w.p. `p_recover`.
    Markov {
        slow_factor: f64,
        p_slow: f64,
        p_recover: f64,
    },
}

/// A complete system-heterogeneity scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemModel {
    /// distribution of the per-client base times `T_i`
    pub base: SpeedModel,
    pub dynamics: Dynamics,
    /// per-round probability that a client drops out of the round: it
    /// still holds the round open until the deadline (the server waits),
    /// but its update never arrives.
    pub p_drop: f64,
    /// correlated-availability process (`fed::traces`). Unlike `p_drop`,
    /// unavailability is OBSERVABLE at selection time: offline clients
    /// are skipped — never waited for, never charged, never fed to the
    /// speed estimator. `None` = every client always online.
    pub avail: Option<AvailabilityModel>,
    /// trace replay (`trace:FILE[:wrap|:hold]`): when set, realized
    /// times and availability come verbatim from the recorded trace; the
    /// other fields must stay at their defaults (a trace is a complete
    /// scenario on its own).
    pub trace: Option<TraceReplay>,
}

impl From<SpeedModel> for SystemModel {
    fn from(base: SpeedModel) -> Self {
        SystemModel {
            base,
            dynamics: Dynamics::Static,
            p_drop: 0.0,
            avail: None,
            trace: None,
        }
    }
}

impl SystemModel {
    /// The paper's Section-5.1 default: static uniform [50, 500).
    pub fn paper_uniform() -> Self {
        SpeedModel::paper_uniform().into()
    }

    pub fn is_static(&self) -> bool {
        self.dynamics == Dynamics::Static
            && self.p_drop == 0.0
            && self.avail.is_none()
            && self.trace.is_none()
    }

    /// Build a trace-replay scenario (the base/dynamics fields are inert
    /// placeholders: every realized round comes from the trace).
    pub fn from_trace(replay: TraceReplay) -> Self {
        SystemModel {
            base: SpeedModel::Homogeneous { t: 1.0 },
            dynamics: Dynamics::Static,
            p_drop: 0.0,
            avail: None,
            trace: Some(replay),
        }
    }

    /// Parse a scenario spec. Grammar (prefixes compose, base spec last):
    ///
    /// ```text
    ///   [avail:iid:P: | avail:diurnal:PERIOD:DUTY:SPREAD: |
    ///    avail:cluster:C:PF:PR:]
    ///   [drop:P:] [static: | jitter:SIGMA: | markov:F:PS:PR:] BASE
    ///   BASE = uniform:lo:hi | exp:lambda | homog:t
    ///
    ///   or, standalone:  trace:FILE[:wrap|:hold]
    /// ```
    ///
    /// Plain base specs (`uniform:50:500`) parse as static scenarios, so
    /// every seed-era `--speed` value keeps working unchanged. Examples:
    /// `jitter:0.3:uniform:50:500`, `drop:0.05:markov:4:0.1:0.5:exp:0.01`,
    /// `avail:diurnal:2000:0.5:1:uniform:50:500`. A `trace:` spec loads
    /// the CSV eagerly, so parse errors carry the file name and line.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let toks: Vec<&str> = spec.split(':').collect();
        // trace replay is a complete scenario on its own: the CSV carries
        // both the realized times and the availability, so no prefix or
        // base composes with it
        if toks.first() == Some(&"trace") {
            let mut rest = &toks[1..];
            let mode = match rest.last().copied() {
                Some("wrap") => {
                    rest = &rest[..rest.len() - 1];
                    TraceMode::Wrap
                }
                Some("hold") => {
                    rest = &rest[..rest.len() - 1];
                    TraceMode::Hold
                }
                _ => TraceMode::Hold,
            };
            let path = rest.join(":");
            if path.is_empty() {
                return Err(format!(
                    "missing trace file in system spec '{spec}'"
                ));
            }
            return Ok(SystemModel::from_trace(TraceReplay::load(
                &path, mode,
            )?));
        }
        let mut i = 0;
        let num = |what: &str, tok: Option<&&str>| -> Result<f64, String> {
            let tok = tok.ok_or_else(|| {
                format!("missing {what} in system spec '{spec}'")
            })?;
            tok.parse().map_err(|_| {
                format!("bad {what} '{tok}' in system spec '{spec}'")
            })
        };

        let mut avail = None;
        if toks.get(i) == Some(&"avail") {
            let (model, used) =
                AvailabilityModel::parse_tokens(&toks[i + 1..], spec)?;
            avail = Some(model);
            i += 1 + used;
        }
        let mut p_drop = 0.0;
        if toks.get(i) == Some(&"drop") {
            p_drop = num("drop probability", toks.get(i + 1))?;
            if !(0.0..1.0).contains(&p_drop) {
                return Err(format!(
                    "drop probability {p_drop} outside [0, 1) in system spec '{spec}'"
                ));
            }
            i += 2;
        }
        let dynamics = match toks.get(i).copied() {
            Some("static") => {
                i += 1;
                Dynamics::Static
            }
            Some("jitter") => {
                let sigma = num("jitter sigma", toks.get(i + 1))?;
                if sigma < 0.0 {
                    return Err(format!(
                        "jitter sigma {sigma} must be >= 0 in system spec '{spec}'"
                    ));
                }
                i += 2;
                Dynamics::Jitter { sigma }
            }
            Some("markov") => {
                let slow_factor = num("markov slow factor", toks.get(i + 1))?;
                let p_slow = num("markov p_slow", toks.get(i + 2))?;
                let p_recover = num("markov p_recover", toks.get(i + 3))?;
                if slow_factor < 1.0 {
                    return Err(format!(
                        "markov slow factor {slow_factor} must be >= 1 in system spec '{spec}'"
                    ));
                }
                for (name, p) in [("p_slow", p_slow), ("p_recover", p_recover)] {
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!(
                            "markov {name} {p} outside [0, 1] in system spec '{spec}'"
                        ));
                    }
                }
                i += 4;
                Dynamics::Markov { slow_factor, p_slow, p_recover }
            }
            _ => Dynamics::Static,
        };
        let base = SpeedModel::parse(&toks[i..].join(":"))?;
        Ok(SystemModel { base, dynamics, p_drop, avail, trace: None })
    }

    /// Canonical spec string; `parse(spec()) == self` for every scenario.
    pub fn spec(&self) -> String {
        if let Some(tr) = &self.trace {
            return tr.spec();
        }
        let mut s = String::new();
        if let Some(a) = &self.avail {
            s.push_str(&a.spec());
            s.push(':');
        }
        if self.p_drop > 0.0 {
            s.push_str(&format!("drop:{}:", self.p_drop));
        }
        match &self.dynamics {
            Dynamics::Static => {}
            Dynamics::Jitter { sigma } => s.push_str(&format!("jitter:{sigma}:")),
            Dynamics::Markov { slow_factor, p_slow, p_recover } => {
                s.push_str(&format!("markov:{slow_factor}:{p_slow}:{p_recover}:"))
            }
        }
        s.push_str(&self.base.spec());
        s
    }

    /// Structural sanity check (configs can be built without `parse`).
    pub fn validate(&self) -> Result<(), String> {
        if let Some(tr) = &self.trace {
            if tr.data.num_rounds() == 0 {
                return Err(format!("trace '{}' has no rounds", tr.path));
            }
            // a hold replay pins past-the-end rounds to the final trace
            // round forever: if that round has nobody available, every
            // solver would spin free idle rounds to its budget with the
            // clock frozen — reject the degenerate fixture up front
            if tr.mode == TraceMode::Hold {
                let (_, avail) = tr.data.round(tr.data.num_rounds() - 1);
                if avail.iter().all(|&a| !a) {
                    return Err(format!(
                        "trace '{}' ends with an all-offline round: a hold \
                         replay would idle forever once past the end \
                         (replay with :wrap or extend the trace)",
                        tr.path
                    ));
                }
            }
            if self.p_drop != 0.0
                || self.dynamics != Dynamics::Static
                || self.avail.is_some()
            {
                return Err(
                    "trace replay is a complete scenario: it does not \
                     compose with drop/dynamics/avail prefixes"
                        .into(),
                );
            }
            return Ok(());
        }
        if let Some(a) = &self.avail {
            a.validate()?;
        }
        if !(0.0..1.0).contains(&self.p_drop) {
            return Err(format!("p_drop {} outside [0, 1)", self.p_drop));
        }
        match self.dynamics {
            Dynamics::Static => {}
            Dynamics::Jitter { sigma } => {
                if !(sigma >= 0.0) {
                    return Err(format!("jitter sigma {sigma} must be >= 0"));
                }
            }
            Dynamics::Markov { slow_factor, p_slow, p_recover } => {
                if !(slow_factor >= 1.0) {
                    return Err(format!("slow factor {slow_factor} must be >= 1"));
                }
                if !(0.0..=1.0).contains(&p_slow) || !(0.0..=1.0).contains(&p_recover) {
                    return Err(format!(
                        "markov probabilities ({p_slow}, {p_recover}) outside [0, 1]"
                    ));
                }
            }
        }
        Ok(())
    }

    /// The oracle base draw `T_i`. Every scenario consumes exactly the
    /// same RNG budget here (one draw per client — see
    /// [`SpeedModel::draw`]), so downstream stream positions (the
    /// per-client minibatch forks) are identical across scenarios;
    /// trace replays depend on this for bit-identical record→replay.
    /// Trace scenarios return round 0 of the trace (the recorded
    /// profiling probe) as the base.
    pub fn draw_base(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        let drawn = self.base.draw(rng, n);
        match &self.trace {
            Some(tr) => {
                let (times, _) = tr.data.round(0);
                assert_eq!(
                    times.len(),
                    n,
                    "trace '{}' replays {} clients, fleet has {n}",
                    tr.path,
                    times.len()
                );
                times.to_vec()
            }
            None => drawn,
        }
    }
}

/// One round's realized conditions for EVERY client (indexed by id).
#[derive(Clone, Debug)]
pub struct RoundConditions {
    /// realized per-update compute time this round
    pub times: Vec<f64>,
    /// false when the client silently drops out of this round (the
    /// `drop:` process): NOT observable at selection time — it holds a
    /// synchronous round open and its update never arrives
    pub available: Vec<bool>,
    /// false when the client is offline this round (`avail:` models and
    /// the trace `available` column): observable at selection time, so
    /// solvers skip it — it is never waited for, never charged to the
    /// clock and never fed to the speed estimator
    pub online: Vec<bool>,
}

impl RoundConditions {
    /// Clients of `ids` that are observably online this round.
    pub fn online_of(&self, ids: &[usize]) -> Vec<usize> {
        ids.iter().copied().filter(|&i| self.online[i]).collect()
    }

    /// Fleet-wide count of observably-online clients (the per-round
    /// `available` trace column).
    pub fn online_count(&self) -> usize {
        self.online.iter().filter(|&&o| o).count()
    }
}

/// The realized heterogeneity process. Advances once per communication
/// round for ALL clients, so RNG consumption — and therefore every
/// realized trajectory — is independent of which clients are active.
#[derive(Clone, Debug)]
pub struct SystemState {
    model: SystemModel,
    /// the base draw `T_i` (the oracle speeds of the static scenario)
    base: Vec<f64>,
    /// Markov slow-state flags (all clients start fast)
    slow: Vec<bool>,
    /// per-cluster Markov outage states (`avail:cluster`, else empty)
    cluster_down: Vec<bool>,
    rng: Rng,
    rounds_realized: usize,
    /// when set, every realized round (probe included) is appended for
    /// trace export (`--record-trace`)
    recorder: Option<TraceRecorder>,
}

impl SystemState {
    pub fn new(model: SystemModel, base: Vec<f64>, rng: Rng) -> Self {
        let n = base.len();
        let clusters =
            model.avail.as_ref().map_or(0, |a| a.num_clusters());
        SystemState {
            model,
            base,
            slow: vec![false; n],
            cluster_down: vec![false; clusters],
            rng,
            rounds_realized: 0,
            recorder: None,
        }
    }

    pub fn model(&self) -> &SystemModel {
        &self.model
    }

    pub fn base_speeds(&self) -> &[f64] {
        &self.base
    }

    pub fn rounds_realized(&self) -> usize {
        self.rounds_realized
    }

    /// Start recording every realized round (including the construction
    /// probe) for trace export. Must be enabled BEFORE the probe so a
    /// replayed trace primes the speed estimator exactly as the recorded
    /// run did. Idempotent.
    pub fn enable_recording(&mut self) {
        if self.recorder.is_none() {
            self.recorder = Some(TraceRecorder::new(self.base.len()));
        }
    }

    /// The recorded trace so far (None unless recording was enabled).
    pub fn recorder(&self) -> Option<&TraceRecorder> {
        self.recorder.as_ref()
    }

    /// Realize the next round at time 0 (scenarios without a time-based
    /// availability model ignore the timestamp entirely).
    pub fn next_round(&mut self) -> RoundConditions {
        self.next_round_at(0.0)
    }

    /// Realize the next round at virtual time `now` (diurnal
    /// availability windows are time-based; everything else ignores
    /// `now`). Static scenarios consume no randomness and return the
    /// base draw unchanged (bit-for-bit seed parity). Trace scenarios
    /// replay the recorded round verbatim, with the trace's
    /// availability observable (`online`) and no silent dropout.
    pub fn next_round_at(&mut self, now: f64) -> RoundConditions {
        let idx = self.rounds_realized;
        self.rounds_realized += 1;
        let n = self.base.len();
        let cond = if let Some(tr) = &self.model.trace {
            let (times, avail) = tr.data.round(tr.round_index(idx));
            debug_assert_eq!(times.len(), n);
            RoundConditions {
                times: times.to_vec(),
                available: vec![true; n],
                online: avail.to_vec(),
            }
        } else {
            let mut times = Vec::with_capacity(n);
            match self.model.dynamics {
                Dynamics::Static => times.extend_from_slice(&self.base),
                Dynamics::Jitter { sigma } => {
                    for i in 0..n {
                        let factor = (sigma * self.rng.normal()).exp();
                        times.push(self.base[i] * factor);
                    }
                }
                Dynamics::Markov { slow_factor, p_slow, p_recover } => {
                    for i in 0..n {
                        let u = self.rng.next_f64();
                        self.slow[i] = if self.slow[i] {
                            u >= p_recover
                        } else {
                            u < p_slow
                        };
                        times.push(if self.slow[i] {
                            self.base[i] * slow_factor
                        } else {
                            self.base[i]
                        });
                    }
                }
            }
            let available = if self.model.p_drop > 0.0 {
                (0..n)
                    .map(|_| self.rng.next_f64() >= self.model.p_drop)
                    .collect()
            } else {
                vec![true; n]
            };
            let online = match &self.model.avail {
                None => vec![true; n],
                Some(a) => a.realize(
                    now,
                    n,
                    &mut self.cluster_down,
                    &mut self.rng,
                ),
            };
            RoundConditions { times, available, online }
        };
        if let Some(rec) = &mut self.recorder {
            rec.record(&cond);
        }
        cond
    }
}

/// TiFL-style online speed estimator: an EWMA over observed per-update
/// times. The coordinator feeds it the realized upload timings of every
/// participating client; FLANP ranks its fastest-prefix from the current
/// estimates instead of oracle speeds.
#[derive(Clone, Debug)]
pub struct SpeedEstimator {
    est: Vec<f64>,
    alpha: f64,
    observations: Vec<u64>,
}

impl SpeedEstimator {
    /// `prior` is one profiling observation per client (TiFL's tiering
    /// probe); under static dynamics it equals the true `T_i` exactly.
    pub fn new(prior: &[f64], alpha: f64) -> Self {
        assert!(
            alpha > 0.0 && alpha <= 1.0,
            "EWMA alpha {alpha} outside (0, 1]"
        );
        SpeedEstimator {
            est: prior.to_vec(),
            alpha,
            observations: vec![0; prior.len()],
        }
    }

    /// Fold one observed per-update time into the estimate. Written as
    /// `est += alpha * (obs - est)` so an observation equal to the
    /// current estimate is an exact fixed point — static scenarios keep
    /// estimates bit-identical to the oracle speeds forever.
    pub fn observe(&mut self, client: usize, per_update_time: f64) {
        let e = &mut self.est[client];
        *e += self.alpha * (per_update_time - *e);
        self.observations[client] += 1;
    }

    /// Fold a *censored* observation: the client was still computing at
    /// the aggregation deadline, so all we learn is `per-update time >
    /// lower_bound` (`lower_bound = deadline / updates`). The estimate
    /// is pulled up toward the bound when the bound exceeds it and left
    /// untouched otherwise — a censored observation can never make a
    /// client look *faster*, which would feed back into tighter
    /// deadlines and starve the round (the deadline/estimation
    /// interplay TiFL warns about).
    pub fn observe_censored(&mut self, client: usize, lower_bound: f64) {
        if lower_bound > self.est[client] {
            self.observe(client, lower_bound);
        }
    }

    pub fn estimate(&self, client: usize) -> f64 {
        self.est[client]
    }

    pub fn estimates(&self) -> &[f64] {
        &self.est
    }

    pub fn observations(&self, client: usize) -> u64 {
        self.observations[client]
    }

    /// Client ids sorted fastest-first by current estimate (stable:
    /// equal estimates keep id order, matching the oracle sort).
    pub fn ranked(&self) -> Vec<usize> {
        sort_fastest_first(&self.est)
    }

    /// The `k` fastest client ids by current estimate — bit-identical
    /// to `ranked()` truncated to `k`, via top-K heap selection
    /// ([`crate::fed::TopK`]): O(n log k) per call instead of the full
    /// O(n log n) sort, the difference between a stage boundary costing
    /// a population sort and costing a cohort scan (see `docs/scale.md`).
    pub fn ranked_prefix(&self, k: usize) -> Vec<usize> {
        crate::fed::sketch::TopK::select(&self.est, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys(spec: &str) -> SystemModel {
        SystemModel::parse(spec).unwrap()
    }

    #[test]
    fn parse_roundtrips_every_variant() {
        for spec in [
            "uniform:50:500",
            "exp:0.5",
            "homog:10",
            "static:uniform:50:500",
            "jitter:0.3:uniform:50:500",
            "markov:4:0.1:0.5:exp:0.01",
            "drop:0.05:uniform:50:500",
            "drop:0.05:jitter:0.2:homog:100",
            "drop:0.1:markov:2:0.2:0.4:uniform:50:500",
            "avail:iid:0.6:uniform:50:500",
            "avail:diurnal:2000:0.5:1:uniform:50:500",
            "avail:cluster:4:0.1:0.5:exp:0.01",
            "avail:diurnal:2000:0.25:0.5:drop:0.05:markov:4:0.1:0.5:homog:100",
        ] {
            let m = sys(spec);
            assert_eq!(SystemModel::parse(&m.spec()).unwrap(), m, "spec {spec}");
        }
        // canonical form drops the redundant `static:` prefix
        assert_eq!(sys("static:homog:5").spec(), "homog:5");
        assert_eq!(sys("uniform:50:500"), SystemModel::paper_uniform());
    }

    #[test]
    fn parse_errors_name_the_full_spec() {
        for bad in [
            "jitter:x:uniform:50:500",
            "markov:4:0.1:uniform:50:500", // missing p_recover
            "drop:1.5:homog:10",
            "markov:0.5:0.1:0.1:homog:10", // slow factor < 1
            "warp:9",
            "avail:weekly:3:uniform:50:500", // unknown availability model
            "avail:iid:1.5:uniform:50:500",  // probability out of range
            "avail:diurnal:0:0.5:1:homog:10", // non-positive period
            "avail:cluster:0:0.1:0.5:homog:10", // zero clusters
        ] {
            let e = SystemModel::parse(bad).unwrap_err();
            assert!(e.contains(bad) || e.contains("speed"), "error '{e}' for '{bad}'");
        }
        // base-layer errors carry the base spec
        let e = SystemModel::parse("jitter:0.1:uniform:a:500").unwrap_err();
        assert!(e.contains("uniform:a:500"), "{e}");
        // a missing trace file names the path
        let e = SystemModel::parse("trace:/no/such/file.csv").unwrap_err();
        assert!(e.contains("/no/such/file.csv"), "{e}");
        assert!(SystemModel::parse("trace:").is_err());
    }

    #[test]
    fn static_rounds_equal_base_bit_for_bit() {
        let base = vec![110.0, 70.5, 300.25];
        let mut st = SystemState::new(
            sys("uniform:50:500"),
            base.clone(),
            Rng::with_stream(1, 2),
        );
        for _ in 0..5 {
            let c = st.next_round();
            assert_eq!(c.times, base);
            assert!(c.available.iter().all(|&a| a));
        }
        assert_eq!(st.rounds_realized(), 5);
    }

    #[test]
    fn jitter_perturbs_multiplicatively() {
        let base = vec![100.0; 64];
        let mut st =
            SystemState::new(sys("jitter:0.2:homog:100"), base, Rng::new(3));
        let c = st.next_round();
        assert!(c.times.iter().all(|&t| t > 0.0));
        assert!(c.times.iter().any(|&t| t != 100.0));
        // log-normal(0, 0.2): all realistic mass within e^{±10 sigma}
        assert!(c.times.iter().all(|&t| (10.0..1000.0).contains(&t)));
        // successive rounds re-draw
        let c2 = st.next_round();
        assert_ne!(c.times, c2.times);
    }

    #[test]
    fn markov_times_take_exactly_two_levels() {
        let base = vec![100.0; 32];
        let mut st = SystemState::new(
            sys("markov:4:0.3:0.3:homog:100"),
            base,
            Rng::new(7),
        );
        let mut seen_slow = false;
        for _ in 0..50 {
            let c = st.next_round();
            for &t in &c.times {
                assert!(t == 100.0 || t == 400.0, "time {t}");
                seen_slow |= t == 400.0;
            }
        }
        assert!(seen_slow, "no slow transitions in 50 rounds at p=0.3");
    }

    #[test]
    fn dropout_rate_matches_probability() {
        let base = vec![1.0; 100];
        let mut st =
            SystemState::new(sys("drop:0.2:homog:1"), base, Rng::new(11));
        let mut dropped = 0usize;
        let rounds = 200;
        for _ in 0..rounds {
            let c = st.next_round();
            dropped += c.available.iter().filter(|&&a| !a).count();
        }
        let rate = dropped as f64 / (rounds * 100) as f64;
        assert!((rate - 0.2).abs() < 0.02, "dropout rate {rate}");
    }

    #[test]
    fn realization_is_deterministic_in_the_stream() {
        let mk = || {
            SystemState::new(
                sys("drop:0.1:markov:4:0.2:0.4:uniform:50:500"),
                vec![60.0, 120.0, 240.0],
                Rng::with_stream(5, 9),
            )
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..20 {
            let (ca, cb) = (a.next_round(), b.next_round());
            assert_eq!(ca.times, cb.times);
            assert_eq!(ca.available, cb.available);
            assert_eq!(ca.online, cb.online);
        }
    }

    #[test]
    fn scenarios_without_avail_are_always_online() {
        let mut st = SystemState::new(
            sys("drop:0.3:jitter:0.2:uniform:50:500"),
            vec![100.0, 200.0],
            Rng::new(5),
        );
        for _ in 0..20 {
            let c = st.next_round();
            assert!(c.online.iter().all(|&o| o), "dropout leaked into online");
        }
    }

    #[test]
    fn diurnal_online_flags_follow_the_clock_not_the_round() {
        let mut st = SystemState::new(
            sys("avail:diurnal:100:0.5:1:homog:10"),
            vec![10.0; 4],
            Rng::new(5),
        );
        // phases 0, 0.25, 0.5, 0.75 at duty 0.5
        let c = st.next_round_at(0.0);
        assert_eq!(c.online, vec![true, true, false, false]);
        assert_eq!(c.online_count(), 2);
        assert_eq!(c.online_of(&[0, 2, 3]), vec![0]);
        let c = st.next_round_at(50.0);
        assert_eq!(c.online, vec![false, false, true, true]);
        // dropout stays independent of availability
        assert!(c.available.iter().all(|&a| a));
    }

    #[test]
    fn trace_models_replay_verbatim_and_extend_by_hold() {
        use crate::fed::traces::{TraceData, TraceMode, TraceReplay};
        let mut data = TraceData::empty(2);
        data.push_round(vec![10.0, 20.0], vec![true, true]);
        data.push_round(vec![11.0, 21.0], vec![true, false]);
        let model = SystemModel::from_trace(TraceReplay::from_data(
            "mem",
            data,
            TraceMode::Hold,
        ));
        assert!(!model.is_static());
        assert!(model.validate().is_ok());
        // the base draw is the trace's round 0 (probe) measurement
        let mut rng = Rng::new(9);
        assert_eq!(model.draw_base(&mut rng, 2), vec![10.0, 20.0]);
        let mut st =
            SystemState::new(model, vec![10.0, 20.0], Rng::new(9));
        let c0 = st.next_round();
        assert_eq!(c0.times, vec![10.0, 20.0]);
        assert_eq!(c0.online, vec![true, true]);
        let c1 = st.next_round();
        assert_eq!(c1.times, vec![11.0, 21.0]);
        assert_eq!(c1.online, vec![true, false]);
        // trace availability is observable, never a silent dropout
        assert!(c1.available.iter().all(|&a| a));
        // past the end, hold repeats the final round
        let c2 = st.next_round();
        assert_eq!(c2.times, c1.times);
        assert_eq!(c2.online, c1.online);
    }

    #[test]
    fn hold_replay_rejects_an_all_offline_tail() {
        use crate::fed::traces::{TraceData, TraceMode, TraceReplay};
        let mut data = TraceData::empty(2);
        data.push_round(vec![10.0, 20.0], vec![true, true]);
        data.push_round(vec![10.0, 20.0], vec![false, false]);
        let hold = SystemModel::from_trace(TraceReplay::from_data(
            "mem",
            data.clone(),
            TraceMode::Hold,
        ));
        let e = hold.validate().unwrap_err();
        assert!(e.contains("all-offline"), "{e}");
        // wrap cycles back to the online round: fine
        let wrap = SystemModel::from_trace(TraceReplay::from_data(
            "mem",
            data,
            TraceMode::Wrap,
        ));
        assert!(wrap.validate().is_ok());
    }

    #[test]
    fn recording_captures_probe_and_every_round() {
        let mut st = SystemState::new(
            sys("markov:4:0.3:0.3:homog:100"),
            vec![100.0; 3],
            Rng::new(3),
        );
        st.enable_recording();
        let probe = st.next_round();
        for _ in 0..5 {
            st.next_round();
        }
        let rec = st.recorder().unwrap();
        assert_eq!(rec.rounds_recorded(), 6);
        let (t0, a0) = rec.data().round(0);
        assert_eq!(t0, &probe.times[..]);
        assert!(a0.iter().all(|&a| a));
    }

    #[test]
    fn estimator_is_exact_fixed_point_on_static_observations() {
        let prior = vec![50.0, 275.3, 499.9];
        let mut est = SpeedEstimator::new(&prior, 0.25);
        for _ in 0..100 {
            for (i, &t) in prior.iter().enumerate() {
                est.observe(i, t);
            }
        }
        // bit-for-bit: static scenarios never perturb the ranking
        assert_eq!(est.estimates(), &prior[..]);
        assert_eq!(est.ranked(), vec![0, 1, 2]);
        assert_eq!(est.observations(1), 100);
    }

    #[test]
    fn ranked_prefix_equals_truncated_ranking() {
        // including ties, which the stable sort breaks by id
        let mut est = SpeedEstimator::new(&[30.0, 10.0, 20.0, 10.0, 20.0], 0.5);
        est.observe(0, 5.0); // drift one estimate
        for k in 0..=6 {
            let mut full = est.ranked();
            full.truncate(k);
            assert_eq!(est.ranked_prefix(k), full, "k = {k}");
        }
    }

    #[test]
    fn censored_observations_only_pull_estimates_up() {
        let mut est = SpeedEstimator::new(&[100.0], 0.5);
        // bound below the estimate: no information, no movement
        est.observe_censored(0, 60.0);
        assert_eq!(est.estimate(0), 100.0);
        assert_eq!(est.observations(0), 0);
        // bound above: the estimate moves toward the bound
        est.observe_censored(0, 200.0);
        assert_eq!(est.estimate(0), 150.0);
        assert_eq!(est.observations(0), 1);
    }

    #[test]
    fn estimator_tracks_drift_and_reranks() {
        // client 0 starts fastest, then slows 10x; client 1 is steady
        let mut est = SpeedEstimator::new(&[50.0, 100.0], 0.5);
        assert_eq!(est.ranked(), vec![0, 1]);
        for _ in 0..20 {
            est.observe(0, 500.0);
            est.observe(1, 100.0);
        }
        assert!(est.estimate(0) > 400.0, "{}", est.estimate(0));
        assert_eq!(est.ranked(), vec![1, 0], "estimator did not re-rank");
    }
}
