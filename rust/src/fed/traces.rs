//! Trace replay & correlated availability (`fed::traces`).
//!
//! Every scenario so far is i.i.d.-synthetic: static / jitter / Markov
//! speed dynamics and independent per-round dropout. Two things real
//! federations have that those scenarios cannot express:
//!
//! * **Measured traces.** Production FL systems (TiFL, Chai et al.) tune
//!   against recorded per-client latency traces, not distributions.
//!   [`TraceData`] is that artifact: a per-client, per-round CSV of
//!   realized latencies and availability, replayed through the
//!   `trace:FILE[:wrap|:hold]` scenario spec and exported from ANY run
//!   by [`TraceRecorder`] — so every synthetic scenario doubles as a
//!   replayable fixture, and record→replay is bit-identical (see
//!   `rust/tests/traces.rs`).
//! * **Correlated availability.** Hard et al. (*Learning from straggler
//!   clients in federated learning*, 2024) show diurnal cycles and
//!   clustered outages — clients going offline *together* — can flip
//!   which algorithm wins. [`AvailabilityModel`] composes an `avail:`
//!   prefix with every existing base scenario: i.i.d. observable
//!   availability (the uncorrelated control), phase-staggered diurnal
//!   on/off windows, and clustered two-state Markov outages.
//!
//! Unavailability is **observable at selection time** — the opposite of
//! the `drop:` process, whose silent dropouts hold a synchronous round
//! open. The synchronous cohort solvers skip an offline client: it is
//! never waited for by the clock, never fed to the speed estimator, and
//! never counted as a dropout (see
//! `coordinator::solvers::deadline_round`). FedBuff has no round
//! cohort; its asynchronous attempts simply fail while offline (counted
//! per-client in `dropped`) and the client re-polls.
//!
//! ## Trace CSV schema
//!
//! ```text
//! round,client,time,available
//! 0,0,110.5,1
//! 0,1,420.25,0
//! ...
//! ```
//!
//! Rows are round-major with clients ascending; every round lists every
//! client. Round 0 is the construction-time profiling probe (the TiFL
//! tiering measurement that primes the speed estimator), so a replayed
//! trace primes the estimator exactly as the recorded run did. Parse
//! errors always carry the source name and 1-based line number.

use crate::fed::system::RoundConditions;
use crate::util::Rng;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

/// The exact header row of the trace CSV schema.
pub const TRACE_CSV_HEADER: &str = "round,client,time,available";

/// A measured (or recorded) per-client, per-round latency/availability
/// trace. Construct from CSV via [`TraceData::parse_csv`] /
/// [`TraceData::load`], or incrementally via [`TraceRecorder`].
#[derive(Clone, Debug, PartialEq)]
pub struct TraceData {
    num_clients: usize,
    /// `rounds[r] = (times, available)`, each of length `num_clients`
    rounds: Vec<(Vec<f64>, Vec<bool>)>,
}

impl TraceData {
    /// An empty trace over a fixed fleet size (the recorder's seed).
    pub fn empty(num_clients: usize) -> Self {
        assert!(num_clients > 0, "trace over an empty fleet");
        TraceData { num_clients, rounds: Vec::new() }
    }

    pub fn num_clients(&self) -> usize {
        self.num_clients
    }

    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// One round's `(times, available)` rows (panics out of range —
    /// wrap/hold extension lives in [`TraceReplay::round_index`]).
    pub fn round(&self, r: usize) -> (&[f64], &[bool]) {
        let (t, a) = &self.rounds[r];
        (t, a)
    }

    /// Append one realized round (lengths must match the fleet).
    pub fn push_round(&mut self, times: Vec<f64>, available: Vec<bool>) {
        assert_eq!(times.len(), self.num_clients, "trace round width");
        assert_eq!(available.len(), self.num_clients, "trace round width");
        self.rounds.push((times, available));
    }

    /// Parse the CSV schema above. `source` names the origin (file path
    /// or label) so every error reads `source:line: message`.
    pub fn parse_csv(text: &str, source: &str) -> Result<Self, String> {
        let mut lines = text.lines().enumerate();
        let header = match lines.next() {
            Some((_, h)) => h,
            None => {
                return Err(format!(
                    "{source}:1: empty trace (expected header \
                     '{TRACE_CSV_HEADER}')"
                ))
            }
        };
        if header.trim() != TRACE_CSV_HEADER {
            return Err(format!(
                "{source}:1: bad trace header '{}' (expected \
                 '{TRACE_CSV_HEADER}')",
                header.trim()
            ));
        }
        let mut rounds: Vec<(Vec<f64>, Vec<bool>)> = Vec::new();
        let mut last_line = 1usize;
        for (idx, line) in lines {
            let lineno = idx + 1;
            last_line = lineno;
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() != 4 {
                return Err(format!(
                    "{source}:{lineno}: expected 4 columns \
                     '{TRACE_CSV_HEADER}', got {}",
                    cols.len()
                ));
            }
            let round: usize = cols[0].trim().parse().map_err(|_| {
                format!("{source}:{lineno}: bad round '{}'", cols[0].trim())
            })?;
            let client: usize = cols[1].trim().parse().map_err(|_| {
                format!("{source}:{lineno}: bad client '{}'", cols[1].trim())
            })?;
            let time: f64 = cols[2].trim().parse().map_err(|_| {
                format!("{source}:{lineno}: bad time '{}'", cols[2].trim())
            })?;
            if !(time.is_finite() && time > 0.0) {
                return Err(format!(
                    "{source}:{lineno}: time {time} must be finite and \
                     positive"
                ));
            }
            let available = match cols[3].trim() {
                "0" => false,
                "1" => true,
                other => {
                    return Err(format!(
                        "{source}:{lineno}: bad available flag '{other}' \
                         (expected 0 or 1)"
                    ))
                }
            };
            // strict round-major, client-ascending ordering: a new round
            // may only open once the previous one listed every client
            if round == rounds.len() && client == 0 {
                if let Some((prev, _)) = rounds.last() {
                    if prev.len() != rounds[0].0.len() {
                        return Err(format!(
                            "{source}:{lineno}: round {} listed {} clients, \
                             expected {}",
                            rounds.len() - 1,
                            prev.len(),
                            rounds[0].0.len()
                        ));
                    }
                }
                rounds.push((Vec::new(), Vec::new()));
            }
            if round + 1 != rounds.len() {
                return Err(format!(
                    "{source}:{lineno}: round {round} out of order \
                     (expected {})",
                    rounds.len().saturating_sub(1)
                ));
            }
            let width = rounds[0].0.len();
            let first_round = rounds.len() == 1;
            let cur_len = rounds.last().unwrap().0.len();
            if client != cur_len {
                return Err(format!(
                    "{source}:{lineno}: client {client} out of order \
                     (expected {cur_len})"
                ));
            }
            if !first_round && client >= width {
                return Err(format!(
                    "{source}:{lineno}: client {client} exceeds the trace \
                     width {width}"
                ));
            }
            let last = rounds.last_mut().unwrap();
            last.0.push(time);
            last.1.push(available);
        }
        if rounds.is_empty() {
            return Err(format!(
                "{source}:{last_line}: trace has no rounds"
            ));
        }
        let num_clients = rounds[0].0.len();
        if let Some((t, _)) = rounds.last() {
            if t.len() != num_clients {
                return Err(format!(
                    "{source}:{last_line}: round {} listed {} clients, \
                     expected {num_clients}",
                    rounds.len() - 1,
                    t.len()
                ));
            }
        }
        Ok(TraceData { num_clients, rounds })
    }

    /// Load from a CSV file; errors carry the path (and line, once the
    /// file is readable).
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            format!("cannot read trace '{}': {e}", path.display())
        })?;
        Self::parse_csv(&text, &path.display().to_string())
    }

    /// Serialize to the CSV schema; `parse_csv(to_csv()) == self` for
    /// every trace (f64 `Display` round-trips exactly).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(TRACE_CSV_HEADER);
        s.push('\n');
        for (r, (times, avails)) in self.rounds.iter().enumerate() {
            for (c, (t, a)) in times.iter().zip(avails).enumerate() {
                s.push_str(&format!("{r},{c},{t},{}\n", *a as u8));
            }
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// What a replay does once the run outlives the trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TraceMode {
    /// repeat the final round forever (the default)
    #[default]
    Hold,
    /// cycle back to round 0
    Wrap,
}

/// A trace wired into the scenario grammar: `trace:FILE[:wrap|:hold]`.
/// A trace is a complete scenario on its own — it carries both the
/// realized per-round times and the availability, so no `drop:` /
/// dynamics / `avail:` prefix composes with it.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceReplay {
    /// display path (or label) used by `spec()` and error messages
    pub path: String,
    pub mode: TraceMode,
    pub data: Arc<TraceData>,
}

impl TraceReplay {
    pub fn load(path: &str, mode: TraceMode) -> Result<Self, String> {
        let data = TraceData::load(Path::new(path))?;
        Ok(TraceReplay { path: path.to_string(), mode, data: Arc::new(data) })
    }

    /// Wrap an in-memory trace (record→replay without touching disk).
    pub fn from_data(label: &str, data: TraceData, mode: TraceMode) -> Self {
        assert!(data.num_rounds() > 0, "replaying an empty trace");
        TraceReplay { path: label.to_string(), mode, data: Arc::new(data) }
    }

    /// Map a realized-round index onto the trace under wrap/hold.
    pub fn round_index(&self, realized: usize) -> usize {
        let len = self.data.num_rounds();
        match self.mode {
            TraceMode::Wrap => realized % len,
            TraceMode::Hold => realized.min(len - 1),
        }
    }

    /// Canonical spec string (the default `hold` mode is omitted).
    pub fn spec(&self) -> String {
        match self.mode {
            TraceMode::Hold => format!("trace:{}", self.path),
            TraceMode::Wrap => format!("trace:{}:wrap", self.path),
        }
    }
}

/// Records every realized round of a run (including the construction
/// probe) into a [`TraceData`], so any scenario becomes a replayable
/// fixture. Enabled via `ExperimentConfig::record_trace` /
/// `flanp run --record-trace`; the recorded availability bit is
/// `online && available` — a replay makes ALL unavailability observable
/// at selection time, which is exactly what a measured trace gives a
/// real scheduler. Three caveats bound the bit-identity guarantee:
/// replaying a `drop:` scenario is not bit-identical (its silent
/// dropouts become observable); a recorded `avail:diurnal` wait replays
/// as an estimate-priced waiting round rather than a jump to the exact
/// window boundary (the trace does not carry the window schedule);
/// and ORACLE-ranked runs (`--oracle-ranking`, `fedgate-fastK`) can
/// diverge under jitter/Markov, because the replayed fleet's base
/// speeds — and hence its oracle ordering — are the recorded round-0
/// probe times, not the recorded base draw. The roundtrip IS
/// bit-identical for estimate-ranked runs (the default) under static,
/// jitter, markov, avail:iid and avail:cluster scenarios.
#[derive(Clone, Debug)]
pub struct TraceRecorder {
    data: TraceData,
}

impl TraceRecorder {
    pub fn new(num_clients: usize) -> Self {
        TraceRecorder { data: TraceData::empty(num_clients) }
    }

    /// Append one realized round.
    pub fn record(&mut self, cond: &RoundConditions) {
        let available: Vec<bool> = cond
            .online
            .iter()
            .zip(&cond.available)
            .map(|(&o, &a)| o && a)
            .collect();
        self.data.push_round(cond.times.clone(), available);
    }

    pub fn rounds_recorded(&self) -> usize {
        self.data.num_rounds()
    }

    pub fn data(&self) -> &TraceData {
        &self.data
    }
}

/// A correlated-availability process, layered over any base scenario via
/// the `avail:` spec prefix. Unavailability is observable at selection
/// time (unlike `drop:`): offline clients are skipped, never charged to
/// the clock and never fed to the speed estimator.
#[derive(Clone, Debug, PartialEq)]
pub enum AvailabilityModel {
    /// `avail:iid:P:` — each client is online i.i.d. with probability
    /// `P` per round: the *uncorrelated* control every correlated
    /// scenario is compared against (same marginal availability, zero
    /// correlation).
    Iid { p: f64 },
    /// `avail:diurnal:PERIOD:DUTY:SPREAD:` — deterministic time-based
    /// on/off windows: client `i` of `n` is online while
    /// `frac(now/PERIOD + SPREAD * i/n) < DUTY`. `SPREAD = 0` puts the
    /// whole fleet on one shared window (perfectly correlated outages);
    /// `SPREAD = 1` staggers phases uniformly (a rotating online
    /// cohort). `PERIOD` is in virtual-clock units.
    Diurnal { period: f64, duty: f64, spread: f64 },
    /// `avail:cluster:C:PF:PR:` — `C` contiguous-id clusters, each with
    /// its own two-state Markov outage chain (up→down w.p. `PF`,
    /// down→up w.p. `PR` per round). Co-located clients fail together.
    Cluster { clusters: usize, p_fail: f64, p_recover: f64 },
}

impl AvailabilityModel {
    /// Parse the tokens following the `avail:` keyword; returns the
    /// model and how many tokens were consumed. `spec` is the full
    /// system spec, quoted in every error.
    pub(crate) fn parse_tokens(
        toks: &[&str],
        spec: &str,
    ) -> Result<(Self, usize), String> {
        let num = |what: &str, tok: Option<&&str>| -> Result<f64, String> {
            let tok = tok.ok_or_else(|| {
                format!("missing {what} in system spec '{spec}'")
            })?;
            tok.parse().map_err(|_| {
                format!("bad {what} '{tok}' in system spec '{spec}'")
            })
        };
        let (model, used) = match toks.first().copied() {
            Some("iid") => (
                AvailabilityModel::Iid {
                    p: num("iid availability", toks.get(1))?,
                },
                2,
            ),
            Some("diurnal") => (
                AvailabilityModel::Diurnal {
                    period: num("diurnal period", toks.get(1))?,
                    duty: num("diurnal duty", toks.get(2))?,
                    spread: num("diurnal spread", toks.get(3))?,
                },
                4,
            ),
            Some("cluster") => {
                let ctok = toks.get(1).ok_or_else(|| {
                    format!("missing cluster count in system spec '{spec}'")
                })?;
                let clusters: usize = ctok.parse().map_err(|_| {
                    format!(
                        "bad cluster count '{ctok}' in system spec '{spec}'"
                    )
                })?;
                (
                    AvailabilityModel::Cluster {
                        clusters,
                        p_fail: num("cluster p_fail", toks.get(2))?,
                        p_recover: num("cluster p_recover", toks.get(3))?,
                    },
                    4,
                )
            }
            _ => {
                return Err(format!(
                    "unknown availability model after 'avail:' in system \
                     spec '{spec}' (expected iid:P | \
                     diurnal:PERIOD:DUTY:SPREAD | cluster:C:PF:PR)"
                ))
            }
        };
        model
            .validate()
            .map_err(|e| format!("{e} in system spec '{spec}'"))?;
        Ok((model, used))
    }

    /// Canonical spec fragment (no trailing colon):
    /// `avail:diurnal:2000:0.5:1` etc.
    pub fn spec(&self) -> String {
        match self {
            AvailabilityModel::Iid { p } => format!("avail:iid:{p}"),
            AvailabilityModel::Diurnal { period, duty, spread } => {
                format!("avail:diurnal:{period}:{duty}:{spread}")
            }
            AvailabilityModel::Cluster { clusters, p_fail, p_recover } => {
                format!("avail:cluster:{clusters}:{p_fail}:{p_recover}")
            }
        }
    }

    /// Structural sanity check (configs can be built without `parse`).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            AvailabilityModel::Iid { p } => {
                if !(p > 0.0 && p <= 1.0) {
                    return Err(format!(
                        "iid availability {p} outside (0, 1]"
                    ));
                }
            }
            AvailabilityModel::Diurnal { period, duty, spread } => {
                if !(period.is_finite() && period > 0.0) {
                    return Err(format!(
                        "diurnal period {period} must be finite and positive"
                    ));
                }
                if !(duty > 0.0 && duty <= 1.0) {
                    return Err(format!("diurnal duty {duty} outside (0, 1]"));
                }
                if !(0.0..=1.0).contains(&spread) {
                    return Err(format!(
                        "diurnal spread {spread} outside [0, 1]"
                    ));
                }
            }
            AvailabilityModel::Cluster { clusters, p_fail, p_recover } => {
                if clusters == 0 {
                    return Err("cluster count must be positive".into());
                }
                for (name, p) in
                    [("p_fail", p_fail), ("p_recover", p_recover)]
                {
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!(
                            "cluster {name} {p} outside [0, 1]"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Markov states needed by the cluster variant (0 otherwise).
    pub(crate) fn num_clusters(&self) -> usize {
        match self {
            AvailabilityModel::Cluster { clusters, .. } => *clusters,
            _ => 0,
        }
    }

    fn phase(spread: f64, i: usize, n: usize) -> f64 {
        spread * i as f64 / n as f64
    }

    /// Contiguous-id cluster assignment (co-located clients adjacent).
    pub(crate) fn cluster_of(i: usize, n: usize, clusters: usize) -> usize {
        (i * clusters / n).min(clusters - 1)
    }

    /// Realize one round's online flags for `n` clients at virtual time
    /// `now`. `cluster_down` holds the per-cluster Markov states across
    /// rounds; only the cluster (and iid) variants consume randomness.
    pub(crate) fn realize(
        &self,
        now: f64,
        n: usize,
        cluster_down: &mut [bool],
        rng: &mut Rng,
    ) -> Vec<bool> {
        match self {
            AvailabilityModel::Iid { p } => {
                (0..n).map(|_| rng.next_f64() < *p).collect()
            }
            AvailabilityModel::Diurnal { .. } => (0..n)
                // single source of truth with the lazy per-client path
                .map(|i| self.online_at(now, i, n).unwrap())
                .collect(),
            AvailabilityModel::Cluster { clusters, .. } => {
                self.step_clusters(cluster_down, rng);
                (0..n)
                    .map(|i| !cluster_down[Self::cluster_of(i, n, *clusters)])
                    .collect()
            }
        }
    }

    /// Advance the per-cluster Markov outage chains by one charged
    /// round (`avail:cluster`; a no-op for the other variants). Exactly
    /// the chain step [`AvailabilityModel::realize`] performs, split
    /// out so a lazy population fleet can advance the O(C) global state
    /// once per round and derive each cohort member's flag from it
    /// without realizing all N clients.
    pub fn step_clusters(&self, cluster_down: &mut [bool], rng: &mut Rng) {
        if let AvailabilityModel::Cluster { p_fail, p_recover, .. } = self {
            for down in cluster_down.iter_mut() {
                let u = rng.next_f64();
                *down = if *down { u >= *p_recover } else { u < *p_fail };
            }
        }
    }

    /// Closed-form online flag for ONE client at virtual time `now`:
    /// `Some(flag)` when the model is deterministic given the clock
    /// (diurnal windows — the same arithmetic as
    /// [`AvailabilityModel::realize`], per client), `None` when
    /// availability is a stochastic process whose realization needs the
    /// chain state or a fresh draw (iid, cluster). The lazy population
    /// fleet uses this to realize a cohort member's availability in
    /// O(1) instead of realizing the fleet.
    pub fn online_at(&self, now: f64, i: usize, n: usize) -> Option<bool> {
        match self {
            AvailabilityModel::Diurnal { period, duty, spread } => Some(
                (now / period + Self::phase(*spread, i, n)).fract() < *duty,
            ),
            _ => None,
        }
    }

    /// When every member of `cohort` is offline: the next virtual time
    /// at which one of them comes back online, if the model knows it.
    /// Diurnal windows are deterministic, so the clock can jump straight
    /// to the cohort's next window; stochastic outages (iid / cluster)
    /// return `None` — the caller charges one estimate-priced waiting
    /// round instead (see `coordinator::solvers::deadline_round`) and
    /// the next realization retries.
    pub fn next_online_time(
        &self,
        now: f64,
        cohort: &[usize],
        n: usize,
    ) -> Option<f64> {
        match self {
            AvailabilityModel::Diurnal { period, spread, .. } => {
                let mut wake = f64::INFINITY;
                for &i in cohort {
                    let x =
                        (now / period + Self::phase(*spread, i, n)).fract();
                    // client i's window reopens when its phase wraps to 0
                    wake = wake.min(now + (1.0 - x) * period);
                }
                if wake.is_finite() {
                    // nudge past the boundary so the realization at the
                    // wake time is unambiguously inside the window
                    Some(wake + period * 1e-6)
                } else {
                    None
                }
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_trace() -> TraceData {
        let mut t = TraceData::empty(3);
        t.push_round(vec![10.0, 20.5, 30.0], vec![true, true, true]);
        t.push_round(vec![11.0, 21.0, 31.25], vec![true, false, true]);
        t
    }

    #[test]
    fn csv_roundtrips_bit_for_bit() {
        let t = small_trace();
        let csv = t.to_csv();
        assert!(csv.starts_with(TRACE_CSV_HEADER));
        let parsed = TraceData::parse_csv(&csv, "mem").unwrap();
        assert_eq!(parsed, t);
        // and a second serialize is byte-identical
        assert_eq!(parsed.to_csv(), csv);
    }

    #[test]
    fn parse_errors_carry_source_and_line() {
        let cases: Vec<(&str, &str)> = vec![
            ("", "t.csv:1:"),
            ("round,client,latency,avail\n", "t.csv:1:"),
            // bad time on line 3
            ("round,client,time,available\n0,0,10,1\n0,1,x,1\n", "t.csv:3:"),
            // non-positive time
            ("round,client,time,available\n0,0,-5,1\n", "t.csv:2:"),
            // bad availability flag
            ("round,client,time,available\n0,0,10,yes\n", "t.csv:2:"),
            // wrong column count
            ("round,client,time,available\n0,0,10\n", "t.csv:2:"),
            // client out of order
            ("round,client,time,available\n0,1,10,1\n", "t.csv:2:"),
            // round out of order
            ("round,client,time,available\n0,0,10,1\n2,0,10,1\n", "t.csv:3:"),
            // header only: no rounds
            ("round,client,time,available\n", "t.csv:1:"),
            // ragged final round
            (
                "round,client,time,available\n0,0,10,1\n0,1,20,1\n1,0,10,1\n",
                "t.csv:4:",
            ),
        ];
        for (text, want) in cases {
            let e = TraceData::parse_csv(text, "t.csv").unwrap_err();
            assert!(
                e.starts_with(want),
                "error '{e}' does not start with '{want}'"
            );
        }
    }

    #[test]
    fn wrap_and_hold_extend_the_trace() {
        let t = small_trace();
        let hold = TraceReplay::from_data("mem", t.clone(), TraceMode::Hold);
        let wrap = TraceReplay::from_data("mem", t, TraceMode::Wrap);
        assert_eq!(hold.round_index(0), 0);
        assert_eq!(hold.round_index(1), 1);
        assert_eq!(hold.round_index(7), 1, "hold repeats the last round");
        assert_eq!(wrap.round_index(7), 1);
        assert_eq!(wrap.round_index(8), 0, "wrap cycles back to round 0");
        // canonical specs: hold (the default) is omitted
        assert_eq!(hold.spec(), "trace:mem");
        assert_eq!(wrap.spec(), "trace:mem:wrap");
    }

    #[test]
    fn recorder_roundtrips_through_csv() {
        let mut rec = TraceRecorder::new(2);
        rec.record(&RoundConditions {
            times: vec![5.0, 7.5],
            available: vec![true, true],
            online: vec![true, false],
        });
        rec.record(&RoundConditions {
            times: vec![5.5, 7.0],
            available: vec![false, true],
            online: vec![true, true],
        });
        assert_eq!(rec.rounds_recorded(), 2);
        // recorded availability merges dropout and offline
        let (_, a0) = rec.data().round(0);
        assert_eq!(a0, &[true, false]);
        let (_, a1) = rec.data().round(1);
        assert_eq!(a1, &[false, true]);
        let parsed =
            TraceData::parse_csv(&rec.data().to_csv(), "mem").unwrap();
        assert_eq!(&parsed, rec.data());
    }

    #[test]
    fn diurnal_windows_are_deterministic_and_phase_staggered() {
        let m = AvailabilityModel::Diurnal {
            period: 100.0,
            duty: 0.5,
            spread: 1.0,
        };
        let mut down = Vec::new();
        let mut rng = Rng::new(1);
        // 4 clients, phases 0, 0.25, 0.5, 0.75: at t = 0 clients 0 and 1
        // are inside their windows (0 and 0.25 < 0.5), 2 and 3 are not
        let on = m.realize(0.0, 4, &mut down, &mut rng);
        assert_eq!(on, vec![true, true, false, false]);
        // half a period later the window has rotated
        let on = m.realize(50.0, 4, &mut down, &mut rng);
        assert_eq!(on, vec![false, false, true, true]);
        // deterministic: no randomness consumed
        let mut rng2 = Rng::new(1);
        assert_eq!(rng.next_u64(), rng2.next_u64());
    }

    #[test]
    fn diurnal_spread_zero_is_one_shared_window() {
        let m = AvailabilityModel::Diurnal {
            period: 10.0,
            duty: 0.3,
            spread: 0.0,
        };
        let mut down = Vec::new();
        let mut rng = Rng::new(2);
        for step in 0..30 {
            let now = step as f64;
            let on = m.realize(now, 8, &mut down, &mut rng);
            assert!(
                on.iter().all(|&o| o == on[0]),
                "spread 0 must switch the whole fleet together"
            );
        }
    }

    #[test]
    fn diurnal_next_online_time_lands_inside_the_window() {
        let m = AvailabilityModel::Diurnal {
            period: 100.0,
            duty: 0.25,
            spread: 1.0,
        };
        let mut down = Vec::new();
        let mut rng = Rng::new(3);
        // at t = 30, client 0 (phase 0) is offline (0.30 >= 0.25)
        let on = m.realize(30.0, 4, &mut down, &mut rng);
        assert!(!on[0]);
        let wake = m.next_online_time(30.0, &[0], 4).unwrap();
        assert!(wake > 30.0);
        let on = m.realize(wake, 4, &mut down, &mut rng);
        assert!(on[0], "client 0 still offline at its wake time {wake}");
        // stochastic models advertise no wake time
        let iid = AvailabilityModel::Iid { p: 0.5 };
        assert_eq!(iid.next_online_time(30.0, &[0], 4), None);
    }

    #[test]
    fn online_at_matches_realized_flags() {
        let m = AvailabilityModel::Diurnal {
            period: 100.0,
            duty: 0.4,
            spread: 1.0,
        };
        let mut down = Vec::new();
        let mut rng = Rng::new(5);
        for now in [0.0, 13.0, 40.0, 77.5, 260.0] {
            let on = m.realize(now, 6, &mut down, &mut rng);
            for (i, &flag) in on.iter().enumerate() {
                assert_eq!(m.online_at(now, i, 6), Some(flag), "t={now} i={i}");
            }
        }
        // stochastic models have no closed form
        assert_eq!(AvailabilityModel::Iid { p: 0.5 }.online_at(0.0, 0, 4), None);
        let cl = AvailabilityModel::Cluster {
            clusters: 2,
            p_fail: 0.1,
            p_recover: 0.5,
        };
        assert_eq!(cl.online_at(0.0, 0, 4), None);
    }

    #[test]
    fn step_clusters_matches_realized_chain() {
        let m = AvailabilityModel::Cluster {
            clusters: 3,
            p_fail: 0.3,
            p_recover: 0.3,
        };
        // same seed, same chain: stepping the state alone must follow
        // the exact trajectory realize() walks
        let mut down_a = vec![false; 3];
        let mut down_b = vec![false; 3];
        let (mut rng_a, mut rng_b) = (Rng::new(9), Rng::new(9));
        for _ in 0..50 {
            let on = m.realize(0.0, 9, &mut down_a, &mut rng_a);
            m.step_clusters(&mut down_b, &mut rng_b);
            assert_eq!(down_a, down_b);
            for (i, &flag) in on.iter().enumerate() {
                let c = AvailabilityModel::cluster_of(i, 9, 3);
                assert_eq!(flag, !down_b[c]);
            }
        }
        // non-cluster models consume nothing and touch nothing
        let iid = AvailabilityModel::Iid { p: 0.5 };
        let mut rng = Rng::new(4);
        iid.step_clusters(&mut [], &mut rng);
        assert_eq!(rng.next_u64(), Rng::new(4).next_u64());
    }

    #[test]
    fn cluster_members_fail_together() {
        let m = AvailabilityModel::Cluster {
            clusters: 2,
            p_fail: 0.4,
            p_recover: 0.4,
        };
        let mut down = vec![false; 2];
        let mut rng = Rng::new(7);
        let mut saw_outage = false;
        for _ in 0..100 {
            let on = m.realize(0.0, 8, &mut down, &mut rng);
            // contiguous halves share one state each
            assert!(on[..4].iter().all(|&o| o == on[0]));
            assert!(on[4..].iter().all(|&o| o == on[4]));
            saw_outage |= !on[0] || !on[4];
        }
        assert!(saw_outage, "no cluster outage in 100 rounds at p = 0.4");
    }

    #[test]
    fn iid_availability_matches_probability() {
        let m = AvailabilityModel::Iid { p: 0.7 };
        let mut down = Vec::new();
        let mut rng = Rng::new(11);
        let mut online = 0usize;
        let rounds = 200;
        for _ in 0..rounds {
            let on = m.realize(0.0, 50, &mut down, &mut rng);
            online += on.iter().filter(|&&o| o).count();
        }
        let rate = online as f64 / (rounds * 50) as f64;
        assert!((rate - 0.7).abs() < 0.02, "iid online rate {rate}");
    }

    #[test]
    fn availability_validation_rejects_bad_parameters() {
        assert!(AvailabilityModel::Iid { p: 0.0 }.validate().is_err());
        assert!(AvailabilityModel::Iid { p: 1.0 }.validate().is_ok());
        assert!(AvailabilityModel::Diurnal {
            period: 0.0,
            duty: 0.5,
            spread: 0.5
        }
        .validate()
        .is_err());
        assert!(AvailabilityModel::Diurnal {
            period: 100.0,
            duty: 0.5,
            spread: 1.5
        }
        .validate()
        .is_err());
        assert!(AvailabilityModel::Cluster {
            clusters: 0,
            p_fail: 0.1,
            p_recover: 0.5
        }
        .validate()
        .is_err());
        assert!(AvailabilityModel::Cluster {
            clusters: 4,
            p_fail: 1.5,
            p_recover: 0.5
        }
        .validate()
        .is_err());
    }

    #[test]
    fn cluster_assignment_is_contiguous_and_total() {
        for (n, c) in [(8, 2), (7, 3), (3, 5), (16, 4)] {
            let mut prev = 0usize;
            for i in 0..n {
                let k = AvailabilityModel::cluster_of(i, n, c);
                assert!(k < c, "cluster {k} out of range for C = {c}");
                assert!(k >= prev, "cluster ids must be non-decreasing");
                prev = k;
            }
        }
    }
}
