//! Aggregation deadline policies (`fed::aggregation`).
//!
//! Every solver in the seed aggregated fully synchronously: one round
//! ends when the *slowest* cohort member uploads, so a single straggler
//! stalls everyone (the premise of the paper — and the cost its FLANP
//! algorithm attacks by shrinking the cohort). Production FL stacks
//! attack the same cost from the other side: the server sets a **round
//! deadline** `t_deadline`, aggregates whatever arrived by then, and
//! discards (or buffers) the rest — see Hard et al., *Learning from
//! straggler clients in federated learning* (2024) and the tier-based
//! deadlines of TiFL (Chai et al., 2020).
//!
//! This module is the policy layer for that behavior:
//!
//! * [`DeadlinePolicy`] — the configuration: how each round's deadline
//!   is chosen ([`DeadlinePolicy::Sync`] waits forever, reproducing the
//!   seed bit-for-bit; `Fixed` / `Quantile` / `Adaptive` close rounds
//!   early). Parsed from the CLI with [`DeadlinePolicy::parse`].
//! * [`DeadlineController`] — the per-run state machine: computes one
//!   deadline per round from the cohort's *estimated* speeds (the same
//!   TiFL-style EWMA estimates FLANP ranks its prefixes from, so the
//!   deadline choice and the speed estimator interact exactly as the
//!   paper's interplay suggests) and, for the adaptive variant, tunes
//!   itself from observed arrival fractions.
//!
//! Deadlines are expressed in **compute time for the whole round**: a
//! client performing `tau` local updates at per-update time `T_i`
//! arrives iff `tau * T_i <= deadline`. The virtual clock then charges
//! `min(deadline, slowest cohort member)` per round — see
//! [`crate::fed::VirtualClock::charge_round_deadline`].
//!
//! ```
//! use flanp::fed::DeadlinePolicy;
//!
//! // spec grammar: sync | fixed:T | quantile:Q | adaptive:F
//! let p = DeadlinePolicy::parse("quantile:0.8").unwrap();
//! assert_eq!(p, DeadlinePolicy::Quantile { q: 0.8 });
//! assert_eq!(p.spec(), "quantile:0.8");
//! // every canonical spec re-parses to the same policy
//! assert_eq!(DeadlinePolicy::parse(&p.spec()).unwrap(), p);
//! ```

/// How the server chooses each round's aggregation deadline.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum DeadlinePolicy {
    /// No deadline: the server waits for every cohort member (the
    /// paper's synchronous model, bit-identical to the seed).
    #[default]
    Sync,
    /// A fixed compute-time budget per round. `t` is the *total* round
    /// time (it already includes the `tau` local updates): a client
    /// arrives iff `tau * T_i <= t`.
    Fixed { t: f64 },
    /// `deadline = tau * Q-quantile of the cohort's estimated
    /// per-update times`, `q` in (0, 1]. `q = 1` budgets for the
    /// slowest *estimated* member — under drift the realized slowest
    /// may still miss, which is exactly the TiFL-style interaction
    /// between deadline choice and speed estimation.
    Quantile { q: f64 },
    /// Self-tuning: starts from the cohort's estimated median and
    /// rescales itself multiplicatively each round to keep the arrival
    /// fraction near `target`.
    Adaptive { target: f64 },
}

impl DeadlinePolicy {
    /// Parse a policy spec. Grammar:
    ///
    /// ```text
    ///   sync | fixed:T | quantile:Q | adaptive:F
    /// ```
    ///
    /// `T` is a positive round compute-time budget, `Q` a quantile in
    /// (0, 1], `F` a target arrival fraction in (0, 1].
    ///
    /// ```
    /// use flanp::fed::DeadlinePolicy;
    /// assert_eq!(DeadlinePolicy::parse("sync").unwrap(), DeadlinePolicy::Sync);
    /// assert_eq!(
    ///     DeadlinePolicy::parse("fixed:1500").unwrap(),
    ///     DeadlinePolicy::Fixed { t: 1500.0 }
    /// );
    /// assert!(DeadlinePolicy::parse("quantile:1.5").is_err());
    /// ```
    pub fn parse(spec: &str) -> Result<Self, String> {
        let (kind, rest) = match spec.split_once(':') {
            Some((k, r)) => (k, Some(r)),
            None => (spec, None),
        };
        let num = |what: &str| -> Result<f64, String> {
            let tok = rest.ok_or_else(|| {
                format!("missing {what} in deadline spec '{spec}'")
            })?;
            tok.parse().map_err(|_| {
                format!("bad {what} '{tok}' in deadline spec '{spec}'")
            })
        };
        let policy = match kind {
            "sync" => {
                if rest.is_some() {
                    return Err(format!(
                        "sync takes no parameter in deadline spec '{spec}'"
                    ));
                }
                DeadlinePolicy::Sync
            }
            "fixed" => DeadlinePolicy::Fixed { t: num("budget")? },
            "quantile" => DeadlinePolicy::Quantile { q: num("quantile")? },
            "adaptive" => DeadlinePolicy::Adaptive { target: num("target")? },
            _ => {
                return Err(format!(
                    "unknown deadline policy '{spec}' \
                     (expected sync | fixed:T | quantile:Q | adaptive:F)"
                ))
            }
        };
        policy.validate().map_err(|e| format!("{e} in deadline spec '{spec}'"))?;
        Ok(policy)
    }

    /// Canonical spec string; `parse(spec()) == self` for every policy.
    pub fn spec(&self) -> String {
        match self {
            DeadlinePolicy::Sync => "sync".into(),
            DeadlinePolicy::Fixed { t } => format!("fixed:{t}"),
            DeadlinePolicy::Quantile { q } => format!("quantile:{q}"),
            DeadlinePolicy::Adaptive { target } => format!("adaptive:{target}"),
        }
    }

    /// Structural sanity check (configs can be built without `parse`).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            DeadlinePolicy::Sync => Ok(()),
            DeadlinePolicy::Fixed { t } => {
                // +inf is legal: an unreachable deadline is exactly Sync
                if t > 0.0 {
                    Ok(())
                } else {
                    Err(format!("fixed deadline budget {t} must be positive"))
                }
            }
            DeadlinePolicy::Quantile { q } => {
                if q > 0.0 && q <= 1.0 {
                    Ok(())
                } else {
                    Err(format!("quantile {q} outside (0, 1]"))
                }
            }
            DeadlinePolicy::Adaptive { target } => {
                if target > 0.0 && target <= 1.0 {
                    Ok(())
                } else {
                    Err(format!("adaptive target fraction {target} outside (0, 1]"))
                }
            }
        }
    }
}

/// Bounds on the adaptive policy's self-tuned scale so one pathological
/// round cannot drive the deadline to zero or infinity.
const ADAPTIVE_SCALE_MIN: f64 = 0.25;
const ADAPTIVE_SCALE_MAX: f64 = 64.0;
/// Multiplicative loosen / tighten factors (AIMD-flavored: loosen fast
/// when rounds starve, tighten gently while arrivals are plentiful).
const ADAPTIVE_LOOSEN: f64 = 1.25;
const ADAPTIVE_TIGHTEN: f64 = 0.97;

/// Per-run deadline state: computes one deadline per round and (for
/// [`DeadlinePolicy::Adaptive`]) learns from arrival outcomes.
///
/// The controller is deterministic: the same policy, estimate stream and
/// arrival history always produce the same deadline sequence.
///
/// ```
/// use flanp::fed::{DeadlineController, DeadlinePolicy};
///
/// // deadline arithmetic: quantile policies budget tau local updates at
/// // the Q-quantile of the cohort's estimated per-update times
/// let ddl = DeadlineController::new(DeadlinePolicy::Quantile { q: 0.5 });
/// let est = [10.0, 20.0, 30.0, 40.0];
/// assert_eq!(ddl.round_deadline(&est, 5), 5.0 * 20.0);
/// // sync never imposes a deadline
/// let sync = DeadlineController::new(DeadlinePolicy::Sync);
/// assert_eq!(sync.round_deadline(&est, 5), f64::INFINITY);
/// ```
#[derive(Clone, Debug)]
pub struct DeadlineController {
    policy: DeadlinePolicy,
    /// adaptive multiplier on the estimated-median budget
    scale: f64,
}

impl DeadlineController {
    pub fn new(policy: DeadlinePolicy) -> Self {
        DeadlineController { policy, scale: 1.0 }
    }

    pub fn policy(&self) -> &DeadlinePolicy {
        &self.policy
    }

    /// The adaptive policy's current scale (1.0 unless adapted).
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// This round's deadline for a cohort whose *estimated* per-update
    /// times are `est`, performing `updates` local updates each.
    /// Returns `f64::INFINITY` when the policy never closes early.
    pub fn round_deadline(&self, est: &[f64], updates: usize) -> f64 {
        match self.policy {
            DeadlinePolicy::Sync => f64::INFINITY,
            DeadlinePolicy::Fixed { t } => t,
            DeadlinePolicy::Quantile { q } => {
                updates as f64 * quantile(est, q)
            }
            DeadlinePolicy::Adaptive { .. } => {
                self.scale * updates as f64 * quantile(est, 0.5)
            }
        }
    }

    /// Sketch-backed twin of [`DeadlineController::round_deadline`] for
    /// population-scale fleets: the quantile comes from a
    /// [`crate::fed::QuantileSketch`] of the cohort's estimated
    /// per-update times instead of a sorted copy of them, so computing
    /// a deadline never materializes (or re-sorts) the estimate vector.
    /// While the sketch is exact — which it always is at cohort sizes
    /// under its capacity — the result is bit-identical to
    /// `round_deadline` over the same estimates; see the
    /// sketch-approximation pitfall in `docs/scenarios.md` for why tiny
    /// cohorts should keep the sketch in its exact regime.
    ///
    /// ```
    /// use flanp::fed::{DeadlineController, DeadlinePolicy, QuantileSketch};
    ///
    /// let ddl = DeadlineController::new(DeadlinePolicy::Quantile { q: 0.5 });
    /// let est = [10.0, 20.0, 30.0, 40.0];
    /// let mut sk = QuantileSketch::new(64);
    /// for &e in &est {
    ///     sk.push(e);
    /// }
    /// assert_eq!(
    ///     ddl.round_deadline_sketch(&sk, 5),
    ///     ddl.round_deadline(&est, 5)
    /// );
    /// ```
    pub fn round_deadline_sketch(
        &self,
        est: &crate::fed::sketch::QuantileSketch,
        updates: usize,
    ) -> f64 {
        match self.policy {
            DeadlinePolicy::Sync => f64::INFINITY,
            DeadlinePolicy::Fixed { t } => t,
            DeadlinePolicy::Quantile { q } => updates as f64 * est.query(q),
            DeadlinePolicy::Adaptive { .. } => {
                self.scale * updates as f64 * est.query(0.5)
            }
        }
    }

    /// Feed one round's outcome back: `arrived` out of the `cohort`
    /// clients the deadline could have admitted (callers pass the
    /// *available* participants, not the intended cohort — dropped
    /// clients can never arrive by any deadline and must not drive the
    /// tuning). Only the adaptive policy changes state: below-target
    /// arrival fractions loosen the deadline, at-or-above-target rounds
    /// tighten it gently; all-dropout rounds (`cohort == 0`) are
    /// ignored.
    pub fn observe_round(&mut self, arrived: usize, cohort: usize) {
        if let DeadlinePolicy::Adaptive { target } = self.policy {
            if cohort == 0 {
                return;
            }
            let frac = arrived as f64 / cohort as f64;
            let factor =
                if frac < target { ADAPTIVE_LOOSEN } else { ADAPTIVE_TIGHTEN };
            self.scale =
                (self.scale * factor).clamp(ADAPTIVE_SCALE_MIN, ADAPTIVE_SCALE_MAX);
        }
    }
}

/// Empirical `q`-quantile (nearest-rank, `q` in (0, 1]) of `xs`.
/// `q = 1` is the maximum; an empty slice yields `+inf` so a deadline
/// over an empty cohort never rejects anyone.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::INFINITY;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_variant() {
        for spec in ["sync", "fixed:1500", "quantile:0.8", "adaptive:0.9"] {
            let p = DeadlinePolicy::parse(spec).unwrap();
            assert_eq!(p.spec(), spec);
            assert_eq!(DeadlinePolicy::parse(&p.spec()).unwrap(), p, "{spec}");
        }
    }

    #[test]
    fn parse_errors_name_the_full_spec() {
        for bad in [
            "fixed",          // missing budget
            "fixed:-3",       // non-positive budget
            "fixed:x",        // non-numeric
            "quantile:0",     // outside (0, 1]
            "quantile:1.5",   // outside (0, 1]
            "adaptive:0",     // outside (0, 1]
            "sync:1",         // sync takes no parameter
            "lenient:2",      // unknown policy
        ] {
            let e = DeadlinePolicy::parse(bad).unwrap_err();
            assert!(e.contains(bad), "error '{e}' does not name '{bad}'");
        }
    }

    #[test]
    fn validate_accepts_infinite_fixed_budget() {
        assert!(DeadlinePolicy::Fixed { t: f64::INFINITY }.validate().is_ok());
        assert!(DeadlinePolicy::Fixed { t: 0.0 }.validate().is_err());
    }

    #[test]
    fn quantile_is_nearest_rank() {
        let xs = [40.0, 10.0, 30.0, 20.0];
        assert_eq!(quantile(&xs, 0.25), 10.0);
        assert_eq!(quantile(&xs, 0.5), 20.0);
        assert_eq!(quantile(&xs, 0.75), 30.0);
        assert_eq!(quantile(&xs, 1.0), 40.0);
        // tiny q still returns the minimum, never an out-of-range rank
        assert_eq!(quantile(&xs, 0.01), 10.0);
        assert_eq!(quantile(&[], 0.5), f64::INFINITY);
    }

    #[test]
    fn sync_and_fixed_deadlines() {
        let est = [100.0, 200.0];
        let sync = DeadlineController::new(DeadlinePolicy::Sync);
        assert_eq!(sync.round_deadline(&est, 10), f64::INFINITY);
        let fixed = DeadlineController::new(DeadlinePolicy::Fixed { t: 750.0 });
        // fixed budgets ignore the cohort and the update count
        assert_eq!(fixed.round_deadline(&est, 10), 750.0);
        assert_eq!(fixed.round_deadline(&[], 1), 750.0);
    }

    #[test]
    fn quantile_deadline_scales_with_updates() {
        let ddl = DeadlineController::new(DeadlinePolicy::Quantile { q: 1.0 });
        assert_eq!(ddl.round_deadline(&[50.0, 500.0], 10), 5000.0);
        assert_eq!(ddl.round_deadline(&[50.0, 500.0], 1), 500.0);
    }

    #[test]
    fn sketch_deadline_matches_exact_deadline() {
        use crate::fed::sketch::QuantileSketch;
        let est = [120.0, 40.0, 300.0, 80.0, 220.0];
        let mut sk = QuantileSketch::new(64);
        for &e in &est {
            sk.push(e);
        }
        for policy in [
            DeadlinePolicy::Sync,
            DeadlinePolicy::Fixed { t: 750.0 },
            DeadlinePolicy::Quantile { q: 0.8 },
            DeadlinePolicy::Adaptive { target: 0.9 },
        ] {
            let mut ddl = DeadlineController::new(policy.clone());
            assert_eq!(
                ddl.round_deadline_sketch(&sk, 10),
                ddl.round_deadline(&est, 10),
                "{policy:?}"
            );
            // the adaptive scale feeds through identically
            ddl.observe_round(0, 5);
            assert_eq!(
                ddl.round_deadline_sketch(&sk, 10),
                ddl.round_deadline(&est, 10),
                "{policy:?} after adaptation"
            );
        }
        // empty sketch == empty slice: never rejects anyone
        let empty = QuantileSketch::new(64);
        let ddl = DeadlineController::new(DeadlinePolicy::Quantile { q: 0.5 });
        assert_eq!(ddl.round_deadline_sketch(&empty, 3), f64::INFINITY);
    }

    #[test]
    fn adaptive_loosens_when_starved_and_tightens_when_full() {
        let mut ddl =
            DeadlineController::new(DeadlinePolicy::Adaptive { target: 0.8 });
        let est = [100.0; 4];
        let d0 = ddl.round_deadline(&est, 10);
        assert_eq!(d0, 1000.0); // scale 1.0 * tau * median
        ddl.observe_round(0, 4); // starved round: loosen
        assert!(ddl.round_deadline(&est, 10) > d0);
        let loosened = ddl.round_deadline(&est, 10);
        ddl.observe_round(4, 4); // full round: tighten gently
        assert!(ddl.round_deadline(&est, 10) < loosened);
    }

    #[test]
    fn adaptive_scale_is_clamped() {
        let mut ddl =
            DeadlineController::new(DeadlinePolicy::Adaptive { target: 0.5 });
        for _ in 0..1000 {
            ddl.observe_round(0, 10);
        }
        assert_eq!(ddl.scale(), ADAPTIVE_SCALE_MAX);
        for _ in 0..10_000 {
            ddl.observe_round(10, 10);
        }
        assert_eq!(ddl.scale(), ADAPTIVE_SCALE_MIN);
        // empty cohorts never move the scale
        let before = ddl.scale();
        ddl.observe_round(0, 0);
        assert_eq!(ddl.scale(), before);
    }

    #[test]
    fn non_adaptive_policies_ignore_outcomes() {
        let mut ddl = DeadlineController::new(DeadlinePolicy::Quantile { q: 0.5 });
        let before = ddl.round_deadline(&[10.0, 20.0], 5);
        ddl.observe_round(0, 2);
        assert_eq!(ddl.round_deadline(&[10.0, 20.0], 5), before);
    }
}
