//! Discrete-event virtual wall-clock.
//!
//! The paper measures wall-clock in units of the per-update times T_i:
//! one synchronous round with participant set P and tau local updates
//! costs `tau * max_{i in P} T_i` (the server waits for the slowest
//! participant — Propositions 2 and 3). An optional per-round
//! communication overhead models the upload/broadcast latency.

#[derive(Clone, Debug)]
pub struct VirtualClock {
    now: f64,
    /// fixed per-round communication overhead (0 by default: the paper's
    /// analysis is computation-dominated)
    pub comm_overhead: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0, comm_overhead: 0.0 }
    }

    pub fn with_comm_overhead(comm: f64) -> Self {
        VirtualClock { now: 0.0, comm_overhead: comm }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by one synchronous round: `updates` local updates on every
    /// participant with speeds `t_participants`; returns the round cost.
    pub fn advance_round(&mut self, t_participants: &[f64], updates: usize) -> f64 {
        let slowest = t_participants
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        let cost = updates as f64 * slowest + self.comm_overhead;
        self.now += cost;
        cost
    }

    /// Advance by a heterogeneous round (FedNova): client i performs
    /// `updates[i]` updates at speed `t[i]`; the server waits for the
    /// slowest *product*.
    pub fn advance_round_hetero(&mut self, t: &[f64], updates: &[usize]) -> f64 {
        assert_eq!(t.len(), updates.len());
        let slowest = t
            .iter()
            .zip(updates)
            .map(|(ti, &u)| ti * u as f64)
            .fold(0.0f64, f64::max);
        let cost = slowest + self.comm_overhead;
        self.now += cost;
        cost
    }

    pub fn reset(&mut self) {
        self.now = 0.0;
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_cost_is_tau_times_slowest() {
        let mut c = VirtualClock::new();
        let cost = c.advance_round(&[10.0, 30.0, 20.0], 5);
        assert_eq!(cost, 150.0);
        assert_eq!(c.now(), 150.0);
        c.advance_round(&[1.0], 2);
        assert_eq!(c.now(), 152.0);
    }

    #[test]
    fn monotonicity() {
        let mut c = VirtualClock::new();
        let mut prev = 0.0;
        for k in 1..50 {
            c.advance_round(&[k as f64], k);
            assert!(c.now() > prev);
            prev = c.now();
        }
    }

    #[test]
    fn comm_overhead_added_per_round() {
        let mut c = VirtualClock::with_comm_overhead(7.0);
        c.advance_round(&[10.0], 1);
        assert_eq!(c.now(), 17.0);
    }

    #[test]
    fn hetero_round_uses_product() {
        let mut c = VirtualClock::new();
        // slow client does few updates: 100*1=100; fast does many: 10*20=200
        let cost = c.advance_round_hetero(&[100.0, 10.0], &[1, 20]);
        assert_eq!(cost, 200.0);
    }

    #[test]
    fn faster_prefix_is_cheaper() {
        // the FLANP premise: a round over the fastest m < n clients costs
        // no more than a round over all n
        let speeds = vec![10.0, 20.0, 80.0, 400.0];
        let mut a = VirtualClock::new();
        let mut b = VirtualClock::new();
        a.advance_round(&speeds[..2], 10);
        b.advance_round(&speeds, 10);
        assert!(a.now() <= b.now());
    }
}
