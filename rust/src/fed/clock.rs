//! Discrete-event virtual wall-clock.
//!
//! The paper measures wall-clock in units of the per-update times T_i:
//! one synchronous round with participant set P and tau local updates
//! costs `tau * max_{i in P} T_i` (the server waits for the slowest
//! participant — Propositions 2 and 3). An optional per-round
//! communication overhead models the upload/broadcast latency.
//!
//! The clock exposes two layers:
//!
//! * the **event interface** ([`VirtualClock::charge_round`] /
//!   [`VirtualClock::charge_round_hetero`]): charges realized per-client
//!   times and records one [`RoundEvent`] per round — who the straggler
//!   was, how many clients dropped. This is what the coordinator uses.
//! * the **legacy helpers** ([`VirtualClock::advance_round`] /
//!   [`VirtualClock::advance_round_hetero`]): cost arithmetic only, kept
//!   for direct use in tests and theory checks. Both layers share the
//!   same cost formula, so they agree bit-for-bit on identical inputs.

/// One completed communication round as charged to the clock.
#[derive(Clone, Debug)]
pub struct RoundEvent {
    /// 0-based index among charged rounds
    pub round: usize,
    /// total cost charged (compute critical path + comm overhead)
    pub cost: f64,
    /// client id on the critical path (this round's straggler)
    pub slowest: Option<usize>,
    /// realized per-update time of that client
    pub slowest_time: f64,
    /// clients whose update arrived
    pub participants: usize,
    /// clients that dropped (held the deadline open, uploaded nothing)
    pub dropped: usize,
}

#[derive(Clone, Debug)]
pub struct VirtualClock {
    now: f64,
    /// fixed per-round communication overhead (0 by default: the paper's
    /// analysis is computation-dominated)
    pub comm_overhead: f64,
    events: Vec<RoundEvent>,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0, comm_overhead: 0.0, events: Vec::new() }
    }

    pub fn with_comm_overhead(comm: f64) -> Self {
        VirtualClock { now: 0.0, comm_overhead: comm, events: Vec::new() }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Every round charged through the event interface, in order. This
    /// stream (straggler identity + realized critical-path time per
    /// round) is the substrate for deadline/async aggregation policies
    /// (ROADMAP "fed::system follow-ons"); per-round dropout counts are
    /// additionally persisted on each trace row.
    pub fn events(&self) -> &[RoundEvent] {
        &self.events
    }

    /// Total dropouts recorded across all charged rounds.
    pub fn total_dropped(&self) -> usize {
        self.events.iter().map(|e| e.dropped).sum()
    }

    /// Charge one synchronous round: client `ids[k]` needs
    /// `updates * times[k]` compute time and the server waits for the
    /// slowest member. Dropped clients are included in `ids`/`times`
    /// (they hold the round open until the deadline) but counted in
    /// `dropped` because their upload never arrives.
    pub fn charge_round(
        &mut self,
        ids: &[usize],
        times: &[f64],
        updates: usize,
        dropped: usize,
    ) -> RoundEvent {
        debug_assert_eq!(ids.len(), times.len());
        debug_assert!(
            !ids.is_empty(),
            "charging a round with an empty participant set"
        );
        debug_assert!(dropped <= ids.len());
        let mut slowest = None;
        let mut slowest_time = 0.0f64;
        for (k, &t) in times.iter().enumerate() {
            if t > slowest_time || slowest.is_none() {
                slowest_time = slowest_time.max(t);
                slowest = Some(ids[k]);
            }
        }
        let cost = updates as f64 * slowest_time + self.comm_overhead;
        self.now += cost;
        let ev = RoundEvent {
            round: self.events.len(),
            cost,
            slowest,
            slowest_time,
            participants: ids.len() - dropped,
            dropped,
        };
        self.events.push(ev.clone());
        ev
    }

    /// Charge a heterogeneous round (FedNova): client `ids[k]` performs
    /// `updates[k]` updates at per-update time `times[k]`; the server
    /// waits for the slowest *product*.
    pub fn charge_round_hetero(
        &mut self,
        ids: &[usize],
        times: &[f64],
        updates: &[usize],
        dropped: usize,
    ) -> RoundEvent {
        debug_assert_eq!(ids.len(), times.len());
        debug_assert_eq!(ids.len(), updates.len());
        debug_assert!(
            !ids.is_empty(),
            "charging a round with an empty participant set"
        );
        let mut slowest = None;
        let mut slowest_total = 0.0f64;
        let mut slowest_time = 0.0f64;
        for (k, (&t, &u)) in times.iter().zip(updates).enumerate() {
            let total = t * u as f64;
            if total > slowest_total || slowest.is_none() {
                slowest_total = slowest_total.max(total);
                slowest_time = t;
                slowest = Some(ids[k]);
            }
        }
        let cost = slowest_total + self.comm_overhead;
        self.now += cost;
        let ev = RoundEvent {
            round: self.events.len(),
            cost,
            slowest,
            slowest_time,
            participants: ids.len() - dropped,
            dropped,
        };
        self.events.push(ev.clone());
        ev
    }

    /// Legacy helper: advance by one synchronous round of `updates` local
    /// updates on every participant with speeds `t_participants`; returns
    /// the round cost. Records no event. An empty slice would silently
    /// charge only `comm_overhead`, which is always a caller bug.
    pub fn advance_round(&mut self, t_participants: &[f64], updates: usize) -> f64 {
        debug_assert!(
            !t_participants.is_empty(),
            "advance_round over an empty participant slice"
        );
        let slowest = t_participants
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        let cost = updates as f64 * slowest + self.comm_overhead;
        self.now += cost;
        cost
    }

    /// Legacy helper: heterogeneous round — client i performs
    /// `updates[i]` updates at speed `t[i]`; the server waits for the
    /// slowest *product*. Records no event.
    pub fn advance_round_hetero(&mut self, t: &[f64], updates: &[usize]) -> f64 {
        assert_eq!(t.len(), updates.len());
        debug_assert!(
            !t.is_empty(),
            "advance_round_hetero over an empty participant slice"
        );
        let slowest = t
            .iter()
            .zip(updates)
            .map(|(ti, &u)| ti * u as f64)
            .fold(0.0f64, f64::max);
        let cost = slowest + self.comm_overhead;
        self.now += cost;
        cost
    }

    pub fn reset(&mut self) {
        self.now = 0.0;
        self.events.clear();
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_cost_is_tau_times_slowest() {
        let mut c = VirtualClock::new();
        let cost = c.advance_round(&[10.0, 30.0, 20.0], 5);
        assert_eq!(cost, 150.0);
        assert_eq!(c.now(), 150.0);
        c.advance_round(&[1.0], 2);
        assert_eq!(c.now(), 152.0);
    }

    #[test]
    fn monotonicity() {
        let mut c = VirtualClock::new();
        let mut prev = 0.0;
        for k in 1..50 {
            c.advance_round(&[k as f64], k);
            assert!(c.now() > prev);
            prev = c.now();
        }
    }

    #[test]
    fn comm_overhead_added_per_round() {
        let mut c = VirtualClock::with_comm_overhead(7.0);
        c.advance_round(&[10.0], 1);
        assert_eq!(c.now(), 17.0);
    }

    #[test]
    fn hetero_round_uses_product() {
        let mut c = VirtualClock::new();
        // slow client does few updates: 100*1=100; fast does many: 10*20=200
        let cost = c.advance_round_hetero(&[100.0, 10.0], &[1, 20]);
        assert_eq!(cost, 200.0);
    }

    #[test]
    fn faster_prefix_is_cheaper() {
        // the FLANP premise: a round over the fastest m < n clients costs
        // no more than a round over all n
        let speeds = vec![10.0, 20.0, 80.0, 400.0];
        let mut a = VirtualClock::new();
        let mut b = VirtualClock::new();
        a.advance_round(&speeds[..2], 10);
        b.advance_round(&speeds, 10);
        assert!(a.now() <= b.now());
    }

    #[test]
    fn charge_round_matches_advance_round_and_records_event() {
        let speeds = [10.0, 30.0, 20.0];
        let mut legacy = VirtualClock::with_comm_overhead(3.0);
        let mut event = VirtualClock::with_comm_overhead(3.0);
        let cost = legacy.advance_round(&speeds, 5);
        let ev = event.charge_round(&[7, 8, 9], &speeds, 5, 1);
        assert_eq!(ev.cost, cost);
        assert_eq!(event.now(), legacy.now());
        assert_eq!(ev.slowest, Some(8), "straggler is the slowest client");
        assert_eq!(ev.slowest_time, 30.0);
        assert_eq!(ev.participants, 2);
        assert_eq!(ev.dropped, 1);
        assert_eq!(event.events().len(), 1);
        assert_eq!(event.total_dropped(), 1);
        // legacy path records no events
        assert!(legacy.events().is_empty());
    }

    #[test]
    fn charge_round_hetero_matches_advance_round_hetero() {
        let (t, u) = ([100.0, 10.0], [1usize, 20]);
        let mut legacy = VirtualClock::new();
        let mut event = VirtualClock::new();
        let cost = legacy.advance_round_hetero(&t, &u);
        let ev = event.charge_round_hetero(&[3, 4], &t, &u, 0);
        assert_eq!(ev.cost, cost);
        assert_eq!(ev.slowest, Some(4), "critical path is the max product");
        assert_eq!(event.now(), legacy.now());
    }

    #[test]
    fn reset_clears_events() {
        let mut c = VirtualClock::new();
        c.charge_round(&[0], &[5.0], 2, 0);
        c.reset();
        assert_eq!(c.now(), 0.0);
        assert!(c.events().is_empty());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "empty participant slice")]
    fn advance_round_rejects_empty_participants() {
        // regression: an empty fold used to silently return comm_overhead
        VirtualClock::new().advance_round(&[], 5);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "empty participant set")]
    fn charge_round_rejects_empty_participants() {
        VirtualClock::new().charge_round(&[], &[], 5, 0);
    }
}
