//! Discrete-event virtual wall-clock.
//!
//! The paper measures wall-clock in units of the per-update times T_i:
//! one synchronous round with participant set P and tau local updates
//! costs `tau * max_{i in P} T_i` (the server waits for the slowest
//! participant — Propositions 2 and 3). An optional per-round
//! communication overhead models the upload/broadcast latency.
//!
//! The clock exposes three layers:
//!
//! * the **event interface** ([`VirtualClock::charge_round`] /
//!   [`VirtualClock::charge_round_hetero`]): charges realized per-client
//!   times and records one [`RoundEvent`] per round — who the straggler
//!   was, how many clients dropped. This is what the coordinator uses.
//! * the **deadline interface**
//!   ([`VirtualClock::charge_round_deadline`] /
//!   [`VirtualClock::charge_round_hetero_deadline`] /
//!   [`VirtualClock::charge_until`]): semi-synchronous rounds close at
//!   `min(deadline, slowest cohort member)` — a partial round charges
//!   only the deadline, never the straggler beyond it — and buffered-
//!   async servers advance the clock to arbitrary flush times. The
//!   synchronous interface is the special case `deadline = +inf`, so
//!   both agree bit-for-bit (see the regression tests in
//!   `tests/deadline.rs`).
//! * the **legacy helpers** ([`VirtualClock::advance_round`] /
//!   [`VirtualClock::advance_round_hetero`]): cost arithmetic only, kept
//!   for direct use in tests and theory checks. All layers share the
//!   same cost formula, so they agree bit-for-bit on identical inputs.
//!
//! Deadline arithmetic in one doc-test:
//!
//! ```
//! use flanp::fed::VirtualClock;
//!
//! let mut c = VirtualClock::new();
//! // cohort of 3, 10 updates each: products are 100, 400, 200.
//! // A 250-budget deadline closes the round early: the straggler
//! // (client 1, product 400) misses and the round costs 250, not 400.
//! let ev = c.charge_round_deadline(&[0, 1, 2], &[10.0, 40.0, 20.0], 10, 250.0, 0, 1);
//! assert_eq!(ev.cost, 250.0);
//! assert_eq!(ev.missed, 1);
//! assert_eq!(ev.participants, 2);
//! // with deadline = +inf the same round reproduces the synchronous
//! // cost exactly: tau * max T_i = 400
//! let ev = c.charge_round_deadline(&[0, 1, 2], &[10.0, 40.0, 20.0], 10, f64::INFINITY, 0, 0);
//! assert_eq!(ev.cost, 400.0);
//! assert_eq!(c.now(), 650.0);
//! ```

/// One completed communication round as charged to the clock.
#[derive(Clone, Debug)]
pub struct RoundEvent {
    /// 0-based index among charged rounds
    pub round: usize,
    /// total cost charged (compute critical path + comm overhead)
    pub cost: f64,
    /// client id on the critical path (this round's straggler)
    pub slowest: Option<usize>,
    /// realized per-update time of that client
    pub slowest_time: f64,
    /// clients whose update arrived and was aggregated
    pub participants: usize,
    /// clients that dropped (uploaded nothing at all this round)
    pub dropped: usize,
    /// clients that were computing but missed the aggregation deadline
    /// (their update is discarded; 0 under synchronous aggregation)
    pub missed: usize,
    /// clients whose in-flight work the server actively cancelled at the
    /// k-th arrival (over-selection, `fed::selection`; 0 unless the
    /// round was charged via [`VirtualClock::charge_round_cancel`])
    pub cancelled: usize,
}

#[derive(Clone, Debug)]
pub struct VirtualClock {
    now: f64,
    /// fixed per-round communication overhead (0 by default: the paper's
    /// analysis is computation-dominated)
    pub comm_overhead: f64,
    events: Vec<RoundEvent>,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0, comm_overhead: 0.0, events: Vec::new() }
    }

    pub fn with_comm_overhead(comm: f64) -> Self {
        VirtualClock { now: 0.0, comm_overhead: comm, events: Vec::new() }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Every round charged through the event interface, in order. This
    /// stream (straggler identity + realized critical-path time per
    /// round) is the substrate the deadline/async aggregation policies
    /// ([`crate::fed::DeadlinePolicy`], the FedBuff solver) are built
    /// on; per-round dropout and deadline-miss counts are additionally
    /// persisted on each trace row.
    pub fn events(&self) -> &[RoundEvent] {
        &self.events
    }

    /// Total dropouts recorded across all charged rounds.
    pub fn total_dropped(&self) -> usize {
        self.events.iter().map(|e| e.dropped).sum()
    }

    /// Total deadline misses recorded across all charged rounds.
    pub fn total_missed(&self) -> usize {
        self.events.iter().map(|e| e.missed).sum()
    }

    /// Total cancellations recorded across all charged rounds
    /// (over-selection's actively cancelled in-flight work).
    pub fn total_cancelled(&self) -> usize {
        self.events.iter().map(|e| e.cancelled).sum()
    }

    /// Shared core of every round-charging path: the critical path is
    /// the max per-client total `times[k] * updates[k]`, truncated at
    /// the aggregation deadline. `deadline = +inf` reproduces the
    /// synchronous formula bit-for-bit (`min(+inf, x) == x`, and the
    /// max total is the one product `t_max * updates` the synchronous
    /// path computes).
    fn charge_core(
        &mut self,
        ids: &[usize],
        times: &[f64],
        total_of: impl Fn(usize) -> f64,
        deadline: f64,
        dropped: usize,
        missed: usize,
        cancelled: usize,
    ) -> RoundEvent {
        debug_assert_eq!(ids.len(), times.len());
        debug_assert!(
            !ids.is_empty(),
            "charging a round with an empty participant set"
        );
        debug_assert!(dropped + missed + cancelled <= ids.len());
        debug_assert!(deadline > 0.0, "non-positive deadline {deadline}");
        let mut slowest = None;
        let mut slowest_total = 0.0f64;
        let mut slowest_time = 0.0f64;
        for (k, &t) in times.iter().enumerate() {
            let total = total_of(k);
            if total > slowest_total || slowest.is_none() {
                slowest_total = total;
                slowest_time = t;
                slowest = Some(ids[k]);
            }
        }
        let cost = slowest_total.min(deadline) + self.comm_overhead;
        self.now += cost;
        let ev = RoundEvent {
            round: self.events.len(),
            cost,
            slowest,
            slowest_time,
            participants: ids.len() - dropped - missed - cancelled,
            dropped,
            missed,
            cancelled,
        };
        self.events.push(ev.clone());
        ev
    }

    /// Charge one synchronous round: client `ids[k]` needs
    /// `updates * times[k]` compute time and the server waits for the
    /// slowest member. Dropped clients are included in `ids`/`times`
    /// (they hold the round open) but counted in `dropped` because
    /// their upload never arrives.
    pub fn charge_round(
        &mut self,
        ids: &[usize],
        times: &[f64],
        updates: usize,
        dropped: usize,
    ) -> RoundEvent {
        self.charge_round_deadline(ids, times, updates, f64::INFINITY, dropped, 0)
    }

    /// Charge one deadline-bounded round of `updates` local updates per
    /// client: the server aggregates whatever arrived by `deadline` and
    /// the round costs `min(deadline, updates * max times)` — a partial
    /// round charges only the deadline, not the straggler beyond it.
    /// `missed` counts the clients whose compute exceeded the deadline
    /// (classified by the caller, which also discards their updates).
    pub fn charge_round_deadline(
        &mut self,
        ids: &[usize],
        times: &[f64],
        updates: usize,
        deadline: f64,
        dropped: usize,
        missed: usize,
    ) -> RoundEvent {
        self.charge_core(
            ids,
            times,
            |k| times[k] * updates as f64,
            deadline,
            dropped,
            missed,
            0,
        )
    }

    /// Over-selection round (`fed::selection`): the server asked this
    /// whole cohort for updates but statistically needs only the first
    /// `target` arrivals — at the `target`-th arrival it CANCELS the
    /// remaining in-flight work instead of waiting or discarding the
    /// round. `cutoff` is `min(deadline, total of the target-th
    /// arrival)`, computed by the caller (which owns the
    /// arrival/dropout classification —
    /// `coordinator::solvers::deadline_round`); the round costs
    /// `min(cutoff, slowest cohort member)` and the `cancelled` tail is
    /// accounted separately from deadline `missed` (an actively
    /// cancelled client is a selection-policy cost, not a deadline
    /// miss). With `cutoff = deadline` and `cancelled = 0` this is
    /// bit-identical to [`VirtualClock::charge_round_deadline`].
    #[allow(clippy::too_many_arguments)]
    pub fn charge_round_cancel(
        &mut self,
        ids: &[usize],
        times: &[f64],
        updates: usize,
        cutoff: f64,
        dropped: usize,
        cancelled: usize,
    ) -> RoundEvent {
        self.charge_core(
            ids,
            times,
            |k| times[k] * updates as f64,
            cutoff,
            dropped,
            0,
            cancelled,
        )
    }

    /// Charge a heterogeneous round (FedNova): client `ids[k]` performs
    /// `updates[k]` updates at per-update time `times[k]`; the server
    /// waits for the slowest *product*.
    pub fn charge_round_hetero(
        &mut self,
        ids: &[usize],
        times: &[f64],
        updates: &[usize],
        dropped: usize,
    ) -> RoundEvent {
        self.charge_round_hetero_deadline(
            ids,
            times,
            updates,
            f64::INFINITY,
            dropped,
            0,
        )
    }

    /// Deadline-bounded heterogeneous round: like
    /// [`VirtualClock::charge_round_hetero`] but the server stops
    /// waiting at `deadline`.
    pub fn charge_round_hetero_deadline(
        &mut self,
        ids: &[usize],
        times: &[f64],
        updates: &[usize],
        deadline: f64,
        dropped: usize,
        missed: usize,
    ) -> RoundEvent {
        debug_assert_eq!(ids.len(), updates.len());
        self.charge_core(
            ids,
            times,
            |k| times[k] * updates[k] as f64,
            deadline,
            dropped,
            missed,
            0,
        )
    }

    /// Charge an availability wait: nobody in the round's cohort was
    /// observably online, so the server idles — no participants, nothing
    /// dropped or missed — until `t`, the cohort's next availability
    /// window. With an unknown wake time (stochastic outages) callers
    /// price the wait themselves — one estimate-priced round, see
    /// `coordinator::solvers::deadline_round` — and pass the resulting
    /// `t > now`, so an all-down round is always charged real time
    /// (plus the communication overhead) before the next realization
    /// retries. Offline clients are never charged as stragglers —
    /// unavailability is observable at selection time, unlike dropout
    /// (see `fed::traces`).
    pub fn charge_wait(&mut self, t: f64) -> RoundEvent {
        self.charge_until(t, 0, 0, 0)
    }

    /// Advance the clock to the absolute time `t` and record the
    /// interval as one event (buffered-async aggregation: the server
    /// flushes its buffer at the K-th arrival). `t` earlier than `now`
    /// charges only the communication overhead — with a nonzero
    /// overhead, back-to-back flushes serialize on the server.
    pub fn charge_until(
        &mut self,
        t: f64,
        participants: usize,
        dropped: usize,
        missed: usize,
    ) -> RoundEvent {
        let cost = (t - self.now).max(0.0) + self.comm_overhead;
        self.now += cost;
        let ev = RoundEvent {
            round: self.events.len(),
            cost,
            slowest: None,
            slowest_time: 0.0,
            participants,
            dropped,
            missed,
            cancelled: 0,
        };
        self.events.push(ev.clone());
        ev
    }

    /// Legacy helper: advance by one synchronous round of `updates` local
    /// updates on every participant with speeds `t_participants`; returns
    /// the round cost. Records no event. An empty slice would silently
    /// charge only `comm_overhead`, which is always a caller bug.
    pub fn advance_round(&mut self, t_participants: &[f64], updates: usize) -> f64 {
        debug_assert!(
            !t_participants.is_empty(),
            "advance_round over an empty participant slice"
        );
        let slowest = t_participants
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        let cost = updates as f64 * slowest + self.comm_overhead;
        self.now += cost;
        cost
    }

    /// Legacy helper: heterogeneous round — client i performs
    /// `updates[i]` updates at speed `t[i]`; the server waits for the
    /// slowest *product*. Records no event.
    pub fn advance_round_hetero(&mut self, t: &[f64], updates: &[usize]) -> f64 {
        assert_eq!(t.len(), updates.len());
        debug_assert!(
            !t.is_empty(),
            "advance_round_hetero over an empty participant slice"
        );
        let slowest = t
            .iter()
            .zip(updates)
            .map(|(ti, &u)| ti * u as f64)
            .fold(0.0f64, f64::max);
        let cost = slowest + self.comm_overhead;
        self.now += cost;
        cost
    }

    pub fn reset(&mut self) {
        self.now = 0.0;
        self.events.clear();
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_cost_is_tau_times_slowest() {
        let mut c = VirtualClock::new();
        let cost = c.advance_round(&[10.0, 30.0, 20.0], 5);
        assert_eq!(cost, 150.0);
        assert_eq!(c.now(), 150.0);
        c.advance_round(&[1.0], 2);
        assert_eq!(c.now(), 152.0);
    }

    #[test]
    fn monotonicity() {
        let mut c = VirtualClock::new();
        let mut prev = 0.0;
        for k in 1..50 {
            c.advance_round(&[k as f64], k);
            assert!(c.now() > prev);
            prev = c.now();
        }
    }

    #[test]
    fn comm_overhead_added_per_round() {
        let mut c = VirtualClock::with_comm_overhead(7.0);
        c.advance_round(&[10.0], 1);
        assert_eq!(c.now(), 17.0);
    }

    #[test]
    fn hetero_round_uses_product() {
        let mut c = VirtualClock::new();
        // slow client does few updates: 100*1=100; fast does many: 10*20=200
        let cost = c.advance_round_hetero(&[100.0, 10.0], &[1, 20]);
        assert_eq!(cost, 200.0);
    }

    #[test]
    fn faster_prefix_is_cheaper() {
        // the FLANP premise: a round over the fastest m < n clients costs
        // no more than a round over all n
        let speeds = vec![10.0, 20.0, 80.0, 400.0];
        let mut a = VirtualClock::new();
        let mut b = VirtualClock::new();
        a.advance_round(&speeds[..2], 10);
        b.advance_round(&speeds, 10);
        assert!(a.now() <= b.now());
    }

    #[test]
    fn charge_round_matches_advance_round_and_records_event() {
        let speeds = [10.0, 30.0, 20.0];
        let mut legacy = VirtualClock::with_comm_overhead(3.0);
        let mut event = VirtualClock::with_comm_overhead(3.0);
        let cost = legacy.advance_round(&speeds, 5);
        let ev = event.charge_round(&[7, 8, 9], &speeds, 5, 1);
        assert_eq!(ev.cost, cost);
        assert_eq!(event.now(), legacy.now());
        assert_eq!(ev.slowest, Some(8), "straggler is the slowest client");
        assert_eq!(ev.slowest_time, 30.0);
        assert_eq!(ev.participants, 2);
        assert_eq!(ev.dropped, 1);
        assert_eq!(ev.missed, 0);
        assert_eq!(event.events().len(), 1);
        assert_eq!(event.total_dropped(), 1);
        // legacy path records no events
        assert!(legacy.events().is_empty());
    }

    #[test]
    fn charge_round_hetero_matches_advance_round_hetero() {
        let (t, u) = ([100.0, 10.0], [1usize, 20]);
        let mut legacy = VirtualClock::new();
        let mut event = VirtualClock::new();
        let cost = legacy.advance_round_hetero(&t, &u);
        let ev = event.charge_round_hetero(&[3, 4], &t, &u, 0);
        assert_eq!(ev.cost, cost);
        assert_eq!(ev.slowest, Some(4), "critical path is the max product");
        assert_eq!(event.now(), legacy.now());
    }

    #[test]
    fn deadline_truncates_the_straggler() {
        let mut c = VirtualClock::with_comm_overhead(3.0);
        // products: 50, 150, 100 at tau = 5; deadline 120 cuts client 8
        let ev = c.charge_round_deadline(
            &[7, 8, 9],
            &[10.0, 30.0, 20.0],
            5,
            120.0,
            0,
            1,
        );
        assert_eq!(ev.cost, 123.0);
        assert_eq!(ev.participants, 2);
        assert_eq!(ev.missed, 1);
        // the straggler identity is still the critical-path client
        assert_eq!(ev.slowest, Some(8));
        assert_eq!(c.total_missed(), 1);
    }

    #[test]
    fn infinite_deadline_is_bit_identical_to_sync() {
        let speeds = [110.25, 317.5, 50.125, 499.9];
        let mut sync = VirtualClock::with_comm_overhead(1.5);
        let mut ddl = VirtualClock::with_comm_overhead(1.5);
        for tau in 1..20usize {
            let a = sync.charge_round(&[0, 1, 2, 3], &speeds, tau, 0);
            let b = ddl.charge_round_deadline(
                &[0, 1, 2, 3],
                &speeds,
                tau,
                f64::INFINITY,
                0,
                0,
            );
            assert_eq!(a.cost, b.cost, "tau {tau}");
            assert_eq!(a.slowest, b.slowest);
            assert_eq!(a.slowest_time, b.slowest_time);
        }
        assert_eq!(sync.now(), ddl.now());
    }

    #[test]
    fn deadline_larger_than_straggler_changes_nothing() {
        let mut a = VirtualClock::new();
        let mut b = VirtualClock::new();
        let ea = a.charge_round(&[0, 1], &[10.0, 20.0], 5, 0);
        let eb = b.charge_round_deadline(&[0, 1], &[10.0, 20.0], 5, 100.1, 0, 0);
        assert_eq!(ea.cost, eb.cost);
        assert_eq!(a.now(), b.now());
    }

    #[test]
    fn hetero_deadline_truncates_the_product() {
        let mut c = VirtualClock::new();
        // products 100 and 200; deadline 150 cuts the 20-update client
        let ev = c.charge_round_hetero_deadline(
            &[3, 4],
            &[100.0, 10.0],
            &[1, 20],
            150.0,
            0,
            1,
        );
        assert_eq!(ev.cost, 150.0);
        assert_eq!(ev.missed, 1);
    }

    #[test]
    fn cancel_round_charges_the_kth_arrival() {
        let mut c = VirtualClock::with_comm_overhead(3.0);
        // totals at tau = 5: 50, 150, 100, 125. Over-selected round with
        // target 2: the 2nd arrival is client 9 (total 100), so the two
        // slower clients are cancelled and the round costs 100, not 150.
        let ev = c.charge_round_cancel(
            &[7, 8, 9, 10],
            &[10.0, 30.0, 20.0, 25.0],
            5,
            100.0,
            0,
            2,
        );
        assert_eq!(ev.cost, 103.0);
        assert_eq!(ev.participants, 2);
        assert_eq!(ev.cancelled, 2);
        assert_eq!(ev.missed, 0);
        // the straggler identity is still the critical-path client
        assert_eq!(ev.slowest, Some(8));
        assert_eq!(c.total_cancelled(), 2);
        assert_eq!(c.total_missed(), 0);
    }

    #[test]
    fn cancel_with_full_cutoff_is_bit_identical_to_deadline() {
        let speeds = [110.25, 317.5, 50.125, 499.9];
        let mut ddl = VirtualClock::with_comm_overhead(1.5);
        let mut cancel = VirtualClock::with_comm_overhead(1.5);
        for tau in 1..20usize {
            let deadline = 1000.0 * tau as f64;
            let a = ddl.charge_round_deadline(
                &[0, 1, 2, 3],
                &speeds,
                tau,
                deadline,
                0,
                0,
            );
            let b = cancel.charge_round_cancel(
                &[0, 1, 2, 3],
                &speeds,
                tau,
                deadline,
                0,
                0,
            );
            assert_eq!(a.cost, b.cost, "tau {tau}");
            assert_eq!(a.slowest, b.slowest);
            assert_eq!(a.participants, b.participants);
        }
        assert_eq!(ddl.now(), cancel.now());
        assert_eq!(cancel.total_cancelled(), 0);
    }

    #[test]
    fn charge_until_advances_to_absolute_time() {
        let mut c = VirtualClock::new();
        let ev = c.charge_until(40.0, 4, 1, 0);
        assert_eq!(ev.cost, 40.0);
        assert_eq!(c.now(), 40.0);
        assert_eq!(ev.participants, 4);
        assert_eq!(ev.dropped, 1);
        let ev = c.charge_until(55.5, 2, 0, 0);
        assert_eq!(ev.cost, 15.5);
        assert_eq!(c.now(), 55.5);
        // a flush at (or before) the current time is free without comm
        let ev = c.charge_until(55.5, 1, 0, 0);
        assert_eq!(ev.cost, 0.0);
        assert_eq!(c.now(), 55.5);
    }

    #[test]
    fn charge_wait_is_an_idle_event() {
        let mut c = VirtualClock::new();
        let ev = c.charge_wait(25.0);
        assert_eq!(ev.cost, 25.0);
        assert_eq!(ev.participants, 0);
        assert_eq!(ev.dropped + ev.missed, 0);
        assert_eq!(ev.slowest, None, "a wait has no straggler");
        assert_eq!(c.now(), 25.0);
        // unknown wake time (t <= now): a free idle tick without comm
        let ev = c.charge_wait(10.0);
        assert_eq!(ev.cost, 0.0);
        assert_eq!(c.now(), 25.0);
    }

    #[test]
    fn charge_until_serializes_on_comm_overhead() {
        let mut c = VirtualClock::with_comm_overhead(2.0);
        c.charge_until(10.0, 1, 0, 0);
        assert_eq!(c.now(), 12.0);
        // a flush "due" at t=11 (already past) still pays the overhead
        c.charge_until(11.0, 1, 0, 0);
        assert_eq!(c.now(), 14.0);
    }

    #[test]
    fn reset_clears_events() {
        let mut c = VirtualClock::new();
        c.charge_round(&[0], &[5.0], 2, 0);
        c.reset();
        assert_eq!(c.now(), 0.0);
        assert!(c.events().is_empty());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "empty participant slice")]
    fn advance_round_rejects_empty_participants() {
        // regression: an empty fold used to silently return comm_overhead
        VirtualClock::new().advance_round(&[], 5);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "empty participant set")]
    fn charge_round_rejects_empty_participants() {
        VirtualClock::new().charge_round(&[], &[], 5, 0);
    }
}
