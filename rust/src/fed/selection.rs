//! Predictive client selection (`fed::selection`).
//!
//! The paper's FLANP adapts *how many* clients participate as statistical
//! accuracy grows, and `fed::system`'s estimator adapts *which* — but
//! both react to realized speeds only after paying for a doomed cohort.
//! Production FL stacks predict instead (Hard et al., *Federated Learning
//! for Mobile Keyboard Prediction*; TiFL's latency-aware selection):
//!
//! * **Over-selection** — ask `ceil(F * k)` clients for a round that
//!   statistically needs `k`, aggregate the first `k` arrivals and
//!   *cancel* the stragglers' in-flight work, charging the clock only to
//!   the k-th arrival ([`overselect_target`], [`parse_overselect`]; the
//!   clock side is [`crate::fed::VirtualClock::charge_round_cancel`]).
//! * **Availability forecasting** — a per-client window tracker
//!   ([`AvailabilityForecaster`]) learned online from the same realized
//!   `RoundConditions::online` bits the `SpeedEstimator` sees, consulted
//!   at selection time so FLANP / TiFL skip clients whose predicted
//!   availability window does not cover the round.
//!
//! Both are deterministic and RNG-free: the forecaster only reads
//! already-realized online bits (which are drawn on the system stream
//! regardless), so enabling either knob never perturbs any random
//! stream — with `overselect = 1.0` and no forecaster every solver is
//! bit-identical to the pre-selection-layer behavior (pinned by
//! `rust/tests/golden.rs` and `rust/tests/selection.rs`).
//!
//! Forecast state is **sparse**: a `HashMap` keyed by the client ids
//! actually observed, so the lazy population path
//! ([`crate::fed::LazyFleet`]) stays O(cohort) per round — an id with no
//! entry predicts the optimistic prior, which makes every per-client
//! prediction stateless-reconstructible from (policy, observations).
//!
//! ```
//! use flanp::fed::selection::{overselect_target, ForecastPolicy};
//!
//! // grammar: forecast:ewma:A | forecast:window:W (prefix optional)
//! let p = ForecastPolicy::parse("forecast:ewma:0.3").unwrap();
//! assert_eq!(p, ForecastPolicy::Ewma { alpha: 0.3 });
//! assert_eq!(p.spec(), "forecast:ewma:0.3");
//! // ceil(1.3 * 10) = 13 candidates for a 10-client round
//! assert_eq!(overselect_target(10, 1.3, 64), 13);
//! // the target never exceeds the fleet and never shrinks the cohort
//! assert_eq!(overselect_target(10, 1.3, 11), 11);
//! assert_eq!(overselect_target(10, 1.0, 64), 10);
//! ```

use std::collections::HashMap;

/// Over-selection factor meaning "off": select exactly `k` clients.
pub const OVERSELECT_OFF: f64 = 1.0;

/// Largest accepted over-selection factor — past this the "cancelled
/// tail" is most of the fleet and the wasted-work pitfall dominates
/// (docs/scenarios.md §8).
pub const OVERSELECT_MAX: f64 = 16.0;

/// Optimistic prior for never-observed clients: assumed online, so the
/// forecaster never starves selection of clients it has not tried yet.
const PRIOR_ONLINE: f64 = 1.0;

/// Predicted-online decision threshold on the tracked score.
const ONLINE_THRESHOLD: f64 = 0.5;

/// Largest window the `window:W` tracker accepts (observations are
/// packed into a u64 bitmask so per-client state stays constant-size).
pub const FORECAST_WINDOW_MAX: usize = 64;

/// Parse an over-selection spec. Grammar: `overselect:F` (the bare `F`
/// is accepted too, for CLI ergonomics). `F` must be in
/// `[1.0, OVERSELECT_MAX]`; `1.0` means off.
///
/// ```
/// use flanp::fed::selection::parse_overselect;
/// assert_eq!(parse_overselect("overselect:1.3").unwrap(), 1.3);
/// assert_eq!(parse_overselect("1.0").unwrap(), 1.0);
/// assert!(parse_overselect("overselect:0.5").is_err());
/// ```
pub fn parse_overselect(spec: &str) -> Result<f64, String> {
    let tok = spec.strip_prefix("overselect:").unwrap_or(spec);
    let f: f64 = tok
        .parse()
        .map_err(|_| format!("bad factor '{tok}' in overselect spec '{spec}'"))?;
    validate_overselect(f).map_err(|e| format!("{e} in overselect spec '{spec}'"))?;
    Ok(f)
}

/// Structural check for an over-selection factor (configs can be built
/// without `parse`).
pub fn validate_overselect(f: f64) -> Result<(), String> {
    if f.is_finite() && (OVERSELECT_OFF..=OVERSELECT_MAX).contains(&f) {
        Ok(())
    } else {
        Err(format!(
            "overselect factor {f} outside [{OVERSELECT_OFF}, {OVERSELECT_MAX}]"
        ))
    }
}

/// How many clients to *select* for a round that statistically needs
/// `k`: `ceil(F * k)`, never below `k`, never above the fleet.
pub fn overselect_target(k: usize, factor: f64, n_total: usize) -> usize {
    ((k as f64 * factor).ceil() as usize).max(k).min(n_total)
}

/// How a client's availability window is tracked.
#[derive(Clone, Debug, PartialEq)]
pub enum ForecastPolicy {
    /// Exponential moving average of the realized online bit with
    /// smoothing `alpha` in (0, 1]: `score += alpha * (online - score)`.
    Ewma { alpha: f64 },
    /// Fraction of online observations over the last `w` rounds the
    /// client was looked at (`w` in `1..=FORECAST_WINDOW_MAX`).
    Window { w: usize },
}

impl ForecastPolicy {
    /// Parse a forecast spec. Grammar:
    ///
    /// ```text
    ///   forecast:ewma:A | forecast:window:W
    /// ```
    ///
    /// (the `forecast:` prefix is optional, for CLI ergonomics).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let body = spec.strip_prefix("forecast:").unwrap_or(spec);
        let policy = match body.split_once(':') {
            Some(("ewma", a)) => {
                let alpha: f64 = a.parse().map_err(|_| {
                    format!("bad alpha '{a}' in forecast spec '{spec}'")
                })?;
                ForecastPolicy::Ewma { alpha }
            }
            Some(("window", w)) => {
                let w: usize = w.parse().map_err(|_| {
                    format!("bad window '{w}' in forecast spec '{spec}'")
                })?;
                ForecastPolicy::Window { w }
            }
            _ => {
                return Err(format!(
                    "unknown forecast policy '{spec}' \
                     (expected forecast:ewma:A | forecast:window:W)"
                ))
            }
        };
        policy.validate().map_err(|e| format!("{e} in forecast spec '{spec}'"))?;
        Ok(policy)
    }

    /// Canonical spec string; `parse(spec()) == self` for every policy.
    pub fn spec(&self) -> String {
        match self {
            ForecastPolicy::Ewma { alpha } => format!("forecast:ewma:{alpha}"),
            ForecastPolicy::Window { w } => format!("forecast:window:{w}"),
        }
    }

    /// Structural sanity check (configs can be built without `parse`).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            ForecastPolicy::Ewma { alpha } => {
                if alpha > 0.0 && alpha <= 1.0 {
                    Ok(())
                } else {
                    Err(format!("forecast ewma alpha {alpha} outside (0, 1]"))
                }
            }
            ForecastPolicy::Window { w } => {
                if (1..=FORECAST_WINDOW_MAX).contains(&w) {
                    Ok(())
                } else {
                    Err(format!(
                        "forecast window {w} outside 1..={FORECAST_WINDOW_MAX}"
                    ))
                }
            }
        }
    }
}

/// Per-client tracked state: `score` is the EWMA estimate; `bits`/`len`
/// pack the sliding window (only the fields the policy uses are read).
#[derive(Clone, Copy, Debug, Default)]
struct ClientWindow {
    score: f64,
    bits: u64,
    len: u32,
}

/// Online availability forecaster: one window tracker per *observed*
/// client, fed the realized `online` bit every time a client appears in
/// a selected cohort, and consulted at selection time to skip clients
/// whose predicted window does not cover the round.
///
/// ```
/// use flanp::fed::selection::{AvailabilityForecaster, ForecastPolicy};
///
/// let mut f = AvailabilityForecaster::new(ForecastPolicy::Ewma { alpha: 0.5 });
/// // never observed: optimistic prior, predicted online
/// assert!(f.predicted_online(7));
/// f.observe(7, false);
/// f.observe(7, false);
/// assert!(!f.predicted_online(7)); // 1.0 -> 0.5 -> 0.25
/// f.observe(7, true);
/// f.observe(7, true);
/// assert!(f.predicted_online(7));
/// ```
#[derive(Clone, Debug)]
pub struct AvailabilityForecaster {
    policy: ForecastPolicy,
    state: HashMap<usize, ClientWindow>,
}

impl AvailabilityForecaster {
    pub fn new(policy: ForecastPolicy) -> Self {
        AvailabilityForecaster { policy, state: HashMap::new() }
    }

    pub fn policy(&self) -> &ForecastPolicy {
        &self.policy
    }

    /// Number of clients with tracked state (O(observed ids), never
    /// O(population) — the lazy fleet's contract).
    pub fn tracked(&self) -> usize {
        self.state.len()
    }

    /// Ids with tracked state, unordered (the lazy fleet folds these
    /// into its memory-footprint accounting).
    pub fn tracked_ids(&self) -> impl Iterator<Item = usize> + '_ {
        self.state.keys().copied()
    }

    /// Feed one realized online bit for one client. Deterministic and
    /// RNG-free: the bit was already drawn on the system stream.
    pub fn observe(&mut self, client: usize, online: bool) {
        let w = self.state.entry(client).or_insert(ClientWindow {
            score: PRIOR_ONLINE,
            bits: 0,
            len: 0,
        });
        match self.policy {
            ForecastPolicy::Ewma { alpha } => {
                let obs = if online { 1.0 } else { 0.0 };
                w.score += alpha * (obs - w.score);
            }
            ForecastPolicy::Window { w: width } => {
                w.bits = (w.bits << 1) | online as u64;
                w.len = (w.len + 1).min(width as u32);
            }
        }
    }

    /// Predicted probability the client is online next round; clients
    /// never observed predict the optimistic prior (1.0).
    pub fn predict(&self, client: usize) -> f64 {
        let w = match self.state.get(&client) {
            Some(w) => w,
            None => return PRIOR_ONLINE,
        };
        match self.policy {
            ForecastPolicy::Ewma { .. } => w.score,
            ForecastPolicy::Window { w: width } => {
                if w.len == 0 {
                    return PRIOR_ONLINE;
                }
                let kept = w.len.min(width as u32);
                let mask = if kept >= 64 { u64::MAX } else { (1u64 << kept) - 1 };
                (w.bits & mask).count_ones() as f64 / kept as f64
            }
        }
    }

    /// Selection-time decision: does the predicted availability window
    /// cover the round?
    pub fn predicted_online(&self, client: usize) -> bool {
        self.predict(client) >= ONLINE_THRESHOLD
    }

    /// Pick up to `k` clients from a fastest-first `ranking`, preferring
    /// clients predicted online; if fewer than `k` are predicted online
    /// the fastest predicted-offline clients top the cohort back up (the
    /// forecaster reorders within the ranking, it never shrinks the
    /// cohort — an all-wrong forecast degrades to the plain prefix).
    pub fn filter_prefix(&self, ranking: &[usize], k: usize) -> Vec<usize> {
        let mut picked = Vec::with_capacity(k.min(ranking.len()));
        let mut skipped = Vec::new();
        for &i in ranking {
            if picked.len() == k {
                break;
            }
            if self.predicted_online(i) {
                picked.push(i);
            } else {
                skipped.push(i);
            }
        }
        for i in skipped {
            if picked.len() == k {
                break;
            }
            picked.push(i);
        }
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overselect_parse_roundtrips_and_rejects() {
        assert_eq!(parse_overselect("overselect:1.3").unwrap(), 1.3);
        assert_eq!(parse_overselect("2").unwrap(), 2.0);
        for bad in ["overselect:0.9", "overselect:x", "overselect:inf", "-1"] {
            let e = parse_overselect(bad).unwrap_err();
            assert!(e.contains(bad), "error '{e}' does not name '{bad}'");
        }
    }

    #[test]
    fn overselect_target_is_ceil_clamped() {
        assert_eq!(overselect_target(10, 1.3, 100), 13);
        assert_eq!(overselect_target(10, 1.0, 100), 10);
        assert_eq!(overselect_target(3, 1.1, 100), 4); // ceil(3.3)
        assert_eq!(overselect_target(10, 4.0, 12), 12); // fleet-clamped
        assert_eq!(overselect_target(0, 1.3, 100), 0);
    }

    #[test]
    fn forecast_parse_roundtrips_every_variant() {
        for spec in ["forecast:ewma:0.3", "forecast:window:8"] {
            let p = ForecastPolicy::parse(spec).unwrap();
            assert_eq!(p.spec(), spec);
            assert_eq!(ForecastPolicy::parse(&p.spec()).unwrap(), p, "{spec}");
        }
        // the forecast: prefix is optional
        assert_eq!(
            ForecastPolicy::parse("ewma:0.3").unwrap(),
            ForecastPolicy::Ewma { alpha: 0.3 }
        );
    }

    #[test]
    fn forecast_parse_errors_name_the_full_spec() {
        for bad in [
            "forecast:ewma:0",    // alpha outside (0, 1]
            "forecast:ewma:1.5",  // alpha outside (0, 1]
            "forecast:ewma:x",    // non-numeric
            "forecast:window:0",  // window outside 1..=64
            "forecast:window:65", // window outside 1..=64
            "forecast:median:3",  // unknown policy
            "forecast:ewma",      // missing parameter
        ] {
            let e = ForecastPolicy::parse(bad).unwrap_err();
            assert!(e.contains(bad), "error '{e}' does not name '{bad}'");
        }
    }

    #[test]
    fn ewma_tracker_follows_the_online_bit() {
        let mut f =
            AvailabilityForecaster::new(ForecastPolicy::Ewma { alpha: 0.5 });
        assert_eq!(f.predict(3), 1.0);
        f.observe(3, false);
        assert_eq!(f.predict(3), 0.5);
        assert!(f.predicted_online(3)); // threshold is inclusive
        f.observe(3, false);
        assert_eq!(f.predict(3), 0.25);
        assert!(!f.predicted_online(3));
        f.observe(3, true);
        f.observe(3, true);
        assert!(f.predicted_online(3));
        assert_eq!(f.tracked(), 1);
    }

    #[test]
    fn window_tracker_is_a_sliding_majority() {
        let mut f =
            AvailabilityForecaster::new(ForecastPolicy::Window { w: 4 });
        assert!(f.predicted_online(0));
        for _ in 0..4 {
            f.observe(0, false);
        }
        assert_eq!(f.predict(0), 0.0);
        // three online observations push the 4-window majority back up
        f.observe(0, true);
        f.observe(0, true);
        f.observe(0, true);
        assert_eq!(f.predict(0), 0.75);
        assert!(f.predicted_online(0));
        // old observations slide out entirely
        f.observe(0, true);
        assert_eq!(f.predict(0), 1.0);
    }

    #[test]
    fn window_width_64_masks_correctly() {
        let mut f =
            AvailabilityForecaster::new(ForecastPolicy::Window { w: 64 });
        for _ in 0..64 {
            f.observe(9, true);
        }
        assert_eq!(f.predict(9), 1.0);
        f.observe(9, false);
        assert_eq!(f.predict(9), 63.0 / 64.0);
    }

    #[test]
    fn filter_prefix_prefers_predicted_online_but_never_shrinks() {
        let mut f =
            AvailabilityForecaster::new(ForecastPolicy::Ewma { alpha: 1.0 });
        f.observe(0, false); // fastest client predicted offline
        f.observe(2, false);
        let ranking = [0, 1, 2, 3, 4];
        // predicted-online clients fill first, in ranking order
        assert_eq!(f.filter_prefix(&ranking, 3), vec![1, 3, 4]);
        // not enough predicted online: fastest skipped clients top up
        assert_eq!(f.filter_prefix(&ranking, 4), vec![1, 3, 4, 0]);
        assert_eq!(f.filter_prefix(&ranking, 5), vec![1, 3, 4, 0, 2]);
        // k past the ranking just returns everything reordered
        assert_eq!(f.filter_prefix(&ranking, 9).len(), 5);
        // an untouched forecaster is the identity on prefixes
        let g = AvailabilityForecaster::new(ForecastPolicy::Ewma { alpha: 0.5 });
        assert_eq!(g.filter_prefix(&ranking, 3), vec![0, 1, 2]);
    }
}
