//! The simulated heterogeneous client fleet.
//!
//! Owns the mapping client -> (data shard, system conditions, minibatch
//! RNG), the oracle fastest-first ordering, the realized per-round
//! heterogeneity process ([`SystemState`]) and the online speed
//! estimates ([`SpeedEstimator`]) FLANP ranks its prefixes from. All
//! batch assembly is fill-into-buffer so the coordinator's round loop
//! does not allocate.

use crate::data::{Dataset, Shard};
use crate::fed::selection::{AvailabilityForecaster, ForecastPolicy};
use crate::fed::speed::sort_fastest_first;
use crate::fed::system::{RoundConditions, SpeedEstimator, SystemModel, SystemState};
use crate::fed::tiers::{TierPolicy, TierScheduler};
use crate::util::Rng;

/// Default EWMA smoothing for the online estimator; overridden from
/// `ExperimentConfig::ewma_alpha` by `setup::build_fleet`.
pub const DEFAULT_EWMA_ALPHA: f64 = 0.25;

pub struct ClientFleet {
    pub dataset: Dataset,
    pub shards: Vec<Shard>,
    /// oracle base times T_i indexed by client id (the system model's
    /// base draw; realized per-round times may drift from these)
    pub speeds: Vec<f64>,
    /// client ids sorted fastest-first by ORACLE base speed
    pub order: Vec<usize>,
    /// realized per-round heterogeneity process
    pub system: SystemState,
    /// online EWMA estimates of per-update times (TiFL-style)
    pub estimates: SpeedEstimator,
    /// optional TiFL tier scheduler over the estimates (`fed::tiers`);
    /// enabled by [`ClientFleet::ensure_tiers`] when the experiment uses
    /// tier-cached ranking or the tifl solver
    pub tiers: Option<TierScheduler>,
    /// optional availability forecaster (`fed::selection`), learned
    /// online from the realized online bits in [`ClientFleet::
    /// realize_round`] and consulted by [`ClientFleet::select_cohort`].
    /// None (the default) leaves every selection path bit-identical to
    /// the pre-forecast behavior.
    pub forecast: Option<AvailabilityForecaster>,
    /// per-client held-out rows reserved at the TAIL of each shard for
    /// per-client accuracy evaluation (`coordinator::eval::ClientEval`);
    /// 0 (the default) keeps every training path bit-identical to the
    /// pre-holdout behavior. Set via [`ClientFleet::set_holdout`].
    holdout: usize,
    rngs: Vec<Rng>,
}

impl ClientFleet {
    pub fn new(
        dataset: Dataset,
        shards: Vec<Shard>,
        system_model: &SystemModel,
        rng: &mut Rng,
    ) -> Self {
        Self::with_alpha(dataset, shards, system_model, DEFAULT_EWMA_ALPHA, rng)
    }

    /// Like [`ClientFleet::new`] with an explicit estimator smoothing
    /// (`ExperimentConfig::ewma_alpha` — validate the config first).
    pub fn with_alpha(
        dataset: Dataset,
        shards: Vec<Shard>,
        system_model: &SystemModel,
        ewma_alpha: f64,
        rng: &mut Rng,
    ) -> Self {
        Self::with_options(dataset, shards, system_model, ewma_alpha, false, rng)
    }

    /// Like [`ClientFleet::with_alpha`], optionally recording every
    /// realized round for trace export (`ExperimentConfig::record_trace`
    /// / `flanp run --record-trace`). Recording starts BEFORE the
    /// profiling probe, so the exported trace's round 0 is the probe and
    /// a replay primes the speed estimator exactly as this run did.
    pub fn with_options(
        dataset: Dataset,
        shards: Vec<Shard>,
        system_model: &SystemModel,
        ewma_alpha: f64,
        record_trace: bool,
        rng: &mut Rng,
    ) -> Self {
        let n = shards.len();
        // every scenario consumes the same base-draw RNG budget (see
        // SpeedModel::draw), and trace replays take the recorded probe
        // as their base — so the forks below never depend on the model
        let speeds = system_model.draw_base(rng, n);
        let order = sort_fastest_first(&speeds);
        let rngs: Vec<Rng> = (0..n).map(|i| rng.fork(i as u64)).collect();
        // the system stream is forked AFTER the per-client minibatch
        // streams, so every scenario consumes exactly the seed's draw
        // sequence for data synthesis and batch sampling
        let sys_rng = rng.fork(n as u64);
        let mut system =
            SystemState::new(system_model.clone(), speeds.clone(), sys_rng);
        if record_trace {
            system.enable_recording();
        }
        // profiling probe (TiFL tiering): one realized observation primes
        // the estimator before any round is charged; under static
        // dynamics this is exactly T_i, so estimate-based ranking
        // reproduces the oracle ranking bit-for-bit
        let probe = system.next_round();
        let estimates = SpeedEstimator::new(&probe.times, ewma_alpha);
        ClientFleet {
            dataset,
            shards,
            speeds,
            order,
            system,
            estimates,
            tiers: None,
            forecast: None,
            holdout: 0,
            rngs,
        }
    }

    /// Enable availability forecasting (`ExperimentConfig::forecast` /
    /// `--forecast`). Consumes no RNG and touches no realized state, so
    /// enabling it right after construction (as `setup::build_fleet`
    /// does) cannot perturb any scenario draw.
    pub fn set_forecast(&mut self, policy: ForecastPolicy) {
        self.forecast = Some(AvailabilityForecaster::new(policy));
    }

    pub fn num_clients(&self) -> usize {
        self.shards.len()
    }

    /// Realize the next round's conditions for every client at virtual
    /// time 0 (kept for tests and scenarios without time-based
    /// availability). The process advances globally (all clients, every
    /// round), so realized trajectories are independent of which
    /// clients are active.
    pub fn next_round_conditions(&mut self) -> RoundConditions {
        self.system.next_round()
    }

    /// Realize the next round's conditions at virtual time `now`
    /// (diurnal availability windows are time-based).
    pub fn next_round_conditions_at(&mut self, now: f64) -> RoundConditions {
        self.system.next_round_at(now)
    }

    /// One round's shared orchestration step for every solver: realize
    /// the next conditions at virtual time `now` and split the intended
    /// cohort into the clients whose upload arrives (`participants`) vs
    /// offline clients and dropouts. Offline clients
    /// (`!cond.online[i]`) are observable at selection time and must be
    /// SKIPPED — never charged; silent dropouts hold the round open
    /// until the deadline. The caller charges the clock over the ONLINE
    /// cohort (`cond.online_of(active)`, which
    /// `coordinator::solvers::deadline_round` does) and aggregates only
    /// the participants.
    pub fn realize_round(
        &mut self,
        active: &[usize],
        now: f64,
    ) -> (RoundConditions, Vec<usize>) {
        let cond = self.next_round_conditions_at(now);
        // availability forecasting learns from the same selection-time
        // observability the estimator path uses: the server contacted
        // the cohort, so it saw exactly these online bits. RNG-free (the
        // bits were already realized above), and scoped to the cohort so
        // forecast state stays O(observed clients), mirroring the lazy
        // population fleet's sparse estimates.
        if let Some(f) = &mut self.forecast {
            for &i in active {
                f.observe(i, cond.online[i]);
            }
        }
        let participants: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&i| cond.online[i] && cond.available[i])
            .collect();
        (cond, participants)
    }

    /// Start recording every realized round for trace export. Prefer
    /// [`ClientFleet::with_options`] (recording from the probe onward);
    /// enabling mid-run yields a trace whose round 0 is NOT the probe.
    pub fn enable_recording(&mut self) {
        self.system.enable_recording();
    }

    /// The realized trace recorded so far (None unless recording was
    /// enabled).
    pub fn recorded_trace(&self) -> Option<&crate::fed::traces::TraceData> {
        self.system.recorder().map(|r| r.data())
    }

    /// Write the recorded trace CSV (replayable via
    /// `--speed trace:PATH`).
    pub fn write_recorded_trace(
        &self,
        path: &std::path::Path,
    ) -> Result<(), String> {
        let data = self.recorded_trace().ok_or_else(|| {
            "trace recording was not enabled for this run \
             (set ExperimentConfig::record_trace)"
                .to_string()
        })?;
        data.write_csv(path).map_err(|e| {
            format!("cannot write trace '{}': {e}", path.display())
        })
    }

    /// Active set for a stage of k clients: ranked by the online speed
    /// estimates when `estimated` (re-ranks under drift, TiFL-style),
    /// else the oracle fastest-first prefix. Estimate ranking is top-K
    /// selection ([`SpeedEstimator::ranked_prefix`]): O(n log k) per
    /// call, bit-identical to the full sort it replaced.
    pub fn active_prefix(&self, k: usize, estimated: bool) -> Vec<usize> {
        if estimated {
            self.estimates.ranked_prefix(k)
        } else {
            self.order[..k].to_vec()
        }
    }

    /// Predictive cohort builder (`fed::selection`): extend `base` —
    /// the statistically-required cohort in its selection order (ranked
    /// prefix for FLANP, tier members for TiFL) — to `target` members
    /// (`overselect_target`) with the fastest estimate-ranked clients
    /// not already in it, then let the availability forecaster swap
    /// predicted-offline picks for the fastest predicted-online
    /// alternates ([`AvailabilityForecaster::filter_prefix`]).
    ///
    /// With `target <= base.len()` and forecasting off this returns
    /// `base` unchanged without touching the estimate ranking — the
    /// default path is bit-identical to pre-selection behavior.
    pub fn select_cohort(&self, base: &[usize], target: usize) -> Vec<usize> {
        let want = target.max(base.len());
        if want == base.len() && self.forecast.is_none() {
            return base.to_vec();
        }
        // candidate ranking: base first (its own order), then every
        // other client fastest-first by the online estimates
        let n = self.num_clients();
        let mut in_base = vec![false; n];
        for &i in base {
            in_base[i] = true;
        }
        let mut ranking = base.to_vec();
        ranking.extend(
            self.estimates
                .ranked_prefix(n)
                .into_iter()
                .filter(|&i| !in_base[i]),
        );
        match &self.forecast {
            None => {
                ranking.truncate(want);
                ranking
            }
            Some(f) => f.filter_prefix(&ranking, want),
        }
    }

    /// Enable (or re-policy) the TiFL tier scheduler over the current
    /// estimates. Idempotent for an unchanged policy, so the cached
    /// membership — and the re-tier event count — survives repeated
    /// calls from solver entry points.
    pub fn ensure_tiers(&mut self, policy: &TierPolicy) {
        let up_to_date =
            self.tiers.as_ref().map(|t| t.policy() == policy).unwrap_or(false);
        if !up_to_date {
            self.tiers =
                Some(TierScheduler::new(policy.clone(), &self.estimates));
        }
    }

    /// Hysteresis-gated re-tier check against the current estimates;
    /// true iff a re-tier happened. No-op (false) when tiers are off.
    pub fn refresh_tiers(&mut self) -> bool {
        match &mut self.tiers {
            Some(t) => t.refresh(&self.estimates),
            None => false,
        }
    }

    /// Tier-granular active set: the fastest whole tiers covering at
    /// least `n` clients, in the scheduler's cached fastest-first order
    /// (FLANP stage sizes snap to tier boundaries). Requires
    /// [`ClientFleet::ensure_tiers`] first.
    pub fn tiered_prefix(&self, n: usize) -> Vec<usize> {
        self.tiers
            .as_ref()
            .expect("tiered_prefix without ensure_tiers")
            .prefix(n)
    }

    /// Re-tier events recorded by the scheduler (0 when tiers are off).
    pub fn retier_events(&self) -> usize {
        self.tiers.as_ref().map_or(0, |t| t.retier_events())
    }

    /// Snapshot of the tier assignments (client id -> tier index, 0 =
    /// fastest; empty when tiers are off). The observability layer
    /// (`fed::observe`) diffs two snapshots around
    /// [`ClientFleet::refresh_tiers`] to report per-client
    /// promotions/demotions — only taken when an observer is enabled.
    pub fn tier_assignments(&self) -> Vec<usize> {
        self.tiers.as_ref().map_or_else(Vec::new, |t| t.assignments().to_vec())
    }

    /// Frozen per-tier estimate bands `[min, max]` from the last tiering
    /// (empty when tiers are off).
    pub fn tier_bands(&self) -> Vec<(f64, f64)> {
        self.tiers.as_ref().map_or_else(Vec::new, |t| t.bands().to_vec())
    }

    /// Feed the round's observed upload timings back into the estimator
    /// (only clients whose upload arrived can be measured).
    pub fn observe_round(&mut self, participants: &[usize], cond: &RoundConditions) {
        for &i in participants {
            self.estimates.observe(i, cond.times[i]);
        }
    }

    /// Censored feedback for deadline-missed clients: the server only
    /// learns their per-update time exceeded `per_update_floor`
    /// (`deadline / updates`); the estimator is pulled up toward the
    /// bound, never down (see [`SpeedEstimator::observe_censored`]).
    pub fn observe_censored(&mut self, missed: &[usize], per_update_floor: f64) {
        for &i in missed {
            self.estimates.observe_censored(i, per_update_floor);
        }
    }

    /// Reserve `rows` held-out rows at the tail of EVERY client's shard.
    /// Training paths ([`ClientFleet::fill_minibatch`],
    /// [`ClientFleet::fill_round_batches`],
    /// [`ClientFleet::for_each_full_chunk`]) see only the remaining
    /// train prefix, so held-out rows never leak into an update.
    /// Consumes no RNG; call right after construction (as
    /// `setup::build_fleet` does) so the shared draw sequence is
    /// untouched.
    pub fn set_holdout(&mut self, rows: usize) {
        for (c, sh) in self.shards.iter().enumerate() {
            assert!(
                rows < sh.s(),
                "holdout {rows} leaves client {c} no training rows \
                 (shard size {})",
                sh.s()
            );
        }
        self.holdout = rows;
    }

    /// Held-out rows per client (0 when per-client eval is off).
    pub fn holdout(&self) -> usize {
        self.holdout
    }

    /// The client's held-out row indices (the shard tail).
    pub fn holdout_rows(&self, client: usize) -> &[usize] {
        let sh = &self.shards[client];
        &sh.indices[sh.s() - self.holdout..]
    }

    /// Rows available for training: shard size minus the holdout.
    fn train_len(&self, client: usize) -> usize {
        self.shards[client].s() - self.holdout
    }

    /// Samples held by one client.
    pub fn s(&self, client: usize) -> usize {
        self.shards[client].s()
    }

    pub fn d(&self) -> usize {
        self.dataset.d
    }

    /// Client ids of the k fastest clients (FLANP's active prefix).
    pub fn fastest(&self, k: usize) -> &[usize] {
        &self.order[..k]
    }

    /// Speeds of a set of clients (for the virtual clock).
    pub fn speeds_of(&self, clients: &[usize]) -> Vec<f64> {
        clients.iter().map(|&c| self.speeds[c]).collect()
    }

    /// Fill one stochastic minibatch (size b, sampled without replacement
    /// from the client's shard) into x/y buffers.
    /// x_buf: [b*d], y_buf: [b*encoded_width].
    pub fn fill_minibatch(
        &mut self,
        client: usize,
        b: usize,
        x_buf: &mut [f32],
        y_buf: &mut [f32],
    ) {
        let mut rng = std::mem::replace(&mut self.rngs[client], Rng::new(0));
        self.fill_minibatch_with(&mut rng, client, b, x_buf, y_buf);
        self.rngs[client] = rng;
    }

    /// Like [`ClientFleet::fill_minibatch`] but sampling from a
    /// caller-owned stream instead of the client's own minibatch stream.
    /// Lets side computations (ditto's personal-head steps) draw batches
    /// without perturbing the client's canonical stream — the global
    /// trajectory stays bit-identical to a run without the side work.
    pub fn fill_minibatch_with(
        &self,
        rng: &mut Rng,
        client: usize,
        b: usize,
        x_buf: &mut [f32],
        y_buf: &mut [f32],
    ) {
        let train_len = self.train_len(client);
        assert!(b <= train_len, "batch {b} > train rows {train_len}");
        let picks = rng.sample_indices(train_len, b);
        let rows: Vec<usize> =
            picks.iter().map(|&p| self.shards[client].indices[p]).collect();
        self.dataset.gather_x(&rows, x_buf);
        self.dataset.y.encode_into(&rows, y_buf);
    }

    /// Fill tau stacked minibatches for one fused local round.
    /// xs_buf: [tau*b*d], ys_buf: [tau*b*encoded_width].
    pub fn fill_round_batches(
        &mut self,
        client: usize,
        tau: usize,
        b: usize,
        xs_buf: &mut [f32],
        ys_buf: &mut [f32],
    ) {
        let d = self.dataset.d;
        let yw = self.dataset.y.encoded_width();
        assert_eq!(xs_buf.len(), tau * b * d);
        assert_eq!(ys_buf.len(), tau * b * yw);
        for t in 0..tau {
            let (xs, ys) = (
                &mut xs_buf[t * b * d..(t + 1) * b * d],
                &mut ys_buf[t * b * yw..(t + 1) * b * yw],
            );
            self.fill_minibatch(client, b, xs, ys);
        }
    }

    /// Visit the client's full TRAIN prefix (the whole shard when no
    /// holdout is set) in chunks of exactly `b` rows (requires the
    /// train length to be a multiple of b — validated by the experiment
    /// config). Used for the exact local gradients of the stopping rule.
    pub fn for_each_full_chunk<F: FnMut(&[f32], &[f32])>(
        &self,
        client: usize,
        b: usize,
        x_buf: &mut [f32],
        y_buf: &mut [f32],
        mut f: F,
    ) {
        let s = self.train_len(client);
        let shard = &self.shards[client];
        assert_eq!(
            s % b,
            0,
            "shard size {s} must be a multiple of artifact batch {b}"
        );
        for chunk in shard.indices[..s].chunks(b) {
            self.dataset.gather_x(chunk, x_buf);
            self.dataset.y.encode_into(chunk, y_buf);
            f(x_buf, y_buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard, Labels};
    use crate::fed::speed::SpeedModel;

    fn fleet(n_clients: usize, s: usize, d: usize) -> ClientFleet {
        fleet_sys(n_clients, s, d, &SpeedModel::paper_uniform().into())
    }

    fn fleet_sys(
        n_clients: usize,
        s: usize,
        d: usize,
        system: &SystemModel,
    ) -> ClientFleet {
        let n = n_clients * s;
        let mut rng = Rng::new(1);
        let mut x = vec![0.0f32; n * d];
        rng.fill_normal(&mut x, 1.0);
        let y = Labels::Class((0..n).map(|i| (i % 3) as u32).collect(), 3);
        let ds = Dataset::new(x, y, d);
        let shards = shard::partition_iid(&mut rng, &ds, n_clients);
        ClientFleet::new(ds, shards, system, &mut rng)
    }

    #[test]
    fn order_is_fastest_first() {
        let f = fleet(10, 20, 4);
        let sorted: Vec<f64> = f.order.iter().map(|&c| f.speeds[c]).collect();
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(f.fastest(3).len(), 3);
        assert_eq!(f.fastest(3), &f.order[..3]);
    }

    #[test]
    fn static_prefix_matches_oracle_and_conditions_match_speeds() {
        let mut f = fleet(10, 20, 4);
        // estimator primed by the static probe == oracle speeds exactly
        assert_eq!(f.estimates.estimates(), &f.speeds[..]);
        assert_eq!(f.active_prefix(4, true), f.active_prefix(4, false));
        assert_eq!(f.active_prefix(4, false), &f.order[..4]);
        let cond = f.next_round_conditions();
        assert_eq!(cond.times, f.speeds);
        assert!(cond.available.iter().all(|&a| a));
        // static observations never move the estimates
        let all: Vec<usize> = (0..10).collect();
        f.observe_round(&all, &cond);
        assert_eq!(f.estimates.estimates(), &f.speeds[..]);
    }

    #[test]
    fn drifted_observations_rerank_the_prefix() {
        let mut f = fleet(6, 20, 4);
        let fastest = f.order[0];
        // the oracle-fastest client slows down 100x for many rounds
        let mut cond = f.next_round_conditions();
        cond.times[fastest] *= 100.0;
        for _ in 0..30 {
            f.observe_round(&[fastest], &cond);
        }
        let prefix = f.active_prefix(3, true);
        assert!(
            !prefix.contains(&fastest),
            "estimated prefix {prefix:?} still contains slowed client {fastest}"
        );
        // oracle ranking is unaffected
        assert!(f.active_prefix(3, false).contains(&fastest));
    }

    #[test]
    fn realize_round_skips_offline_clients() {
        let sys = SystemModel::parse("avail:diurnal:100:0.5:1:uniform:50:500")
            .unwrap();
        let mut f = fleet_sys(4, 20, 4, &sys);
        // phases 0, 0.25, 0.5, 0.75 at duty 0.5: clients 0, 1 online at
        // t = 0; the offline clients are skipped, not dropped
        let (cond, participants) = f.realize_round(&[0, 1, 2, 3], 0.0);
        assert_eq!(cond.online, vec![true, true, false, false]);
        assert_eq!(participants, vec![0, 1]);
        assert!(cond.available.iter().all(|&a| a));
        assert_eq!(cond.online_of(&[0, 1, 2, 3]), vec![0, 1]);
        // half a period later the window rotates
        let (cond, participants) = f.realize_round(&[0, 1, 2, 3], 50.0);
        assert_eq!(cond.online, vec![false, false, true, true]);
        assert_eq!(participants, vec![2, 3]);
    }

    #[test]
    fn recorded_trace_round_zero_is_the_probe() {
        let n_clients = 3;
        let s = 10;
        let d = 4;
        let nrows = n_clients * s;
        let mut rng = Rng::new(1);
        let mut x = vec![0.0f32; nrows * d];
        rng.fill_normal(&mut x, 1.0);
        let y = Labels::Class((0..nrows).map(|i| (i % 3) as u32).collect(), 3);
        let ds = Dataset::new(x, y, d);
        let shards = shard::partition_iid(&mut rng, &ds, n_clients);
        let mut f = ClientFleet::with_options(
            ds,
            shards,
            &SpeedModel::paper_uniform().into(),
            DEFAULT_EWMA_ALPHA,
            true,
            &mut rng,
        );
        // the construction probe is already recorded as round 0, and
        // under static dynamics it equals the base speeds exactly
        let rec = f.recorded_trace().unwrap();
        assert_eq!(rec.num_rounds(), 1);
        let (t0, a0) = rec.round(0);
        assert_eq!(t0, &f.speeds[..]);
        assert!(a0.iter().all(|&a| a));
        f.next_round_conditions();
        assert_eq!(f.recorded_trace().unwrap().num_rounds(), 2);
        // a non-recording fleet exposes no trace
        let g = fleet(3, 10, 4);
        assert!(g.recorded_trace().is_none());
        assert!(g.write_recorded_trace(std::path::Path::new("/tmp/x")).is_err());
    }

    #[test]
    fn same_seed_same_base_draw_across_scenarios() {
        // scenario dynamics must not perturb the base draw or the data
        // streams: same seed => same oracle speeds under any dynamics
        let a = fleet(6, 20, 4);
        let b = fleet_sys(
            6,
            20,
            4,
            &SystemModel::parse("drop:0.2:markov:4:0.2:0.2:uniform:50:500").unwrap(),
        );
        assert_eq!(a.speeds, b.speeds);
        assert_eq!(a.order, b.order);
    }

    #[test]
    fn tiered_prefix_matches_estimate_prefix_under_static_alignment() {
        // static scenarios: the probe-primed estimates ARE the oracle
        // speeds, so the cached tier ranking equals the live estimate
        // ranking and aligned prefixes agree bit-for-bit
        let mut f = fleet(8, 20, 4);
        f.ensure_tiers(&TierPolicy::new(4));
        assert_eq!(f.tiered_prefix(2), f.active_prefix(2, true));
        assert_eq!(f.tiered_prefix(4), f.active_prefix(4, true));
        // misaligned sizes snap UP to the next whole tier
        assert_eq!(f.tiered_prefix(3).len(), 4);
        assert!(!f.refresh_tiers(), "static estimates triggered a re-tier");
        assert_eq!(f.retier_events(), 0);
        // re-ensuring with the same policy keeps the cached scheduler
        f.ensure_tiers(&TierPolicy::new(4));
        assert_eq!(f.retier_events(), 0);
    }

    #[test]
    fn drifted_estimates_retier_through_the_fleet() {
        let mut f = fleet(6, 20, 4);
        f.ensure_tiers(&TierPolicy::new(3));
        let fastest = f.order[0];
        let mut cond = f.next_round_conditions();
        cond.times[fastest] *= 100.0;
        let mut retiers = 0;
        for _ in 0..30 {
            f.observe_round(&[fastest], &cond);
            retiers += f.refresh_tiers() as usize;
        }
        assert_eq!(retiers, f.retier_events());
        assert!(retiers >= 1, "a 100x sustained slowdown never re-tiered");
        let t = f.tiers.as_ref().unwrap();
        assert_eq!(t.tier_of(fastest), t.num_tiers() - 1);
    }

    #[test]
    fn select_cohort_without_forecast_or_surplus_is_identity() {
        let f = fleet(8, 20, 4);
        let base = f.active_prefix(3, true);
        assert_eq!(f.select_cohort(&base, 3), base);
        // never shrinks below the statistical requirement
        assert_eq!(f.select_cohort(&base, 0), base);
    }

    #[test]
    fn select_cohort_extends_with_fastest_nonmembers() {
        let f = fleet(8, 20, 4);
        let base = f.active_prefix(3, true);
        let ext = f.select_cohort(&base, 5);
        assert_eq!(ext.len(), 5);
        assert_eq!(&ext[..3], &base[..]);
        // static scenario: extending the ranked prefix IS the larger
        // ranked prefix
        assert_eq!(ext, f.active_prefix(5, true));
        // target past the fleet clamps to the fleet
        assert_eq!(f.select_cohort(&base, 99).len(), 8);
    }

    #[test]
    fn forecaster_learns_from_realized_rounds_and_reroutes_selection() {
        let sys = SystemModel::parse("avail:diurnal:100:0.5:1:uniform:50:500")
            .unwrap();
        let mut f = fleet_sys(4, 20, 4, &sys);
        f.set_forecast(ForecastPolicy::parse("ewma:0.5").unwrap());
        // at t = 0 clients 0, 1 are online and 2, 3 offline; a few
        // realized rounds teach the forecaster that split
        for _ in 0..4 {
            f.realize_round(&[0, 1, 2, 3], 0.0);
        }
        let fc = f.forecast.as_ref().unwrap();
        assert_eq!(fc.tracked(), 4);
        assert!(fc.predicted_online(0) && fc.predicted_online(1));
        assert!(!fc.predicted_online(2) && !fc.predicted_online(3));
        // selection swaps the predicted-offline base for predicted-online
        // alternates — and never shrinks the cohort
        let cohort = f.select_cohort(&[2, 3], 2);
        assert_eq!(cohort.len(), 2);
        assert!(!cohort.contains(&2) && !cohort.contains(&3));
    }

    #[test]
    fn minibatch_rows_come_from_own_shard() {
        let mut f = fleet(5, 20, 4);
        let b = 8;
        let mut x = vec![0.0; b * 4];
        let mut y = vec![0.0; b * 3];
        f.fill_minibatch(2, b, &mut x, &mut y);
        // every sampled row must match some row of client 2's shard
        for r in 0..b {
            let row = &x[r * 4..(r + 1) * 4];
            let found = f.shards[2]
                .indices
                .iter()
                .any(|&i| f.dataset.row(i) == row);
            assert!(found, "row {r} not in shard");
        }
        // one-hot rows sum to 1
        for r in 0..b {
            let s: f32 = y[r * 3..(r + 1) * 3].iter().sum();
            assert_eq!(s, 1.0);
        }
    }

    #[test]
    fn round_batches_fill_every_slot() {
        let mut f = fleet(3, 30, 4);
        let (tau, b) = (5, 6);
        let mut xs = vec![f32::NAN; tau * b * 4];
        let mut ys = vec![f32::NAN; tau * b * 3];
        f.fill_round_batches(0, tau, b, &mut xs, &mut ys);
        assert!(xs.iter().all(|v| v.is_finite()));
        assert!(ys.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn full_chunks_cover_shard_exactly_once() {
        let f = fleet(4, 24, 4);
        let b = 6;
        let mut x = vec![0.0; b * 4];
        let mut y = vec![0.0; b * 3];
        let mut rows_seen = 0;
        f.for_each_full_chunk(1, b, &mut x, &mut y, |xc, _| {
            rows_seen += xc.len() / 4;
        });
        assert_eq!(rows_seen, 24);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn full_chunks_reject_indivisible_batch() {
        let f = fleet(4, 25, 4);
        let mut x = vec![0.0; 6 * 4];
        let mut y = vec![0.0; 6 * 3];
        f.for_each_full_chunk(0, 6, &mut x, &mut y, |_, _| {});
    }

    #[test]
    fn holdout_rows_never_enter_training() {
        let mut f = fleet(3, 24, 4);
        f.set_holdout(6);
        assert_eq!(f.holdout(), 6);
        for c in 0..3 {
            assert_eq!(f.holdout_rows(c).len(), 6);
            assert_eq!(f.holdout_rows(c), &f.shards[c].indices[18..]);
        }
        let held: std::collections::HashSet<usize> =
            f.holdout_rows(1).iter().copied().collect();
        // minibatches draw only from the train prefix
        let b = 8;
        let mut x = vec![0.0; b * 4];
        let mut y = vec![0.0; b * 3];
        for _ in 0..20 {
            f.fill_minibatch(1, b, &mut x, &mut y);
            for r in 0..b {
                let row = &x[r * 4..(r + 1) * 4];
                let hit = held.iter().any(|&i| f.dataset.row(i) == row);
                assert!(!hit, "held-out row sampled into a minibatch");
            }
        }
        // full chunks cover exactly the train prefix
        let mut rows_seen = 0;
        f.for_each_full_chunk(1, 6, &mut x[..6 * 4], &mut y[..6 * 3], |xc, _| {
            rows_seen += xc.len() / 4;
        });
        assert_eq!(rows_seen, 18);
    }

    #[test]
    #[should_panic(expected = "no training rows")]
    fn holdout_must_leave_training_rows() {
        let mut f = fleet(2, 10, 4);
        f.set_holdout(10);
    }

    #[test]
    fn minibatch_streams_differ_across_clients() {
        let mut f = fleet(3, 30, 4);
        let b = 4;
        let mut x1 = vec![0.0; b * 4];
        let mut x2 = vec![0.0; b * 4];
        let mut y = vec![0.0; b * 3];
        f.fill_minibatch(0, b, &mut x1, &mut y);
        f.fill_minibatch(1, b, &mut x2, &mut y);
        assert_ne!(x1, x2);
    }
}
