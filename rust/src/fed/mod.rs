//! Federated substrate: heterogeneous client fleet, speed models, virtual
//! wall-clock, and per-round metric traces.

pub mod client;
pub mod clock;
pub mod metrics;
pub mod speed;

pub use client::ClientFleet;
pub use clock::VirtualClock;
pub use metrics::{RoundRecord, Trace};
pub use speed::SpeedModel;
