//! Federated substrate: heterogeneous client fleet, system-heterogeneity
//! scenarios (speed models + per-round dynamics + dropout + correlated
//! availability), trace recording/replay, aggregation deadline policies,
//! predictive selection (over-selection + cancellation + availability
//! forecasting), TiFL-style tier scheduling, lazily-realized populations
//! with sketch summaries, virtual wall-clock with round events, and
//! per-round metric traces.

pub mod aggregation;
pub mod client;
pub mod clock;
pub mod metrics;
pub mod observe;
pub mod population;
pub mod selection;
pub mod sketch;
pub mod speed;
pub mod system;
pub mod tiers;
pub mod traces;

pub use aggregation::{DeadlineController, DeadlinePolicy};
pub use client::{ClientFleet, DEFAULT_EWMA_ALPHA};
pub use clock::{RoundEvent, VirtualClock};
pub use metrics::{RoundRecord, StreamingStats, Trace};
pub use observe::{
    Event, EventKind, JsonlObserver, NoopObserver, Observe, Observer, Phase,
    Span, EVENTS_SCHEMA, SUMMARY_SCHEMA,
};
pub use population::{
    CohortConditions, LazyFleet, LazyShards, PopulationFleet, PopulationSpec,
    DEFAULT_EXACT_THRESHOLD, DEFAULT_FRONTIER, LAZY_EVENT_SAMPLE,
};
pub use selection::{
    overselect_target, parse_overselect, validate_overselect,
    AvailabilityForecaster, ForecastPolicy, OVERSELECT_OFF,
};
pub use sketch::{QuantileSketch, TopK};
pub use speed::SpeedModel;
pub use system::{Dynamics, RoundConditions, SpeedEstimator, SystemModel, SystemState};
pub use tiers::{TierPolicy, TierScheduler, TierSplit};
pub use traces::{
    AvailabilityModel, TraceData, TraceMode, TraceRecorder, TraceReplay,
};
