//! Per-round metric traces — everything the paper's figures plot —
//! plus constant-memory streaming aggregation ([`StreamingStats`]) for
//! population-scale sweeps where per-round, per-client rows no longer
//! fit (`flanp-bench scale`, `docs/scale.md`).

use crate::util::json::{obj, Json};
use std::io::Write;
use std::path::Path;

/// One communication round's measurements.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// virtual wall-clock at the END of this round
    pub time: f64,
    /// number of participating clients this round
    pub participants: usize,
    /// global training loss L_n over the ACTIVE set (what the solver sees)
    pub loss_active: f64,
    /// global training loss L_N over ALL N clients' data (what the paper
    /// plots — progress towards the full-ERM objective)
    pub loss_full: f64,
    /// squared norm of the active-set gradient (stopping rule input)
    pub grad_norm_sq: f64,
    /// ||w - w*|| when the exact optimum is known (linreg), else NaN
    pub dist_to_opt: f64,
    /// test / train accuracy when classification, else NaN
    pub accuracy: f64,
    /// FLANP stage index (0 for non-adaptive solvers)
    pub stage: usize,
    /// clients that dropped out of this round (scenario-dependent; 0
    /// under the paper's static scenarios)
    pub dropped: usize,
    /// clients that were computing but missed the aggregation deadline
    /// (0 under synchronous aggregation — the arrived-vs-missed split
    /// of the deadline policies in [`crate::fed::aggregation`])
    pub missed: usize,
    /// ranking-maintenance events charged to this round: full estimate
    /// re-ranks (1 per stage boundary under FLANP's default cadence, 1
    /// per round under per-round re-ranking) or hysteresis-triggered
    /// re-tiers of the [`crate::fed::TierScheduler`] cache (0 while the
    /// cache holds)
    pub reranks: usize,
    /// observably-online clients fleet-wide this round (the `avail:` /
    /// `trace:` scenarios of `fed::traces`; equals the fleet size
    /// otherwise). Mirrors the per-client `available` column of the
    /// recorded trace CSV.
    pub available: usize,
    /// clients whose in-flight work the server actively cancelled at
    /// the k-th arrival (over-selection, `fed::selection`; 0 unless
    /// `overselect > 1` closed the round at its target arrival)
    pub cancelled: usize,
    /// mean per-client held-out accuracy (the statistical-heterogeneity
    /// measurement — `coordinator::eval::ClientEval`; evaluated with
    /// each client's OWN model for personalized solvers, the global
    /// model otherwise). NaN when per-client eval is off — the IID
    /// default; between eval rounds the previous value carries, like
    /// `loss_full`.
    pub acc: f64,
}

/// A full run's trace plus identifying metadata.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub algo: String,
    pub rounds: Vec<RoundRecord>,
    /// stage-transition log: (round, new participant count)
    pub stage_transitions: Vec<(usize, usize)>,
    pub finished: bool,
    /// total simulated time at termination
    pub total_time: f64,
    /// final per-client held-out accuracies (empty unless per-client
    /// eval ran — the source of [`Trace::mean_client_acc`] /
    /// [`Trace::worst_decile_acc`])
    pub client_acc: Vec<f64>,
}

impl Trace {
    pub fn new(algo: &str) -> Self {
        Trace { algo: algo.to_string(), ..Default::default() }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.total_time = rec.time;
        self.rounds.push(rec);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    /// First virtual time at which `loss_full <= target` (linear
    /// interpolation is unnecessary: round granularity matches the paper).
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.loss_full <= target)
            .map(|r| r.time)
    }

    /// First virtual time at which `dist_to_opt <= target`.
    pub fn time_to_dist(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.dist_to_opt <= target)
            .map(|r| r.time)
    }

    /// Total ranking-maintenance events (estimate re-ranks / tier-cache
    /// re-tiers) charged across the run.
    pub fn total_reranks(&self) -> usize {
        self.rounds.iter().map(|r| r.reranks).sum()
    }

    /// Smallest fleet-wide online count seen across the run's rounds
    /// (the severity of the worst availability trough; `None` on an
    /// empty trace).
    pub fn min_available(&self) -> Option<usize> {
        self.rounds.iter().map(|r| r.available).min()
    }

    /// Total deadline misses across the run (the arrived-vs-missed
    /// split of [`crate::fed::aggregation`]'s policies; cancellations
    /// are booked separately in [`Trace::total_cancelled`]).
    pub fn total_missed(&self) -> usize {
        self.rounds.iter().map(|r| r.missed).sum()
    }

    /// Total in-flight cancellations across the run (over-selection's
    /// wasted-work bill — see docs/scenarios.md §8).
    pub fn total_cancelled(&self) -> usize {
        self.rounds.iter().map(|r| r.cancelled).sum()
    }

    /// Mean of the final per-client held-out accuracies (NaN unless
    /// per-client eval ran).
    pub fn mean_client_acc(&self) -> f64 {
        if self.client_acc.is_empty() {
            return f64::NAN;
        }
        self.client_acc.iter().sum::<f64>() / self.client_acc.len() as f64
    }

    /// Mean accuracy of the worst decile of clients — the ceil(n/10)
    /// clients with the LOWEST final held-out accuracy. The fairness
    /// aggregate of the interplay experiment: a solver whose global
    /// model abandons the slow-and-shifted cohort collapses here while
    /// its mean barely moves (docs/scenarios.md §9). NaN unless
    /// per-client eval ran.
    pub fn worst_decile_acc(&self) -> f64 {
        if self.client_acc.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.client_acc.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let k = (sorted.len() + 9) / 10;
        sorted[..k].iter().sum::<f64>() / k as f64
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("algo", self.algo.as_str().into()),
            ("finished", self.finished.into()),
            ("total_time", self.total_time.into()),
            (
                "stage_transitions",
                self.stage_transitions
                    .iter()
                    .map(|&(r, n)| Json::Arr(vec![r.into(), n.into()]))
                    .collect(),
            ),
            (
                "rounds",
                self.rounds
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("round", r.round.into()),
                            ("time", r.time.into()),
                            ("participants", r.participants.into()),
                            ("loss_active", json_num(r.loss_active)),
                            ("loss_full", json_num(r.loss_full)),
                            ("grad_norm_sq", json_num(r.grad_norm_sq)),
                            ("dist_to_opt", json_num(r.dist_to_opt)),
                            ("accuracy", json_num(r.accuracy)),
                            ("stage", r.stage.into()),
                            ("dropped", r.dropped.into()),
                            ("missed", r.missed.into()),
                            ("reranks", r.reranks.into()),
                            ("available", r.available.into()),
                            ("cancelled", r.cancelled.into()),
                            ("acc", json_num(r.acc)),
                        ])
                    })
                    .collect(),
            ),
        ])
    }

    /// CSV with a header row (one line per round).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,time,participants,loss_active,loss_full,grad_norm_sq,dist_to_opt,accuracy,stage,dropped,missed,reranks,available,cancelled,acc\n",
        );
        for r in &self.rounds {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                r.round,
                r.time,
                r.participants,
                r.loss_active,
                r.loss_full,
                r.grad_norm_sq,
                r.dist_to_opt,
                r.accuracy,
                r.stage,
                r.dropped,
                r.missed,
                r.reranks,
                r.available,
                r.cancelled,
                r.acc
            ));
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

fn json_num(v: f64) -> Json {
    // JSON has no NaN; encode as null
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Constant-memory streaming aggregation: count, mean, variance
/// (Welford's online algorithm — numerically stable at any stream
/// length), min and max. At population scale a [`Trace`] row per round
/// per metric would dominate memory; a `StreamingStats` per metric is
/// five words regardless of how many rounds flow through it, which is
/// what `flanp-bench scale` aggregates its measured round costs with.
///
/// ```
/// use flanp::fed::StreamingStats;
///
/// let mut s = StreamingStats::new();
/// for x in [1.0, 2.0, 3.0, 4.0] {
///     s.push(x);
/// }
/// assert_eq!(s.count(), 4);
/// assert_eq!(s.mean(), 2.5);
/// assert_eq!((s.min(), s.max()), (1.0, 4.0));
/// // population variance of 1..4 is 1.25
/// assert!((s.variance() - 1.25).abs() < 1e-12);
/// assert!(StreamingStats::new().mean().is_nan());
/// ```
#[derive(Clone, Debug, Default)]
pub struct StreamingStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl StreamingStats {
    pub fn new() -> Self {
        StreamingStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation in (O(1), no allocation).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean of the stream (`NaN` while empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance of the stream (`NaN` while empty).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Smallest observation (`+inf` while empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation (`-inf` while empty).
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, time: f64, loss: f64) -> RoundRecord {
        RoundRecord {
            round,
            time,
            participants: 4,
            loss_active: loss,
            loss_full: loss,
            grad_norm_sq: loss * loss,
            dist_to_opt: f64::NAN,
            accuracy: f64::NAN,
            stage: 0,
            dropped: 0,
            missed: 0,
            reranks: 0,
            available: 4,
            cancelled: 0,
            acc: f64::NAN,
        }
    }

    #[test]
    fn time_to_loss_finds_first_crossing() {
        let mut t = Trace::new("x");
        t.push(rec(0, 10.0, 1.0));
        t.push(rec(1, 20.0, 0.5));
        t.push(rec(2, 30.0, 0.2));
        assert_eq!(t.time_to_loss(0.5), Some(20.0));
        assert_eq!(t.time_to_loss(0.1), None);
        assert_eq!(t.total_time, 30.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = Trace::new("x");
        t.push(rec(0, 1.0, 2.0));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("round,time"));
        assert!(
            csv.lines().next().unwrap().ends_with(",available,cancelled,acc")
        );
    }

    #[test]
    fn reranks_are_totaled_and_serialized() {
        let mut t = Trace::new("x");
        let mut r = rec(0, 1.0, 2.0);
        r.reranks = 3;
        t.push(r);
        t.push(rec(1, 2.0, 1.0));
        assert_eq!(t.total_reranks(), 3);
        assert!(t.to_json().to_string().contains("\"reranks\":3"));
    }

    #[test]
    fn available_column_is_totaled_and_serialized() {
        let mut t = Trace::new("x");
        let mut r = rec(0, 1.0, 2.0);
        r.available = 7;
        t.push(r);
        t.push(rec(1, 2.0, 1.0));
        assert_eq!(t.min_available(), Some(4));
        assert!(t.to_json().to_string().contains("\"available\":7"));
        let csv = t.to_csv();
        let row = csv.lines().nth(1).unwrap();
        assert!(
            row.ends_with(",7,0,NaN"),
            "row '{row}' lacks the available,cancelled,acc columns"
        );
    }

    #[test]
    fn cancelled_column_is_totaled_and_serialized() {
        let mut t = Trace::new("x");
        let mut r = rec(0, 1.0, 2.0);
        r.cancelled = 3;
        t.push(r);
        t.push(rec(1, 2.0, 1.0));
        assert_eq!(t.total_cancelled(), 3);
        assert!(t.to_json().to_string().contains("\"cancelled\":3"));
        let csv = t.to_csv();
        let row = csv.lines().nth(1).unwrap();
        assert!(
            row.ends_with(",3,NaN"),
            "row '{row}' lacks the cancelled,acc columns"
        );
    }

    #[test]
    fn acc_column_and_client_aggregates() {
        let mut t = Trace::new("x");
        let mut r = rec(0, 1.0, 2.0);
        r.acc = 0.75;
        t.push(r);
        let csv = t.to_csv();
        assert!(csv.lines().nth(1).unwrap().ends_with(",0.75"));
        assert!(t.to_json().to_string().contains("\"acc\":0.75"));
        // no per-client vector -> NaN aggregates, never a silent zero
        assert!(t.mean_client_acc().is_nan());
        assert!(t.worst_decile_acc().is_nan());
        // 20 clients: worst decile = mean of the 2 lowest
        t.client_acc = (0..20).map(|i| i as f64 / 20.0).collect();
        assert!((t.mean_client_acc() - 0.475).abs() < 1e-12);
        assert!((t.worst_decile_acc() - 0.025).abs() < 1e-12);
        // non-divisible count rounds the decile UP (ceil(5/10) = 1)
        t.client_acc = vec![0.9, 0.8, 0.1, 0.7, 0.6];
        assert_eq!(t.worst_decile_acc(), 0.1);
    }

    #[test]
    fn streaming_stats_match_batch_moments() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut s = StreamingStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert_eq!(s.count(), 1000);
        assert!((s.mean() - mean).abs() < 1e-9, "{} vs {mean}", s.mean());
        assert!((s.variance() - var).abs() < 1e-9);
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!((s.min(), s.max()), (min, max));
        // the empty stream is explicit, never a misleading zero
        let e = StreamingStats::new();
        assert!(e.mean().is_nan() && e.variance().is_nan());
        assert_eq!(e.count(), 0);
    }

    #[test]
    fn json_encodes_nan_as_null() {
        let mut t = Trace::new("x");
        t.push(rec(0, 1.0, 2.0));
        let s = t.to_json().to_string();
        assert!(s.contains("\"dist_to_opt\":null"));
        // and parses back
        crate::util::json::Json::parse(&s).unwrap();
    }
}
