//! Structured observability for the round loop (`fed::observe`).
//!
//! Three layers, all inert by default:
//!
//! * the **event log** — an [`Observer`] sink receiving one typed
//!   [`Event`] per decision the stack makes: cohort selection and
//!   padding (`fed::selection`), deadline pricing and the per-client
//!   arrived / missed / cancelled / offline split
//!   (`fed::aggregation`, `fed::clock`), re-ranks and tier
//!   promotions/demotions (`fed::tiers`), stage transitions with their
//!   stopping-rule inputs (`coordinator::flanp`) and sampled lazy-fleet
//!   realizations (`fed::population`). [`NoopObserver`] is the
//!   zero-cost default; [`JsonlObserver`] appends one JSON object per
//!   line (schema `flanp-events/v1`).
//! * the **metrics registry** — per-kind event counters plus an
//!   estimator-error histogram ([`StreamingStats`] +
//!   [`QuantileSketch`]), rolled into a machine-readable run summary
//!   (schema `flanp-summary/v1`, [`Observe::summary_json`]).
//! * the **span profiler** — RAII [`Span`] timers around the five
//!   round-loop phases (select / local-rounds / aggregate / eval /
//!   bookkeeping) and the `engine::kernels` fan-out, aggregated into a
//!   per-phase host-µs breakdown in the same summary. Timers are global
//!   atomics so deep call sites (`coordinator::gate`) need no plumbing;
//!   when profiling is off a span is one relaxed atomic load.
//!
//! The hot-path contract: every emission site is guarded by a single
//! `if obs.enabled()` branch, and [`Observe::off`] keeps that branch
//! false — with observability disabled the solver byte-stream
//! (RNG consumption, clock arithmetic, trace rows) is untouched, which
//! `tests/observe.rs` pins against the golden fixtures.

use crate::fed::metrics::{StreamingStats, Trace};
use crate::fed::sketch::QuantileSketch;
use crate::util::json::{obj, Json};
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

/// Event-log schema identifier: the first line of every JSONL sink.
pub const EVENTS_SCHEMA: &str = "flanp-events/v1";
/// Run-summary schema identifier ([`Observe::summary_json`]).
pub const SUMMARY_SCHEMA: &str = "flanp-summary/v1";

/// Every decision the stack can report. The wire name
/// ([`EventKind::as_str`]) is the `kind` field of the JSONL line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum EventKind {
    /// a ranked cohort was selected (detail: `n`, `ids`)
    CohortSelected,
    /// over-selection padded the cohort past its statistical target
    /// (detail: `base`, `padded`, `factor`)
    CohortPadded,
    /// the availability forecaster reordered the ranked prefix
    /// (detail: `ids`)
    CohortReordered,
    /// a round deadline was priced (detail: `deadline`, `updates`,
    /// `cohort`, `present`)
    Deadline,
    /// an all-offline cohort held the round open; the wait was charged
    /// (detail: `now`, `wake`)
    Wait,
    /// a client's update arrived before the deadline (detail: `total`,
    /// `time`)
    Arrived,
    /// a client was computing but missed the deadline (detail: `total`,
    /// `deadline`)
    Missed,
    /// over-selection actively cancelled a client's in-flight work at
    /// the k-th arrival (detail: `total`, `cutoff`)
    Cancelled,
    /// a cohort member contributed nothing: observably offline or a
    /// silent dropout (detail: `online`, `available`)
    Offline,
    /// a censored estimator observation was fed back for a missed or
    /// cancelled client (detail: `floor`)
    Censored,
    /// the speed ranking was recomputed (detail: `count`)
    Rerank,
    /// a tier-cache refresh moved a client to a FASTER tier (detail:
    /// `from`, `to`, `band` — the breached `[lo, hi]` estimate band)
    TierPromote,
    /// a tier-cache refresh moved a client to a SLOWER tier (same
    /// detail as [`EventKind::TierPromote`])
    TierDemote,
    /// a FLANP stage transition with its stopping-rule inputs (detail:
    /// `n`, `grad_norm_sq`, `threshold`)
    Stage,
    /// a sampled lazy-fleet cohort realization (`fed::population`;
    /// detail: `cohort`, `online`, `available`)
    LazyRound,
}

/// Number of event kinds (the size of the per-kind counter registry).
pub const NUM_KINDS: usize = 15;

impl EventKind {
    /// Every kind, in wire order.
    pub const ALL: [EventKind; NUM_KINDS] = [
        EventKind::CohortSelected,
        EventKind::CohortPadded,
        EventKind::CohortReordered,
        EventKind::Deadline,
        EventKind::Wait,
        EventKind::Arrived,
        EventKind::Missed,
        EventKind::Cancelled,
        EventKind::Offline,
        EventKind::Censored,
        EventKind::Rerank,
        EventKind::TierPromote,
        EventKind::TierDemote,
        EventKind::Stage,
        EventKind::LazyRound,
    ];

    /// The wire name used in the JSONL `kind` field.
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::CohortSelected => "cohort_selected",
            EventKind::CohortPadded => "cohort_padded",
            EventKind::CohortReordered => "cohort_reordered",
            EventKind::Deadline => "deadline",
            EventKind::Wait => "wait",
            EventKind::Arrived => "arrived",
            EventKind::Missed => "missed",
            EventKind::Cancelled => "cancelled",
            EventKind::Offline => "offline",
            EventKind::Censored => "censored",
            EventKind::Rerank => "rerank",
            EventKind::TierPromote => "tier_promote",
            EventKind::TierDemote => "tier_demote",
            EventKind::Stage => "stage",
            EventKind::LazyRound => "lazy_round",
        }
    }

    /// Inverse of [`EventKind::as_str`].
    pub fn parse(s: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }
}

/// One structured event: the JSONL line is
/// `{"round":R,"stage":S,"kind":"...","client":C|null,"detail":{...}}`.
#[derive(Clone, Debug)]
pub struct Event {
    /// round the event belongs to (trace-row numbering: the first
    /// charged round is 1; selection events for it carry the same
    /// index)
    pub round: usize,
    /// FLANP stage index (0 for non-staged solvers)
    pub stage: usize,
    pub kind: EventKind,
    /// client id, when the event is about one client
    pub client: Option<usize>,
    /// kind-specific payload (see [`EventKind`])
    pub detail: Json,
}

impl Event {
    /// The event as a JSON object (one JSONL line, sans newline).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("round", self.round.into()),
            ("stage", self.stage.into()),
            ("kind", self.kind.as_str().into()),
            (
                "client",
                match self.client {
                    Some(c) => c.into(),
                    None => Json::Null,
                },
            ),
            ("detail", self.detail.clone()),
        ])
    }

    /// Parse one JSONL line back into an [`Event`] (used by the schema
    /// roundtrip test; `ci/check_events.py` is the python twin).
    pub fn from_json(j: &Json) -> Result<Event, String> {
        let kind_s = j.req_str("kind").map_err(|e| e.to_string())?;
        let kind = EventKind::parse(kind_s)
            .ok_or_else(|| format!("unknown event kind '{kind_s}'"))?;
        let client = match j.req("client").map_err(|e| e.to_string())? {
            Json::Null => None,
            c => Some(
                c.as_usize()
                    .ok_or_else(|| "field 'client' not a usize".to_string())?,
            ),
        };
        Ok(Event {
            round: j.req_usize("round").map_err(|e| e.to_string())?,
            stage: j.req_usize("stage").map_err(|e| e.to_string())?,
            kind,
            client,
            detail: j.req("detail").map_err(|e| e.to_string())?.clone(),
        })
    }
}

/// An event sink. The default methods make `impl Observer for T {}` a
/// disabled observer; [`Observe`] only forwards to an enabled sink.
pub trait Observer {
    /// Whether [`Observer::emit`] does anything — the one branch the
    /// hot path takes.
    fn enabled(&self) -> bool {
        false
    }
    fn emit(&mut self, _ev: &Event) {}
}

/// The zero-cost default sink: never enabled, emits nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopObserver;

impl Observer for NoopObserver {}

/// Appends events to a file, one JSON object per line. The first line
/// is the schema header `{"schema":"flanp-events/v1"}`.
#[derive(Debug)]
pub struct JsonlObserver {
    out: BufWriter<File>,
}

impl JsonlObserver {
    /// Create (truncate) `path` and write the schema header.
    pub fn create(path: &Path) -> std::io::Result<JsonlObserver> {
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{{\"schema\":\"{EVENTS_SCHEMA}\"}}")?;
        Ok(JsonlObserver { out })
    }
}

impl Observer for JsonlObserver {
    fn enabled(&self) -> bool {
        true
    }

    fn emit(&mut self, ev: &Event) {
        // best-effort: a full disk should not abort a simulation
        let _ = writeln!(self.out, "{}", ev.to_json().to_string());
    }
}

impl Drop for JsonlObserver {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

/// The observability bundle threaded through
/// [`crate::coordinator::run_solver_with`]: an event sink plus the
/// metrics registry (per-kind counters, estimator-error histogram) and
/// the round/stage cursors events are stamped with.
pub struct Observe {
    sink: Box<dyn Observer>,
    /// collect registry state even without an event sink (a summary
    /// was requested)
    collect: bool,
    counts: [u64; NUM_KINDS],
    est_err: StreamingStats,
    est_err_sketch: QuantileSketch,
    round: usize,
    stage: usize,
}

impl Observe {
    /// Fully disabled: [`Observe::enabled`] is false, every emission
    /// site short-circuits. This is what [`crate::coordinator::run_solver`]
    /// threads through, keeping the default path bit-identical.
    pub fn off() -> Observe {
        Observe::new(Box::new(NoopObserver), false)
    }

    /// Build from a sink; `collect` additionally enables the registry
    /// (pass true when a run summary was requested).
    pub fn new(sink: Box<dyn Observer>, collect: bool) -> Observe {
        Observe {
            sink,
            collect,
            counts: [0; NUM_KINDS],
            est_err: StreamingStats::new(),
            est_err_sketch: QuantileSketch::new(
                QuantileSketch::DEFAULT_CAPACITY,
            ),
            round: 0,
            stage: 0,
        }
    }

    /// THE hot-path branch: every emission site is
    /// `if obs.enabled() { ... }` and nothing else.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.collect || self.sink.enabled()
    }

    /// Stamp subsequent events with trace-row round `r`.
    pub fn set_round(&mut self, r: usize) {
        self.round = r;
    }

    /// Stamp subsequent events with FLANP stage index `s`.
    pub fn set_stage(&mut self, s: usize) {
        self.stage = s;
    }

    pub fn round(&self) -> usize {
        self.round
    }

    /// Count the event and forward it to the sink (if any). Callers
    /// guard with [`Observe::enabled`]; calling unguarded is correct
    /// but pays the detail construction.
    pub fn emit(&mut self, kind: EventKind, client: Option<usize>, detail: Json) {
        self.counts[kind as usize] += 1;
        if self.sink.enabled() {
            let ev = Event {
                round: self.round,
                stage: self.stage,
                kind,
                client,
                detail,
            };
            self.sink.emit(&ev);
        }
    }

    /// Events of `kind` seen so far.
    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind as usize]
    }

    /// Fold one relative speed-estimator error `|est - realized| /
    /// realized` into the registry histogram (fed by
    /// `coordinator::solvers::deadline_round` for every arrived
    /// client).
    pub fn observe_estimate_error(&mut self, rel: f64) {
        if rel.is_finite() {
            self.est_err.push(rel);
            self.est_err_sketch.push(rel);
        }
    }

    /// The machine-readable run summary (schema `flanp-summary/v1`):
    /// final statistics from the trace, per-kind event counts, the
    /// estimator-error quantiles and the per-phase host-time breakdown
    /// of the span profiler.
    pub fn summary_json(&self, trace: &Trace, wall_ms: f64) -> Json {
        let last = trace.rounds.last();
        let f = |g: fn(&crate::fed::metrics::RoundRecord) -> f64| {
            num(last.map_or(f64::NAN, g))
        };
        let events = Json::Obj(
            EventKind::ALL
                .iter()
                .map(|k| {
                    (k.as_str().to_string(), Json::from(self.counts[*k as usize] as f64))
                })
                .collect(),
        );
        let est = if self.est_err.count() > 0 {
            obj(vec![
                ("count", (self.est_err.count() as usize).into()),
                ("mean", num(self.est_err.mean())),
                ("p50", num(self.est_err_sketch.query(0.5))),
                ("p90", num(self.est_err_sketch.query(0.9))),
                ("p99", num(self.est_err_sketch.query(0.99))),
                ("max", num(self.est_err.max())),
            ])
        } else {
            obj(vec![("count", 0usize.into())])
        };
        let spans = Json::Obj(
            span_report()
                .into_iter()
                .map(|(name, total_us, count)| {
                    (
                        name.to_string(),
                        obj(vec![
                            ("total_us", (total_us as f64).into()),
                            ("count", (count as f64).into()),
                        ]),
                    )
                })
                .collect(),
        );
        obj(vec![
            ("schema", SUMMARY_SCHEMA.into()),
            ("algo", trace.algo.as_str().into()),
            ("rounds", trace.rounds.len().saturating_sub(1).into()),
            ("virtual_time", num(trace.total_time)),
            ("finished", trace.finished.into()),
            ("final_loss", f(|r| r.loss_full)),
            ("final_acc", f(|r| r.accuracy)),
            ("final_dist", f(|r| r.dist_to_opt)),
            ("wall_ms", num(wall_ms)),
            (
                "totals",
                obj(vec![
                    ("missed", trace.total_missed().into()),
                    ("cancelled", trace.total_cancelled().into()),
                    (
                        "dropped",
                        trace
                            .rounds
                            .iter()
                            .map(|r| r.dropped)
                            .sum::<usize>()
                            .into(),
                    ),
                    ("reranks", trace.total_reranks().into()),
                    (
                        "min_available",
                        match trace.min_available() {
                            Some(m) => m.into(),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            ("events", events),
            ("estimator_error", est),
            ("spans", spans),
        ])
    }
}

impl std::fmt::Debug for Observe {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Observe")
            .field("enabled", &self.enabled())
            .field("round", &self.round)
            .field("stage", &self.stage)
            .finish()
    }
}

/// A finite number as [`Json::Num`], anything else (NaN, the `+inf`
/// deadline of [`crate::fed::DeadlinePolicy::Sync`]) as [`Json::Null`]
/// — JSON has no spelling for non-finite floats. Shared by every
/// event-detail construction site.
pub fn num(n: f64) -> Json {
    if n.is_finite() {
        Json::Num(n)
    } else {
        Json::Null
    }
}

// ---------------------------------------------------------------------------
// span profiler
// ---------------------------------------------------------------------------

/// The instrumented phases of the round loop. `Kernels` nests inside
/// `LocalRounds` (the `engine::kernels` fan-out measured from
/// `coordinator::gate`), so the five top-level phases partition the
/// loop and `kernels` attributes the compute share separately.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Phase {
    Select,
    LocalRounds,
    Aggregate,
    Eval,
    Bookkeeping,
    Kernels,
}

/// Number of profiled phases.
pub const NUM_PHASES: usize = 6;

/// Phase wire names, indexed by `Phase as usize`.
pub const PHASE_NAMES: [&str; NUM_PHASES] =
    ["select", "local_rounds", "aggregate", "eval", "bookkeeping", "kernels"];

static PROFILING: AtomicBool = AtomicBool::new(false);

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static SPAN_US: [AtomicU64; NUM_PHASES] = [ZERO; NUM_PHASES];
static SPAN_N: [AtomicU64; NUM_PHASES] = [ZERO; NUM_PHASES];

/// Turn the span profiler on or off process-wide. Off (the default)
/// reduces every [`Span::enter`] to one relaxed atomic load.
pub fn enable_profiling(on: bool) {
    PROFILING.store(on, Ordering::Relaxed);
}

/// Whether the span profiler is currently recording.
pub fn profiling_enabled() -> bool {
    PROFILING.load(Ordering::Relaxed)
}

/// Zero all accumulated span totals (call before a profiled run).
pub fn reset_spans() {
    for i in 0..NUM_PHASES {
        SPAN_US[i].store(0, Ordering::Relaxed);
        SPAN_N[i].store(0, Ordering::Relaxed);
    }
}

/// `(phase name, total host µs, times entered)` for every phase.
pub fn span_report() -> Vec<(&'static str, u64, u64)> {
    (0..NUM_PHASES)
        .map(|i| {
            (
                PHASE_NAMES[i],
                SPAN_US[i].load(Ordering::Relaxed),
                SPAN_N[i].load(Ordering::Relaxed),
            )
        })
        .collect()
}

/// RAII phase timer: construction snapshots `Instant::now`, drop adds
/// the elapsed µs to the phase's global total. When profiling is off,
/// construction is one atomic load and drop does nothing — safe to
/// leave in release hot paths.
#[must_use = "a span measures the scope it lives in"]
pub struct Span {
    phase: usize,
    start: Option<Instant>,
}

impl Span {
    #[inline]
    pub fn enter(phase: Phase) -> Span {
        let start = if PROFILING.load(Ordering::Relaxed) {
            Some(Instant::now())
        } else {
            None
        };
        Span { phase: phase as usize, start }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let us = t0.elapsed().as_micros() as u64;
            SPAN_US[self.phase].fetch_add(us, Ordering::Relaxed);
            SPAN_N[self.phase].fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(EventKind::parse("nope"), None);
    }

    #[test]
    fn event_json_roundtrip() {
        let ev = Event {
            round: 3,
            stage: 1,
            kind: EventKind::Missed,
            client: Some(7),
            detail: obj(vec![("total", 410.0.into())]),
        };
        let line = ev.to_json().to_string();
        let back = Event::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.round, 3);
        assert_eq!(back.stage, 1);
        assert_eq!(back.kind, EventKind::Missed);
        assert_eq!(back.client, Some(7));
        assert_eq!(back.detail.req_f64("total").unwrap(), 410.0);
    }

    #[test]
    fn off_is_disabled_and_noop() {
        let mut o = Observe::off();
        assert!(!o.enabled());
        // unguarded emit still counts (callers guard; this is the
        // registry contract, not the hot path)
        o.emit(EventKind::Arrived, Some(0), Json::Null);
        assert_eq!(o.count(EventKind::Arrived), 1);
    }

    #[test]
    fn collect_only_is_enabled() {
        let o = Observe::new(Box::new(NoopObserver), true);
        assert!(o.enabled());
    }

    #[test]
    fn spans_accumulate_only_when_profiling() {
        reset_spans();
        enable_profiling(false);
        {
            let _s = Span::enter(Phase::Eval);
        }
        assert_eq!(span_report()[Phase::Eval as usize].2, 0);
        enable_profiling(true);
        {
            let _s = Span::enter(Phase::Eval);
        }
        enable_profiling(false);
        let (name, _us, n) = span_report()[Phase::Eval as usize];
        assert_eq!(name, "eval");
        assert_eq!(n, 1);
    }

    #[test]
    fn summary_schema_fields() {
        let mut o = Observe::new(Box::new(NoopObserver), true);
        o.observe_estimate_error(0.25);
        let t = Trace::new("flanp");
        let s = o.summary_json(&t, 12.5);
        assert_eq!(s.req_str("schema").unwrap(), SUMMARY_SCHEMA);
        assert_eq!(s.req("events").unwrap().req_usize("arrived").unwrap(), 0);
        assert_eq!(
            s.req("estimator_error").unwrap().req_usize("count").unwrap(),
            1
        );
        for p in PHASE_NAMES {
            assert!(s.req("spans").unwrap().get(p).is_some(), "missing {p}");
        }
        // roundtrips through the parser
        let back = Json::parse(&s.to_string()).unwrap();
        assert_eq!(back.req_str("schema").unwrap(), SUMMARY_SCHEMA);
    }
}
