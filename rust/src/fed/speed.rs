//! Client computation-speed models (Section 2, "System Heterogeneity").
//!
//! `T_i` is node i's expected time for one local model update. The paper
//! uses two models in the experiments:
//!   * fixed speeds drawn uniformly from [50, 500]   (Section 5.1)
//!   * i.i.d. exponential with rate lambda           (Section 5.2, Thm 2)
//! plus the homogeneous case (all T_i equal) discussed after Theorem 2.

use crate::util::Rng;

#[derive(Clone, Debug, PartialEq)]
pub enum SpeedModel {
    /// T_i ~ Uniform[lo, hi), fixed for the whole run.
    Uniform { lo: f64, hi: f64 },
    /// T_i ~ Exponential(lambda), fixed for the whole run.
    Exponential { lambda: f64 },
    /// All clients identical: T_i = t.
    Homogeneous { t: f64 },
}

impl SpeedModel {
    /// The paper's Section-5.1 default.
    pub fn paper_uniform() -> Self {
        SpeedModel::Uniform { lo: 50.0, hi: 500.0 }
    }

    /// Draw T_1..T_N (unsorted). Every model consumes exactly one
    /// uniform draw per client — including `Homogeneous`, which ignores
    /// its draw — so the RNG position after the base draw is identical
    /// for every scenario. Downstream forks (the per-client minibatch
    /// streams) therefore never depend on the speed model, and a trace
    /// replay (`fed::traces`) reproduces a recorded run's data streams
    /// exactly regardless of what base model was recorded.
    pub fn draw(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.draw_one(rng)).collect()
    }

    /// One base-time draw. Consumes exactly one uniform for every model
    /// (`Homogeneous` ignores its draw) — the invariant [`SpeedModel::draw`]
    /// documents, and what lets the lazy population fleet
    /// (`fed::population`) realize client `i`'s base time from its own
    /// per-client stream with a single call, bit-identical on every
    /// re-realization.
    pub fn draw_one(&self, rng: &mut Rng) -> f64 {
        let u = rng.next_f64();
        match self {
            // identical to rng.uniform(lo, hi)
            SpeedModel::Uniform { lo, hi } => lo + (hi - lo) * u,
            // identical to rng.exponential(lambda)
            SpeedModel::Exponential { lambda } => -(1.0 - u).ln() / lambda,
            SpeedModel::Homogeneous { t } => *t,
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        // "uniform:50:500" | "exp:1.0" | "homog:100"
        let parts: Vec<&str> = s.split(':').collect();
        // NB: slice-pattern bindings below are `&&str`, hence the `&&`.
        let num = |what: &str, tok: &&str| -> Result<f64, String> {
            tok.parse()
                .map_err(|_| format!("bad {what} '{tok}' in speed spec '{s}'"))
        };
        match parts.as_slice() {
            ["uniform", lo, hi] => {
                let (lo, hi) = (num("lo", lo)?, num("hi", hi)?);
                if hi <= lo {
                    return Err(format!(
                        "uniform bounds need lo < hi in speed spec '{s}'"
                    ));
                }
                Ok(SpeedModel::Uniform { lo, hi })
            }
            ["exp", l] => {
                let lambda = num("lambda", l)?;
                if lambda <= 0.0 {
                    return Err(format!(
                        "lambda must be positive in speed spec '{s}'"
                    ));
                }
                Ok(SpeedModel::Exponential { lambda })
            }
            ["homog", t] => Ok(SpeedModel::Homogeneous { t: num("t", t)? }),
            _ => Err(format!(
                "unknown speed model '{s}' \
                 (expected uniform:lo:hi | exp:lambda | homog:t)"
            )),
        }
    }

    /// Population CDF: the fraction of clients with base time <= `t`
    /// — the percentile a drawn base speed sits at. The lazy `data:`
    /// path grades `corr:speed` skew strength with this (the O(1)
    /// analytic analogue of the eager path's speed rank / (N-1));
    /// Homogeneous has no ordering, so every client sits at 0.5.
    pub fn cdf(&self, t: f64) -> f64 {
        match self {
            SpeedModel::Uniform { lo, hi } => {
                ((t - lo) / (hi - lo)).clamp(0.0, 1.0)
            }
            SpeedModel::Exponential { lambda } => {
                if t <= 0.0 {
                    0.0
                } else {
                    1.0 - (-lambda * t).exp()
                }
            }
            SpeedModel::Homogeneous { .. } => 0.5,
        }
    }

    /// Canonical spec string; `parse(spec()) == self`.
    pub fn spec(&self) -> String {
        match self {
            SpeedModel::Uniform { lo, hi } => format!("uniform:{lo}:{hi}"),
            SpeedModel::Exponential { lambda } => format!("exp:{lambda}"),
            SpeedModel::Homogeneous { t } => format!("homog:{t}"),
        }
    }
}

/// Sort clients fastest-first and return the permutation: `order[rank] =
/// original index`. FLANP activates prefixes of this order.
pub fn sort_fastest_first(speeds: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..speeds.len()).collect();
    order.sort_by(|&a, &b| speeds[a].partial_cmp(&speeds[b]).unwrap());
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_range() {
        let m = SpeedModel::paper_uniform();
        let ts = m.draw(&mut Rng::new(1), 1000);
        assert!(ts.iter().all(|&t| (50.0..500.0).contains(&t)));
    }

    #[test]
    fn exponential_positive_with_right_mean() {
        let m = SpeedModel::Exponential { lambda: 2.0 };
        let ts = m.draw(&mut Rng::new(2), 50_000);
        assert!(ts.iter().all(|&t| t > 0.0));
        let mean = ts.iter().sum::<f64>() / ts.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn homogeneous_all_equal() {
        let m = SpeedModel::Homogeneous { t: 7.5 };
        assert!(m.draw(&mut Rng::new(3), 10).iter().all(|&t| t == 7.5));
    }

    #[test]
    fn cdf_matches_the_draw_distribution() {
        let u = SpeedModel::Uniform { lo: 50.0, hi: 500.0 };
        assert_eq!(u.cdf(50.0), 0.0);
        assert_eq!(u.cdf(500.0), 1.0);
        assert_eq!(u.cdf(275.0), 0.5);
        assert_eq!(u.cdf(0.0), 0.0, "clamped below the support");
        assert_eq!(u.cdf(1e9), 1.0, "clamped above the support");
        let e = SpeedModel::Exponential { lambda: 2.0 };
        assert_eq!(e.cdf(0.0), 0.0);
        assert!((e.cdf(0.5 * std::f64::consts::LN_2) - 0.5).abs() < 1e-12);
        let h = SpeedModel::Homogeneous { t: 7.0 };
        assert_eq!(h.cdf(7.0), 0.5);
        // empirical check: the CDF at a draw is the draw's percentile
        let draws = u.draw(&mut Rng::new(4), 20_000);
        let t = 200.0;
        let frac = draws.iter().filter(|&&x| x <= t).count() as f64
            / draws.len() as f64;
        assert!((frac - u.cdf(t)).abs() < 0.02);
    }

    #[test]
    fn draw_is_sequential_draw_one() {
        for m in [
            SpeedModel::paper_uniform(),
            SpeedModel::Exponential { lambda: 0.5 },
            SpeedModel::Homogeneous { t: 7.0 },
        ] {
            let batch = m.draw(&mut Rng::new(9), 32);
            let mut rng = Rng::new(9);
            let one_by_one: Vec<f64> =
                (0..32).map(|_| m.draw_one(&mut rng)).collect();
            assert_eq!(batch, one_by_one, "{m:?}");
        }
    }

    #[test]
    fn sorting_is_fastest_first() {
        let speeds = vec![5.0, 1.0, 3.0];
        let order = sort_fastest_first(&speeds);
        assert_eq!(order, vec![1, 2, 0]);
        // sorted speeds are non-decreasing
        let sorted: Vec<f64> = order.iter().map(|&i| speeds[i]).collect();
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(
            SpeedModel::parse("uniform:50:500").unwrap(),
            SpeedModel::paper_uniform()
        );
        assert_eq!(
            SpeedModel::parse("exp:0.5").unwrap(),
            SpeedModel::Exponential { lambda: 0.5 }
        );
        assert_eq!(
            SpeedModel::parse("homog:10").unwrap(),
            SpeedModel::Homogeneous { t: 10.0 }
        );
        assert!(SpeedModel::parse("nope").is_err());
        // spec() is the parseable canonical form for every variant
        for spec in ["uniform:50:500", "exp:0.5", "homog:10"] {
            let m = SpeedModel::parse(spec).unwrap();
            assert_eq!(m.spec(), spec);
            assert_eq!(SpeedModel::parse(&m.spec()).unwrap(), m);
        }
    }

    #[test]
    fn parse_errors_include_the_offending_spec() {
        for bad in ["uniform:a:500", "uniform:500:50", "exp:-1", "exp:x", "homog:y"] {
            let e = SpeedModel::parse(bad).unwrap_err();
            assert!(e.contains(bad), "error '{e}' does not name '{bad}'");
        }
        assert!(SpeedModel::parse("warp:9").unwrap_err().contains("warp:9"));
    }
}
