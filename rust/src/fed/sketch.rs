//! Constant-memory sketches for population-scale fleets (`fed::sketch`).
//!
//! At 10^6 clients the full-materialization structures every solver
//! leans on — the fastest-first ranking behind
//! [`crate::fed::ClientFleet::active_prefix`], the tier boundaries of
//! [`crate::fed::TierScheduler`], the `quantile:Q` deadline of
//! [`crate::fed::DeadlineController`] — stop fitting in a round budget:
//! each one is a sort or a scan over all N clients every time it is
//! consulted. This module holds their sketch replacements, sized to the
//! cohort instead of the population:
//!
//! * [`TopK`] — the k smallest `(value, id)` pairs of a stream: the
//!   FLANP prefix frontier. Selecting over n values costs O(n log k)
//!   memory O(k), and the result is **bit-identical** to the full
//!   stable sort followed by `truncate(k)`: ties break by ascending id,
//!   exactly what a stable sort over values indexed by id produces.
//! * [`QuantileSketch`] — a deterministic KLL-style quantile sketch for
//!   tier boundaries and deadline quantiles. While it holds at most
//!   `capacity` points it is *exact* — bit-identical to
//!   [`crate::fed::aggregation::quantile`]'s nearest-rank answer —
//!   and beyond that it compacts into weighted levels of
//!   O(capacity · log2(n/capacity)) total memory with a bounded rank
//!   error (see [`QuantileSketch::query`]).
//!
//! The exactness in the small regime is what lets the lazy population
//! fleet (`fed::population`) pin itself bit-identical to the
//! materialized [`crate::fed::ClientFleet`] at small N while the same
//! code path scales to millions (see `docs/scale.md`).
//!
//! ```
//! use flanp::fed::{QuantileSketch, TopK};
//!
//! // TopK selection == stable sort + truncate; ties break by id
//! let est = [3.0, 1.0, 2.0, 1.0];
//! assert_eq!(TopK::select(&est, 3), vec![1, 3, 2]);
//! assert_eq!(TopK::select(&est, 9), vec![1, 3, 2, 0]);
//!
//! // the sketch is exact below its capacity...
//! let mut sk = QuantileSketch::new(256);
//! for i in 0..100 {
//!     sk.push(i as f64);
//! }
//! assert!(sk.is_exact());
//! assert_eq!(sk.query(0.5), 49.0); // nearest-rank, like fed::aggregation::quantile
//! // ...and stays within its rank-error bound far beyond it
//! for i in 100..100_000 {
//!     sk.push(i as f64);
//! }
//! assert!(!sk.is_exact());
//! let med = sk.query(0.5) / 100_000.0;
//! assert!((med - 0.5).abs() < 0.05, "median rank {med}");
//! ```

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A `(value, id)` stream element ordered lexicographically — by value
/// first (`f64::total_cmp`), then by id. This is exactly the effective
/// key of the stable [`crate::fed::speed::sort_fastest_first`] sort,
/// which keeps index order on equal speeds.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Entry {
    value: f64,
    id: usize,
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.value.total_cmp(&other.value).then(self.id.cmp(&other.id))
    }
}

/// Streaming top-K selection: retains the k smallest `(value, id)`
/// pairs seen so far (a bounded max-heap), the FLANP fastest-prefix
/// frontier at population scale.
///
/// [`TopK::ids`] returns the retained ids fastest-first and is
/// bit-identical to sorting all n values with the stable fastest-first
/// sort and truncating to k — the property
/// [`crate::fed::SpeedEstimator::ranked_prefix`] (and therefore every
/// existing prefix test) relies on.
///
/// ```
/// use flanp::fed::TopK;
///
/// let mut t = TopK::new(2);
/// for (id, v) in [4.0, 1.0, 3.0, 1.0].into_iter().enumerate() {
///     t.push(v, id);
/// }
/// // the two smallest values are the ties at 1.0; ids stay ascending
/// assert_eq!(t.ids(), vec![1, 3]);
/// assert_eq!(t.items(), vec![(1.0, 1), (1.0, 3)]);
/// ```
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    heap: BinaryHeap<Entry>,
}

impl TopK {
    /// An empty frontier that will retain at most `k` elements.
    pub fn new(k: usize) -> Self {
        TopK { k, heap: BinaryHeap::with_capacity(k + 1) }
    }

    /// Capacity `k` this frontier was built with.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Elements currently retained (`min(k, pushes)` once ids are
    /// distinct).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer one element; it is retained iff it is among the k
    /// lexicographically-smallest `(value, id)` pairs seen so far.
    /// `value` must not be NaN (NaN would also panic the materialized
    /// fastest-first sort this mirrors).
    pub fn push(&mut self, value: f64, id: usize) {
        assert!(!value.is_nan(), "NaN value in top-K frontier");
        let e = Entry { value, id };
        if self.heap.len() < self.k {
            self.heap.push(e);
        } else if let Some(top) = self.heap.peek() {
            if e < *top {
                self.heap.pop();
                self.heap.push(e);
            }
        }
    }

    /// Retained `(value, id)` pairs, fastest-first (ties by ascending
    /// id).
    pub fn items(&self) -> Vec<(f64, usize)> {
        let mut v: Vec<Entry> = self.heap.iter().copied().collect();
        v.sort();
        v.into_iter().map(|e| (e.value, e.id)).collect()
    }

    /// Retained ids, fastest-first — bit-identical to
    /// `sort_fastest_first(values)` truncated to k when fed every
    /// `(values[i], i)`.
    pub fn ids(&self) -> Vec<usize> {
        let mut v: Vec<Entry> = self.heap.iter().copied().collect();
        v.sort();
        v.into_iter().map(|e| e.id).collect()
    }

    /// One-shot selection over a full slice: the ids of the k smallest
    /// values, fastest-first. O(n log k) — the drop-in replacement for
    /// "stable-sort all n, keep the first k".
    pub fn select(values: &[f64], k: usize) -> Vec<usize> {
        let mut t = TopK::new(k.min(values.len()));
        for (i, &v) in values.iter().enumerate() {
            t.push(v, i);
        }
        t.ids()
    }
}

/// A deterministic KLL-style quantile sketch.
///
/// Values live in levels of weight `2^level`; level buffers that
/// overflow `capacity` are sorted and *compacted*: every other element
/// (alternating the starting offset between compactions, so successive
/// compaction errors cancel instead of accumulating) is promoted to the
/// next level at double weight, the rest are discarded. Memory is
/// O(capacity · log2(n/capacity)); the rank error of a query is at most
/// `(log2(n/capacity) + 1) / capacity` of the total weight — about
/// 0.04 at capacity 256 over 10^5 points, and typically far smaller
/// because of the alternating offsets (verified empirically in this
/// module's tests).
///
/// Until the first compaction ([`QuantileSketch::is_exact`]) every
/// point is stored at weight 1 and [`QuantileSketch::query`] is
/// bit-identical to [`crate::fed::aggregation::quantile`] — same
/// nearest-rank formula, same `+inf` on an empty sketch. That exactness
/// is the small-N regression pin for sketch-based deadlines and tier
/// boundaries.
///
/// ```
/// use flanp::fed::aggregation::quantile;
/// use flanp::fed::QuantileSketch;
///
/// let xs = [40.0, 10.0, 30.0, 20.0];
/// let mut sk = QuantileSketch::new(64);
/// for &x in &xs {
///     sk.push(x);
/// }
/// for q in [0.01, 0.25, 0.5, 0.75, 1.0] {
///     assert_eq!(sk.query(q), quantile(&xs, q));
/// }
/// assert_eq!(QuantileSketch::new(64).query(0.5), f64::INFINITY);
/// ```
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    capacity: usize,
    /// `levels[l]` holds values of weight `2^l` (level 0 unsorted)
    levels: Vec<Vec<f64>>,
    count: u64,
    compactions: u64,
}

impl QuantileSketch {
    /// Default per-level buffer capacity: ~1% rank error at 10^6 points.
    pub const DEFAULT_CAPACITY: usize = 256;

    /// A sketch whose per-level buffers hold `capacity` values.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 8, "sketch capacity {capacity} < 8");
        QuantileSketch {
            capacity,
            levels: vec![Vec::new()],
            count: 0,
            compactions: 0,
        }
    }

    /// Total points pushed (not the number stored).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Values currently stored across all levels (the memory bound).
    pub fn stored(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// True while no compaction has happened: every point is stored and
    /// [`QuantileSketch::query`] equals the exact nearest-rank quantile.
    pub fn is_exact(&self) -> bool {
        self.compactions == 0
    }

    /// Add one point. Amortized O(1); NaN is rejected (it would poison
    /// every downstream deadline and boundary).
    pub fn push(&mut self, x: f64) {
        assert!(!x.is_nan(), "NaN value in quantile sketch");
        self.levels[0].push(x);
        self.count += 1;
        let mut l = 0;
        while self.levels[l].len() > self.capacity {
            self.compact(l);
            l += 1;
        }
    }

    fn compact(&mut self, l: usize) {
        if self.levels.len() == l + 1 {
            self.levels.push(Vec::new());
        }
        let offset = (self.compactions & 1) as usize;
        self.compactions += 1;
        let mut buf = std::mem::take(&mut self.levels[l]);
        buf.sort_by(|a, b| a.total_cmp(b));
        let mut i = offset;
        while i < buf.len() {
            self.levels[l + 1].push(buf[i]);
            i += 2;
        }
    }

    /// Weighted nearest-rank `q`-quantile of everything pushed so far
    /// (`q` in (0, 1]; `q = 1` is the stored maximum). An empty sketch
    /// yields `+inf`, mirroring [`crate::fed::aggregation::quantile`]
    /// so a deadline over an empty cohort never rejects anyone.
    pub fn query(&self, q: f64) -> f64 {
        let mut items: Vec<(f64, u64)> = Vec::with_capacity(self.stored());
        for (l, buf) in self.levels.iter().enumerate() {
            let w = 1u64 << l;
            items.extend(buf.iter().map(|&v| (v, w)));
        }
        if items.is_empty() {
            return f64::INFINITY;
        }
        items.sort_by(|a, b| a.0.total_cmp(&b.0));
        let total: u64 = items.iter().map(|&(_, w)| w).sum();
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for &(v, w) in &items {
            cum += w;
            if cum >= rank {
                return v;
            }
        }
        items.last().unwrap().0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::aggregation::quantile;
    use crate::fed::speed::sort_fastest_first;
    use crate::util::Rng;

    #[test]
    fn topk_matches_stable_sort_prefix() {
        let mut rng = Rng::new(5);
        // duplicates are the interesting case: ties must keep id order
        let values: Vec<f64> =
            (0..200).map(|_| (rng.below(40) as f64) * 0.5).collect();
        let full = sort_fastest_first(&values);
        for k in [0, 1, 3, 17, 100, 200, 500] {
            let want: Vec<usize> =
                full.iter().copied().take(k).collect();
            assert_eq!(TopK::select(&values, k), want, "k = {k}");
        }
    }

    #[test]
    fn topk_tracks_drifted_estimates() {
        // the frontier is rebuilt from live estimates each selection, so
        // a drifted client must fall out exactly as a full re-sort says
        let mut est: Vec<f64> = (0..50).map(|i| 50.0 + i as f64).collect();
        assert_eq!(TopK::select(&est, 3), vec![0, 1, 2]);
        est[0] = 1e6; // the fastest client slows down 4 orders
        est[49] = 1.0; // the slowest becomes fastest
        let want: Vec<usize> =
            sort_fastest_first(&est).into_iter().take(3).collect();
        assert_eq!(TopK::select(&est, 3), want);
        assert_eq!(TopK::select(&est, 3), vec![49, 1, 2]);
    }

    #[test]
    fn topk_streaming_matches_one_shot() {
        let mut rng = Rng::new(9);
        let values: Vec<f64> = (0..500).map(|_| rng.next_f64()).collect();
        let mut t = TopK::new(16);
        for (i, &v) in values.iter().enumerate() {
            t.push(v, i);
        }
        assert_eq!(t.len(), 16);
        assert_eq!(t.k(), 16);
        assert!(!t.is_empty());
        assert_eq!(t.ids(), TopK::select(&values, 16));
        let items = t.items();
        assert!(items.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn topk_zero_capacity_is_empty() {
        let mut t = TopK::new(0);
        t.push(1.0, 0);
        assert!(t.is_empty());
        assert_eq!(t.ids(), Vec::<usize>::new());
        assert_eq!(TopK::select(&[1.0, 2.0], 0), Vec::<usize>::new());
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn topk_rejects_nan() {
        TopK::new(4).push(f64::NAN, 0);
    }

    #[test]
    fn sketch_is_exact_below_capacity() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> =
            (0..200).map(|_| rng.uniform(50.0, 500.0)).collect();
        let mut sk = QuantileSketch::new(256);
        for &x in &xs {
            sk.push(x);
        }
        assert!(sk.is_exact());
        assert_eq!(sk.count(), 200);
        assert_eq!(sk.stored(), 200);
        for q in [0.01, 0.1, 0.25, 0.5, 0.8, 0.95, 1.0] {
            assert_eq!(sk.query(q), quantile(&xs, q), "q = {q}");
        }
    }

    #[test]
    fn sketch_empty_is_infinite() {
        assert_eq!(QuantileSketch::new(64).query(0.5), f64::INFINITY);
    }

    #[test]
    fn sketch_rank_error_is_bounded_at_scale() {
        // 10^5 uniform points: the value at rank-quantile q is ~q, so
        // |query(q) - q| reads the rank error directly
        let n = 100_000usize;
        let m = 256usize;
        let mut rng = Rng::new(11);
        let mut sk = QuantileSketch::new(m);
        for _ in 0..n {
            sk.push(rng.next_f64());
        }
        assert!(!sk.is_exact());
        // documented bound: (log2(n/m) + 1) / m ≈ 0.037 at these sizes
        let bound = ((n as f64 / m as f64).log2() + 1.0) / m as f64;
        for q in [0.05, 0.25, 0.5, 0.75, 0.9, 0.99] {
            let err = (sk.query(q) - q).abs();
            assert!(
                err <= bound,
                "q = {q}: rank error {err} exceeds bound {bound}"
            );
        }
    }

    #[test]
    fn sketch_memory_is_logarithmic() {
        let m = 64usize;
        let mut sk = QuantileSketch::new(m);
        for i in 0..1_000_000u64 {
            sk.push(i as f64);
        }
        // O(capacity * log2(n/capacity)): generous factor-2 headroom
        let levels = (1_000_000f64 / m as f64).log2().ceil() as usize + 2;
        assert!(
            sk.stored() <= m * levels,
            "stored {} over {} levels of {m}",
            sk.stored(),
            levels
        );
    }

    #[test]
    fn sketch_query_order_statistics_are_monotone() {
        let mut rng = Rng::new(17);
        let mut sk = QuantileSketch::new(32);
        for _ in 0..10_000 {
            sk.push(rng.uniform(0.0, 1000.0));
        }
        let qs = [0.1, 0.3, 0.5, 0.7, 0.9, 1.0];
        let vals: Vec<f64> = qs.iter().map(|&q| sk.query(q)).collect();
        assert!(vals.windows(2).all(|w| w[0] <= w[1]), "{vals:?}");
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn sketch_rejects_nan() {
        QuantileSketch::new(8).push(f64::NAN);
    }
}
