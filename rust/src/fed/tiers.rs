//! TiFL-style tier scheduling (`fed::tiers`).
//!
//! Re-ranking every client every round is what the per-round estimate
//! lookup in [`crate::fed::ClientFleet::active_prefix`] amounts to; TiFL
//! (Chai et al., 2020) shows that grouping clients into latency **tiers**
//! and scheduling whole tiers cuts both wall-clock and scheduling
//! overhead at scale, because tier membership is *cached* and only
//! revisited when a client's latency genuinely drifts. This module is
//! that subsystem:
//!
//! * [`TierPolicy`] — the configuration: how many tiers `K` and the
//!   hysteresis band `H` that cached membership must be breached by
//!   before anything is recomputed. Parsed from the CLI with
//!   [`TierPolicy::parse`] (grammar
//!   `tiers:K[:split:quantile|kmeans][:hysteresis:H]`, composing with
//!   the scenario and deadline grammars of [`crate::fed::SystemModel`] /
//!   [`crate::fed::DeadlinePolicy`]). The split clause picks boundary
//!   placement: equal-rank quantiles (default) or a 1-D k-means that
//!   adapts to clustered latency distributions ([`TierSplit`]).
//! * [`TierScheduler`] — the per-run state machine: clusters the fleet
//!   into `K` equal-rank latency tiers from the online
//!   [`SpeedEstimator`] (a quantile split of the estimate ranking),
//!   caches the ranking and the tier membership across rounds and
//!   stages, re-tiers **only** when a client's estimate drifts past `H x`
//!   its tier's frozen estimate band, and selects one tier per round by
//!   TiFL's fairness credits (smooth weighted round-robin: fast tiers
//!   are scheduled proportionally more often, slow tiers still
//!   contribute at a guaranteed rate instead of starving).
//!
//! Under a static scenario the estimator is an exact fixed point
//! (see [`SpeedEstimator::observe`]), so the cached ranking equals the
//! live estimate ranking bit-for-bit and the hysteresis check never
//! fires: tier caching is a strict no-op relative to estimate-based
//! ranking (proven in `tests/tiers.rs`). Deadline-censored observations
//! ([`SpeedEstimator::observe_censored`]) move estimates through the
//! same path as exact ones, so a deadline-missing client can be demoted
//! out of its tier by the very same hysteresis trigger.
//!
//! ```
//! use flanp::fed::{TierPolicy, TierSplit};
//!
//! // spec grammar: tiers:K[:split:quantile|kmeans][:hysteresis:H]
//! let p = TierPolicy::parse("tiers:5").unwrap();
//! assert_eq!(p.tiers, 5);
//! assert_eq!(p.hysteresis, flanp::fed::tiers::DEFAULT_HYSTERESIS);
//! assert_eq!(p.split, TierSplit::Quantile);
//! let q = TierPolicy::parse("tiers:4:hysteresis:2").unwrap();
//! assert_eq!(q.hysteresis, 2.0);
//! // the 1-D k-means split adapts boundaries to clustered latencies
//! let k = TierPolicy::parse("tiers:3:split:kmeans").unwrap();
//! assert_eq!(k.split, TierSplit::KMeans);
//! // every canonical spec re-parses to the same policy
//! assert_eq!(TierPolicy::parse(&p.spec()).unwrap(), p);
//! assert_eq!(TierPolicy::parse(&q.spec()).unwrap(), q);
//! assert_eq!(TierPolicy::parse(&k.spec()).unwrap(), k);
//! assert_eq!(k.spec(), "tiers:3:split:kmeans");
//! assert!(TierPolicy::parse("tiers:0").is_err());
//! assert!(TierPolicy::parse("tiers:3:split:dbscan").is_err());
//! ```

use crate::fed::speed::sort_fastest_first;
use crate::fed::system::SpeedEstimator;

/// Default hysteresis band multiplier: an estimate may drift up to 1.5x
/// past its tier's frozen band before a re-tier is triggered.
pub const DEFAULT_HYSTERESIS: f64 = 1.5;

/// How tier boundaries are placed on the estimate ranking.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TierSplit {
    /// Equal-rank quantile split (TiFL's default): tier sizes differ by
    /// at most one regardless of the latency distribution.
    #[default]
    Quantile,
    /// 1-D k-means (Lloyd's) over the estimates: boundaries settle into
    /// the gaps of a clustered latency distribution — a fleet of "fast
    /// datacenter / mid-tier phone / slow straggler" groups tiers along
    /// those modes instead of splitting a mode down the middle.
    KMeans,
}

/// How the fleet is clustered into latency tiers.
#[derive(Clone, Debug, PartialEq)]
pub struct TierPolicy {
    /// number of tiers `K` (clamped to the fleet size at scheduling time)
    pub tiers: usize,
    /// hysteresis band multiplier `H >= 1`: a client triggers a re-tier
    /// only when its estimate exceeds `H x` its tier's frozen upper band
    /// (demotion) or falls below `1/H x` the frozen lower band
    /// (promotion)
    pub hysteresis: f64,
    /// where the tier boundaries go (quantile ranks vs 1-D k-means)
    pub split: TierSplit,
}

impl TierPolicy {
    /// A `K`-tier policy with the default hysteresis band and split.
    pub fn new(tiers: usize) -> Self {
        TierPolicy {
            tiers,
            hysteresis: DEFAULT_HYSTERESIS,
            split: TierSplit::Quantile,
        }
    }

    /// Parse a tier spec. Grammar:
    ///
    /// ```text
    ///   tiers:K[:split:quantile|kmeans][:hysteresis:H]
    /// ```
    ///
    /// `K` is a positive tier count, `H >= 1` a hysteresis band
    /// multiplier (default [`DEFAULT_HYSTERESIS`]); the `split` clause
    /// selects boundary placement (default `quantile`).
    ///
    /// ```
    /// use flanp::fed::{TierPolicy, TierSplit};
    /// assert_eq!(TierPolicy::parse("tiers:4").unwrap(), TierPolicy::new(4));
    /// let p = TierPolicy::parse("tiers:4:split:kmeans:hysteresis:2").unwrap();
    /// assert_eq!((p.split, p.hysteresis), (TierSplit::KMeans, 2.0));
    /// assert_eq!(TierPolicy::parse(&p.spec()).unwrap(), p);
    /// assert!(TierPolicy::parse("tiers:4:hysteresis:0.5").is_err());
    /// assert!(TierPolicy::parse("tiers").is_err());
    /// ```
    pub fn parse(spec: &str) -> Result<Self, String> {
        let toks: Vec<&str> = spec.split(':').collect();
        if toks.first() != Some(&"tiers") || toks.len() < 2 {
            return Err(format!(
                "unknown tier spec '{spec}' \
                 (expected tiers:K[:split:quantile|kmeans][:hysteresis:H])"
            ));
        }
        let tiers = toks[1].parse().map_err(|_| {
            format!("bad tier count '{}' in tier spec '{spec}'", toks[1])
        })?;
        let mut policy = TierPolicy::new(tiers);
        let mut rest = &toks[2..];
        while !rest.is_empty() {
            match rest {
                ["hysteresis", h, tail @ ..] => {
                    policy.hysteresis = h.parse().map_err(|_| {
                        format!("bad hysteresis '{h}' in tier spec '{spec}'")
                    })?;
                    rest = tail;
                }
                ["split", s, tail @ ..] => {
                    policy.split = match *s {
                        "quantile" => TierSplit::Quantile,
                        "kmeans" => TierSplit::KMeans,
                        other => {
                            return Err(format!(
                                "bad split '{other}' in tier spec '{spec}' \
                                 (expected quantile | kmeans)"
                            ))
                        }
                    };
                    rest = tail;
                }
                _ => {
                    return Err(format!(
                        "unknown tier spec '{spec}' (expected \
                         tiers:K[:split:quantile|kmeans][:hysteresis:H])"
                    ))
                }
            }
        }
        policy.validate().map_err(|e| format!("{e} in tier spec '{spec}'"))?;
        Ok(policy)
    }

    /// Canonical spec string; `parse(spec()) == self` for every policy.
    /// The default hysteresis and split are omitted, mirroring how
    /// [`crate::fed::SystemModel::spec`] drops the redundant `static:`.
    pub fn spec(&self) -> String {
        let mut s = format!("tiers:{}", self.tiers);
        if self.split != TierSplit::Quantile {
            s.push_str(":split:kmeans");
        }
        if self.hysteresis != DEFAULT_HYSTERESIS {
            s.push_str(&format!(":hysteresis:{}", self.hysteresis));
        }
        s
    }

    /// Structural sanity check (configs can be built without `parse`).
    pub fn validate(&self) -> Result<(), String> {
        if self.tiers == 0 {
            return Err("tier count must be positive".into());
        }
        if !(self.hysteresis >= 1.0 && self.hysteresis.is_finite()) {
            return Err(format!(
                "hysteresis {} must be a finite multiplier >= 1",
                self.hysteresis
            ));
        }
        Ok(())
    }

    /// Tier boundaries from a population quantile sketch instead of a
    /// full ranking: the upper estimate bound of tier `t` (0 = fastest)
    /// is the sketch's `(t+1)/K` quantile. While the sketch is exact
    /// these equal the quantile-split upper *bands* the materialized
    /// [`TierScheduler`] freezes — both are the nearest-rank value at
    /// `ceil(k·n/K)` — so a population fleet can place a client into a
    /// tier by comparing its estimate against K boundaries without ever
    /// ranking all N clients (see `docs/scale.md`).
    ///
    /// ```
    /// use flanp::fed::{QuantileSketch, TierPolicy};
    ///
    /// let mut sk = QuantileSketch::new(64);
    /// for e in [10.0, 20.0, 30.0, 40.0, 50.0, 60.0] {
    ///     sk.push(e);
    /// }
    /// let bounds = TierPolicy::new(3).sketch_bounds(&sk);
    /// // 6 clients into 3 tiers: boundaries at ranks 2, 4, 6
    /// assert_eq!(bounds, vec![20.0, 40.0, 60.0]);
    /// ```
    pub fn sketch_bounds(
        &self,
        sketch: &crate::fed::sketch::QuantileSketch,
    ) -> Vec<f64> {
        (1..=self.tiers)
            .map(|k| sketch.query(k as f64 / self.tiers as f64))
            .collect()
    }
}

/// The per-run tier state machine: cached latency ranking, cached tier
/// membership with hysteresis-gated re-tiering, and credit-based tier
/// selection.
///
/// The scheduler is deterministic: the same policy and estimate stream
/// always produce the same tierings, the same re-tier events and the
/// same tier-selection sequence (no RNG anywhere).
#[derive(Clone, Debug)]
pub struct TierScheduler {
    policy: TierPolicy,
    /// cached fastest-first ranking of all clients (from the last tiering)
    order: Vec<usize>,
    /// client id -> tier index (0 = fastest tier)
    tier_of: Vec<usize>,
    /// exclusive end rank of each tier in `order`; the last entry is the
    /// fleet size, so every bound is a whole-tier prefix length
    bounds: Vec<usize>,
    /// frozen per-tier estimate bands `[min, max]` at tiering time — the
    /// reference the hysteresis check compares live estimates against
    bands: Vec<(f64, f64)>,
    /// fairness credits for tier selection (smooth weighted round-robin)
    credits: Vec<f64>,
    retier_events: usize,
}

impl TierScheduler {
    /// Tier the fleet from the current estimates. The initial tiering is
    /// TiFL's profiling step and is not counted as a re-tier event.
    pub fn new(policy: TierPolicy, est: &SpeedEstimator) -> Self {
        policy.validate().expect("invalid tier policy");
        let n = est.estimates().len();
        assert!(n > 0, "tiering an empty fleet");
        let num_tiers = policy.tiers.min(n);
        let mut s = TierScheduler {
            policy,
            order: Vec::new(),
            tier_of: vec![0; n],
            bounds: Vec::new(),
            bands: Vec::new(),
            credits: vec![0.0; num_tiers],
            retier_events: 0,
        };
        s.tier(est);
        s
    }

    pub fn policy(&self) -> &TierPolicy {
        &self.policy
    }

    /// Number of tiers actually in use (`K` clamped to the fleet size).
    pub fn num_tiers(&self) -> usize {
        self.bounds.len()
    }

    /// The cached fastest-first ranking (valid as of the last tiering).
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    /// Tier index of one client (0 = fastest tier).
    pub fn tier_of(&self, client: usize) -> usize {
        self.tier_of[client]
    }

    /// Client ids of one tier, fastest-first.
    pub fn tier_members(&self, tier: usize) -> &[usize] {
        let start = if tier == 0 { 0 } else { self.bounds[tier - 1] };
        &self.order[start..self.bounds[tier]]
    }

    /// Re-tier events so far (the initial tiering is not counted).
    pub fn retier_events(&self) -> usize {
        self.retier_events
    }

    /// The full client-id -> tier-index map (0 = fastest tier). Used by
    /// the observability layer (`fed::observe`) to diff assignments
    /// around a [`TierScheduler::refresh`] and report per-client
    /// promotions/demotions.
    pub fn assignments(&self) -> &[usize] {
        &self.tier_of
    }

    /// The frozen per-tier estimate bands `[min, max]` from the last
    /// tiering, indexed by tier. A promotion/demotion event reports the
    /// band the moved client breached.
    pub fn bands(&self) -> &[(f64, f64)] {
        &self.bands
    }

    /// Recompute ranking, membership, boundaries and bands from the
    /// current estimates: a quantile split of the estimate ranking into
    /// `num_tiers` near-equal rank ranges, or a 1-D k-means split whose
    /// boundaries settle into the gaps of a clustered distribution
    /// ([`TierSplit`]).
    fn tier(&mut self, est: &SpeedEstimator) {
        let ests = est.estimates();
        let n = ests.len();
        let num_tiers = self.policy.tiers.min(n);
        self.order = sort_fastest_first(ests);
        self.bounds = match self.policy.split {
            TierSplit::Quantile => {
                (1..=num_tiers).map(|k| (k * n).div_ceil(num_tiers)).collect()
            }
            TierSplit::KMeans => {
                let sorted: Vec<f64> =
                    self.order.iter().map(|&c| ests[c]).collect();
                kmeans_bounds(&sorted, num_tiers)
            }
        };
        self.bands.clear();
        let mut start = 0;
        for (tier, &end) in self.bounds.iter().enumerate() {
            self.bands.push((ests[self.order[start]], ests[self.order[end - 1]]));
            for &c in &self.order[start..end] {
                self.tier_of[c] = tier;
            }
            start = end;
        }
    }

    /// Has any client's estimate drifted past the hysteresis band of its
    /// cached tier? A client in the slowest tier cannot drift *down* out
    /// of it, nor a fastest-tier client *up*, so those directions are
    /// exempt — within-tier movement never invalidates the cache.
    pub fn needs_retier(&self, est: &SpeedEstimator) -> bool {
        let h = self.policy.hysteresis;
        let last = self.bands.len() - 1;
        self.tier_of.iter().enumerate().any(|(client, &tier)| {
            let e = est.estimate(client);
            let (lo, hi) = self.bands[tier];
            (tier < last && e > hi * h) || (tier > 0 && e * h < lo)
        })
    }

    /// The hysteresis gate: re-tier from the current estimates iff some
    /// client breached its band; returns whether a re-tier happened.
    /// Cached membership survives any amount of within-band drift.
    pub fn refresh(&mut self, est: &SpeedEstimator) -> bool {
        if self.needs_retier(est) {
            self.tier(est);
            self.retier_events += 1;
            true
        } else {
            false
        }
    }

    /// Smallest whole-tier prefix length covering at least `n` clients
    /// (FLANP stage sizes snap UP to tier boundaries).
    pub fn snap(&self, n: usize) -> usize {
        let n = n.max(1);
        for &b in &self.bounds {
            if b >= n {
                return b;
            }
        }
        *self.bounds.last().unwrap()
    }

    /// The fastest whole tiers covering at least `n` clients, in cached
    /// fastest-first order.
    pub fn prefix(&self, n: usize) -> Vec<usize> {
        self.order[..self.snap(n)].to_vec()
    }

    /// Select the tier that trains this round by TiFL's fairness credits
    /// (smooth weighted round-robin): every round every tier accrues
    /// credit — faster tiers proportionally more — and the richest tier
    /// is selected and pays the full weight sum. Tier `t` of `K` is thus
    /// selected exactly `K - t` times per `K(K+1)/2` rounds: fast tiers
    /// dominate, but slow tiers are guaranteed a known participation
    /// rate instead of starving (their data still enters the model).
    pub fn select_tier(&mut self) -> usize {
        let num_tiers = self.credits.len();
        let total = (num_tiers * (num_tiers + 1) / 2) as f64;
        let mut sel = 0;
        for t in 0..num_tiers {
            self.credits[t] += (num_tiers - t) as f64;
            if self.credits[t] > self.credits[sel] {
                sel = t;
            }
        }
        self.credits[sel] -= total;
        sel
    }
}

/// 1-D k-means (Lloyd's) over the sorted estimates, returned as the same
/// exclusive-end rank bounds the quantile split produces. Optimal 1-D
/// clusters are contiguous in sorted order, so the assignment step
/// reduces to moving each of the `k - 1` interior boundaries to the
/// midpoint between the adjacent cluster means. Deterministic:
/// quantile-split initialization, a fixed iteration cap, and boundaries
/// clamped so every tier keeps at least one client.
fn kmeans_bounds(sorted: &[f64], k: usize) -> Vec<usize> {
    let n = sorted.len();
    debug_assert!(k >= 1 && k <= n);
    let mut bounds: Vec<usize> =
        (1..=k).map(|j| (j * n).div_ceil(k)).collect();
    for _ in 0..64 {
        // cluster means from the current boundaries
        let mut means = Vec::with_capacity(k);
        let mut start = 0;
        for &end in &bounds {
            let m =
                sorted[start..end].iter().sum::<f64>() / (end - start) as f64;
            means.push(m);
            start = end;
        }
        // Lloyd assignment in 1-D: each interior boundary moves to the
        // first rank past the midpoint of the adjacent cluster means
        let mut next = bounds.clone();
        for j in 0..k - 1 {
            let mid = 0.5 * (means[j] + means[j + 1]);
            let cut = sorted.partition_point(|&v| v <= mid);
            let lo = if j == 0 { 1 } else { next[j - 1] + 1 };
            let hi = n - (k - 1 - j);
            next[j] = cut.clamp(lo, hi);
        }
        if next == bounds {
            break;
        }
        bounds = next;
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::speed::SpeedModel;
    use crate::fed::system::{SystemModel, SystemState};
    use crate::util::Rng;

    #[test]
    fn parse_roundtrips_every_variant() {
        for spec in [
            "tiers:1",
            "tiers:4",
            "tiers:4:hysteresis:2",
            "tiers:8:hysteresis:1.25",
            "tiers:4:split:kmeans",
            "tiers:3:split:kmeans:hysteresis:2",
        ] {
            let p = TierPolicy::parse(spec).unwrap();
            assert_eq!(p.spec(), spec);
            assert_eq!(TierPolicy::parse(&p.spec()).unwrap(), p, "{spec}");
        }
        // the default hysteresis is canonicalized away
        assert_eq!(TierPolicy::parse("tiers:4:hysteresis:1.5").unwrap().spec(), "tiers:4");
    }

    #[test]
    fn parse_errors_name_the_full_spec() {
        for bad in [
            "tiers",                  // missing K
            "tiers:0",                // zero tiers
            "tiers:x",                // non-numeric K
            "tiers:4:hysteresis",     // missing H
            "tiers:4:hysteresis:0.5", // H < 1
            "tiers:4:hysteresis:y",   // non-numeric H
            "tiers:4:h:2",            // wrong keyword
            "tiers:4:split",          // missing split kind
            "tiers:4:split:dbscan",   // unknown split kind
            "layers:4",               // unknown spec
        ] {
            let e = TierPolicy::parse(bad).unwrap_err();
            assert!(e.contains(bad), "error '{e}' does not name '{bad}'");
        }
    }

    #[test]
    fn sketch_bounds_equal_materialized_band_maxima() {
        // an exact sketch reproduces the quantile split's frozen upper
        // bands: nearest-rank at k/K over n == rank (k*n).div_ceil(K)
        for (n, k_tiers) in [(8usize, 4usize), (10, 5), (7, 3), (12, 4)] {
            let mut rng = Rng::new(n as u64 + k_tiers as u64);
            let speeds = SpeedModel::paper_uniform().draw(&mut rng, n);
            let est = SpeedEstimator::new(&speeds, 0.25);
            let policy = TierPolicy::new(k_tiers);
            let sched = TierScheduler::new(policy.clone(), &est);
            let mut sk = crate::fed::sketch::QuantileSketch::new(256);
            for &s in &speeds {
                sk.push(s);
            }
            let bounds = policy.sketch_bounds(&sk);
            assert_eq!(bounds.len(), sched.num_tiers());
            for t in 0..sched.num_tiers() {
                let band_max = est
                    .estimate(*sched.tier_members(t).last().unwrap());
                assert_eq!(
                    bounds[t], band_max,
                    "tier {t} of {k_tiers} over {n} clients"
                );
            }
        }
    }

    #[test]
    fn quantile_split_covers_the_fleet_in_rank_order() {
        let est = SpeedEstimator::new(&[60.0, 10.0, 50.0, 20.0, 40.0, 30.0], 0.25);
        let s = TierScheduler::new(TierPolicy::new(3), &est);
        assert_eq!(s.num_tiers(), 3);
        assert_eq!(s.order(), &[1, 3, 5, 4, 2, 0]);
        assert_eq!(s.tier_members(0), &[1, 3]);
        assert_eq!(s.tier_members(1), &[5, 4]);
        assert_eq!(s.tier_members(2), &[2, 0]);
        assert_eq!(s.tier_of(1), 0);
        assert_eq!(s.tier_of(0), 2);
        // uneven split: every tier non-empty, sizes differ by at most one
        let s = TierScheduler::new(TierPolicy::new(4), &est);
        let sizes: Vec<usize> = (0..4).map(|t| s.tier_members(t).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 6);
        assert!(sizes.iter().all(|&z| z == 1 || z == 2), "{sizes:?}");
    }

    #[test]
    fn kmeans_split_settles_into_latency_gaps() {
        // clustered fleet, three latency modes: the quantile split cuts
        // the fast mode down the middle; k-means puts both boundaries in
        // the gaps between modes
        let est = SpeedEstimator::new(
            &[10.0, 11.0, 12.0, 100.0, 101.0, 1000.0],
            0.25,
        );
        let q = TierScheduler::new(TierPolicy::new(3), &est);
        assert_eq!(q.tier_members(0), &[0, 1], "quantile splits the mode");
        let mut policy = TierPolicy::new(3);
        policy.split = TierSplit::KMeans;
        let s = TierScheduler::new(policy, &est);
        assert_eq!(s.tier_members(0), &[0, 1, 2]);
        assert_eq!(s.tier_members(1), &[3, 4]);
        assert_eq!(s.tier_members(2), &[5]);
        assert_eq!(s.tier_of(5), 2);
    }

    #[test]
    fn kmeans_split_keeps_every_tier_nonempty() {
        // degenerate fleet: identical estimates collapse every midpoint;
        // boundary clamping must still leave one client per tier
        let est = SpeedEstimator::new(&[5.0; 6], 0.25);
        let mut policy = TierPolicy::new(3);
        policy.split = TierSplit::KMeans;
        let s = TierScheduler::new(policy, &est);
        let sizes: Vec<usize> =
            (0..3).map(|t| s.tier_members(t).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 6);
        assert!(sizes.iter().all(|&z| z >= 1), "{sizes:?}");
    }

    #[test]
    fn kmeans_matches_quantile_on_evenly_spread_estimates() {
        let est = SpeedEstimator::new(
            &[10.0, 20.0, 30.0, 40.0, 50.0, 60.0],
            0.25,
        );
        let q = TierScheduler::new(TierPolicy::new(3), &est);
        let mut policy = TierPolicy::new(3);
        policy.split = TierSplit::KMeans;
        let k = TierScheduler::new(policy, &est);
        for t in 0..3 {
            assert_eq!(q.tier_members(t), k.tier_members(t), "tier {t}");
        }
    }

    #[test]
    fn tier_count_clamps_to_fleet_size() {
        let est = SpeedEstimator::new(&[30.0, 10.0, 20.0], 0.25);
        let s = TierScheduler::new(TierPolicy::new(10), &est);
        assert_eq!(s.num_tiers(), 3);
        assert!((0..3).all(|t| s.tier_members(t).len() == 1));
    }

    #[test]
    fn snap_returns_whole_tier_prefixes() {
        let est = SpeedEstimator::new(&[10.0, 20.0, 30.0, 40.0, 50.0, 60.0], 0.25);
        let s = TierScheduler::new(TierPolicy::new(3), &est);
        assert_eq!(s.snap(1), 2);
        assert_eq!(s.snap(2), 2);
        assert_eq!(s.snap(3), 4);
        assert_eq!(s.snap(4), 4);
        assert_eq!(s.snap(5), 6);
        // n beyond the fleet clamps to the whole fleet
        assert_eq!(s.snap(100), 6);
        assert_eq!(s.prefix(3), vec![0, 1, 2, 3]);
    }

    #[test]
    fn static_estimates_never_retier() {
        let prior = vec![50.0, 275.3, 120.0, 499.9];
        let mut est = SpeedEstimator::new(&prior, 0.25);
        let mut s = TierScheduler::new(TierPolicy::new(2), &est);
        for _ in 0..100 {
            for (i, &t) in prior.iter().enumerate() {
                est.observe(i, t);
            }
            assert!(!s.refresh(&est), "static observations triggered a re-tier");
        }
        assert_eq!(s.retier_events(), 0);
    }

    #[test]
    fn markov_oscillation_inside_the_band_triggers_zero_retiers() {
        // hysteresis stability: a Markov-drift run whose slow factor F
        // stays within the band (F <= H) oscillates estimates inside
        // their tiers forever — the cache must never be invalidated
        let model = SystemModel::parse("markov:1.4:0.3:0.3:uniform:50:500").unwrap();
        let mut rng = Rng::new(9);
        let base = SpeedModel::paper_uniform().draw(&mut rng, 24);
        let mut state = SystemState::new(model, base, rng.fork(1));
        // profiling probe primes the estimator, exactly as ClientFleet does
        let probe = state.next_round();
        let mut est = SpeedEstimator::new(&probe.times, 0.25);
        // default policy: hysteresis 1.5, quantile split
        let mut s = TierScheduler::new(TierPolicy::new(4), &est);
        for _ in 0..300 {
            let cond = state.next_round();
            for (i, &t) in cond.times.iter().enumerate() {
                est.observe(i, t);
            }
            assert!(!s.refresh(&est), "within-band drift triggered a re-tier");
        }
        assert_eq!(s.retier_events(), 0);
    }

    #[test]
    fn sustained_slowdown_triggers_exactly_one_demotion() {
        // hysteresis stability, other direction: a fastest-tier client
        // slows for good, crosses its band once, is demoted into the
        // next tier — and the NEW band absorbs all further drift, so the
        // whole episode costs exactly one re-tier event
        let mut est =
            SpeedEstimator::new(&[10.0, 20.0, 30.0, 40.0, 50.0, 60.0], 0.5);
        // default policy: hysteresis 1.5, quantile split
        let mut s = TierScheduler::new(TierPolicy::new(3), &est);
        assert_eq!(s.tier_of(0), 0);
        let mut retiers = 0;
        for _ in 0..50 {
            est.observe(0, 35.0); // sustained slowdown toward 35
            retiers += s.refresh(&est) as usize;
        }
        assert_eq!(retiers, 1, "hysteresis must charge exactly one re-tier");
        assert_eq!(s.retier_events(), 1);
        assert_eq!(s.tier_of(0), 1, "slowed client was not demoted");
        // everyone else kept their tier
        assert_eq!(s.tier_of(1), 0);
        assert_eq!(s.tier_of(5), 2);
    }

    #[test]
    fn censored_observations_promote_through_the_same_path() {
        // deadline interop: a deadline-missing client only ever reports
        // censored lower bounds, which still climb the estimate past the
        // band and demote it out of its tier
        let mut est = SpeedEstimator::new(&[10.0, 20.0, 30.0, 40.0], 0.5);
        // default policy: hysteresis 1.5, quantile split
        let mut s = TierScheduler::new(TierPolicy::new(2), &est);
        assert_eq!(s.tier_of(0), 0);
        let mut retiers = 0;
        for _ in 0..20 {
            est.observe_censored(0, 35.0);
            retiers += s.refresh(&est) as usize;
        }
        assert_eq!(retiers, 1);
        assert_eq!(s.tier_of(0), 1, "censored drift did not demote the client");
    }

    #[test]
    fn credit_selection_is_fair_and_weighted() {
        let est = SpeedEstimator::new(&[10.0, 20.0, 30.0, 40.0], 0.25);
        let mut s = TierScheduler::new(TierPolicy::new(4), &est);
        // over one full credit cycle of K(K+1)/2 rounds, tier t is
        // selected exactly K - t times: fast tiers dominate, the slowest
        // tier still participates (no starvation)
        let mut counts = [0usize; 4];
        for _ in 0..10 {
            counts[s.select_tier()] += 1;
        }
        assert_eq!(counts, [4, 3, 2, 1]);
        // the schedule is periodic: a second cycle repeats the shares
        for _ in 0..10 {
            counts[s.select_tier()] += 1;
        }
        assert_eq!(counts, [8, 6, 4, 2]);
    }

    #[test]
    fn selection_is_deterministic() {
        let est = SpeedEstimator::new(&[10.0, 20.0, 30.0], 0.25);
        let mut a = TierScheduler::new(TierPolicy::new(3), &est);
        let mut b = TierScheduler::new(TierPolicy::new(3), &est);
        for _ in 0..30 {
            assert_eq!(a.select_tier(), b.select_tier());
        }
    }
}
