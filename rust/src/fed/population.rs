//! Lazily-realized population fleets (`fed::population`).
//!
//! The materialized [`ClientFleet`] touches all N clients every round:
//! the base draw, the per-round realization, the estimate ranking and
//! the trace rows are each O(N). Hard et al. (*Learning from straggler
//! clients in federated learning*, PAPERS.md) run against fleets of
//! ~10^6 phones — a regime where a round must cost O(cohort), not
//! O(population). This module is that regime:
//!
//! * [`PopulationSpec`] — a population described by a distribution, not
//!   a roster: `pop:N:SCENARIO`, where `SCENARIO` is the full system
//!   grammar of [`crate::fed::SystemModel`].
//! * [`LazyFleet`] — clients realized on demand from **per-client
//!   seeded streams**: client `i`'s base speed, dynamics lane, data
//!   rows and per-round draws each come from their own
//!   deterministically-derived PCG stream, so any client id can be
//!   realized at any time and re-realized bit-identically — the
//!   property record→replay parity rests on. Rounds realize only the
//!   cohort; the global structures live in sketch form (a
//!   [`crate::fed::TopK`] prefix frontier, a
//!   [`crate::fed::QuantileSketch`] of the speed distribution).
//! * [`LazyShards`] — lazily synthesized linear-regression shards:
//!   row `j` of client `i` is re-derived from its own stream on every
//!   touch, so a million clients' data occupies zero bytes until (and
//!   after) a cohort trains on it.
//! * [`PopulationFleet`] — the two-regime switch: at small N
//!   (≤ [`DEFAULT_EXACT_THRESHOLD`]) populations materialize into a
//!   plain [`ClientFleet`] via `setup::build_population_fleet`, keeping
//!   every existing prefix/loss/wall-clock/trace pin **bit-identical**;
//!   past the threshold the lazy fleet takes over with the same spec.
//!
//! The two regimes draw from differently-shaped RNG streams (one
//! sequential stream vs per-client streams), so their concrete samples
//! differ; what is preserved across the switch is the distribution, the
//! determinism, and every structural contract (estimate ranking
//! semantics, deadline arithmetic, availability observability). See
//! `docs/scale.md` for the full scaling model and its guarantees.
//!
//! ```
//! use flanp::fed::{LazyFleet, PopulationSpec};
//!
//! let spec = PopulationSpec::parse(
//!     "pop:10000:avail:diurnal:1000:0.5:1:uniform:50:500",
//! )
//! .unwrap();
//! assert_eq!(spec.n, 10_000);
//! assert_eq!(PopulationSpec::parse(&spec.spec()).unwrap(), spec);
//!
//! let mut fleet = LazyFleet::new(spec, 7);
//! // the frontier hands out the estimated-fastest cohort in O(frontier)
//! let cohort = fleet.cohort(8);
//! assert_eq!(cohort.len(), 8);
//! // one round realizes conditions for the cohort only — O(cohort)
//! let cond = fleet.realize_cohort(&cohort, 0.0);
//! assert_eq!(cond.times.len(), 8);
//! // any client id is realizable on demand, bit-identically every time
//! assert_eq!(fleet.base_speed(9_123), fleet.base_speed(9_123));
//! ```

use crate::data::{synth, DataSpec};
use crate::fed::client::ClientFleet;
use crate::fed::selection::{AvailabilityForecaster, ForecastPolicy};
use crate::fed::sketch::{QuantileSketch, TopK};
use crate::fed::speed::SpeedModel;
use crate::fed::system::{Dynamics, SystemModel};
use crate::fed::traces::AvailabilityModel;
use crate::util::Rng;
use std::collections::HashMap;

/// Populations at or below this size materialize into a plain
/// [`ClientFleet`] (`setup::build_population_fleet`): full
/// materialization is affordable, and delegation keeps every existing
/// small-N regression pin bit-identical.
pub const DEFAULT_EXACT_THRESHOLD: usize = 4096;

/// Default prefix-frontier size: how many base-fastest candidates the
/// lazy fleet keeps live estimates for (cohorts are selected within the
/// frontier, TiFL-cache style).
pub const DEFAULT_FRONTIER: usize = 1024;

/// Event-stream sampling stride for population-scale loops: a lazy run
/// charges thousands of O(cohort) rounds, so `flanp-bench scale` emits a
/// [`crate::fed::EventKind::LazyRound`] event for every
/// `LAZY_EVENT_SAMPLE`-th round rather than all of them — the event log
/// stays O(rounds / stride) while still pinning the realized
/// online/available mix across the run.
pub const LAZY_EVENT_SAMPLE: usize = 16;

/// Per-client stream components. Client `i` owns streams
/// `i * STREAM_COMPONENTS + comp`; reserved global streams sit at the
/// top of the id space, unreachable for any realizable population.
/// Components 5 (Dirichlet skew) and 6 (covariate shift) are claimed by
/// `data/synth.rs` (`DATA_SKEW_COMPONENT` / `DATA_SHIFT_COMPONENT`), so
/// the lazy non-IID state is derived from the very same streams the
/// eager `data:` path uses; component 7 is free.
const STREAM_COMPONENTS: u64 = 8;
const COMP_SPEED: u64 = 0;
const COMP_MARKOV: u64 = 1;
const COMP_DATA: u64 = 2;
const COMP_ROUND: u64 = 3;
const COMP_ROW: u64 = 4;
/// Global streams (never collide with `sid`: populations are far below
/// `2^61` clients).
const TEACHER_STREAM: u64 = u64::MAX - 1;
const CLUSTER_STREAM: u64 = u64::MAX - 3;
/// Cluster-teacher streams for the lazy `data:dirichlet` regime:
/// teacher `k` of [`LAZY_CLUSTERS`] lives at `u64::MAX - 16 - k`.
const CLUSTER_TEACHER_BASE: u64 = u64::MAX - 16;
/// Teacher clusters the lazy Dirichlet skew mixes over (the regression
/// analogue of label classes: each client's effective teacher is its
/// Dirichlet-weighted mixture of these).
pub const LAZY_CLUSTERS: usize = 4;

fn sid(i: usize, comp: u64) -> u64 {
    (i as u64) * STREAM_COMPONENTS + comp
}

/// Weyl-sequence salt decorrelating per-round stateless streams
/// (golden-ratio increment; the `+1` keeps round 0 off the raw seed).
fn round_salt(r: usize) -> u64 {
    (r as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

fn row_salt(j: usize) -> u64 {
    (j as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03)
}

fn base_speed_of(seed: u64, base: &SpeedModel, i: usize) -> f64 {
    base.draw_one(&mut Rng::with_stream(seed, sid(i, COMP_SPEED)))
}

/// A population described by its size and scenario distribution —
/// the `pop:N:SCENARIO` grammar.
#[derive(Clone, Debug, PartialEq)]
pub struct PopulationSpec {
    /// population size N
    pub n: usize,
    /// the scenario every client's parameters are drawn from (the full
    /// grammar of [`SystemModel::parse`], minus `trace:` — a trace
    /// carries per-client rows, the opposite of a population
    /// distribution)
    pub system: SystemModel,
}

impl PopulationSpec {
    /// Parse a population spec. Grammar:
    ///
    /// ```text
    ///   pop:N:SCENARIO
    /// ```
    ///
    /// `N` is a positive population size and `SCENARIO` any
    /// non-`trace:` system scenario ([`SystemModel::parse`]).
    ///
    /// ```
    /// use flanp::fed::PopulationSpec;
    ///
    /// let p = PopulationSpec::parse("pop:1000000:jitter:0.3:uniform:50:500")
    ///     .unwrap();
    /// assert_eq!(p.n, 1_000_000);
    /// assert_eq!(p.spec(), "pop:1000000:jitter:0.3:uniform:50:500");
    /// assert_eq!(PopulationSpec::parse(&p.spec()).unwrap(), p);
    /// assert!(PopulationSpec::parse("pop:0:homog:10").is_err());
    /// assert!(PopulationSpec::parse("uniform:50:500").is_err());
    /// ```
    pub fn parse(spec: &str) -> Result<Self, String> {
        let rest = spec.strip_prefix("pop:").ok_or_else(|| {
            format!(
                "population spec '{spec}' must start with 'pop:N:' \
                 (expected pop:N:SCENARIO)"
            )
        })?;
        let (n_tok, sys_spec) = rest.split_once(':').ok_or_else(|| {
            format!("missing scenario in population spec '{spec}'")
        })?;
        let n: usize = n_tok.parse().map_err(|_| {
            format!(
                "bad population size '{n_tok}' in population spec '{spec}'"
            )
        })?;
        let system = SystemModel::parse(sys_spec)?;
        let pop = PopulationSpec { n, system };
        pop.validate()
            .map_err(|e| format!("{e} in population spec '{spec}'"))?;
        Ok(pop)
    }

    /// Canonical spec string; `parse(spec()) == self`.
    pub fn spec(&self) -> String {
        format!("pop:{}:{}", self.n, self.system.spec())
    }

    /// Structural sanity check (configs can be built without `parse`).
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("population size must be positive".into());
        }
        if self.system.trace.is_some() {
            return Err(
                "trace replay carries per-client rows and cannot describe \
                 a population distribution"
                    .into(),
            );
        }
        self.system.validate()
    }
}

/// One round's realized conditions for a COHORT (indexed by cohort
/// position, not client id — O(cohort) memory, the population-scale
/// twin of [`crate::fed::RoundConditions`]).
#[derive(Clone, Debug)]
pub struct CohortConditions {
    /// the cohort's client ids, in selection order
    pub ids: Vec<usize>,
    /// realized per-update compute time of each cohort member
    pub times: Vec<f64>,
    /// false when the member silently drops out (`drop:`, unobservable)
    pub available: Vec<bool>,
    /// false when the member is offline (`avail:`, observable at
    /// selection time — skipped, never charged, never estimated)
    pub online: Vec<bool>,
}

impl CohortConditions {
    /// Cohort positions (not client ids) that are observably online.
    pub fn online_positions(&self) -> Vec<usize> {
        (0..self.ids.len()).filter(|&k| self.online[k]).collect()
    }

    /// Number of observably-online cohort members.
    pub fn online_count(&self) -> usize {
        self.online.iter().filter(|&&o| o).count()
    }

    /// The round's realized mix as a [`crate::fed::EventKind::LazyRound`]
    /// event detail: cohort size, observably-online count and silent
    /// availability count (O(cohort) to compute, O(1) to store — ids are
    /// deliberately omitted at population scale).
    pub fn event_detail(&self) -> crate::util::json::Json {
        crate::util::json::obj(vec![
            ("cohort", self.ids.len().into()),
            ("online", self.online_count().into()),
            (
                "available",
                self.available.iter().filter(|&&a| a).count().into(),
            ),
        ])
    }
}

#[derive(Clone, Debug)]
struct MarkovLane {
    rng: Rng,
    slow: bool,
    rounds_done: usize,
}

/// A lazily-realized population: any client id can be realized on
/// demand from its own seeded streams, rounds charge O(cohort) work,
/// and the only O(N) cost is one streaming construction pass that seeds
/// the prefix frontier and the population speed sketch.
///
/// Memory after construction is O(frontier + touched clients +
/// sketch): the estimate table, the Markov lanes and the data lanes
/// hold entries only for clients some cohort actually touched.
///
/// Dynamics semantics mirror [`crate::fed::SystemState`] per charged
/// round: jitter and dropout are i.i.d. per (round, client) and come
/// from stateless per-round streams; Markov fast/slow chains advance
/// one transition per charged round on a sequential per-client lane
/// (lazily caught up on first touch, so an untouched client's chain
/// state is independent of when it is first realized); cluster outage
/// chains advance once per charged round globally — a charged waiting
/// round steps them, consistent with the charged-wait fix in
/// `coordinator::solvers::deadline_round` (see `docs/scale.md`).
#[derive(Clone, Debug)]
pub struct LazyFleet {
    spec: PopulationSpec,
    seed: u64,
    alpha: f64,
    /// ids of the frontier-capacity base-fastest clients, fastest-first
    /// by base speed (the cached candidate set cohorts re-rank within)
    frontier: Vec<usize>,
    /// population base-speed quantile sketch (deadlines, tier bounds)
    speed_sketch: QuantileSketch,
    /// EWMA estimates for touched clients (prior = base speed)
    estimates: HashMap<usize, f64>,
    /// optional availability forecaster — sparse like `estimates`, fed
    /// the realized online bit of every cohort member, so its state is
    /// O(touched clients) and any per-client prediction is
    /// stateless-reconstructible from (policy, that client's
    /// observations)
    forecast: Option<AvailabilityForecaster>,
    markov: HashMap<usize, MarkovLane>,
    cluster_down: Vec<bool>,
    cluster_rng: Rng,
    rounds: usize,
}

impl LazyFleet {
    /// Build with the default frontier and sketch capacities. One O(N)
    /// streaming pass (no per-client state is retained).
    pub fn new(spec: PopulationSpec, seed: u64) -> Self {
        Self::with_capacity(
            spec,
            seed,
            DEFAULT_FRONTIER,
            QuantileSketch::DEFAULT_CAPACITY,
        )
    }

    /// Build with explicit frontier / sketch capacities. Panics on an
    /// invalid spec (mirrors [`ClientFleet`]'s constructor contract).
    pub fn with_capacity(
        spec: PopulationSpec,
        seed: u64,
        frontier_capacity: usize,
        sketch_capacity: usize,
    ) -> Self {
        spec.validate()
            .unwrap_or_else(|e| panic!("invalid population spec: {e}"));
        assert!(frontier_capacity > 0, "empty prefix frontier");
        let n = spec.n;
        let mut topk = TopK::new(frontier_capacity.min(n));
        let mut sketch = QuantileSketch::new(sketch_capacity);
        for i in 0..n {
            let t = base_speed_of(seed, &spec.system.base, i);
            topk.push(t, i);
            sketch.push(t);
        }
        let clusters =
            spec.system.avail.as_ref().map_or(0, |a| a.num_clusters());
        LazyFleet {
            frontier: topk.ids(),
            speed_sketch: sketch,
            spec,
            seed,
            alpha: crate::fed::client::DEFAULT_EWMA_ALPHA,
            estimates: HashMap::new(),
            forecast: None,
            markov: HashMap::new(),
            cluster_down: vec![false; clusters],
            cluster_rng: Rng::with_stream(seed, CLUSTER_STREAM),
            rounds: 0,
        }
    }

    pub fn spec(&self) -> &PopulationSpec {
        &self.spec
    }

    /// Enable availability forecasting
    /// ([`crate::fed::AvailabilityForecaster`]): every subsequent
    /// [`Self::realize_cohort`] feeds the forecaster the cohort's
    /// realized online bits and [`Self::cohort`] prefers frontier
    /// members predicted online. RNG-free, so enabling it never
    /// perturbs any realization stream.
    pub fn set_forecast(&mut self, policy: ForecastPolicy) {
        self.forecast = Some(AvailabilityForecaster::new(policy));
    }

    pub fn num_clients(&self) -> usize {
        self.spec.n
    }

    /// Charged rounds realized so far.
    pub fn rounds_realized(&self) -> usize {
        self.rounds
    }

    /// The frontier's client ids, fastest-first by base speed.
    pub fn frontier(&self) -> &[usize] {
        &self.frontier
    }

    /// The population base-speed quantile sketch (feed it to
    /// [`crate::fed::DeadlineController::round_deadline_sketch`] or
    /// [`crate::fed::TierPolicy::sketch_bounds`]).
    pub fn speed_sketch(&self) -> &QuantileSketch {
        &self.speed_sketch
    }

    /// Client `i`'s base per-update time, re-derived from its own
    /// stream — bit-identical on every call, no state consulted.
    pub fn base_speed(&self, i: usize) -> f64 {
        assert!(i < self.spec.n, "client {i} outside population {}", self.spec.n);
        base_speed_of(self.seed, &self.spec.system.base, i)
    }

    /// Current speed estimate for client `i` (the base speed until an
    /// observation arrives — the lazy analogue of the probe prior).
    pub fn estimate(&self, i: usize) -> f64 {
        self.estimates.get(&i).copied().unwrap_or_else(|| self.base_speed(i))
    }

    /// The `k` estimated-fastest frontier members — O(frontier · log k),
    /// independent of N. Like tier membership in
    /// [`crate::fed::TierScheduler`], the frontier is a cached candidate
    /// set: estimates re-rank within it every call, but a client outside
    /// it (never among the base-fastest) is not reconsidered.
    ///
    /// With a forecaster enabled ([`Self::set_forecast`]) the whole
    /// frontier is ranked (O(frontier · log frontier)) and members
    /// predicted offline yield their slot to the next-fastest predicted
    /// online; the cohort never shrinks — an all-offline forecast
    /// degrades to the plain estimate prefix.
    pub fn cohort(&self, k: usize) -> Vec<usize> {
        match &self.forecast {
            None => {
                let mut t = TopK::new(k.min(self.frontier.len()));
                for &i in &self.frontier {
                    t.push(self.estimate(i), i);
                }
                t.ids()
            }
            Some(f) => {
                let mut t = TopK::new(self.frontier.len());
                for &i in &self.frontier {
                    t.push(self.estimate(i), i);
                }
                f.filter_prefix(&t.ids(), k.min(self.frontier.len()))
            }
        }
    }

    /// Realize one charged round's conditions for `ids` only at virtual
    /// time `now` — O(cohort + clusters) work, nothing else realized.
    /// Global chain state (cluster outages) advances exactly once per
    /// call, so every charged round — including waiting rounds — steps
    /// the outage process.
    pub fn realize_cohort(
        &mut self,
        ids: &[usize],
        now: f64,
    ) -> CohortConditions {
        let r = self.rounds;
        self.rounds += 1;
        let seed = self.seed;
        let n = self.spec.n;
        if let Some(a) = &self.spec.system.avail {
            a.step_clusters(&mut self.cluster_down, &mut self.cluster_rng);
        }
        let mut times = Vec::with_capacity(ids.len());
        let mut available = Vec::with_capacity(ids.len());
        let mut online = Vec::with_capacity(ids.len());
        for &i in ids {
            assert!(i < n, "client {i} outside population {n}");
            let base = base_speed_of(seed, &self.spec.system.base, i);
            // stateless per-(round, client) stream: jitter, dropout and
            // iid availability are independent across rounds, so a
            // fresh salted stream realizes them without per-client
            // round state
            let mut rs =
                Rng::with_stream(seed ^ round_salt(r), sid(i, COMP_ROUND));
            let t = match self.spec.system.dynamics {
                Dynamics::Static => base,
                Dynamics::Jitter { sigma } => {
                    base * (sigma * rs.normal()).exp()
                }
                Dynamics::Markov { slow_factor, p_slow, p_recover } => {
                    // sequential per-client lane, caught up one
                    // transition per charged round on first touch
                    let lane =
                        self.markov.entry(i).or_insert_with(|| MarkovLane {
                            rng: Rng::with_stream(seed, sid(i, COMP_MARKOV)),
                            slow: false,
                            rounds_done: 0,
                        });
                    while lane.rounds_done <= r {
                        let u = lane.rng.next_f64();
                        lane.slow = if lane.slow {
                            u >= p_recover
                        } else {
                            u < p_slow
                        };
                        lane.rounds_done += 1;
                    }
                    if lane.slow {
                        base * slow_factor
                    } else {
                        base
                    }
                }
            };
            times.push(t);
            available.push(if self.spec.system.p_drop > 0.0 {
                rs.next_f64() >= self.spec.system.p_drop
            } else {
                true
            });
            let on = match &self.spec.system.avail {
                None => true,
                Some(a) => match a.online_at(now, i, n) {
                    Some(flag) => flag,
                    None => match a {
                        AvailabilityModel::Iid { p } => rs.next_f64() < *p,
                        AvailabilityModel::Cluster { clusters, .. } => {
                            !self.cluster_down
                                [AvailabilityModel::cluster_of(i, n, *clusters)]
                        }
                        AvailabilityModel::Diurnal { .. } => unreachable!(),
                    },
                },
            };
            online.push(on);
        }
        if let Some(f) = &mut self.forecast {
            for (k, &i) in ids.iter().enumerate() {
                f.observe(i, online[k]);
            }
        }
        CohortConditions { ids: ids.to_vec(), times, available, online }
    }

    /// Fold one observed per-update time into client `i`'s estimate —
    /// the same exact-fixed-point EWMA as
    /// [`crate::fed::SpeedEstimator::observe`].
    pub fn observe(&mut self, i: usize, per_update_time: f64) {
        let base = self.base_speed(i);
        let e = self.estimates.entry(i).or_insert(base);
        *e += self.alpha * (per_update_time - *e);
    }

    /// Censored feedback (deadline miss): pull the estimate up toward
    /// the bound, never down
    /// ([`crate::fed::SpeedEstimator::observe_censored`]).
    pub fn observe_censored(&mut self, i: usize, lower_bound: f64) {
        if lower_bound > self.estimate(i) {
            self.observe(i, lower_bound);
        }
    }

    /// Clients with retained per-client state (estimates, dynamics,
    /// forecast windows or data lanes) — the memory footprint check:
    /// everything else about the population occupies no per-client
    /// storage.
    pub fn touched_clients(&self) -> usize {
        let mut ids: Vec<usize> = self
            .estimates
            .keys()
            .chain(self.markov.keys())
            .copied()
            .chain(self.forecast.iter().flat_map(|f| f.tracked_ids()))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }
}

/// Lazily synthesized linear-regression shards over a population: a
/// hidden teacher `w*` plus per-row streams, so row `j` of client `i`
/// is re-derived bit-identically on every touch and the dataset as a
/// whole is never stored. The population twin of
/// `data::synth` + [`ClientFleet::fill_minibatch`], sized for the
/// `flanp-bench scale` training loop.
///
/// ```
/// use flanp::fed::LazyShards;
///
/// let mut shards = LazyShards::new(7, 100, 4, 0.1);
/// assert_eq!(shards.teacher().len(), 4);
/// let (mut x, mut y) = (vec![0.0f32; 8 * 4], vec![0.0f32; 8]);
/// shards.fill_minibatch(42, 8, &mut x, &mut y);
/// // rows are re-realizable: the same (client, row) always yields the
/// // same sample
/// let mut x2 = vec![0.0f32; 4];
/// let y2 = shards.realize_row(42, 3, &mut x2);
/// assert_eq!(y2, shards.realize_row(42, 3, &mut x2));
/// ```
#[derive(Clone, Debug)]
pub struct LazyShards {
    seed: u64,
    /// rows per client shard
    s: usize,
    /// feature dimension
    d: usize,
    /// label noise scale
    noise: f64,
    teacher: Vec<f32>,
    /// the `data:` grammar applied lazily (IID by default). Dirichlet
    /// skew is the regression analogue of label skew: each client's
    /// effective teacher is its Dirichlet mixture over
    /// [`LAZY_CLUSTERS`] cluster teachers. Shift adds the client's
    /// seeded shift vector ([`synth::shift_vector`]) to every feature
    /// row AFTER the label is computed, matching the eager path where
    /// labels are synthesized before the shift mutates the features.
    data: DataSpec,
    /// base speed model for the `corr:speed` strength grading
    /// ([`SpeedModel::cdf`] of the client's own base draw); required
    /// when `data` says `corr:speed`
    base: Option<SpeedModel>,
    /// cluster teachers (empty unless `data.dirichlet` is on)
    cluster_teachers: Vec<Vec<f32>>,
    /// per-client minibatch sampling lanes (created on first touch)
    lanes: HashMap<usize, Rng>,
}

impl LazyShards {
    pub fn new(seed: u64, s: usize, d: usize, noise: f64) -> Self {
        Self::with_data(seed, s, d, noise, DataSpec::iid(), None)
    }

    /// Build with a `data:` spec. Skew state is derived per touch from
    /// the same pure per-client streams the eager path uses
    /// (`synth::dirichlet_proportions` / `synth::shift_vector`), so a
    /// million-client non-IID population still occupies zero bytes of
    /// data. `base` must be the population's base speed model when the
    /// spec says `corr:speed` (strength = the client's base-speed
    /// percentile, [`SpeedModel::cdf`] — the O(1) population analogue
    /// of the eager path's speed rank).
    pub fn with_data(
        seed: u64,
        s: usize,
        d: usize,
        noise: f64,
        data: DataSpec,
        base: Option<SpeedModel>,
    ) -> Self {
        assert!(s > 0 && d > 0, "degenerate shard shape {s}x{d}");
        assert!(
            !data.corr_speed || base.is_some(),
            "data spec '{}' says corr:speed but no base speed model given",
            data.spec()
        );
        let mut teacher = vec![0.0f32; d];
        Rng::with_stream(seed, TEACHER_STREAM).fill_normal(&mut teacher, 1.0);
        let cluster_teachers = if data.dirichlet.is_some() {
            (0..LAZY_CLUSTERS)
                .map(|k| {
                    let mut t = vec![0.0f32; d];
                    Rng::with_stream(seed, CLUSTER_TEACHER_BASE - k as u64)
                        .fill_normal(&mut t, 1.0);
                    t
                })
                .collect()
        } else {
            Vec::new()
        };
        LazyShards {
            seed,
            s,
            d,
            noise,
            teacher,
            data,
            base,
            cluster_teachers,
            lanes: HashMap::new(),
        }
    }

    /// Skew strength in [0, 1] for client `i` (1 unless `corr:speed`).
    pub fn strength(&self, i: usize) -> f64 {
        match (&self.base, self.data.corr_speed) {
            (Some(b), true) => {
                let t = base_speed_of(self.seed, b, i);
                b.cdf(t)
            }
            _ => 1.0,
        }
    }

    /// Client `i`'s effective teacher under the lazy Dirichlet skew:
    /// the Dirichlet-weighted mixture of the cluster teachers, blended
    /// toward uniform by the client's strength. Bit-reuses the eager
    /// path's proportions ([`synth::dirichlet_proportions`]), which is
    /// what the cross-path property test pins.
    pub fn client_teacher(&self, i: usize) -> Vec<f32> {
        let alpha = match self.data.dirichlet {
            Some(a) => a,
            None => return self.teacher.clone(),
        };
        let mut p =
            synth::dirichlet_proportions(self.seed, i, alpha, LAZY_CLUSTERS);
        synth::blend_to_uniform(&mut p, self.strength(i));
        let mut t = vec![0.0f32; self.d];
        for (k, ct) in self.cluster_teachers.iter().enumerate() {
            for (tj, cj) in t.iter_mut().zip(ct) {
                *tj += p[k] as f32 * cj;
            }
        }
        t
    }

    /// The hidden regression target `w*` (drawn once from its own
    /// global stream).
    pub fn teacher(&self) -> &[f32] {
        &self.teacher
    }

    /// Rows per client shard.
    pub fn s(&self) -> usize {
        self.s
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Realize row `j` of client `i` into `x` (length `d`), returning
    /// the label `y = x·w_i* + noise·z` (the client's effective teacher
    /// under `data:dirichlet`, the global teacher otherwise). The
    /// covariate shift is added to `x` AFTER the label, so a shifted
    /// client's conditional y|x moves — the distribution shift a global
    /// model cannot fit. Stateless: bit-identical on every call, and
    /// byte-identical to the pre-`data:` behavior when the spec is IID.
    pub fn realize_row(&self, client: usize, row: usize, x: &mut [f32]) -> f32 {
        assert!(row < self.s, "row {row} outside shard of {}", self.s);
        assert_eq!(x.len(), self.d);
        let mut rng =
            Rng::with_stream(self.seed ^ row_salt(row), sid(client, COMP_ROW));
        rng.fill_normal(x, 1.0);
        let teacher_buf;
        let teacher: &[f32] = if self.data.dirichlet.is_some() {
            teacher_buf = self.client_teacher(client);
            &teacher_buf
        } else {
            &self.teacher
        };
        let dot: f32 = x.iter().zip(teacher).map(|(a, b)| a * b).sum();
        let y = dot + self.noise as f32 * rng.normal() as f32;
        if let Some(mag) = self.data.shift {
            let g = self.strength(client) as f32;
            if g > 0.0 {
                let v = synth::shift_vector(self.seed, client, self.d, mag);
                for (xj, vj) in x.iter_mut().zip(&v) {
                    *xj += g * vj;
                }
            }
        }
        y
    }

    /// Fill one stochastic minibatch (size `b`, sampled without
    /// replacement from client `i`'s shard) into `x_buf` (`b*d`) /
    /// `y_buf` (`b`). Sampling advances the client's own lane, exactly
    /// like the materialized fleet's per-client minibatch streams.
    pub fn fill_minibatch(
        &mut self,
        client: usize,
        b: usize,
        x_buf: &mut [f32],
        y_buf: &mut [f32],
    ) {
        assert!(b <= self.s, "batch {b} > shard {}", self.s);
        assert_eq!(x_buf.len(), b * self.d);
        assert_eq!(y_buf.len(), b);
        let picks = {
            let seed = self.seed;
            let lane = self.lanes.entry(client).or_insert_with(|| {
                Rng::with_stream(seed, sid(client, COMP_DATA))
            });
            lane.sample_indices(self.s, b)
        };
        for (k, &row) in picks.iter().enumerate() {
            let x = &mut x_buf[k * self.d..(k + 1) * self.d];
            y_buf[k] = self.realize_row(client, row, x);
        }
    }
}

/// The two-regime population switch (see the module docs): exact
/// materialization at small N for the bit-identity pin, lazy
/// sketch-backed realization at scale. Built by
/// `setup::build_population_fleet`.
pub enum PopulationFleet {
    /// N ≤ threshold: a fully materialized [`ClientFleet`], built
    /// through the identical code path as a non-population run —
    /// prefixes, losses, wall-clock and trace CSVs are byte-identical.
    Exact(Box<ClientFleet>),
    /// N > threshold: the lazy fleet.
    Lazy(Box<LazyFleet>),
}

impl PopulationFleet {
    pub fn num_clients(&self) -> usize {
        match self {
            PopulationFleet::Exact(f) => f.num_clients(),
            PopulationFleet::Lazy(f) => f.num_clients(),
        }
    }

    pub fn is_exact(&self) -> bool {
        matches!(self, PopulationFleet::Exact(_))
    }

    /// The materialized fleet (None in the lazy regime).
    pub fn exact_mut(&mut self) -> Option<&mut ClientFleet> {
        match self {
            PopulationFleet::Exact(f) => Some(f),
            PopulationFleet::Lazy(_) => None,
        }
    }

    /// The lazy fleet (None in the exact regime).
    pub fn lazy_mut(&mut self) -> Option<&mut LazyFleet> {
        match self {
            PopulationFleet::Exact(_) => None,
            PopulationFleet::Lazy(f) => Some(f),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::speed::sort_fastest_first;

    fn spec(s: &str) -> PopulationSpec {
        PopulationSpec::parse(s).unwrap()
    }

    #[test]
    fn parse_roundtrips_and_rejects() {
        for s in [
            "pop:100:uniform:50:500",
            "pop:1000000:avail:diurnal:40000:0.25:1:uniform:50:500",
            "pop:64:drop:0.05:markov:4:0.1:0.5:exp:0.01",
        ] {
            let p = spec(s);
            assert_eq!(p.spec(), s);
            assert_eq!(PopulationSpec::parse(&p.spec()).unwrap(), p);
        }
        for bad in [
            "pop:0:homog:10",        // empty population
            "pop:x:homog:10",        // non-numeric N
            "pop:10",                // missing scenario
            "pop:10:warp:9",         // bad scenario
            "uniform:50:500",        // missing pop: prefix
        ] {
            let e = PopulationSpec::parse(bad).unwrap_err();
            assert!(
                e.contains(bad) || e.contains("speed"),
                "error '{e}' for '{bad}'"
            );
        }
    }

    #[test]
    fn base_speeds_are_rerealized_bit_identically() {
        let f = LazyFleet::new(spec("pop:500:uniform:50:500"), 11);
        for i in [0usize, 7, 123, 499] {
            let a = f.base_speed(i);
            assert_eq!(a, f.base_speed(i));
            assert!((50.0..500.0).contains(&a));
        }
        // independent instances agree: realization is pure in (seed, id)
        let g = LazyFleet::new(spec("pop:500:uniform:50:500"), 11);
        assert_eq!(f.base_speed(250), g.base_speed(250));
        // a different seed realizes a different population
        let h = LazyFleet::new(spec("pop:500:uniform:50:500"), 12);
        assert_ne!(f.base_speed(250), h.base_speed(250));
    }

    #[test]
    fn frontier_is_the_exact_base_speed_prefix() {
        // at small N the frontier must equal a full materialized sort
        let n = 300;
        let f = LazyFleet::with_capacity(
            spec("pop:300:uniform:50:500"),
            3,
            16,
            QuantileSketch::DEFAULT_CAPACITY,
        );
        let speeds: Vec<f64> = (0..n).map(|i| f.base_speed(i)).collect();
        let want: Vec<usize> =
            sort_fastest_first(&speeds).into_iter().take(16).collect();
        assert_eq!(f.frontier(), &want[..]);
        // and the default cohort is the frontier prefix (no drift yet)
        assert_eq!(f.cohort(4), want[..4].to_vec());
    }

    #[test]
    fn speed_sketch_is_exact_at_small_n() {
        let f = LazyFleet::new(spec("pop:100:uniform:50:500"), 5);
        let speeds: Vec<f64> =
            (0..100).map(|i| f.base_speed(i)).collect();
        assert!(f.speed_sketch().is_exact());
        for q in [0.1, 0.5, 0.9, 1.0] {
            assert_eq!(
                f.speed_sketch().query(q),
                crate::fed::aggregation::quantile(&speeds, q)
            );
        }
    }

    #[test]
    fn cohort_reranks_under_drifted_estimates() {
        let mut f = LazyFleet::new(spec("pop:200:uniform:50:500"), 7);
        let fastest = f.cohort(1)[0];
        // the base-fastest client slows 100x for many observed rounds
        for _ in 0..50 {
            f.observe(fastest, f.base_speed(fastest) * 100.0);
        }
        let c = f.cohort(8);
        assert!(
            !c.contains(&fastest),
            "cohort {c:?} still contains slowed client {fastest}"
        );
        // censored feedback only ever pulls estimates up
        let other = c[0];
        let before = f.estimate(other);
        f.observe_censored(other, before * 0.5);
        assert_eq!(f.estimate(other), before);
        f.observe_censored(other, before * 4.0);
        assert!(f.estimate(other) > before);
    }

    #[test]
    fn realization_is_deterministic_and_order_independent() {
        let mk = || {
            LazyFleet::new(
                spec("pop:100:drop:0.1:jitter:0.3:uniform:50:500"),
                13,
            )
        };
        let (mut a, mut b) = (mk(), mk());
        // same rounds, different cohort shapes per instance would break
        // determinism if realization were stateful beyond the round
        // counter — identical cohorts must match exactly
        for r in 0..10 {
            let ids: Vec<usize> = (0..10).map(|k| (k * 7 + r) % 100).collect();
            let ca = a.realize_cohort(&ids, 0.0);
            let cb = b.realize_cohort(&ids, 0.0);
            assert_eq!(ca.times, cb.times);
            assert_eq!(ca.available, cb.available);
            assert_eq!(ca.online, cb.online);
        }
        // jitter re-draws per round: same cohort, different rounds
        let ids = vec![1, 2, 3];
        let c1 = a.realize_cohort(&ids, 0.0);
        let c2 = a.realize_cohort(&ids, 0.0);
        assert_ne!(c1.times, c2.times);
    }

    #[test]
    fn markov_lanes_catch_up_independently_of_touch_order() {
        let s = "pop:50:markov:4:0.3:0.3:homog:100";
        // fleet A touches client 5 every round; fleet B only at the end
        let (mut a, mut b) = (LazyFleet::new(spec(s), 3), LazyFleet::new(spec(s), 3));
        let mut last_a = Vec::new();
        for _ in 0..12 {
            last_a = a.realize_cohort(&[5], 0.0).times.clone();
            b.realize_cohort(&[], 0.0);
        }
        let last_b = b.realize_cohort(&[5], 0.0).times;
        // B's 13th round pairs with A realizing one more
        let last_a13 = a.realize_cohort(&[5], 0.0).times;
        assert_eq!(last_a13, last_b, "lane catch-up diverged (prev {last_a:?})");
        // two-level times only
        assert!(last_b[0] == 100.0 || last_b[0] == 400.0);
    }

    #[test]
    fn diurnal_flags_match_the_availability_model() {
        let mut f = LazyFleet::new(
            spec("pop:4:avail:diurnal:100:0.5:1:homog:10"),
            5,
        );
        let all: Vec<usize> = (0..4).collect();
        let c = f.realize_cohort(&all, 0.0);
        assert_eq!(c.online, vec![true, true, false, false]);
        assert_eq!(c.online_count(), 2);
        assert_eq!(c.online_positions(), vec![0, 1]);
        let c = f.realize_cohort(&all, 50.0);
        assert_eq!(c.online, vec![false, false, true, true]);
        // diurnal realization consumes no randomness: dropout-free
        assert!(c.available.iter().all(|&x| x));
    }

    #[test]
    fn cluster_chains_advance_per_charged_round_globally() {
        // p_fail = 1, p_recover = 0: every cluster is down from the
        // first charged round onward, even for a round that realizes an
        // empty cohort — waiting rounds step the outage process
        let mut f = LazyFleet::new(
            spec("pop:8:avail:cluster:2:1:0:homog:10"),
            9,
        );
        let c = f.realize_cohort(&[0, 7], 0.0);
        assert_eq!(c.online, vec![false, false]);
        f.realize_cohort(&[], 0.0); // a waiting round still steps chains
        let c = f.realize_cohort(&[3], 0.0);
        assert_eq!(c.online, vec![false]);
        assert_eq!(f.rounds_realized(), 3);
    }

    #[test]
    fn forecast_reroutes_the_lazy_cohort_and_stays_sparse() {
        use crate::fed::selection::ForecastPolicy;
        // homog speeds: ties rank by id, so the un-forecast cohort is
        // always the id prefix of the frontier
        let mut f = LazyFleet::new(
            spec("pop:4:avail:diurnal:100:0.5:1:homog:10"),
            5,
        );
        assert_eq!(f.cohort(2), vec![0, 1]);
        f.set_forecast(ForecastPolicy::Ewma { alpha: 0.5 });
        // an untouched forecaster changes nothing (optimistic prior)
        assert_eq!(f.cohort(2), vec![0, 1]);
        // at t=50 the diurnal phase puts clients 0,1 offline and 2,3
        // online; a few observed rounds teach the forecaster that
        for _ in 0..3 {
            let c = f.realize_cohort(&[0, 1, 2, 3], 50.0);
            assert_eq!(c.online, vec![false, false, true, true]);
        }
        assert_eq!(f.cohort(2), vec![2, 3]);
        // the cohort never shrinks: asking for all four tops back up
        // with the predicted-offline pair, fastest-first
        assert_eq!(f.cohort(4), vec![2, 3, 0, 1]);
        // forecast state is O(touched), and it counts in the footprint
        assert_eq!(f.touched_clients(), 4);
    }

    #[test]
    fn touched_state_stays_cohort_sized() {
        let mut f = LazyFleet::new(
            spec("pop:100000:markov:4:0.1:0.5:uniform:50:500"),
            21,
        );
        for r in 0..20 {
            let ids: Vec<usize> = (0..16).map(|k| k * 3 + (r % 2)).collect();
            let c = f.realize_cohort(&ids, 0.0);
            for (k, &i) in c.ids.iter().enumerate() {
                f.observe(i, c.times[k]);
            }
        }
        // 2 interleaved cohorts of 16 at most: far below the population
        assert!(
            f.touched_clients() <= 48,
            "touched {} clients for 16-cohorts",
            f.touched_clients()
        );
    }

    #[test]
    fn lazy_shards_rows_are_stable_and_minibatches_draw_from_them() {
        let mut sh = LazyShards::new(17, 32, 4, 0.0);
        assert_eq!((sh.s(), sh.d()), (32, 4));
        // zero noise: y is exactly x·w*
        let mut x = vec![0.0f32; 4];
        let y = sh.realize_row(3, 10, &mut x);
        let dot: f32 =
            x.iter().zip(sh.teacher()).map(|(a, b)| a * b).sum();
        assert_eq!(y, dot);
        // minibatch rows come from the client's own shard
        let (mut xb, mut yb) = (vec![0.0f32; 8 * 4], vec![0.0f32; 8]);
        sh.fill_minibatch(3, 8, &mut xb, &mut yb);
        let mut probe = vec![0.0f32; 4];
        for k in 0..8 {
            let row = &xb[k * 4..(k + 1) * 4];
            let found = (0..32).any(|j| {
                sh.realize_row(3, j, &mut probe);
                probe == row
            });
            assert!(found, "minibatch row {k} not in client 3's shard");
        }
        // different clients see different data
        let (mut xc, mut yc) = (vec![0.0f32; 8 * 4], vec![0.0f32; 8]);
        sh.fill_minibatch(4, 8, &mut xc, &mut yc);
        assert_ne!(xb, xc);
    }

    #[test]
    fn lazy_noniid_shards_are_stateless_and_iid_off_is_identical() {
        let data =
            DataSpec::parse("data:dirichlet:0.2:shift:3:corr:speed").unwrap();
        let base = SpeedModel::Uniform { lo: 50.0, hi: 500.0 };
        let sh = LazyShards::with_data(19, 64, 6, 0.1, data, Some(base));
        // per-touch re-realization is bit-identical
        let (mut a, mut b) = (vec![0.0f32; 6], vec![0.0f32; 6]);
        let ya = sh.realize_row(12, 5, &mut a);
        let yb = sh.realize_row(12, 5, &mut b);
        assert_eq!(ya, yb);
        assert_eq!(a, b);
        // strengths are valid percentiles and teachers differ by client
        for i in [0usize, 3, 63] {
            let g = sh.strength(i);
            assert!((0.0..=1.0).contains(&g), "strength {g}");
        }
        assert_ne!(sh.client_teacher(0), sh.client_teacher(1));
        // the IID spelling is byte-identical to the pre-`data:` path
        let mut plain = LazyShards::new(19, 64, 6, 0.1);
        let mut via_data =
            LazyShards::with_data(19, 64, 6, 0.1, DataSpec::iid(), None);
        assert_eq!(plain.teacher(), via_data.teacher());
        let (mut xp, mut yp) = (vec![0.0f32; 8 * 6], vec![0.0f32; 8]);
        let (mut xv, mut yv) = (vec![0.0f32; 8 * 6], vec![0.0f32; 8]);
        plain.fill_minibatch(7, 8, &mut xp, &mut yp);
        via_data.fill_minibatch(7, 8, &mut xv, &mut yv);
        assert_eq!(xp, xv);
        assert_eq!(yp, yv);
    }

    #[test]
    fn lazy_corr_speed_grades_skew_by_base_percentile() {
        // homogeneous base speeds: every client sits at the same
        // percentile, so grading is uniform; a uniform base spreads the
        // strengths across [0, 1]
        let data = DataSpec::parse("data:shift:2:corr:speed").unwrap();
        let sh = LazyShards::with_data(
            3,
            16,
            4,
            0.0,
            data.clone(),
            Some(SpeedModel::Uniform { lo: 50.0, hi: 500.0 }),
        );
        let gs: Vec<f64> = (0..200).map(|i| sh.strength(i)).collect();
        let (lo, hi) = gs.iter().fold((1.0f64, 0.0f64), |(l, h), &g| {
            (l.min(g), h.max(g))
        });
        assert!(lo < 0.2 && hi > 0.8, "strengths not spread: [{lo}, {hi}]");
        // without corr:speed every client is fully skewed
        let full = LazyShards::with_data(
            3,
            16,
            4,
            0.0,
            DataSpec::parse("data:shift:2").unwrap(),
            None,
        );
        assert!((0..20).all(|i| full.strength(i) == 1.0));
    }

    #[test]
    #[should_panic(expected = "corr:speed")]
    fn lazy_corr_speed_without_base_model_panics() {
        let data = DataSpec::parse("data:shift:1:corr:speed").unwrap();
        LazyShards::with_data(1, 8, 2, 0.0, data, None);
    }

    #[test]
    fn population_fleet_reports_its_regime() {
        let lazy = PopulationFleet::Lazy(Box::new(LazyFleet::new(
            spec("pop:5000:uniform:50:500"),
            1,
        )));
        assert!(!lazy.is_exact());
        assert_eq!(lazy.num_clients(), 5000);
    }
}
