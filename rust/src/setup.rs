//! Shared experiment assembly: engine + dataset + fleet from a config.
//! Used by the CLI (`main.rs`), the bench harness (`flanp-bench`), the
//! examples and the integration tests.

use crate::coordinator::ExperimentConfig;
use crate::data::{shard, synth, Labels};
use crate::engine::{
    Engine, HloEngine, KernelPath, Manifest, ModelKind, ModelMeta, NativeEngine,
};
use crate::fed::{ClientFleet, LazyFleet, PopulationFleet, PopulationSpec};
use crate::util::Rng;
use anyhow::{Context, Result};
use std::path::Path;

/// Default artifact directory (relative to the repo root / CWD).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    // honor the env override used by tests run from other CWDs
    if let Ok(dir) = std::env::var("FLANP_ARTIFACTS") {
        return dir.into();
    }
    let cwd = Path::new("artifacts");
    if cwd.exists() {
        return cwd.to_path_buf();
    }
    // fall back to the crate root (useful under `cargo test`)
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Build an engine by kind ("hlo" loads artifacts; "native" is the
/// pure-Rust twin — metadata from the manifest when present, else parsed
/// from the model name; "native-naive" is the same twin pinned to the
/// unblocked reference kernels, used by the bench ablation and the
/// differential kernel tests).
pub fn build_engine(
    engine_kind: &str,
    model: &str,
    artifacts_dir: &Path,
) -> Result<Box<dyn Engine>> {
    match engine_kind {
        "hlo" => {
            let manifest = Manifest::load(artifacts_dir)?;
            Ok(Box::new(HloEngine::load(&manifest, model)?))
        }
        "native" | "native-naive" => {
            let path = if engine_kind == "native-naive" {
                KernelPath::Naive
            } else {
                KernelPath::Blocked
            };
            if let Ok(manifest) = Manifest::load(artifacts_dir) {
                if let Ok(meta) = manifest.model(model) {
                    return Ok(Box::new(
                        NativeEngine::new(meta.clone()).kernel_path(path),
                    ));
                }
            }
            Ok(Box::new(native_from_name(model)?.kernel_path(path)))
        }
        other => {
            anyhow::bail!("unknown engine '{other}' (hlo|native|native-naive)")
        }
    }
}

/// Parse model names like `linreg_d25`, `logreg_d784_c10`,
/// `mlp_d512_c10_h128_h64` into a NativeEngine with catalog defaults.
pub fn native_from_name(name: &str) -> Result<NativeEngine> {
    let mut kind = "";
    let mut d = 0usize;
    let mut c = 1usize;
    let mut hidden = Vec::new();
    for (i, part) in name.split('_').enumerate() {
        if i == 0 {
            kind = part;
            continue;
        }
        if let Some(v) = part.strip_prefix('d') {
            d = v.parse().context("bad d")?;
        } else if let Some(v) = part.strip_prefix('c') {
            c = v.parse().context("bad c")?;
        } else if let Some(v) = part.strip_prefix('h') {
            hidden.push(v.parse().context("bad h")?);
        }
    }
    anyhow::ensure!(d > 0, "model name '{name}' lacks a d<dim> part");
    // batch/tau defaults matching the full catalog (aot.py)
    Ok(match kind {
        "linreg" => NativeEngine::linreg(d, 10, 10),
        "logreg" => NativeEngine::logreg(d, c, 0.01, 50, 10),
        "mlp" => NativeEngine::mlp(d, c, hidden, 0.01, 50, 10),
        other => anyhow::bail!("unknown model kind '{other}'"),
    })
}

/// Synthesize the dataset the model family expects (DESIGN.md §6) and
/// shard it across `cfg.num_clients` clients of `cfg.s` samples each.
pub fn build_fleet(
    meta: &ModelMeta,
    cfg: &ExperimentConfig,
    noise: f64,
    separation: f64,
) -> Result<ClientFleet> {
    let mut rng = Rng::new(cfg.seed);
    let total = cfg.num_clients * cfg.s;
    let dataset = match meta.kind {
        ModelKind::LinReg => synth::linreg(&mut rng, total, meta.d, noise).0,
        _ => {
            // d >= 700 is the MNIST-like regime, smaller the CIFAR-like
            let mut spec = if meta.d >= 700 {
                synth::MixtureSpec::mnist_like(total)
            } else {
                synth::MixtureSpec::cifar_like(total)
            };
            spec.d = meta.d;
            spec.classes = meta.classes;
            if separation > 0.0 {
                spec.separation = separation;
            }
            synth::mixture(&mut rng, &spec)
        }
    };
    let shards =
        shard::partition_fixed_s(&mut rng, &dataset, cfg.num_clients, cfg.s);
    let mut fleet = ClientFleet::with_options(
        dataset,
        shards,
        &cfg.system,
        cfg.ewma_alpha,
        cfg.record_trace,
        &mut rng,
    );
    // non-IID skew is applied AFTER construction from pure per-client
    // streams (data/synth.rs), so the dataset synthesis, the IID
    // partition draw and every fleet fork above consume exactly the
    // seed's draw sequence: `data:` off is bit-identical, and `data:`
    // on changes only shard membership and feature values — never
    // speeds, ordering or the system process.
    if !cfg.data.is_iid() {
        apply_data_skew(&mut fleet, cfg)?;
    }
    if cfg.client_eval_enabled() {
        fleet.set_holdout(meta.batch);
    }
    if let Some(policy) = &cfg.tiers {
        fleet.ensure_tiers(policy);
    }
    // forecasting is RNG-free, so enabling it here (after every scenario
    // draw) cannot perturb the fleet's streams
    if let Some(fc) = &cfg.forecast {
        fleet.set_forecast(fc.clone());
    }
    Ok(fleet)
}

/// Per-client skew strength in [0, 1] for the `corr:speed` grading:
/// the fastest client gets 0 (IID-like), the slowest 1 (fully skewed),
/// linear in speed rank. Without `corr:speed` every client is fully
/// skewed. Exposed so tests and the lazy path can pin the eager
/// convention.
pub fn skew_strengths(order: &[usize], corr_speed: bool) -> Vec<f64> {
    let n = order.len();
    let mut strength = vec![1.0; n];
    if corr_speed && n > 1 {
        for (rank, &c) in order.iter().enumerate() {
            strength[c] = rank as f64 / (n - 1) as f64;
        }
    }
    strength
}

/// Apply the `data:` grammar (`ExperimentConfig::data`) to a freshly
/// built fleet: Dirichlet label skew re-partitions the rows through
/// [`shard::partition_dirichlet`]; covariate shift adds each client's
/// seeded shift vector ([`synth::shift_vector`]) to its own rows in
/// place. Both are keyed to `(cfg.seed, client)` alone, so the lazy
/// population path reproduces the same per-client skew state without
/// materializing anything.
fn apply_data_skew(fleet: &mut ClientFleet, cfg: &ExperimentConfig) -> Result<()> {
    let strength = skew_strengths(&fleet.order, cfg.data.corr_speed);
    if let Some(alpha) = cfg.data.dirichlet {
        let (labels, classes): (Vec<usize>, usize) = match &fleet.dataset.y {
            Labels::Class(l, k) => {
                (l.iter().map(|&v| v as usize).collect(), *k)
            }
            Labels::Real(_) => anyhow::bail!(
                "data:dirichlet needs a classification model \
                 (validate the config first)"
            ),
        };
        fleet.shards = shard::partition_dirichlet(
            cfg.seed,
            &labels,
            classes,
            cfg.num_clients,
            cfg.s,
            alpha,
            &strength,
        );
    }
    if let Some(mag) = cfg.data.shift {
        let d = fleet.dataset.d;
        for c in 0..cfg.num_clients {
            if strength[c] == 0.0 {
                continue;
            }
            let v = synth::shift_vector(cfg.seed, c, d, mag);
            // rows are disjoint across shards, so in-place mutation
            // shifts each row exactly once
            for &row in &fleet.shards[c].indices {
                let x = &mut fleet.dataset.x[row * d..(row + 1) * d];
                for (xj, vj) in x.iter_mut().zip(&v) {
                    *xj += strength[c] as f32 * vj;
                }
            }
        }
    }
    Ok(())
}

/// Build a [`PopulationFleet`] from a `pop:N:SCENARIO` spec: at
/// `N <= exact_threshold` the population materializes through the
/// identical [`build_fleet`] path as a non-population run (config
/// resized to `N`, system swapped for the population scenario), so
/// small populations stay **bit-identical** to plain fleets; past the
/// threshold the lazy sketch-backed fleet takes over. Pass
/// [`crate::fed::DEFAULT_EXACT_THRESHOLD`] unless an experiment pins
/// its own switch point. See `docs/scale.md`.
pub fn build_population_fleet(
    meta: &ModelMeta,
    cfg: &ExperimentConfig,
    pop: &PopulationSpec,
    noise: f64,
    separation: f64,
    exact_threshold: usize,
) -> Result<PopulationFleet> {
    pop.validate().map_err(anyhow::Error::msg)?;
    if pop.n <= exact_threshold {
        let mut sized = cfg.clone();
        sized.num_clients = pop.n;
        sized.system = pop.system.clone();
        let fleet = build_fleet(meta, &sized, noise, separation)?;
        Ok(PopulationFleet::Exact(Box::new(fleet)))
    } else {
        let mut lazy = LazyFleet::new(pop.clone(), cfg.seed);
        if let Some(fc) = &cfg.forecast {
            lazy.set_forecast(fc.clone());
        }
        Ok(PopulationFleet::Lazy(Box::new(lazy)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SolverKind;

    #[test]
    fn native_from_name_parses_catalog_names() {
        let e = native_from_name("linreg_d25").unwrap();
        assert_eq!(e.meta().param_count, 26);
        let e = native_from_name("logreg_d784_c10").unwrap();
        assert_eq!(e.meta().param_count, 7850);
        let e = native_from_name("mlp_d512_c10_h128_h64").unwrap();
        assert_eq!(e.meta().hidden, vec![128, 64]);
        assert!(native_from_name("mlp").is_err());
        assert!(native_from_name("gru_d5").is_err());
    }

    #[test]
    fn native_naive_engine_agrees_with_native() {
        let dir = Path::new("/nonexistent-artifacts");
        let blocked = build_engine("native", "logreg_d12_c3", dir).unwrap();
        let naive = build_engine("native-naive", "logreg_d12_c3", dir).unwrap();
        let meta = blocked.meta().clone();
        let mut rng = Rng::new(3);
        let mut params = vec![0.0f32; meta.param_count];
        rng.fill_normal(&mut params, 0.2);
        let mut x = vec![0.0f32; meta.batch * meta.d];
        rng.fill_normal(&mut x, 0.5);
        let mut y = vec![0.0f32; meta.batch * meta.classes];
        for r in 0..meta.batch {
            y[r * meta.classes + rng.below(meta.classes)] = 1.0;
        }
        // order-preserving blocked kernels: bitwise-identical results
        let (la, ga) = blocked.loss_grad(&params, &x, &y).unwrap();
        let (lb, gb) = naive.loss_grad(&params, &x, &y).unwrap();
        assert_eq!(la, lb);
        assert_eq!(ga, gb);
    }

    #[test]
    fn build_engine_rejects_unknown_kind() {
        assert!(build_engine("nativ", "linreg_d5", Path::new(".")).is_err());
    }

    #[test]
    fn build_fleet_linreg_shapes() {
        let e = native_from_name("linreg_d25").unwrap();
        let cfg = ExperimentConfig::new(SolverKind::Flanp, "linreg_d25", 10, 20);
        let fleet = build_fleet(e.meta(), &cfg, 0.1, 0.0).unwrap();
        assert_eq!(fleet.num_clients(), 10);
        assert_eq!(fleet.s(0), 20);
        assert_eq!(fleet.d(), 25);
    }

    #[test]
    fn population_fleet_materializes_below_threshold() {
        let e = native_from_name("linreg_d25").unwrap();
        let cfg = ExperimentConfig::new(SolverKind::Flanp, "linreg_d25", 10, 20);
        let pop = PopulationSpec::parse("pop:6:uniform:50:500").unwrap();
        let mut f =
            build_population_fleet(e.meta(), &cfg, &pop, 0.1, 0.0, 4096)
                .unwrap();
        assert!(f.is_exact());
        assert_eq!(f.num_clients(), 6);
        // identical to a plain fleet built with a resized config: the
        // exact regime IS the ordinary construction path
        let mut sized = cfg.clone();
        sized.num_clients = 6;
        sized.system = pop.system.clone();
        let plain = build_fleet(e.meta(), &sized, 0.1, 0.0).unwrap();
        assert_eq!(f.exact_mut().unwrap().speeds, plain.speeds);
        assert_eq!(f.exact_mut().unwrap().order, plain.order);
        // past the threshold the population goes lazy
        let big = PopulationSpec::parse("pop:100000:uniform:50:500").unwrap();
        let f = build_population_fleet(e.meta(), &cfg, &big, 0.1, 0.0, 4096)
            .unwrap();
        assert!(!f.is_exact());
        assert_eq!(f.num_clients(), 100_000);
    }

    #[test]
    fn build_fleet_classification_uses_mixture() {
        let e = native_from_name("logreg_d784_c10").unwrap();
        let cfg =
            ExperimentConfig::new(SolverKind::FedGate, "logreg_d784_c10", 4, 100);
        let fleet = build_fleet(e.meta(), &cfg, 0.0, 0.0).unwrap();
        assert_eq!(fleet.dataset.y.classes(), 10);
        assert_eq!(fleet.d(), 784);
    }
}
