//! FLANP — Straggler-Resilient Federated Learning.
//!
//! Rust + JAX + Pallas reproduction of *"Straggler-Resilient Federated
//! Learning: Leveraging the Interplay Between Statistical Accuracy and
//! System Heterogeneity"* (Reisizadeh et al., 2020).
//!
//! Three layers (see `DESIGN.md`):
//!
//! * **Layer 3 (this crate)** — the federated coordinator: the FLANP
//!   adaptive-node-participation meta-algorithm ([`coordinator::flanp`]),
//!   the FedGATE / FedAvg / FedNova / FedProx solvers, the simulated
//!   heterogeneous client fleet and virtual wall-clock ([`fed`]), and the
//!   PJRT runtime that executes AOT-compiled JAX/Pallas artifacts
//!   ([`engine::HloEngine`]).
//! * **Layer 2** — JAX models over flat parameter vectors
//!   (`python/compile/model.py`), lowered once by `make artifacts`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`), the tiled
//!   matmul + fused-update hot spots, lowered into the same HLO.
//!
//! Python never runs at training time: the coordinator is self-contained
//! once `artifacts/` exists.

pub mod coordinator;
pub mod data;
pub mod engine;
pub mod fed;
pub mod setup;
pub mod util;

pub use coordinator::config::{ExperimentConfig, SolverKind};
pub use engine::{Engine, ModelMeta};
