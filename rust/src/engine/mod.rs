//! Compute engines: the numerical core behind the coordinator.
//!
//! Two interchangeable implementations of [`Engine`]:
//!
//! * [`HloEngine`] — the production path. Loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`, lowered once from JAX+Pallas by
//!   `make artifacts`), compiles them on the PJRT CPU client and executes
//!   them from the Rust hot loop. Python is never invoked.
//! * [`NativeEngine`] — a pure-Rust twin implementing identical math.
//!   Used for artifact-free unit tests, differential testing against the
//!   HLO path, and large-N simulations (Table 2 runs N=1000 clients).
//!
//! All engines operate on flat `f32[P]` parameter vectors; layout is owned
//! by Layer 2 (`python/compile/model.py`) and mirrored in
//! [`native::flat_layout`].

#[cfg(feature = "pjrt")]
pub mod hlo;
#[cfg(not(feature = "pjrt"))]
#[path = "hlo_stub.rs"]
pub mod hlo;
pub mod kernels;
pub mod manifest;
pub mod native;

pub use hlo::HloEngine;
pub use kernels::KernelPath;
pub use manifest::{ArtifactInfo, Manifest};
pub use native::NativeEngine;

use anyhow::Result;

/// Which model family an engine computes (Section 5 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    LinReg,
    LogReg,
    Mlp,
}

impl ModelKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "linreg" => Ok(ModelKind::LinReg),
            "logreg" => Ok(ModelKind::LogReg),
            "mlp" => Ok(ModelKind::Mlp),
            other => anyhow::bail!("unknown model kind '{other}'"),
        }
    }
}

/// Static description of one model variant (mirrors `ModelSpec.to_json`).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub kind: ModelKind,
    pub d: usize,
    pub classes: usize,
    pub hidden: Vec<usize>,
    pub l2: f32,
    pub param_count: usize,
    /// static minibatch size baked into the artifacts
    pub batch: usize,
    /// fused-round length baked into the `*_round_t{tau}` artifact
    pub tau: usize,
}

impl ModelMeta {
    /// (in, out) dims of each dense layer — must match model.py.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        match self.kind {
            ModelKind::LinReg => vec![(self.d, 1)],
            ModelKind::LogReg => vec![(self.d, self.classes)],
            ModelKind::Mlp => {
                let mut dims = Vec::new();
                let mut prev = self.d;
                for &h in &self.hidden {
                    dims.push((prev, h));
                    prev = h;
                }
                dims.push((prev, self.classes));
                dims
            }
        }
    }

    pub fn expected_param_count(&self) -> usize {
        self.layer_dims().iter().map(|(i, o)| i * o + o).sum()
    }

    /// Width of one encoded label row.
    pub fn y_width(&self) -> usize {
        if self.kind == ModelKind::LinReg {
            1
        } else {
            self.classes
        }
    }
}

/// The uniform compute interface the coordinator drives.
///
/// All batch arguments are exactly `meta().batch` rows; `xs`/`ys` round
/// arguments stack `tau` such batches. Implementations must be
/// deterministic functions of their inputs.
///
/// (No `Send` bound: [`HloEngine`] holds PJRT handles that are not
/// thread-safe; parallel simulations use per-thread [`NativeEngine`]s.)
pub trait Engine {
    fn meta(&self) -> &ModelMeta;

    /// Mean loss over one batch (+ L2 term).
    fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32>;

    /// (loss, gradient) over one batch.
    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[f32])
        -> Result<(f32, Vec<f32>)>;

    /// One FedGATE-corrected local step: `w - eta * (grad - delta)`.
    fn gate_step(
        &self,
        params: &[f32],
        delta: &[f32],
        x: &[f32],
        y: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>>;

    /// `meta().tau` fused local steps (the hot-path call).
    fn gate_round(
        &self,
        params: &[f32],
        delta: &[f32],
        xs: &[f32],
        ys: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>>;

    /// `meta().tau` FedProx steps towards `anchor`.
    fn prox_round(
        &self,
        params: &[f32],
        anchor: &[f32],
        xs: &[f32],
        ys: &[f32],
        eta: f32,
        prox_mu: f32,
    ) -> Result<Vec<f32>>;

    /// Classification accuracy over one batch (NaN for regression).
    fn accuracy(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32>;

    /// Thread-safe view for fan-out across simulated clients, when the
    /// implementation supports it. [`NativeEngine`] is stateless and
    /// returns itself; [`HloEngine`] returns `None` (PJRT handles are
    /// not exposed as `Sync` by the `xla` crate) and runs serially —
    /// the PJRT CPU client already parallelizes inside each execute.
    fn as_sync(&self) -> Option<&(dyn Engine + Sync)> {
        None
    }

    /// Whether `gate_round`/`prox_round` accept an arbitrary number of
    /// stacked batches (true for Native) or only `meta().tau` (HLO).
    fn round_tau_flexible(&self) -> bool {
        false
    }

    /// One fused round for EVERY client in a communication round:
    /// client k starts from the shared global `w`, uses tracking
    /// variable `deltas[k]` and its pre-sampled batches
    /// `xs_all[k*stride..]`. The default loops [`Engine::gate_round`];
    /// [`HloEngine`] overrides it to build the `w`/`eta` literals once
    /// per round instead of once per client (§Perf lever 5).
    fn gate_rounds_batch(
        &self,
        w: &[f32],
        deltas: &[&[f32]],
        xs_all: &[f32],
        ys_all: &[f32],
        eta: f32,
    ) -> Result<Vec<Vec<f32>>> {
        let m = self.meta();
        let n = deltas.len();
        let xstride = xs_all.len() / n.max(1);
        let ystride = ys_all.len() / n.max(1);
        debug_assert_eq!(xstride % (m.batch * m.d), 0);
        (0..n)
            .map(|k| {
                self.gate_round(
                    w,
                    deltas[k],
                    &xs_all[k * xstride..(k + 1) * xstride],
                    &ys_all[k * ystride..(k + 1) * ystride],
                    eta,
                )
            })
            .collect()
    }
}

/// Average (loss, grad) of a client's FULL shard by chunking it through
/// batch-sized `loss_grad` calls. Exact because every chunk contributes
/// the same row count and the L2 term is identical across chunks.
pub fn full_loss_grad(
    engine: &dyn Engine,
    fleet: &crate::fed::ClientFleet,
    client: usize,
    params: &[f32],
) -> Result<(f64, Vec<f32>)> {
    let meta = engine.meta();
    let b = meta.batch;
    let mut x_buf = vec![0.0f32; b * meta.d];
    let mut y_buf = vec![0.0f32; b * meta.y_width()];
    let mut loss_acc = 0.0f64;
    let mut grad_acc = vec![0.0f64; meta.param_count];
    let mut chunks = 0usize;
    let mut err: Option<anyhow::Error> = None;
    fleet.for_each_full_chunk(client, b, &mut x_buf, &mut y_buf, |x, y| {
        if err.is_some() {
            return;
        }
        match engine.loss_grad(params, x, y) {
            Ok((l, g)) => {
                loss_acc += l as f64;
                crate::util::linalg::accumulate(&mut grad_acc, &g);
                chunks += 1;
            }
            Err(e) => err = Some(e),
        }
    });
    if let Some(e) = err {
        return Err(e);
    }
    let inv = 1.0 / chunks.max(1) as f64;
    Ok((
        loss_acc * inv,
        grad_acc.iter().map(|g| (*g * inv) as f32).collect(),
    ))
}
