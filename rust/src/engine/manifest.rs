//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parses `artifacts/manifest.json`.

use super::{ModelKind, ModelMeta};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered HLO artifact and its I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub kind: String, // loss | grad | step | round | proxround | acc
    pub model: String,
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
    pub models: BTreeMap<String, ModelMeta>,
}

fn parse_io(j: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    j.as_arr()
        .context("io list not an array")?
        .iter()
        .map(|e| {
            let name = e.req_str("name")?.to_string();
            let shape = e
                .req_arr("shape")?
                .iter()
                .map(|d| d.as_usize().context("dim not usize"))
                .collect::<Result<Vec<_>>>()?;
            Ok((name, shape))
        })
        .collect()
}

fn parse_model_meta(j: &Json) -> Result<ModelMeta> {
    Ok(ModelMeta {
        name: j.req_str("name")?.to_string(),
        kind: ModelKind::parse(j.req_str("kind")?)?,
        d: j.req_usize("d")?,
        classes: j.req_usize("classes")?,
        hidden: j
            .req_arr("hidden")?
            .iter()
            .map(|h| h.as_usize().context("hidden not usize"))
            .collect::<Result<Vec<_>>>()?,
        l2: j.req_f64("l2")? as f32,
        param_count: j.req_usize("param_count")?,
        batch: j.req_usize("batch")?,
        tau: j.req_usize("tau")?,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse(&src, dir)
    }

    pub fn parse(src: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            j.req_usize("version")? == 1,
            "unsupported manifest version"
        );
        let artifacts = j
            .req_arr("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactInfo {
                    name: a.req_str("name")?.to_string(),
                    file: dir.join(a.req_str("file")?),
                    kind: a.req_str("kind")?.to_string(),
                    model: a.req_str("model")?.to_string(),
                    inputs: parse_io(a.req("inputs")?)?,
                    outputs: parse_io(a.req("outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let models = j
            .req_arr("models")?
            .iter()
            .map(|m| {
                let meta = parse_model_meta(m)?;
                Ok((meta.name.clone(), meta))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, models })
    }

    /// Find the artifact of `kind` for `model` (pallas variant, i.e. no
    /// `_jnp` suffix) — or the `_jnp` variant when `jnp` is set.
    pub fn find(&self, model: &str, kind: &str, jnp: bool) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| {
            a.model == model
                && a.kind == kind
                && a.name.ends_with("_jnp") == jnp
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "catalog": "quick",
      "artifacts": [
        {"name": "linreg_d8_grad", "file": "linreg_d8_grad.hlo.txt",
         "kind": "grad", "model": "linreg_d8",
         "inputs": [{"name": "params", "shape": [9]},
                    {"name": "x", "shape": [5, 8]},
                    {"name": "y", "shape": [5]}],
         "outputs": [{"name": "loss", "shape": []},
                     {"name": "grad", "shape": [9]}],
         "meta": {}, "sha256_16": "x"}
      ],
      "models": [
        {"name": "linreg_d8", "kind": "linreg", "d": 8, "classes": 1,
         "hidden": [], "l2": 0.0, "param_count": 9, "batch": 5, "tau": 4,
         "pallas": true}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("linreg_d8", "grad", false).unwrap();
        assert_eq!(a.inputs[1].1, vec![5, 8]);
        assert_eq!(a.file, Path::new("/tmp/a/linreg_d8_grad.hlo.txt"));
        let meta = m.model("linreg_d8").unwrap();
        assert_eq!(meta.kind, ModelKind::LinReg);
        assert_eq!(meta.batch, 5);
        assert_eq!(meta.expected_param_count(), 9);
    }

    #[test]
    fn missing_model_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.find("linreg_d8", "round", false).is_none());
    }

    #[test]
    fn real_manifest_loads_when_built() {
        // integration-style: only runs when `make artifacts` has run
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(a.file.exists(), "{:?} missing", a.file);
            }
        }
    }
}
