//! Pure-Rust engine: the differential twin of the JAX/Pallas artifacts.
//!
//! Implements exactly the math of `python/compile/model.py` (forward,
//! softmax cross-entropy / squared loss, L2 on weights only, FedGATE
//! update) so that `NativeEngine` and `HloEngine` agree to f32 tolerance
//! on identical inputs — the cross-layer correctness check in
//! `rust/tests/differential.rs`.

use super::{Engine, ModelKind, ModelMeta};
use anyhow::Result;

pub struct NativeEngine {
    meta: ModelMeta,
}

impl NativeEngine {
    pub fn new(meta: ModelMeta) -> Self {
        assert_eq!(
            meta.param_count,
            meta.expected_param_count(),
            "param_count mismatch for {}",
            meta.name
        );
        NativeEngine { meta }
    }

    /// Convenience constructors mirroring the python catalog.
    pub fn linreg(d: usize, batch: usize, tau: usize) -> Self {
        Self::new(ModelMeta {
            name: format!("linreg_d{d}"),
            kind: ModelKind::LinReg,
            d,
            classes: 1,
            hidden: vec![],
            l2: 0.0,
            param_count: d + 1,
            batch,
            tau,
        })
    }

    pub fn logreg(d: usize, classes: usize, l2: f32, batch: usize, tau: usize) -> Self {
        Self::new(ModelMeta {
            name: format!("logreg_d{d}_c{classes}"),
            kind: ModelKind::LogReg,
            d,
            classes,
            hidden: vec![],
            l2,
            param_count: d * classes + classes,
            batch,
            tau,
        })
    }

    pub fn mlp(
        d: usize,
        classes: usize,
        hidden: Vec<usize>,
        l2: f32,
        batch: usize,
        tau: usize,
    ) -> Self {
        let mut pc = 0;
        let mut prev = d;
        for &h in hidden.iter().chain(std::iter::once(&classes)) {
            pc += prev * h + h;
            prev = h;
        }
        Self::new(ModelMeta {
            name: format!("mlp_d{d}_c{classes}"),
            kind: ModelKind::Mlp,
            d,
            classes,
            hidden,
            l2,
            param_count: pc,
            batch,
            tau,
        })
    }

    /// Forward through all layers. Returns per-layer pre-activations
    /// `zs[l]` ([b, out_l]) and hidden activations `acts[l] = relu(zs[l])`
    /// (empty for the output layer) so the backward pass can reuse them
    /// without recomputing (perf: saves one alloc + pass per hidden
    /// layer per call — see EXPERIMENTS.md §Perf).
    fn forward_all(
        &self,
        params: &[f32],
        x: &[f32],
        b: usize,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let dims = self.meta.layer_dims();
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(dims.len());
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(dims.len());
        let mut off = 0usize;
        for (li, &(fin, fout)) in dims.iter().enumerate() {
            let w = &params[off..off + fin * fout];
            let bia = &params[off + fin * fout..off + fin * fout + fout];
            off += fin * fout + fout;
            let input: &[f32] = if li == 0 { x } else { &acts[li - 1] };
            let mut z = vec![0.0f32; b * fout];
            matmul_bias(input, w, bia, &mut z, b, fin, fout);
            if li + 1 < dims.len() {
                acts.push(z.iter().map(|&v| v.max(0.0)).collect());
            } else {
                acts.push(Vec::new());
            }
            zs.push(z);
        }
        (zs, acts)
    }

    fn l2_loss(&self, params: &[f32]) -> f64 {
        if self.meta.l2 == 0.0 {
            return 0.0;
        }
        let mut off = 0usize;
        let mut sq = 0.0f64;
        for (fin, fout) in self.meta.layer_dims() {
            for v in &params[off..off + fin * fout] {
                sq += (*v as f64) * (*v as f64);
            }
            off += fin * fout + fout;
        }
        0.5 * self.meta.l2 as f64 * sq
    }

    /// loss + full backward pass. Returns (loss, grad).
    fn backprop(&self, params: &[f32], x: &[f32], y: &[f32], b: usize) -> (f32, Vec<f32>) {
        let meta = &self.meta;
        let dims = meta.layer_dims();
        let (zs, acts) = self.forward_all(params, x, b);
        let last = zs.len() - 1;
        let out_w = dims[last].1;

        // dz for the output layer + data loss
        let mut dz = vec![0.0f32; b * out_w];
        let data_loss: f64 = match meta.kind {
            ModelKind::LinReg => {
                // loss = 0.5*mean(resid^2); dz = resid / b
                let mut acc = 0.0f64;
                for r in 0..b {
                    let resid = zs[last][r] - y[r];
                    acc += 0.5 * (resid as f64) * (resid as f64);
                    dz[r] = resid / b as f32;
                }
                acc / b as f64
            }
            _ => {
                // softmax xent; dz = (p - y)/b
                let mut acc = 0.0f64;
                for r in 0..b {
                    let logits = &zs[last][r * out_w..(r + 1) * out_w];
                    let yrow = &y[r * out_w..(r + 1) * out_w];
                    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut zsum = 0.0f64;
                    for &l in logits {
                        zsum += ((l - m) as f64).exp();
                    }
                    let logz = zsum.ln() + m as f64;
                    for c in 0..out_w {
                        let p = ((logits[c] as f64 - logz).exp()) as f32;
                        dz[r * out_w + c] = (p - yrow[c]) / b as f32;
                        acc -= yrow[c] as f64 * (logits[c] as f64 - logz);
                    }
                }
                acc / b as f64
            }
        };

        // walk layers backward accumulating gradients
        let mut grad = vec![0.0f32; meta.param_count];
        let mut offsets = Vec::with_capacity(dims.len());
        {
            let mut off = 0;
            for &(fin, fout) in &dims {
                offsets.push(off);
                off += fin * fout + fout;
            }
        }
        let mut dcur = dz;
        for li in (0..dims.len()).rev() {
            let (fin, fout) = dims[li];
            let off = offsets[li];
            let w = &params[off..off + fin * fout];
            // layer input: x for layer 0, cached relu(z_{li-1}) otherwise
            let input: &[f32] = if li == 0 { x } else { &acts[li - 1] };
            // dW = input^T dcur (+ l2*W), db = colsum(dcur)
            {
                let (gw, gb) = grad[off..off + fin * fout + fout]
                    .split_at_mut(fin * fout);
                for r in 0..b {
                    let xr = &input[r * fin..(r + 1) * fin];
                    let dr = &dcur[r * fout..(r + 1) * fout];
                    for i in 0..fin {
                        let xi = xr[i];
                        if xi == 0.0 {
                            continue;
                        }
                        let row = &mut gw[i * fout..(i + 1) * fout];
                        for j in 0..fout {
                            row[j] += xi * dr[j];
                        }
                    }
                    for j in 0..fout {
                        gb[j] += dr[j];
                    }
                }
                if meta.l2 != 0.0 {
                    for (g, wv) in gw.iter_mut().zip(w) {
                        *g += meta.l2 * wv;
                    }
                }
            }
            // propagate: dprev = (dcur W^T) * relu'(z_{li-1})
            if li > 0 {
                let mut dprev = vec![0.0f32; b * fin];
                for r in 0..b {
                    let dr = &dcur[r * fout..(r + 1) * fout];
                    let dp = &mut dprev[r * fin..(r + 1) * fin];
                    for i in 0..fin {
                        let wrow = &w[i * fout..(i + 1) * fout];
                        let mut s = 0.0f32;
                        for j in 0..fout {
                            s += dr[j] * wrow[j];
                        }
                        dp[i] = s;
                    }
                }
                for (dp, z) in dprev.iter_mut().zip(&zs[li - 1]) {
                    if *z <= 0.0 {
                        *dp = 0.0;
                    }
                }
                dcur = dprev;
            }
        }
        let total = data_loss + self.l2_loss(params);
        (total as f32, grad)
    }

    fn check_batch(&self, x: &[f32], y: &[f32]) -> usize {
        let b = self.meta.batch;
        assert_eq!(x.len(), b * self.meta.d, "x batch mismatch");
        assert_eq!(y.len(), b * self.meta.y_width(), "y batch mismatch");
        b
    }
}

/// z = x @ w + bias; x: [b, fin], w: [fin, fout] row-major.
fn matmul_bias(x: &[f32], w: &[f32], bias: &[f32], z: &mut [f32], b: usize, fin: usize, fout: usize) {
    // init with bias
    for r in 0..b {
        z[r * fout..(r + 1) * fout].copy_from_slice(bias);
    }
    // ikj loop: stride-1 inner over fout
    for r in 0..b {
        let xr = &x[r * fin..(r + 1) * fin];
        let zr = &mut z[r * fout..(r + 1) * fout];
        for i in 0..fin {
            let xi = xr[i];
            if xi == 0.0 {
                continue;
            }
            let wrow = &w[i * fout..(i + 1) * fout];
            for j in 0..fout {
                zr[j] += xi * wrow[j];
            }
        }
    }
}

impl Engine for NativeEngine {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        let b = self.check_batch(x, y);
        let (zs, _) = self.forward_all(params, x, b);
        let last = zs.len() - 1;
        let out_w = self.meta.layer_dims()[last].1;
        let data: f64 = match self.meta.kind {
            ModelKind::LinReg => {
                let mut acc = 0.0f64;
                for r in 0..b {
                    let resid = (zs[last][r] - y[r]) as f64;
                    acc += 0.5 * resid * resid;
                }
                acc / b as f64
            }
            _ => {
                let mut acc = 0.0f64;
                for r in 0..b {
                    let logits = &zs[last][r * out_w..(r + 1) * out_w];
                    let yrow = &y[r * out_w..(r + 1) * out_w];
                    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut zsum = 0.0f64;
                    for &l in logits {
                        zsum += ((l - m) as f64).exp();
                    }
                    let logz = zsum.ln() + m as f64;
                    for c in 0..out_w {
                        acc -= yrow[c] as f64 * (logits[c] as f64 - logz);
                    }
                }
                acc / b as f64
            }
        };
        Ok((data + self.l2_loss(params)) as f32)
    }

    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
        let b = self.check_batch(x, y);
        Ok(self.backprop(params, x, y, b))
    }

    fn gate_step(
        &self,
        params: &[f32],
        delta: &[f32],
        x: &[f32],
        y: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>> {
        let (_, g) = self.loss_grad(params, x, y)?;
        Ok(params
            .iter()
            .zip(g.iter().zip(delta))
            .map(|(w, (gi, di))| w - eta * (gi - di))
            .collect())
    }

    fn gate_round(
        &self,
        params: &[f32],
        delta: &[f32],
        xs: &[f32],
        ys: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>> {
        let b = self.meta.batch;
        let xstride = b * self.meta.d;
        let ystride = b * self.meta.y_width();
        assert_eq!(xs.len() % xstride, 0);
        let tau = xs.len() / xstride;
        assert_eq!(ys.len(), tau * ystride);
        let mut w = params.to_vec();
        for t in 0..tau {
            w = self.gate_step(
                &w,
                delta,
                &xs[t * xstride..(t + 1) * xstride],
                &ys[t * ystride..(t + 1) * ystride],
                eta,
            )?;
        }
        Ok(w)
    }

    fn prox_round(
        &self,
        params: &[f32],
        anchor: &[f32],
        xs: &[f32],
        ys: &[f32],
        eta: f32,
        prox_mu: f32,
    ) -> Result<Vec<f32>> {
        let b = self.meta.batch;
        let xstride = b * self.meta.d;
        let ystride = b * self.meta.y_width();
        let tau = xs.len() / xstride;
        let mut w = params.to_vec();
        for t in 0..tau {
            let (_, mut g) = self.loss_grad(
                &w,
                &xs[t * xstride..(t + 1) * xstride],
                &ys[t * ystride..(t + 1) * ystride],
            )?;
            for ((gi, wi), ai) in g.iter_mut().zip(&w).zip(anchor) {
                *gi += prox_mu * (wi - ai);
            }
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= eta * gi;
            }
        }
        Ok(w)
    }

    fn accuracy(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        if self.meta.kind == ModelKind::LinReg {
            return Ok(f32::NAN);
        }
        let b = self.check_batch(x, y);
        let (zs, _) = self.forward_all(params, x, b);
        let last = zs.len() - 1;
        let c = self.meta.classes;
        let mut correct = 0usize;
        for r in 0..b {
            let logits = &zs[last][r * c..(r + 1) * c];
            let yrow = &y[r * c..(r + 1) * c];
            let pred = argmax(logits);
            let lab = argmax(yrow);
            if pred == lab {
                correct += 1;
            }
        }
        Ok(correct as f32 / b as f32)
    }

    fn as_sync(&self) -> Option<&(dyn Engine + Sync)> {
        Some(self)
    }

    fn round_tau_flexible(&self) -> bool {
        true
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn finite_diff_grad(
        e: &NativeEngine,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        eps: f32,
    ) -> Vec<f32> {
        let mut g = vec![0.0f32; params.len()];
        let mut p = params.to_vec();
        for i in 0..params.len() {
            p[i] = params[i] + eps;
            let lp = e.loss(&p, x, y).unwrap();
            p[i] = params[i] - eps;
            let lm = e.loss(&p, x, y).unwrap();
            p[i] = params[i];
            g[i] = (lp - lm) / (2.0 * eps);
        }
        g
    }

    #[test]
    fn linreg_grad_matches_finite_diff() {
        let e = NativeEngine::linreg(4, 6, 2);
        let mut rng = Rng::new(1);
        let p = rand_vec(&mut rng, 5);
        let x = rand_vec(&mut rng, 24);
        let y = rand_vec(&mut rng, 6);
        let (_, g) = e.loss_grad(&p, &x, &y).unwrap();
        let fd = finite_diff_grad(&e, &p, &x, &y, 1e-3);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn logreg_grad_matches_finite_diff() {
        let e = NativeEngine::logreg(5, 3, 0.1, 4, 2);
        let mut rng = Rng::new(2);
        let p = rand_vec(&mut rng, e.meta().param_count);
        let x = rand_vec(&mut rng, 20);
        let mut y = vec![0.0f32; 12];
        for r in 0..4 {
            y[r * 3 + r % 3] = 1.0;
        }
        let (_, g) = e.loss_grad(&p, &x, &y).unwrap();
        let fd = finite_diff_grad(&e, &p, &x, &y, 1e-3);
        for (i, (a, b)) in g.iter().zip(&fd).enumerate() {
            assert!((a - b).abs() < 3e-3, "idx {i}: {a} vs {b}");
        }
    }

    #[test]
    fn mlp_grad_matches_finite_diff() {
        let e = NativeEngine::mlp(4, 3, vec![6, 5], 0.05, 3, 2);
        let mut rng = Rng::new(3);
        let p = rand_vec(&mut rng, e.meta().param_count);
        let x = rand_vec(&mut rng, 12);
        let mut y = vec![0.0f32; 9];
        for r in 0..3 {
            y[r * 3 + (r + 1) % 3] = 1.0;
        }
        let (_, g) = e.loss_grad(&p, &x, &y).unwrap();
        let fd = finite_diff_grad(&e, &p, &x, &y, 1e-3);
        for (i, (a, b)) in g.iter().zip(&fd).enumerate() {
            assert!((a - b).abs() < 5e-3, "idx {i}: {a} vs {b}");
        }
    }

    #[test]
    fn gate_step_formula() {
        let e = NativeEngine::linreg(3, 2, 1);
        let mut rng = Rng::new(4);
        let p = rand_vec(&mut rng, 4);
        let delta = rand_vec(&mut rng, 4);
        let x = rand_vec(&mut rng, 6);
        let y = rand_vec(&mut rng, 2);
        let (_, g) = e.loss_grad(&p, &x, &y).unwrap();
        let stepped = e.gate_step(&p, &delta, &x, &y, 0.1).unwrap();
        for i in 0..4 {
            let want = p[i] - 0.1 * (g[i] - delta[i]);
            assert!((stepped[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn gate_round_equals_sequential_steps() {
        let e = NativeEngine::logreg(4, 3, 0.01, 2, 3);
        let mut rng = Rng::new(5);
        let p = rand_vec(&mut rng, e.meta().param_count);
        let delta = rand_vec(&mut rng, e.meta().param_count);
        let xs = rand_vec(&mut rng, 3 * 2 * 4);
        let mut ys = vec![0.0f32; 3 * 2 * 3];
        for t in 0..6 {
            ys[t * 3 + t % 3] = 1.0;
        }
        let fused = e.gate_round(&p, &delta, &xs, &ys, 0.05).unwrap();
        let mut w = p.clone();
        for t in 0..3 {
            w = e
                .gate_step(&w, &delta, &xs[t * 8..(t + 1) * 8], &ys[t * 6..(t + 1) * 6], 0.05)
                .unwrap();
        }
        assert_eq!(fused, w);
    }

    #[test]
    fn prox_round_zero_mu_is_plain_sgd() {
        let e = NativeEngine::linreg(3, 2, 2);
        let mut rng = Rng::new(6);
        let p = rand_vec(&mut rng, 4);
        let anchor = rand_vec(&mut rng, 4);
        let xs = rand_vec(&mut rng, 2 * 2 * 3);
        let ys = rand_vec(&mut rng, 4);
        let prox = e.prox_round(&p, &anchor, &xs, &ys, 0.05, 0.0).unwrap();
        let zero = vec![0.0f32; 4];
        let sgd = e.gate_round(&p, &zero, &xs, &ys, 0.05).unwrap();
        for (a, b) in prox.iter().zip(&sgd) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn accuracy_counts_argmax_matches() {
        let e = NativeEngine::logreg(2, 2, 0.0, 2, 1);
        // w maps feature0 -> class1 strongly
        let p = vec![-5.0, 5.0, 0.0, 0.0, 0.0, 0.0]; // W (2x2 row-major), b (2)
        let x = vec![1.0, 0.0, -1.0, 0.0];
        let y_right = vec![0.0, 1.0, 1.0, 0.0];
        assert_eq!(e.accuracy(&p, &x, &y_right).unwrap(), 1.0);
        let y_wrong = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(e.accuracy(&p, &x, &y_wrong).unwrap(), 0.0);
    }

    #[test]
    fn sgd_descends() {
        let e = NativeEngine::linreg(5, 10, 1);
        let mut rng = Rng::new(7);
        let p0 = rand_vec(&mut rng, 6);
        let x = rand_vec(&mut rng, 50);
        let y = rand_vec(&mut rng, 10);
        let l0 = e.loss(&p0, &x, &y).unwrap();
        let zero = vec![0.0f32; 6];
        let mut w = p0;
        for _ in 0..30 {
            w = e.gate_step(&w, &zero, &x, &y, 0.1).unwrap();
        }
        let l1 = e.loss(&w, &x, &y).unwrap();
        assert!(l1 < 0.5 * l0, "{l1} !< {l0}/2");
    }
}
