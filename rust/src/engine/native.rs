//! Pure-Rust engine: the differential twin of the JAX/Pallas artifacts.
//!
//! Implements exactly the math of `python/compile/model.py` (forward,
//! softmax cross-entropy / squared loss, L2 on weights only, FedGATE
//! update) so that `NativeEngine` and `HloEngine` agree to f32 tolerance
//! on identical inputs — the cross-layer correctness check in
//! `rust/tests/differential.rs`.
//!
//! Hot-path structure (docs/perf.md):
//!
//! * the dense compute lives in [`super::kernels`] — blocked tile loops
//!   by default, with the original naive loops retained behind
//!   [`KernelPath::Naive`] for differential tests and the bench
//!   ablation;
//! * all per-call temporaries (pre-activations, activations, the two
//!   backward delta buffers, the gradient, the packed `Wᵀ`) live in a
//!   thread-local [`Scratch`] workspace, so a tau-step
//!   `gate_round`/`prox_round` performs zero heap allocations after
//!   warmup beyond the returned weight vector itself;
//! * the local-SGD weight update is fused into the backward result
//!   (`w -= eta * (g - delta)` in place) instead of allocating a fresh
//!   vector per step as the old `gate_step` loop did.
//!
//! Every fused/blocked path preserves the naive path's floating-point
//! evaluation order per output element, so solver-level bit-identical
//! regression pins (deadline/tiers/traces) hold across kernel paths on
//! ordinary data.

use super::kernels::{self, KernelPath};
use super::{Engine, ModelKind, ModelMeta};
use anyhow::Result;
use std::cell::RefCell;

/// Reusable per-thread workspace for forward/backward passes. Buffers
/// are `resize`d (never shrunk in capacity) on entry, so steady-state
/// rounds touch no allocator. One caveat documented in docs/perf.md:
/// `util::par::par_map` spawns scoped workers per round, so each worker
/// thread re-warms its scratch once per round (O(threads) allocations
/// per communication round, not O(clients·tau)).
#[derive(Default)]
struct Scratch {
    /// per-layer pre-activations `z_l` ([b, out_l])
    zs: Vec<Vec<f32>>,
    /// per-layer hidden activations `relu(z_l)` (last entry unused)
    acts: Vec<Vec<f32>>,
    /// backward delta of the current layer
    dcur: Vec<f32>,
    /// backward delta being built for the previous layer
    dprev: Vec<f32>,
    /// full flat gradient
    grad: Vec<f32>,
    /// packed `Wᵀ` for the blocked `dz @ Wᵀ` pass
    wt: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

pub struct NativeEngine {
    meta: ModelMeta,
    path: KernelPath,
    /// cached `meta.layer_dims()` (avoids re-allocating it per call)
    dims: Vec<(usize, usize)>,
    /// flat param offset of each layer's `[W | b]` block
    offsets: Vec<usize>,
    /// max layer width (over fin and fout) — sizes the delta buffers
    max_width: usize,
    /// max `fin * fout` — sizes the packed-transpose buffer
    max_mat: usize,
}

impl NativeEngine {
    pub fn new(meta: ModelMeta) -> Self {
        Self::with_kernel_path(meta, KernelPath::default())
    }

    pub fn with_kernel_path(meta: ModelMeta, path: KernelPath) -> Self {
        assert_eq!(
            meta.param_count,
            meta.expected_param_count(),
            "param_count mismatch for {}",
            meta.name
        );
        let dims = meta.layer_dims();
        let mut offsets = Vec::with_capacity(dims.len());
        let (mut off, mut max_width, mut max_mat) = (0usize, 0usize, 0usize);
        for &(fin, fout) in &dims {
            offsets.push(off);
            off += fin * fout + fout;
            max_width = max_width.max(fin).max(fout);
            max_mat = max_mat.max(fin * fout);
        }
        NativeEngine { meta, path, dims, offsets, max_width, max_mat }
    }

    /// Builder-style kernel-path override (used by the bench ablation
    /// and `setup::build_engine("native-naive", ..)`).
    pub fn kernel_path(mut self, path: KernelPath) -> Self {
        self.path = path;
        self
    }

    /// Convenience constructors mirroring the python catalog.
    pub fn linreg(d: usize, batch: usize, tau: usize) -> Self {
        Self::new(ModelMeta {
            name: format!("linreg_d{d}"),
            kind: ModelKind::LinReg,
            d,
            classes: 1,
            hidden: vec![],
            l2: 0.0,
            param_count: d + 1,
            batch,
            tau,
        })
    }

    pub fn logreg(d: usize, classes: usize, l2: f32, batch: usize, tau: usize) -> Self {
        Self::new(ModelMeta {
            name: format!("logreg_d{d}_c{classes}"),
            kind: ModelKind::LogReg,
            d,
            classes,
            hidden: vec![],
            l2,
            param_count: d * classes + classes,
            batch,
            tau,
        })
    }

    pub fn mlp(
        d: usize,
        classes: usize,
        hidden: Vec<usize>,
        l2: f32,
        batch: usize,
        tau: usize,
    ) -> Self {
        let mut pc = 0;
        let mut prev = d;
        for &h in hidden.iter().chain(std::iter::once(&classes)) {
            pc += prev * h + h;
            prev = h;
        }
        Self::new(ModelMeta {
            name: format!("mlp_d{d}_c{classes}"),
            kind: ModelKind::Mlp,
            d,
            classes,
            hidden,
            l2,
            param_count: pc,
            batch,
            tau,
        })
    }

    /// Size the thread-local scratch for this model at batch `b`.
    /// `Vec::resize` keeps capacity, so after the first call per thread
    /// (per model size) this is allocation-free.
    fn ensure_scratch(&self, s: &mut Scratch, b: usize) {
        let nl = self.dims.len();
        s.zs.resize_with(nl, Vec::new);
        s.acts.resize_with(nl, Vec::new);
        for (li, &(_, fout)) in self.dims.iter().enumerate() {
            s.zs[li].resize(b * fout, 0.0);
            if li + 1 < nl {
                s.acts[li].resize(b * fout, 0.0);
            }
        }
        s.dcur.resize(b * self.max_width, 0.0);
        s.dprev.resize(b * self.max_width, 0.0);
        s.grad.resize(self.meta.param_count, 0.0);
        s.wt.resize(self.max_mat, 0.0);
    }

    /// Run `f` against the sized thread-local scratch. NOT re-entrant:
    /// engine methods must not call each other inside the closure
    /// (RefCell would panic) — they share compute via the `*_into`
    /// helpers instead.
    fn with_scratch<R>(&self, b: usize, f: impl FnOnce(&mut Scratch) -> R) -> R {
        SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            self.ensure_scratch(s, b);
            f(s)
        })
    }

    /// Forward through all layers into scratch: `zs[l]` pre-activations,
    /// `acts[l] = relu(zs[l])` for hidden layers (the backward pass
    /// reuses both without recomputing).
    fn forward_into(
        &self,
        params: &[f32],
        x: &[f32],
        b: usize,
        zs: &mut [Vec<f32>],
        acts: &mut [Vec<f32>],
    ) {
        let nl = self.dims.len();
        for li in 0..nl {
            let (fin, fout) = self.dims[li];
            let off = self.offsets[li];
            let w = &params[off..off + fin * fout];
            let bia = &params[off + fin * fout..off + fin * fout + fout];
            {
                let input: &[f32] = if li == 0 { x } else { &acts[li - 1][..b * fin] };
                let z = &mut zs[li][..b * fout];
                match self.path {
                    KernelPath::Blocked => {
                        kernels::matmul_bias_blocked(input, w, bia, z, b, fin, fout)
                    }
                    KernelPath::Naive => {
                        kernels::matmul_bias_naive(input, w, bia, z, b, fin, fout)
                    }
                }
            }
            if li + 1 < nl {
                let z = &zs[li][..b * fout];
                for (a, &zv) in acts[li][..b * fout].iter_mut().zip(z) {
                    *a = zv.max(0.0);
                }
            }
        }
    }

    fn l2_loss(&self, params: &[f32]) -> f64 {
        if self.meta.l2 == 0.0 {
            return 0.0;
        }
        let mut sq = 0.0f64;
        for (li, &(fin, fout)) in self.dims.iter().enumerate() {
            let off = self.offsets[li];
            for v in &params[off..off + fin * fout] {
                sq += (*v as f64) * (*v as f64);
            }
        }
        0.5 * self.meta.l2 as f64 * sq
    }

    /// Mean data loss over the output layer; when `dz` is provided also
    /// writes the output-layer delta (`resid/b` resp. `(p - y)/b`).
    fn output_loss(
        &self,
        zlast: &[f32],
        y: &[f32],
        b: usize,
        out_w: usize,
        mut dz: Option<&mut [f32]>,
    ) -> f64 {
        match self.meta.kind {
            ModelKind::LinReg => {
                let mut acc = 0.0f64;
                for r in 0..b {
                    let resid = zlast[r] - y[r];
                    acc += 0.5 * (resid as f64) * (resid as f64);
                    if let Some(dz) = dz.as_deref_mut() {
                        dz[r] = resid / b as f32;
                    }
                }
                acc / b as f64
            }
            _ => {
                let mut acc = 0.0f64;
                for r in 0..b {
                    let logits = &zlast[r * out_w..(r + 1) * out_w];
                    let yrow = &y[r * out_w..(r + 1) * out_w];
                    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut zsum = 0.0f64;
                    for &l in logits {
                        zsum += ((l - m) as f64).exp();
                    }
                    let logz = zsum.ln() + m as f64;
                    for c in 0..out_w {
                        if let Some(dz) = dz.as_deref_mut() {
                            let p = ((logits[c] as f64 - logz).exp()) as f32;
                            dz[r * out_w + c] = (p - yrow[c]) / b as f32;
                        }
                        acc -= yrow[c] as f64 * (logits[c] as f64 - logz);
                    }
                }
                acc / b as f64
            }
        }
    }

    /// Full backward pass into `s.grad` (zeroed first). Returns the
    /// total loss. All temporaries live in `s`; no allocation.
    fn backprop_into(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        b: usize,
        s: &mut Scratch,
    ) -> f32 {
        let Scratch { zs, acts, dcur, dprev, grad, wt } = s;
        self.forward_into(params, x, b, zs, acts);
        let nl = self.dims.len();
        let out_w = self.dims[nl - 1].1;
        let data_loss =
            self.output_loss(&zs[nl - 1][..b * out_w], y, b, out_w, Some(&mut dcur[..b * out_w]));

        grad.fill(0.0);
        for li in (0..nl).rev() {
            let (fin, fout) = self.dims[li];
            let off = self.offsets[li];
            let w = &params[off..off + fin * fout];
            // layer input: x for layer 0, cached relu(z_{li-1}) otherwise
            let input: &[f32] = if li == 0 { x } else { &acts[li - 1][..b * fin] };
            let d = &dcur[..b * fout];
            {
                let (gw, gb) = grad[off..off + fin * fout + fout].split_at_mut(fin * fout);
                match self.path {
                    KernelPath::Blocked => {
                        kernels::grad_weights_blocked(input, d, gw, gb, b, fin, fout)
                    }
                    KernelPath::Naive => {
                        kernels::grad_weights_naive(input, d, gw, gb, b, fin, fout)
                    }
                }
                if self.meta.l2 != 0.0 {
                    for (g, wv) in gw.iter_mut().zip(w) {
                        *g += self.meta.l2 * wv;
                    }
                }
            }
            // propagate: dprev = (dcur Wᵀ) * relu'(z_{li-1})
            if li > 0 {
                let dp = &mut dprev[..b * fin];
                // packing Wᵀ only pays once the batch amortizes it
                if self.path == KernelPath::Blocked && b >= 8 {
                    kernels::pack_transpose(w, wt, fin, fout);
                    kernels::dprev_blocked(d, wt, dp, b, fin, fout);
                } else {
                    kernels::dprev_naive(d, w, dp, b, fin, fout);
                }
                for (dv, &zv) in dp.iter_mut().zip(&zs[li - 1][..b * fin]) {
                    if zv <= 0.0 {
                        *dv = 0.0;
                    }
                }
                std::mem::swap(dcur, dprev);
            }
        }
        (data_loss + self.l2_loss(params)) as f32
    }

    fn check_batch(&self, x: &[f32], y: &[f32]) -> usize {
        let b = self.meta.batch;
        assert_eq!(x.len(), b * self.meta.d, "x batch mismatch");
        assert_eq!(y.len(), b * self.meta.y_width(), "y batch mismatch");
        b
    }

    fn round_strides(&self, xs: &[f32], ys: &[f32]) -> (usize, usize, usize, usize) {
        let b = self.meta.batch;
        let xstride = b * self.meta.d;
        let ystride = b * self.meta.y_width();
        assert_eq!(xs.len() % xstride, 0);
        let tau = xs.len() / xstride;
        assert_eq!(ys.len(), tau * ystride);
        (b, xstride, ystride, tau)
    }
}

impl Engine for NativeEngine {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        let b = self.check_batch(x, y);
        let out_w = self.dims[self.dims.len() - 1].1;
        let data = self.with_scratch(b, |s| {
            let Scratch { zs, acts, .. } = s;
            self.forward_into(params, x, b, zs, acts);
            self.output_loss(&zs[zs.len() - 1][..b * out_w], y, b, out_w, None)
        });
        Ok((data + self.l2_loss(params)) as f32)
    }

    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
        let b = self.check_batch(x, y);
        Ok(self.with_scratch(b, |s| {
            let loss = self.backprop_into(params, x, y, b, s);
            (loss, s.grad.clone())
        }))
    }

    fn gate_step(
        &self,
        params: &[f32],
        delta: &[f32],
        x: &[f32],
        y: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>> {
        let b = self.check_batch(x, y);
        Ok(self.with_scratch(b, |s| {
            self.backprop_into(params, x, y, b, s);
            params
                .iter()
                .zip(s.grad.iter().zip(delta))
                .map(|(w, (gi, di))| w - eta * (gi - di))
                .collect()
        }))
    }

    fn gate_round(
        &self,
        params: &[f32],
        delta: &[f32],
        xs: &[f32],
        ys: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>> {
        let (b, xstride, ystride, tau) = self.round_strides(xs, ys);
        // the returned weights are the ONLY allocation in the round loop
        let mut w = params.to_vec();
        self.with_scratch(b, |s| {
            for t in 0..tau {
                self.backprop_into(
                    &w,
                    &xs[t * xstride..(t + 1) * xstride],
                    &ys[t * ystride..(t + 1) * ystride],
                    b,
                    s,
                );
                // fused update; same FP expression as the old per-step
                // `w - eta * (g - delta)`, evaluated in place
                for (wi, (gi, di)) in w.iter_mut().zip(s.grad.iter().zip(delta)) {
                    *wi -= eta * (gi - di);
                }
            }
        });
        Ok(w)
    }

    fn prox_round(
        &self,
        params: &[f32],
        anchor: &[f32],
        xs: &[f32],
        ys: &[f32],
        eta: f32,
        prox_mu: f32,
    ) -> Result<Vec<f32>> {
        let (b, xstride, ystride, tau) = self.round_strides(xs, ys);
        let mut w = params.to_vec();
        self.with_scratch(b, |s| {
            for t in 0..tau {
                self.backprop_into(
                    &w,
                    &xs[t * xstride..(t + 1) * xstride],
                    &ys[t * ystride..(t + 1) * ystride],
                    b,
                    s,
                );
                // fused `w -= eta * (g + mu * (w - anchor))`; identical
                // evaluation order to the old two-pass formulation
                for ((wi, gi), ai) in w.iter_mut().zip(&s.grad).zip(anchor) {
                    *wi -= eta * (gi + prox_mu * (*wi - ai));
                }
            }
        });
        Ok(w)
    }

    fn accuracy(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        if self.meta.kind == ModelKind::LinReg {
            return Ok(f32::NAN);
        }
        let b = self.check_batch(x, y);
        let c = self.meta.classes;
        let correct = self.with_scratch(b, |s| {
            let Scratch { zs, acts, .. } = s;
            self.forward_into(params, x, b, zs, acts);
            let zlast = &zs[zs.len() - 1];
            let mut correct = 0usize;
            for r in 0..b {
                let logits = &zlast[r * c..(r + 1) * c];
                let yrow = &y[r * c..(r + 1) * c];
                if argmax(logits) == argmax(yrow) {
                    correct += 1;
                }
            }
            correct
        });
        Ok(correct as f32 / b as f32)
    }

    fn as_sync(&self) -> Option<&(dyn Engine + Sync)> {
        Some(self)
    }

    fn round_tau_flexible(&self) -> bool {
        true
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn finite_diff_grad(
        e: &NativeEngine,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        eps: f32,
    ) -> Vec<f32> {
        let mut g = vec![0.0f32; params.len()];
        let mut p = params.to_vec();
        for i in 0..params.len() {
            p[i] = params[i] + eps;
            let lp = e.loss(&p, x, y).unwrap();
            p[i] = params[i] - eps;
            let lm = e.loss(&p, x, y).unwrap();
            p[i] = params[i];
            g[i] = (lp - lm) / (2.0 * eps);
        }
        g
    }

    #[test]
    fn linreg_grad_matches_finite_diff() {
        let e = NativeEngine::linreg(4, 6, 2);
        let mut rng = Rng::new(1);
        let p = rand_vec(&mut rng, 5);
        let x = rand_vec(&mut rng, 24);
        let y = rand_vec(&mut rng, 6);
        let (_, g) = e.loss_grad(&p, &x, &y).unwrap();
        let fd = finite_diff_grad(&e, &p, &x, &y, 1e-3);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn logreg_grad_matches_finite_diff() {
        let e = NativeEngine::logreg(5, 3, 0.1, 4, 2);
        let mut rng = Rng::new(2);
        let p = rand_vec(&mut rng, e.meta().param_count);
        let x = rand_vec(&mut rng, 20);
        let mut y = vec![0.0f32; 12];
        for r in 0..4 {
            y[r * 3 + r % 3] = 1.0;
        }
        let (_, g) = e.loss_grad(&p, &x, &y).unwrap();
        let fd = finite_diff_grad(&e, &p, &x, &y, 1e-3);
        for (i, (a, b)) in g.iter().zip(&fd).enumerate() {
            assert!((a - b).abs() < 3e-3, "idx {i}: {a} vs {b}");
        }
    }

    #[test]
    fn mlp_grad_matches_finite_diff() {
        let e = NativeEngine::mlp(4, 3, vec![6, 5], 0.05, 3, 2);
        let mut rng = Rng::new(3);
        let p = rand_vec(&mut rng, e.meta().param_count);
        let x = rand_vec(&mut rng, 12);
        let mut y = vec![0.0f32; 9];
        for r in 0..3 {
            y[r * 3 + (r + 1) % 3] = 1.0;
        }
        let (_, g) = e.loss_grad(&p, &x, &y).unwrap();
        let fd = finite_diff_grad(&e, &p, &x, &y, 1e-3);
        for (i, (a, b)) in g.iter().zip(&fd).enumerate() {
            assert!((a - b).abs() < 5e-3, "idx {i}: {a} vs {b}");
        }
    }

    #[test]
    fn gate_step_formula() {
        let e = NativeEngine::linreg(3, 2, 1);
        let mut rng = Rng::new(4);
        let p = rand_vec(&mut rng, 4);
        let delta = rand_vec(&mut rng, 4);
        let x = rand_vec(&mut rng, 6);
        let y = rand_vec(&mut rng, 2);
        let (_, g) = e.loss_grad(&p, &x, &y).unwrap();
        let stepped = e.gate_step(&p, &delta, &x, &y, 0.1).unwrap();
        for i in 0..4 {
            let want = p[i] - 0.1 * (g[i] - delta[i]);
            assert!((stepped[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn gate_round_equals_sequential_steps() {
        let e = NativeEngine::logreg(4, 3, 0.01, 2, 3);
        let mut rng = Rng::new(5);
        let p = rand_vec(&mut rng, e.meta().param_count);
        let delta = rand_vec(&mut rng, e.meta().param_count);
        let xs = rand_vec(&mut rng, 3 * 2 * 4);
        let mut ys = vec![0.0f32; 3 * 2 * 3];
        for t in 0..6 {
            ys[t * 3 + t % 3] = 1.0;
        }
        let fused = e.gate_round(&p, &delta, &xs, &ys, 0.05).unwrap();
        let mut w = p.clone();
        for t in 0..3 {
            w = e
                .gate_step(&w, &delta, &xs[t * 8..(t + 1) * 8], &ys[t * 6..(t + 1) * 6], 0.05)
                .unwrap();
        }
        assert_eq!(fused, w);
    }

    #[test]
    fn prox_round_zero_mu_is_plain_sgd() {
        let e = NativeEngine::linreg(3, 2, 2);
        let mut rng = Rng::new(6);
        let p = rand_vec(&mut rng, 4);
        let anchor = rand_vec(&mut rng, 4);
        let xs = rand_vec(&mut rng, 2 * 2 * 3);
        let ys = rand_vec(&mut rng, 4);
        let prox = e.prox_round(&p, &anchor, &xs, &ys, 0.05, 0.0).unwrap();
        let zero = vec![0.0f32; 4];
        let sgd = e.gate_round(&p, &zero, &xs, &ys, 0.05).unwrap();
        for (a, b) in prox.iter().zip(&sgd) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn accuracy_counts_argmax_matches() {
        let e = NativeEngine::logreg(2, 2, 0.0, 2, 1);
        // w maps feature0 -> class1 strongly
        let p = vec![-5.0, 5.0, 0.0, 0.0, 0.0, 0.0]; // W (2x2 row-major), b (2)
        let x = vec![1.0, 0.0, -1.0, 0.0];
        let y_right = vec![0.0, 1.0, 1.0, 0.0];
        assert_eq!(e.accuracy(&p, &x, &y_right).unwrap(), 1.0);
        let y_wrong = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(e.accuracy(&p, &x, &y_wrong).unwrap(), 0.0);
    }

    #[test]
    fn sgd_descends() {
        let e = NativeEngine::linreg(5, 10, 1);
        let mut rng = Rng::new(7);
        let p0 = rand_vec(&mut rng, 6);
        let x = rand_vec(&mut rng, 50);
        let y = rand_vec(&mut rng, 10);
        let l0 = e.loss(&p0, &x, &y).unwrap();
        let zero = vec![0.0f32; 6];
        let mut w = p0;
        for _ in 0..30 {
            w = e.gate_step(&w, &zero, &x, &y, 0.1).unwrap();
        }
        let l1 = e.loss(&w, &x, &y).unwrap();
        assert!(l1 < 0.5 * l0, "{l1} !< {l0}/2");
    }

    /// Engine-level smoke of the kernel-path ablation: blocked and
    /// naive paths agree bit-for-bit on a full MLP round (the dedicated
    /// differential suite lives in rust/tests/kernels.rs).
    #[test]
    fn blocked_and_naive_paths_agree_on_mlp_round() {
        let make = |path| {
            NativeEngine::mlp(9, 4, vec![7, 5], 0.02, 6, 3).kernel_path(path)
        };
        let eb = make(KernelPath::Blocked);
        let en = make(KernelPath::Naive);
        let mut rng = Rng::new(8);
        let p = rand_vec(&mut rng, eb.meta().param_count);
        let delta = rand_vec(&mut rng, eb.meta().param_count);
        let xs = rand_vec(&mut rng, 3 * 6 * 9);
        let mut ys = vec![0.0f32; 3 * 6 * 4];
        for t in 0..18 {
            ys[t * 4 + t % 4] = 1.0;
        }
        let wb = eb.gate_round(&p, &delta, &xs, &ys, 0.05).unwrap();
        let wn = en.gate_round(&p, &delta, &xs, &ys, 0.05).unwrap();
        assert_eq!(wb, wn);
        let (lb, gb) = eb.loss_grad(&p, &xs[..54], &ys[..24]).unwrap();
        let (ln, gn) = en.loss_grad(&p, &xs[..54], &ys[..24]).unwrap();
        assert_eq!(lb, ln);
        assert_eq!(gb, gn);
    }

    /// Scratch reuse across different engines on one thread must not
    /// leak state between models (the thread-local is shared).
    #[test]
    fn scratch_is_safe_across_models() {
        let big = NativeEngine::mlp(20, 5, vec![16], 0.0, 8, 1);
        let small = NativeEngine::linreg(3, 2, 1);
        let mut rng = Rng::new(9);
        let pb = rand_vec(&mut rng, big.meta().param_count);
        let xb = rand_vec(&mut rng, 8 * 20);
        let mut yb = vec![0.0f32; 8 * 5];
        for r in 0..8 {
            yb[r * 5 + r % 5] = 1.0;
        }
        let ps = rand_vec(&mut rng, 4);
        let xsm = rand_vec(&mut rng, 6);
        let ysm = rand_vec(&mut rng, 2);
        // interleave: big, small, big — results must match fresh-thread runs
        let (l1, g1) = big.loss_grad(&pb, &xb, &yb).unwrap();
        let (ls, _) = small.loss_grad(&ps, &xsm, &ysm).unwrap();
        let (l2, g2) = big.loss_grad(&pb, &xb, &yb).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        let (ls2, _) = small.loss_grad(&ps, &xsm, &ysm).unwrap();
        assert_eq!(ls, ls2);
    }
}
