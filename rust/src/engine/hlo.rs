//! PJRT engine: loads and executes the AOT artifacts from the Rust hot
//! path (the production compute path — Python is never invoked).
//!
//! Pipeline per artifact (see /opt/xla-example/load_hlo):
//!   HLO text --HloModuleProto::from_text_file--> XlaComputation
//!   --PjRtClient::compile--> PjRtLoadedExecutable --execute--> Literals

use super::{Engine, Manifest, ModelKind, ModelMeta};
use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

pub struct HloEngine {
    meta: ModelMeta,
    #[allow(dead_code)]
    client: PjRtClient,
    loss_exe: PjRtLoadedExecutable,
    grad_exe: PjRtLoadedExecutable,
    step_exe: PjRtLoadedExecutable,
    round_exe: PjRtLoadedExecutable,
    proxround_exe: PjRtLoadedExecutable,
    acc_exe: Option<PjRtLoadedExecutable>,
}

fn compile(
    client: &PjRtClient,
    manifest: &Manifest,
    model: &str,
    kind: &str,
    jnp: bool,
) -> Result<PjRtLoadedExecutable> {
    let info = manifest
        .find(model, kind, jnp)
        .with_context(|| format!("artifact {model}/{kind} (jnp={jnp}) not in manifest"))?;
    let proto = xla::HloModuleProto::from_text_file(&info.file)
        .with_context(|| format!("parsing {:?}", info.file))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", info.name))
}

impl HloEngine {
    /// Load + compile all artifacts of `model` on a fresh PJRT CPU client.
    pub fn load(manifest: &Manifest, model: &str) -> Result<Self> {
        Self::load_variant(manifest, model, false)
    }

    /// `jnp = true` selects the pure-jnp (no-pallas) artifact variants —
    /// the perf-pass ablation (build with `aot.py --jnp-variants`).
    pub fn load_variant(manifest: &Manifest, model: &str, jnp: bool) -> Result<Self> {
        let meta = manifest.model(model)?.clone();
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let acc_exe = if meta.kind == ModelKind::LinReg {
            None
        } else {
            Some(compile(&client, manifest, model, "acc", jnp)?)
        };
        Ok(HloEngine {
            loss_exe: compile(&client, manifest, model, "loss", jnp)?,
            grad_exe: compile(&client, manifest, model, "grad", jnp)?,
            step_exe: compile(&client, manifest, model, "step", jnp)?,
            round_exe: compile(&client, manifest, model, "round", jnp)?,
            proxround_exe: compile(&client, manifest, model, "proxround", jnp)?,
            acc_exe,
            meta,
            client,
        })
    }

    fn lit1(&self, v: &[f32]) -> Literal {
        Literal::vec1(v)
    }

    fn lit2(&self, v: &[f32], r: usize, c: usize) -> Result<Literal> {
        anyhow::ensure!(v.len() == r * c, "literal shape mismatch");
        Ok(Literal::vec1(v).reshape(&[r as i64, c as i64])?)
    }

    fn lit3(&self, v: &[f32], a: usize, r: usize, c: usize) -> Result<Literal> {
        anyhow::ensure!(v.len() == a * r * c, "literal shape mismatch");
        Ok(Literal::vec1(v).reshape(&[a as i64, r as i64, c as i64])?)
    }

    /// y literal: f32[b] for regression, f32[b, C] one-hot otherwise.
    fn lit_y(&self, y: &[f32], stacked_tau: Option<usize>) -> Result<Literal> {
        let b = self.meta.batch;
        let w = self.meta.y_width();
        match (self.meta.kind, stacked_tau) {
            (ModelKind::LinReg, None) => {
                anyhow::ensure!(y.len() == b, "y len");
                Ok(self.lit1(y))
            }
            (ModelKind::LinReg, Some(t)) => self.lit2(y, t, b),
            (_, None) => self.lit2(y, b, w),
            (_, Some(t)) => self.lit3(y, t, b, w),
        }
    }

    fn run1(&self, exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Literal> {
        let bufs = exe.execute::<Literal>(args)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }

    fn scalar_out(lit: Literal) -> Result<f32> {
        Ok(lit.to_vec::<f32>()?[0])
    }

    fn check_xy(&self, x: &[f32], y: &[f32]) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.meta.batch * self.meta.d,
            "x batch mismatch: got {}, want {}",
            x.len(),
            self.meta.batch * self.meta.d
        );
        anyhow::ensure!(
            y.len() == self.meta.batch * self.meta.y_width(),
            "y batch mismatch"
        );
        Ok(())
    }
}

impl Engine for HloEngine {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        self.check_xy(x, y)?;
        let out = self.run1(
            &self.loss_exe,
            &[
                self.lit1(params),
                self.lit2(x, self.meta.batch, self.meta.d)?,
                self.lit_y(y, None)?,
            ],
        )?;
        Self::scalar_out(out)
    }

    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
        self.check_xy(x, y)?;
        let bufs = self.grad_exe.execute::<Literal>(&[
            self.lit1(params),
            self.lit2(x, self.meta.batch, self.meta.d)?,
            self.lit_y(y, None)?,
        ])?;
        let (loss_l, grad_l) = bufs[0][0].to_literal_sync()?.to_tuple2()?;
        Ok((Self::scalar_out(loss_l)?, grad_l.to_vec::<f32>()?))
    }

    fn gate_step(
        &self,
        params: &[f32],
        delta: &[f32],
        x: &[f32],
        y: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>> {
        self.check_xy(x, y)?;
        let out = self.run1(
            &self.step_exe,
            &[
                self.lit1(params),
                self.lit1(delta),
                self.lit2(x, self.meta.batch, self.meta.d)?,
                self.lit_y(y, None)?,
                Literal::scalar(eta),
            ],
        )?;
        Ok(out.to_vec::<f32>()?)
    }

    fn gate_round(
        &self,
        params: &[f32],
        delta: &[f32],
        xs: &[f32],
        ys: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>> {
        let (b, d, tau) = (self.meta.batch, self.meta.d, self.meta.tau);
        anyhow::ensure!(
            xs.len() == tau * b * d,
            "gate_round wants xs of tau*b*d = {} (artifact tau={tau}), got {}",
            tau * b * d,
            xs.len()
        );
        let out = self.run1(
            &self.round_exe,
            &[
                self.lit1(params),
                self.lit1(delta),
                self.lit3(xs, tau, b, d)?,
                self.lit_y(ys, Some(tau))?,
                Literal::scalar(eta),
            ],
        )?;
        Ok(out.to_vec::<f32>()?)
    }

    fn prox_round(
        &self,
        params: &[f32],
        anchor: &[f32],
        xs: &[f32],
        ys: &[f32],
        eta: f32,
        prox_mu: f32,
    ) -> Result<Vec<f32>> {
        let (b, d, tau) = (self.meta.batch, self.meta.d, self.meta.tau);
        anyhow::ensure!(xs.len() == tau * b * d, "prox_round shape");
        let out = self.run1(
            &self.proxround_exe,
            &[
                self.lit1(params),
                self.lit1(anchor),
                self.lit3(xs, tau, b, d)?,
                self.lit_y(ys, Some(tau))?,
                Literal::scalar(eta),
                Literal::scalar(prox_mu),
            ],
        )?;
        Ok(out.to_vec::<f32>()?)
    }

    fn gate_rounds_batch(
        &self,
        w: &[f32],
        deltas: &[&[f32]],
        xs_all: &[f32],
        ys_all: &[f32],
        eta: f32,
    ) -> Result<Vec<Vec<f32>>> {
        // §Perf: build the shared w / eta literals ONCE per communication
        // round; only the per-client delta/xs/ys literals vary.
        let (b, d, tau) = (self.meta.batch, self.meta.d, self.meta.tau);
        let n = deltas.len();
        anyhow::ensure!(n > 0, "empty batch");
        let xstride = xs_all.len() / n;
        let ystride = ys_all.len() / n;
        anyhow::ensure!(xstride == tau * b * d, "gate_rounds_batch shape");
        let w_lit = self.lit1(w);
        let eta_lit = Literal::scalar(eta);
        (0..n)
            .map(|k| {
                let delta_lit = self.lit1(deltas[k]);
                let xs_lit =
                    self.lit3(&xs_all[k * xstride..(k + 1) * xstride], tau, b, d)?;
                let ys_lit =
                    self.lit_y(&ys_all[k * ystride..(k + 1) * ystride], Some(tau))?;
                // execute takes Borrow<Literal>: pass references so the
                // shared w/eta literals are reused without copies
                let bufs = self.round_exe.execute::<&Literal>(&[
                    &w_lit, &delta_lit, &xs_lit, &ys_lit, &eta_lit,
                ])?;
                let out = bufs[0][0].to_literal_sync()?.to_tuple1()?;
                Ok(out.to_vec::<f32>()?)
            })
            .collect()
    }

    fn accuracy(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        let Some(exe) = &self.acc_exe else {
            return Ok(f32::NAN);
        };
        self.check_xy(x, y)?;
        let out = self.run1(
            exe,
            &[
                self.lit1(params),
                self.lit2(x, self.meta.batch, self.meta.d)?,
                self.lit_y(y, None)?,
            ],
        )?;
        Self::scalar_out(out)
    }
}
