//! CPU compute kernels for [`super::NativeEngine`]: a naive reference
//! set and a blocked set ported from the Pallas tiling ideas in
//! `python/compile/kernels/{matmul,fused}.py`.
//!
//! Three primitives cover the whole forward/backward hot path:
//!
//! * `matmul_bias`   — `z = x @ W + bias`      (forward, per layer)
//! * `grad_weights`  — `gW += xᵀ @ dz, gb += colsum(dz)` (backward dW)
//! * `dprev`         — `dx = dz @ Wᵀ`          (backward propagation)
//!
//! The blocked variants tile the loops `MR × BN × BK` (row micro-tile ×
//! output-column block × reduction block — the CPU analogue of the
//! Pallas kernels' `BM × BN × BK` MXU grid): each weight row loaded
//! from memory is reused across `MR` batch rows, the reduction walks
//! `BK`-sized panels so the active weight panel stays cache-resident,
//! and the backward `dz @ Wᵀ` pass runs over a packed `Wᵀ`
//! ([`pack_transpose`]) so its inner loop is stride-1 instead of
//! striding `fout` floats between elements.
//!
//! **Order-preservation contract:** for every output element the
//! blocked kernels perform exactly the same floating-point additions in
//! exactly the same order as the naive reference — tiling only reorders
//! *independent* outputs, never the reduction sequence of one output,
//! and multi-row contributions are written as separate sequential adds
//! (never reassociated into a tree). On data without engineered signed
//! zeros the two paths are bit-identical; the differential tests in
//! `rust/tests/kernels.rs` pin them to f32 tolerance anyway, and the
//! unit tests below pin random-data runs exactly.
//!
//! The naive kernels are retained (not deleted) as the differential
//! reference and for the `naive-vs-blocked` ablation row of
//! `benches/hotpath.rs` / `BENCH_<n>.json` (docs/perf.md).

/// Batch-row micro-tile: one weight row loaded serves `MR` batch rows.
pub const MR: usize = 4;
/// Reduction (fan-in) cache block: the active `BK × BN` weight panel is
/// at most 256 KiB of f32 — L2-resident on every target CPU.
pub const BK: usize = 128;
/// Output-column cache block (f32 lane count × 128, matching the Pallas
/// kernels' lane-aligned `bn`; our widest layer is 784 so at most two
/// panels are cut).
pub const BN: usize = 512;

/// Which kernel set a [`super::NativeEngine`] dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelPath {
    /// Tiled kernels (default).
    #[default]
    Blocked,
    /// Reference loops — the pre-PR-6 hot path, kept for differential
    /// tests and the bench ablation.
    Naive,
}

// ---------------------------------------------------------------------------
// forward: z = x @ W + bias
// ---------------------------------------------------------------------------

/// Naive reference: row-major ikj loop, stride-1 inner over `fout`.
/// x: [b, fin], w: [fin, fout] row-major, z: [b, fout].
pub fn matmul_bias_naive(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    z: &mut [f32],
    b: usize,
    fin: usize,
    fout: usize,
) {
    for r in 0..b {
        z[r * fout..(r + 1) * fout].copy_from_slice(bias);
    }
    for r in 0..b {
        let xr = &x[r * fin..(r + 1) * fin];
        let zr = &mut z[r * fout..(r + 1) * fout];
        for i in 0..fin {
            let xi = xr[i];
            if xi == 0.0 {
                continue;
            }
            let wrow = &w[i * fout..(i + 1) * fout];
            for j in 0..fout {
                zr[j] += xi * wrow[j];
            }
        }
    }
}

/// Blocked `z = x @ W + bias`: `MR`-row micro-tile over `BN × BK`
/// weight panels. Per output element the reduction order over `fin` is
/// identical to the naive kernel (panels ascend, rows within a panel
/// ascend), so results match the reference bit-for-bit on ordinary
/// data.
pub fn matmul_bias_blocked(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    z: &mut [f32],
    b: usize,
    fin: usize,
    fout: usize,
) {
    for r in 0..b {
        z[r * fout..(r + 1) * fout].copy_from_slice(bias);
    }
    matmul_acc_blocked(x, w, z, b, fin, fout);
}

/// `z += x @ W` over pre-initialized `z` — the shared tile loop behind
/// [`matmul_bias_blocked`] (bias init) and [`dprev_blocked`] (zero
/// init, packed transposed weights).
fn matmul_acc_blocked(x: &[f32], w: &[f32], z: &mut [f32], b: usize, fin: usize, fout: usize) {
    let full = b - b % MR;
    let mut jb = 0;
    while jb < fout {
        let jn = BN.min(fout - jb);
        let mut kb = 0;
        while kb < fin {
            let kn = BK.min(fin - kb);
            let mut rb = 0;
            while rb < full {
                // four disjoint output-row panels of the (jb, jn) block
                let (r0, rest) = z[rb * fout..(rb + MR) * fout].split_at_mut(fout);
                let (r1, rest) = rest.split_at_mut(fout);
                let (r2, r3) = rest.split_at_mut(fout);
                let z0 = &mut r0[jb..jb + jn];
                let z1 = &mut r1[jb..jb + jn];
                let z2 = &mut r2[jb..jb + jn];
                let z3 = &mut r3[jb..jb + jn];
                let x0 = &x[rb * fin..(rb + 1) * fin];
                let x1 = &x[(rb + 1) * fin..(rb + 2) * fin];
                let x2 = &x[(rb + 2) * fin..(rb + 3) * fin];
                let x3 = &x[(rb + 3) * fin..(rb + 4) * fin];
                for k in kb..kb + kn {
                    let (xa, xb, xc, xd) = (x0[k], x1[k], x2[k], x3[k]);
                    if xa == 0.0 && xb == 0.0 && xc == 0.0 && xd == 0.0 {
                        continue; // relu-sparse inputs skip whole quads
                    }
                    let wrow = &w[k * fout + jb..k * fout + jb + jn];
                    for ((((za, zb), zc), zd), &wv) in z0
                        .iter_mut()
                        .zip(z1.iter_mut())
                        .zip(z2.iter_mut())
                        .zip(z3.iter_mut())
                        .zip(wrow)
                    {
                        *za += xa * wv;
                        *zb += xb * wv;
                        *zc += xc * wv;
                        *zd += xd * wv;
                    }
                }
                rb += MR;
            }
            kb += kn;
        }
        jb += jn;
    }
    // remainder rows (b % MR): the naive per-row loop, full fin/fout
    for r in full..b {
        let xr = &x[r * fin..(r + 1) * fin];
        let zr = &mut z[r * fout..(r + 1) * fout];
        for i in 0..fin {
            let xi = xr[i];
            if xi == 0.0 {
                continue;
            }
            let wrow = &w[i * fout..(i + 1) * fout];
            for j in 0..fout {
                zr[j] += xi * wrow[j];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// backward dW: gW += xᵀ @ dz, gb += colsum(dz)
// ---------------------------------------------------------------------------

/// Naive reference: per batch row, rank-1 update of the weight gradient
/// plus the bias column sum (the loop lifted out of the pre-PR-6
/// `backprop`).
pub fn grad_weights_naive(
    input: &[f32],
    dcur: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    b: usize,
    fin: usize,
    fout: usize,
) {
    for r in 0..b {
        let xr = &input[r * fin..(r + 1) * fin];
        let dr = &dcur[r * fout..(r + 1) * fout];
        for i in 0..fin {
            let xi = xr[i];
            if xi == 0.0 {
                continue;
            }
            let row = &mut gw[i * fout..(i + 1) * fout];
            for j in 0..fout {
                row[j] += xi * dr[j];
            }
        }
        for j in 0..fout {
            gb[j] += dr[j];
        }
    }
}

/// Blocked `gW += xᵀ @ dz`: four rank-1 updates fused per pass, so the
/// `fin × fout` gradient matrix is streamed `b/MR` times instead of `b`
/// times. The four contributions are added as separate sequential
/// statements (not a reassociated sum), preserving the naive reduction
/// order over `r` for every `gW[i][j]` and `gb[j]`.
pub fn grad_weights_blocked(
    input: &[f32],
    dcur: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    b: usize,
    fin: usize,
    fout: usize,
) {
    let full = b - b % MR;
    let mut rb = 0;
    while rb < full {
        let x0 = &input[rb * fin..(rb + 1) * fin];
        let x1 = &input[(rb + 1) * fin..(rb + 2) * fin];
        let x2 = &input[(rb + 2) * fin..(rb + 3) * fin];
        let x3 = &input[(rb + 3) * fin..(rb + 4) * fin];
        let d0 = &dcur[rb * fout..(rb + 1) * fout];
        let d1 = &dcur[(rb + 1) * fout..(rb + 2) * fout];
        let d2 = &dcur[(rb + 2) * fout..(rb + 3) * fout];
        let d3 = &dcur[(rb + 3) * fout..(rb + 4) * fout];
        for i in 0..fin {
            let (xa, xb, xc, xd) = (x0[i], x1[i], x2[i], x3[i]);
            if xa == 0.0 && xb == 0.0 && xc == 0.0 && xd == 0.0 {
                continue;
            }
            let row = &mut gw[i * fout..(i + 1) * fout];
            for (j, g) in row.iter_mut().enumerate() {
                let mut v = *g;
                v += xa * d0[j];
                v += xb * d1[j];
                v += xc * d2[j];
                v += xd * d3[j];
                *g = v;
            }
        }
        for (j, g) in gb.iter_mut().enumerate() {
            let mut v = *g;
            v += d0[j];
            v += d1[j];
            v += d2[j];
            v += d3[j];
            *g = v;
        }
        rb += MR;
    }
    if full < b {
        grad_weights_naive(
            &input[full * fin..b * fin],
            &dcur[full * fout..b * fout],
            gw,
            gb,
            b - full,
            fin,
            fout,
        );
    }
}

// ---------------------------------------------------------------------------
// backward dx: dprev = dz @ Wᵀ
// ---------------------------------------------------------------------------

/// Pack `Wᵀ` row-major: `wt[j * fin + i] = w[i * fout + j]`, so the
/// backward propagation's inner loop runs stride-1 over `fin`. Packed
/// once per layer per backward pass into scratch and reused across all
/// `b` batch rows.
pub fn pack_transpose(w: &[f32], wt: &mut [f32], fin: usize, fout: usize) {
    debug_assert_eq!(w.len(), fin * fout);
    debug_assert!(wt.len() >= fin * fout);
    for j in 0..fout {
        let row = &mut wt[j * fin..(j + 1) * fin];
        for (i, v) in row.iter_mut().enumerate() {
            *v = w[i * fout + j];
        }
    }
}

/// Naive reference: per batch row, `dprev[r][i] = dot(dz[r], W[i, :])`
/// over the untransposed weights (already stride-1; its inefficiency is
/// that `W` is re-streamed for every batch row).
pub fn dprev_naive(
    dcur: &[f32],
    w: &[f32],
    dprev: &mut [f32],
    b: usize,
    fin: usize,
    fout: usize,
) {
    for r in 0..b {
        let dr = &dcur[r * fout..(r + 1) * fout];
        let dp = &mut dprev[r * fin..(r + 1) * fin];
        for i in 0..fin {
            let wrow = &w[i * fout..(i + 1) * fout];
            let mut s = 0.0f32;
            for j in 0..fout {
                s += dr[j] * wrow[j];
            }
            dp[i] = s;
        }
    }
}

/// Blocked `dprev = dz @ Wᵀ` over a packed transpose `wt` (see
/// [`pack_transpose`]): the same `MR × BN × BK` tile loop as the
/// forward matmul, with the reduction running over `fout` and each
/// packed `Wᵀ` row reused across `MR` batch rows. The per-output
/// reduction order over `j` matches [`dprev_naive`] exactly.
pub fn dprev_blocked(
    dcur: &[f32],
    wt: &[f32],
    dprev: &mut [f32],
    b: usize,
    fin: usize,
    fout: usize,
) {
    dprev[..b * fin].fill(0.0);
    matmul_acc_blocked(dcur, wt, &mut dprev[..b * fin], b, fout, fin);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.7);
        v
    }

    /// Sprinkle exact +0.0s to exercise the relu-sparsity skip paths.
    fn relu_like(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = rand_vec(rng, n);
        for x in v.iter_mut() {
            *x = x.max(0.0);
        }
        v
    }

    // the awkward-shape sweep: not multiples of MR/BK/BN, batch=1, fout=1
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 7, 1),
        (1, 784, 10),
        (3, 13, 5),
        (4, 128, 64),
        (5, 130, 66),
        (8, 5, 1),
        (50, 784, 128),
        (17, 257, 31),
    ];

    #[test]
    fn matmul_blocked_matches_naive_exactly() {
        for &(b, fin, fout) in SHAPES {
            let mut rng = Rng::new(100 + (b + fin + fout) as u64);
            let x = relu_like(&mut rng, b * fin);
            let w = rand_vec(&mut rng, fin * fout);
            let bias = rand_vec(&mut rng, fout);
            let mut z_n = vec![0.0f32; b * fout];
            let mut z_b = vec![7.0f32; b * fout]; // stale garbage: init must overwrite
            matmul_bias_naive(&x, &w, &bias, &mut z_n, b, fin, fout);
            matmul_bias_blocked(&x, &w, &bias, &mut z_b, b, fin, fout);
            assert_eq!(z_n, z_b, "matmul mismatch at b={b} fin={fin} fout={fout}");
        }
    }

    #[test]
    fn grad_weights_blocked_matches_naive_exactly() {
        for &(b, fin, fout) in SHAPES {
            let mut rng = Rng::new(200 + (b * fin + fout) as u64);
            let x = relu_like(&mut rng, b * fin);
            let d = rand_vec(&mut rng, b * fout);
            // start from a nonzero gradient: the kernels ACCUMULATE
            let g0 = rand_vec(&mut rng, fin * fout);
            let gb0 = rand_vec(&mut rng, fout);
            let (mut gw_n, mut gb_n) = (g0.clone(), gb0.clone());
            let (mut gw_b, mut gb_b) = (g0, gb0);
            grad_weights_naive(&x, &d, &mut gw_n, &mut gb_n, b, fin, fout);
            grad_weights_blocked(&x, &d, &mut gw_b, &mut gb_b, b, fin, fout);
            assert_eq!(gw_n, gw_b, "gW mismatch at b={b} fin={fin} fout={fout}");
            assert_eq!(gb_n, gb_b, "gb mismatch at b={b} fin={fin} fout={fout}");
        }
    }

    #[test]
    fn dprev_blocked_matches_naive_exactly() {
        for &(b, fin, fout) in SHAPES {
            let mut rng = Rng::new(300 + (b + fin * fout) as u64);
            let d = rand_vec(&mut rng, b * fout);
            let w = rand_vec(&mut rng, fin * fout);
            let mut wt = vec![0.0f32; fin * fout];
            pack_transpose(&w, &mut wt, fin, fout);
            let mut dp_n = vec![0.0f32; b * fin];
            let mut dp_b = vec![9.0f32; b * fin]; // stale garbage: fill must clear
            dprev_naive(&d, &w, &mut dp_n, b, fin, fout);
            dprev_blocked(&d, &wt, &mut dp_b, b, fin, fout);
            assert_eq!(dp_n, dp_b, "dprev mismatch at b={b} fin={fin} fout={fout}");
        }
    }

    #[test]
    fn pack_transpose_roundtrips() {
        let (fin, fout) = (5, 3);
        let w: Vec<f32> = (0..15).map(|v| v as f32).collect();
        let mut wt = vec![0.0f32; 15];
        pack_transpose(&w, &mut wt, fin, fout);
        for i in 0..fin {
            for j in 0..fout {
                assert_eq!(wt[j * fin + i], w[i * fout + j]);
            }
        }
    }

    #[test]
    fn matmul_known_values() {
        // [1 2; 3 4] @ [5 6; 7 8] + [0.5, -0.5]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![5.0, 6.0, 7.0, 8.0];
        let bias = vec![0.5, -0.5];
        let mut z = vec![0.0f32; 4];
        matmul_bias_blocked(&x, &w, &bias, &mut z, 2, 2, 2);
        assert_eq!(z, vec![19.5, 21.5, 43.5, 49.5]);
    }
}
