//! Stub `HloEngine` compiled when the `pjrt` feature is off (the `xla`
//! PJRT bindings are not vendored in the offline build image). Keeps the
//! whole crate — including the differential tests and benches, which
//! skip themselves when artifacts are missing — compiling against the
//! exact same API as the real engine in `hlo.rs`.

use super::{Engine, Manifest, ModelMeta};
use anyhow::Result;

pub struct HloEngine {
    meta: ModelMeta,
}

impl HloEngine {
    pub fn load(manifest: &Manifest, model: &str) -> Result<Self> {
        Self::load_variant(manifest, model, false)
    }

    pub fn load_variant(_manifest: &Manifest, model: &str, _jnp: bool) -> Result<Self> {
        anyhow::bail!(
            "HloEngine for '{model}' unavailable: this build has no PJRT \
             runtime (compile with --features pjrt and the xla crate, or \
             use --engine native)"
        )
    }
}

impl Engine for HloEngine {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn loss(&self, _params: &[f32], _x: &[f32], _y: &[f32]) -> Result<f32> {
        unreachable!("stub HloEngine cannot be constructed")
    }

    fn loss_grad(&self, _params: &[f32], _x: &[f32], _y: &[f32]) -> Result<(f32, Vec<f32>)> {
        unreachable!("stub HloEngine cannot be constructed")
    }

    fn gate_step(
        &self,
        _params: &[f32],
        _delta: &[f32],
        _x: &[f32],
        _y: &[f32],
        _eta: f32,
    ) -> Result<Vec<f32>> {
        unreachable!("stub HloEngine cannot be constructed")
    }

    fn gate_round(
        &self,
        _params: &[f32],
        _delta: &[f32],
        _xs: &[f32],
        _ys: &[f32],
        _eta: f32,
    ) -> Result<Vec<f32>> {
        unreachable!("stub HloEngine cannot be constructed")
    }

    fn prox_round(
        &self,
        _params: &[f32],
        _anchor: &[f32],
        _xs: &[f32],
        _ys: &[f32],
        _eta: f32,
        _prox_mu: f32,
    ) -> Result<Vec<f32>> {
        unreachable!("stub HloEngine cannot be constructed")
    }

    fn accuracy(&self, _params: &[f32], _x: &[f32], _y: &[f32]) -> Result<f32> {
        unreachable!("stub HloEngine cannot be constructed")
    }
}
