//! `flanp` — CLI entry point: run one federated experiment end to end.
//!
//! Examples:
//!   flanp run --solver flanp   --model linreg_d25 --clients 100 --s 100
//!   flanp run --solver fedgate --model logreg_d784_c10 --clients 50 \
//!       --s 1200 --engine hlo --trace out.csv
//!   flanp list-artifacts

use anyhow::{Context, Result};
use flanp::coordinator::{run_solver_with, ExperimentConfig, SolverKind};
use flanp::data::DataSpec;
use flanp::engine::Manifest;
use flanp::fed::{
    DeadlinePolicy, JsonlObserver, NoopObserver, Observe, Observer,
    SystemModel, TierPolicy,
};
use flanp::setup;
use flanp::util::cli::Args;
use flanp::util::log;
use flanp::{log_error, log_info};
use std::path::Path;

const USAGE: &str = "\
flanp — straggler-resilient federated learning (FLANP)

USAGE:
  flanp run [options]            run one experiment, print a summary
  flanp list-artifacts [options] list the AOT artifact catalog
  flanp help                     show this help

OPTIONS (run):
  --solver S        flanp | flanp-heuristic | fedgate | fedavg | fednova |
                    fedprox | fedgate-randK | fedgate-fastK | fedbuffK |
                    tifl | ditto:L
                    (fedbuffK = buffered-async, flush every K uploads;
                    tifl = tier-scheduled FedGATE, needs --tiers;
                    ditto:L = fedavg global model + per-client personal
                    heads trained with lambda-L proximal SGD — the acc
                    trace column scores the heads)
                                                       [flanp]
  --model M         manifest model name                [linreg_d25]
  --engine E        hlo | native                       [hlo]
  --artifacts DIR   artifact directory                 [artifacts]
  --clients N       number of clients                  [50]
  --s S             samples per client                 [100]
  --n0 N0           FLANP initial participants         [2]
  --eta F --gamma F stepsizes                          [0.05, 1.0]
  --tau T           local updates per round            [artifact tau]
  --mu F --c F      statistical-accuracy constants     [0.01, 1.0]
  --speed SPEC      system-heterogeneity scenario      [uniform:50:500]
                    grammar:
                      [avail:...:][drop:P:][static:|jitter:SIGMA:|markov:F:PS:PR:]BASE
                      or standalone: trace:FILE[:wrap|:hold]
                    prefixes (composable, availability first, dropout next):
                      avail:iid:P:       each client online i.i.d. w.p. P per
                                         round (the uncorrelated control)
                      avail:diurnal:PERIOD:DUTY:SPREAD:
                                         time-based on/off windows: online
                                         while frac(now/PERIOD + SPREAD*i/n)
                                         < DUTY (SPREAD 0 = one shared
                                         window, 1 = rotating cohort)
                      avail:cluster:C:PF:PR:
                                         C co-located clusters share Markov
                                         outages (up->down PF, down->up PR)
                      drop:P:            P in [0,1): per-round client dropout
                      static:            no per-round dynamics (default)
                      jitter:SIGMA:      log-normal per-round speed jitter
                      markov:F:PS:PR:    fast/slow Markov drift (slow = F x
                                         base, fast->slow PS, slow->fast PR)
                    BASE = uniform:lo:hi | exp:lambda | homog:t
                    e.g. jitter:0.3:uniform:50:500 (per-round log-normal
                    jitter), avail:diurnal:40000:0.25:1:uniform:50:500
                    (rotating diurnal windows), drop:0.05:uniform:50:500
                    (5% round dropouts). Offline (avail:/trace:) clients
                    are observable at selection time: skipped, never
                    charged — unlike drop: dropouts, which hold the round
                    open. trace:FILE replays a recorded per-round CSV
                    (wrap cycles, hold repeats the last round; see
                    --record-trace)
  --data SPEC       statistical-heterogeneity scenario [iid]
                    grammar (composable, in this order):
                      data:[dirichlet:A:][shift:S:][corr:speed]
                      dirichlet:A:   non-IID label skew — each client's
                                     shard is drawn from a Dirichlet(A)
                                     mixture over the classes (small A =
                                     near single-class shards; needs a
                                     classification model)
                      shift:S:       per-client covariate shift — a fixed
                                     random direction of norm S is added
                                     to every feature row of the shard
                      corr:speed     grade the skew by client speed: the
                                     FASTEST client stays IID, the
                                     SLOWEST gets full-strength skew (the
                                     paper's adversarial interplay case)
                    e.g. data:dirichlet:0.1:corr:speed (label skew
                    concentrated on the stragglers),
                    data:dirichlet:0.5:shift:2: (skew plus shift).
                    Non-IID runs (and ditto) reserve one engine batch per
                    client as a held-out tail and report mean per-client
                    accuracy in the trace's acc column
  --deadline SPEC   aggregation deadline policy        [sync]
                    sync           wait for the slowest cohort member
                    fixed:T        aggregate whatever arrived by round
                                   compute time T
                    quantile:Q     deadline = tau * Q-quantile of the
                                   cohort's estimated speeds, Q in (0,1]
                    adaptive:F     self-tuning deadline targeting arrival
                                   fraction F in (0,1]
                    (applies to flanp | flanp-heuristic | fedgate | tifl)
  --tiers SPEC      TiFL tier scheduling               [off]
                    tiers:K[:split:quantile|kmeans][:hysteresis:H]
                    cluster clients into K latency tiers from the online
                    speed estimates; membership is cached and re-tiered
                    only when an estimate drifts past H x its tier's band
                    (H >= 1, default 1.5). split:kmeans places boundaries
                    by 1-D k-means (gaps of a clustered latency
                    distribution) instead of equal-rank quantiles. FLANP
                    stage sizes snap to tier boundaries; required by the
                    tifl solver. Re-tier events land in the trace's
                    reranks column.
  --overselect F    predictive over-selection             [1.0 = off]
                    select ceil(F x k) clients for a round that
                    statistically needs k, aggregate the first k arrivals
                    and cancel the stragglers' in-flight work — the clock
                    is charged only to the k-th arrival, cancellations
                    land in the trace's cancelled column. F in [1, 16];
                    applies to flanp | flanp-heuristic | tifl
  --forecast SPEC   availability forecasting              [off]
                    forecast:ewma:A | forecast:window:W — track each
                    observed client's realized online bit (EWMA with
                    alpha A, or the majority of the last W observations)
                    and skip clients predicted offline at selection time,
                    topping the cohort back up with the next-fastest
                    predicted-online candidates. Applies to flanp |
                    flanp-heuristic | tifl
  --ewma F          EWMA alpha of the online speed estimator [0.25]
  --oracle-ranking  rank FLANP prefixes by oracle speeds instead of the
                    online estimates
  --rerank-every-round
                    re-rank the FLANP prefix from the estimates every
                    round instead of at stage boundaries (the per-round
                    individual re-ranking baseline; conflicts with --tiers)
  --seed N          PRNG seed                          [1]
  --max-rounds R    round budget                       [400]
  --eval-rows N     rows for full-objective eval (0=all) [2000]
  --trace PATH      write per-round CSV trace
  --record-trace P  record the realized per-client latency/availability
                    trace (round 0 = the profiling probe) and write it to
                    P — replayable via --speed trace:P
  --events PATH     write the structured event log (JSONL, schema
                    flanp-events/v1): one typed event per round-loop
                    decision — cohort selection/padding/reordering,
                    deadlines, arrivals, misses, cancellations, offline
                    skips, censored estimates, re-ranks, tier moves and
                    stage transitions. Off by default; when off the run
                    is bit-identical to the pre-observability behavior
  --summary PATH    write the run summary (JSON, schema flanp-summary/v1):
                    final statistics, per-kind event totals, estimator-
                    error quantiles and the host-side per-phase span
                    profile (select/local_rounds/aggregate/eval/
                    bookkeeping/kernels)
  --log-level L     error | warn | info | debug        [info]
                    (FLANP_LOG env var is the fallback; the flag wins.
                    info reproduces the historical output exactly)
  --noise F         linreg label noise                 [0.1]
  --separation F    mixture class separation (classification data)
  --quiet           suppress the configuration line
";

fn main() {
    log::init_from_env();
    if let Err(e) = real_main() {
        log_error!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::from_env(&["run", "list-artifacts", "help"])
        .map_err(|e| anyhow::anyhow!(e))?;
    if let Some(l) = args.flag_opt("log-level") {
        log::set_level(log::Level::parse(&l).map_err(|e| anyhow::anyhow!(e))?);
    }
    // `flanp run --help` (and `--help` anywhere) prints the same usage
    // text as the `help` subcommand
    if args.switch("help") {
        print!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some("list-artifacts") => {
            let dir = args.flag_str(
                "artifacts",
                setup::default_artifacts_dir().to_str().unwrap(),
            );
            args.finish().map_err(|e| anyhow::anyhow!(e))?;
            let manifest = Manifest::load(Path::new(&dir))?;
            log_info!("{} artifacts in {dir}:", manifest.artifacts.len());
            for a in &manifest.artifacts {
                let ins: Vec<String> =
                    a.inputs.iter().map(|(n, s)| format!("{n}{s:?}")).collect();
                log_info!("  {:<44} {}", a.name, ins.join(" "));
            }
            Ok(())
        }
        Some("run") => cmd_run(&mut args),
        Some(other) => anyhow::bail!("unknown subcommand '{other}'"),
    }
}

fn cmd_run(args: &mut Args) -> Result<()> {
    let solver = SolverKind::parse(&args.flag_str("solver", "flanp"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let model = args.flag_str("model", "linreg_d25");
    let engine_kind = args.flag_str("engine", "hlo");
    let artifacts_dir = args.flag_str(
        "artifacts",
        setup::default_artifacts_dir().to_str().unwrap(),
    );
    let clients = args.flag_usize("clients", 50).map_err(|e| anyhow::anyhow!(e))?;
    let s = args.flag_usize("s", 100).map_err(|e| anyhow::anyhow!(e))?;
    let n0 = args.flag_usize("n0", 2).map_err(|e| anyhow::anyhow!(e))?;
    let eta = args.flag_f64("eta", 0.05).map_err(|e| anyhow::anyhow!(e))? as f32;
    let gamma = args.flag_f64("gamma", 1.0).map_err(|e| anyhow::anyhow!(e))? as f32;
    let tau = args.flag_usize("tau", 0).map_err(|e| anyhow::anyhow!(e))?;
    let mu = args.flag_f64("mu", 0.01).map_err(|e| anyhow::anyhow!(e))?;
    let c_stat = args.flag_f64("c", 1.0).map_err(|e| anyhow::anyhow!(e))?;
    let system = SystemModel::parse(&args.flag_str("speed", "uniform:50:500"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let deadline = DeadlinePolicy::parse(&args.flag_str("deadline", "sync"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let data = DataSpec::parse(&args.flag_str("data", "data:iid"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let tiers = args
        .flag_opt("tiers")
        .map(|s| TierPolicy::parse(&s))
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let overselect = flanp::fed::parse_overselect(
        &args.flag_str("overselect", "1.0"),
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    let forecast = args
        .flag_opt("forecast")
        .map(|s| flanp::fed::ForecastPolicy::parse(&s))
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let ewma = args
        .flag_f64("ewma", flanp::fed::DEFAULT_EWMA_ALPHA)
        .map_err(|e| anyhow::anyhow!(e))?;
    let oracle_ranking = args.switch("oracle-ranking");
    let rerank_per_round = args.switch("rerank-every-round");
    let seed = args.flag_usize("seed", 1).map_err(|e| anyhow::anyhow!(e))? as u64;
    let max_rounds =
        args.flag_usize("max-rounds", 400).map_err(|e| anyhow::anyhow!(e))?;
    let eval_rows =
        args.flag_usize("eval-rows", 2000).map_err(|e| anyhow::anyhow!(e))?;
    let trace_path = args.flag_opt("trace");
    let record_trace = args.flag_opt("record-trace");
    let events_path = args.flag_opt("events");
    let summary_path = args.flag_opt("summary");
    let noise = args.flag_f64("noise", 0.1).map_err(|e| anyhow::anyhow!(e))?;
    let separation =
        args.flag_f64("separation", 0.0).map_err(|e| anyhow::anyhow!(e))?;
    let quiet = args.switch("quiet");
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let engine = setup::build_engine(&engine_kind, &model, Path::new(&artifacts_dir))?;
    let meta = engine.meta().clone();

    let mut cfg = ExperimentConfig::new(solver, &model, clients, s);
    cfg.eta = eta;
    cfg.gamma = gamma;
    cfg.tau = if tau == 0 { meta.tau } else { tau };
    cfg.n0 = n0;
    cfg.mu = mu;
    cfg.c_stat = c_stat;
    cfg.system = system;
    cfg.deadline = deadline;
    cfg.data = data;
    cfg.tiers = tiers;
    cfg.overselect = overselect;
    cfg.forecast = forecast;
    cfg.estimate_speeds = !oracle_ranking;
    cfg.rerank_per_round = rerank_per_round;
    cfg.ewma_alpha = ewma;
    cfg.seed = seed;
    cfg.max_rounds = max_rounds;
    cfg.eval_rows = eval_rows;
    cfg.record_trace = record_trace.is_some();
    cfg.events = events_path;
    cfg.summary = summary_path;
    cfg.log_level = log::level();
    // validate before the fleet is built: bad flags (e.g. --ewma 0) must
    // surface as config errors, not construction-time assertions
    cfg.validate(meta.batch).map_err(|e| anyhow::anyhow!(e))?;

    let mut fleet = setup::build_fleet(&meta, &cfg, noise, separation)?;

    if !quiet {
        log_info!(
            "flanp run: solver={} model={} engine={} N={} s={} tau={} eta={} \
             gamma={} system={} data={} deadline={} tiers={} overselect={} \
             forecast={} ranking={}",
            cfg.solver.name(),
            model,
            engine_kind,
            clients,
            s,
            cfg.tau,
            eta,
            gamma,
            cfg.system.spec(),
            cfg.data.spec(),
            cfg.deadline.spec(),
            cfg.tiers.as_ref().map(|t| t.spec()).unwrap_or_else(|| "off".into()),
            cfg.overselect,
            cfg.forecast
                .as_ref()
                .map(|f| f.spec())
                .unwrap_or_else(|| "off".into()),
            if cfg.estimate_speeds {
                if cfg.rerank_per_round { "per-round" } else { "estimated" }
            } else {
                "oracle"
            },
        );
    }
    // observability: a JSONL sink when --events was given, the metrics
    // registry + span profiler when --summary was. With neither, the
    // disabled observer keeps the run bit-identical to the historical
    // behavior (one branch per decision point).
    let mut obs = if cfg.events.is_none() && cfg.summary.is_none() {
        Observe::off()
    } else {
        let sink: Box<dyn Observer> = match &cfg.events {
            Some(p) => Box::new(
                JsonlObserver::create(Path::new(p))
                    .with_context(|| format!("creating event log {p}"))?,
            ),
            None => Box::new(NoopObserver),
        };
        if cfg.summary.is_some() {
            flanp::fed::observe::reset_spans();
            flanp::fed::observe::enable_profiling(true);
        }
        Observe::new(sink, cfg.summary.is_some())
    };
    let t0 = std::time::Instant::now();
    let trace = run_solver_with(engine.as_ref(), &mut fleet, &cfg, &mut obs)?;
    let wall = t0.elapsed();

    let last = trace.last().context("empty trace")?;
    log_info!(
        "done: rounds={} virtual_time={:.1} loss_full={:.6} grad^2={:.3e} \
         dist={:.4} acc={:.4} finished={} ({} stages, {} reranks, \
         {} cancelled) [{:.2?} real]",
        last.round,
        trace.total_time,
        last.loss_full,
        last.grad_norm_sq,
        last.dist_to_opt,
        last.accuracy,
        trace.finished,
        trace.stage_transitions.len().max(1),
        trace.total_reranks(),
        trace.total_cancelled(),
        wall
    );
    if !trace.client_acc.is_empty() {
        log_info!(
            "client holdout acc: mean={:.4} worst-decile={:.4} (N={})",
            trace.mean_client_acc(),
            trace.worst_decile_acc(),
            trace.client_acc.len()
        );
    }
    if let Some(p) = trace_path {
        trace.write_csv(Path::new(&p))?;
        log_info!("trace written to {p}");
    }
    if let Some(p) = record_trace {
        fleet
            .write_recorded_trace(Path::new(&p))
            .map_err(|e| anyhow::anyhow!(e))?;
        log_info!(
            "realized system trace written to {p} (replay with --speed trace:{p})"
        );
    }
    if let Some(p) = &cfg.summary {
        let json = obs.summary_json(&trace, wall.as_secs_f64() * 1e3);
        std::fs::write(p, json.to_string() + "\n")
            .with_context(|| format!("writing run summary {p}"))?;
        log_info!("run summary written to {p}");
    }
    if let Some(p) = &cfg.events {
        log_info!("event log written to {p}");
    }
    Ok(())
}
