//! Dependency-free utility substrate.
//!
//! The build is fully offline with only `xla` + `anyhow` vendored, so the
//! crates a project would normally pull (rand, serde, clap, proptest,
//! criterion) are replaced by small, unit-tested implementations here.

pub mod cli;
pub mod json;
pub mod linalg;
pub mod log;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
