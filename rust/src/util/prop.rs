//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it greedily shrinks using the input's `Shrink`
//! implementation and panics with the minimal counterexample. Used by the
//! coordinator invariants suite (`rust/tests/properties.rs`).

use crate::util::Rng;
use std::fmt::Debug;

/// Types that can propose structurally smaller candidates of themselves.
pub trait Shrink: Sized + Clone {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let mut c = Vec::new();
        if *self > 0 {
            c.push(0);
            c.push(self / 2);
            c.push(self - 1);
        }
        c.dedup();
        c
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<u64> {
        let mut c = Vec::new();
        if *self > 0 {
            c.push(0);
            c.push(self / 2);
        }
        c.dedup();
        c
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<f64> {
        let mut c = Vec::new();
        if *self != 0.0 {
            c.push(0.0);
            c.push(self / 2.0);
        }
        c
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<(A, B, C)> {
        let mut out: Vec<(A, B, C)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        // shrink one element
        if let Some(first) = self.first() {
            for cand in first.shrink() {
                let mut v = self.clone();
                v[0] = cand;
                out.push(v);
            }
        }
        out
    }
}

/// Run a property over `cases` random inputs; shrink + panic on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  \
                 counterexample: {min_input:?}\n  reason: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T, P>(mut input: T, mut msg: String, prop: &P) -> (T, String)
where
    T: Shrink + Debug,
    P: Fn(&T) -> Result<(), String>,
{
    // bounded greedy descent
    for _ in 0..200 {
        let mut advanced = false;
        for cand in input.shrink() {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (input, msg)
}

/// Generator helper: usize in [lo, hi].
pub fn gen_usize(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Generator helper: f64 in [lo, hi).
pub fn gen_f64(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    rng.uniform(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |r| gen_usize(r, 0, 100),
            |_| {
                Ok(())
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "counterexample")]
    fn failing_property_panics_with_counterexample() {
        forall(
            2,
            100,
            |r| gen_usize(r, 0, 1000),
            |&x| {
                if x < 500 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let caught = std::panic::catch_unwind(|| {
            forall(
                3,
                100,
                |r| gen_usize(r, 0, 10_000),
                |&x| if x < 17 { Ok(()) } else { Err("ge 17".into()) },
            );
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // greedy halving from any failing x should land near the boundary
        let ce: usize = msg
            .split("counterexample: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(ce >= 17 && ce <= 40, "shrunk to {ce}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let v = vec![5usize, 6, 7, 8];
        assert!(v.shrink().iter().any(|c| c.len() < v.len()));
    }
}
