//! Tiny leveled logger for the bins (`util::log`).
//!
//! Four levels — `error < warn < info < debug` — stored in a process
//! global. The default is [`Level::Info`], which keeps the bins' stdout
//! byte-identical to the historical `println!` output; `--log-level` or
//! the `FLANP_LOG` environment variable (flag wins) raise or lower it.
//! `info`/`debug` write to stdout, `error`/`warn` to stderr, exactly
//! like the `println!`/`eprintln!` calls they replace.
//!
//! Use through the crate-root macros:
//!
//! ```
//! flanp::util::log::set_level(flanp::util::log::Level::Warn);
//! flanp::log_info!("suppressed at warn level");
//! flanp::log_error!("still printed (stderr)");
//! flanp::util::log::set_level(flanp::util::log::Level::Info);
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};

/// Log verbosity, ordered: a message prints when its level is at or
/// below the current one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a level name (the `--log-level` / `FLANP_LOG` grammar).
    pub fn parse(s: &str) -> Result<Level, String> {
        match s {
            "error" => Ok(Level::Error),
            "warn" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            other => Err(format!(
                "unknown log level '{other}' (expected error|warn|info|debug)"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

static LEVEL: AtomicUsize = AtomicUsize::new(Level::Info as usize);

/// Set the process-wide log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as usize, Ordering::Relaxed);
}

/// The current log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        3 => Level::Debug,
        _ => Level::Info,
    }
}

/// Whether a message at `l` would print.
#[inline]
pub fn enabled(l: Level) -> bool {
    (l as usize) <= LEVEL.load(Ordering::Relaxed)
}

/// Initialize from the `FLANP_LOG` environment variable, if set and
/// valid (an invalid value is ignored — the bins' `--log-level` flag
/// reports bad names loudly instead). Returns the resulting level.
pub fn init_from_env() -> Level {
    if let Ok(v) = std::env::var("FLANP_LOG") {
        if let Ok(l) = Level::parse(&v) {
            set_level(l);
        }
    }
    level()
}

/// `println!` gated at [`Level::Info`] (stdout).
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            println!($($t)*);
        }
    };
}

/// `println!` gated at [`Level::Debug`] (stdout).
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            println!($($t)*);
        }
    };
}

/// `eprintln!` gated at [`Level::Warn`] (stderr).
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            eprintln!($($t)*);
        }
    };
}

/// `eprintln!` gated at [`Level::Error`] (stderr).
#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Error) {
            eprintln!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_order() {
        assert!(Level::parse("nope").is_err());
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.as_str()).unwrap(), l);
        }
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn gating() {
        // NOTE: process-global — keep this the only test that mutates
        // the level, and restore the default before returning
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
