//! Summary statistics + the harmonic-number helpers used by the runtime
//! analysis (Theorem 2's order-statistics argument) and by benches.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Nearest-rank percentile (p in [0, 100]) over a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// H_n = 1 + 1/2 + ... + 1/n (expected order statistics of exp(1): the
/// i-th fastest of N clients has E[T_(i)] = H_N - H_{N-i}; Appendix D).
pub fn harmonic(n: usize) -> f64 {
    (1..=n).map(|k| 1.0 / k as f64).sum()
}

/// E[T_(i)] for i.i.d. exp(1) order statistics.
pub fn expected_order_stat_exp(n: usize, i: usize) -> f64 {
    assert!(i >= 1 && i <= n);
    harmonic(n) - harmonic(n - i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn harmonic_values() {
        assert!((harmonic(1) - 1.0).abs() < 1e-12);
        assert!((harmonic(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25)).abs() < 1e-12);
        // ln(n) + gamma bounds
        let n = 1000;
        let h = harmonic(n);
        let gamma = 0.5772156649;
        assert!(h > (n as f64).ln() + gamma);
        assert!(h < ((n + 1) as f64).ln() + gamma);
    }

    #[test]
    fn order_stats_monotone_and_sum() {
        let n = 16;
        let mut prev = 0.0;
        for i in 1..=n {
            let e = expected_order_stat_exp(n, i);
            assert!(e > prev);
            prev = e;
        }
        // E[T_(N)] = H_N
        assert!((expected_order_stat_exp(n, n) - harmonic(n)).abs() < 1e-12);
    }
}
