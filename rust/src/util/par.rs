//! Minimal scoped-thread parallel map (no rayon offline).
//!
//! `par_map(n, f)` evaluates `f(0..n)` across `available_parallelism`
//! worker threads with static chunking and returns results in order.
//! Used by the coordinator to fan local client work out across cores —
//! the simulated analogue of clients computing concurrently.

/// Number of worker threads to use for `n` items.
pub fn threads_for(n: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    cores.min(n).max(1)
}

/// Parallel map over `0..n` preserving order. `f` must be `Sync`.
/// Falls back to a serial loop for tiny inputs.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads_for(n);
    if workers <= 1 || n < 2 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut rest: &mut [Option<T>] = &mut out;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < n {
            let len = chunk.min(n - start);
            let (head, tail) = rest.split_at_mut(len);
            rest = tail;
            let begin = start;
            let fref = &f;
            handles.push(scope.spawn(move || {
                for (off, slot) in head.iter_mut().enumerate() {
                    *slot = Some(fref(begin + off));
                }
            }));
            start += len;
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });
    out.into_iter().map(|x| x.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map() {
        let got = par_map(1000, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn order_preserved_with_uneven_chunks() {
        let got = par_map(17, |i| i);
        assert_eq!(got, (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn threads_bounded_by_items() {
        assert_eq!(threads_for(1), 1);
        assert!(threads_for(100) >= 1);
    }
}
