//! Minimal scoped-thread parallel map (no rayon offline).
//!
//! `par_map(n, f)` evaluates `f(0..n)` across `available_parallelism`
//! worker threads with static chunking and returns results in order.
//! Used by the coordinator to fan local client work out across cores —
//! the simulated analogue of clients computing concurrently.
//!
//! `par_map_min_chunk(n, min_chunk, f)` is the threshold-aware variant:
//! it never hands a worker fewer than `min_chunk` items, so callers with
//! cheap per-item work (an 8-client linreg round is a few thousand
//! FLOPs) stay serial instead of paying ~10µs of thread spawn/join per
//! worker. Callers translate a per-item work estimate into a threshold
//! via [`min_chunk_for_work`].

/// Number of worker threads for `n` items at `min_chunk` items per
/// worker minimum. `min_chunk = 1` reproduces the old `threads_for`.
pub fn threads_for_chunked(n: usize, min_chunk: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    cores.min(n / min_chunk.max(1)).min(n).max(1)
}

/// Number of worker threads to use for `n` items.
pub fn threads_for(n: usize) -> usize {
    threads_for_chunked(n, 1)
}

/// Items each worker must amortize its spawn cost over, given an
/// estimate of the FLOPs (or any proportional work unit) per item.
/// Tuned so one worker's chunk is ≥ ~2M FLOPs (≈ the cost of a few
/// thread spawns at sub-GFLOP/s scalar throughput): tiny models run
/// serial, one MLP `gate_round` (~2.4M FLOPs per tau=10, b=50 client)
/// already clears it at 1 item.
pub const PAR_MIN_FLOP: usize = 2_000_000;

pub fn min_chunk_for_work(flop_per_item: usize) -> usize {
    (PAR_MIN_FLOP / flop_per_item.max(1)).max(1)
}

/// Parallel map over `0..n` preserving order. `f` must be `Sync`.
/// Falls back to a serial loop for tiny inputs.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_min_chunk(n, 1, f)
}

/// Parallel map over `0..n` preserving order, spawning a worker only
/// for every `min_chunk` items. Serial (same thread, same order) when
/// the threshold leaves a single worker, so results are always
/// order-identical to `(0..n).map(f)`.
pub fn par_map_min_chunk<T, F>(n: usize, min_chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads_for_chunked(n, min_chunk);
    if workers <= 1 || n < 2 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut rest: &mut [Option<T>] = &mut out;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < n {
            let len = chunk.min(n - start);
            let (head, tail) = rest.split_at_mut(len);
            rest = tail;
            let begin = start;
            let fref = &f;
            handles.push(scope.spawn(move || {
                for (off, slot) in head.iter_mut().enumerate() {
                    *slot = Some(fref(begin + off));
                }
            }));
            start += len;
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });
    out.into_iter().map(|x| x.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map() {
        let got = par_map(1000, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn order_preserved_with_uneven_chunks() {
        let got = par_map(17, |i| i);
        assert_eq!(got, (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn threads_bounded_by_items() {
        assert_eq!(threads_for(1), 1);
        assert!(threads_for(100) >= 1);
    }

    #[test]
    fn min_chunk_keeps_small_n_serial() {
        // 8 items at min_chunk 100 -> a single worker regardless of cores
        assert_eq!(threads_for_chunked(8, 100), 1);
        assert_eq!(threads_for_chunked(0, 100), 1);
        // and par_map_min_chunk must take the serial path (observable:
        // the closure sees calls strictly in order on one thread)
        let order = std::sync::Mutex::new(Vec::new());
        let got = par_map_min_chunk(8, 100, |i| {
            order.lock().unwrap().push(i);
            i * 3
        });
        assert_eq!(got, (0..8).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn min_chunk_results_order_identical_to_serial() {
        for min_chunk in [1, 3, 64, 10_000] {
            let got = par_map_min_chunk(257, min_chunk, |i| i * i + 1);
            let want: Vec<usize> = (0..257).map(|i| i * i + 1).collect();
            assert_eq!(got, want, "min_chunk={min_chunk}");
        }
    }

    #[test]
    fn threads_scale_with_work_budget() {
        // plenty of items, large chunks: worker count limited by n/chunk
        let t = threads_for_chunked(64, 16);
        assert!(t <= 4, "expected <= 64/16 workers, got {t}");
        assert!(t >= 1);
    }

    #[test]
    fn min_chunk_for_work_thresholds() {
        assert_eq!(min_chunk_for_work(PAR_MIN_FLOP), 1);
        assert_eq!(min_chunk_for_work(PAR_MIN_FLOP * 10), 1);
        assert_eq!(min_chunk_for_work(PAR_MIN_FLOP / 4), 4);
        assert_eq!(min_chunk_for_work(0), PAR_MIN_FLOP);
    }
}
