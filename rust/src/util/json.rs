//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar; numbers are kept as f64. Used for the
//! artifact `manifest.json` and for metric/trace dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| JsonError(format!("field '{key}' not a string")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| JsonError(format!("field '{key}' not a usize")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| JsonError(format!("field '{key}' not a number")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| JsonError(format!("field '{key}' not an array")))
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> FromIterator<T> for Json {
    fn from_iter<I: IntoIterator<Item = T>>(it: I) -> Self {
        Json::Arr(it.into_iter().map(Into::into).collect())
    }
}

/// Build a Json object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone)]
pub struct JsonError(pub String);

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our manifests)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"linreg_d25","shape":[10,25],"pi":3.25,"ok":true,"nil":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("quote \" slash \\ nl \n tab \t".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn real_manifest_parses() {
        // shape of the aot.py manifest
        let src = r#"{"version":1,"artifacts":[{"name":"m_grad","file":"m_grad.hlo.txt",
          "inputs":[{"name":"params","shape":[9]}],"outputs":[{"name":"loss","shape":[]}],
          "meta":{"batch":5,"tau":4,"l2":0.01}}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.req_usize("version").unwrap(), 1);
        let a = &j.req_arr("artifacts").unwrap()[0];
        assert_eq!(a.req_str("name").unwrap(), "m_grad");
        assert_eq!(a.req("meta").unwrap().req_f64("l2").unwrap(), 0.01);
    }
}
