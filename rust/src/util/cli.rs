//! Minimal CLI flag parser (clap is unavailable offline).
//!
//! Grammar: `prog [subcommand] --flag value --switch ... positional`.
//! Flags may be `--k v` or `--k=v`. Unknown flags are an error so typos
//! fail loudly in experiment scripts.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        subcommands: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") && subcommands.contains(&first.as_str())
            {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--k v` if a non-flag follows, else a switch
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(body.to_string(), v);
                        }
                        _ => out.switches.push(body.to_string()),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(subcommands: &[&str]) -> Result<Args, String> {
        Self::parse(std::env::args().skip(1), subcommands)
    }

    fn mark(&mut self, key: &str) {
        self.known.push(key.to_string());
    }

    pub fn flag_str(&mut self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn flag_opt(&mut self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn flag_usize(&mut self, key: &str, default: usize) -> Result<usize, String> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn flag_f64(&mut self, key: &str, default: f64) -> Result<f64, String> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn switch(&mut self, key: &str) -> bool {
        self.mark(key);
        self.switches.iter().any(|s| s == key)
            || self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Call after reading all flags: rejects anything unrecognized.
    pub fn finish(&self) -> Result<(), String> {
        for k in self.flags.keys() {
            if !self.known.contains(k) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        for k in &self.switches {
            if !self.known.contains(k) {
                return Err(format!("unknown switch --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["run", "fig1"])
            .unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        // note the grammar: a bare token after `--flag` is consumed as its
        // value, so positionals go before switches (or use --flag=value)
        let mut a = parse("fig1 out.csv --n 50 --eta=0.05 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("fig1"));
        assert_eq!(a.flag_usize("n", 0).unwrap(), 50);
        assert_eq!(a.flag_f64("eta", 0.0).unwrap(), 0.05);
        assert!(a.switch("quick"));
        assert_eq!(a.positional, vec!["out.csv"]);
        a.finish().unwrap();
    }

    #[test]
    fn defaults() {
        let mut a = parse("run");
        assert_eq!(a.flag_usize("n", 7).unwrap(), 7);
        assert_eq!(a.flag_str("mode", "x"), "x");
        assert!(!a.switch("quick"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_rejected() {
        let mut a = parse("run --oops 3");
        let _ = a.flag_usize("n", 1);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_rejected() {
        let mut a = parse("run --n abc");
        assert!(a.flag_usize("n", 1).is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        let mut a = parse("run --shift=-2.5");
        assert_eq!(a.flag_f64("shift", 0.0).unwrap(), -2.5);
    }
}
