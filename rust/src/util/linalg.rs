//! Small dense linear algebra: vector ops for the coordinator hot path and
//! a Gaussian-elimination solver used to compute the exact linear-regression
//! optimum `w*` (Figures 2, 7, 8 plot `||w - w*||`).

/// y += a * x (fused server update; the Rust twin of kernels.axpy).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// out = a * x + y, allocating.
pub fn axpy_new(a: f32, x: &[f32], y: &[f32]) -> Vec<f32> {
    x.iter().zip(y).map(|(xi, yi)| a * xi + yi).collect()
}

pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

pub fn norm2_sq(x: &[f32]) -> f64 {
    dot(x, x)
}

pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

pub fn sub(x: &[f32], y: &[f32]) -> Vec<f32> {
    x.iter().zip(y).map(|(a, b)| a - b).collect()
}

pub fn dist2(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Accumulate `x` into `acc` (f64 accumulation for stable averaging).
pub fn accumulate(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, v) in acc.iter_mut().zip(x) {
        *a += *v as f64;
    }
}

/// acc / n -> f32 vector.
pub fn mean_of(acc: &[f64], n: usize) -> Vec<f32> {
    let inv = 1.0 / n as f64;
    acc.iter().map(|a| (*a * inv) as f32).collect()
}

/// Solve A x = b for symmetric positive-definite A (n x n, row-major) by
/// Gaussian elimination with partial pivoting. Used for the linreg normal
/// equations (d <= a few hundred), f64 throughout.
pub fn solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut m = a.to_vec();
    let mut rhs = b.to_vec();
    for col in 0..n {
        // partial pivot
        let mut piv = col;
        let mut best = m[col * n + col].abs();
        for row in col + 1..n {
            let v = m[row * n + col].abs();
            if v > best {
                best = v;
                piv = row;
            }
        }
        if best < 1e-12 {
            return None; // singular
        }
        if piv != col {
            for k in 0..n {
                m.swap(col * n + k, piv * n + k);
            }
            rhs.swap(col, piv);
        }
        let d = m[col * n + col];
        for row in col + 1..n {
            let f = m[row * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                m[row * n + k] -= f * m[col * n + k];
            }
            rhs[row] -= f * rhs[col];
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut s = rhs[row];
        for k in row + 1..n {
            s -= m[row * n + k] * x[k];
        }
        x[row] = s / m[row * n + row];
    }
    Some(x)
}

/// Exact ridge-regression optimum of
///   0.5/n * ||X w + b 1 - y||^2 + 0.5*l2*||w||^2
/// over the (row-major) data. Returns the flat param vector [w..., b]
/// matching the Layer-2 linreg layout.
pub fn linreg_optimum(x: &[f32], y: &[f32], n: usize, d: usize, l2: f64) -> Vec<f32> {
    assert_eq!(x.len(), n * d);
    assert_eq!(y.len(), n);
    // augmented design [X | 1]; normal equations (G + n*l2*I') w = X^T y
    let dd = d + 1;
    let mut g = vec![0.0f64; dd * dd];
    let mut rhs = vec![0.0f64; dd];
    for r in 0..n {
        let row = &x[r * d..(r + 1) * d];
        for i in 0..d {
            let xi = row[i] as f64;
            rhs[i] += xi * y[r] as f64;
            for j in i..d {
                g[i * dd + j] += xi * row[j] as f64;
            }
            g[i * dd + d] += xi; // vs bias column
        }
        rhs[d] += y[r] as f64;
    }
    g[d * dd + d] = n as f64;
    // mirror the upper triangle
    for i in 0..dd {
        for j in 0..i {
            g[i * dd + j] = g[j * dd + i];
        }
    }
    // ridge on weights only (not bias) — matches model.py `_l2_term`
    for i in 0..d {
        g[i * dd + i] += l2 * n as f64;
    }
    let w = solve(&g, &rhs, dd).expect("normal equations singular");
    w.into_iter().map(|v| v as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn axpy_matches_naive() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(-2.0, &x, &mut y);
        assert_eq!(y, vec![8.0, 16.0, 24.0]);
        assert_eq!(axpy_new(-2.0, &x, &[10.0, 20.0, 30.0]), y);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((norm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-9);
        assert!((dist2(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn solve_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![3.0, -2.0];
        assert_eq!(solve(&a, &b, 2).unwrap(), b);
    }

    #[test]
    fn solve_known_system() {
        // [2 1; 1 3] x = [5; 10] -> x = [1, 3]
        let a = vec![2.0, 1.0, 1.0, 3.0];
        let x = solve(&a, &[5.0, 10.0], 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_rejects_singular() {
        let a = vec![1.0, 2.0, 2.0, 4.0];
        assert!(solve(&a, &[1.0, 2.0], 2).is_none());
    }

    #[test]
    fn linreg_optimum_recovers_planted_model() {
        let mut rng = Rng::new(1);
        let (n, d) = (2000, 6);
        let w_true: Vec<f64> = (0..d).map(|i| (i as f64) - 2.5).collect();
        let b_true = 0.7;
        let mut x = vec![0.0f32; n * d];
        rng.fill_normal(&mut x, 1.0);
        let y: Vec<f32> = (0..n)
            .map(|r| {
                let mut s = b_true;
                for i in 0..d {
                    s += w_true[i] * x[r * d + i] as f64;
                }
                (s + 0.01 * rng.normal()) as f32
            })
            .collect();
        let w = linreg_optimum(&x, &y, n, d, 0.0);
        for i in 0..d {
            assert!((w[i] as f64 - w_true[i]).abs() < 0.02, "w[{i}]={}", w[i]);
        }
        assert!((w[d] as f64 - b_true).abs() < 0.02);
    }

    #[test]
    fn linreg_optimum_gradient_is_zero() {
        // at w*, the gradient of the regularized ERM must vanish
        let mut rng = Rng::new(2);
        let (n, d, l2) = (500, 4, 0.1);
        let mut x = vec![0.0f32; n * d];
        rng.fill_normal(&mut x, 1.0);
        let y: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let w = linreg_optimum(&x, &y, n, d, l2);
        // grad_w = X^T (Xw + b - y)/n + l2 w ; grad_b = mean(resid)
        let mut gw = vec![0.0f64; d];
        let mut gb = 0.0f64;
        for r in 0..n {
            let mut pred = w[d] as f64;
            for i in 0..d {
                pred += w[i] as f64 * x[r * d + i] as f64;
            }
            let resid = pred - y[r] as f64;
            gb += resid;
            for i in 0..d {
                gw[i] += resid * x[r * d + i] as f64;
            }
        }
        for i in 0..d {
            let g = gw[i] / n as f64 + l2 * w[i] as f64;
            assert!(g.abs() < 1e-4, "gw[{i}]={g}");
        }
        assert!((gb / n as f64).abs() < 1e-4);
    }
}
