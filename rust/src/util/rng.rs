//! Deterministic PRNG (PCG-XSH-RR 64/32) + distributions.
//!
//! Every stochastic choice in the system (dataset synthesis, shard
//! assignment, minibatch sampling, client speeds, init) flows through
//! this generator so experiments are bit-reproducible from a seed.

/// PCG-XSH-RR 64/32: small, fast, statistically solid. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seeded generator; `stream` selects an independent sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut r = Rng { state: 0, inc, spare: None };
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        r.state = r.state.wrapping_add(seed);
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        r
    }

    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator (for per-client streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::with_stream(self.next_u64(), stream.wrapping_mul(2) + 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.next_f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.next_f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.next_f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices drawn from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fill with i.i.d. N(0, sigma^2) f32.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }
}

#[inline]
fn mul128(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform(50.0, 500.0);
            assert!((50.0..500.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 275.0).abs() < 5.0, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let lambda = 0.25;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_small_n() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 40);
        assert_eq!(idx.len(), 40);
        let mut seen = std::collections::HashSet::new();
        for i in idx {
            assert!(i < 100);
            assert!(seen.insert(i));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(123);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
