//! In-memory dense dataset: row-major f32 features + labels.

/// Labels: real-valued targets (regression) or class ids (classification).
#[derive(Clone, Debug)]
pub enum Labels {
    Real(Vec<f32>),
    /// (class id per row, number of classes)
    Class(Vec<u32>, usize),
}

impl Labels {
    pub fn len(&self) -> usize {
        match self {
            Labels::Real(v) => v.len(),
            Labels::Class(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn classes(&self) -> usize {
        match self {
            Labels::Real(_) => 1,
            Labels::Class(_, c) => *c,
        }
    }

    /// Width of one encoded label row as fed to the engines
    /// (f32 target for regression, one-hot f32[C] for classification).
    pub fn encoded_width(&self) -> usize {
        match self {
            Labels::Real(_) => 1,
            Labels::Class(_, c) => *c,
        }
    }

    /// Encode rows `idx` into `out` (len = idx.len() * encoded_width()).
    pub fn encode_into(&self, idx: &[usize], out: &mut [f32]) {
        match self {
            Labels::Real(v) => {
                assert_eq!(out.len(), idx.len());
                for (o, &i) in out.iter_mut().zip(idx) {
                    *o = v[i];
                }
            }
            Labels::Class(v, c) => {
                assert_eq!(out.len(), idx.len() * c);
                out.fill(0.0);
                for (r, &i) in idx.iter().enumerate() {
                    out[r * c + v[i] as usize] = 1.0;
                }
            }
        }
    }
}

/// Dense dataset; `x` is row-major `[n, d]`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Labels,
    pub d: usize,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Labels, d: usize) -> Self {
        assert_eq!(x.len() % d, 0, "x length not a multiple of d");
        assert_eq!(x.len() / d, y.len(), "row count mismatch");
        Dataset { x, y, d }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Gather feature rows `idx` into `out` (len = idx.len() * d).
    pub fn gather_x(&self, idx: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), idx.len() * self.d);
        for (r, &i) in idx.iter().enumerate() {
            out[r * self.d..(r + 1) * self.d].copy_from_slice(self.row(i));
        }
    }

    /// Standardize features to zero mean / unit variance in place
    /// (global statistics — the server-side preprocessing step).
    pub fn standardize(&mut self) {
        let n = self.n();
        if n == 0 {
            return;
        }
        for j in 0..self.d {
            let mut s = 0.0f64;
            let mut s2 = 0.0f64;
            for r in 0..n {
                let v = self.x[r * self.d + j] as f64;
                s += v;
                s2 += v * v;
            }
            let mean = s / n as f64;
            let var = (s2 / n as f64 - mean * mean).max(1e-12);
            let inv = 1.0 / var.sqrt();
            for r in 0..n {
                let v = &mut self.x[r * self.d + j];
                *v = ((*v as f64 - mean) * inv) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_and_row() {
        let ds = Dataset::new(
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            Labels::Real(vec![10.0, 20.0, 30.0]),
            2,
        );
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        let mut out = vec![0.0; 4];
        ds.gather_x(&[2, 0], &mut out);
        assert_eq!(out, vec![5.0, 6.0, 1.0, 2.0]);
    }

    #[test]
    fn onehot_encoding() {
        let y = Labels::Class(vec![2, 0, 1], 3);
        assert_eq!(y.encoded_width(), 3);
        let mut out = vec![9.0; 6];
        y.encode_into(&[0, 2], &mut out);
        assert_eq!(out, vec![0.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn real_encoding() {
        let y = Labels::Real(vec![0.5, -1.5]);
        let mut out = vec![0.0; 2];
        y.encode_into(&[1, 0], &mut out);
        assert_eq!(out, vec![-1.5, 0.5]);
    }

    #[test]
    fn standardize_moments() {
        let mut ds = Dataset::new(
            vec![1.0, 100.0, 2.0, 200.0, 3.0, 300.0, 4.0, 400.0],
            Labels::Real(vec![0.0; 4]),
            2,
        );
        ds.standardize();
        for j in 0..2 {
            let col: Vec<f64> =
                (0..4).map(|r| ds.x[r * 2 + j] as f64).collect();
            let m = col.iter().sum::<f64>() / 4.0;
            let v = col.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 4.0;
            assert!(m.abs() < 1e-6);
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "row count mismatch")]
    fn mismatched_rows_panics() {
        Dataset::new(vec![0.0; 6], Labels::Real(vec![0.0; 2]), 2);
    }
}
