//! Federated sharding: partition a dataset's rows across N clients.
//!
//! The paper's setup (Section 2): each of N nodes holds s i.i.d. samples,
//! drawn once before training; nodes cannot re-sample. An i.i.d. shard is
//! a random partition of an i.i.d. dataset; we shuffle then slice.

use crate::data::Dataset;
use crate::util::Rng;

/// One client's view: indices into the shared dataset.
#[derive(Clone, Debug)]
pub struct Shard {
    pub indices: Vec<usize>,
}

impl Shard {
    pub fn s(&self) -> usize {
        self.indices.len()
    }
}

/// Random equal partition: N shards of s = n/N samples each.
/// Requires N*s <= n; leftover rows are unused (as in the paper, where
/// each node stores exactly s samples).
pub fn partition_iid(rng: &mut Rng, dataset: &Dataset, num_clients: usize) -> Vec<Shard> {
    let n = dataset.n();
    assert!(num_clients > 0 && num_clients <= n);
    let s = n / num_clients;
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    (0..num_clients)
        .map(|c| Shard { indices: idx[c * s..(c + 1) * s].to_vec() })
        .collect()
}

/// Partition with an explicit per-client sample count.
pub fn partition_fixed_s(
    rng: &mut Rng,
    dataset: &Dataset,
    num_clients: usize,
    s: usize,
) -> Vec<Shard> {
    let n = dataset.n();
    assert!(
        num_clients * s <= n,
        "need {}x{} = {} samples, dataset has {n}",
        num_clients,
        s,
        num_clients * s
    );
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    (0..num_clients)
        .map(|c| Shard { indices: idx[c * s..(c + 1) * s].to_vec() })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Labels};

    fn toy(n: usize) -> Dataset {
        Dataset::new(
            (0..n * 2).map(|i| i as f32).collect(),
            Labels::Real(vec![0.0; n]),
            2,
        )
    }

    #[test]
    fn partition_is_disjoint_and_equal() {
        let ds = toy(100);
        let shards = partition_iid(&mut Rng::new(1), &ds, 10);
        assert_eq!(shards.len(), 10);
        let mut seen = std::collections::HashSet::new();
        for sh in &shards {
            assert_eq!(sh.s(), 10);
            for &i in &sh.indices {
                assert!(i < 100);
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    }

    #[test]
    fn partition_drops_remainder() {
        let ds = toy(103);
        let shards = partition_iid(&mut Rng::new(2), &ds, 10);
        let total: usize = shards.iter().map(|s| s.s()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn fixed_s_respects_request() {
        let ds = toy(100);
        let shards = partition_fixed_s(&mut Rng::new(3), &ds, 4, 20);
        assert_eq!(shards.len(), 4);
        assert!(shards.iter().all(|s| s.s() == 20));
    }

    #[test]
    #[should_panic(expected = "samples")]
    fn fixed_s_overflow_panics() {
        let ds = toy(50);
        partition_fixed_s(&mut Rng::new(4), &ds, 10, 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = toy(64);
        let a = partition_iid(&mut Rng::new(9), &ds, 8);
        let b = partition_iid(&mut Rng::new(9), &ds, 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }
}
