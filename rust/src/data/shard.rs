//! Federated sharding: partition a dataset's rows across N clients.
//!
//! The paper's setup (Section 2): each of N nodes holds s i.i.d. samples,
//! drawn once before training; nodes cannot re-sample. An i.i.d. shard is
//! a random partition of an i.i.d. dataset; we shuffle then slice.
//! [`partition_dirichlet`] is the non-IID variant (`data:dirichlet:A:`):
//! each client draws its labels from its own Dirichlet(alpha) categorical.

use crate::data::{synth, Dataset};
use crate::util::Rng;

/// One client's view: indices into the shared dataset.
#[derive(Clone, Debug)]
pub struct Shard {
    pub indices: Vec<usize>,
}

impl Shard {
    pub fn s(&self) -> usize {
        self.indices.len()
    }
}

/// Random equal partition: N shards of s = n/N samples each.
/// Requires N*s <= n; leftover rows are unused (as in the paper, where
/// each node stores exactly s samples).
pub fn partition_iid(rng: &mut Rng, dataset: &Dataset, num_clients: usize) -> Vec<Shard> {
    let n = dataset.n();
    assert!(num_clients > 0 && num_clients <= n);
    let s = n / num_clients;
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    (0..num_clients)
        .map(|c| Shard { indices: idx[c * s..(c + 1) * s].to_vec() })
        .collect()
}

/// Partition with an explicit per-client sample count.
pub fn partition_fixed_s(
    rng: &mut Rng,
    dataset: &Dataset,
    num_clients: usize,
    s: usize,
) -> Vec<Shard> {
    let n = dataset.n();
    assert!(
        num_clients * s <= n,
        "need {}x{} = {} samples, dataset has {n}",
        num_clients,
        s,
        num_clients * s
    );
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    (0..num_clients)
        .map(|c| Shard { indices: idx[c * s..(c + 1) * s].to_vec() })
        .collect()
}

/// Non-IID partition (`data:dirichlet:A:`): client `c` draws its `s`
/// labels from its own Dirichlet(alpha) categorical
/// ([`synth::dirichlet_proportions`], blended toward uniform by
/// `strength[c]` for the `corr:speed` grading) and pulls matching rows
/// from per-class pools in dataset order. An exhausted class falls back
/// to the class with the most remaining rows, so every client still gets
/// exactly `s` rows and all `n*s` rows are used — deterministic in
/// `(seed, labels)`, with each client's draws confined to its own skew
/// stream.
pub fn partition_dirichlet(
    seed: u64,
    labels: &[usize],
    num_classes: usize,
    num_clients: usize,
    s: usize,
    alpha: f64,
    strength: &[f64],
) -> Vec<Shard> {
    assert!(num_classes > 1, "dirichlet skew needs >= 2 classes");
    assert_eq!(strength.len(), num_clients);
    assert!(
        num_clients * s <= labels.len(),
        "need {}x{} = {} samples, dataset has {}",
        num_clients,
        s,
        num_clients * s,
        labels.len()
    );
    // per-class row pools, consumed back-to-front (dataset order)
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (row, &l) in labels.iter().enumerate() {
        assert!(l < num_classes, "label {l} out of range");
        pools[l].push(row);
    }
    (0..num_clients)
        .map(|c| {
            // the proportions AND the categorical picks come from the
            // client's own skew stream, so the lazy path can reproduce
            // the proportions bit-exactly from (seed, client) alone
            let mut rng = synth::skew_stream(seed, c);
            let mut p =
                synth::dirichlet_proportions_with(&mut rng, alpha, num_classes);
            synth::blend_to_uniform(&mut p, strength[c]);
            let mut indices = Vec::with_capacity(s);
            for _ in 0..s {
                let u = rng.next_f64();
                let mut acc = 0.0;
                let mut pick = num_classes - 1;
                for (cls, &pc) in p.iter().enumerate() {
                    acc += pc;
                    if u < acc {
                        pick = cls;
                        break;
                    }
                }
                if pools[pick].is_empty() {
                    // fallback: most-remaining class keeps the partition
                    // total-preserving when a popular label runs dry
                    pick = (0..num_classes)
                        .max_by_key(|&cls| pools[cls].len())
                        .unwrap();
                }
                indices.push(pools[pick].pop().expect("pools exhausted"));
            }
            Shard { indices }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Labels};

    fn toy(n: usize) -> Dataset {
        Dataset::new(
            (0..n * 2).map(|i| i as f32).collect(),
            Labels::Real(vec![0.0; n]),
            2,
        )
    }

    #[test]
    fn partition_is_disjoint_and_equal() {
        let ds = toy(100);
        let shards = partition_iid(&mut Rng::new(1), &ds, 10);
        assert_eq!(shards.len(), 10);
        let mut seen = std::collections::HashSet::new();
        for sh in &shards {
            assert_eq!(sh.s(), 10);
            for &i in &sh.indices {
                assert!(i < 100);
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    }

    #[test]
    fn partition_drops_remainder() {
        let ds = toy(103);
        let shards = partition_iid(&mut Rng::new(2), &ds, 10);
        let total: usize = shards.iter().map(|s| s.s()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn fixed_s_respects_request() {
        let ds = toy(100);
        let shards = partition_fixed_s(&mut Rng::new(3), &ds, 4, 20);
        assert_eq!(shards.len(), 4);
        assert!(shards.iter().all(|s| s.s() == 20));
    }

    #[test]
    #[should_panic(expected = "samples")]
    fn fixed_s_overflow_panics() {
        let ds = toy(50);
        partition_fixed_s(&mut Rng::new(4), &ds, 10, 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = toy(64);
        let a = partition_iid(&mut Rng::new(9), &ds, 8);
        let b = partition_iid(&mut Rng::new(9), &ds, 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }

    /// Round-robin labels so every class pool has exactly n/k rows.
    fn cyclic_labels(n: usize, k: usize) -> Vec<usize> {
        (0..n).map(|i| i % k).collect()
    }

    #[test]
    fn dirichlet_partition_is_disjoint_and_exact() {
        let labels = cyclic_labels(400, 4);
        let shards =
            partition_dirichlet(7, &labels, 4, 8, 50, 0.2, &vec![1.0; 8]);
        assert_eq!(shards.len(), 8);
        let mut seen = std::collections::HashSet::new();
        for sh in &shards {
            assert_eq!(sh.s(), 50);
            for &i in &sh.indices {
                assert!(i < 400);
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
        assert_eq!(seen.len(), 400, "not all rows used");
    }

    #[test]
    fn dirichlet_partition_deterministic_and_skewed() {
        let labels = cyclic_labels(800, 4);
        let a = partition_dirichlet(3, &labels, 4, 8, 100, 0.1, &vec![1.0; 8]);
        let b = partition_dirichlet(3, &labels, 4, 8, 100, 0.1, &vec![1.0; 8]);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
        // alpha = 0.1: the average client's top label should dominate its
        // shard well beyond the IID share of 1/4
        let top_share: f64 = a
            .iter()
            .map(|sh| {
                let mut counts = [0usize; 4];
                for &i in &sh.indices {
                    counts[labels[i]] += 1;
                }
                *counts.iter().max().unwrap() as f64 / sh.s() as f64
            })
            .sum::<f64>()
            / a.len() as f64;
        assert!(top_share > 0.5, "mean top-label share {top_share}");
    }

    #[test]
    fn dirichlet_zero_strength_is_near_uniform() {
        // strength 0 blends fully to uniform: each client's label
        // histogram stays close to the 1/k IID share
        let labels = cyclic_labels(800, 4);
        let shards =
            partition_dirichlet(3, &labels, 4, 4, 200, 0.1, &vec![0.0; 4]);
        for sh in &shards {
            let mut counts = [0usize; 4];
            for &i in &sh.indices {
                counts[labels[i]] += 1;
            }
            let top = *counts.iter().max().unwrap() as f64 / sh.s() as f64;
            assert!(top < 0.45, "top share {top} under zero strength");
        }
    }
}
