//! Synthetic dataset generators (the paper's data substitutes, DESIGN.md §6).
//!
//! * [`linreg`] — the paper's own "synthetic dataset" for linear
//!   regression (Figures 2, 7, 8; Tables 1, 2): Gaussian features, planted
//!   linear model + observation noise.
//! * [`mixture`] — a C-class Gaussian-mixture classification set standing
//!   in for MNIST (d=784, well-separated) and CIFAR10 (lower separation =
//!   harder, more rounds), preserving the i.i.d.-across-clients setup.

use crate::data::{Dataset, Labels};
use crate::util::Rng;

/// Planted linear model: y = <w*, x> + b* + noise, x ~ N(0, I_d).
/// Returns the dataset and the planted flat parameter vector [w*, b*]
/// (note: the *ERM* optimum differs slightly; use
/// `util::linalg::linreg_optimum` for exact suboptimality curves).
pub fn linreg(rng: &mut Rng, n: usize, d: usize, noise: f64) -> (Dataset, Vec<f32>) {
    let mut w = vec![0.0f32; d + 1];
    for v in w.iter_mut() {
        *v = rng.normal_f32();
    }
    let mut x = vec![0.0f32; n * d];
    rng.fill_normal(&mut x, 1.0);
    let mut y = vec![0.0f32; n];
    for r in 0..n {
        let mut s = w[d] as f64;
        for j in 0..d {
            s += w[j] as f64 * x[r * d + j] as f64;
        }
        y[r] = (s + noise * rng.normal()) as f32;
    }
    (Dataset::new(x, Labels::Real(y), d), w)
}

/// Parameters for the Gaussian-mixture classification generator.
#[derive(Clone, Debug)]
pub struct MixtureSpec {
    pub n: usize,
    pub d: usize,
    pub classes: usize,
    /// distance scale between class means; smaller = harder (CIFAR-like)
    pub separation: f64,
    /// within-class standard deviation
    pub sigma: f64,
}

impl MixtureSpec {
    /// MNIST stand-in: 784-dim, 10 classes, comfortably separable.
    pub fn mnist_like(n: usize) -> Self {
        MixtureSpec { n, d: 784, classes: 10, separation: 2.2, sigma: 1.0 }
    }

    /// CIFAR10 stand-in: harder (lower separation). d reduced from 3072
    /// to keep artifact/runtime size laptop-scale; hardness is what
    /// matters for the Figure-4 comparison (see DESIGN.md §6).
    pub fn cifar_like(n: usize) -> Self {
        MixtureSpec { n, d: 512, classes: 10, separation: 1.1, sigma: 1.3 }
    }
}

/// C-class isotropic Gaussian mixture with random unit-ish mean directions.
pub fn mixture(rng: &mut Rng, spec: &MixtureSpec) -> Dataset {
    let MixtureSpec { n, d, classes, separation, sigma } = *spec;
    // class means: random Gaussian directions with ||mean|| = separation,
    // so the between-class distance is ~separation*sqrt(2) against
    // per-coordinate noise sigma — a tunable Bayes error
    let mut means = vec![0.0f32; classes * d];
    for c in 0..classes {
        let row = &mut means[c * d..(c + 1) * d];
        rng.fill_normal(row, 1.0);
        let norm = (row.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sqrt();
        let scale = (separation / norm) as f32;
        for v in row.iter_mut() {
            *v *= scale;
        }
    }
    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0u32; n];
    for r in 0..n {
        let c = rng.below(classes);
        y[r] = c as u32;
        let mean = &means[c * d..(c + 1) * d];
        let row = &mut x[r * d..(r + 1) * d];
        for (v, m) in row.iter_mut().zip(mean) {
            *v = m + sigma as f32 * rng.normal_f32();
        }
    }
    Dataset::new(x, Labels::Class(y, classes), d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg;

    #[test]
    fn linreg_shapes_and_determinism() {
        let (ds1, w1) = linreg(&mut Rng::new(5), 100, 8, 0.1);
        let (ds2, w2) = linreg(&mut Rng::new(5), 100, 8, 0.1);
        assert_eq!(ds1.n(), 100);
        assert_eq!(ds1.d, 8);
        assert_eq!(w1.len(), 9);
        assert_eq!(ds1.x, ds2.x);
        assert_eq!(w1, w2);
    }

    #[test]
    fn linreg_erm_optimum_near_planted() {
        let mut rng = Rng::new(7);
        let (ds, w_true) = linreg(&mut rng, 5000, 6, 0.05);
        let y = match &ds.y {
            Labels::Real(v) => v.clone(),
            _ => unreachable!(),
        };
        let w_star = linalg::linreg_optimum(&ds.x, &y, ds.n(), ds.d, 0.0);
        let err = linalg::dist2(&w_star, &w_true);
        assert!(err < 0.05, "|w* - w_true| = {err}");
    }

    #[test]
    fn mixture_shapes_and_label_range() {
        let spec = MixtureSpec { n: 300, d: 20, classes: 4, separation: 2.0, sigma: 1.0 };
        let ds = mixture(&mut Rng::new(1), &spec);
        assert_eq!(ds.n(), 300);
        assert_eq!(ds.d, 20);
        match &ds.y {
            Labels::Class(v, c) => {
                assert_eq!(*c, 4);
                assert!(v.iter().all(|&l| l < 4));
                // all classes present in 300 draws (w.h.p.)
                for cls in 0..4u32 {
                    assert!(v.contains(&cls));
                }
            }
            _ => panic!("expected class labels"),
        }
    }

    #[test]
    fn mixture_is_linearly_separable_when_far() {
        // nearest-class-mean classification should beat chance by a lot
        let spec = MixtureSpec { n: 400, d: 16, classes: 3, separation: 6.0, sigma: 0.5 };
        let mut rng = Rng::new(3);
        let ds = mixture(&mut rng, &spec);
        let (labels, c) = match &ds.y {
            Labels::Class(v, c) => (v.clone(), *c),
            _ => unreachable!(),
        };
        // estimate class means from the data itself
        let mut means = vec![0.0f64; c * ds.d];
        let mut counts = vec![0usize; c];
        for r in 0..ds.n() {
            let cls = labels[r] as usize;
            counts[cls] += 1;
            for j in 0..ds.d {
                means[cls * ds.d + j] += ds.row(r)[j] as f64;
            }
        }
        for cls in 0..c {
            for j in 0..ds.d {
                means[cls * ds.d + j] /= counts[cls].max(1) as f64;
            }
        }
        let mut correct = 0;
        for r in 0..ds.n() {
            let mut best = (f64::INFINITY, 0usize);
            for cls in 0..c {
                let dist: f64 = (0..ds.d)
                    .map(|j| {
                        let dv = ds.row(r)[j] as f64 - means[cls * ds.d + j];
                        dv * dv
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, cls);
                }
            }
            if best.1 == labels[r] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n() as f64;
        assert!(acc > 0.95, "nearest-mean accuracy {acc}");
    }

    #[test]
    fn cifar_like_is_harder_than_mnist_like() {
        let m = MixtureSpec::mnist_like(10);
        let c = MixtureSpec::cifar_like(10);
        assert!(c.separation < m.separation);
        assert!(c.sigma >= m.sigma);
    }
}
