//! Synthetic dataset generators (the paper's data substitutes, DESIGN.md §6).
//!
//! * [`linreg`] — the paper's own "synthetic dataset" for linear
//!   regression (Figures 2, 7, 8; Tables 1, 2): Gaussian features, planted
//!   linear model + observation noise.
//! * [`mixture`] — a C-class Gaussian-mixture classification set standing
//!   in for MNIST (d=784, well-separated) and CIFAR10 (lower separation =
//!   harder, more rounds), preserving the i.i.d.-across-clients setup.
//! * [`DataSpec`] — the statistical-heterogeneity grammar
//!   (`data:dirichlet:A:shift:S:corr:speed`): per-client Dirichlet
//!   label/cluster skew, per-client covariate shift, optionally graded by
//!   the speed ranking so the slow cohort is the shifted one.

use crate::data::{Dataset, Labels};
use crate::util::Rng;

/// Planted linear model: y = <w*, x> + b* + noise, x ~ N(0, I_d).
/// Returns the dataset and the planted flat parameter vector [w*, b*]
/// (note: the *ERM* optimum differs slightly; use
/// `util::linalg::linreg_optimum` for exact suboptimality curves).
pub fn linreg(rng: &mut Rng, n: usize, d: usize, noise: f64) -> (Dataset, Vec<f32>) {
    let mut w = vec![0.0f32; d + 1];
    for v in w.iter_mut() {
        *v = rng.normal_f32();
    }
    let mut x = vec![0.0f32; n * d];
    rng.fill_normal(&mut x, 1.0);
    let mut y = vec![0.0f32; n];
    for r in 0..n {
        let mut s = w[d] as f64;
        for j in 0..d {
            s += w[j] as f64 * x[r * d + j] as f64;
        }
        y[r] = (s + noise * rng.normal()) as f32;
    }
    (Dataset::new(x, Labels::Real(y), d), w)
}

/// Parameters for the Gaussian-mixture classification generator.
#[derive(Clone, Debug)]
pub struct MixtureSpec {
    pub n: usize,
    pub d: usize,
    pub classes: usize,
    /// distance scale between class means; smaller = harder (CIFAR-like)
    pub separation: f64,
    /// within-class standard deviation
    pub sigma: f64,
}

impl MixtureSpec {
    /// MNIST stand-in: 784-dim, 10 classes, comfortably separable.
    pub fn mnist_like(n: usize) -> Self {
        MixtureSpec { n, d: 784, classes: 10, separation: 2.2, sigma: 1.0 }
    }

    /// CIFAR10 stand-in: harder (lower separation). d reduced from 3072
    /// to keep artifact/runtime size laptop-scale; hardness is what
    /// matters for the Figure-4 comparison (see DESIGN.md §6).
    pub fn cifar_like(n: usize) -> Self {
        MixtureSpec { n, d: 512, classes: 10, separation: 1.1, sigma: 1.3 }
    }
}

/// C-class isotropic Gaussian mixture with random unit-ish mean directions.
pub fn mixture(rng: &mut Rng, spec: &MixtureSpec) -> Dataset {
    let MixtureSpec { n, d, classes, separation, sigma } = *spec;
    // class means: random Gaussian directions with ||mean|| = separation,
    // so the between-class distance is ~separation*sqrt(2) against
    // per-coordinate noise sigma — a tunable Bayes error
    let mut means = vec![0.0f32; classes * d];
    for c in 0..classes {
        let row = &mut means[c * d..(c + 1) * d];
        rng.fill_normal(row, 1.0);
        let norm = (row.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sqrt();
        let scale = (separation / norm) as f32;
        for v in row.iter_mut() {
            *v *= scale;
        }
    }
    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0u32; n];
    for r in 0..n {
        let c = rng.below(classes);
        y[r] = c as u32;
        let mean = &means[c * d..(c + 1) * d];
        let row = &mut x[r * d..(r + 1) * d];
        for (v, m) in row.iter_mut().zip(mean) {
            *v = m + sigma as f32 * rng.normal_f32();
        }
    }
    Dataset::new(x, Labels::Class(y, classes), d)
}

// ---------------------------------------------------------------------------
// Statistical heterogeneity: the `data:` grammar + per-client skew streams
// ---------------------------------------------------------------------------

/// Per-client RNG stream layout for the data-skew lanes. These mirror
/// `fed::population`'s 8-component per-client blocks (components 0–4 are
/// taken by speed/markov/data/round/row lanes); the skew lanes claim the
/// previously-free components 5 and 6 so the eager partitioner and the
/// lazy `LazyShards` synthesis derive bit-identical per-client skew state
/// from the same `(seed, client)` pair.
pub const DATA_STREAM_COMPONENTS: u64 = 8;
/// Component 5: Dirichlet proportions + categorical label draws.
pub const DATA_SKEW_COMPONENT: u64 = 5;
/// Component 6: the covariate-shift direction.
pub const DATA_SHIFT_COMPONENT: u64 = 6;

/// Statistical-heterogeneity scenario: how client shards deviate from the
/// IID partition. Composable, like the system grammar:
///
/// ```text
/// data:iid                          explicit IID (the default)
/// data:[dirichlet:A:][shift:S:][corr:speed]
///   dirichlet:A:   per-client label skew ~ Dirichlet(A); smaller A =
///                  more concentrated (each client sees few labels)
///   shift:S:       per-client covariate shift: x += S * u_c for a
///                  client-specific unit direction u_c
///   corr:speed     grade the skew by speed rank — the fastest client is
///                  IID, the slowest fully skewed (the paper-adjacent
///                  "slow-and-shifted cohort" scenario)
/// ```
///
/// ```
/// use flanp::data::synth::DataSpec;
/// let d = DataSpec::parse("data:dirichlet:0.1:shift:3:corr:speed").unwrap();
/// assert_eq!(d.dirichlet, Some(0.1));
/// assert_eq!(d.shift, Some(3.0));
/// assert!(d.corr_speed);
/// assert_eq!(DataSpec::parse(&d.spec()).unwrap(), d);
/// assert!(DataSpec::parse("data:iid").unwrap().is_iid());
/// assert!(DataSpec::parse("dirichlet:0.1").is_err()); // missing data:
/// assert!(DataSpec::parse("data:corr:speed").is_err()); // corr alone
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct DataSpec {
    /// Dirichlet concentration for per-client label/cluster skew.
    pub dirichlet: Option<f64>,
    /// Per-client covariate-shift magnitude.
    pub shift: Option<f64>,
    /// Grade skew strength by speed rank (slowest = fully skewed).
    pub corr_speed: bool,
}

impl Default for DataSpec {
    fn default() -> Self {
        DataSpec::iid()
    }
}

impl DataSpec {
    /// The default: IID shards, no shift — byte-identical to the seed.
    pub fn iid() -> Self {
        DataSpec { dirichlet: None, shift: None, corr_speed: false }
    }

    pub fn is_iid(&self) -> bool {
        self.dirichlet.is_none() && self.shift.is_none()
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        let rest = s
            .strip_prefix("data:")
            .ok_or_else(|| format!("data spec '{s}' must start with 'data:'"))?;
        if rest == "iid" {
            return Ok(DataSpec::iid());
        }
        let mut spec = DataSpec::iid();
        // trailing colons are legal (`data:dirichlet:0.1:` — the grammar
        // is prefix-shaped like the system grammar, with nothing after)
        let toks: Vec<&str> = rest.split(':').filter(|t| !t.is_empty()).collect();
        if toks.is_empty() {
            return Err(format!(
                "empty data spec '{s}' (use data:iid for the explicit default)"
            ));
        }
        let num = |what: &str, tok: &str| -> Result<f64, String> {
            let v: f64 = tok
                .parse()
                .map_err(|_| format!("bad {what} '{tok}' in data spec '{s}'"))?;
            if !(v > 0.0) || !v.is_finite() {
                return Err(format!(
                    "{what} must be positive and finite in data spec '{s}'"
                ));
            }
            Ok(v)
        };
        let mut i = 0;
        while i < toks.len() {
            match toks[i] {
                "dirichlet" if spec.dirichlet.is_none() => {
                    let tok = toks.get(i + 1).ok_or_else(|| {
                        format!("dirichlet needs an alpha in data spec '{s}'")
                    })?;
                    spec.dirichlet = Some(num("alpha", tok)?);
                    i += 2;
                }
                "shift" if spec.shift.is_none() => {
                    let tok = toks.get(i + 1).ok_or_else(|| {
                        format!("shift needs a magnitude in data spec '{s}'")
                    })?;
                    spec.shift = Some(num("shift", tok)?);
                    i += 2;
                }
                "corr" if !spec.corr_speed => {
                    match toks.get(i + 1) {
                        Some(&"speed") => spec.corr_speed = true,
                        other => {
                            return Err(format!(
                                "corr supports only 'speed', got {other:?} \
                                 in data spec '{s}'"
                            ))
                        }
                    }
                    i += 2;
                }
                other => {
                    return Err(format!(
                        "unknown or repeated data segment '{other}' in \
                         data spec '{s}' (expected \
                         data:[dirichlet:A:][shift:S:][corr:speed] | data:iid)"
                    ))
                }
            }
        }
        if spec.corr_speed && spec.is_iid() {
            return Err(format!(
                "corr:speed without dirichlet: or shift: has nothing to \
                 correlate in data spec '{s}'"
            ));
        }
        Ok(spec)
    }

    /// Canonical spec string; `parse(spec()) == self`.
    pub fn spec(&self) -> String {
        if self.is_iid() {
            return "data:iid".into();
        }
        let mut out = String::from("data");
        if let Some(a) = self.dirichlet {
            out.push_str(&format!(":dirichlet:{a}"));
        }
        if let Some(sh) = self.shift {
            out.push_str(&format!(":shift:{sh}"));
        }
        if self.corr_speed {
            out.push_str(":corr:speed");
        }
        out
    }
}

/// One Gamma(alpha, 1) sample (Marsaglia–Tsang squeeze; alpha < 1 via the
/// Gamma(alpha+1) * U^(1/alpha) boost). Building block for
/// [`dirichlet_proportions`].
pub fn gamma(rng: &mut Rng, alpha: f64) -> f64 {
    if alpha < 1.0 {
        let u = rng.next_f64();
        return gamma(rng, alpha + 1.0) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v = v * v * v;
        let u = rng.next_f64();
        if u < 1.0 - 0.0331 * x * x * x * x {
            return d * v;
        }
        if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// Client `client`'s Dirichlet(alpha) label proportions over `k` classes,
/// drawn from the continuation of `rng` (normalized Gamma draws). The
/// all-zero corner (possible underflow at tiny alpha) falls back to a
/// point mass on the client's first Gamma argmax — still a valid simplex.
pub fn dirichlet_proportions_with(rng: &mut Rng, alpha: f64, k: usize) -> Vec<f64> {
    let mut p: Vec<f64> = (0..k).map(|_| gamma(rng, alpha)).collect();
    let sum: f64 = p.iter().sum();
    if sum > 0.0 && sum.is_finite() {
        for v in &mut p {
            *v /= sum;
        }
    } else {
        let top = p
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        p.iter_mut().for_each(|v| *v = 0.0);
        p[top] = 1.0;
    }
    p
}

/// Pure per-client Dirichlet proportions: deterministic in
/// `(seed, client)`, independent of everything else (own stream
/// [`DATA_SKEW_COMPONENT`]). The eager partitioner
/// (`shard::partition_dirichlet`) and the lazy population synthesizer
/// (`fed::population::LazyShards`) both call THIS function, which is what
/// makes their per-client skew state bit-identical across regimes.
pub fn dirichlet_proportions(seed: u64, client: usize, alpha: f64, k: usize) -> Vec<f64> {
    let mut rng = skew_stream(seed, client);
    dirichlet_proportions_with(&mut rng, alpha, k)
}

/// The client's skew stream (proportions + its categorical label draws).
pub fn skew_stream(seed: u64, client: usize) -> Rng {
    Rng::with_stream(
        seed,
        client as u64 * DATA_STREAM_COMPONENTS + DATA_SKEW_COMPONENT,
    )
}

/// Client `client`'s covariate-shift vector: a fixed direction of norm
/// `mag`, deterministic in `(seed, client)` (own stream
/// [`DATA_SHIFT_COMPONENT`]); shared verbatim by the eager and lazy paths.
pub fn shift_vector(seed: u64, client: usize, d: usize, mag: f64) -> Vec<f32> {
    let mut rng = Rng::with_stream(
        seed,
        client as u64 * DATA_STREAM_COMPONENTS + DATA_SHIFT_COMPONENT,
    );
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 1.0);
    let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    if norm > 0.0 {
        let scale = (mag / norm) as f32;
        for x in &mut v {
            *x *= scale;
        }
    }
    v
}

/// Blend proportions toward the uniform simplex: `strength` 1 keeps the
/// full skew, 0 is exactly uniform — the `corr:speed` grading, where a
/// client's strength is its speed percentile (fastest 0, slowest 1).
pub fn blend_to_uniform(p: &mut [f64], strength: f64) {
    let k = p.len().max(1) as f64;
    let s = strength.clamp(0.0, 1.0);
    for v in p.iter_mut() {
        *v = s * *v + (1.0 - s) / k;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg;

    #[test]
    fn linreg_shapes_and_determinism() {
        let (ds1, w1) = linreg(&mut Rng::new(5), 100, 8, 0.1);
        let (ds2, w2) = linreg(&mut Rng::new(5), 100, 8, 0.1);
        assert_eq!(ds1.n(), 100);
        assert_eq!(ds1.d, 8);
        assert_eq!(w1.len(), 9);
        assert_eq!(ds1.x, ds2.x);
        assert_eq!(w1, w2);
    }

    #[test]
    fn linreg_erm_optimum_near_planted() {
        let mut rng = Rng::new(7);
        let (ds, w_true) = linreg(&mut rng, 5000, 6, 0.05);
        let y = match &ds.y {
            Labels::Real(v) => v.clone(),
            _ => unreachable!(),
        };
        let w_star = linalg::linreg_optimum(&ds.x, &y, ds.n(), ds.d, 0.0);
        let err = linalg::dist2(&w_star, &w_true);
        assert!(err < 0.05, "|w* - w_true| = {err}");
    }

    #[test]
    fn mixture_shapes_and_label_range() {
        let spec = MixtureSpec { n: 300, d: 20, classes: 4, separation: 2.0, sigma: 1.0 };
        let ds = mixture(&mut Rng::new(1), &spec);
        assert_eq!(ds.n(), 300);
        assert_eq!(ds.d, 20);
        match &ds.y {
            Labels::Class(v, c) => {
                assert_eq!(*c, 4);
                assert!(v.iter().all(|&l| l < 4));
                // all classes present in 300 draws (w.h.p.)
                for cls in 0..4u32 {
                    assert!(v.contains(&cls));
                }
            }
            _ => panic!("expected class labels"),
        }
    }

    #[test]
    fn mixture_is_linearly_separable_when_far() {
        // nearest-class-mean classification should beat chance by a lot
        let spec = MixtureSpec { n: 400, d: 16, classes: 3, separation: 6.0, sigma: 0.5 };
        let mut rng = Rng::new(3);
        let ds = mixture(&mut rng, &spec);
        let (labels, c) = match &ds.y {
            Labels::Class(v, c) => (v.clone(), *c),
            _ => unreachable!(),
        };
        // estimate class means from the data itself
        let mut means = vec![0.0f64; c * ds.d];
        let mut counts = vec![0usize; c];
        for r in 0..ds.n() {
            let cls = labels[r] as usize;
            counts[cls] += 1;
            for j in 0..ds.d {
                means[cls * ds.d + j] += ds.row(r)[j] as f64;
            }
        }
        for cls in 0..c {
            for j in 0..ds.d {
                means[cls * ds.d + j] /= counts[cls].max(1) as f64;
            }
        }
        let mut correct = 0;
        for r in 0..ds.n() {
            let mut best = (f64::INFINITY, 0usize);
            for cls in 0..c {
                let dist: f64 = (0..ds.d)
                    .map(|j| {
                        let dv = ds.row(r)[j] as f64 - means[cls * ds.d + j];
                        dv * dv
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, cls);
                }
            }
            if best.1 == labels[r] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n() as f64;
        assert!(acc > 0.95, "nearest-mean accuracy {acc}");
    }

    #[test]
    fn cifar_like_is_harder_than_mnist_like() {
        let m = MixtureSpec::mnist_like(10);
        let c = MixtureSpec::cifar_like(10);
        assert!(c.separation < m.separation);
        assert!(c.sigma >= m.sigma);
    }

    #[test]
    fn data_spec_roundtrip_and_rejects() {
        for spec in [
            "data:iid",
            "data:dirichlet:0.1",
            "data:shift:3",
            "data:dirichlet:0.1:shift:3",
            "data:dirichlet:0.1:shift:3:corr:speed",
            "data:shift:0.5:corr:speed",
        ] {
            let d = DataSpec::parse(spec).unwrap();
            assert_eq!(d.spec(), spec, "canonical form drifted");
            assert_eq!(DataSpec::parse(&d.spec()).unwrap(), d);
        }
        // trailing colon (prefix spelling) parses to the same spec
        assert_eq!(
            DataSpec::parse("data:dirichlet:0.1:").unwrap(),
            DataSpec::parse("data:dirichlet:0.1").unwrap()
        );
        for bad in [
            "dirichlet:0.1",
            "data:",
            "data:corr:speed",
            "data:dirichlet:-1",
            "data:dirichlet:0",
            "data:dirichlet:x",
            "data:shift:-2",
            "data:corr:rank",
            "data:dirichlet:0.1:dirichlet:0.2",
            "data:warp:9",
        ] {
            let e = DataSpec::parse(bad).unwrap_err();
            assert!(e.contains(bad), "error '{e}' does not name '{bad}'");
        }
    }

    #[test]
    fn gamma_mean_matches_alpha() {
        // E[Gamma(alpha, 1)] = alpha, both below and above the alpha=1
        // boost boundary
        for alpha in [0.3, 1.0, 4.0] {
            let mut rng = Rng::new(11);
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| gamma(&mut rng, alpha)).sum::<f64>() / n as f64;
            assert!(
                (mean - alpha).abs() < 0.05 * alpha.max(1.0),
                "alpha {alpha}: mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_proportions_simplex_and_deterministic() {
        for client in [0usize, 1, 17] {
            let p = dirichlet_proportions(9, client, 0.3, 5);
            assert_eq!(p, dirichlet_proportions(9, client, 0.3, 5));
            assert_eq!(p.len(), 5);
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)), "{p:?}");
            let sum: f64 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
        }
        // different clients draw different proportions
        assert_ne!(
            dirichlet_proportions(9, 0, 0.3, 5),
            dirichlet_proportions(9, 1, 0.3, 5)
        );
    }

    #[test]
    fn shift_vector_norm_and_determinism() {
        let v = shift_vector(5, 3, 16, 2.5);
        assert_eq!(v, shift_vector(5, 3, 16, 2.5));
        let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm - 2.5).abs() < 1e-4, "norm {norm}");
        assert_ne!(v, shift_vector(5, 4, 16, 2.5));
    }

    #[test]
    fn blend_to_uniform_endpoints() {
        let base = vec![0.7, 0.2, 0.1, 0.0];
        let mut p = base.clone();
        blend_to_uniform(&mut p, 1.0);
        assert_eq!(p, base);
        let mut p = base.clone();
        blend_to_uniform(&mut p, 0.0);
        assert!(p.iter().all(|&v| (v - 0.25).abs() < 1e-12));
        let mut p = base;
        blend_to_uniform(&mut p, 0.5);
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }
}
