//! Dataset substrate: synthetic data generators + federated sharding.
//!
//! The paper evaluates on MNIST, CIFAR10 and a synthetic regression set.
//! Raw MNIST/CIFAR are not available in this environment, so we build
//! statistically equivalent *generators* (DESIGN.md §6): every claim the
//! paper makes concerns time-to-statistical-accuracy under i.i.d.
//! across-client data, which any fixed, learnable distribution exercises.
//! The `data:` grammar ([`synth::DataSpec`]) breaks the i.i.d.
//! assumption on demand — Dirichlet label skew, per-client covariate
//! shift, optionally correlated with the speed ranking — to exercise the
//! statistical half of the paper's interplay (docs/scenarios.md §9).

pub mod dataset;
pub mod shard;
pub mod synth;

pub use dataset::{Dataset, Labels};
pub use shard::Shard;
pub use synth::DataSpec;
