//! Offline stand-in for the `anyhow` crate: the crates.io registry is
//! unreachable in the build image, so this vendored crate implements the
//! exact subset of the anyhow 1.x API the workspace uses — `Error`,
//! `Result`, the `Context` extension trait for `Result` and `Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream where it matters to callers:
//!   * `{e}` prints the outermost message, `{e:#}` the full context chain
//!     joined by ": " (upstream's alternate formatting);
//!   * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!     `Error`, capturing its source chain;
//!   * `Error` itself deliberately does NOT implement `std::error::Error`
//!     (same as upstream), which is what makes the blanket `From` legal.

use std::fmt;

/// A context-chained error. `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` lowers to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `Context::context` uses).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_vs_alternate() {
        let e = Error::from(io_err()).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: gone");
        assert_eq!(format!("{e:?}"), "outer: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().root_cause(), "gone");
    }

    #[test]
    fn option_and_result_context() {
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let r: std::result::Result<u8, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("ctx {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx 1: gone");
    }

    #[test]
    fn macros() {
        fn b() -> Result<u8> {
            bail!("boom {}", 2);
        }
        assert_eq!(b().unwrap_err().to_string(), "boom 2");
        fn e(x: u8) -> Result<u8> {
            ensure!(x > 3, "x was {x}");
            Ok(x)
        }
        assert_eq!(e(1).unwrap_err().to_string(), "x was 1");
        assert_eq!(e(5).unwrap(), 5);
        let from_string = anyhow!(String::from("s"));
        assert_eq!(from_string.to_string(), "s");
        let fmt = anyhow!("v = {}", 7);
        assert_eq!(fmt.to_string(), "v = 7");
    }
}
