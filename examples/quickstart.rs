//! Quickstart: the smallest end-to-end FLANP run.
//!
//! Builds a synthetic linear-regression federation of 16 heterogeneous
//! clients, loads the AOT-compiled JAX/Pallas artifacts through the PJRT
//! runtime, and runs the straggler-resilient FLANP algorithm against the
//! non-adaptive FedGATE benchmark.
//!
//!   make artifacts && cargo run --release --example quickstart

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::setup;

fn main() -> anyhow::Result<()> {
    let artifacts = setup::default_artifacts_dir();
    println!("loading artifacts from {artifacts:?}");

    // Try the real PJRT path; fall back to the pure-Rust engine when
    // artifacts have not been built yet.
    let engine = setup::build_engine("hlo", "linreg_d25", &artifacts)
        .or_else(|e| {
            eprintln!("(hlo engine unavailable: {e:#}; using native)");
            setup::build_engine("native", "linreg_d25", &artifacts)
        })?;

    let mut results = Vec::new();
    for solver in [SolverKind::Flanp, SolverKind::FedGate] {
        let mut cfg = ExperimentConfig::new(solver, "linreg_d25", 16, 50);
        cfg.tau = 10;
        cfg.eta = 0.05;
        cfg.n0 = 2;
        cfg.mu = 0.5;
        cfg.c_stat = 0.05;
        cfg.seed = 7;

        let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
        let trace = run_solver(engine.as_ref(), &mut fleet, &cfg)?;
        let last = trace.last().unwrap();
        println!(
            "{:<8} reached statistical accuracy in {:>4} rounds, \
             simulated time {:>10.1}  (final ||w-w*|| = {:.4})",
            trace.algo, last.round, trace.total_time, last.dist_to_opt
        );
        results.push(trace.total_time);
    }
    println!(
        "FLANP speedup over FedGATE: {:.2}x wall-clock",
        results[1] / results[0]
    );
    Ok(())
}
