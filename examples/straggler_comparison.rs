//! Straggler-resilience comparison across speed distributions.
//!
//! Runs FLANP and every benchmark under three heterogeneity regimes —
//! the paper's uniform [50, 500], i.i.d. exponential, and homogeneous —
//! and prints the wall-clock each algorithm needs to reach the same
//! statistical accuracy. Reproduces the qualitative claims of Sections
//! 4.2 and 5.2: FLANP's gain grows with heterogeneity (largest under
//! exponential speeds). With identical clients the advantage is the
//! asymptotic log(Ns)/log(N) sample-adaptivity factor, which needs much
//! larger N*s than this demo to dominate — expect rough parity there.
//!
//!   cargo run --release --example straggler_comparison

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::SpeedModel;
use flanp::setup;

fn main() -> anyhow::Result<()> {
    let artifacts = setup::default_artifacts_dir();
    let engine = setup::build_engine("native", "linreg_d25", &artifacts)?;

    let regimes = [
        ("uniform[50,500)", SpeedModel::paper_uniform()),
        ("exponential", SpeedModel::Exponential { lambda: 1.0 / 275.0 }),
        ("homogeneous", SpeedModel::Homogeneous { t: 275.0 }),
    ];
    let solvers = [
        SolverKind::Flanp,
        SolverKind::FedGate,
        SolverKind::FedAvg,
        SolverKind::FedNova,
        SolverKind::FedProx,
    ];

    for (label, speed) in regimes {
        println!("== speed regime: {label} ==");
        let mut flanp_time = None;
        for solver in solvers.clone() {
            let mut cfg =
                ExperimentConfig::new(solver.clone(), "linreg_d25", 32, 100);
            cfg.tau = 10;
            cfg.eta = 0.05;
            cfg.n0 = 2;
            cfg.mu = 0.5;
            cfg.c_stat = 0.5;
            cfg.system = speed.clone().into();
            cfg.seed = 11;
            cfg.max_rounds = 2000;
            cfg.eval_every = 5;
            let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
            let trace = run_solver(engine.as_ref(), &mut fleet, &cfg)?;
            let last = trace.last().unwrap();
            if solver == SolverKind::Flanp {
                flanp_time = Some(trace.total_time);
            }
            let vs = flanp_time
                .map(|f| format!("{:>5.2}x flanp", trace.total_time / f))
                .unwrap_or_default();
            println!(
                "  {:<14} rounds={:<5} sim-time={:<12.1} ||w-w*||={:<8.4} \
                 finished={} {vs}",
                trace.algo, last.round, trace.total_time, last.dist_to_opt,
                trace.finished,
            );
        }
    }
    Ok(())
}
