//! Figure-9 scenario: FLANP without knowing mu, c, V_ns.
//!
//! The oracle FLANP needs the statistical-accuracy constants to decide
//! when to double the participant set. The practical variant monitors
//! the global gradient norm and successively halves its own threshold.
//! This example runs both (plus FedGATE) on the same federation and
//! shows the heuristic tracks the oracle closely.
//!
//!   cargo run --release --example heuristic_tuning

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::setup;

fn main() -> anyhow::Result<()> {
    let artifacts = setup::default_artifacts_dir();
    let engine = setup::build_engine("native", "logreg_d784_c10", &artifacts)?;

    println!("logistic regression, MNIST-like, N=20, s=500");
    let mut rows = Vec::new();
    for solver in [
        SolverKind::Flanp,
        SolverKind::FlanpHeuristic,
        SolverKind::FedGate,
    ] {
        let mut cfg =
            ExperimentConfig::new(solver, "logreg_d784_c10", 20, 500);
        cfg.tau = 10;
        cfg.eta = 0.05;
        cfg.n0 = 2;
        cfg.mu = 0.01;
        cfg.c_stat = 40.0;
        cfg.seed = 5;
        cfg.max_rounds = 80;
        cfg.eval_rows = 1000;
        let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.0, 0.0)?;
        let trace = run_solver(engine.as_ref(), &mut fleet, &cfg)?;
        let last = trace.last().unwrap();
        println!(
            "  {:<16} stages={:<2} rounds={:<4} sim-time={:<12.1} \
             loss={:<9.5} acc={:.3}",
            trace.algo,
            trace.stage_transitions.len(),
            last.round,
            trace.total_time,
            last.loss_full,
            last.accuracy
        );
        rows.push((trace.algo.clone(), last.loss_full, trace.total_time));
    }
    let (oracle, heur) = (rows[0].1, rows[1].1);
    println!(
        "heuristic final loss is {:.1}% of oracle's — {}",
        100.0 * heur / oracle,
        if heur <= oracle * 2.0 {
            "tracks the oracle (Figure 9's claim)"
        } else {
            "diverges"
        }
    );
    Ok(())
}
