//! Deadline-driven semi-synchronous aggregation + FedBuff buffered async.
//!
//! The paper's solvers aggregate synchronously: every round waits for
//! the slowest cohort member. This demo runs the aggregation-policy
//! layer against that baseline under a Markov fast/slow straggler
//! scenario (clients intermittently slow down 4x):
//!
//!   * `flanp-sync`    — FLANP, synchronous rounds (the paper);
//!   * `flanp-q80`     — FLANP with a quantile deadline: each round the
//!     server waits only `tau * (0.8-quantile of the cohort's estimated
//!     speeds)` and aggregates whatever arrived;
//!   * `flanp-adapt`   — FLANP with a self-tuning deadline targeting an
//!     80% arrival fraction;
//!   * `fedbuff`       — buffered asynchronous aggregation: no rounds at
//!     all; the server applies a staleness-weighted average whenever 8
//!     uploads fill its buffer.
//!
//! Every run stops at the same statistical accuracy, so the simulated
//! wall-clock times are directly comparable. Expect the deadline
//! variants to beat sync (straggler rounds charge the deadline, not the
//! straggler) and to report nonzero `missed` counts — the clients whose
//! updates were cut. See `docs/scenarios.md` for the full playbook.
//!
//!   cargo run --release --example deadline_async

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::{DeadlinePolicy, SystemModel};
use flanp::setup;

fn main() -> anyhow::Result<()> {
    let artifacts = setup::default_artifacts_dir();
    let engine = setup::build_engine("native", "linreg_d25", &artifacts)?;
    let system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500")
        .map_err(anyhow::Error::msg)?;

    println!("== markov 4x stragglers: synchronous vs deadline vs async ==");
    let mut sync_time = None;
    for (name, solver, deadline) in [
        ("flanp-sync", SolverKind::Flanp, DeadlinePolicy::Sync),
        (
            "flanp-q80",
            SolverKind::Flanp,
            DeadlinePolicy::Quantile { q: 0.8 },
        ),
        (
            "flanp-adapt",
            SolverKind::Flanp,
            DeadlinePolicy::Adaptive { target: 0.8 },
        ),
        ("fedbuff", SolverKind::FedBuff { k: 8 }, DeadlinePolicy::Sync),
    ] {
        let mut cfg = ExperimentConfig::new(solver, "linreg_d25", 32, 100);
        cfg.tau = 10;
        cfg.eta = 0.05;
        cfg.n0 = 2;
        cfg.mu = 0.5;
        cfg.c_stat = 0.5;
        cfg.system = system.clone();
        cfg.deadline = deadline;
        cfg.seed = 17;
        cfg.max_rounds = if name == "fedbuff" { 20_000 } else { 3000 };
        cfg.eval_every = 5;
        cfg.eval_rows = 500;

        let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
        let trace = run_solver(engine.as_ref(), &mut fleet, &cfg)?;
        let last = trace.last().unwrap();
        let missed: usize = trace.rounds.iter().map(|r| r.missed).sum();
        if name == "flanp-sync" {
            sync_time = Some(trace.total_time);
        }
        let vs = sync_time
            .map(|t| format!("{:>5.2}x vs sync", t / trace.total_time))
            .unwrap_or_default();
        println!(
            "  {name:<12} rounds={:<6} sim-time={:<12.1} ||w-w*||={:<8.4} \
             missed={missed:<5} finished={} {vs}",
            last.round, trace.total_time, last.dist_to_opt, trace.finished,
        );
    }
    println!(
        "\nA straggler round charges min(deadline, slowest): the deadline \
         variants trade a few discarded updates for never paying the 4x \
         straggler tax; FedBuff removes rounds entirely and advances the \
         clock only to each buffer-flush time."
    );
    Ok(())
}
