//! TiFL-style tier scheduling (`fed::tiers`): cached latency tiers vs
//! re-ranking individuals every round.
//!
//! Re-ranking the active prefix from live estimates every round tracks
//! drift perfectly — and pays a scheduling event every single round.
//! TiFL's observation is that caching tier membership and re-tiering
//! only when an estimate drifts past a hysteresis band keeps nearly the
//! same wall-clock at a tiny fraction of the scheduling churn. This demo
//! runs FLANP under Markov fast/slow drift with four ranking cadences —
//! cached tiers, per-round individual re-ranking, stage-boundary
//! re-ranking, oracle ranking — plus the credit-scheduled `tifl` solver,
//! and prints each run's simulated wall-clock next to the re-rank /
//! re-tier events it paid.
//!
//!   cargo run --release --example tiered_selection

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::{SystemModel, TierPolicy};
use flanp::setup;

fn main() -> anyhow::Result<()> {
    let artifacts = setup::default_artifacts_dir();
    let engine = setup::build_engine("native", "linreg_d25", &artifacts)?;
    let system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500")
        .map_err(anyhow::Error::msg)?;
    let policy = TierPolicy::parse("tiers:4").map_err(anyhow::Error::msg)?;

    println!("== FLANP ranking cadences under {} ==", system.spec());
    // (label, solver, tier policy, per-round re-rank, estimate ranking)
    let variants: [(&str, SolverKind, bool, bool, bool); 5] = [
        ("tiered (cached)", SolverKind::Flanp, true, false, true),
        ("per-round rerank", SolverKind::Flanp, false, true, true),
        ("stage rerank", SolverKind::Flanp, false, false, true),
        ("oracle ranking", SolverKind::Flanp, false, false, false),
        ("tifl solver", SolverKind::Tifl, true, false, true),
    ];
    for (label, solver, tiered, perround, estimated) in variants {
        let is_tifl = solver == SolverKind::Tifl;
        let mut cfg = ExperimentConfig::new(solver, "linreg_d25", 32, 100);
        cfg.tau = 10;
        cfg.eta = 0.05;
        cfg.n0 = 2;
        cfg.mu = 0.5;
        cfg.c_stat = 0.5;
        cfg.system = system.clone();
        cfg.tiers = if tiered { Some(policy.clone()) } else { None };
        cfg.rerank_per_round = perround;
        cfg.estimate_speeds = estimated;
        cfg.seed = 17;
        // tifl trains one tier per round: cheap rounds, larger budget
        cfg.max_rounds = if is_tifl { 12_000 } else { 3000 };
        cfg.eval_every = 5;
        cfg.eval_rows = 500;

        let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
        let trace = run_solver(engine.as_ref(), &mut fleet, &cfg)?;
        let last = trace.last().unwrap();
        println!(
            "  {label:<17} rounds={:<5} sim-time={:<12.1} reranks={:<5} \
             ||w-w*||={:<8.4} finished={}",
            last.round,
            trace.total_time,
            trace.total_reranks(),
            last.dist_to_opt,
            trace.finished,
        );
    }
    println!(
        "\nThe cached-tier run tracks the per-round re-ranker's wall-clock \
         while re-tiering only when the 4x Markov drift genuinely pushes a \
         client past its hysteresis band; the tifl solver goes further and \
         schedules one whole tier per round by fairness credits, so its \
         rounds never wait for a straggler outside the tier."
    );
    Ok(())
}
