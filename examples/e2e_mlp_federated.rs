//! End-to-end validation driver (the DESIGN.md §5 E2E workload):
//! federated training of the paper's 2-hidden-layer MLP (128, 64 — ~109k
//! parameters) on a 10-class synthetic MNIST-like corpus across 20
//! heterogeneous clients, for a few hundred communication rounds,
//! logging the full loss/accuracy curve. All three layers compose here:
//! Rust coordinator -> PJRT runtime -> HLO lowered from JAX -> Pallas
//! matmul/fused-update kernels.
//!
//!   make artifacts && cargo run --release --example e2e_mlp_federated
//!     [-- --rounds 300 --engine hlo|native --csv out.csv]
//!
//! The run recorded in EXPERIMENTS.md §E2E used the default arguments.

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::setup;
use flanp::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let mut args =
        Args::from_env(&[]).map_err(|e| anyhow::anyhow!(e))?;
    let rounds = args.flag_usize("rounds", 300).map_err(|e| anyhow::anyhow!(e))?;
    let engine_kind = args.flag_str("engine", "hlo");
    let csv = args.flag_opt("csv");
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let artifacts = setup::default_artifacts_dir();
    let engine = setup::build_engine(&engine_kind, "mlp_d784_c10_h128_h64", &artifacts)?;
    println!(
        "e2e: federated MLP (d=784 -> 128 -> 64 -> 10, {} params) on {} engine",
        engine.meta().param_count,
        engine_kind,
    );

    let mut cfg = ExperimentConfig::new(
        SolverKind::Flanp,
        "mlp_d784_c10_h128_h64",
        20,   // N clients
        500,  // s samples per client (10k total)
    );
    cfg.eta = 0.05;
    cfg.gamma = 1.0;
    cfg.tau = 10;
    cfg.n0 = 2;
    cfg.mu = 0.01;
    cfg.c_stat = 2000.0;
    cfg.seed = 42;
    cfg.max_rounds = rounds;
    cfg.eval_rows = 1000;

    let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.0, 0.0)?;
    let t0 = std::time::Instant::now();
    let trace = run_solver(engine.as_ref(), &mut fleet, &cfg)?;
    let wall = t0.elapsed();

    println!("round  stage  n   sim-time      loss      acc");
    for r in trace.rounds.iter().step_by((trace.rounds.len() / 20).max(1)) {
        println!(
            "{:>5}  {:>5}  {:>3} {:>10.0}  {:>8.4}  {:>6.3}",
            r.round, r.stage, r.participants, r.time, r.loss_full, r.accuracy
        );
    }
    let last = trace.last().unwrap();
    println!(
        "final: rounds={} stages={} sim_time={:.0} loss={:.4} acc={:.3} \
         finished={} [{wall:.2?} real]",
        last.round,
        trace.stage_transitions.len(),
        trace.total_time,
        last.loss_full,
        last.accuracy,
        trace.finished,
    );
    if let Some(p) = csv {
        trace.write_csv(Path::new(&p))?;
        println!("trace written to {p}");
    }
    // validation gate: the default 300-round run must at least halve the
    // loss; short probe runs must still show clear descent
    let drop = last.loss_full / trace.rounds[0].loss_full;
    let gate = if rounds >= 200 { 0.5 } else { 0.9 };
    anyhow::ensure!(
        drop < gate,
        "training reduced the loss only to {:.2}x of initial (gate {gate})",
        drop
    );
    Ok(())
}
