//! Time-varying system heterogeneity: the scenarios `fed::system` opens
//! beyond the paper's static speed draws.
//!
//! The seed sorted clients by a single oracle draw; FLANP now re-ranks
//! its fastest-prefix at every stage boundary from TiFL-style online
//! EWMA estimates of observed round times. This demo runs FLANP (with
//! and without estimation) against full-participation FedGATE under
//! four scenarios — static, per-round log-normal jitter, two-state
//! Markov fast/slow drift, and Markov drift with 5% round dropouts —
//! and prints the simulated wall-clock each needs to reach the same
//! statistical accuracy, plus the dropout totals the event-driven clock
//! recorded.
//!
//!   cargo run --release --example time_varying_speeds

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::SystemModel;
use flanp::setup;

fn main() -> anyhow::Result<()> {
    let artifacts = setup::default_artifacts_dir();
    let engine = setup::build_engine("native", "linreg_d25", &artifacts)?;

    let scenarios = [
        ("static (paper)", "uniform:50:500"),
        ("jitter 30%", "jitter:0.3:uniform:50:500"),
        ("markov 4x drift", "markov:4:0.1:0.5:uniform:50:500"),
        ("drift + dropout", "drop:0.05:markov:4:0.1:0.5:uniform:50:500"),
    ];

    for (label, spec) in scenarios {
        let system = SystemModel::parse(spec).map_err(anyhow::Error::msg)?;
        println!("== scenario: {label}  ({spec}) ==");
        let mut fedgate_time = None;
        for (name, solver, estimate) in [
            ("fedgate", SolverKind::FedGate, true),
            ("flanp", SolverKind::Flanp, true),
            ("flanp-oracle", SolverKind::Flanp, false),
        ] {
            let mut cfg = ExperimentConfig::new(solver, "linreg_d25", 32, 100);
            cfg.tau = 10;
            cfg.eta = 0.05;
            cfg.n0 = 2;
            cfg.mu = 0.5;
            cfg.c_stat = 0.5;
            cfg.system = system.clone();
            cfg.estimate_speeds = estimate;
            cfg.seed = 17;
            cfg.max_rounds = 3000;
            cfg.eval_every = 5;
            cfg.eval_rows = 500;

            let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
            let trace = run_solver(engine.as_ref(), &mut fleet, &cfg)?;
            let last = trace.last().unwrap();
            let dropped: usize = trace.rounds.iter().map(|r| r.dropped).sum();
            if name == "fedgate" {
                fedgate_time = Some(trace.total_time);
            }
            let vs = fedgate_time
                .map(|t| format!("{:>5.2}x fedgate", trace.total_time / t))
                .unwrap_or_default();
            println!(
                "  {name:<13} rounds={:<5} sim-time={:<12.1} ||w-w*||={:<8.4} \
                 dropped={dropped:<4} finished={} {vs}",
                last.round, trace.total_time, last.dist_to_opt, trace.finished,
            );
        }
    }
    println!(
        "\nFLANP's advantage persists under drift because the online \
         estimator keeps the active prefix aligned with the CURRENTLY \
         fastest clients; `flanp-oracle` ranks by the stale initial draw."
    );
    Ok(())
}
