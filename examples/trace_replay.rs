//! Record → replay: every scenario is a replayable fixture.
//!
//! Runs FLANP once under Markov fast/slow drift while recording the
//! realized per-client, per-round latencies and availability
//! (`fed::traces::TraceRecorder`), writes the trace CSV, replays it
//! through the `trace:FILE` scenario spec, and prints a field-by-field
//! diff of the two runs. The diff is all zeros: record → replay is
//! bit-identical in wall-clock, losses and every trace column, so a
//! measured trace from a real cluster slots in exactly where the
//! synthetic scenarios do.
//!
//!   cargo run --release --example trace_replay

use flanp::coordinator::{run_solver, ExperimentConfig, SolverKind};
use flanp::fed::SystemModel;
use flanp::setup;

fn main() -> anyhow::Result<()> {
    let artifacts = setup::default_artifacts_dir();
    let engine = setup::build_engine("native", "linreg_d25", &artifacts)?;

    let mut cfg = ExperimentConfig::new(SolverKind::Flanp, "linreg_d25", 16, 50);
    cfg.tau = 10;
    cfg.eta = 0.05;
    cfg.n0 = 2;
    cfg.mu = 0.5;
    cfg.c_stat = 0.5;
    cfg.system = SystemModel::parse("markov:4:0.1:0.5:uniform:50:500")
        .map_err(anyhow::Error::msg)?;
    cfg.seed = 11;
    cfg.max_rounds = 2000;
    cfg.eval_every = 5;
    cfg.eval_rows = 500;
    cfg.record_trace = true;

    println!("== record: FLANP under {} ==", cfg.system.spec());
    let mut fleet = setup::build_fleet(engine.meta(), &cfg, 0.1, 0.0)?;
    let recorded = run_solver(engine.as_ref(), &mut fleet, &cfg)?;
    let path = std::env::temp_dir().join("flanp_trace_replay_demo.csv");
    fleet.write_recorded_trace(&path).map_err(anyhow::Error::msg)?;
    println!(
        "  {} rounds, sim-time {:.1}; recorded {} realized rounds to {}",
        recorded.rounds.len() - 1,
        recorded.total_time,
        fleet.recorded_trace().map_or(0, |d| d.num_rounds()),
        path.display()
    );

    let mut replay_cfg = cfg.clone();
    replay_cfg.record_trace = false;
    replay_cfg.system =
        SystemModel::parse(&format!("trace:{}", path.display()))
            .map_err(anyhow::Error::msg)?;
    println!("== replay: FLANP under {} ==", replay_cfg.system.spec());
    let mut fleet2 = setup::build_fleet(engine.meta(), &replay_cfg, 0.1, 0.0)?;
    let replayed = run_solver(engine.as_ref(), &mut fleet2, &replay_cfg)?;
    println!(
        "  {} rounds, sim-time {:.1}",
        replayed.rounds.len() - 1,
        replayed.total_time
    );

    println!("== diff (recorded vs replayed) ==");
    let mut rows_differing = 0usize;
    let mut max_dt = 0.0f64;
    let mut max_dloss = 0.0f64;
    for (a, b) in recorded.rounds.iter().zip(&replayed.rounds) {
        let dt = (a.time - b.time).abs();
        let dl = (a.loss_full - b.loss_full).abs();
        if dt != 0.0 || dl != 0.0 || a.participants != b.participants {
            rows_differing += 1;
        }
        max_dt = max_dt.max(dt);
        max_dloss = max_dloss.max(dl);
    }
    println!(
        "  rounds: {} vs {} | rows differing: {rows_differing} | \
         max |Δtime|: {max_dt:e} | max |Δloss|: {max_dloss:e}",
        recorded.rounds.len(),
        replayed.rounds.len()
    );
    anyhow::ensure!(
        recorded.rounds.len() == replayed.rounds.len()
            && rows_differing == 0
            && recorded.total_time == replayed.total_time,
        "record → replay diverged"
    );
    println!("  bit-identical: every round, every column.");
    Ok(())
}
