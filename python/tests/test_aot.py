"""AOT pipeline tests: entry catalogs, HLO text emission, manifest shape."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, model as M


def test_catalogs_are_well_formed():
    for name, cat in aot.CATALOGS.items():
        assert len(cat) >= 1
        for spec, b, tau in cat:
            assert b >= 1 and tau >= 1
            assert spec.param_count > 0


def test_entries_cover_design_artifact_kinds():
    ents = aot.entries_for_model(M.logreg(6, 3, l2=0.01), b=4, tau=3)
    kinds = {e.kind for e in ents}
    assert kinds == {"loss", "grad", "step", "round", "proxround", "acc"}
    # linreg has no accuracy artifact
    ents = aot.entries_for_model(M.linreg(5), b=4, tau=3)
    assert {e.kind for e in ents} == {"loss", "grad", "step", "round",
                                      "proxround"}


def test_entry_shapes_match_spec():
    spec = M.logreg(6, 3, l2=0.01)
    ents = {e.kind: e for e in aot.entries_for_model(spec, b=4, tau=3)}
    p = spec.param_count
    grad = ents["grad"]
    assert dict(grad.inputs)["params"] == (p,)
    assert dict(grad.inputs)["x"] == (4, 6)
    assert dict(grad.inputs)["y"] == (4, 3)
    assert dict(grad.outputs)["grad"] == (p,)
    rnd = ents["round"]
    assert dict(rnd.inputs)["xs"] == (3, 4, 6)
    assert dict(rnd.inputs)["ys"] == (3, 4, 3)
    assert dict(rnd.inputs)["eta"] == ()


def test_lower_entry_produces_hlo_text():
    spec = M.linreg(4)
    ents = aot.entries_for_model(spec, b=3, tau=2)
    text = aot.lower_entry(ents[0])  # loss
    assert "HloModule" in text
    assert "ENTRY" in text
    # entry layout must list the flat param vector first
    assert f"f32[{spec.param_count}]" in text


def test_jnp_variant_entries_have_suffix():
    ents = aot.build_entries("quick", jnp_variants=True)
    names = {e.name for e in ents}
    assert "linreg_d8_grad" in names
    assert "linreg_d8_grad_jnp" in names


def test_main_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "arts"
    rc = aot.main(["--out-dir", str(out), "--catalog", "quick",
                   "--only", "linreg_d8_grad,linreg_d8_loss"])
    assert rc == 0
    man = json.loads((out / "manifest.json").read_text())
    assert man["version"] == 1
    names = {a["name"] for a in man["artifacts"]}
    assert names == {"linreg_d8_grad", "linreg_d8_loss"}
    for a in man["artifacts"]:
        f = out / a["file"]
        assert f.exists() and f.stat().st_size > 100
        assert a["sha256_16"]
        assert a["meta"]["param_count"] == 9
    assert man["models"][0]["name"] == "linreg_d8"
