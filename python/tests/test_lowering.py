"""Lowering-equivalence tests: the HLO text we ship must compute the same
function whether the pallas kernels or the plain-jnp path lowered it, and
the lowered artifact must be executable by XLA (compile + run in-process
via jax.jit on the same traced function)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


@pytest.mark.parametrize(
    "spec,b,tau",
    [
        (M.linreg(8), 5, 3),
        (M.logreg(12, 3, l2=0.01), 6, 3),
        (M.mlp(10, 3, (8, 6), l2=0.01), 4, 2),
    ],
    ids=lambda v: getattr(v, "name", str(v)),
)
def test_pallas_and_jnp_entries_agree(spec, b, tau):
    """Every artifact kind computes the same values on both lowerings."""
    ents_p = {e.kind: e for e in aot.entries_for_model(spec, b, tau, True)}
    ents_j = {e.kind: e for e in aot.entries_for_model(spec, b, tau, False)}
    key = jax.random.PRNGKey(1)
    args_by_kind = {}
    p = spec.param_count
    d = spec.d
    yw = b if spec.kind == "linreg" else (b, spec.classes)

    def rnd(key, shape):
        return jax.random.normal(key, shape, jnp.float32) * 0.3

    k = iter(jax.random.split(key, 16))
    params = rnd(next(k), (p,))
    delta = rnd(next(k), (p,))
    x = rnd(next(k), (b, d))
    if spec.kind == "linreg":
        y = rnd(next(k), (b,))
        ys = rnd(next(k), (tau, b))
    else:
        lab = jax.random.randint(next(k), (b,), 0, spec.classes)
        y = jax.nn.one_hot(lab, spec.classes)
        labs = jax.random.randint(next(k), (tau, b), 0, spec.classes)
        ys = jax.nn.one_hot(labs, spec.classes)
    xs = rnd(next(k), (tau, b, d))
    anchor = rnd(next(k), (p,))

    args_by_kind["loss"] = (params, x, y)
    args_by_kind["grad"] = (params, x, y)
    args_by_kind["step"] = (params, delta, x, y, jnp.float32(0.05))
    args_by_kind["round"] = (params, delta, xs, ys, jnp.float32(0.05))
    args_by_kind["proxround"] = (
        params, anchor, xs, ys, jnp.float32(0.05), jnp.float32(0.1),
    )
    if spec.kind != "linreg":
        args_by_kind["acc"] = (params, x, y)

    for kind, args in args_by_kind.items():
        out_p = ents_p[kind].fn(*args)
        out_j = ents_j[kind].fn(*args)
        for a, bv in zip(jax.tree_util.tree_leaves(out_p),
                         jax.tree_util.tree_leaves(out_j)):
            np.testing.assert_allclose(
                a, bv, rtol=5e-3, atol=5e-4,
                err_msg=f"{spec.name}/{kind} pallas != jnp",
            )


def test_lowered_hlo_executes_via_xla():
    """The exact jitted function we lower must run under XLA and match
    its eager evaluation (catches lowering-only bugs)."""
    spec = M.logreg(6, 3, l2=0.01)
    ents = {e.kind: e for e in aot.entries_for_model(spec, b=4, tau=2)}
    ent = ents["round"]
    key = jax.random.PRNGKey(3)
    p = spec.param_count
    ks = jax.random.split(key, 4)
    params = jax.random.normal(ks[0], (p,)) * 0.2
    delta = jnp.zeros((p,))
    xs = jax.random.normal(ks[1], (2, 4, 6))
    ys = jax.nn.one_hot(jax.random.randint(ks[2], (2, 4), 0, 3), 3)
    eager = ent.fn(params, delta, xs, ys, jnp.float32(0.05))
    jitted = jax.jit(ent.fn)(params, delta, xs, ys, jnp.float32(0.05))
    np.testing.assert_allclose(eager[0], jitted[0], rtol=1e-5, atol=1e-6)


def test_hlo_text_has_stable_entry_signature():
    """The manifest contract: parameter order in the HLO entry matches
    the Entry.inputs order (the Rust runtime feeds literals by position)."""
    spec = M.linreg(4)
    ents = {e.kind: e for e in aot.entries_for_model(spec, b=3, tau=2)}
    text = aot.lower_entry(ents["step"])
    # the entry layout line declares the positional parameter signature
    layout = [l for l in text.splitlines()
              if "entry_computation_layout" in l][0]
    # params f32[5], delta f32[5], x f32[3,4], y f32[3], eta f32[]
    assert "f32[5]" in layout
    assert "f32[3,4]" in layout
    # order: the two f32[5] come before the x operand
    assert layout.index("f32[5]") < layout.index("f32[3,4]")
    # parameter(N) declarations must cover all five inputs
    params_decl = [l for l in text.splitlines() if "parameter(" in l]
    assert len(params_decl) >= 5
