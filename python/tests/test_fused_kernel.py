"""L1 correctness: fused elementwise kernels vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gate_update, axpy, bias_relu, ref


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


@settings(max_examples=30, deadline=None)
@given(p=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1),
       eta=st.floats(0.0, 1.0))
def test_gate_update_hypothesis(p, seed, eta):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    w, g, d = rand(k1, (p,)), rand(k2, (p,)), rand(k3, (p,))
    np.testing.assert_allclose(
        gate_update(w, g, d, eta), ref.gate_update(w, g, d, eta),
        rtol=1e-6, atol=1e-6,
    )


@pytest.mark.parametrize("p", [1, 127, 128, 129, 1024, 109386])
def test_gate_update_sizes(p):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(p), 3)
    w, g, d = rand(k1, (p,)), rand(k2, (p,)), rand(k3, (p,))
    np.testing.assert_allclose(
        gate_update(w, g, d, 0.05), ref.gate_update(w, g, d, 0.05),
        rtol=1e-6, atol=1e-6,
    )


def test_gate_update_zero_delta_is_sgd():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w, g = rand(k1, (513,)), rand(k2, (513,))
    z = jnp.zeros_like(w)
    np.testing.assert_allclose(
        gate_update(w, g, z, 0.1), w - 0.1 * g, rtol=1e-6, atol=1e-6
    )


def test_gate_update_shape_mismatch_raises():
    with pytest.raises(ValueError):
        gate_update(jnp.zeros((4,)), jnp.zeros((5,)), jnp.zeros((4,)), 0.1)
    with pytest.raises(ValueError):
        gate_update(jnp.zeros((4, 1)), jnp.zeros((4, 1)), jnp.zeros((4, 1)), 0.1)


@settings(max_examples=20, deadline=None)
@given(p=st.integers(1, 4000), seed=st.integers(0, 2**31 - 1),
       a=st.floats(-2.0, 2.0))
def test_axpy_hypothesis(p, seed, a):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x, y = rand(k1, (p,)), rand(k2, (p,))
    np.testing.assert_allclose(
        axpy(a, x, y), ref.axpy(a, x, y), rtol=1e-6, atol=1e-6
    )


def test_axpy_is_server_update():
    # server update w <- w - eta*gamma*Delta == axpy(-eta*gamma, Delta, w)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    w, delta = rand(k1, (777,)), rand(k2, (777,))
    eta, gamma = 0.05, 1.3
    np.testing.assert_allclose(
        axpy(-eta * gamma, delta, w), w - eta * gamma * delta,
        rtol=1e-6, atol=1e-6,
    )


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 64), n=st.integers(1, 200),
       seed=st.integers(0, 2**31 - 1))
def test_bias_relu_hypothesis(m, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x, b = rand(k1, (m, n)), rand(k2, (n,))
    np.testing.assert_allclose(
        bias_relu(x, b), ref.bias_relu(x, b), rtol=1e-6, atol=1e-6
    )


def test_bias_relu_grad():
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x, b = rand(k1, (9, 33)), rand(k2, (33,))
    got = jax.grad(lambda a, c: jnp.sum(bias_relu(a, c) ** 2), (0, 1))(x, b)
    want = jax.grad(lambda a, c: jnp.sum(ref.bias_relu(a, c) ** 2), (0, 1))(x, b)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-5, atol=1e-5)


def test_bias_relu_nonnegative():
    k1, k2 = jax.random.split(jax.random.PRNGKey(6))
    x, b = rand(k1, (31, 130)), rand(k2, (130,))
    assert float(jnp.min(bias_relu(x, b))) >= 0.0
