"""L1 correctness: Pallas tiled matmul vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; explicit cases pin the MXU-aligned
and ragged-tail paths. This is the CORE correctness signal for Layer 1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, matmul_pallas_raw, ref
from compile.kernels.matmul import vmem_bytes

# f32 matmul over K-length dot products: tolerance scales with K.
def tol(k):
    return dict(rtol=5e-4, atol=1e-4 * max(1.0, k / 128))


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


EXPLICIT_SHAPES = [
    (1, 1, 1),            # degenerate
    (8, 128, 128),        # exactly one VMEM tile
    (128, 128, 128),      # exactly one MXU block
    (256, 384, 128),      # multi-block, divisible
    (130, 257, 65),       # ragged in all three dims
    (50, 784, 128),       # the logreg/mlp layer-1 shape
    (5, 25, 1),           # linreg shape
]


@pytest.mark.parametrize("m,k,n", EXPLICIT_SHAPES)
def test_matmul_explicit(m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 1000 + k + n))
    x, w = rand(kx, (m, k)), rand(kw, (k, n))
    np.testing.assert_allclose(matmul(x, w), ref.matmul(x, w), **tol(k))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 200),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_f32(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x, w = rand(kx, (m, k)), rand(kw, (k, n))
    np.testing.assert_allclose(matmul(x, w), ref.matmul(x, w), **tol(k))


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 80),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_bf16_accumulates_f32(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x, w = rand(kx, (m, k), jnp.bfloat16), rand(kw, (k, n), jnp.bfloat16)
    got = matmul_pallas_raw(x, w, out_dtype=jnp.float32)
    want = ref.matmul(x, w, out_dtype=jnp.float32)
    # bf16 inputs: tolerance driven by the 8-bit mantissa of the inputs,
    # accumulation itself is f32 on both sides.
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (16, 64, 32), (128, 128, 256)])
def test_matmul_block_shape_invariance(bm, bn, bk):
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x, w = rand(kx, (70, 300)), rand(kw, (300, 90))
    got = matmul_pallas_raw(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul(x, w), **tol(300))


def test_matmul_vjp_matches_jnp():
    kx, kw = jax.random.split(jax.random.PRNGKey(3))
    x, w = rand(kx, (17, 33)), rand(kw, (33, 9))

    def f(x, w):
        return jnp.sum(jnp.sin(matmul(x, w)))

    def fr(x, w):
        return jnp.sum(jnp.sin(ref.matmul(x, w)))

    gx, gw = jax.grad(f, (0, 1))(x, w)
    rx, rw = jax.grad(fr, (0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-3, atol=1e-4)


def test_matmul_jittable_and_stable_under_jit():
    kx, kw = jax.random.split(jax.random.PRNGKey(11))
    x, w = rand(kx, (33, 65)), rand(kw, (65, 17))
    eager = matmul(x, w)
    jitted = jax.jit(matmul)(x, w)
    np.testing.assert_allclose(eager, jitted, rtol=1e-6, atol=1e-6)


def test_matmul_shape_mismatch_raises():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 3))
    with pytest.raises(ValueError):
        matmul_pallas_raw(x, w)


def test_default_blockspec_fits_vmem_budget():
    # DESIGN.md §4: default schedule must fit well under 16 MiB/core VMEM.
    assert vmem_bytes() <= 4 * 1024 * 1024


def test_zero_and_identity():
    x = jnp.eye(64, dtype=jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    np.testing.assert_allclose(matmul(x, w), w, rtol=1e-6, atol=1e-6)
    z = jnp.zeros((16, 64))
    np.testing.assert_allclose(matmul(z, w), jnp.zeros((16, 32)), atol=0)
