"""L2 correctness: models over flat params — pallas path vs jnp oracle,
gradients vs jax.grad, FedGATE local-update semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


SPECS = [
    M.linreg(8),
    M.linreg(25),
    M.logreg(16, 4, l2=0.01),
    M.mlp(12, 3, (8, 5), l2=0.01),
]


def data_for(spec, b, seed=0):
    kx, ky, kp = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (b, spec.d))
    if spec.kind == "linreg":
        y = jax.random.normal(ky, (b,))
    else:
        lab = jax.random.randint(ky, (b,), 0, spec.classes)
        y = jax.nn.one_hot(lab, spec.classes)
    p = M.init_params(spec, kp)
    return p, x, y


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_param_count_matches_flatten(spec):
    p, _, _ = data_for(spec, 4)
    assert p.shape == (spec.param_count,)
    layers = M.unflatten(spec, p)
    assert len(layers) == len(spec.layer_dims)
    for (w, b), (i, o) in zip(layers, spec.layer_dims):
        assert w.shape == (i, o) and b.shape == (o,)
    np.testing.assert_allclose(M.flatten(spec, layers), p, atol=0)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_pallas_loss_matches_jnp(spec):
    p, x, y = data_for(spec, 7)
    lp = M.loss(spec, p, x, y, use_pallas=True)
    lj = M.loss(spec, p, x, y, use_pallas=False)
    np.testing.assert_allclose(lp, lj, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_pallas_grad_matches_jnp(spec):
    p, x, y = data_for(spec, 7, seed=1)
    lp, gp = M.loss_and_grad(spec, p, x, y, use_pallas=True)
    lj, gj = M.loss_and_grad(spec, p, x, y, use_pallas=False)
    np.testing.assert_allclose(lp, lj, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gp, gj, rtol=5e-3, atol=5e-4)


def test_linreg_grad_matches_closed_form():
    spec = M.linreg(6)
    p, x, y = data_for(spec, 32, seed=2)
    w, b = p[:6], p[6]
    resid = x @ w + b - y
    gw = x.T @ resid / 32
    gb = jnp.mean(resid)
    _, g = M.loss_and_grad(spec, p, x, y, use_pallas=False)
    np.testing.assert_allclose(g[:6], gw, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(g[6], gb, rtol=1e-5, atol=1e-5)


def test_logreg_l2_adds_mu_convexity():
    # grad of the L2 term alone must be l2 * w (weights, not biases)
    spec = M.logreg(5, 3, l2=0.5)
    spec0 = M.logreg(5, 3, l2=0.0)
    p, x, y = data_for(spec, 9, seed=3)
    _, g = M.loss_and_grad(spec, p, x, y, use_pallas=False)
    _, g0 = M.loss_and_grad(spec0, p, x, y, use_pallas=False)
    diff = g - g0
    nw = 5 * 3
    np.testing.assert_allclose(diff[:nw], 0.5 * p[:nw], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(diff[nw:], jnp.zeros(3), atol=1e-6)


@pytest.mark.parametrize("spec", SPECS[:3], ids=lambda s: s.name)
def test_gate_step_semantics(spec):
    p, x, y = data_for(spec, 5, seed=4)
    delta = 0.01 * jnp.ones_like(p)
    eta = 0.07
    stepped = M.gate_step(spec, p, delta, x, y, eta, use_pallas=False)
    _, g = M.loss_and_grad(spec, p, x, y, use_pallas=False)
    np.testing.assert_allclose(stepped, p - eta * (g - delta),
                               rtol=1e-5, atol=1e-6)


def test_gate_round_equals_sequential_steps():
    spec = M.logreg(6, 3, l2=0.01)
    p, _, _ = data_for(spec, 4, seed=5)
    tau, b = 5, 4
    kx, ky = jax.random.split(jax.random.PRNGKey(6))
    xs = jax.random.normal(kx, (tau, b, spec.d))
    lab = jax.random.randint(ky, (tau, b), 0, spec.classes)
    ys = jax.nn.one_hot(lab, spec.classes)
    delta = 0.02 * jnp.ones_like(p)
    eta = 0.05
    fused = M.gate_round(spec, p, delta, xs, ys, eta, use_pallas=False)
    w = p
    for t in range(tau):
        w = M.gate_step(spec, w, delta, xs[t], ys[t], eta, use_pallas=False)
    np.testing.assert_allclose(fused, w, rtol=1e-5, atol=1e-6)


def test_gate_round_pallas_matches_jnp():
    spec = M.logreg(6, 3, l2=0.01)
    p, _, _ = data_for(spec, 4, seed=7)
    tau, b = 3, 4
    kx, ky = jax.random.split(jax.random.PRNGKey(8))
    xs = jax.random.normal(kx, (tau, b, spec.d))
    ys = jax.nn.one_hot(jax.random.randint(ky, (tau, b), 0, 3), 3)
    delta = jnp.zeros_like(p)
    fp = M.gate_round(spec, p, delta, xs, ys, 0.05, use_pallas=True)
    fj = M.gate_round(spec, p, delta, xs, ys, 0.05, use_pallas=False)
    np.testing.assert_allclose(fp, fj, rtol=5e-3, atol=5e-4)


def test_sgd_round_is_gate_round_with_zero_delta():
    spec = M.linreg(5)
    p, _, _ = data_for(spec, 4, seed=9)
    tau, b = 4, 4
    kx, ky = jax.random.split(jax.random.PRNGKey(10))
    xs = jax.random.normal(kx, (tau, b, 5))
    ys = jax.random.normal(ky, (tau, b))
    np.testing.assert_allclose(
        M.sgd_round(spec, p, xs, ys, 0.03, use_pallas=False),
        M.gate_round(spec, p, jnp.zeros_like(p), xs, ys, 0.03,
                     use_pallas=False),
        atol=0,
    )


def test_prox_round_zero_mu_is_sgd():
    spec = M.logreg(5, 3)
    p, _, _ = data_for(spec, 4, seed=11)
    tau, b = 3, 4
    kx, ky = jax.random.split(jax.random.PRNGKey(12))
    xs = jax.random.normal(kx, (tau, b, 5))
    ys = jax.nn.one_hot(jax.random.randint(ky, (tau, b), 0, 3), 3)
    anchor = p + 1.0
    np.testing.assert_allclose(
        M.prox_round(spec, p, anchor, xs, ys, 0.05, 0.0, use_pallas=False),
        M.sgd_round(spec, p, xs, ys, 0.05, use_pallas=False),
        rtol=1e-6, atol=1e-6,
    )


def test_prox_pulls_towards_anchor():
    spec = M.linreg(4)
    p, x, y = data_for(spec, 8, seed=13)
    anchor = p + 10.0
    xs, ys = x[None], y[None]
    no_prox = M.prox_round(spec, p, anchor, xs, ys, 0.05, 0.0,
                           use_pallas=False)
    with_prox = M.prox_round(spec, p, anchor, xs, ys, 0.05, 5.0,
                             use_pallas=False)
    # proximal term pulls the iterate towards the (larger) anchor
    assert float(jnp.sum(with_prox - no_prox)) > 0


def test_accuracy_perfect_and_zero():
    spec = M.logreg(4, 2)
    # weights that trivially classify x by sign of feature 0
    w = jnp.zeros((4, 2)).at[0, 1].set(10.0).at[0, 0].set(-10.0)
    p = M.flatten(spec, [(w, jnp.zeros(2))])
    x = jnp.array([[1.0, 0, 0, 0], [-1.0, 0, 0, 0]])
    y_right = jax.nn.one_hot(jnp.array([1, 0]), 2)
    y_wrong = jax.nn.one_hot(jnp.array([0, 1]), 2)
    assert float(M.accuracy(spec, p, x, y_right, use_pallas=False)) == 1.0
    assert float(M.accuracy(spec, p, x, y_wrong, use_pallas=False)) == 0.0


def test_mlp_forward_shapes_and_nonlinearity():
    spec = M.mlp(10, 4, (8, 6))
    p, x, _ = data_for(spec, 9, seed=14)
    # He-init biases are zero, which makes a ReLU net positively
    # homogeneous; perturb them so the nonlinearity is observable.
    p = p + 0.1
    out = M.forward(spec, p, x, use_pallas=False)
    assert out.shape == (9, 4)
    # nonlinearity: f(2x) != 2 f(x) for an MLP with ReLU + nonzero biases
    out2 = M.forward(spec, p, 2 * x, use_pallas=False)
    assert not np.allclose(out2, 2 * out)


def test_sgd_descends_on_full_batch():
    spec = M.linreg(6)
    p, x, y = data_for(spec, 64, seed=15)
    l0 = float(M.loss(spec, p, x, y, use_pallas=False))
    w = p
    for _ in range(20):
        w = M.gate_step(spec, w, jnp.zeros_like(w), x, y, 0.1,
                        use_pallas=False)
    l1 = float(M.loss(spec, w, x, y, use_pallas=False))
    assert l1 < l0 * 0.5
