"""AOT pipeline: lower every Layer-2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage (invoked by ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts [--catalog full]

Outputs ``<artifact>.hlo.txt`` per entry point plus ``manifest.json``
describing every artifact's inputs/outputs so the Rust runtime can load
and invoke them without any knowledge of the python side.
"""

import argparse
import hashlib
import json
import os
import sys
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclass
class Entry:
    """One lowerable entry point: fn + example input specs."""

    name: str
    fn: Callable
    inputs: List[Tuple[str, Tuple[int, ...]]]   # (name, shape), all f32
    outputs: List[Tuple[str, Tuple[int, ...]]]
    kind: str                                   # grad|loss|step|round|...
    model: str
    meta: Dict

    def specs(self):
        return [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in self.inputs]


def _y_shape(spec: M.ModelSpec, b: int) -> Tuple[int, ...]:
    return (b,) if spec.kind == "linreg" else (b, spec.classes)


def entries_for_model(
    spec: M.ModelSpec, b: int, tau: int, use_pallas: bool = True
) -> List[Entry]:
    """The full artifact set for one model variant (DESIGN.md §2 table)."""
    p = spec.param_count
    d = spec.d
    ys = _y_shape(spec, b)
    name = spec.name
    meta = {"batch": b, "tau": tau, "pallas": use_pallas, **spec.to_json()}
    suffix = "" if use_pallas else "_jnp"

    def wrap1(f):
        # Return single-output entry points as 1-tuples for a uniform ABI.
        return lambda *a: (f(*a),)

    ents = [
        Entry(
            f"{name}_loss{suffix}",
            wrap1(lambda w, x, y: M.loss(spec, w, x, y, use_pallas=use_pallas)),
            [("params", (p,)), ("x", (b, d)), ("y", ys)],
            [("loss", ())],
            "loss", name, meta,
        ),
        Entry(
            f"{name}_grad{suffix}",
            lambda w, x, y: M.loss_and_grad(spec, w, x, y, use_pallas=use_pallas),
            [("params", (p,)), ("x", (b, d)), ("y", ys)],
            [("loss", ()), ("grad", (p,))],
            "grad", name, meta,
        ),
        Entry(
            f"{name}_step{suffix}",
            wrap1(lambda w, dl, x, y, eta: M.gate_step(
                spec, w, dl, x, y, eta, use_pallas=use_pallas)),
            [("params", (p,)), ("delta", (p,)), ("x", (b, d)), ("y", ys),
             ("eta", ())],
            [("params", (p,))],
            "step", name, meta,
        ),
        Entry(
            f"{name}_round_t{tau}{suffix}",
            wrap1(lambda w, dl, xs, ys_, eta: M.gate_round(
                spec, w, dl, xs, ys_, eta, use_pallas=use_pallas)),
            [("params", (p,)), ("delta", (p,)), ("xs", (tau, b, d)),
             ("ys", (tau,) + ys), ("eta", ())],
            [("params", (p,))],
            "round", name, meta,
        ),
        Entry(
            f"{name}_proxround_t{tau}{suffix}",
            wrap1(lambda w, anchor, xs, ys_, eta, pm: M.prox_round(
                spec, w, anchor, xs, ys_, eta, pm, use_pallas=use_pallas)),
            [("params", (p,)), ("anchor", (p,)), ("xs", (tau, b, d)),
             ("ys", (tau,) + ys), ("eta", ()), ("prox_mu", ())],
            [("params", (p,))],
            "proxround", name, meta,
        ),
    ]
    if spec.kind != "linreg":
        ents.append(
            Entry(
                f"{name}_acc{suffix}",
                wrap1(lambda w, x, y: M.accuracy(
                    spec, w, x, y, use_pallas=use_pallas)),
                [("params", (p,)), ("x", (b, d)), ("y", ys)],
                [("acc", ())],
                "acc", name, meta,
            )
        )
    return ents


# ---------------------------------------------------------------------------
# catalogs — which model variants ship as artifacts
# ---------------------------------------------------------------------------

# (spec, batch, tau). Batch is static per artifact; a client's s samples
# are chunked/sampled by the Rust coordinator. tau is the fused-round
# length (Theorem 1's tau is O(s); the experiments use modest tau).
CATALOGS: Dict[str, List[Tuple[M.ModelSpec, int, int]]] = {
    # quick: small shapes for fast artifact builds in CI / unit tests.
    "quick": [
        (M.linreg(8), 5, 4),
        (M.logreg(16, 4, l2=0.01), 8, 4),
    ],
    # full: everything the paper's figures need (DESIGN.md §5).
    "full": [
        (M.linreg(25), 10, 10),                       # Fig 2, 7, 8; Tab 1-2
        (M.logreg(784, 10, l2=0.01), 50, 10),          # Fig 1
        (M.mlp(784, 10, (128, 64), l2=0.01), 50, 10),  # Fig 3, 5, 6, 9
        (M.mlp(512, 10, (128, 64), l2=0.01), 50, 10),  # Fig 4 (cifar-like)
    ],
}


def build_entries(catalog: str, jnp_variants: bool = False) -> List[Entry]:
    ents: List[Entry] = []
    for spec, b, tau in CATALOGS[catalog]:
        ents.extend(entries_for_model(spec, b, tau, use_pallas=True))
        if jnp_variants:
            ents.extend(entries_for_model(spec, b, tau, use_pallas=False))
    return ents


def lower_entry(ent: Entry) -> str:
    lowered = jax.jit(ent.fn).lower(*ent.specs())
    return to_hlo_text(lowered)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--catalog", default="full", choices=sorted(CATALOGS))
    ap.add_argument(
        "--jnp-variants", action="store_true",
        help="also emit pure-jnp (no-pallas) artifact variants "
             "(perf-pass ablation)",
    )
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name filter")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    ents = build_entries(args.catalog, args.jnp_variants)
    if args.only:
        keep = set(args.only.split(","))
        ents = [e for e in ents if e.name in keep]

    manifest = {"version": 1, "catalog": args.catalog, "artifacts": [],
                "models": []}
    seen_models = {}
    t_all = time.time()
    for ent in ents:
        t0 = time.time()
        text = lower_entry(ent)
        fname = f"{ent.name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"].append(
            {
                "name": ent.name,
                "file": fname,
                "kind": ent.kind,
                "model": ent.model,
                "inputs": [{"name": n, "shape": list(s)} for n, s in ent.inputs],
                "outputs": [{"name": n, "shape": list(s)} for n, s in ent.outputs],
                "meta": ent.meta,
                "sha256_16": digest,
            }
        )
        if ent.model not in seen_models:
            seen_models[ent.model] = {**ent.meta}
        print(
            f"  lowered {ent.name:<42} {len(text):>9} chars "
            f"in {time.time() - t0:5.1f}s",
            flush=True,
        )
    manifest["models"] = [
        {"name": k, **v} for k, v in sorted(seen_models.items())
    ]
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(ents)} artifacts + manifest.json "
          f"to {args.out_dir} in {time.time() - t_all:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
