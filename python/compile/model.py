"""Layer-2: the paper's models as JAX fwd/bwd over FLAT parameter vectors.

Every function here operates on a single flat ``f32[P]`` parameter vector
so the Rust coordinator (Layer 3) can treat all models uniformly — it
never needs to know the parameter structure; (un)flattening is owned here
and baked into the lowered HLO.

Models (Section 5 of the paper):
- ``linreg``  — linear regression,   loss = 0.5*mean((Xw + b - y)^2) + 0.5*l2*|w|^2
- ``logreg``  — multiclass logistic regression (softmax xent + L2; the L2
                term supplies the strong convexity mu = l2 the FLANP
                stopping rule needs)
- ``mlp``     — fully connected net with two hidden layers (128, 64) and
                ReLU, exactly the architecture of Figures 3-5.

Entry points lowered to HLO artifacts (aot.py):
- ``loss(params, X, Y) -> loss``
- ``grad(params, X, Y) -> (loss, grad)``          [stopping rule, FedAvg]
- ``gate_step(params, delta, X, Y, eta) -> params``     [one local update]
- ``gate_round(params, delta, Xs, Ys, eta) -> params``  [tau fused updates
  via lax.scan — the hot-path artifact]

The dense matmuls route through the Layer-1 Pallas kernel
(``kernels.matmul``); the FedGATE update through ``kernels.gate_update``.
Set ``use_pallas=False`` to emit a pure-jnp variant (used by tests as an
oracle and by the perf pass as an ablation).
"""

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul as _pallas_matmul
from .kernels import gate_update as _pallas_gate_update
from .kernels import ref as _ref


# ---------------------------------------------------------------------------
# model specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelSpec:
    """Static description of one model variant (shapes + regularization)."""

    kind: str                 # "linreg" | "logreg" | "mlp"
    d: int                    # input features
    classes: int = 1          # output classes (1 for regression)
    hidden: Tuple[int, ...] = ()   # hidden layer widths (mlp only)
    l2: float = 0.0           # L2 regularization coefficient (= mu)

    @property
    def name(self) -> str:
        h = "".join(f"_h{w}" for w in self.hidden)
        c = f"_c{self.classes}" if self.kind != "linreg" else ""
        return f"{self.kind}_d{self.d}{c}{h}"

    @property
    def layer_dims(self) -> List[Tuple[int, int]]:
        """(in, out) of each dense layer, in order."""
        if self.kind == "linreg":
            return [(self.d, 1)]
        if self.kind == "logreg":
            return [(self.d, self.classes)]
        dims = []
        prev = self.d
        for h in self.hidden:
            dims.append((prev, h))
            prev = h
        dims.append((prev, self.classes))
        return dims

    @property
    def param_count(self) -> int:
        return sum(i * o + o for i, o in self.layer_dims)

    def to_json(self) -> Dict:
        return {
            "kind": self.kind,
            "d": self.d,
            "classes": self.classes,
            "hidden": list(self.hidden),
            "l2": self.l2,
            "name": self.name,
            "param_count": self.param_count,
        }


def linreg(d: int, l2: float = 0.0) -> ModelSpec:
    return ModelSpec("linreg", d=d, classes=1, l2=l2)


def logreg(d: int, classes: int, l2: float = 0.0) -> ModelSpec:
    return ModelSpec("logreg", d=d, classes=classes, l2=l2)


def mlp(d: int, classes: int, hidden=(128, 64), l2: float = 0.0) -> ModelSpec:
    return ModelSpec("mlp", d=d, classes=classes, hidden=tuple(hidden), l2=l2)


# ---------------------------------------------------------------------------
# flat <-> structured parameters
# ---------------------------------------------------------------------------


def unflatten(spec: ModelSpec, flat):
    """Split flat f32[P] into [(W_l, b_l)] per layer_dims."""
    params = []
    off = 0
    for i, o in spec.layer_dims:
        w = flat[off : off + i * o].reshape(i, o)
        off += i * o
        b = flat[off : off + o]
        off += o
        params.append((w, b))
    return params


def flatten(spec: ModelSpec, params) -> jnp.ndarray:
    pieces = []
    for w, b in params:
        pieces.append(w.reshape(-1))
        pieces.append(b.reshape(-1))
    return jnp.concatenate(pieces)


def init_params(spec: ModelSpec, key) -> jnp.ndarray:
    """He-init flat parameter vector (matches rust util::init_he)."""
    chunks = []
    for i, o in spec.layer_dims:
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / i)
        chunks.append((jax.random.normal(sub, (i, o)) * scale).reshape(-1))
        chunks.append(jnp.zeros((o,)))
    return jnp.concatenate(chunks).astype(jnp.float32)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _mm(use_pallas: bool):
    return _pallas_matmul if use_pallas else _ref.matmul


def forward(spec: ModelSpec, flat, x, *, use_pallas: bool = True):
    """Model forward pass: logits f32[b, C] (or predictions f32[b, 1])."""
    mm = _mm(use_pallas)
    layers = unflatten(spec, flat)
    h = x
    for li, (w, b) in enumerate(layers):
        h = mm(h, w) + b
        if li + 1 < len(layers):  # hidden layers: ReLU
            h = jnp.maximum(h, 0.0)
    return h


def _l2_term(spec: ModelSpec, flat):
    # Regularize weights only (not biases) — matches the Rust NativeEngine.
    sq = 0.0
    for w, _ in unflatten(spec, flat):
        sq = sq + jnp.sum(w * w)
    return 0.5 * spec.l2 * sq


def loss(spec: ModelSpec, flat, x, y, *, use_pallas: bool = True):
    """Mean loss over the batch + L2. y: f32[b] (linreg) or one-hot f32[b,C]."""
    out = forward(spec, flat, x, use_pallas=use_pallas)
    if spec.kind == "linreg":
        resid = out[:, 0] - y
        data = 0.5 * jnp.mean(resid * resid)
    else:
        logp = jax.nn.log_softmax(out, axis=-1)
        data = -jnp.mean(jnp.sum(y * logp, axis=-1))
    return data + _l2_term(spec, flat)


def loss_and_grad(spec: ModelSpec, flat, x, y, *, use_pallas: bool = True):
    """(loss, grad) with grad flat f32[P]."""
    return jax.value_and_grad(
        lambda p: loss(spec, p, x, y, use_pallas=use_pallas)
    )(flat)


def accuracy(spec: ModelSpec, flat, x, y, *, use_pallas: bool = True):
    """Classification accuracy (y one-hot). Lowered for eval artifacts."""
    out = forward(spec, flat, x, use_pallas=use_pallas)
    pred = jnp.argmax(out, axis=-1)
    lab = jnp.argmax(y, axis=-1)
    return jnp.mean((pred == lab).astype(jnp.float32))


# ---------------------------------------------------------------------------
# FedGATE local updates (Algorithm 2 inner loop)
# ---------------------------------------------------------------------------


def gate_step(spec: ModelSpec, flat, delta, x, y, eta, *, use_pallas: bool = True):
    """One corrected local step:  w <- w - eta * (grad(w; x, y) - delta)."""
    _, g = loss_and_grad(spec, flat, x, y, use_pallas=use_pallas)
    if use_pallas:
        return _pallas_gate_update(flat, g, delta, eta)
    return _ref.gate_update(flat, g, delta, eta)


def gate_round(spec: ModelSpec, flat, delta, xs, ys, eta, *, use_pallas: bool = True):
    """tau fused local steps via lax.scan — the hot-path artifact.

    xs: f32[tau, b, d]; ys: f32[tau, b] or f32[tau, b, C]. The scan keeps
    the whole round in one executable so the Rust hot loop pays a single
    PJRT dispatch per (client, round) instead of tau.
    """

    def body(w, batch):
        xb, yb = batch
        return gate_step(spec, w, delta, xb, yb, eta, use_pallas=use_pallas), None

    out, _ = jax.lax.scan(body, flat, (xs, ys))
    return out


def sgd_round(spec: ModelSpec, flat, xs, ys, eta, *, use_pallas: bool = True):
    """tau plain SGD steps (FedAvg / FedNova local work; delta == 0)."""
    zero = jnp.zeros_like(flat)
    return gate_round(spec, flat, zero, xs, ys, eta, use_pallas=use_pallas)


def prox_step(spec: ModelSpec, flat, anchor, x, y, eta, prox_mu,
              *, use_pallas: bool = True):
    """FedProx local step: grad of loss + (prox_mu/2)*|w - anchor|^2."""
    _, g = loss_and_grad(spec, flat, x, y, use_pallas=use_pallas)
    g = g + prox_mu * (flat - anchor)
    if use_pallas:
        return _pallas_gate_update(flat, g, jnp.zeros_like(flat), eta)
    return _ref.gate_update(flat, g, jnp.zeros_like(flat), eta)


def prox_round(spec: ModelSpec, flat, anchor, xs, ys, eta, prox_mu,
               *, use_pallas: bool = True):
    def body(w, batch):
        xb, yb = batch
        return (
            prox_step(spec, w, anchor, xb, yb, eta, prox_mu,
                      use_pallas=use_pallas),
            None,
        )

    out, _ = jax.lax.scan(body, flat, (xs, ys))
    return out
