"""Fused elementwise Pallas kernels for the FedGATE hot loop.

These kernels fuse the memory-bound elementwise tails of the local update
so each parameter vector makes exactly one HBM round-trip per step:

- ``gate_update``:  w_new = w - eta * (g - delta)   (Algorithm 2, line
  "set d_i = grad - delta_i; update w_i = w_i - eta * d_i")
- ``axpy``:         out = a * x + y                 (server model update
  w <- w - eta*gamma*Delta is axpy with a = -eta*gamma)
- ``bias_relu``:    out = max(x + b, 0)             (MLP epilogue; fused
  bias-add + activation so the matmul output tile is consumed in VMEM)

All are 1-D/2-D blocked over 128-lane tiles and run interpret=True (see
matmul.py for the rationale).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
# Elementwise block: (8, 128) f32 VMEM tile times 8 sublanes of headroom.
BLOCK = 8 * LANES


def _ceil_to(v: int, b: int) -> int:
    return -(-v // b) * b


def _pad1(a, n):
    return a if a.shape[0] == n else jnp.pad(a, (0, n - a.shape[0]))


def _gate_kernel(w_ref, g_ref, d_ref, eta_ref, o_ref):
    # eta arrives as a (1,)-shaped operand so the same artifact serves all
    # stage stepsizes (FLANP re-tunes eta_n per stage, Theorem 1).
    o_ref[...] = w_ref[...] - eta_ref[0] * (g_ref[...] - d_ref[...])


def gate_update(w, g, delta, eta, *, block: int = BLOCK):
    """Fused FedGATE local update ``w - eta * (g - delta)`` (flat f32[P]).

    ``eta`` may be a python float or a scalar/1-element array.
    """
    if w.shape != g.shape or w.shape != delta.shape or w.ndim != 1:
        raise ValueError(
            f"gate_update wants flat equal shapes, got {w.shape} {g.shape} "
            f"{delta.shape}"
        )
    (p,) = w.shape
    eta = jnp.asarray(eta, dtype=w.dtype).reshape((1,))
    block = min(block, _ceil_to(p, LANES))
    pp = _ceil_to(p, block)
    wp, gp, dp = _pad1(w, pp), _pad1(g, pp), _pad1(delta, pp)

    out = pl.pallas_call(
        _gate_kernel,
        grid=(pp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            # eta is broadcast to every grid step (block index 0).
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), w.dtype),
        interpret=True,
    )(wp, gp, dp, eta)
    return out[:p] if pp != p else out


def _axpy_kernel(x_ref, y_ref, a_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


def axpy(a, x, y, *, block: int = BLOCK):
    """Fused ``a * x + y`` over flat vectors (server-side model update)."""
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"axpy wants flat equal shapes, got {x.shape} {y.shape}")
    (p,) = x.shape
    a = jnp.asarray(a, dtype=x.dtype).reshape((1,))
    block = min(block, _ceil_to(p, LANES))
    pp = _ceil_to(p, block)
    xp, yp = _pad1(x, pp), _pad1(y, pp)
    out = pl.pallas_call(
        _axpy_kernel,
        grid=(pp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), x.dtype),
        interpret=True,
    )(xp, yp, a)
    return out[:p] if pp != p else out


def _bias_relu_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] + b_ref[...], 0.0)


def _bias_relu_fwd_impl(x, b, *, bm: int = 8, bn: int = LANES):
    m, n = x.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, LANES if n >= LANES else 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = x if (m, n) == (mp, np_) else jnp.pad(x, ((0, mp - m), (0, np_ - n)))
    bp = _pad1(b, np_)
    out = pl.pallas_call(
        _bias_relu_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, bp)
    return out[:m, :n] if (mp, np_) != (m, n) else out


@jax.custom_vjp
def bias_relu(x, b):
    """Fused ``relu(x + b)`` for (batch, features) activations."""
    return _bias_relu_fwd_impl(x, b)


def _bias_relu_fwd(x, b):
    y = _bias_relu_fwd_impl(x, b)
    return y, y  # relu mask recoverable from the output sign


def _bias_relu_bwd(y, gy):
    mask = (y > 0).astype(gy.dtype)
    gx = gy * mask
    return gx, jnp.sum(gx, axis=0)


bias_relu.defvjp(_bias_relu_fwd, _bias_relu_bwd)
